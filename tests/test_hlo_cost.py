"""Trip-count-aware HLO cost analysis vs XLA's own (on unrolled graphs)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.hlo_cost import analyze, shape_bytes


def _scan_matmul(n, unroll=1):
    def f(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        y, _ = jax.lax.scan(body, x, None, length=n, unroll=unroll)
        return y
    return f


X = jax.ShapeDtypeStruct((256, 256), jnp.float32)
W = jax.ShapeDtypeStruct((256, 256), jnp.float32)


@pytest.mark.parametrize("n", [1, 7, 23])
def test_trip_count_multiplication(n):
    c = jax.jit(_scan_matmul(n)).lower(X, W).compile()
    cost = analyze(c.as_text())
    assert cost.flops == pytest.approx(n * 2 * 256**3, rel=1e-6)


def test_matches_xla_on_unrolled():
    c = jax.jit(_scan_matmul(6, unroll=6)).lower(X, W).compile()
    xla = c.cost_analysis()
    if isinstance(xla, (list, tuple)):  # jax 0.4.x returns [dict]
        xla = xla[0]
    mine = analyze(c.as_text())
    assert mine.flops == pytest.approx(float(xla["flops"]), rel=1e-6)
    if jax.__version_info__ >= (0, 5):
        # jax 0.4.x HLO contains unfused scan-boundary copies that XLA's own
        # "bytes accessed" excludes; the byte comparison only holds on the
        # cleaner HLO newer versions emit.
        assert mine.bytes == pytest.approx(float(xla["bytes accessed"]),
                                           rel=0.05)
    else:
        assert mine.bytes >= float(xla["bytes accessed"])


def test_nested_scans_multiply():
    def f(x, w):
        def outer(c, _):
            def inner(ci, _):
                return ci @ w, None
            y, _ = jax.lax.scan(inner, c, None, length=3)
            return y, None
        y, _ = jax.lax.scan(outer, x, None, length=5)
        return y
    c = jax.jit(f).lower(X, W).compile()
    cost = analyze(c.as_text())
    assert cost.flops == pytest.approx(15 * 2 * 256**3, rel=1e-6)


def test_grad_flops_roughly_3x_forward():
    def loss(w, x):
        return jnp.sum(jnp.tanh(x @ w) ** 2)
    gf = jax.jit(jax.grad(loss))
    cf = gf.lower(W, X).compile()
    cost_bwd = analyze(cf.as_text())
    cost_fwd = analyze(jax.jit(loss).lower(W, X).compile().as_text())
    ratio = cost_bwd.flops / cost_fwd.flops
    assert 2.0 <= ratio <= 4.0


def test_shape_bytes_parsing():
    assert shape_bytes("f32[16,16]{1,0}") == 1024
    assert shape_bytes("bf16[8]") == 16
    assert shape_bytes("(s32[], f32[4,4]{1,0})") == 4 + 64
    assert shape_bytes("pred[10]") == 10


def test_collective_accounting_in_loops():
    """A psum inside a scan must count trip-count times."""
    from conftest import run_in_subprocess
    code = r"""
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from jax import shard_map
from repro.launch.hlo_cost import analyze

mesh = jax.make_mesh((4,), ("m",), axis_types=(jax.sharding.AxisType.Auto,))

def f(x):
    def body(c, _):
        return jax.lax.pvary(jax.lax.psum(c, "m") * 0.25, ("m",)), None
    y, _ = jax.lax.scan(body, x, None, length=9)
    return y

g = shard_map(f, mesh=mesh, in_specs=P("m"), out_specs=P("m"))
c = jax.jit(g).lower(jax.ShapeDtypeStruct((64,), jnp.float32)).compile()
cost = analyze(c.as_text(), 4)
per = 2 * (16 * 4) * (3 / 4)  # all-reduce of 16 f32 per device, ring factor
expected = 9 * per
assert abs(cost.collective_bytes - expected) / expected < 0.05, (
    cost.collective_bytes, expected)
print("collective ok", cost.collective_bytes)
"""
    out = run_in_subprocess(code, n_devices=4)
    assert "collective ok" in out
