"""Minimal stand-in for ``hypothesis`` when it isn't installed.

Property tests degrade gracefully: ``@given`` draws ``max_examples``
pseudo-random samples from each strategy (seeded, so failures reproduce)
and calls the test once per sample. No shrinking, no database, no
``@example`` — install the real package (see requirements-dev.txt) for
those. Only the strategy surface this repo uses is implemented.
"""

from __future__ import annotations

import functools
import inspect
import random
from typing import Any, Callable, Sequence

_DEFAULT_MAX_EXAMPLES = 20


class _Strategy:
    def __init__(self, sample: Callable[[random.Random], Any]):
        self._sample = sample

    def draw(self, rng: random.Random) -> Any:
        return self._sample(rng)


class strategies:  # mirrors `from hypothesis import strategies as st`
    @staticmethod
    def integers(min_value: int, max_value: int) -> _Strategy:
        return _Strategy(lambda rng: rng.randint(min_value, max_value))

    @staticmethod
    def sampled_from(elements: Sequence[Any]) -> _Strategy:
        seq = list(elements)
        return _Strategy(lambda rng: seq[rng.randrange(len(seq))])

    @staticmethod
    def floats(min_value: float, max_value: float, **_kw) -> _Strategy:
        return _Strategy(lambda rng: rng.uniform(min_value, max_value))

    @staticmethod
    def booleans() -> _Strategy:
        return _Strategy(lambda rng: rng.random() < 0.5)


st = strategies


def settings(max_examples: int = _DEFAULT_MAX_EXAMPLES, **_ignored):
    """Accepts and ignores everything but max_examples (deadline etc.)."""

    def deco(fn):
        fn._fallback_max_examples = max_examples
        return fn

    return deco


def given(**strats: _Strategy):
    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            n = getattr(wrapper, "_fallback_max_examples",
                        getattr(fn, "_fallback_max_examples",
                                _DEFAULT_MAX_EXAMPLES))
            rng = random.Random(0xC0FFEE)
            for _ in range(n):
                drawn = {k: s.draw(rng) for k, s in strats.items()}
                fn(*args, **kwargs, **drawn)

        # pytest must not see the drawn params as fixtures: hide the wrapped
        # signature (keep only params not supplied by strategies).
        params = [p for name, p in
                  inspect.signature(fn).parameters.items()
                  if name not in strats]
        wrapper.__signature__ = inspect.Signature(params)
        del wrapper.__wrapped__
        return wrapper

    return deco
