"""Ring (blocks-mode) collectives vs unchunked references, on 8 fake
devices in a subprocess (XLA device count is locked at first jax init)."""

from conftest import run_in_subprocess

_CODE = r"""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from jax import shard_map
from repro.core import pipeline_collectives as pc

mesh = jax.make_mesh((8,), ("m",),
                     axis_types=(jax.sharding.AxisType.Auto,))
x = jnp.arange(8 * 4 * 6, dtype=jnp.float32).reshape(32, 6) / 100.0
w = jnp.arange(6 * 10, dtype=jnp.float32).reshape(6, 10) / 50.0

f = shard_map(lambda a: pc.ring_all_gather(a, "m", axis=0), mesh=mesh,
              in_specs=P("m", None), out_specs=P("m", None))
out = np.asarray(jax.device_get(f(x)))
for i in range(8):
    np.testing.assert_allclose(out[i * 32:(i + 1) * 32], np.asarray(x),
                               rtol=1e-6)
print("ag ok")

xr = jnp.arange(8 * 16 * 5, dtype=jnp.float32).reshape(8, 16, 5) / 100.0
f2 = shard_map(lambda a: pc.ring_reduce_scatter(a[0], "m", axis=0),
               mesh=mesh, in_specs=P("m", None, None), out_specs=P("m", None))
np.testing.assert_allclose(np.asarray(jax.device_get(f2(xr))),
                           np.asarray(xr).sum(0), rtol=1e-5)
print("rs ok")

f3 = shard_map(lambda a, b: pc.overlapped_matmul_ag(a, b, "m"), mesh=mesh,
               in_specs=(P("m", None), P(None, None)),
               out_specs=P("m", None))
out3 = np.asarray(jax.device_get(f3(x, w)))
ref3 = np.asarray(x) @ np.asarray(w)
for i in range(8):
    np.testing.assert_allclose(out3[i * 32:(i + 1) * 32], ref3, rtol=1e-5)
print("mm-ag ok")

xm = jnp.arange(16 * 24, dtype=jnp.float32).reshape(16, 24) / 100.0
wm = jnp.arange(24 * 10, dtype=jnp.float32).reshape(24, 10) / 50.0
f4 = shard_map(lambda a, b: pc.overlapped_matmul_rs(a, b, "m"), mesh=mesh,
               in_specs=(P(None, "m"), P("m", None)), out_specs=P("m", None))
np.testing.assert_allclose(np.asarray(jax.device_get(f4(xm, wm))),
                           np.asarray(xm) @ np.asarray(wm), rtol=1e-5)
print("mm-rs ok")

# equivalence with lax collectives
from jax import lax
g1 = shard_map(lambda a: lax.all_gather(a, "m", axis=0, tiled=True),
               mesh=mesh, in_specs=P("m", None), out_specs=P("m", None))
np.testing.assert_allclose(out, np.asarray(jax.device_get(g1(x))), rtol=1e-6)
g2 = shard_map(lambda a: lax.psum_scatter(a[0], "m", scatter_dimension=0,
                                          tiled=True),
               mesh=mesh, in_specs=P("m", None, None), out_specs=P("m", None))
np.testing.assert_allclose(np.asarray(jax.device_get(f2(xr))),
                           np.asarray(jax.device_get(g2(xr))), rtol=1e-5)
print("lax-equiv ok")
"""


def test_ring_collectives_match_references():
    out = run_in_subprocess(_CODE)
    for tag in ("ag ok", "rs ok", "mm-ag ok", "mm-rs ok", "lax-equiv ok"):
        assert tag in out
