"""Three-way-overlap streaming executor: correctness and overlap behaviour."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.streaming import HostStreamingExecutor
from repro.core.transfer import (
    Buffering,
    Management,
    Partitioning,
    TransferEngine,
    TransferPolicy,
)


def _layers(n, d, key):
    out = []

    def apply_fn(params, x):
        w, b = params
        return jnp.tanh(x @ w + b)

    jitted = jax.jit(apply_fn)
    for i in range(n):
        key, k = jax.random.split(key)
        w = np.asarray(jax.random.normal(k, (d, d)) * 0.1, np.float32)
        b = np.zeros(d, np.float32)
        out.append((f"l{i}", [w, b], jitted))
    return out


def _reference(layers, x):
    y = jnp.asarray(x)
    for _, (w, b), fn in layers:
        y = fn([jnp.asarray(w), jnp.asarray(b)], y)
    return np.asarray(y)


@pytest.mark.parametrize("policy", [
    TransferPolicy.user_level_polling(),
    TransferPolicy.kernel_level(),
    TransferPolicy(Management.INTERRUPT, Buffering.DOUBLE, Partitioning.UNIQUE),
    TransferPolicy.kernel_level_ring(3),
    TransferPolicy.kernel_level_ring(5, block_bytes=1 << 14),
], ids=lambda p: p.tag)
def test_streamed_equals_reference(policy):
    layers = _layers(5, 64, jax.random.PRNGKey(0))
    x = np.random.rand(2, 64).astype(np.float32)
    eng = TransferEngine(policy)
    out, timing = HostStreamingExecutor(eng).run(layers, x)
    np.testing.assert_allclose(out, _reference(layers, x), rtol=1e-5, atol=1e-5)
    assert len(timing.layers) == 5
    assert all(l.rx_bytes > 0 for l in timing.layers)
    eng.close()


def test_second_frame_hits_layout_cache():
    layers = _layers(4, 32, jax.random.PRNGKey(1))
    x = np.random.rand(2, 32).astype(np.float32)
    eng = TransferEngine(TransferPolicy.kernel_level_ring(4))
    ex = HostStreamingExecutor(eng)
    out1, _ = ex.run(layers, x)
    assert eng.layouts.misses == 4 and eng.layouts.hits == 0
    out2, _ = ex.run(layers, x)
    assert eng.layouts.misses == 4 and eng.layouts.hits == 4  # no re-derive
    # the frame result must be a FRESH array each run (interior layers
    # reuse zero-copy RX buffers, the final layer never does)
    assert out1 is not out2
    np.testing.assert_allclose(out1, out2, rtol=1e-6, atol=1e-6)
    # steady state: the host params are the same objects -> zero pack copies
    for key in [(i, f"l{i}") for i in range(4)]:
        lay = eng.layouts._layouts[key]
        assert lay.pack_count == 2 and lay.copy_count == 1
    eng.close()


def test_overlapped_rx_returns_final_layer_output():
    """The async-RX pipeline must hand back the LAST layer's fmap, not a
    stale earlier ticket."""
    layers = _layers(6, 48, jax.random.PRNGKey(2))
    x = np.random.rand(3, 48).astype(np.float32)
    eng = TransferEngine(TransferPolicy.kernel_level_ring(4))
    out, timing = HostStreamingExecutor(eng).run(layers, x)
    np.testing.assert_allclose(out, _reference(layers, x), rtol=1e-5, atol=1e-5)
    eng.close()


def test_staged_false_matches_staged_true():
    """The legacy baseline path and the ring path are numerically identical."""
    layers = _layers(4, 32, jax.random.PRNGKey(3))
    x = np.random.rand(2, 32).astype(np.float32)
    outs = []
    for staged in (True, False):
        eng = TransferEngine(TransferPolicy(
            Management.INTERRUPT, Buffering.DOUBLE, Partitioning.UNIQUE))
        out, _ = HostStreamingExecutor(eng, staged=staged).run(layers, x)
        outs.append(out)
        eng.close()
    np.testing.assert_array_equal(outs[0], outs[1])


def test_single_layer_and_empty_edge_cases():
    eng = TransferEngine(TransferPolicy.kernel_level_ring(4))
    layers = _layers(1, 16, jax.random.PRNGKey(4))
    x = np.random.rand(1, 16).astype(np.float32)
    out, timing = HostStreamingExecutor(eng).run(layers, x)
    np.testing.assert_allclose(out, _reference(layers, x), rtol=1e-5,
                               atol=1e-5)
    assert len(timing.layers) == 1
    eng.close()


@pytest.mark.parametrize("staged", [True, False])
def test_empty_layer_list_returns_transferred_input(staged):
    """Zero layers must hand back the round-tripped input, not None (the
    overlapped path used to fall off the end with host_out=None)."""
    eng = TransferEngine(TransferPolicy.kernel_level_ring(4))
    x = np.random.rand(3, 8).astype(np.float32)
    out, timing = HostStreamingExecutor(eng, staged=staged).run([], x)
    assert out is not None
    np.testing.assert_array_equal(np.asarray(out).reshape(x.shape), x)
    assert timing.layers == []
    eng.close()
