"""Per-arch smoke tests (reduced configs) + decode/forward consistency."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import ARCHS, get_config, smoke_config
from repro.models.api import build_model, input_specs
from repro.models.config import SHAPE_CELLS, cell_applicable

KEY = jax.random.PRNGKey(0)


def make_batch(cfg, b=2, s=32, key=KEY):
    toks = jax.random.randint(key, (b, s), 0, cfg.vocab)
    if cfg.family == "audio":
        return {"frames": jax.random.normal(key, (b, 16, cfg.d_model)),
                "tokens": toks, "labels": toks}
    if cfg.family == "vlm":
        return {"tokens": toks,
                "patch_embeds": jax.random.normal(
                    key, (b, cfg.n_prefix_tokens, cfg.d_model)),
                "labels": toks}
    return {"tokens": toks, "labels": toks}


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_and_train_step(arch):
    """One forward + one grad step on CPU: shapes right, no NaNs."""
    cfg = smoke_config(arch)
    model = build_model(cfg)
    params = model.init(KEY)
    batch = make_batch(cfg)
    logits, aux = jax.jit(model.forward)(params, batch)
    s_out = batch["tokens"].shape[1] + (
        cfg.n_prefix_tokens if cfg.family == "vlm" else 0)
    assert logits.shape == (2, s_out, cfg.vocab_padded)
    assert np.isfinite(np.asarray(logits, np.float32)).all()

    (loss, metrics), grads = jax.jit(
        jax.value_and_grad(model.loss, has_aux=True))(params, batch)
    assert np.isfinite(float(loss))
    gnorm = sum(float(jnp.sum(jnp.square(g.astype(jnp.float32))))
                for g in jax.tree.leaves(grads))
    assert np.isfinite(gnorm) and gnorm > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_matches_forward(arch):
    """Greedy equivalence: prefill(S-1) + decode(1) == forward(S)."""
    kw = {"capacity_factor": 64.0} if "moe" in arch else {}
    cfg = smoke_config(arch).replace(dtype="float32", **kw)
    model = build_model(cfg)
    params = model.init(KEY)
    b, s = 2, 24
    toks = jax.random.randint(jax.random.fold_in(KEY, 7), (b, s), 0,
                              cfg.vocab)
    off = cfg.n_prefix_tokens if cfg.family == "vlm" else 0
    batch = make_batch(cfg, b, s, jax.random.fold_in(KEY, 8))
    batch["tokens"] = toks
    batch["labels"] = toks
    pbatch = {k: v for k, v in batch.items() if k != "labels"}
    pbatch["tokens"] = toks[:, : s - 1]

    full, _ = jax.jit(model.forward)(params, batch)
    pl_, cache = jax.jit(lambda p, bb: model.prefill(p, bb, off + s + 8))(
        params, pbatch)
    dl, _ = jax.jit(model.decode)(params, toks[:, s - 1 : s], cache)
    np.testing.assert_allclose(np.asarray(full[:, off + s - 2]),
                               np.asarray(pl_[:, -1]), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(full[:, off + s - 1]),
                               np.asarray(dl[:, -1]), rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_param_count_sane(arch):
    """Full config param counts are in the advertised ballpark."""
    cfg = get_config(arch)
    n = cfg.param_count()
    expected = {
        "seamless-m4t-medium": (0.3e9, 1.5e9),
        "stablelm-12b": (10e9, 14e9),
        "qwen2.5-3b": (2.5e9, 4e9),
        "internlm2-20b": (17e9, 23e9),
        "h2o-danube-1.8b": (1.4e9, 2.2e9),
        "pixtral-12b": (10e9, 14e9),
        "deepseek-moe-16b": (14e9, 20e9),
        "granite-moe-1b-a400m": (0.8e9, 1.8e9),
        "mamba2-780m": (0.6e9, 1.0e9),
        "zamba2-1.2b": (0.9e9, 1.6e9),
    }[arch]
    assert expected[0] <= n <= expected[1], f"{arch}: {n/1e9:.2f}B"


@pytest.mark.parametrize("arch", ARCHS)
def test_input_specs_cover_all_cells(arch):
    cfg = get_config(arch)
    for cell in SHAPE_CELLS:
        ok, why = cell_applicable(cfg, cell)
        if not ok:
            assert cell.name == "long_500k" and not cfg.supports_long_context
            continue
        specs = input_specs(cfg, cell)
        assert all(hasattr(v, "shape") for v in specs.values())
        if cell.kind != "decode":
            lead = {v.shape[0] for v in specs.values()}
            assert lead == {cell.global_batch}


def test_long_context_flags():
    assert get_config("mamba2-780m").supports_long_context
    assert get_config("zamba2-1.2b").supports_long_context
    assert get_config("h2o-danube-1.8b").supports_long_context  # SWA
    assert not get_config("qwen2.5-3b").supports_long_context
    assert not get_config("pixtral-12b").supports_long_context


def test_moe_active_params_below_total():
    cfg = get_config("deepseek-moe-16b")
    assert cfg.active_param_count() < 0.45 * cfg.param_count()


def test_chunked_prefill_matches_single_shot():
    """§Perf B5: Blocks-mode prefill must equal single-shot prefill."""
    from repro.models import lm
    for arch in ("qwen2.5-3b", "deepseek-moe-16b"):
        kw = {"capacity_factor": 64.0} if "moe" in arch else {}
        cfg = smoke_config(arch).replace(dtype="float32", **kw)
        model = build_model(cfg)
        params = model.init(KEY)
        toks = jax.random.randint(jax.random.fold_in(KEY, 5), (2, 32), 0,
                                  cfg.vocab)
        l_ref, c_ref = jax.jit(lambda p, t: lm.prefill(cfg, p, t, 48))(
            params, toks)
        l_chk, c_chk = jax.jit(
            lambda p, t: lm.prefill_chunked(cfg, p, t, 48, chunk=8))(
            params, toks)
        np.testing.assert_allclose(np.asarray(l_ref), np.asarray(l_chk),
                                   atol=1e-4)
        np.testing.assert_allclose(np.asarray(c_ref.k, np.float32),
                                   np.asarray(c_chk.k, np.float32), atol=1e-4)
