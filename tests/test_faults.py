"""Fault injection + self-healing transfer channels.

Injection side: deterministic seeded FaultPlan schedules through the
``engine_factory`` seam. Recovery side: bounded ticket waits escalating to
the runtime timeout scan, retry-on-sibling striping, channel quarantine /
probe-based un-quarantine, checksum verification, and provable resource
release on every chunk-chain error path.
"""

import dataclasses
import os
import threading
import time

import numpy as np
import pytest

from repro.core.adaptive import AdaptiveChannelGroup, AdaptiveConfig
from repro.core.channels import ChannelGroup
from repro.core.cost_model import TransferCostModel
from repro.core.faults import (
    FaultInjector,
    FaultPlan,
    FaultSpec,
    InjectedFault,
    RecoveryConfig,
)
from repro.core.runtime import PriorityClass, TransferRuntime
from repro.core.transfer import (
    LayoutCache,
    Ticket,
    TransferChecksumError,
    TransferEngine,
    TransferFaultError,
    TransferPolicy,
    TransferTimeoutError,
)


def _ring(depth=4, block=1 << 16):
    return TransferPolicy.kernel_level_ring(depth, block_bytes=block)


def _roundtrip_bytes(eng, x):
    back = eng.rx(eng.tx(x))
    return np.concatenate([np.asarray(b).reshape(-1).view(np.uint8)
                           for b in back])


# ---- spec / plan validation ------------------------------------------------

def test_fault_spec_validation():
    with pytest.raises(ValueError):
        FaultSpec(kind="gremlin")
    with pytest.raises(ValueError):
        FaultSpec(kind="delay", p=1.5)
    with pytest.raises(ValueError):
        FaultSpec(kind="delay", direction="sideways")
    with pytest.raises(ValueError):
        FaultSpec(kind="corrupt", direction="tx")
    # corrupt pins itself to RX so a direction-agnostic spec never burns a
    # max_injections draw on a TX op where corruption is a no-op
    assert FaultSpec(kind="corrupt").direction == "rx"


def test_recovery_config_validation():
    with pytest.raises(ValueError):
        RecoveryConfig(stripe_timeout_s=0.0)
    with pytest.raises(ValueError):
        RecoveryConfig(max_retries=-1)
    with pytest.raises(ValueError):
        RecoveryConfig(quarantine_after=0)
    with pytest.raises(ValueError):
        RecoveryConfig(drift_quarantine_ratio=1.0)


# ---- seeded determinism ----------------------------------------------------

def test_seeded_fault_schedule_is_deterministic():
    """Same seed + same workload => identical (channel, op, kind) ledgers.
    Polling management keeps every op on the caller thread, so the ledger
    order itself is reproducible, not just the per-channel sets."""

    def run(seed):
        inj = FaultInjector(FaultPlan(seed=seed, specs=(
            FaultSpec(kind="delay", p=0.4, delay_s=0.0),
            FaultSpec(kind="stall", p=0.3, stall_s=0.0),
        )))
        eng = inj.engine_factory()(TransferPolicy.user_level_polling())
        for i in range(8):
            eng.rx(eng.tx(np.full(1 << 12, i, np.uint8)))
        eng.close()
        return list(inj.events)

    a, b = run(11), run(11)
    assert a == b
    assert a, "schedule fired nothing — p too low for the workload"
    assert run(12) != a  # a different seed draws a different schedule


def test_injection_ledger_attributes_channels_by_creation_order():
    inj = FaultInjector(FaultPlan(seed=0, specs=(
        FaultSpec(kind="delay", p=1.0, channel=1, delay_s=0.0),)))
    g = ChannelGroup(_ring(), n_channels=2, min_stripe_bytes=1 << 14,
                     engine_factory=inj.engine_factory())
    g.tx(np.zeros(1 << 16, np.uint8))
    assert inj.n_engines == 2
    assert all(ev[0] == 1 for ev in inj.events)
    g.close()


# ---- bounded waits + runtime escalation ------------------------------------

def test_ticket_wait_timeout_raises_and_engine_survives():
    inj = FaultInjector(FaultPlan(seed=3, specs=(
        FaultSpec(kind="delay", p=1.0, delay_s=0.4, max_injections=1),)))
    eng = inj.engine_factory()(_ring())
    t = eng.tx_async(np.zeros(1 << 14, np.uint8))
    with pytest.raises(TransferTimeoutError):
        t.wait(0.02)
    chunks = t.wait(5.0)  # the delayed completion eventually lands
    assert chunks
    x = np.arange(1 << 14, dtype=np.uint8)
    np.testing.assert_array_equal(_roundtrip_bytes(eng, x), x)
    eng.close()


def test_wait_timeout_escalates_to_runtime_scan():
    """A descriptor stuck QUEUED behind a busy worker is cancelled by the
    timeout scan and surfaces as TransferTimeoutError — not a hang."""
    rt = TransferRuntime(workers=1)
    gate = threading.Event()
    blocker = rt.register("blocker", PriorityClass.TOKEN)
    t_block = Ticket(*blocker.submit(gate.wait, nbytes=1))
    eng = TransferEngine(_ring(), runtime=rt, priority=PriorityClass.BULK)
    try:
        t = eng.tx_async(np.zeros(1 << 14, np.uint8))
        time.sleep(0.25)  # age the queued descriptors past the bound
        with pytest.raises(TransferTimeoutError):
            t.wait(0.05)
        assert rt.class_summary()["bulk"]["timeouts"] >= 1
    finally:
        gate.set()
        t_block.wait(5.0)
        eng.close()
        blocker.close()
        rt.close()


def test_scan_timeouts_spares_started_descriptors():
    rt = TransferRuntime(workers=1)
    started = threading.Event()
    gate = threading.Event()
    h = rt.register("w", PriorityClass.BULK)

    def slow():
        started.set()
        gate.wait()

    t = Ticket(*h.submit(slow, nbytes=1))
    try:
        assert started.wait(5.0)
        time.sleep(0.05)
        assert rt.scan_timeouts(1e-3) == 0  # in service: not cancellable
        gate.set()
        t.wait(5.0)
    finally:
        gate.set()
        h.close()
        rt.close()


# ---- retry on a sibling channel --------------------------------------------

def test_fault_retries_on_sibling_and_data_is_exact():
    inj = FaultInjector(FaultPlan(seed=1, specs=(
        FaultSpec(kind="drop", p=1.0, channel=0, direction="tx",
                  hold_s=0.0, max_injections=1),)))
    g = ChannelGroup(_ring(), n_channels=2, min_stripe_bytes=1 << 14,
                     engine_factory=inj.engine_factory())
    x = np.arange(1 << 18, dtype=np.uint8)
    chunks = g.tx(x)  # channel 0's stripe fails once, retries on channel 1
    flat = np.concatenate([np.asarray(c).reshape(-1).view(np.uint8)
                           for c in chunks])
    np.testing.assert_array_equal(np.sort(flat), np.sort(x))  # stripe order
    s = g.fault_state.summary()
    assert s["faults"] == 1 and s["faults_by_channel"] == {0: 1}
    assert s["retries"] == 1 and s["retry_successes"] == 1
    g.close()


def test_structural_errors_are_never_retried():
    g = ChannelGroup(_ring(), n_channels=2)
    with pytest.raises((ValueError, TypeError)):
        g.tx(object())  # not a payload: must surface, not bounce channels
    assert g.fault_state.summary()["retries"] == 0
    g.close()


def test_retry_exhaustion_surfaces_the_fault():
    inj = FaultInjector(FaultPlan(seed=2, specs=(
        FaultSpec(kind="drop", p=1.0, direction="tx", hold_s=0.0),)))
    g = ChannelGroup(_ring(), n_channels=2, min_stripe_bytes=1 << 14,
                     engine_factory=inj.engine_factory(),
                     recovery=RecoveryConfig(max_retries=1,
                                             quarantine_after=10))
    with pytest.raises(TransferFaultError):
        g.tx(np.zeros(1 << 16, np.uint8))
    assert g.fault_state.summary()["faults"] >= 2  # original + retry
    g.close()


# ---- quarantine lifecycle --------------------------------------------------

def test_consecutive_faults_quarantine_then_probe_unquarantines():
    inj = FaultInjector(FaultPlan(seed=4, specs=(
        FaultSpec(kind="drop", p=1.0, channel=0, direction="tx",
                  hold_s=0.0, max_injections=2),)))
    rec = RecoveryConfig(quarantine_after=2, probe_interval_s=0.0,
                         drift_quarantine_ratio=None)
    g = ChannelGroup(_ring(), n_channels=3, min_stripe_bytes=1 << 12,
                     engine_factory=inj.engine_factory(), recovery=rec)
    x = np.zeros(1 << 16, np.uint8)
    for _ in range(3):
        g.tx(x)
    assert g.quarantined == {0}
    s = g.fault_state.summary()
    assert s["quarantines"] == 1
    # the fault burned out (max_injections); the probe brings channel 0 back
    assert g.maybe_adapt() is True
    assert g.quarantined == set()
    assert g.fault_state.summary()["unquarantines"] == 1
    assert sorted(g._active_indices()) == [0, 1, 2]
    g.close()


def test_drift_quarantine_pulls_stalled_channel_from_rotation():
    inj = FaultInjector(FaultPlan(seed=5))
    rec = RecoveryConfig(drift_quarantine_ratio=3.0, health_min_samples=4,
                         probe_interval_s=60.0)  # no rejoin during the test
    g = ChannelGroup(_ring(block=1 << 14), n_channels=3,
                     min_stripe_bytes=1 << 12,
                     engine_factory=inj.engine_factory(), recovery=rec)
    inj.stall(0, on=True, stall_s=0.01)
    x = np.zeros(3 << 16, np.uint8)
    for _ in range(4):
        g.tx(x)
        g.check_channel_health()
    assert g.quarantined == {0}
    # stalled channel takes no stripes now: new ops land on 1 and 2 only
    ops_before = dict(inj._ops)
    g.tx(x)
    assert inj._ops.get(0, 0) == ops_before.get(0, 0)
    assert g.summary()["quarantined"] == [0]
    g.close()


def test_stalled_channel_fails_probe_rate_check_and_stays_out():
    """A stall completes probes — completion alone must not rejoin it."""
    inj = FaultInjector(FaultPlan(seed=6))
    rec = RecoveryConfig(drift_quarantine_ratio=3.0, health_min_samples=4,
                         probe_interval_s=0.0, probe_bytes=1 << 14)
    g = ChannelGroup(_ring(block=1 << 14), n_channels=3,
                     min_stripe_bytes=1 << 12,
                     engine_factory=inj.engine_factory(), recovery=rec)
    inj.stall(0, on=True, stall_s=0.01)
    x = np.zeros(3 << 16, np.uint8)
    for _ in range(4):
        g.tx(x)
        g.check_channel_health()
    assert g.quarantined == {0}
    g.check_channel_health()  # probes channel 0: completes, but too slow
    assert g.quarantined == {0}
    inj.stall(0, on=False)
    g.check_channel_health()  # healthy-rate probe rejoins it
    assert g.quarantined == set()
    g.close()


def test_last_active_channel_is_never_quarantined():
    inj = FaultInjector(FaultPlan(seed=7, specs=(
        FaultSpec(kind="drop", p=1.0, direction="tx", hold_s=0.0),)))
    rec = RecoveryConfig(quarantine_after=1, max_retries=2)
    g = ChannelGroup(_ring(), n_channels=2, min_stripe_bytes=1 << 14,
                     engine_factory=inj.engine_factory(), recovery=rec)
    with pytest.raises(TransferFaultError):
        g.tx(np.zeros(1 << 16, np.uint8))  # every channel drops every op
    assert len(g.quarantined) <= 1  # one channel always remains in rotation
    assert g._active_indices()
    g.close()


# ---- checksum verification -------------------------------------------------

def test_checksum_mismatch_raises_and_counts():
    pol = dataclasses.replace(_ring(), checksum=True)
    inj = FaultInjector(FaultPlan(seed=8, specs=(
        FaultSpec(kind="corrupt", p=1.0, max_injections=1),)))
    eng = inj.engine_factory()(pol)
    chunks = eng.tx(np.arange(1 << 16, dtype=np.uint8))
    with pytest.raises(TransferChecksumError):
        eng.rx(chunks)
    assert eng.summary()["checksum_failures"] == 1
    # device state was never corrupted in place: a retry reads clean bytes
    flat = np.concatenate([np.asarray(b).reshape(-1).view(np.uint8)
                           for b in eng.rx(chunks)])
    np.testing.assert_array_equal(flat, np.arange(1 << 16, dtype=np.uint8))
    eng.close()


def test_checksum_mismatch_retries_on_sibling_channel():
    pol = dataclasses.replace(_ring(), checksum=True)
    inj = FaultInjector(FaultPlan(seed=9, specs=(
        FaultSpec(kind="corrupt", p=1.0, max_injections=1),)))
    g = ChannelGroup(pol, n_channels=2, min_stripe_bytes=1 << 14,
                     engine_factory=inj.engine_factory())
    x = np.arange(1 << 18, dtype=np.uint8)
    chunks = g.tx(x)
    out = np.concatenate([np.asarray(b).reshape(-1).view(np.uint8)
                          for b in g.rx(chunks)])
    np.testing.assert_array_equal(np.sort(out), np.sort(x))
    s = g.fault_state.summary()
    assert s["checksum_failures"] == 1
    assert s["retry_successes"] == 1
    g.close()


def test_checksum_off_by_default_costs_nothing():
    eng = TransferEngine(_ring())
    x = np.arange(1 << 14, dtype=np.uint8)
    np.testing.assert_array_equal(_roundtrip_bytes(eng, x), x)
    assert eng.summary()["checksum_failures"] == 0
    eng.close()


# ---- chunk-chain error paths release every resource (satellite 2) ----------

def _assert_ring_clean(eng):
    assert eng._inflight == 0
    assert not any(eng._slot_held)


def test_async_chunk_chain_error_releases_ring_and_layout():
    """Mid-chain chunk failure: remaining chunks are cancelled, every ring
    slot is freed exactly once, the staged layout's busy flag clears, and
    the engine is immediately reusable."""
    inj = FaultInjector(FaultPlan(seed=10, specs=(
        FaultSpec(kind="drop", p=1.0, direction="tx", after_ops=2,
                  hold_s=0.0, max_injections=1),)))
    eng = inj.engine_factory()(_ring(depth=4, block=1 << 14))
    cache = LayoutCache()
    arrays = [np.arange(1 << 17, dtype=np.uint8)]  # 8 chunks of 16 KiB
    lay = cache.get("l0", arrays)
    t = eng.tx_async(lay.pack(arrays), layout=lay)
    with pytest.raises(InjectedFault):
        t.wait(5.0)
    _assert_ring_clean(eng)
    assert eng.chunks_cancelled >= 1
    assert lay._busy is not None and lay._busy.is_set()  # busy flag cleared
    # reusable: same layout, same engine, clean roundtrip
    chunks = eng.tx_async(lay.pack(arrays), layout=lay).wait(5.0)
    got = np.concatenate([np.asarray(c).reshape(-1).view(np.uint8)
                          for c in chunks])
    np.testing.assert_array_equal(got, arrays[0])
    _assert_ring_clean(eng)
    eng.close()


def test_sync_chunk_chain_error_releases_ring():
    inj = FaultInjector(FaultPlan(seed=11, specs=(
        FaultSpec(kind="drop", p=1.0, direction="tx", after_ops=3,
                  hold_s=0.0, max_injections=1),)))
    eng = inj.engine_factory()(_ring(depth=4, block=1 << 14))
    x = np.arange(1 << 17, dtype=np.uint8)
    with pytest.raises(InjectedFault):
        eng.tx(x)
    _assert_ring_clean(eng)
    np.testing.assert_array_equal(_roundtrip_bytes(eng, x), x)
    eng.close()


# ---- counters flow into the runtime's class summary ------------------------

def test_class_summary_reports_fault_columns():
    rt = TransferRuntime(workers=1)
    inj = FaultInjector(FaultPlan(seed=12, specs=(
        FaultSpec(kind="drop", p=1.0, channel=0, direction="tx",
                  hold_s=0.0, max_injections=1),)))
    g = ChannelGroup(_ring(), n_channels=2, min_stripe_bytes=1 << 14,
                     engine_factory=inj.engine_factory(), runtime=rt,
                     priority=PriorityClass.LAYER)
    g.tx(np.zeros(1 << 16, np.uint8))
    row = rt.class_summary()["layer"]
    for key in ("faults", "retries", "timeouts", "quarantines"):
        assert key in row
    assert row["faults"] == 1 and row["retries"] == 1
    g.close()
    rt.close()


# ---- adaptive facade: replan around the reduced channel set ----------------

def test_controller_replan_channels_bounds_the_plan(monkeypatch):
    monkeypatch.setattr(os, "cpu_count", lambda: 8)
    from repro.core.adaptive import OnlineTransferController
    ctl = OnlineTransferController(
        32 << 20, model=TransferCostModel(t0_s=50e-6, bw_Bps=2e9),
        cfg=AdaptiveConfig(max_channels=4))
    assert ctl.plan.n_channels == 4
    plan = ctl.replan_channels(2)
    assert plan is not None and plan.n_channels == 2
    assert ctl.replan_channels(2) is None  # already bounded: no churn
    plan = ctl.replan_channels(None)  # quarantine lifted: full width again
    assert plan is not None and plan.n_channels == 4


def test_adaptive_group_quarantine_triggers_replan(monkeypatch):
    monkeypatch.setattr(os, "cpu_count", lambda: 8)
    inj = FaultInjector(FaultPlan(seed=13))
    rec = RecoveryConfig(drift_quarantine_ratio=2.0, health_min_samples=4,
                         probe_interval_s=60.0)
    # min_samples=10**6 disables organic refit replans: on a loaded host the
    # measured t0/BW can drift past hysteresis and swap generations for
    # reasons unrelated to the quarantine this test is about.
    g = AdaptiveChannelGroup(
        32 << 20, cfg=AdaptiveConfig(max_channels=4, min_samples=10 ** 6),
        model=TransferCostModel(t0_s=50e-6, bw_Bps=2e9),
        engine_factory=inj.engine_factory(), recovery=rec)
    assert g.n_channels == 4
    inj.stall(0, on=True, stall_s=0.02)
    x = np.zeros(32 << 20, np.uint8)
    for _ in range(10):
        g.tx(x)
        g.maybe_adapt()
        if g.fault_state.summary()["quarantines"] >= 1 and g.generation >= 1:
            break
    assert g.generation >= 1  # swapped to a reduced-channel generation
    assert g.n_channels == 3
    assert g.adapt_summary()["channel_limit"] == 3
    assert g.fault_state.summary()["quarantines"] == 1  # ledger survives
    g.close()


def test_adaptive_group_shares_one_fault_ledger_across_generations():
    from repro.dist.fault import TransferFaultState
    fs = TransferFaultState()
    g = AdaptiveChannelGroup(
        1 << 20, model=TransferCostModel(t0_s=20e-6, bw_Bps=4e9),
        fault_state=fs)
    assert g.fault_state is fs
    assert g._group.fault_state is fs  # the generation's group shares it
    g.close()


# ---- chaos: random faults under 4-class QoS load (stress lane) -------------

@pytest.mark.stress
def test_chaos_hammer_exact_byte_accounting_under_qos_load():
    """Random delay/submit/drop faults against four priority classes on one
    shared runtime: every roundtrip stays bit-exact, every logical byte is
    accounted exactly once at the group level, rings come back clean, and
    every surfaced fault was recovered (no caller ever saw an error)."""
    rt = TransferRuntime(workers=2)
    inj = FaultInjector(FaultPlan(seed=14, specs=(
        FaultSpec(kind="delay", p=0.10, delay_s=0.002),
        FaultSpec(kind="submit_error", p=0.05),
        FaultSpec(kind="drop", p=0.05, hold_s=0.0),
    )))
    rec = RecoveryConfig(max_retries=6, quarantine_after=10 ** 6,
                         drift_quarantine_ratio=None)
    classes = [PriorityClass.SENSOR, PriorityClass.TOKEN,
               PriorityClass.LAYER, PriorityClass.BULK]
    groups = {cls: ChannelGroup(_ring(depth=3, block=1 << 14), n_channels=2,
                                min_stripe_bytes=1 << 13,
                                engine_factory=inj.engine_factory(),
                                recovery=rec, runtime=rt, priority=cls)
              for cls in classes}
    iters, n_elems = 6, 16 * 1024
    errors: list = []

    def hammer(cls, seed):
        try:
            g = groups[cls]
            x = np.full(n_elems, seed, np.uint8)
            for _ in range(iters):
                host = g.rx(g.tx(x))
                flat = np.concatenate([np.asarray(h).reshape(-1)
                                       for h in host])
                np.testing.assert_array_equal(np.sort(flat), x)
        except BaseException as e:  # noqa: BLE001
            errors.append(e)

    threads = [threading.Thread(target=hammer, args=(cls, i))
               for i, cls in enumerate(classes) for _ in range(2)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors, errors
    assert inj.events, "chaos lane injected nothing"
    expected = 2 * iters * n_elems  # bytes per direction per class
    total_faults = 0
    for cls, g in groups.items():
        tx_logical = sum(s.nbytes for s in g.stats if s.direction == "tx")
        rx_logical = sum(s.nbytes for s in g.stats if s.direction == "rx")
        assert tx_logical == expected, cls
        assert rx_logical == expected, cls
        s = g.fault_state.summary()
        # a retry may itself fault (success=False) before the next one
        # lands; "all recovered" is the errors list being empty above
        assert s["retry_successes"] <= s["retries"] <= s["faults"], cls
        total_faults += s["faults"]
        for eng in g.engines:
            _assert_ring_clean(eng)
            assert eng.slot_collisions == 0
        g.close()
    # exact fault accounting: every injected drop/submit event surfaced as
    # exactly one ledger fault (delays are latency, not faults)
    injected = sum(1 for ev in inj.events if ev[2] in ("drop", "submit_error"))
    assert total_faults == injected
    summ = rt.class_summary()
    for cls in classes:
        assert summ[cls.value]["completed"] > 0
    rt.close()


# ---- batched submission under faults (tx_many / rx_many) -------------------

def test_many_mid_batch_fault_fails_only_affected_ticket():
    """A per-descriptor fault inside a batched group errors ONLY its
    ticket: siblings complete with exact data, the group's single ring
    slot is released exactly once, and the engine is reusable."""
    inj = FaultInjector(FaultPlan(seed=5, specs=(
        FaultSpec(kind="drop", p=1.0, direction="tx", after_ops=2,
                  hold_s=0.0, max_injections=1),)))
    eng = inj.engine_factory()(_ring(depth=4))
    arrays = [np.full(1 << 10, i, np.uint8) for i in range(5)]
    tickets = eng.tx_many(arrays)
    assert len(tickets) == 5
    # ops on channel 0: submit-stage check (op 0), then one op per
    # descriptor (1..5) — after_ops=2 drops the SECOND descriptor.
    with pytest.raises(InjectedFault):
        tickets[1].wait(5.0)
    for i in (0, 2, 3, 4):
        dev = tickets[i].wait(5.0)
        np.testing.assert_array_equal(
            np.asarray(dev).reshape(-1).view(np.uint8), arrays[i])
    _assert_ring_clean(eng)
    # exact accounting: only the 4 surviving descriptors' bytes recorded
    assert eng.tx_bytes_total == 4 * (1 << 10)
    # immediately reusable for another batch
    again = [t.wait(5.0) for t in eng.tx_many(arrays[:2])]
    assert len(again) == 2
    _assert_ring_clean(eng)
    eng.close()


def test_many_rx_drop_never_writes_out_buffer():
    """A dropped RX descriptor in a batch must not touch the caller's
    ``out=`` landing buffer; sibling descriptors land theirs exactly."""
    inj = FaultInjector(FaultPlan(seed=6, specs=(
        FaultSpec(kind="drop", p=1.0, direction="rx", hold_s=0.0,
                  max_injections=1),)))
    eng = inj.engine_factory()(_ring(depth=4))
    arrays = [np.full(256, 10 + i, np.uint8) for i in range(4)]
    devs = [t.wait(5.0) for t in eng.tx_many(arrays)]
    outs = [np.full(256, 0xEE, np.uint8) for _ in arrays]
    tickets = eng.rx_many(devs, out=outs)
    # the first RX op draws the single drop; the rest land
    with pytest.raises(InjectedFault):
        tickets[0].wait(5.0)
    np.testing.assert_array_equal(outs[0], np.full(256, 0xEE, np.uint8))
    for i in (1, 2, 3):
        assert tickets[i].wait(5.0) is outs[i]
        np.testing.assert_array_equal(outs[i], arrays[i])
    _assert_ring_clean(eng)
    assert eng.rx_bytes_total == 3 * 256
    eng.close()


def test_many_submit_error_fails_group_before_any_slot():
    """A transient submit_error on the batched entry points fails the
    whole group AT THE CALL (uniform with tx/rx_async) — no ring slot is
    consumed, and the next batch goes through clean."""
    inj = FaultInjector(FaultPlan(seed=7, specs=(
        FaultSpec(kind="submit_error", p=1.0, direction="tx",
                  max_injections=1),
        FaultSpec(kind="submit_error", p=1.0, direction="rx",
                  max_injections=1),)))
    eng = inj.engine_factory()(_ring(depth=2))
    arrays = [np.zeros(128, np.uint8) for _ in range(3)]
    with pytest.raises(InjectedFault):
        eng.tx_many(arrays)
    _assert_ring_clean(eng)
    devs = [t.wait(5.0) for t in eng.tx_many(arrays)]  # tx injection spent
    with pytest.raises(InjectedFault):
        eng.rx_many(devs)
    _assert_ring_clean(eng)
    hosts = [t.wait(5.0) for t in eng.rx_many(devs)]
    assert len(hosts) == 3
    _assert_ring_clean(eng)
    eng.close()


def test_group_many_fault_surfaces_on_its_own_ticket():
    """Through ChannelGroup the batch is round-robin partitioned; a fault
    on one channel's share errors only the affected descriptor's ticket —
    NO sibling retry on the batched path (exactly-once submission) — and
    the other channel's descriptors are unaffected."""
    inj = FaultInjector(FaultPlan(seed=8, specs=(
        FaultSpec(kind="drop", p=1.0, channel=0, direction="tx",
                  hold_s=0.0, max_injections=1),)))
    g = ChannelGroup(_ring(depth=4), n_channels=2,
                     engine_factory=inj.engine_factory())
    arrays = [np.full(512, i, np.uint8) for i in range(4)]
    tickets = g.tx_many(arrays)  # ch0 gets idx 0,2; ch1 gets idx 1,3
    with pytest.raises(InjectedFault):
        tickets[0].wait(5.0)
    for i in (1, 2, 3):
        dev = tickets[i].wait(5.0)
        np.testing.assert_array_equal(
            np.asarray(dev).reshape(-1).view(np.uint8), arrays[i])
    assert len(inj.events) == 1 and inj.events[0][0] == 0  # no retry fired
    for eng in g.engines:
        _assert_ring_clean(eng)
    g.close()

# ---- scatter-gather fault isolation ----------------------------------------

def test_sg_mid_segment_fault_isolated_exactly_once_release():
    """A payload-stage fault on ONE segment of an SG submit must surface on
    that segment's ticket only — siblings deliver byte-exact — and the ring
    slot must release exactly once (subsequent submits never collide)."""
    inj = FaultInjector(FaultPlan(seed=3, specs=(
        FaultSpec(kind="drop", p=1.0, after_ops=3, max_injections=1,
                  hold_s=0.0),)))
    eng = inj.engine_factory()(_ring(depth=4))
    try:
        arrays = [(np.arange(256 + 64 * i) % 97).astype(np.float32)
                  for i in range(5)]
        sg = eng.tx_sg(arrays)
        results = sg.wait_each(10.0)
        # op sequence: the submit-stage check is op 0, segment i is op
        # i+1 — after_ops=3 warms past segments 0,1 so segment 2 draws
        # the drop; 3,4 pass again (max_injections=1)
        for i, r in enumerate(results):
            if i == 2:
                assert isinstance(r, InjectedFault)
            else:
                np.testing.assert_array_equal(np.asarray(r), arrays[i])
        with pytest.raises(TransferFaultError):
            sg.wait(10.0)
        assert sg.complete
        assert eng.slot_collisions == 0
        # exactly-once slot release: more SG submits than the ring has
        # depth must all find free slots (a leaked/double-released slot
        # would deadlock or collide here)
        for _ in range(6):
            eng.tx_sg([np.arange(64, dtype=np.float32)]).wait(10.0)
        assert eng.slot_collisions == 0
    finally:
        eng.close()


def test_group_sg_share_sibling_retry():
    """A faulted channel share of a striped SG transfer retries on a
    sibling: data exact, ledger records the retry."""
    inj = FaultInjector(FaultPlan(seed=5, specs=(
        FaultSpec(kind="drop", p=1.0, channel=0, max_injections=1,
                  hold_s=0.0),)))
    g = ChannelGroup(_ring(), n_channels=2, min_stripe_bytes=1 << 10,
                     engine_factory=inj.engine_factory())
    try:
        rng = np.random.default_rng(9)
        arrays = [rng.standard_normal(2048).astype(np.float32)
                  for _ in range(6)]
        devs = g.tx_sg(arrays).wait(10.0)
        for a, d in zip(arrays, devs):
            np.testing.assert_array_equal(np.asarray(d), a)
        s = g.fault_state.summary()
        assert s["faults"] >= 1
        assert s["retries"] >= 1 and s["retry_successes"] >= 1
    finally:
        g.close()
