"""Tests for the concurrency analyzer (repro.analysis): per-rule fixtures
asserting the exact rule fires, waiver/baseline suppression, the runtime
ValidatedLock order validation, and the cleanliness gate over the real
package (the same invariant scripts/ci.sh --lane lint enforces)."""

import pathlib
import sys
import textwrap
import threading
import warnings

import pytest

from repro.analysis import (
    Finding,
    LockAssertionError,
    LockOrderViolation,
    analyze_source,
    assert_held,
    enable,
    extract_module,
    extract_package,
    make_condition,
    make_lock,
    make_rlock,
    order_graph,
    run_rules,
    split_new,
)

SRC_ROOT = pathlib.Path(__file__).resolve().parents[1] / "src"


def _src(body: str) -> str:
    return textwrap.dedent(body)


def _rules(findings: "list[Finding]", *, waived: bool = False) -> set:
    return {f.rule for f in findings if f.waived == waived}


# ---------------------------------------------------------------------------
# lock-order


def test_lock_order_cycle_detected():
    findings = analyze_source(_src("""
        import threading

        class Pair:
            def __init__(self):
                self._a = threading.Lock()
                self._b = threading.Lock()

            def forward(self):
                with self._a:
                    self.inner_b()

            def inner_b(self):
                with self._b:
                    pass

            def backward(self):
                with self._b:
                    self.inner_a()

            def inner_a(self):
                with self._a:
                    pass
    """), rules=("lock-order",))
    assert _rules(findings) == {"lock-order"}
    msg = findings[0].message
    assert "Pair._a" in msg and "Pair._b" in msg


def test_lock_order_consistent_order_is_clean():
    findings = analyze_source(_src("""
        import threading

        class Pair:
            def __init__(self):
                self._a = threading.Lock()
                self._b = threading.Lock()

            def forward(self):
                with self._a:
                    self.inner_b()

            def inner_b(self):
                with self._b:
                    pass

            def also_forward(self):
                with self._a:
                    with self._b:
                        pass
    """), rules=("lock-order",))
    assert findings == []


# ---------------------------------------------------------------------------
# guarded-by


GUARDED_FIXTURE = """
    import threading

    class Counter:
        def __init__(self):
            self._lock = threading.Lock()
            self.count = 0  # guarded-by: _lock

        def bump(self):
            with self._lock:
                self.count += 1

        def racy_read(self):
            return self.count{waiver}
"""


def test_guarded_by_unlocked_access_fires():
    findings = analyze_source(_src(GUARDED_FIXTURE.format(waiver="")),
                              rules=("guarded-by",))
    assert _rules(findings) == {"guarded-by"}
    assert "count" in findings[0].message


def test_guarded_by_waiver_suppresses():
    findings = analyze_source(
        _src(GUARDED_FIXTURE.format(waiver="  # lock-ok: advisory read")),
        rules=("guarded-by",))
    assert _rules(findings) == set()          # nothing active
    assert _rules(findings, waived=True) == {"guarded-by"}


def test_guarded_by_init_exempt():
    findings = analyze_source(_src("""
        import threading

        class C:
            def __init__(self):
                self._lock = threading.Lock()
                self.x = 0  # guarded-by: _lock
    """), rules=("guarded-by",))
    assert findings == []


# ---------------------------------------------------------------------------
# blocking


def test_blocking_sleep_under_lock_fires():
    findings = analyze_source(_src("""
        import threading
        import time

        class C:
            def __init__(self):
                self._lock = threading.Lock()

            def slow(self):
                with self._lock:
                    time.sleep(1)
    """), rules=("blocking",))
    assert _rules(findings) == {"blocking"}
    assert "time.sleep" in findings[0].message


def test_blocking_transitive_through_helper_fires():
    findings = analyze_source(_src("""
        import threading
        import time

        class C:
            def __init__(self):
                self._lock = threading.Lock()

            def outer(self):
                with self._lock:
                    self.helper()

            def helper(self):
                time.sleep(1)
    """), rules=("blocking",))
    assert _rules(findings) == {"blocking"}


def test_blocking_condition_wait_on_held_lock_exempt():
    # Condition.wait RELEASES the lock it is called on: not a blocking
    # violation against that same lock.
    findings = analyze_source(_src("""
        import threading

        class C:
            def __init__(self):
                self._cond = threading.Condition()

            def park(self):
                with self._cond:
                    self._cond.wait()
    """), rules=("blocking",))
    assert findings == []


def test_blocking_waiver_suppresses_direct_and_transitive():
    findings = analyze_source(_src("""
        import threading
        import time

        class C:
            def __init__(self):
                self._lock = threading.Lock()

            def outer(self):
                with self._lock:
                    self.helper()

            def helper(self):
                time.sleep(1)  # lock-ok: bounded by test harness

            def direct(self):
                with self._lock:
                    self.helper()
    """), rules=("blocking",))
    assert _rules(findings) == set()


# ---------------------------------------------------------------------------
# requires-lock


REQUIRES_FIXTURE = """
    import threading

    class C:
        def __init__(self):
            self._lock = threading.Lock()

        def _bump_locked(self):  # requires-lock: _lock
            pass

        def good(self):
            with self._lock:
                self._bump_locked()

        def bad(self):
            self._bump_locked(){waiver}
"""


def test_requires_lock_unlocked_call_fires():
    findings = analyze_source(_src(REQUIRES_FIXTURE.format(waiver="")),
                              rules=("requires-lock",))
    assert _rules(findings) == {"requires-lock"}
    assert "_bump_locked" in findings[0].message


def test_requires_lock_waiver_suppresses():
    findings = analyze_source(
        _src(REQUIRES_FIXTURE.format(waiver="  # lock-ok: single-threaded")),
        rules=("requires-lock",))
    assert _rules(findings) == set()


def test_requires_lock_satisfied_by_nonblocking_acquire():
    findings = analyze_source(_src("""
        import threading

        class C:
            def __init__(self):
                self._lock = threading.Lock()

            def _scan_locked(self):  # requires-lock: _lock
                pass

            def try_scan(self):
                if not self._lock.acquire(blocking=False):
                    return False
                try:
                    self._scan_locked()
                finally:
                    self._lock.release()
                return True
    """), rules=("requires-lock",))
    assert findings == []


# ---------------------------------------------------------------------------
# annotation validation


def test_unknown_lock_in_annotation_reported():
    findings = analyze_source(_src("""
        import threading

        class C:
            def __init__(self):
                self._lock = threading.Lock()
                self.x = 0  # guarded-by: _no_such_lock
    """), rules=("annotation",))
    assert _rules(findings) == {"annotation"}
    assert "_no_such_lock" in findings[0].message


# ---------------------------------------------------------------------------
# baseline + skip-module


def test_baseline_suppresses_known_fingerprints():
    findings = analyze_source(_src(GUARDED_FIXTURE.format(waiver="")),
                              rules=("guarded-by",))
    assert len(findings) == 1
    baseline = {findings[0].fingerprint}
    new, old = split_new(findings, baseline)
    assert new == [] and len(old) == 1
    # an empty baseline keeps the finding "new"
    new, old = split_new(findings, set())
    assert len(new) == 1 and old == []


def test_fingerprint_is_line_number_free():
    a = analyze_source(_src(GUARDED_FIXTURE.format(waiver="")),
                       rules=("guarded-by",))
    shifted = "# a new leading comment line\n" + _src(
        GUARDED_FIXTURE.format(waiver=""))
    b = analyze_source(shifted, rules=("guarded-by",))
    assert a[0].fingerprint == b[0].fingerprint
    assert a[0].line != b[0].line


def test_skip_module_marker_skips_everything():
    mod = extract_module(_src("""
        # analysis: skip-module
        import threading
        import time

        class C:
            def __init__(self):
                self._lock = threading.Lock()

            def slow(self):
                with self._lock:
                    time.sleep(1)
    """), "shim")
    assert mod.skipped
    assert mod.functions == {} and mod.classes == {}


# ---------------------------------------------------------------------------
# the real package must be clean (the lint-lane invariant)


def test_package_is_clean():
    pkg = extract_package(SRC_ROOT)
    findings = [f for f in run_rules(pkg) if not f.waived]
    assert findings == [], "\n".join(f.render() for f in findings)


def test_package_waivers_all_carry_reasons():
    pkg = extract_package(SRC_ROOT)
    waived = [f for f in run_rules(pkg) if f.waived]
    assert waived, "expected the known deliberate sites to be waived inline"
    for f in waived:
        assert f.waiver.strip(), f"waiver without a reason: {f.render()}"


# ---------------------------------------------------------------------------
# deprecated shim


def test_scheduler_shim_warns_and_reexports():
    sys.modules.pop("repro.core.scheduler", None)
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        import repro.core.scheduler as shim
    assert any(issubclass(w.category, DeprecationWarning)
               and "repro.core.runtime" in str(w.message) for w in caught)
    from repro.core.runtime import CooperativeScheduler
    assert shim.CooperativeScheduler is CooperativeScheduler


# ---------------------------------------------------------------------------
# runtime validation (ValidatedLock)


@pytest.fixture
def validated():
    enable(True)
    order_graph.reset()
    try:
        yield
    finally:
        order_graph.reset()
        enable(None)


def test_validated_lock_order_violation(validated):
    a = make_lock("T.a")
    b = make_lock("T.b")
    with a:
        with b:
            pass
    with b:
        with pytest.raises(LockOrderViolation):
            a.acquire()


def test_validated_lock_consistent_order_ok(validated):
    a = make_lock("T.a")
    b = make_lock("T.b")
    for _ in range(3):
        with a:
            with b:
                pass
    assert "T.b" in order_graph.edges().get("T.a", set())


def test_validated_rlock_reentry_ok(validated):
    r = make_rlock("T.r")
    with r:
        with r:   # reentrant re-acquire must not self-edge
            pass
    assert order_graph.edges().get("T.r", set()) == set()


def test_assert_held(validated):
    lock = make_lock("T.held")
    with pytest.raises(LockAssertionError):
        assert_held(lock, "needs_lock")
    with lock:
        assert_held(lock, "needs_lock")   # no raise


def test_assert_held_noop_when_disabled():
    enable(False)
    try:
        assert_held(threading.Lock(), "whatever")   # plain lock: no-op
    finally:
        enable(None)


def test_validated_condition_works(validated):
    cond = make_condition("T.cond")
    hits = []

    def waiter():
        with cond:
            while not hits:
                cond.wait(timeout=5)

    t = threading.Thread(target=waiter)
    t.start()
    with cond:
        hits.append(1)
        cond.notify_all()
    t.join(timeout=5)
    assert not t.is_alive()


def test_factories_return_plain_primitives_when_disabled():
    enable(False)
    try:
        assert not hasattr(make_lock("x"), "name")
        assert not hasattr(make_rlock("x"), "name")
    finally:
        enable(None)
