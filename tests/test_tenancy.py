"""The QosSpec submit-context redesign (PR 10): spec merge semantics, the
deprecation shims over the legacy ``priority=`` / ``class_caps=`` /
``rx_timeout_s=`` / ``rx_group=`` kwargs (both paths must produce
IDENTICAL arbitration), serving-layer admission control, and the
multi-tenant stress hammer with exact per-tenant byte accounting."""

import threading
import time

import numpy as np
import pytest

from repro.core.qos import (
    DEFAULT_TENANT,
    AdmissionController,
    AdmissionError,
    AdmissionPolicy,
    QosSpec,
    resolve_submit_qos,
)
from repro.core.runtime import (
    ClassQos,
    PriorityClass,
    TransferRuntime,
)
from repro.core.transfer import Ticket, TransferEngine, TransferPolicy

# ---- QosSpec semantics -----------------------------------------------------


def test_qosspec_merge_override_wins_per_field():
    base = QosSpec(priority=PriorityClass.LAYER, tenant="a", weight=2.0,
                   timeout_s=30.0)
    over = QosSpec(tenant="b", cap_bytes_per_s=1e6)
    m = base.merged(over)
    assert m.priority is PriorityClass.LAYER  # unset in override: kept
    assert m.tenant == "b"                    # set in override: wins
    assert m.weight == 2.0
    assert m.cap_bytes_per_s == 1e6
    assert m.timeout_s == 30.0
    assert base.merged(None) is base
    assert base.with_(weight=5.0).weight == 5.0


def test_qosspec_effective_tenant_defaults():
    assert QosSpec().effective_tenant == DEFAULT_TENANT
    assert QosSpec(tenant="x").effective_tenant == "x"


# ---- the deprecation shim --------------------------------------------------


def test_resolve_submit_qos_folds_legacy_priority():
    with pytest.warns(DeprecationWarning, match="priority"):
        spec = resolve_submit_qos("X.tx", None, PriorityClass.TOKEN)
    assert spec == QosSpec(priority=PriorityClass.TOKEN)
    # bare PriorityClass in the qos slot = old positional call shape
    with pytest.warns(DeprecationWarning):
        spec = resolve_submit_qos("X.tx", PriorityClass.BULK, None)
    assert spec.priority is PriorityClass.BULK
    # neither given: caller applies its default
    assert resolve_submit_qos("X.tx", None, None) is None
    with pytest.raises(TypeError):
        resolve_submit_qos("X.tx", PriorityClass.BULK, PriorityClass.TOKEN)
    with pytest.warns(DeprecationWarning):
        with pytest.raises(ValueError, match="conflicts"):
            resolve_submit_qos("X.tx", QosSpec(priority=PriorityClass.BULK),
                               PriorityClass.TOKEN)


def test_engine_submit_methods_warn_on_priority_kwarg():
    eng = TransferEngine(TransferPolicy.kernel_level())
    x = np.ones(256, np.uint8)
    with pytest.warns(DeprecationWarning, match=r"TransferEngine\.tx"):
        dev = eng.tx(x, priority=PriorityClass.BULK)
    with pytest.warns(DeprecationWarning, match=r"TransferEngine\.rx"):
        eng.rx(dev, priority=PriorityClass.BULK)
    # the replacement spelling is warning-free
    import warnings as _w
    with _w.catch_warnings():
        _w.simplefilter("error", DeprecationWarning)
        dev = eng.tx(x, qos=QosSpec(priority=PriorityClass.BULK))
        eng.rx(dev, qos=QosSpec(priority=PriorityClass.BULK))
    eng.close()


def _arbitration_order(legacy: bool) -> list:
    """One deterministic contended workload, submitted through the legacy
    priority= kwarg or the QosSpec path; returns completion order."""
    qos = {PriorityClass.TOKEN: ClassQos(weight=8.0, deadline_s=10.0),
           PriorityClass.BULK: ClassQos(weight=1.0, deadline_s=10.0)}
    log: list = []
    with TransferRuntime(workers=1, qos=qos) as rt:
        eng = TransferEngine(TransferPolicy.kernel_level(), runtime=rt,
                             priority=PriorityClass.LAYER)
        gate = threading.Event()
        started = threading.Event()
        h = rt.register("gate", PriorityClass.LAYER)
        Ticket(*h.submit(lambda: (started.set(), gate.wait())[0]))
        assert started.wait(5.0)  # worker busy: submissions below queue
        big = np.ones(1 << 18, np.uint8)
        small = np.ones(64, np.uint8)
        tickets = []
        for i in range(4):
            if legacy:
                with pytest.warns(DeprecationWarning):
                    t = eng.tx_async(big, callback=lambda r, i=i:
                                     log.append(("bulk", i)),
                                     priority=PriorityClass.BULK)
            else:
                t = eng.tx_async(big, callback=lambda r, i=i:
                                 log.append(("bulk", i)),
                                 qos=QosSpec(priority=PriorityClass.BULK))
            tickets.append(t)
        for i in range(2):
            if legacy:
                with pytest.warns(DeprecationWarning):
                    t = eng.tx_async(small, callback=lambda r, i=i:
                                     log.append(("tok", i)),
                                     priority=PriorityClass.TOKEN)
            else:
                t = eng.tx_async(small, callback=lambda r, i=i:
                                 log.append(("tok", i)),
                                 qos=QosSpec(priority=PriorityClass.TOKEN))
            tickets.append(t)
        gate.set()
        for t in tickets:
            t.wait()
        eng.close()
    return log


def test_legacy_and_qos_paths_arbitrate_identically():
    """The shim IS the new path: the same contended workload dispatches in
    the same order whether submitted with priority= or qos=QosSpec(...)."""
    assert _arbitration_order(legacy=True) == _arbitration_order(legacy=False)


def test_serveconfig_legacy_fields_warn():
    from repro.serve.engine import ServeConfig
    import warnings as _w
    with _w.catch_warnings():
        _w.simplefilter("error", DeprecationWarning)
        ServeConfig()  # defaults: no warning
        ServeConfig(qos=QosSpec(timeout_s=5.0, rx_group=4,
                                class_caps={"bulk": 1e9}))
    with pytest.warns(DeprecationWarning, match="class_caps"):
        ServeConfig(class_caps={"bulk": 1e9})
    with pytest.warns(DeprecationWarning, match="rx_timeout_s"):
        ServeConfig(rx_timeout_s=5.0)
    with pytest.warns(DeprecationWarning, match="rx_group"):
        ServeConfig(rx_group=1)


def test_serveconfig_legacy_fields_fold_into_engine_qos():
    from repro.serve.engine import ServeConfig, ServingEngine
    from repro.configs.registry import smoke_config
    from repro.models.api import build_model
    import jax
    cfg = smoke_config("qwen2.5-3b").replace(dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    with pytest.warns(DeprecationWarning):
        sc = ServeConfig(max_seq=64, rx_timeout_s=7.0, rx_group=2)
    legacy = ServingEngine(model, params, sc)
    assert legacy.qos.timeout_s == 7.0 and legacy.qos.rx_group == 2
    modern = ServingEngine(model, params, ServeConfig(
        max_seq=64, qos=QosSpec(timeout_s=7.0, rx_group=2)))
    assert modern.qos.timeout_s == 7.0 and modern.qos.rx_group == 2
    # identical arbitration: same decoded tokens either way
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab, (1, 8)).astype(np.int32)
    a = legacy.generate(prompts, max_new_tokens=4)[0].tokens
    b = modern.generate(prompts, max_new_tokens=4)[0].tokens
    np.testing.assert_array_equal(a, b)
    legacy.close(), modern.close()


# ---- admission control -----------------------------------------------------


def test_admission_accepts_when_idle():
    ctl = AdmissionController()  # no runtime attached
    d = ctl.decide("anyone")
    assert d.action == "accept" and d.admitted
    assert ctl.summary()["accepts"] == 1


def test_admission_queue_then_shed_on_backlog():
    """Depth ladder against a live runtime: queue at queue_depth, shed at
    shed_depth — the shed caller gets an explicit decision with a
    retry-after hint, never a hang."""
    pol = AdmissionPolicy(queue_depth=2, shed_depth=4, retry_after_s=0.01)
    with TransferRuntime(workers=1) as rt:
        ctl = AdmissionController(runtime=rt, policy=pol,
                                  cls=PriorityClass.TOKEN)
        h = rt.register("tok", PriorityClass.TOKEN)
        gate = threading.Event()
        started = threading.Event()
        Ticket(*h.submit(lambda: (started.set(), gate.wait())[0]))
        assert started.wait(5.0)
        flood = QosSpec(tenant="flood")
        tickets = [Ticket(*h.submit(lambda: None, nbytes=64, qos=flood))
                   for _ in range(4)]
        assert rt.tenant_depth(PriorityClass.TOKEN, "flood") == 4
        d = ctl.decide("flood")
        assert d.action == "shed" and not d.admitted
        assert d.retry_after_s and d.retry_after_s > 0
        assert d.queue_depth == 4
        err = AdmissionError(d)
        assert "flood" in str(err) and err.decision is d
        # a different tenant with no backlog is untouched
        assert ctl.decide("innocent").action == "accept"
        gate.set()
        for t in tickets:
            t.wait()
        # backlog drained: between queue_depth and shed_depth -> queue
        t2 = [Ticket(*h.submit(lambda: time.sleep(0.01), nbytes=64,
                               qos=flood)) for _ in range(3)]
        time.sleep(0.002)
        depth = rt.tenant_depth(PriorityClass.TOKEN, "flood")
        d2 = ctl.decide("flood")
        if 2 <= depth < 4:  # racy drain: only assert when the ladder holds
            assert d2.action == "queue" and d2.admitted
        for t in t2:
            t.wait()
        s = ctl.summary()
        assert s["sheds"] == 1
        assert "flood" in s["by_tenant"]


def test_admission_sheds_on_deadline_miss_rate():
    """The miss-rate branch: a backlogged tenant on a runtime already
    missing deadlines is shed with a window-scaled retry hint."""
    qos = {PriorityClass.TOKEN: ClassQos(weight=8.0, deadline_s=1e-4)}
    pol = AdmissionPolicy(queue_depth=64, shed_depth=256,
                          shed_miss_rate=0.5, miss_window_s=5.0)
    with TransferRuntime(workers=1, qos=qos) as rt:
        ctl = AdmissionController(runtime=rt, policy=pol,
                                  cls=PriorityClass.TOKEN)
        h = rt.register("tok", PriorityClass.TOKEN)
        gate = threading.Event()
        started = threading.Event()
        Ticket(*h.submit(lambda: (started.set(), gate.wait())[0]))
        assert started.wait(5.0)
        tickets = [Ticket(*h.submit(lambda: None, nbytes=64))
                   for _ in range(8)]
        time.sleep(0.01)  # everything queued is now past the 0.1ms deadline
        gate.set()
        for t in tickets:
            t.wait()
        assert rt.deadline_miss_rate(PriorityClass.TOKEN) >= 0.5
        # tenant with a live backlog: shed on the miss-rate branch
        gate2 = threading.Event()
        started2 = threading.Event()
        Ticket(*h.submit(lambda: (started2.set(), gate2.wait())[0]))
        assert started2.wait(5.0)
        spec = QosSpec(tenant="late")
        pending = Ticket(*h.submit(lambda: None, nbytes=64, qos=spec))
        d = ctl.decide("late")
        assert d.action == "shed" and d.miss_rate >= 0.5
        assert d.retry_after_s == pol.miss_window_s / 2
        gate2.set()
        pending.wait()


def test_continuous_batching_submit_returns_decision():
    from repro.configs.registry import smoke_config
    from repro.models.api import build_model
    from repro.serve.continuous import ContinuousBatchingEngine, Request
    import jax
    cfg = smoke_config("qwen2.5-3b").replace(dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    eng = ContinuousBatchingEngine(
        model, params, n_slots=2, max_seq=64,
        admission=AdmissionPolicy(queue_depth=1, shed_depth=2))
    mk = lambda i: Request(rid=i, prompt=rng.integers(
        0, cfg.vocab, 8).astype(np.int32), max_new_tokens=3,
        qos=QosSpec(tenant="flood"))
    d0 = eng.submit(mk(0))
    assert d0.action == "accept" and d0.admitted
    d1 = eng.submit(mk(1))
    assert d1.action == "queue" and d1.admitted  # told to back off, kept
    d2 = eng.submit(mk(2))
    assert d2.action == "shed" and not d2.admitted  # NOT enqueued
    assert len(eng.queue) == 2
    done = eng.run_to_completion()
    assert sorted(r.rid for r in done) == [0, 1]  # the shed rid never ran
    s = eng.admission_summary()
    assert s["sheds"] == 1 and "flood" in s["by_tenant"]
    eng.close()


def test_continuous_batching_legacy_kwargs_warn():
    from repro.configs.registry import smoke_config
    from repro.models.api import build_model
    from repro.serve.continuous import ContinuousBatchingEngine
    import jax
    cfg = smoke_config("qwen2.5-3b").replace(dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    with pytest.warns(DeprecationWarning, match="rx_timeout_s"):
        eng = ContinuousBatchingEngine(model, params, n_slots=2,
                                       max_seq=64, rx_timeout_s=5.0)
    assert eng.qos.timeout_s == 5.0 and eng.rx_timeout_s == 5.0
    eng.close()


# ---- stress: multi-tenant hammer -------------------------------------------


@pytest.mark.stress
def test_stress_multi_tenant_hammer_exact_byte_accounting():
    """4 tenants x 2 threads hammer tx/rx roundtrips through ONE engine,
    one tenant leaf-capped: every byte lands in the right tenant row of
    the class ledger, completed == submitted per tenant, and the cap
    never starves its tenant (run under REPRO_VALIDATE_LOCKS=1 in the
    stress lane — instrumented locks assert the guarded-by discipline
    on the new tier-2 structures)."""
    rt = TransferRuntime(workers=2)
    eng = TransferEngine(TransferPolicy.kernel_level(), runtime=rt,
                         priority=PriorityClass.LAYER)
    tenants = ["t0", "t1", "t2", "t-capped"]
    rt.set_tenant_cap(PriorityClass.LAYER, "t-capped", 200e6, burst_s=0.01)
    n_threads_per, iters, n_elems = 2, 4, 8 * 1024
    per_rt = n_elems * 4 * 2  # tx + rx bytes per roundtrip
    errors: list = []

    def hammer(tenant, seed):
        try:
            spec = QosSpec(tenant=tenant)
            x = np.full(n_elems, float(seed), np.float32)
            for _ in range(iters):
                dev = eng.tx_async(x, qos=spec).wait()
                host = eng.rx_async(dev, qos=spec).wait()
                flat = np.concatenate([np.asarray(h).reshape(-1)
                                       for h in host])
                np.testing.assert_array_equal(flat, x)
        except BaseException as e:  # noqa: BLE001
            errors.append(e)

    threads = [threading.Thread(target=hammer, args=(t, i))
               for i, t in enumerate(tenants)
               for _ in range(n_threads_per)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors, errors
    expected = n_threads_per * iters * per_rt
    rows = rt.class_summary()["layer"]["tenants"]
    for tenant in tenants:
        row = rows[tenant]
        assert row["bytes_total"] == expected, tenant
        assert row["completed"] == row["submitted"], tenant
        assert row["cancelled"] == 0, tenant
    assert rows["t-capped"]["cap_bytes_per_s"] == 200e6
    assert rt.tenant_depth(PriorityClass.LAYER, "t0") == 0
    eng.close()
    rt.close()
