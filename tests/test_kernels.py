"""Per-kernel allclose sweeps vs the pure-jnp oracles (interpret mode)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.transfer import Partitioning, TransferPolicy

KEY = jax.random.PRNGKey(42)


# ---- streamed matmul ------------------------------------------------------

@pytest.mark.parametrize("m,k,n", [(128, 128, 128), (256, 384, 512),
                                   (512, 256, 128)])
@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
def test_matmul_blocks_sweep(m, k, n, dtype):
    from repro.kernels.streamed_matmul.kernel import matmul_blocks
    from repro.kernels.streamed_matmul.ref import matmul_ref
    dt = jnp.dtype(dtype)
    x = jax.random.normal(KEY, (m, k)).astype(dt)
    w = jax.random.normal(jax.random.fold_in(KEY, 1), (k, n)).astype(dt)
    out = matmul_blocks(x, w, block_m=128, block_n=128, block_k=128,
                        interpret=True)
    ref = matmul_ref(x, w)
    tol = 2e-2 if dtype == "bfloat16" else 2e-4
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol * 10)


def test_matmul_unique_matches_blocks():
    from repro.kernels.streamed_matmul.kernel import matmul_blocks, matmul_unique
    x = jax.random.normal(KEY, (256, 256))
    w = jax.random.normal(jax.random.fold_in(KEY, 1), (256, 256))
    a = matmul_unique(x, w, interpret=True)
    b = matmul_blocks(x, w, block_m=128, block_n=128, block_k=128,
                      interpret=True)
    np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-4)


def test_matmul_policy_dispatch_and_vmem_guard():
    from repro.kernels.streamed_matmul.ops import streamed_matmul
    x = jax.random.normal(KEY, (256, 256))
    w = jax.random.normal(jax.random.fold_in(KEY, 1), (256, 256))
    out = streamed_matmul(x, w, TransferPolicy(block_bytes=1 << 16),
                          interpret=True)
    assert out.shape == (256, 256)
    # UNIQUE beyond VMEM budget must raise (the 8MB AXI-limit analogue)
    big = jax.ShapeDtypeStruct((8192, 8192), jnp.float32)
    with pytest.raises(ValueError, match="VMEM"):
        streamed_matmul(
            jnp.zeros(big.shape, big.dtype), jnp.zeros(big.shape, big.dtype),
            TransferPolicy(partitioning=Partitioning.UNIQUE), interpret=True)


# ---- flash attention ------------------------------------------------------

@pytest.mark.parametrize("sq,skv", [(128, 128), (256, 512)])
@pytest.mark.parametrize("causal,window", [(True, 0), (False, 0), (True, 96)])
@pytest.mark.parametrize("n_rep", [1, 4])
def test_flash_attention_sweep(sq, skv, causal, window, n_rep):
    from repro.kernels.flash_attention.kernel import flash_attention_bhsd
    from repro.kernels.flash_attention.ref import attention_ref
    if causal and sq != skv:
        pytest.skip("causal assumes aligned q/kv")
    bh, dh = 4, 64
    q = jax.random.normal(KEY, (bh, sq, dh), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (bh // n_rep, skv, dh))
    v = jax.random.normal(jax.random.fold_in(KEY, 2), (bh // n_rep, skv, dh))
    out = flash_attention_bhsd(q, k, v, causal=causal, window=window,
                               block_q=64, block_kv=64, n_rep=n_rep,
                               interpret=True)
    ref = attention_ref(q, k, v, causal=causal, window=window, n_rep=n_rep)
    np.testing.assert_allclose(out, ref, rtol=2e-4, atol=2e-4)


def test_flash_attention_bf16():
    from repro.kernels.flash_attention.ops import flash_attention
    from repro.kernels.flash_attention.ref import attention_ref
    b, s, h, hkv, dh = 2, 128, 4, 2, 32
    q = jax.random.normal(KEY, (b, s, h, dh)).astype(jnp.bfloat16)
    k = jax.random.normal(jax.random.fold_in(KEY, 1),
                          (b, s, hkv, dh)).astype(jnp.bfloat16)
    v = jax.random.normal(jax.random.fold_in(KEY, 2),
                          (b, s, hkv, dh)).astype(jnp.bfloat16)
    out = flash_attention(q, k, v, block_q=64, block_kv=64, interpret=True)
    qf = q.transpose(0, 2, 1, 3).reshape(b * h, s, dh)
    kf = k.transpose(0, 2, 1, 3).reshape(b * hkv, s, dh)
    vf = v.transpose(0, 2, 1, 3).reshape(b * hkv, s, dh)
    ref = attention_ref(qf, kf, vf, n_rep=2).reshape(b, h, s, dh
                                                     ).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), rtol=5e-2,
                               atol=5e-2)


# ---- ssd scan -------------------------------------------------------------

@pytest.mark.parametrize("chunk", [8, 16, 32])
def test_ssd_intra_chunk_sweep(chunk):
    from repro.kernels.ssd_scan.kernel import ssd_intra_chunk_call
    from repro.kernels.ssd_scan.ref import ssd_intra_chunk_ref
    bs, s, h, p, g, n = 2, 64, 4, 16, 2, 8
    x = jax.random.normal(KEY, (bs, s, h, p)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(KEY, 1),
                                           (bs, s, h)))
    a = -jnp.exp(jax.random.normal(jax.random.fold_in(KEY, 2), (h,)) * 0.3)
    b = jax.random.normal(jax.random.fold_in(KEY, 3), (bs, s, g, n)) * 0.3
    c = jax.random.normal(jax.random.fold_in(KEY, 4), (bs, s, g, n)) * 0.3
    yk, stk, deck = ssd_intra_chunk_call(x, dt, a, b, c, chunk=chunk,
                                         interpret=True)
    yr, st_r, decr = ssd_intra_chunk_ref(x, dt, a, b, c, chunk=chunk)
    np.testing.assert_allclose(yk, yr, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(stk, st_r, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(deck, decr, rtol=1e-5, atol=1e-6)


def test_ssd_full_matches_model_path():
    from repro.kernels.ssd_scan.ops import ssd_full
    from repro.models.layers.ssm import ssd_chunked
    bs, s, h, p, g, n = 2, 64, 4, 16, 1, 8
    x = jax.random.normal(KEY, (bs, s, h, p)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(KEY, 1),
                                           (bs, s, h)))
    a = -jnp.exp(jax.random.normal(jax.random.fold_in(KEY, 2), (h,)) * 0.3)
    b = jax.random.normal(jax.random.fold_in(KEY, 3), (bs, s, g, n)) * 0.3
    c = jax.random.normal(jax.random.fold_in(KEY, 4), (bs, s, g, n)) * 0.3
    y1, f1 = ssd_full(x, dt, a, b, c, chunk=16, use_kernel=True,
                      interpret=True)
    y2, f2 = ssd_chunked(x, dt, a, b, c, chunk=16, return_final_state=True)
    np.testing.assert_allclose(y1, y2, rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(f1, f2, rtol=1e-3, atol=1e-3)


# ---- conv2d ---------------------------------------------------------------

@pytest.mark.parametrize("hw,cin,cout,tile_h", [(16, 8, 16, 4), (32, 4, 8, 8),
                                                (8, 1, 16, 8)])
def test_conv2d_sweep(hw, cin, cout, tile_h):
    from repro.kernels.conv2d.ops import conv2d_relu
    from repro.kernels.conv2d.ref import conv2d_relu_ref
    x = jax.random.normal(KEY, (2, hw, hw, cin))
    w = jax.random.normal(jax.random.fold_in(KEY, 1), (3, 3, cin, cout)) * 0.2
    b = jax.random.normal(jax.random.fold_in(KEY, 2), (cout,)) * 0.1
    out = conv2d_relu(x, w, b, tile_h=tile_h, interpret=True)
    ref = conv2d_relu_ref(x, w, b)
    np.testing.assert_allclose(out, ref, rtol=1e-4, atol=1e-4)


def test_conv2d_no_relu():
    from repro.kernels.conv2d.ops import conv2d_relu
    from repro.kernels.conv2d.ref import conv2d_relu_ref
    x = jax.random.normal(KEY, (1, 8, 8, 4))
    w = jax.random.normal(jax.random.fold_in(KEY, 1), (3, 3, 4, 8)) * 0.2
    b = jnp.zeros((8,))
    out = conv2d_relu(x, w, b, tile_h=4, relu=False, interpret=True)
    ref = conv2d_relu_ref(x, w, b, relu=False)
    np.testing.assert_allclose(out, ref, rtol=1e-4, atol=1e-4)
