"""Layer-level properties: attention blocks==unique, SSD invariants, MoE."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # optional dep — degrade to the seeded fallback sampler
    from _hypothesis_fallback import given, settings, st

from repro.models.layers.attention import attention_blocks, attention_unique
from repro.models.layers.moe import moe_apply, moe_params
from repro.models.layers.ssm import segsum, ssd_chunked, ssd_decode_step

KEY = jax.random.PRNGKey(3)


# ---- attention: blocks-mode == unique-mode (the paper's partitioning is
# semantics-preserving) -----------------------------------------------------

@settings(max_examples=15, deadline=None)
@given(sq=st.integers(1, 48), skv_mult=st.integers(1, 6),
       window=st.sampled_from([0, 16, 64]), chunk=st.sampled_from([16, 64]),
       offset=st.integers(0, 64))
def test_attention_blocks_equals_unique(sq, skv_mult, window, chunk, offset):
    b, h, hkv, dh = 2, 4, 2, 16
    skv = offset + sq + skv_mult * 7
    k1, k2, k3 = jax.random.split(KEY, 3)
    q = jax.random.normal(k1, (b, sq, h, dh))
    k = jax.random.normal(k2, (b, skv, hkv, dh))
    v = jax.random.normal(k3, (b, skv, hkv, dh))
    kv_valid = jnp.asarray(offset + sq)
    u = attention_unique(q, k, v, causal=True, window=window,
                         q_offset=offset, kv_valid=kv_valid)
    bl = attention_blocks(q, k, v, causal=True, window=window,
                          q_offset=offset, kv_valid=kv_valid, kv_chunk=chunk)
    np.testing.assert_allclose(np.asarray(u), np.asarray(bl), rtol=1e-4,
                               atol=1e-4)


# ---- SSD ------------------------------------------------------------------

def _ssd_inputs(s, h=4, p=8, g=2, n=4, bs=2):
    x = jax.random.normal(KEY, (bs, s, h, p)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(KEY, 1),
                                           (bs, s, h)))
    a = -jnp.exp(jax.random.normal(jax.random.fold_in(KEY, 2), (h,)) * 0.3)
    b = jax.random.normal(jax.random.fold_in(KEY, 3), (bs, s, g, n)) * 0.3
    c = jax.random.normal(jax.random.fold_in(KEY, 4), (bs, s, g, n)) * 0.3
    return x, dt, a, b, c


def test_ssd_chunk_size_invariance():
    """The BLOCKS knob must not change the math (paper's partitioning)."""
    x, dt, a, b, c = _ssd_inputs(64)
    y16 = ssd_chunked(x, dt, a, b, c, chunk=16)
    y32 = ssd_chunked(x, dt, a, b, c, chunk=32)
    y64 = ssd_chunked(x, dt, a, b, c, chunk=64)
    np.testing.assert_allclose(y16, y32, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(y16, y64, rtol=1e-4, atol=1e-4)


def test_ssd_matches_naive_recurrence():
    """Chunked SSD == token-by-token linear recurrence (the SSM oracle)."""
    x, dt, a, b, c = _ssd_inputs(32)
    y = ssd_chunked(x, dt, a, b, c, chunk=8)
    bs, s, h, p = x.shape
    g, n = b.shape[2], b.shape[3]
    state = jnp.zeros((bs, h, p, n))
    outs = []
    for t in range(s):
        yt, state = ssd_decode_step(state, x[:, t], dt[:, t], a,
                                    b[:, t], c[:, t])
        outs.append(yt)
    y_naive = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_naive),
                               rtol=2e-3, atol=2e-3)


def test_ssd_state_carry_equals_one_shot():
    """Processing [0:32] then [32:64] with carried state == one shot."""
    x, dt, a, b, c = _ssd_inputs(64)
    y_full, f_full = ssd_chunked(x, dt, a, b, c, chunk=16,
                                 return_final_state=True)
    y1, f1 = ssd_chunked(x[:, :32], dt[:, :32], a, b[:, :32], c[:, :32],
                         chunk=16, return_final_state=True)
    y2, f2 = ssd_chunked(x[:, 32:], dt[:, 32:], a, b[:, 32:], c[:, 32:],
                         chunk=16, initial_state=f1, return_final_state=True)
    np.testing.assert_allclose(np.concatenate([y1, y2], axis=1), y_full,
                               rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(f2, f_full, rtol=2e-3, atol=2e-3)


def test_segsum_semantics():
    x = jnp.asarray([1.0, 2.0, 3.0])
    out = segsum(x)
    assert out[2, 0] == pytest.approx(5.0)  # x1 + x2
    assert out[1, 1] == pytest.approx(0.0)
    assert np.isneginf(np.asarray(out)[0, 1])


# ---- MoE ------------------------------------------------------------------

def test_moe_no_drops_at_high_capacity():
    p = moe_params(KEY, 32, n_experts=4, d_expert=16, n_shared=1,
                   dtype=jnp.float32)
    x = jax.random.normal(jax.random.fold_in(KEY, 9), (2, 8, 32))
    out, m = moe_apply(p, x, top_k=2, capacity_factor=8.0)
    assert out.shape == x.shape
    assert float(m.dropped_frac) == 0.0
    assert np.isfinite(float(m.aux_loss))


def test_moe_capacity_drops_pass_through():
    """With capacity_factor ~0, routed contribution ~0 for most tokens but
    output stays finite (residual semantics are the caller's)."""
    p = moe_params(KEY, 16, n_experts=4, d_expert=8, n_shared=0,
                   dtype=jnp.float32)
    x = jax.random.normal(jax.random.fold_in(KEY, 9), (1, 16, 16))
    out, m = moe_apply(p, x, top_k=2, capacity_factor=0.1)
    assert float(m.dropped_frac) > 0.3
    assert np.isfinite(np.asarray(out)).all()


def test_moe_permutation_equivariance():
    """Permuting tokens permutes outputs (at unlimited capacity)."""
    p = moe_params(KEY, 16, n_experts=4, d_expert=8, n_shared=0,
                   dtype=jnp.float32)
    x = jax.random.normal(jax.random.fold_in(KEY, 11), (1, 12, 16))
    perm = jax.random.permutation(jax.random.fold_in(KEY, 12), 12)
    y1, _ = moe_apply(p, x, top_k=2, capacity_factor=16.0)
    y2, _ = moe_apply(p, x[:, perm], top_k=2, capacity_factor=16.0)
    np.testing.assert_allclose(np.asarray(y1[:, perm]), np.asarray(y2),
                               rtol=2e-4, atol=2e-4)


def test_moe_aux_loss_favors_balance():
    """Uniform routing probabilities -> aux ~= 1; collapsed -> > 1."""
    d, e = 8, 4
    p = moe_params(KEY, d, n_experts=e, d_expert=4, n_shared=0,
                   dtype=jnp.float32)
    p = dict(p)
    p["router"] = jnp.zeros((d, e))  # uniform
    # positive inputs so a one-hot-positive router column always wins
    x = jnp.abs(jax.random.normal(KEY, (1, 64, d))) + 0.1
    _, m_uniform = moe_apply(p, x, top_k=1, capacity_factor=8.0)
    p["router"] = jnp.concatenate(
        [jnp.full((d, 1), 5.0), jnp.full((d, e - 1), -5.0)], axis=1)
    _, m_collapsed = moe_apply(p, x, top_k=1, capacity_factor=8.0)
    assert float(m_collapsed.aux_loss) > float(m_uniform.aux_loss) * 1.5
