"""§Perf iteration C1 regression: sliding-window decode with a sliced cache
read must match the full forward pass exactly."""

import jax
import numpy as np
import pytest

from repro.configs.registry import smoke_config
from repro.models.api import build_model


@pytest.mark.parametrize("window", [32, 64])
def test_swa_decode_sliced_cache_matches_forward(window):
    cfg = smoke_config("h2o-danube-1.8b").replace(dtype="float32",
                                                  sliding_window=window)
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    b, s = 2, 300  # cache 1024 >> 2*window -> the slice path triggers
    toks = jax.random.randint(jax.random.PRNGKey(1), (b, s), 0, cfg.vocab)
    full, _ = jax.jit(m.forward)(params, {"tokens": toks, "labels": toks})
    pl_, cache = jax.jit(lambda p, bb: m.prefill(p, bb, 1024))(
        params, {"tokens": toks[:, : s - 1]})
    dl, _ = jax.jit(m.decode)(params, toks[:, s - 1 : s], cache)
    np.testing.assert_allclose(np.asarray(full[:, s - 2]),
                               np.asarray(pl_[:, -1]), atol=1e-3)
    np.testing.assert_allclose(np.asarray(full[:, s - 1]),
                               np.asarray(dl[:, -1]), atol=1e-3)


def test_swa_multi_step_decode_consistent():
    """Greedy decode for several steps with the sliced cache equals
    re-running prefill each time (slow oracle)."""
    cfg = smoke_config("h2o-danube-1.8b").replace(dtype="float32",
                                                  sliding_window=32)
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    b, s0, steps = 1, 200, 4
    toks = np.asarray(jax.random.randint(jax.random.PRNGKey(2), (b, s0), 0,
                                         cfg.vocab))
    logits, cache = jax.jit(lambda p, bb: m.prefill(p, bb, 512))(
        params, {"tokens": jax.numpy.asarray(toks)})
    pred = np.asarray(logits[:, -1, : cfg.vocab].argmax(-1))[:, None]
    cur = toks
    decode = jax.jit(m.decode)
    for _ in range(steps):
        # oracle: forward over cur predicts the same next token as the
        # incremental (sliced-cache) path just did
        full, _ = jax.jit(m.forward)(
            params, {"tokens": jax.numpy.asarray(cur),
                     "labels": jax.numpy.asarray(cur)})
        oracle = np.asarray(full[:, -1, : cfg.vocab].argmax(-1))[:, None]
        np.testing.assert_array_equal(pred, oracle)
        cur = np.concatenate([cur, pred], axis=1)
        logits, cache = decode(params, jax.numpy.asarray(pred), cache)
        pred = np.asarray(logits[:, -1, : cfg.vocab].argmax(-1))[:, None]
