"""Continuous batching: heterogeneous prompts, slot refill, correctness vs
single-request generation."""

import jax
import numpy as np

from repro.configs.registry import smoke_config
from repro.models.api import build_model
from repro.serve.continuous import ContinuousBatchingEngine, Request


def _setup():
    cfg = smoke_config("qwen2.5-3b").replace(dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def test_continuous_matches_single_request():
    cfg, model, params = _setup()
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab, n).astype(np.int32)
               for n in (9, 14, 11)]
    eng = ContinuousBatchingEngine(model, params, n_slots=2, max_seq=64)
    for i, p in enumerate(prompts):
        eng.submit(Request(rid=i, prompt=p, max_new_tokens=6))
    done = eng.run_to_completion()
    assert len(done) == 3

    # oracle: each request generated alone
    from repro.serve.engine import ServeConfig, ServingEngine
    for req in done:
        solo = ServingEngine(model, params, ServeConfig(max_seq=64))
        res = solo.generate(req.prompt[None], max_new_tokens=6)
        np.testing.assert_array_equal(np.asarray(req.tokens),
                                      res[0].tokens)


def test_more_requests_than_slots_all_complete():
    cfg, model, params = _setup()
    rng = np.random.default_rng(1)
    eng = ContinuousBatchingEngine(model, params, n_slots=2, max_seq=48)
    for i in range(5):
        eng.submit(Request(rid=i, prompt=rng.integers(
            0, cfg.vocab, 8).astype(np.int32), max_new_tokens=4))
    done = eng.run_to_completion()
    assert sorted(r.rid for r in done) == [0, 1, 2, 3, 4]
    assert all(len(r.tokens) == 4 for r in done)


def test_run_to_completion_respects_max_steps():
    """max_steps is exact: the old check ran max_steps + 1 decode steps."""
    cfg, model, params = _setup()
    rng = np.random.default_rng(2)
    eng = ContinuousBatchingEngine(model, params, n_slots=2, max_seq=128)
    eng.submit(Request(rid=0, prompt=rng.integers(0, cfg.vocab, 8).astype(
        np.int32), max_new_tokens=100))
    eng.run_to_completion(max_steps=3)
    assert eng.steps == 3
    eng.close()


def test_token_movement_rides_transfer_engine():
    """Prompt admission is a measured TX and each decode step a measured RX
    on the engine (the ROADMAP 'fold token movement onto the engine' item)."""
    cfg, model, params = _setup()
    rng = np.random.default_rng(3)
    eng = ContinuousBatchingEngine(model, params, n_slots=2, max_seq=64)
    eng.submit(Request(rid=0, prompt=rng.integers(0, cfg.vocab, 6).astype(
        np.int32), max_new_tokens=3))
    eng.run_to_completion()
    tx = [s for s in eng.transfer.stats if s.direction == "tx"]
    rx = [s for s in eng.transfer.stats if s.direction == "rx"]
    assert len(tx) == 1  # one admitted prompt
    # prefill yields token 1; the remaining max_new_tokens-1 decode steps
    # each RX one token batch
    assert len(rx) == 2
    eng.close()
