"""Multi-channel transfer rings: striping correctness, the shared staging
pool, and the cost-model-adaptive policy chooser."""

import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # optional dep — degrade to the seeded fallback sampler
    from _hypothesis_fallback import given, settings, st

from repro.core.channels import (
    ChannelGroup,
    StagingPool,
    calibrate_transfer,
    plan_channels,
)
from repro.core.cost_model import TransferCostModel
from repro.core.streaming import HostStreamingExecutor
from repro.core.transfer import (
    BufferInFlightError,
    LayoutCache,
    Management,
    TransferPolicy,
    reassemble_chunks,
)


def _group(n=2, **kw):
    kw.setdefault("min_stripe_bytes", 1 << 14)  # stripe even small payloads
    return ChannelGroup(TransferPolicy.kernel_level_ring(4, block_bytes=1 << 16),
                        n_channels=n, **kw)


# ---- striping round trips --------------------------------------------------

@pytest.mark.parametrize("n_channels", [2, 3])
def test_striped_roundtrip_bit_exact(n_channels):
    """A payload striped across N channels must reassemble bit-exactly."""
    g = _group(n_channels)
    x = np.random.default_rng(0).standard_normal(100_003).astype(np.float32)
    chunks = g.tx(x)
    np.testing.assert_array_equal(np.asarray(reassemble_chunks(chunks)), x)
    back = g.rx(chunks)
    np.testing.assert_array_equal(
        np.concatenate([np.asarray(b).reshape(-1) for b in back]), x)
    assert any(s.direction == "tx" for s in g.stats)
    g.close()


def test_striped_staged_layout_roundtrip():
    """pack -> striped tx -> unpack across channels is bit-exact, and the
    layout comes from the group's shared-pool cache."""
    g = _group(2)
    arrays = [np.random.default_rng(1).standard_normal((257, 33)).astype(np.float32),
              np.arange(1001, dtype=np.int32),
              np.random.default_rng(2).standard_normal(13).astype(np.float16)]
    lay = g.layouts.get("layer0", arrays)
    out = lay.unpack(g.tx(lay.pack(arrays)))
    for o, a in zip(out, arrays):
        np.testing.assert_array_equal(np.asarray(o), a)
    assert g.layouts.misses == 1
    g.close()


@settings(max_examples=15, deadline=None)
@given(n=st.integers(1, 300_000), n_channels=st.integers(1, 4),
       block_pow=st.integers(12, 18), use_out=st.booleans())
def test_striped_roundtrip_property(n, n_channels, block_pow, use_out):
    """For ARBITRARY payload sizes, channel counts, and block sizes:
    TX -> RX round-trips bit-exactly, reassemble_chunks preserves order,
    and the out= zero-copy path lands the same bytes in the caller's
    buffer."""
    g = ChannelGroup(
        TransferPolicy.kernel_level_ring(3, block_bytes=1 << block_pow),
        n_channels=n_channels, min_stripe_bytes=1 << 13)
    x = (np.arange(n, dtype=np.int64) % 65521).astype(np.float32)
    chunks = g.tx(x)
    np.testing.assert_array_equal(np.asarray(reassemble_chunks(chunks)), x)
    if use_out:
        out = np.empty_like(x)
        res = g.rx(chunks, out=out)
        np.testing.assert_array_equal(out, x)
        assert all(np.shares_memory(out, r) for r in res)
    else:
        back = g.rx(chunks)
        np.testing.assert_array_equal(
            np.concatenate([np.asarray(b).reshape(-1) for b in back]), x)
    g.close()


@settings(max_examples=10, deadline=None)
@given(n_arrays=st.integers(1, 6), base=st.integers(1, 5000),
       n_channels=st.integers(2, 3))
def test_rx_many_arrays_order_preserved_property(n_arrays, base, n_channels):
    """Greedy byte-balanced RX assignment must hand results back in the
    ORIGINAL array order, whatever the per-array sizes."""
    g = _group(n_channels)
    arrays = [np.full(base * (i + 1) + 7, float(i), np.float32)
              for i in range(n_arrays)]
    dev = [reassemble_chunks(g.tx(a)) for a in arrays]
    back = g.rx(dev)
    for i, (b, a) in enumerate(zip(back, arrays)):
        np.testing.assert_array_equal(np.asarray(b).reshape(-1), a)
    # and the zero-copy flat-buffer path preserves the same order
    flat = np.empty(sum(a.size for a in arrays), np.float32)
    g.rx(dev, out=flat)
    np.testing.assert_array_equal(flat, np.concatenate(arrays))
    g.close()


def test_sub_stripe_payload_single_channel():
    """Payloads below two minimum stripes ride ONE channel (striping a tiny
    transfer costs more fixed overhead than it hides)."""
    g = ChannelGroup(TransferPolicy.kernel_level_ring(2),
                     n_channels=4, min_stripe_bytes=1 << 20)
    x = np.arange(64, dtype=np.float32)
    np.testing.assert_array_equal(
        np.asarray(reassemble_chunks(g.tx(x))), x)
    # delegated to ONE member engine, but still visible in group stats
    assert len(g.stats) == 1 and g.stats[0].direction == "tx"
    assert sum(len(e.stats) for e in g.engines) == 1
    g.close()


def test_group_requires_interrupt():
    with pytest.raises(ValueError):
        ChannelGroup(TransferPolicy.user_level_polling(), n_channels=2)
    with pytest.raises(ValueError):
        ChannelGroup(n_channels=0)


def test_group_layout_busy_window_covers_all_channels():
    """The staging buffer stays busy until EVERY channel drained; a re-pack
    inside the window must raise."""
    g = _group(2)
    arrays = [np.zeros(1 << 22, np.float32)]  # 16 MiB: stays in flight
    lay = g.layouts.get("big", arrays)
    ticket = g.tx_async(lay.pack(arrays), layout=lay)
    assert lay._busy is not None  # marked before tx_async returned
    if not ticket.complete:
        with pytest.raises(BufferInFlightError):
            lay.pack(arrays, wait=False, force=True)
    ticket.wait()
    lay.pack(arrays, wait=False, force=True)  # safe once complete
    g.close()


def test_group_runs_streaming_executor():
    """A ChannelGroup duck-types TransferEngine through the three-way
    overlap executor."""
    import jax
    import jax.numpy as jnp

    def apply_fn(params, x):
        (w,) = params
        return jnp.tanh(x @ w)

    jitted = jax.jit(apply_fn)
    rng = np.random.default_rng(3)
    layers = [(f"l{i}", [rng.standard_normal((32, 32)).astype(np.float32)],
               jitted) for i in range(4)]
    x = rng.standard_normal((2, 32)).astype(np.float32)
    g = _group(2)
    out, timing = HostStreamingExecutor(g).run(layers, x)
    y = jnp.asarray(x)
    for _, (w,), fn in layers:
        y = fn([jnp.asarray(w)], y)
    np.testing.assert_allclose(out, np.asarray(y), rtol=1e-5, atol=1e-5)
    assert len(timing.layers) == 4
    g.close()


# ---- staging pool ----------------------------------------------------------

def test_staging_pool_recycles_on_layout_eviction():
    pool = StagingPool()
    cache = LayoutCache(pool=pool)
    a1 = [np.zeros(10_000, np.float32)]
    lay1 = cache.get("k", a1)
    buf1 = lay1._staging
    assert pool.allocations == 1
    # same key, new shapes: old layout evicted, its buffer pooled + reused
    a2 = [np.zeros(9_000, np.float32)]  # same power-of-two size class
    lay2 = cache.get("k", a2)
    assert lay2 is not lay1
    assert lay2._staging is buf1
    assert pool.allocations == 1 and pool.reuses == 1


def test_staging_pool_size_classes():
    pool = StagingPool()
    small = pool.acquire(100)
    assert small.nbytes == 4096  # floor class
    big = pool.acquire(4097)
    assert big.nbytes == 8192
    pool.release(big)
    assert pool.acquire(5000) is big


def test_busy_layout_not_pooled_on_eviction():
    """An in-flight staging buffer must be orphaned, not handed to the next
    layout (that would be the DMA corruption the driver forbids)."""
    import threading
    pool = StagingPool()
    cache = LayoutCache(pool=pool)
    lay1 = cache.get("k", [np.zeros(1000, np.float32)])
    lay1._busy = threading.Event()  # in flight, never completes
    cache.get("k", [np.zeros(900, np.float32)])  # evicts lay1
    assert pool.reuses == 0 and pool.allocations == 2


# ---- adaptive policy chooser ----------------------------------------------

def test_plan_scales_with_payload():
    model = TransferCostModel(t0_s=10e-6, bw_Bps=8e9)
    big = plan_channels(48 << 20, model=model, max_channels=4)
    small = plan_channels(4 << 10, model=model, max_channels=4)
    assert big.n_channels >= small.n_channels
    assert small.n_channels == 1  # 4 KiB can't amortize a second channel
    assert big.policy.depth >= 2
    assert big.policy.block_bytes >= model.optimal_block_bytes(48 << 20) // 4
    assert "adaptive" in big.tag and big.row()["n_channels"] == big.n_channels


def test_plan_blocks_cover_stripe():
    """Chosen block/depth must tile the stripe: no degenerate 1-chunk BLOCKS
    plan, no depth below 2 (that would forfeit overlap)."""
    model = TransferCostModel(t0_s=50e-6, bw_Bps=4e9)
    for payload in (1 << 20, 8 << 20, 64 << 20):
        plan = plan_channels(payload, model=model, max_channels=4)
        stripe = -(-payload // plan.n_channels)
        import math
        n_chunks = math.ceil(stripe / plan.policy.block_bytes)
        assert 2 <= plan.policy.depth <= 8
        if plan.policy.partitioning.value == "blocks":
            assert n_chunks >= 2


def test_calibrate_fits_positive_model():
    model = calibrate_transfer(sizes=(4 << 10, 64 << 10, 1 << 20), repeats=1)
    assert model.t0_s > 0 and model.bw_Bps > 0


def test_auto_group_end_to_end():
    model = TransferCostModel(t0_s=20e-6, bw_Bps=6e9)
    g = ChannelGroup.auto(8 << 20, model=model, max_channels=2)
    assert g.plan is not None and g.n_channels == g.plan.n_channels
    x = np.random.default_rng(4).standard_normal(1 << 20).astype(np.float32)
    np.testing.assert_array_equal(np.asarray(reassemble_chunks(g.tx(x))), x)
    g.close()

# ---- scatter-gather striping ----------------------------------------------

def test_group_sg_striped_reassembly_order():
    """An SG segment list split across channels must come back in global
    segment order, with every engine carrying part of the bytes and no
    segment ever split across channels."""
    g = _group(3)
    rng = np.random.default_rng(7)
    arrays = [(rng.integers(0, 251, size=4096 + 512 * i)).astype(np.float32)
              for i in range(9)]
    total = sum(a.nbytes for a in arrays)
    sg = g.tx_sg(arrays)
    devs = sg.wait(10.0)
    assert len(sg) == len(arrays)
    for a, d in zip(arrays, devs):
        np.testing.assert_array_equal(np.asarray(d), a)
    # per-segment tickets project the same join: index i is segment i
    for i, t in enumerate(sg.tickets):
        np.testing.assert_array_equal(np.asarray(t.wait(10.0)), arrays[i])
    # bytes-balanced split at segment granularity: every channel carried
    # whole segments, and together they carried exactly the payload
    per_eng = [e.tx_bytes_total for e in g.engines]
    assert sum(per_eng) == total
    assert all(b > 0 for b in per_eng)
    # one ring slot per channel share, segments assigned whole: the SG
    # records' descriptor counts partition the segment list exactly
    recs = [next(s for s in e.stats if s.direction == "tx")
            for e in g.engines]
    assert sum(r.n_chunks for r in recs) == len(arrays)
    for r, carried in zip(recs, per_eng):
        assert r.nbytes == carried
    g.close()


def test_group_sg_rx_flat_out_carving():
    """Striped rx_sg with a flat out= lands every segment zero-copy into
    the caller's buffer, in segment order."""
    g = _group(2)
    rng = np.random.default_rng(11)
    arrays = [rng.standard_normal(6000 + 700 * i).astype(np.float32)
              for i in range(4)]
    devs = g.tx_sg(arrays).wait(10.0)
    flat = np.empty(sum(a.nbytes for a in arrays), np.uint8)
    results = g.rx_sg(devs, out=flat).wait(10.0)
    off = 0
    for a, r in zip(arrays, results):
        seg = flat[off:off + a.nbytes].view(np.float32)
        np.testing.assert_array_equal(seg, a)
        # the result IS a byte carve of the caller's buffer (zero-copy)
        r = np.asarray(r)
        assert r.base is flat or (r.base is not None and r.base.base is flat)
        np.testing.assert_array_equal(r.view(np.float32).reshape(-1), a)
        off += a.nbytes
    g.close()


def test_group_sg_single_segment_delegates():
    """One segment (or tiny totals) below the stripe threshold delegate to
    a single channel — no cross-channel join overhead."""
    g = _group(2)
    a = np.arange(512, dtype=np.float32)
    devs = g.tx_sg([a]).wait(10.0)
    np.testing.assert_array_equal(np.asarray(devs[0]), a)
    carried = [e.tx_bytes_total for e in g.engines]
    assert sorted(carried) == [0, a.nbytes]  # exactly one channel used
    g.close()
