"""Shared fixtures. NOTE: no XLA_FLAGS here — tests see the real single CPU
device; multi-device tests (collectives, sharding) spawn subprocesses that
set --xla_force_host_platform_device_count themselves."""

import jax
import numpy as np
import pytest


@pytest.fixture(scope="session")
def key():
    return jax.random.PRNGKey(0)


@pytest.fixture(autouse=True)
def _seed_numpy():
    np.random.seed(0)


def run_in_subprocess(code: str, n_devices: int = 8) -> str:
    """Run python code with N fake host devices; returns stdout."""
    import os
    import subprocess
    import sys

    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = "src"
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, cwd=os.path.dirname(
                             os.path.dirname(os.path.abspath(__file__))),
                         timeout=600)
    assert out.returncode == 0, f"stdout:{out.stdout}\nstderr:{out.stderr}"
    return out.stdout
