"""Shared fixtures. NOTE: no XLA_FLAGS here — tests see the real single CPU
device; multi-device tests (collectives, sharding) spawn subprocesses that
set --xla_force_host_platform_device_count themselves."""

import jax
import numpy as np
import pytest


@pytest.fixture(scope="session")
def key():
    return jax.random.PRNGKey(0)


@pytest.fixture(autouse=True)
def _seed_numpy():
    np.random.seed(0)


# Test snippets are written against current-jax spellings (jax.shard_map,
# AxisType, lax.pvary); install aliases when running on an older jax. Each
# branch is a no-op on jax versions that already provide the API.
_JAX_COMPAT_PREAMBLE = r"""
import jax as _cjax
if not hasattr(_cjax, "shard_map"):
    from jax.experimental.shard_map import shard_map as _c_sm
    _cjax.shard_map = _c_sm
if not hasattr(_cjax.lax, "pvary"):
    _cjax.lax.pvary = lambda _x, _names: _x
if not hasattr(_cjax.sharding, "AxisType"):
    class _CAxisType:
        Auto = None
    _cjax.sharding.AxisType = _CAxisType
    _c_mm = _cjax.make_mesh
    _cjax.make_mesh = (
        lambda shape, names, axis_types=None, **kw: _c_mm(shape, names, **kw))
"""


def run_in_subprocess(code: str, n_devices: int = 8) -> str:
    """Run python code with N fake host devices; returns stdout."""
    import os
    import subprocess
    import sys

    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = "src"
    code = _JAX_COMPAT_PREAMBLE + code
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, cwd=os.path.dirname(
                             os.path.dirname(os.path.abspath(__file__))),
                         timeout=600)
    assert out.returncode == 0, f"stdout:{out.stdout}\nstderr:{out.stderr}"
    return out.stdout
