"""Transfer engine: the paper's policy matrix, property-tested."""

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.cost_model import TransferCostModel
from repro.core.scheduler import CooperativeScheduler
from repro.core.transfer import (
    Buffering,
    BufferInFlightError,
    Management,
    Partitioning,
    TransferEngine,
    TransferPolicy,
)

ALL_POLICIES = [
    TransferPolicy(m, b, p, block_bytes=1 << 14)
    for m in Management for b in Buffering for p in Partitioning
]


@pytest.mark.parametrize("policy", ALL_POLICIES, ids=lambda p: p.tag)
def test_roundtrip_identity(policy):
    eng = TransferEngine(policy)
    x = np.random.rand(5000).astype(np.float32)
    dev = eng.tx(x)
    back = eng.rx(dev)
    flat = np.concatenate([np.asarray(b).reshape(-1) for b in back])
    np.testing.assert_array_equal(flat, x)
    assert eng.stats[0].direction == "tx"
    assert eng.stats[0].nbytes == x.nbytes
    assert eng.stats[1].direction == "rx"


@settings(max_examples=20, deadline=None)
@given(n=st.integers(1, 200_000),
       mi=st.integers(0, 2), bi=st.integers(0, 1), pi=st.integers(0, 1))
def test_roundtrip_property(n, mi, bi, pi):
    policy = TransferPolicy(list(Management)[mi], list(Buffering)[bi],
                            list(Partitioning)[pi], block_bytes=1 << 12)
    eng = TransferEngine(policy)
    x = (np.arange(n) % 251).astype(np.float32)
    back = eng.rx(eng.tx(x))
    flat = np.concatenate([np.asarray(b).reshape(-1) for b in back])
    np.testing.assert_array_equal(flat, x)


def test_chunk_count_matches_policy():
    policy = TransferPolicy(Management.POLLING, Buffering.SINGLE,
                            Partitioning.BLOCKS, block_bytes=4096)
    eng = TransferEngine(policy)
    x = np.zeros(4096, np.float32)  # 16 KiB -> 4 chunks of 4 KiB
    eng.tx(x)
    assert eng.stats[0].n_chunks == 4


def test_unique_never_splits():
    eng = TransferEngine(TransferPolicy.user_level_polling())
    eng.tx(np.zeros(1 << 20, np.uint8))
    assert eng.stats[0].n_chunks == 1


def test_async_ticket_and_callback():
    eng = TransferEngine(TransferPolicy.kernel_level())
    hits = []
    t = eng.tx_async(np.ones(100, np.float32), callback=hits.append)
    out = t.wait()
    assert t.complete and len(out) == 1 and len(hits) == 1


def test_async_requires_interrupt():
    eng = TransferEngine(TransferPolicy.user_level_polling())
    with pytest.raises(ValueError):
        eng.tx_async(np.ones(4, np.float32))


def test_scheduler_interleaves_background():
    sched = CooperativeScheduler(background_budget_s=1e-4)
    ran = {"bg": 0}
    sched.register_background(lambda: ran.__setitem__("bg", ran["bg"] + 1))
    eng = TransferEngine(TransferPolicy.user_level_scheduled(),
                         scheduler=sched)
    eng.tx(np.zeros(1000, np.float32))
    assert ran["bg"] > 0  # the paper's 'PS keeps collecting frames'
    assert sched.stats.transfer_tasks_run >= 1


# ---- cost model -----------------------------------------------------------

def test_cost_model_fit_recovers_params():
    m_true = TransferCostModel(t0_s=8e-6, bw_Bps=2.5e9)
    n = np.array([64, 1 << 12, 1 << 16, 1 << 20, 6 << 20], float)
    t = np.array([m_true.time_unique(int(x)) for x in n])
    m = TransferCostModel.fit(n, t)
    assert abs(m.t0_s - 8e-6) / 8e-6 < 0.05
    assert abs(m.bw_Bps - 2.5e9) / 2.5e9 < 0.05


def test_crossover_matches_paper_shape():
    """Kernel driver: higher t0, similar/better BW -> wins only for large n
    ('longer enough packets')."""
    user = TransferCostModel(t0_s=2e-6, bw_Bps=2e9)
    kern = TransferCostModel(t0_s=30e-6, bw_Bps=3e9)
    n_star = TransferCostModel.crossover_bytes(user, kern)
    assert 1e4 < n_star < 1e6
    assert user.time_unique(1 << 10) < kern.time_unique(1 << 10)
    assert kern.time_unique(8 << 20) < user.time_unique(8 << 20)


@settings(max_examples=30, deadline=None)
@given(nbytes=st.integers(1 << 10, 64 << 20),
       block=st.integers(1 << 12, 1 << 22))
def test_double_buffer_never_slower(nbytes, block):
    m = TransferCostModel(t0_s=10e-6, bw_Bps=3e9)
    t_single = m.time_blocks(nbytes, block, Buffering.SINGLE)
    t_double = m.time_blocks(nbytes, block, Buffering.DOUBLE)
    assert t_double <= t_single + 1e-12


def test_optimal_block_keeps_pipe_full():
    m = TransferCostModel(t0_s=10e-6, bw_Bps=3e9)
    c = m.optimal_block_bytes(16 << 20)
    assert c >= int(10e-6 * 3e9) * 0.9  # ~t0*BW


def test_buffer_inflight_protection():
    """Single-buffer + non-INTERRUPT re-use while busy must raise (the
    kernel driver's memory-protection role)."""
    eng = TransferEngine(TransferPolicy.user_level_polling())
    eng._buffers_busy[0] = __import__("threading").Event()  # busy, never set
    with pytest.raises(BufferInFlightError):
        eng.tx(np.zeros(8, np.float32))
