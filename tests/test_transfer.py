"""Transfer engine: the paper's policy matrix, property-tested."""

import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # optional dep — degrade to the seeded fallback sampler
    from _hypothesis_fallback import given, settings, st

from repro.core.cost_model import TransferCostModel
from repro.core.scheduler import CooperativeScheduler
from repro.core.transfer import (
    Buffering,
    BufferInFlightError,
    LayoutCache,
    Management,
    Partitioning,
    StagedLayout,
    TransferEngine,
    TransferPolicy,
)

ALL_POLICIES = [
    TransferPolicy(m, b, p, block_bytes=1 << 14)
    for m in Management for b in Buffering for p in Partitioning
]


@pytest.mark.parametrize("policy", ALL_POLICIES, ids=lambda p: p.tag)
def test_roundtrip_identity(policy):
    eng = TransferEngine(policy)
    x = np.random.rand(5000).astype(np.float32)
    dev = eng.tx(x)
    back = eng.rx(dev)
    flat = np.concatenate([np.asarray(b).reshape(-1) for b in back])
    np.testing.assert_array_equal(flat, x)
    assert eng.stats[0].direction == "tx"
    assert eng.stats[0].nbytes == x.nbytes
    assert eng.stats[1].direction == "rx"


@settings(max_examples=20, deadline=None)
@given(n=st.integers(1, 200_000),
       mi=st.integers(0, 2), bi=st.integers(0, 1), pi=st.integers(0, 1))
def test_roundtrip_property(n, mi, bi, pi):
    policy = TransferPolicy(list(Management)[mi], list(Buffering)[bi],
                            list(Partitioning)[pi], block_bytes=1 << 12)
    eng = TransferEngine(policy)
    x = (np.arange(n) % 251).astype(np.float32)
    back = eng.rx(eng.tx(x))
    flat = np.concatenate([np.asarray(b).reshape(-1) for b in back])
    np.testing.assert_array_equal(flat, x)


def test_chunk_count_matches_policy():
    policy = TransferPolicy(Management.POLLING, Buffering.SINGLE,
                            Partitioning.BLOCKS, block_bytes=4096)
    eng = TransferEngine(policy)
    x = np.zeros(4096, np.float32)  # 16 KiB -> 4 chunks of 4 KiB
    eng.tx(x)
    assert eng.stats[0].n_chunks == 4


def test_unique_never_splits():
    eng = TransferEngine(TransferPolicy.user_level_polling())
    eng.tx(np.zeros(1 << 20, np.uint8))
    assert eng.stats[0].n_chunks == 1


def test_async_ticket_and_callback():
    eng = TransferEngine(TransferPolicy.kernel_level())
    hits = []
    t = eng.tx_async(np.ones(100, np.float32), callback=hits.append)
    out = t.wait()
    assert t.complete and len(out) == 1 and len(hits) == 1


def test_async_requires_interrupt():
    eng = TransferEngine(TransferPolicy.user_level_polling())
    with pytest.raises(ValueError):
        eng.tx_async(np.ones(4, np.float32))


def test_scheduler_interleaves_background():
    sched = CooperativeScheduler(background_budget_s=1e-4)
    ran = {"bg": 0}
    sched.register_background(lambda: ran.__setitem__("bg", ran["bg"] + 1))
    eng = TransferEngine(TransferPolicy.user_level_scheduled(),
                         scheduler=sched)
    eng.tx(np.zeros(1000, np.float32))
    assert ran["bg"] > 0  # the paper's 'PS keeps collecting frames'
    assert sched.stats.transfer_tasks_run >= 1


# ---- cost model -----------------------------------------------------------

def test_cost_model_fit_recovers_params():
    m_true = TransferCostModel(t0_s=8e-6, bw_Bps=2.5e9)
    n = np.array([64, 1 << 12, 1 << 16, 1 << 20, 6 << 20], float)
    t = np.array([m_true.time_unique(int(x)) for x in n])
    m = TransferCostModel.fit(n, t)
    assert abs(m.t0_s - 8e-6) / 8e-6 < 0.05
    assert abs(m.bw_Bps - 2.5e9) / 2.5e9 < 0.05


def test_crossover_matches_paper_shape():
    """Kernel driver: higher t0, similar/better BW -> wins only for large n
    ('longer enough packets')."""
    user = TransferCostModel(t0_s=2e-6, bw_Bps=2e9)
    kern = TransferCostModel(t0_s=30e-6, bw_Bps=3e9)
    n_star = TransferCostModel.crossover_bytes(user, kern)
    assert 1e4 < n_star < 1e6
    assert user.time_unique(1 << 10) < kern.time_unique(1 << 10)
    assert kern.time_unique(8 << 20) < user.time_unique(8 << 20)


@settings(max_examples=30, deadline=None)
@given(nbytes=st.integers(1 << 10, 64 << 20),
       block=st.integers(1 << 12, 1 << 22))
def test_double_buffer_never_slower(nbytes, block):
    m = TransferCostModel(t0_s=10e-6, bw_Bps=3e9)
    t_single = m.time_blocks(nbytes, block, Buffering.SINGLE)
    t_double = m.time_blocks(nbytes, block, Buffering.DOUBLE)
    assert t_double <= t_single + 1e-12


def test_optimal_block_keeps_pipe_full():
    m = TransferCostModel(t0_s=10e-6, bw_Bps=3e9)
    c = m.optimal_block_bytes(16 << 20)
    assert c >= int(10e-6 * 3e9) * 0.9  # ~t0*BW


def test_buffer_inflight_protection():
    """Single-buffer + non-INTERRUPT re-use while busy must raise (the
    kernel driver's memory-protection role)."""
    eng = TransferEngine(TransferPolicy.user_level_polling())
    eng._buffers_busy[0] = __import__("threading").Event()  # busy, never set
    with pytest.raises(BufferInFlightError):
        eng.tx(np.zeros(8, np.float32))


# ---- descriptor ring ------------------------------------------------------

def test_ring_depth_inflight_window():
    """A depth-4 ring must actually keep >= 3 descriptors in flight when the
    payload splits into more chunks than the ring holds."""
    policy = TransferPolicy.kernel_level_ring(4, block_bytes=1 << 12)
    eng = TransferEngine(policy)
    x = np.random.rand(32 * 1024).astype(np.float32)  # 128 KiB -> 32 chunks
    back = eng.rx(eng.tx(x))
    flat = np.concatenate([np.asarray(b).reshape(-1) for b in back])
    np.testing.assert_array_equal(flat, x)
    assert policy.depth == 4
    assert eng.max_inflight >= 3
    eng.close()


def test_ring_depth_derivation_and_tag():
    assert TransferPolicy.user_level_polling().depth == 1
    assert TransferPolicy(Management.INTERRUPT, Buffering.DOUBLE,
                          Partitioning.BLOCKS).depth == 2
    assert TransferPolicy.kernel_level_ring(7).depth == 7
    assert TransferPolicy.kernel_level_ring(7).tag.endswith("-d7")
    with pytest.raises(ValueError):
        TransferPolicy(ring_depth=-1)


def test_engines_share_one_runtime_with_separate_handles():
    """The PR-4 inversion of the retired per-engine pools: concurrent
    kernel-mode engines dispatch on ONE shared TransferRuntime (no thread
    sprawl, cross-stream arbitration) while keeping isolated per-engine
    registrations (ticket state never crosses engines)."""
    a = TransferEngine(TransferPolicy.kernel_level())
    b = TransferEngine(TransferPolicy.kernel_level())
    ta = a.tx_async(np.ones(1000, np.float32))
    tb = b.tx_async(np.full(1000, 2.0, np.float32))
    ta.wait(), tb.wait()
    assert a._handle is not None and b._handle is not None
    assert a._handle is not b._handle
    assert a._handle.runtime is b._handle.runtime  # ONE interrupt controller
    a.close(), b.close()


# ---- staged layouts -------------------------------------------------------

def test_staged_layout_roundtrip_mixed_dtypes():
    arrays = [np.random.rand(17, 3).astype(np.float32),
              np.arange(11, dtype=np.int32),
              np.random.rand(5).astype(np.float16)]
    lay = StagedLayout(arrays)
    eng = TransferEngine(TransferPolicy.kernel_level_ring(3))
    out = lay.unpack(eng.tx(lay.pack(arrays)))
    for o, a in zip(out, arrays):
        np.testing.assert_array_equal(np.asarray(o), a)
    eng.close()


def test_staged_layout_cache_no_repack_across_frames():
    """Frame 2..N must reuse the SAME staging buffer with zero copies."""
    arrays = [np.random.rand(64, 8).astype(np.float32),
              np.zeros(16, np.float32)]
    cache = LayoutCache()
    lay1 = cache.get("layer0", arrays)
    buf1 = lay1.pack(arrays)
    lay2 = cache.get("layer0", arrays)
    buf2 = lay2.pack(arrays)
    assert lay1 is lay2  # cache hit: same layout object
    assert buf1 is buf2  # identical staging buffer, not a fresh allocation
    assert cache.hits == 1 and cache.misses == 1
    assert lay1.pack_count == 2 and lay1.copy_count == 1  # second pack free


def test_staged_layout_repacks_when_arrays_change():
    a1 = [np.ones(8, np.float32)]
    a2 = [np.full(8, 3.0, np.float32)]
    lay = StagedLayout(a1)
    lay.pack(a1)
    payload = lay.pack(a2)  # different objects -> must copy
    assert lay.copy_count == 2
    np.testing.assert_array_equal(payload.view(np.float32), a2[0])


def test_staged_layout_fresh_arrays_never_stage_stale_data():
    """id() reuse after GC must not fool the copy-skip: every pack with a
    freshly allocated array must stage that array's bytes."""
    lay = StagedLayout([np.zeros(1000, np.float32)])
    for i in range(50):
        payload = lay.pack([np.full(1000, float(i), np.float32)])
        np.testing.assert_array_equal(payload.view(np.float32),
                                      np.full(1000, float(i), np.float32))


def test_staged_layout_one_byte_dtypes_roundtrip():
    """int8/bool must come back with their dtype and values (not raw uint8)."""
    arrays = [np.array([-1, 2, -3], np.int8),
              np.array([True, False, True, True]),
              np.arange(5, dtype=np.uint8)]
    lay = StagedLayout(arrays)
    eng = TransferEngine(TransferPolicy.kernel_level())
    out = lay.unpack(eng.tx(lay.pack(arrays)))
    for o, a in zip(out, arrays):
        host = np.asarray(o)
        assert host.dtype == a.dtype, (host.dtype, a.dtype)
        np.testing.assert_array_equal(host, a)
    eng.close()


def test_dedicated_pool_survives_idle_timeout():
    """A submit racing the workers' idle exit must not strand a descriptor
    (ticket.wait would hang forever). DedicatedWorkerPool is the retired
    per-engine pool's machinery, kept for long-occupancy work
    (checkpoint writes)."""
    import time as _time
    from repro.core.runtime import DedicatedWorkerPool
    pool = DedicatedWorkerPool(workers=2, idle_timeout_s=0.02)
    for _ in range(10):
        _time.sleep(0.025)  # let workers hit (or race) the idle exit
        done, out = pool.submit(lambda: 42)
        assert done.wait(timeout=5.0), "descriptor stranded after idle exit"
        assert out[0] == 42
    pool.close()


def test_staged_layout_busy_repack_raises():
    """Re-packing a staging buffer whose TX is in flight is the user-level
    corruption the kernel driver forbids."""
    eng = TransferEngine(TransferPolicy.kernel_level_ring(2))
    arrays = [np.zeros(1 << 22, np.float32)]  # large enough to stay in flight
    lay = eng.layouts.get("big", arrays)
    ticket = eng.tx_async(lay.pack(arrays), layout=lay)
    if not ticket.complete:
        with pytest.raises(BufferInFlightError):
            lay.pack(arrays, wait=False, force=True)
    ticket.wait()
    lay.pack(arrays, wait=False, force=True)  # safe once complete
    eng.close()


def test_layout_mismatch_raises():
    lay = StagedLayout([np.zeros(4, np.float32)])
    with pytest.raises(ValueError):
        lay.pack([np.zeros(5, np.float32)])


# ---- concurrency: the ring under parallel callers --------------------------

def test_parallel_tx_threads_no_slot_collisions():
    """Concurrent tx() from many threads: slot indices never collide, the
    in-flight window never exceeds the ring depth, and every payload
    round-trips bit-exactly."""
    import threading

    policy = TransferPolicy.kernel_level_ring(4, block_bytes=1 << 14)
    eng = TransferEngine(policy)
    n_threads, errors = 8, []

    def worker(seed):
        try:
            x = np.full(16 * 1024, float(seed), np.float32)  # 64 KiB, 4 chunks
            for _ in range(5):
                back = eng.rx(eng.tx(x))
                flat = np.concatenate([b.reshape(-1) for b in back])
                np.testing.assert_array_equal(flat, x)
        except BaseException as e:  # noqa: BLE001
            errors.append(e)

    threads = [threading.Thread(target=worker, args=(i,))
               for i in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors, errors
    assert eng.slot_collisions == 0
    assert eng.inflight_hwm <= policy.depth
    eng.close()


def test_parallel_tx_async_respects_ring_depth():
    """tx_async no longer bypasses the descriptor ring: concurrent async
    callers stay within the in-flight window and never collide on a slot."""
    import threading

    policy = TransferPolicy(Management.INTERRUPT, Buffering.RING,
                            Partitioning.BLOCKS, block_bytes=1 << 13,
                            ring_depth=3)
    eng = TransferEngine(policy)
    tickets, lock, errors = [], threading.Lock(), []

    def worker(seed):
        try:
            x = np.full(8192, float(seed), np.float32)  # 32 KiB, 4 chunks
            t = eng.tx_async(x)
            with lock:
                tickets.append((t, x))
        except BaseException as e:  # noqa: BLE001
            errors.append(e)

    threads = [threading.Thread(target=worker, args=(i,)) for i in range(6)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors, errors
    for ticket, x in tickets:
        flat = np.concatenate(
            [np.asarray(c).reshape(-1) for c in ticket.wait()])
        np.testing.assert_array_equal(flat, x)
    assert eng.slot_collisions == 0
    assert eng.inflight_hwm <= policy.depth
    eng.close()


def test_mixed_sync_async_share_one_ring():
    """tx() and tx_async()/rx_async() racing on one engine must all obey the
    same slot-exclusivity invariant."""
    import threading

    policy = TransferPolicy.kernel_level_ring(2, block_bytes=1 << 13)
    eng = TransferEngine(policy)
    errors = []

    def sync_worker():
        try:
            x = np.arange(4096, dtype=np.float32)
            for _ in range(4):
                np.testing.assert_array_equal(
                    np.concatenate([b.reshape(-1) for b in eng.rx(eng.tx(x))]),
                    x)
        except BaseException as e:  # noqa: BLE001
            errors.append(e)

    def async_worker():
        try:
            x = np.full(4096, 7.0, np.float32)
            for _ in range(4):
                chunks = eng.tx_async(x).wait()
                host = eng.rx_async(chunks).wait()
                np.testing.assert_array_equal(
                    np.concatenate([h.reshape(-1) for h in host]), x)
        except BaseException as e:  # noqa: BLE001
            errors.append(e)

    threads = [threading.Thread(target=sync_worker),
               threading.Thread(target=async_worker)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors, errors
    assert eng.slot_collisions == 0
    assert eng.inflight_hwm <= policy.depth
    eng.close()


def test_layout_marked_busy_before_submit():
    """The busy flag must be set BEFORE the descriptor reaches the shared
    runtime — the old submit-then-flag order left a window where a re-pack
    could corrupt the in-flight staging buffer."""
    from repro.core.runtime import RuntimeHandle

    eng = TransferEngine(TransferPolicy.kernel_level_ring(2))
    arrays = [np.zeros(1024, np.float32)]
    lay = eng.layouts.get("l", arrays)
    seen = []
    orig = RuntimeHandle.submit

    def spy(self, fn, *a, **kw):
        seen.append(lay._busy is not None and not lay._busy.is_set())
        return orig(self, fn, *a, **kw)

    RuntimeHandle.submit = spy
    try:
        eng.tx_async(lay.pack(arrays), layout=lay).wait()
    finally:
        RuntimeHandle.submit = orig
    assert seen and all(seen)
    eng.close()


# ---- async RX -------------------------------------------------------------

def test_rx_async_ticket_semantics():
    eng = TransferEngine(TransferPolicy.kernel_level())
    dev = eng.tx(np.arange(4096, dtype=np.float32))
    hits = []
    t = eng.rx_async(dev, callback=hits.append)
    out = t.wait()
    assert t.complete and len(hits) == 1
    flat = np.concatenate([o.reshape(-1) for o in out])
    np.testing.assert_array_equal(flat, np.arange(4096, dtype=np.float32))
    assert any(s.direction == "rx" for s in eng.stats)
    eng.close()


def test_rx_async_requires_interrupt():
    eng = TransferEngine(TransferPolicy.user_level_polling())
    with pytest.raises(ValueError):
        eng.rx_async([])


# -- batched descriptor submission: tx_many / rx_many ------------------------
# The coalescing tentpole's submission side: a GROUP of small descriptors is
# one ring transaction with per-descriptor tickets. These properties pin the
# contract the serving layer leans on — batched results are byte-identical to
# K single submits, in input order, with exact byte accounting.

_RING_DEPTHS = [0, 2, 6]  # 0 = kernel_level default, else explicit ring


def _interrupt_ring(depth: int) -> "TransferPolicy":
    if depth == 0:
        return TransferPolicy.kernel_level()
    return TransferPolicy.kernel_level_ring(depth)


@settings(max_examples=10, deadline=None)
@given(k=st.integers(1, 9), base=st.integers(1, 300), di=st.integers(0, 2))
def test_tx_many_rx_many_roundtrip_property(k, base, di):
    eng = TransferEngine(_interrupt_ring(_RING_DEPTHS[di]))
    try:
        arrays = [((np.arange(base + 17 * i) + i) % 251).astype(np.float32)
                  for i in range(k)]
        tx_tickets = eng.tx_many(arrays)
        assert len(tx_tickets) == k
        devs = [t.wait(10.0) for t in tx_tickets]
        for a, d in zip(arrays, devs):
            np.testing.assert_array_equal(np.asarray(d).reshape(-1), a)
        rx_tickets = eng.rx_many(devs)
        hosts = [t.wait(10.0) for t in rx_tickets]
        for a, h in zip(arrays, hosts):
            np.testing.assert_array_equal(np.asarray(h).reshape(-1), a)
    finally:
        eng.close()


def test_many_byte_accounting_matches_singles():
    """tx_many/rx_many account exactly the bytes K single submits would:
    tx_bytes_total / rx_bytes_total are equal across the two engines, and
    the batch lands as ONE stats record carrying all K descriptors."""
    arrays = [(np.arange(64 + 32 * i) % 97).astype(np.int32)
              for i in range(5)]
    total = sum(a.nbytes for a in arrays)

    batched = TransferEngine(TransferPolicy.kernel_level())
    singles = TransferEngine(TransferPolicy.kernel_level())
    try:
        devs = [t.wait(10.0) for t in batched.tx_many(arrays)]
        for t in batched.rx_many(devs):
            t.wait(10.0)
        sdevs = [singles.tx_async(a).wait(10.0)[0] for a in arrays]
        for d in sdevs:
            singles.rx_async([d]).wait(10.0)
        assert batched.tx_bytes_total == total == singles.tx_bytes_total
        assert batched.rx_bytes_total == total == singles.rx_bytes_total
        # one ring transaction -> one record per direction, K chunks each
        tx_recs = [s for s in batched.stats if s.direction == "tx"]
        rx_recs = [s for s in batched.stats if s.direction == "rx"]
        assert len(tx_recs) == 1 and tx_recs[0].n_chunks == len(arrays)
        assert len(rx_recs) == 1 and rx_recs[0].n_chunks == len(arrays)
        assert tx_recs[0].nbytes == rx_recs[0].nbytes == total
    finally:
        batched.close()
        singles.close()


def test_rx_many_out_zero_copy_landing():
    """rx_many keeps rx_async's out= contract per descriptor: each ticket
    resolves to the CALLER'S buffer object, written in place."""
    eng = TransferEngine(TransferPolicy.kernel_level())
    try:
        arrays = [(np.arange(100 * (i + 1)) % 53).astype(np.float32)
                  for i in range(4)]
        devs = [t.wait(10.0) for t in eng.tx_many(arrays)]
        outs = [np.empty_like(a) for a in arrays]
        tickets = eng.rx_many(devs, out=outs)
        for i, t in enumerate(tickets):
            got = t.wait(10.0)
            assert got is outs[i]  # zero-copy: the caller's array itself
            np.testing.assert_array_equal(outs[i], arrays[i])
    finally:
        eng.close()


def test_many_requires_interrupt():
    eng = TransferEngine(TransferPolicy.user_level_polling())
    with pytest.raises(ValueError):
        eng.tx_many([np.zeros(4, np.float32)])
    with pytest.raises(ValueError):
        eng.rx_many([])


@settings(max_examples=6, deadline=None)
@given(k=st.integers(2, 12), nch=st.integers(2, 3))
def test_group_many_striping_preserves_order(k, nch):
    """ChannelGroup round-robins a batch over its channels; tickets come
    back in INPUT order and a flat out= array is carved per descriptor."""
    from repro.core.channels import ChannelGroup

    grp = ChannelGroup(TransferPolicy.kernel_level_ring(4), n_channels=nch)
    try:
        arrays = [((np.arange(32 + 8 * i) + 3 * i) % 127).astype(np.int32)
                  for i in range(k)]
        total_words = sum(a.size for a in arrays)
        devs = [t.wait(10.0) for t in grp.tx_many(arrays)]
        for a, d in zip(arrays, devs):
            np.testing.assert_array_equal(np.asarray(d).reshape(-1), a)
        flat = np.empty(total_words, np.int32)
        tickets = grp.rx_many(devs, out=flat)
        for t in tickets:
            t.wait(10.0)
        off = 0
        for a in arrays:
            np.testing.assert_array_equal(flat[off:off + a.size], a)
            off += a.size
        # byte accounting lands on the per-channel engines and sums exactly
        assert sum(e.rx_bytes_total for e in grp.engines) == flat.nbytes
    finally:
        grp.close()

# ---- scatter-gather: one ring slot, zero staging copy ----------------------
# The SG form submits a segment LIST as one logical transfer: byte-identical
# to the pack path, but each segment is its own zero-copy view riding a
# single ring transaction.

def test_sg_roundtrip_matches_pack_bytes():
    """SG and pack deliver byte-identical device payloads for the same
    layer set (the correctness contract that lets the cost model choose)."""
    eng = TransferEngine(TransferPolicy.kernel_level_ring(4))
    try:
        arrays = [(np.arange(400 + 130 * i) % 251).astype(np.float32)
                  for i in range(4)]
        lay = StagedLayout(arrays)
        packed = lay.unpack(eng.tx(lay.pack(arrays)))
        sg = eng.tx_sg(lay.sg_segments(arrays)).wait(10.0)
        for p, s, a in zip(packed, sg, arrays):
            np.testing.assert_array_equal(np.asarray(p).reshape(-1), a)
            np.testing.assert_array_equal(np.asarray(s), a)
            assert np.asarray(s).dtype == a.dtype and s.shape == a.shape
    finally:
        eng.close()


@settings(max_examples=10, deadline=None)
@given(k=st.integers(1, 8), base=st.integers(2, 400), di=st.integers(0, 2))
def test_sg_roundtrip_property(k, base, di):
    """tx_sg -> rx_sg is the identity for k whole-array segments on any
    INTERRUPT ring depth; ordering is segment order."""
    eng = TransferEngine(_interrupt_ring(_RING_DEPTHS[di]))
    try:
        arrays = [((np.arange(base + 31 * i) + 7 * i) % 251)
                  .astype(np.float32) for i in range(k)]
        devs = eng.tx_sg(arrays).wait(10.0)
        backs = eng.rx_sg(devs).wait(10.0)
        for a, d, b in zip(arrays, devs, backs):
            np.testing.assert_array_equal(np.asarray(d), a)
            np.testing.assert_array_equal(np.asarray(b), a)
    finally:
        eng.close()


def test_sg_partial_segments_roundtrip():
    """(array, offset, nbytes) sub-range segments transfer exactly the
    requested bytes — no staging buffer ever sees them."""
    eng = TransferEngine(TransferPolicy.kernel_level())
    try:
        a = (np.arange(1024) % 251).astype(np.float32)
        item = a.dtype.itemsize
        segs = [(a, 0, 256 * item), (a, 512 * item, 256 * item)]
        devs = eng.tx_sg(segs).wait(10.0)
        np.testing.assert_array_equal(np.asarray(devs[0]), a[:256])
        np.testing.assert_array_equal(np.asarray(devs[1]), a[512:768])
    finally:
        eng.close()


def test_sg_segment_validation():
    eng = TransferEngine(TransferPolicy.kernel_level())
    try:
        a = np.zeros(64, np.float32)
        with pytest.raises(ValueError):  # misaligned offset
            eng.tx_sg([(a, 2, 8)])
        with pytest.raises(ValueError):  # out of bounds
            eng.tx_sg([(a, 0, a.nbytes + 4)])
        with pytest.raises(ValueError):  # non-contiguous partial TX view
            eng.tx_sg([(np.zeros((8, 8), np.float32)[:, ::2], 0, 16)])
    finally:
        eng.close()


def test_sg_requires_interrupt():
    eng = TransferEngine(TransferPolicy.user_level_polling())
    with pytest.raises(ValueError):
        eng.tx_sg([np.zeros(4, np.float32)])
    with pytest.raises(ValueError):
        eng.rx_sg([])


def test_rx_sg_out_zero_copy_landing():
    """rx_sg keeps the out= zero-copy contract: per-segment buffers are
    written in place and a flat array is carved per segment."""
    eng = TransferEngine(TransferPolicy.kernel_level())
    try:
        arrays = [(np.arange(64 * (i + 1)) % 53).astype(np.float32)
                  for i in range(3)]
        devs = eng.tx_sg(arrays).wait(10.0)
        outs = [np.empty_like(a) for a in arrays]
        sg = eng.rx_sg(devs, out=outs)
        for i, got in enumerate(sg.wait(10.0)):
            assert got is outs[i]  # zero-copy: the caller's array itself
            np.testing.assert_array_equal(outs[i], arrays[i])
        # flat variant: one preallocated byte array, carved per segment
        flat = np.empty(sum(a.nbytes for a in arrays), np.uint8)
        results = eng.rx_sg(devs, out=flat).wait(10.0)
        off = 0
        for a, r in zip(arrays, results):
            np.testing.assert_array_equal(
                flat[off:off + a.nbytes].view(np.float32), a)
            off += a.nbytes
    finally:
        eng.close()


def test_sg_one_ring_slot_and_byte_accounting():
    """K segments ride ONE ring transaction: one stats record per
    direction carrying all K descriptors and the exact summed bytes."""
    eng = TransferEngine(TransferPolicy.kernel_level())
    try:
        arrays = [(np.arange(128 + 64 * i) % 97).astype(np.int32)
                  for i in range(5)]
        total = sum(a.nbytes for a in arrays)
        devs = eng.tx_sg(arrays).wait(10.0)
        eng.rx_sg(devs).wait(10.0)
        assert eng.tx_bytes_total == total == eng.rx_bytes_total
        tx_recs = [s for s in eng.stats if s.direction == "tx"]
        rx_recs = [s for s in eng.stats if s.direction == "rx"]
        assert len(tx_recs) == 1 and tx_recs[0].n_chunks == len(arrays)
        assert len(rx_recs) == 1 and rx_recs[0].n_chunks == len(arrays)
        assert eng.slot_collisions == 0
    finally:
        eng.close()


def test_choose_sg_crossover_decision():
    """The pack-vs-SG pricing: SG wins iff K*seg_t0 < total/copy_BW, so
    few large segments ride SG and many small arrays keep the pack."""
    from repro.core.transfer import choose_sg, sg_crossover_segments

    model = TransferCostModel(t0_s=50e-6, bw_Bps=8e9)
    copy_bw = 10e9
    few_large = [8 << 20] * 4     # 4 x 8 MiB: 4*50us << 32MiB/10GBps
    many_small = [4 << 10] * 512  # 512 x 4 KiB: 512*50us >> 2MiB/10GBps
    assert choose_sg(few_large, model, copy_bw_Bps=copy_bw) is True
    assert choose_sg(many_small, model, copy_bw_Bps=copy_bw) is False
    # the crossover segment count separates the two regimes
    k_star = sg_crossover_segments(32 << 20, model, copy_bw_Bps=copy_bw)
    assert 4 < k_star < 512


def test_layout_cache_sg_memo_and_invalidation():
    """decide_sg prices once per key, the memo survives repeat frames,
    invalidate_sg() and a shape change both re-price."""
    cache = LayoutCache()
    arrays = [np.zeros(256, np.float32), np.zeros(512, np.float32)]
    lay = cache.get("k", arrays)
    calls = []

    def decide(sizes):
        calls.append(list(sizes))
        return True

    assert cache.decide_sg("k", lay, decide) is True
    assert cache.decide_sg("k", lay, decide) is True  # memo hit
    assert calls == [[1024, 2048]]
    cache.invalidate_sg()
    assert cache.decide_sg("k", lay, decide) is True
    assert len(calls) == 2
    # shape change on the key evicts the stale decision
    arrays2 = [np.zeros(300, np.float32), np.zeros(512, np.float32)]
    lay2 = cache.get("k", arrays2)
    assert cache.decide_sg("k", lay2, decide) is True
    assert len(calls) == 3 and calls[-1] == [1200, 2048]
