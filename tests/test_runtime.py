"""Unified TransferRuntime: QoS arbitration (priority inversion, fairness,
starvation-freedom), preemptive chunked dispatch, per-class bandwidth
caps, the three paper-mode backends behind one submit contract,
SENSOR-class background ingest, and engine teardown ordering."""

import threading
import time

import numpy as np
import pytest

from repro.core.adaptive import AdaptiveChannelGroup
from repro.core.channels import ChannelGroup
from repro.core.cost_model import TransferCostModel
from repro.core.runtime import (
    CoalescePolicy,
    CooperativeScheduler,
    PollingBackend,
    PreemptibleWork,
    PriorityClass,
    ClassQos,
    ScheduledBackend,
    TransferRuntime,
    backend_for,
    get_runtime,
)
from repro.core.qos import QosSpec
from repro.core.streaming import HostStreamingExecutor
from repro.core.transfer import (
    Ticket,
    TransferEngine,
    TransferPolicy,
)


class _SlowChunkEngine(TransferEngine):
    """TransferEngine whose per-chunk service time is padded to a known
    floor, so preemption yield points are wide enough to hit reliably on
    a noisy 2-core host (real memcpys of test-sized chunks finish in
    microseconds)."""

    def __init__(self, *args, chunk_sleep_s: float = 0.002, **kw):
        super().__init__(*args, **kw)
        self.chunk_sleep_s = chunk_sleep_s

    def _one(self, payload, direction, out=None):
        time.sleep(self.chunk_sleep_s)
        return super()._one(payload, direction, out)


def _sleep_task(log, tag, seconds):
    def fn():
        log.append(tag)
        time.sleep(seconds)
        return tag
    return fn


# ---- one submit contract, three paper modes --------------------------------

def test_backends_share_submit_contract():
    """polling / scheduled / interrupt are three backends of ONE
    ``submit(fn) -> (done, out)`` abstraction; Ticket wraps any of them."""
    with TransferRuntime(workers=1) as rt:
        backends = [
            ("polling", PollingBackend()),
            ("scheduled", ScheduledBackend()),
            ("interrupt", rt.register("t", PriorityClass.LAYER)),
        ]
        for mode, be in backends:
            done, out = be.submit(lambda: 41 + 1)
            if isinstance(be, ScheduledBackend):
                assert not done.is_set()  # runs at drain, on the caller
                be.drain()
            assert Ticket(done, out).wait() == 42, mode
        # errors surface at wait() under every backend
        def boom():
            raise ValueError("boom")
        for mode, be in backends:
            if getattr(be, "closed", False):
                continue
            done, out = be.submit(boom)
            if isinstance(be, ScheduledBackend):
                be.drain()
            with pytest.raises(ValueError):
                Ticket(done, out).wait()


def test_backend_for_maps_paper_modes():
    assert isinstance(backend_for("polling"), PollingBackend)
    sched = CooperativeScheduler()
    be = backend_for("scheduled", scheduler=sched)
    assert isinstance(be, ScheduledBackend) and be.scheduler is sched
    with TransferRuntime(workers=1) as rt:
        h = backend_for("interrupt", runtime=rt,
                        priority=PriorityClass.TOKEN)
        assert h.runtime is rt and h.cls is PriorityClass.TOKEN
    with pytest.raises(ValueError):
        backend_for("dma")


def test_interrupt_engines_join_the_process_runtime():
    """No per-engine pools: kernel-mode engines register on the ONE
    process-shared runtime."""
    a = TransferEngine(TransferPolicy.kernel_level())
    b = TransferEngine(TransferPolicy.kernel_level_ring(3))
    a.tx_async(np.ones(512, np.float32)).wait()
    b.tx_async(np.ones(512, np.float32)).wait()
    assert a._handle.runtime is get_runtime()
    assert b._handle.runtime is get_runtime()
    a.close(), b.close()


# ---- arbitration -----------------------------------------------------------

def test_token_jumps_bulk_backlog():
    """Priority inversion: a BULK flood must not starve TOKEN descriptors —
    tokens jump the queue (deadline promotion + 8x fair-queue weight)."""
    log: list = []
    with TransferRuntime(workers=1) as rt:
        hb = rt.register("bulk", PriorityClass.BULK)
        ht = rt.register("tok", PriorityClass.TOKEN)
        bulk = [Ticket(*hb.submit(_sleep_task(log, ("bulk", i), 0.004),
                                  nbytes=1 << 20))
                for i in range(25)]
        time.sleep(0.012)  # a few bulks dispatch; ~20+ still queued
        toks = [Ticket(*ht.submit(_sleep_task(log, ("tok", i), 0.001),
                                  nbytes=64))
                for i in range(4)]
        for t in toks + bulk:
            t.wait()
    last_tok = max(i for i, e in enumerate(log) if e[0] == "tok")
    bulk_after = sum(1 for e in log[last_tok:] if e[0] == "bulk")
    assert bulk_after >= 10, (
        f"tokens waited out the bulk backlog (only {bulk_after} bulk "
        f"descriptors left after the last token): {log}")
    s = rt.class_summary()
    assert s["token"]["completed"] == 4 and s["bulk"]["completed"] == 25


def test_bulk_not_starved_under_continuous_token_load():
    """Starvation-freedom: EDF over ABSOLUTE deadlines means an old BULK
    descriptor eventually outranks fresh TOKEN traffic."""
    log: list = []
    with TransferRuntime(workers=1) as rt:
        ht = rt.register("tok", PriorityClass.TOKEN)
        hb = rt.register("bulk", PriorityClass.BULK)
        waves = []
        waves += [Ticket(*ht.submit(_sleep_task(log, ("tok", 0, i), 0.002),
                                    nbytes=64)) for i in range(30)]
        bulk = Ticket(*hb.submit(_sleep_task(log, ("bulk", 0, 0), 0.002),
                                 nbytes=1 << 20))
        time.sleep(0.16)  # > BULK's 100 ms deadline: the bulk is now overdue
        waves += [Ticket(*ht.submit(_sleep_task(log, ("tok", 1, i), 0.002),
                                    nbytes=64)) for i in range(30)]
        for t in waves + [bulk]:
            t.wait()
    bulk_pos = next(i for i, e in enumerate(log) if e[0] == "bulk")
    late_tok = [i for i, e in enumerate(log) if e[0] == "tok" and e[1] == 1]
    assert bulk_pos < max(late_tok), (
        "overdue BULK descriptor was starved behind fresh TOKEN traffic")


def test_fairness_within_class_is_fifo():
    """Within one priority class, dispatch order is submission order."""
    log: list = []
    with TransferRuntime(workers=1) as rt:
        h = rt.register("layer", PriorityClass.LAYER)
        tickets = [Ticket(*h.submit(_sleep_task(log, i, 0.001), nbytes=4096))
                   for i in range(12)]
        for t in tickets:
            t.wait()
    assert log == sorted(log)


def test_fifo_baseline_disables_promotion():
    """fair=False is the naive-shared-pool baseline: global FIFO, a token
    behind a bulk backlog waits the whole queue out."""
    log: list = []
    with TransferRuntime(workers=1, fair=False) as rt:
        hb = rt.register("bulk", PriorityClass.BULK)
        ht = rt.register("tok", PriorityClass.TOKEN)
        bulk = [Ticket(*hb.submit(_sleep_task(log, ("bulk", i), 0.002),
                                  nbytes=1 << 20)) for i in range(10)]
        time.sleep(0.005)
        tok = Ticket(*ht.submit(_sleep_task(log, ("tok", 0), 0.001),
                                nbytes=64))
        for t in bulk + [tok]:
            t.wait()
    # the token ran close to last — FIFO gave it no help
    tok_pos = next(i for i, e in enumerate(log) if e[0] == "tok")
    assert tok_pos >= 8


def test_weighted_fair_share_interleaves_classes():
    """With everything inside its deadline, the weighted fair queue gives
    TOKEN (weight 8) more early slots per byte than BULK (weight 1): the
    first token never waits for the whole bulk backlog."""
    qos = {PriorityClass.TOKEN: ClassQos(weight=8.0, deadline_s=10.0),
           PriorityClass.BULK: ClassQos(weight=1.0, deadline_s=10.0)}
    log: list = []
    with TransferRuntime(workers=1, qos=qos) as rt:
        hb = rt.register("bulk", PriorityClass.BULK)
        ht = rt.register("tok", PriorityClass.TOKEN)
        tickets = [Ticket(*hb.submit(_sleep_task(log, ("bulk", i), 0.002),
                                     nbytes=1 << 20)) for i in range(12)]
        time.sleep(0.005)
        tickets += [Ticket(*ht.submit(_sleep_task(log, ("tok", i), 0.001),
                                      nbytes=64)) for i in range(4)]
        for t in tickets:
            t.wait()
    last_tok = max(i for i, e in enumerate(log) if e[0] == "tok")
    assert sum(1 for e in log[last_tok:] if e[0] == "bulk") >= 4


def test_reserved_lane_keeps_a_worker_free_for_token():
    """Dispatch is non-preemptive, so with a TOKEN source registered the
    runtime must never let bulk occupy EVERY worker: a token arriving
    mid-bulk-flood gets the reserved slot instead of waiting out an
    in-service bulk descriptor on each worker."""
    with TransferRuntime(workers=2) as rt:
        ht = rt.register("tok", PriorityClass.TOKEN)  # activates the lane
        hb = rt.register("bulk", PriorityClass.BULK)
        bulk = [Ticket(*hb.submit(lambda: time.sleep(0.03), nbytes=8 << 20))
                for _ in range(4)]
        time.sleep(0.01)  # one bulk in service; the lane holds the other
        t0 = time.perf_counter()
        Ticket(*ht.submit(lambda: None, nbytes=64)).wait()
        tok_lat = time.perf_counter() - t0
        for t in bulk:
            t.wait()
    # without the lane both workers sit in 30 ms sleeps and the token
    # waits ~20 ms; with it, dispatch is immediate
    assert tok_lat < 0.02, f"token waited {tok_lat * 1e3:.1f} ms"


# ---- background (SENSOR) ingest -------------------------------------------

def test_background_task_gets_slices_under_load_and_idle():
    count = {"n": 0}
    with TransferRuntime(workers=1) as rt:
        unregister = rt.register_background(
            lambda: count.__setitem__("n", count["n"] + 1))
        h = rt.register("layer", PriorityClass.LAYER)
        tickets = [Ticket(*h.submit(_sleep_task([], i, 0.002), nbytes=4096))
                   for i in range(8)]
        for t in tickets:
            t.wait()
        under_load = count["n"]
        assert under_load > 0  # slices between completion dispatches
        time.sleep(0.03)
        assert count["n"] > under_load  # idle slices too
        unregister()
        frozen = count["n"]
        time.sleep(0.03)
        assert count["n"] == frozen  # deregistered: no more slices
        assert rt.background_slices_run >= frozen


def test_streaming_executor_sensor_ingest():
    """The paper's concurrent collection+transfer scenario: frame ingest
    registered as a SENSOR-class background task runs DURING the streamed
    frame and stops after it."""
    import jax
    import jax.numpy as jnp

    events = {"n": 0}
    rt = TransferRuntime(workers=2)
    eng = TransferEngine(TransferPolicy.kernel_level_ring(3,
                                                          block_bytes=1 << 16),
                         runtime=rt)
    jitted = jax.jit(lambda params, x: jnp.tanh(x @ params[0]))
    rng = np.random.default_rng(0)
    layers = [(f"l{i}", [rng.standard_normal((256, 256)).astype(np.float32)],
               jitted) for i in range(6)]
    x = rng.standard_normal((8, 256)).astype(np.float32)
    ex = HostStreamingExecutor(
        eng, sensor_fn=lambda: events.__setitem__("n", events["n"] + 1))
    out, timing = ex.run(layers, x)
    assert len(timing.layers) == 6
    assert events["n"] > 0 and ex.sensor_slices == events["n"]
    assert rt._background == []  # unregistered at frame end
    frozen = events["n"]
    eng.tx_async(x).wait()  # traffic after the frame: no more sensor slices
    assert events["n"] == frozen
    eng.close()
    rt.close()


# ---- teardown ordering -----------------------------------------------------

def test_engine_close_is_idempotent_and_deregisters():
    rt = TransferRuntime(workers=1)
    eng = TransferEngine(TransferPolicy.kernel_level(), runtime=rt)
    eng.tx_async(np.ones(1024, np.float32)).wait()
    assert rt.n_registered == 1
    eng.close()
    eng.close()  # idempotent
    assert rt.n_registered == 0
    with pytest.raises(RuntimeError):
        eng.tx(np.ones(8, np.float32))
    with pytest.raises(RuntimeError):
        eng.tx_async(np.ones(8, np.float32))
    rt.close()


def test_engine_close_mid_flight_drains_cleanly():
    """Regression (teardown ordering): close() with descriptors in flight
    must drain them — every issued ticket completes, no late completion
    fires into the dead engine, and the handle deregisters."""
    rt = TransferRuntime(workers=2)
    eng = TransferEngine(TransferPolicy.kernel_level_ring(4,
                                                          block_bytes=1 << 16),
                         runtime=rt)
    x = np.random.default_rng(0).standard_normal(1 << 20).astype(np.float32)
    ticket = eng.tx_async(x)
    eng.close()  # mid-flight: must drain, not orphan
    assert ticket.complete
    chunks = ticket.wait()
    flat = np.concatenate([np.asarray(c).reshape(-1) for c in chunks])
    np.testing.assert_array_equal(flat, x)
    assert rt.n_registered == 0
    assert eng.tx_bytes_total == x.nbytes  # the completion was recorded
    rt.close()


def test_channel_group_close_idempotent_mid_flight():
    rt = TransferRuntime(workers=2)
    g = ChannelGroup(TransferPolicy.kernel_level_ring(4,
                                                      block_bytes=1 << 16),
                     n_channels=2, min_stripe_bytes=1 << 14, runtime=rt)
    x = np.random.default_rng(1).standard_normal(300_000).astype(np.float32)
    ticket = g.tx_async(x)
    g.close()  # mid-flight
    g.close()  # idempotent
    chunks = ticket.wait()
    flat = np.concatenate([np.asarray(c).reshape(-1) for c in chunks])
    np.testing.assert_array_equal(flat, x)
    assert rt.n_registered == 0
    with pytest.raises(RuntimeError):
        g.engines[0].tx(x)
    rt.close()


def test_runtime_close_resolves_queued_tickets_and_frees_slots():
    """Abrupt runtime teardown cancels queued descriptors: every issued
    ticket must still RESOLVE (with an error, not a hang) and the ring
    slots of never-run chunks must be released via on_cancel."""
    rt = TransferRuntime(workers=1)
    slow = rt.register("slow", PriorityClass.BULK)
    gate = threading.Event()
    started = threading.Event()

    def gated():
        started.set()
        gate.wait()

    Ticket(*slow.submit(gated))
    assert started.wait(timeout=5.0)  # the only worker is now occupied
    # completion_workers=1: the engine's workers_hint must not grow the
    # runtime past the single gated worker, or the chunks execute
    policy = TransferPolicy.kernel_level_ring(
        4, block_bytes=1 << 12).with_(completion_workers=1)
    eng = TransferEngine(policy, runtime=rt)
    x = np.arange(4 << 10, dtype=np.uint8)  # 4 chunks, all queued
    ticket = eng.tx_async(x)
    rt.close(timeout=0.1)  # cancels the queued chunks; worker still gated
    gate.set()
    assert ticket._done.wait(timeout=5.0), "cancelled ticket never resolved"
    with pytest.raises(RuntimeError, match="cancelled"):
        ticket.wait()
    # every ring slot was released by on_cancel (no stuck completion event)
    assert all(ev is None or ev.is_set() for ev in eng._buffers_busy)


def test_reserved_lane_releases_after_latency_traffic_goes_quiet():
    """Recency gating: a serving engine that merely EXISTS but has been
    idle past the recency window must not keep halving LAYER/BULK
    dispatch concurrency — the lane releases until token traffic
    returns."""
    with TransferRuntime(workers=2, latency_recency_s=0.05) as rt:
        ht = rt.register("tok", PriorityClass.TOKEN)  # engages the lane
        assert rt._latency_handles == 1
        time.sleep(0.08)  # ...but the token stream goes quiet
        # lane released (even though the TOKEN handle is still live):
        # two bulk descriptors run CONCURRENTLY
        hb = rt.register("bulk", PriorityClass.BULK)
        running = []
        peak = {"n": 0}
        lock = threading.Lock()

        def busy():
            with lock:
                running.append(1)
                peak["n"] = max(peak["n"], len(running))
            time.sleep(0.03)
            with lock:
                running.pop()

        tickets = [Ticket(*hb.submit(busy, nbytes=1 << 20))
                   for _ in range(4)]
        for t in tickets:
            t.wait()
        assert peak["n"] == 2, (
            f"lane still reserving a worker after the token stream went "
            f"quiet (peak bulk concurrency {peak['n']})")
        ht.close()


def test_recent_dispatch_latency_is_time_bounded():
    """Burst-era queue waits must stop informing the crossover once the
    contention ends — recent_dispatch_latency returns None past its TTL."""
    with TransferRuntime(workers=1) as rt:
        h = rt.register("tok", PriorityClass.TOKEN)
        Ticket(*h.submit(lambda: time.sleep(0.002), nbytes=64)).wait()
        assert rt.recent_dispatch_latency(PriorityClass.TOKEN) is not None
        time.sleep(0.05)
        assert rt.recent_dispatch_latency(PriorityClass.TOKEN,
                                          ttl_s=0.02) is None


def test_runtime_workers_respawn_after_idle_exit():
    """A submit racing the shared workers' idle exit must not strand a
    descriptor (the retired pool's invariant, now on the runtime)."""
    with TransferRuntime(workers=2, idle_timeout_s=0.02) as rt:
        h = rt.register("t", PriorityClass.LAYER)
        for _ in range(8):
            time.sleep(0.025)  # let workers hit (or race) the idle exit
            done, out = h.submit(lambda: 42)
            assert done.wait(timeout=5.0), "descriptor stranded"
            assert out[0] == 42


def test_class_summary_per_class_accounting():
    with TransferRuntime(workers=1) as rt:
        eng = TransferEngine(TransferPolicy.kernel_level(), runtime=rt,
                             priority=PriorityClass.LAYER)
        eng.tx(np.ones(4096, np.uint8))
        eng.tx(np.ones(4096, np.uint8), priority=PriorityClass.BULK)
        dev = eng.tx(np.ones(64, np.uint8), priority=PriorityClass.TOKEN)
        eng.rx(dev, priority=PriorityClass.TOKEN)
        s = rt.class_summary()
        # engine default class took the first tx; per-call overrides routed
        # the rest — the ZynqNet per-class traffic ledger
        assert s["layer"]["bytes_total"] == 4096
        assert s["bulk"]["bytes_total"] == 4096
        assert s["token"]["bytes_total"] == 128  # 64 tx + 64 rx
        assert s["token"]["completed"] == 2
        assert s["layer"]["dispatch_p99_ms"] >= 0.0
        eng.close()


# ---- tier 2: per-tenant flows inside a class -------------------------------

def test_tenant_wfq_isolates_victim_from_flooding_tenant():
    """Byte-weighted fair queuing between tenants of ONE class: a tenant
    flooding megabyte descriptors must not make a small-descriptor tenant
    wait out its whole backlog — the victim's tiny submissions accrue
    vtime slowly and keep winning dispatch slots."""
    qos = {PriorityClass.BULK: ClassQos(weight=1.0, deadline_s=10.0)}
    log: list = []
    with TransferRuntime(workers=1, qos=qos) as rt:
        h = rt.register("bulk", PriorityClass.BULK)
        gate = threading.Event()
        started = threading.Event()
        Ticket(*h.submit(lambda: (started.set(), gate.wait())[0]))
        assert started.wait(5.0)  # worker busy: everything below queues
        hog = QosSpec(tenant="hog")
        mouse = QosSpec(tenant="mouse")
        tickets = [Ticket(*h.submit(_sleep_task(log, ("hog", i), 0.001),
                                    nbytes=1 << 20, qos=hog))
                   for i in range(10)]
        tickets += [Ticket(*h.submit(_sleep_task(log, ("mouse", i), 0.001),
                                     nbytes=4096, qos=mouse))
                    for i in range(4)]
        gate.set()
        for t in tickets:
            t.wait()
        s = rt.class_summary()["bulk"]
    last_mouse = max(i for i, e in enumerate(log) if e[0] == "mouse")
    assert last_mouse <= 5, (
        f"victim tenant waited out the flood (last mouse dispatch at "
        f"{last_mouse} of {len(log)}): {log}")
    assert s["tenants"]["hog"]["completed"] == 10
    assert s["tenants"]["mouse"]["completed"] == 4
    assert s["tenants"]["mouse"]["bytes_total"] == 4 * 4096


def test_tenant_weight_biases_share():
    """qos.weight scales a tenant's byte-share: equal-sized backlogs, the
    weight-8 tenant drains ahead of the weight-1 tenant."""
    qos = {PriorityClass.BULK: ClassQos(weight=1.0, deadline_s=10.0)}
    log: list = []
    with TransferRuntime(workers=1, qos=qos) as rt:
        h = rt.register("bulk", PriorityClass.BULK)
        gate = threading.Event()
        started = threading.Event()
        Ticket(*h.submit(lambda: (started.set(), gate.wait())[0]))
        assert started.wait(5.0)
        tickets = []
        for i in range(6):
            tickets.append(Ticket(*h.submit(
                _sleep_task(log, ("heavy", i), 0.001), nbytes=1 << 16,
                qos=QosSpec(tenant="heavy", weight=8.0))))
            tickets.append(Ticket(*h.submit(
                _sleep_task(log, ("light", i), 0.001), nbytes=1 << 16,
                qos=QosSpec(tenant="light", weight=1.0))))
        gate.set()
        for t in tickets:
            t.wait()
    last_heavy = max(i for i, e in enumerate(log) if e[0] == "heavy")
    first_lights = sum(1 for e in log[:last_heavy] if e[0] == "light")
    assert first_lights <= 2, (
        f"weight-8 tenant did not outpace weight-1 ({first_lights} light "
        f"dispatches before the last heavy): {log}")


def test_tenant_cap_tree_leaf_defers_capped_tenant_only():
    """The cap tree's leaf: a per-tenant token bucket defers THAT tenant's
    dispatches while uncapped siblings borrow the class headroom — and the
    deferral is accounted, never a hang."""
    qos = {PriorityClass.BULK: ClassQos(weight=1.0, deadline_s=10.0)}
    log: list = []
    with TransferRuntime(workers=1, qos=qos) as rt:
        h = rt.register("bulk", PriorityClass.BULK)
        gate = threading.Event()
        started = threading.Event()
        Ticket(*h.submit(lambda: (started.set(), gate.wait())[0]))
        assert started.wait(5.0)
        capped = QosSpec(tenant="capped", cap_bytes_per_s=64 * 1024,
                         burst_s=0.001)
        free = QosSpec(tenant="free")
        tickets = [Ticket(*h.submit(_sleep_task(log, ("capped", i), 0.0),
                                    nbytes=4096, qos=capped))
                   for i in range(3)]
        tickets += [Ticket(*h.submit(_sleep_task(log, ("free", i), 0.0),
                                     nbytes=4096, qos=free))
                    for i in range(6)]
        gate.set()
        for t in tickets:
            t.wait(timeout=30.0)
        assert rt.tenant_cap(PriorityClass.BULK, "capped") == 64 * 1024
        s = rt.class_summary()["bulk"]
    # the first capped dispatch spends the burst; the remaining two defer
    # ~64 ms each while every uncapped descriptor flows past
    tail = [e[0] for e in log[-2:]]
    assert tail == ["capped", "capped"], log
    assert s["tenants"]["capped"]["cap_deferrals"] > 0
    assert s["tenants"]["capped"]["cap_bytes_per_s"] == 64 * 1024
    assert s["tenants"]["free"]["cap_deferrals"] == 0
    assert s["tenants"]["capped"]["completed"] == 3  # deferred, not starved


def test_set_tenant_cap_clears_and_survives_unchanged_rate():
    with TransferRuntime(workers=1) as rt:
        rt.set_tenant_cap(PriorityClass.LAYER, "t", 1e6, burst_s=0.5)
        assert rt.tenant_cap(PriorityClass.LAYER, "t") == 1e6
        rt.set_tenant_cap(PriorityClass.LAYER, "t", None)
        assert rt.tenant_cap(PriorityClass.LAYER, "t") is None
        rt.set_tenant_cap(PriorityClass.LAYER, "t", -1.0)
        assert rt.tenant_cap(PriorityClass.LAYER, "t") is None


def test_single_tier_baseline_ignores_tenant_tags():
    """tenant_fair=False collapses tier 2: every submission rides the
    class's default flow, so tenant tags change nothing about dispatch
    order (the benchmark's single-tier comparison arm)."""
    qos = {PriorityClass.BULK: ClassQos(weight=1.0, deadline_s=10.0)}
    log: list = []
    with TransferRuntime(workers=1, qos=qos, tenant_fair=False) as rt:
        h = rt.register("bulk", PriorityClass.BULK)
        gate = threading.Event()
        started = threading.Event()
        Ticket(*h.submit(lambda: (started.set(), gate.wait())[0]))
        assert started.wait(5.0)
        tickets = [Ticket(*h.submit(_sleep_task(log, ("hog", i), 0.0),
                                    nbytes=1 << 20,
                                    qos=QosSpec(tenant="hog")))
                   for i in range(8)]
        tickets += [Ticket(*h.submit(_sleep_task(log, ("mouse", i), 0.0),
                                     nbytes=4096,
                                     qos=QosSpec(tenant="mouse")))
                    for i in range(2)]
        gate.set()
        for t in tickets:
            t.wait()
    # FIFO within the class: the mice ran dead last
    assert [e[0] for e in log[-2:]] == ["mouse", "mouse"]


def test_deadline_miss_rate_windowed():
    """Every dispatch past its EDF deadline counts; the rate is 0.0 on an
    idle runtime and decays once the window ages out."""
    qos = {PriorityClass.TOKEN: ClassQos(weight=8.0, deadline_s=0.0001)}
    with TransferRuntime(workers=1, qos=qos) as rt:
        assert rt.deadline_miss_rate(PriorityClass.TOKEN) == 0.0
        h = rt.register("tok", PriorityClass.TOKEN)
        gate = threading.Event()
        started = threading.Event()
        Ticket(*h.submit(lambda: (started.set(), gate.wait())[0]))
        assert started.wait(5.0)
        tickets = [Ticket(*h.submit(lambda: None, nbytes=64))
                   for _ in range(4)]
        time.sleep(0.01)  # queued past the 0.1 ms deadline
        gate.set()
        for t in tickets:
            t.wait()
        assert rt.deadline_miss_rate(PriorityClass.TOKEN) > 0.0
        assert rt.deadline_miss_rate(PriorityClass.TOKEN, ttl_s=1e-9) == 0.0
        s = rt.class_summary()["token"]
        assert s["deadline_miss_rate"] >= 0.0


# ---- preemptive chunked dispatch -------------------------------------------

def test_preemptible_work_parks_for_token_arrival():
    """A BULK descriptor submitted as a PreemptibleWork yields between
    segments the moment a TOKEN is queued: the token's wait is bounded by
    ONE segment, not the whole descriptor, and the park is accounted."""
    with TransferRuntime(workers=1) as rt:
        hb = rt.register("bulk", PriorityClass.BULK)
        ht = rt.register("tok", PriorityClass.TOKEN)
        log: list = []
        finalized: list = []
        work = PreemptibleWork(
            [(lambda i=i: (log.append(("bulk", i)), time.sleep(0.004))[0])
             for i in range(10)],
            collect=lambda parts: "bulk-done",
            finalize=lambda err: finalized.append(err))
        tb = Ticket(*hb.submit(work, nbytes=10 << 20))
        time.sleep(0.010)  # a few segments run; ~7 remain
        t0 = time.perf_counter()
        Ticket(*ht.submit(lambda: log.append(("tok",)), nbytes=64)).wait()
        tok_lat = time.perf_counter() - t0
        assert tb.wait() == "bulk-done"
        tok_idx = log.index(("tok",))
        assert tok_idx < 10, "token waited out the whole bulk descriptor"
        # bounded by one in-service segment (4 ms) + dispatch slop
        assert tok_lat < 0.02, f"token waited {tok_lat * 1e3:.1f} ms"
        s = rt.class_summary()
        assert s["bulk"]["preemptions"] >= 1
        assert s["bulk"]["preempt_park_p99_ms"] >= 0.0
        assert finalized == [None]  # finalize ran exactly once, no error
        # service time is the SUM of the stints, not just the last one
        assert s["bulk"]["service_p50_ms"] >= 30.0


def test_preemptible_work_progresses_under_continuous_token_load():
    """Parked work runs at least one segment between parks: a continuous
    token stream slows bulk down but cannot starve it."""
    with TransferRuntime(workers=1) as rt:
        hb = rt.register("bulk", PriorityClass.BULK)
        ht = rt.register("tok", PriorityClass.TOKEN)
        work = PreemptibleWork(
            [(lambda: time.sleep(0.002)) for _ in range(8)],
            collect=lambda parts: "done")
        tb = Ticket(*hb.submit(work, nbytes=8 << 20))
        stop = threading.Event()

        def token_flood():
            while not stop.is_set():
                Ticket(*ht.submit(lambda: None, nbytes=64)).wait()
                time.sleep(0.001)

        t = threading.Thread(target=token_flood, daemon=True)
        t.start()
        try:
            assert tb.wait() == "done"  # completes despite the flood
        finally:
            stop.set()
            t.join(timeout=5)


def test_engine_preemptive_chunking_roundtrip_and_segment_sizes():
    """preempt_chunk_bytes splits LAYER/BULK TX chunks into resumable
    segments: the returned device chunk list reassembles exactly, and no
    recorded chunk sample exceeds the segment size."""
    rt = TransferRuntime(workers=2)
    pol = TransferPolicy.kernel_level_ring(
        4, block_bytes=1 << 18).with_(preempt_chunk_bytes=1 << 16)
    eng = TransferEngine(pol, runtime=rt, priority=PriorityClass.BULK)
    x = np.random.default_rng(2).standard_normal(150_001).astype(np.float32)
    for chunks in (eng.tx(x), eng.tx_async(x).wait()):
        flat = np.concatenate([np.asarray(c).reshape(-1) for c in chunks])
        np.testing.assert_array_equal(flat, x)
        assert len(chunks) > (x.nbytes + (1 << 18) - 1) // (1 << 18)
    assert max(n for _, _, n, _ in eng.chunk_samples) <= 1 << 16
    # TOKEN-priority traffic on the same engine is never segment-split
    toks = eng.tx(np.arange(64, dtype=np.int32),
                  priority=PriorityClass.TOKEN)
    assert len(toks) == 1
    eng.close()
    rt.close()


def test_engine_bulk_tx_parks_for_token_mid_chunk():
    """End-to-end preemption: a single-worker runtime streaming slowed
    BULK chunks parks mid-chunk for a TOKEN submission."""
    rt = TransferRuntime(workers=1)
    # completion_workers=1: the engine's workers_hint must not grow the
    # runtime — a second worker would take the token without any park.
    pol = TransferPolicy.kernel_level_ring(
        8, block_bytes=1 << 20).with_(preempt_chunk_bytes=1 << 18,
                                      completion_workers=1)
    eng = _SlowChunkEngine(pol, runtime=rt, priority=PriorityClass.BULK,
                           chunk_sleep_s=0.002)
    ht = rt.register("tok", PriorityClass.TOKEN)
    x = np.zeros(2 << 20, np.uint8)  # 2 chunks x 4 segments x >=2 ms
    ticket = eng.tx_async(x)
    time.sleep(0.004)  # mid first chunk
    t0 = time.perf_counter()
    Ticket(*ht.submit(lambda: None, nbytes=64)).wait()
    tok_lat = time.perf_counter() - t0
    ticket.wait()
    s = rt.class_summary()
    assert s["bulk"]["preemptions"] >= 1, s
    # without preemption the token waits a whole chunk (>= 8 ms)
    assert tok_lat < 0.008, f"token waited {tok_lat * 1e3:.1f} ms"
    eng.close()
    rt.close()


def test_preemptible_work_lookahead_knows_exhaustion():
    """One segment of lookahead: right after the last real segment runs,
    ``exhausted`` is True — the runtime must not park finished work (a
    pointless requeue round-trip that would inflate the preemption
    ledger)."""
    w = PreemptibleWork([lambda: 1, lambda: 2], collect=sum)
    assert not w.exhausted
    assert not w.step()
    assert not w.exhausted
    assert not w.step()
    assert w.exhausted
    assert w.step()  # nothing left
    assert w.result() == 3


# ---- per-class bandwidth caps ----------------------------------------------

def test_parked_resume_is_exempt_from_its_class_cap():
    """A parked mid-chunk descriptor already charged its bytes at first
    dispatch (charge-once) and holds a ring slot: the cap gate must not
    re-gate its resume on the deficit it itself created, or an in-service
    chunk stalls for the whole bucket refill."""
    with TransferRuntime(workers=1, cap_burst_s=0.01) as rt:
        hb = rt.register("bulk", PriorityClass.BULK)
        ht = rt.register("tok", PriorityClass.TOKEN)
        # 1 MiB/s with a ~10 KiB burst: the 8 MiB charge leaves an ~8 s
        # deficit — without the exemption the parked chunk waits it out.
        rt.set_class_cap(PriorityClass.BULK, 1 << 20)
        work = PreemptibleWork([(lambda: time.sleep(0.003))
                                for _ in range(4)],
                               collect=len)
        tb = Ticket(*hb.submit(work, nbytes=8 << 20))
        time.sleep(0.004)  # first segment in service
        Ticket(*ht.submit(lambda: None, nbytes=64)).wait()  # forces a park
        t0 = time.perf_counter()
        assert tb.wait() == 4
        resumed_in = time.perf_counter() - t0
        s = rt.class_summary()
        assert s["bulk"]["preemptions"] >= 1, s
        assert resumed_in < 1.0, (
            f"parked chunk waited {resumed_in:.2f}s — re-gated by its own "
            f"cap deficit instead of resuming")


def test_class_cap_throttles_capped_class_and_uncapped_borrows():
    """A BULK cap paces BULK dispatch at the configured bytes/s — even
    once its descriptors are past their deadline (EDF must not override a
    hard ceiling) — while uncapped LAYER traffic flows at full speed
    through the freed headroom."""
    with TransferRuntime(workers=2, cap_burst_s=0.005) as rt:
        hb = rt.register("bulk", PriorityClass.BULK)
        hl = rt.register("layer", PriorityClass.LAYER)
        rt.set_class_cap(PriorityClass.BULK, 50 << 20)  # 50 MiB/s
        t0 = time.perf_counter()
        bulk = [Ticket(*hb.submit(lambda: None, nbytes=1 << 20))
                for _ in range(8)]
        layer = [Ticket(*hl.submit(lambda: None, nbytes=1 << 20))
                 for _ in range(8)]
        for t in layer:
            t.wait()
        layer_done = time.perf_counter() - t0
        for t in bulk:
            t.wait()
        bulk_done = time.perf_counter() - t0
        s = rt.class_summary()
    assert layer_done < 0.1, f"uncapped LAYER throttled ({layer_done:.3f}s)"
    # 8 MiB at 50 MiB/s minus the burst allowance: >= ~0.1 s of pacing
    assert bulk_done > 0.1, f"cap not enforced ({bulk_done:.3f}s)"
    assert s["bulk"]["cap_deferrals"] > 0
    assert s["bulk"]["cap_bytes_per_s"] == 50 << 20
    assert s["layer"]["cap_bytes_per_s"] is None


def test_class_cap_clear_restores_full_rate():
    with TransferRuntime(workers=1, cap_burst_s=0.005) as rt:
        hb = rt.register("bulk", PriorityClass.BULK)
        rt.set_class_cap(PriorityClass.BULK, 1 << 20)
        Ticket(*hb.submit(lambda: None, nbytes=1 << 20)).wait()  # eats burst
        rt.set_class_cap(PriorityClass.BULK, None)
        assert rt.class_cap(PriorityClass.BULK) is None
        t0 = time.perf_counter()
        tickets = [Ticket(*hb.submit(lambda: None, nbytes=1 << 20))
                   for _ in range(8)]
        for t in tickets:
            t.wait()
        assert time.perf_counter() - t0 < 0.5  # uncapped again


def test_set_class_cap_wiring_engine_group_facade():
    """One cap surface on every transfer duck-type; a facade cap on its
    OWN class also reaches the online planner (post-cap bandwidth)."""
    rt = TransferRuntime(workers=1)
    eng = TransferEngine(TransferPolicy.kernel_level(), runtime=rt)
    eng.set_class_cap(PriorityClass.BULK, 123e6)
    assert rt.class_cap(PriorityClass.BULK) == 123e6
    eng.close()
    g = ChannelGroup(TransferPolicy.kernel_level_ring(2), n_channels=2,
                     runtime=rt)
    g.set_class_cap(PriorityClass.BULK, 99e6)
    assert rt.class_cap(PriorityClass.BULK) == 99e6
    g.close()
    ag = AdaptiveChannelGroup(
        1 << 20, runtime=rt, priority=PriorityClass.LAYER,
        model=TransferCostModel(t0_s=50e-6, bw_Bps=2e9))
    ag.set_class_cap(PriorityClass.LAYER, 55e6)
    assert rt.class_cap(PriorityClass.LAYER) == 55e6
    assert ag.controller._bw_cap_Bps == 55e6
    ag.close()
    rt.close()


def test_teardown_under_cap_with_chunked_descriptor_mid_preemption():
    """The PR-4 drain-deregister guarantee under the new machinery: a
    runtime closed while one chunked BULK descriptor is parked
    mid-preemption and the rest of its chunks are cap-deferred must
    resolve every ticket and release every ring slot (no hang, no
    double-release)."""
    rt = TransferRuntime(workers=1, cap_burst_s=0.005)
    pol = TransferPolicy.kernel_level_ring(
        8, block_bytes=1 << 16).with_(preempt_chunk_bytes=1 << 14,
                                      completion_workers=1)
    eng = _SlowChunkEngine(pol, runtime=rt, priority=PriorityClass.BULK,
                           chunk_sleep_s=0.002)
    ht = rt.register("tok", PriorityClass.TOKEN)
    # burst ~5 KiB at this cap: the first 64 KiB chunk dispatches (bucket
    # starts positive), every later chunk defers on the deep deficit.
    rt.set_class_cap(PriorityClass.BULK, 1 << 20)
    x = np.zeros(4 << 16, np.uint8)  # 4 chunks x 4 segments
    ticket = eng.tx_async(x)
    time.sleep(0.004)  # chunk 1 mid-service
    tok = Ticket(*ht.submit(lambda: None, nbytes=64))  # forces a park
    tok.wait()
    rt.close(timeout=0.3)  # cancels parked + cap-deferred chunks
    assert ticket._done.wait(timeout=5.0), "master ticket never resolved"
    with pytest.raises(RuntimeError, match="cancelled"):
        ticket.wait()
    # every ring slot released exactly once (a stuck event would deadlock
    # the next acquirer; a double release would trip slot accounting)
    assert all(ev is None or ev.is_set() for ev in eng._buffers_busy)
    assert eng._inflight == 0
    eng.close()  # idempotent after runtime teardown


def test_class_summary_reports_cap_and_preemption_columns():
    with TransferRuntime(workers=1) as rt:
        h = rt.register("bulk", PriorityClass.BULK)
        rt.set_class_cap(PriorityClass.BULK, 1e9)
        Ticket(*h.submit(lambda: None, nbytes=4096)).wait()
        row = rt.class_summary()["bulk"]
    for key in ("preemptions", "cap_deferrals", "preempt_park_p50_ms",
                "preempt_park_p99_ms", "cap_bytes_per_s"):
        assert key in row
    assert row["cap_bytes_per_s"] == 1e9


# ---- stress: all four classes live ----------------------------------------

@pytest.mark.stress
def test_stress_four_classes_on_one_runtime():
    """Hammer one shared runtime with SENSOR/TOKEN/LAYER/BULK engines from
    8 threads: exact byte accounting per engine, ring invariants hold, and
    every class both completes and is accounted."""
    rt = TransferRuntime(workers=2)
    classes = [PriorityClass.SENSOR, PriorityClass.TOKEN,
               PriorityClass.LAYER, PriorityClass.BULK]
    engines = {cls: TransferEngine(
        TransferPolicy.kernel_level_ring(3, block_bytes=1 << 14),
        runtime=rt, priority=cls) for cls in classes}
    n_threads_per, iters, n_elems = 2, 4, 8 * 1024
    per_tx = n_elems * 4
    errors: list = []
    sensor_count = {"n": 0}
    unregister = rt.register_background(
        lambda: sensor_count.__setitem__("n", sensor_count["n"] + 1))

    def hammer(cls, seed):
        try:
            eng = engines[cls]
            x = np.full(n_elems, float(seed), np.float32)
            for _ in range(iters):
                dev = eng.tx_async(x).wait()
                host = eng.rx_async(dev).wait()
                flat = np.concatenate([np.asarray(h).reshape(-1)
                                       for h in host])
                np.testing.assert_array_equal(flat, x)
        except BaseException as e:  # noqa: BLE001
            errors.append(e)

    threads = [threading.Thread(target=hammer, args=(cls, i))
               for i, cls in enumerate(classes)
               for _ in range(n_threads_per)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    unregister()
    assert not errors, errors
    expected = n_threads_per * iters * per_tx
    for cls, eng in engines.items():
        assert eng.tx_bytes_total == expected, cls
        assert eng.rx_bytes_total == expected, cls
        assert eng.slot_collisions == 0
        assert eng.inflight_hwm <= eng.policy.depth
        eng.close()
    s = rt.class_summary()
    for cls in classes:
        assert s[cls.value]["completed"] == s[cls.value]["submitted"]
        assert s[cls.value]["completed"] > 0
    assert sensor_count["n"] > 0  # collection survived the 4-class storm
    assert rt.n_registered == 0
    rt.close()


# ---- completion coalescing -------------------------------------------------

def test_coalescing_saves_wakeups_on_bulk_burst():
    """A burst of BULK completions coalesces into few delivery passes:
    completed == submitted (no lost/double completions), and the wakeup
    ledger balances exactly (wakeups_saved = completed - wakeups)."""
    n = 64
    with TransferRuntime(workers=1) as rt:
        h = rt.register("burst", PriorityClass.BULK)
        pairs = [h.submit(lambda: 1, nbytes=4096) for _ in range(n)]
        for ev, _out in pairs:
            assert ev.wait(10.0)
        s = rt.class_summary()["bulk"]
        assert s["completed"] == s["submitted"] == n
        # every descriptor's out list holds EXACTLY one result
        assert all(len(out) == 1 for _ev, out in pairs)
        assert s["completion_wakeups"] < n  # the burst actually coalesced
        assert s["wakeups_saved"] == n - s["completion_wakeups"]
        assert s["coalesce_batch_p99"] > 1
        h.close()


def test_sparse_arrivals_bypass_coalescing():
    """Arrivals spaced wider than the class budget deliver immediately:
    batch size stays 1 and no wakeups are saved — an idle decode loop
    never waits out a coalescing window for its only token."""
    with TransferRuntime(workers=1) as rt:
        h = rt.register("sparse", PriorityClass.TOKEN)
        for _ in range(4):
            ev, _ = h.submit(lambda: 1, nbytes=64)
            assert ev.wait(10.0)  # each completes before the next submits
            time.sleep(0.005)  # >> TOKEN budget (100 us)
        s = rt.class_summary()["token"]
        assert s["completed"] == 4
        assert s["completion_wakeups"] == 4
        assert s["wakeups_saved"] == 0
        assert s["coalesce_batch_p99"] == 1
        h.close()


def test_set_coalesce_drains_stranded_vector():
    """Clearing a class's coalesce policy delivers anything already in its
    completion vector — a policy change never strands a ticket behind a
    long budget while a sibling descriptor holds the pipeline open."""
    with TransferRuntime(workers=1) as rt:
        rt.set_coalesce(PriorityClass.LAYER,
                        CoalescePolicy(max_batch=64, budget_s=30.0))
        h = rt.register("strand", PriorityClass.LAYER)
        # warm-up: the first completion of a class is always sparse-immediate
        ev, _ = h.submit(lambda: 0, nbytes=64)
        assert ev.wait(10.0)
        release = threading.Event()
        # A completes while B is queued behind it (pipeline stays open), so
        # A coalesces into the vector and waits on the 30 s budget...
        ev_a, _ = h.submit(lambda: 1, nbytes=64)
        ev_b, _ = h.submit(release.wait, nbytes=64)
        time.sleep(0.15)
        assert not ev_a.is_set()  # stranded behind the huge budget
        # ...until the policy change flushes it.
        rt.set_coalesce(PriorityClass.LAYER, None)
        assert ev_a.wait(2.0)
        release.set()
        assert ev_b.wait(10.0)
        h.close()


@pytest.mark.stress
def test_stress_coalescing_four_class_hammer():
    """4-class load WITH completion coalescing: BULK floods big transfers
    (widest coalescing window) while TOKEN hammers batched rx_many and
    SENSOR/LAYER roundtrip. Exact byte accounting per engine, every ticket
    resolves exactly once, BULK's window saves real wakeups, and a queued
    TOKEN completion is never delayed past its class deadline by BULK's
    coalescing budget."""
    rt = TransferRuntime(workers=2)
    classes = [PriorityClass.SENSOR, PriorityClass.TOKEN,
               PriorityClass.LAYER, PriorityClass.BULK]
    engines = {cls: TransferEngine(
        TransferPolicy.kernel_level_ring(4, block_bytes=1 << 15),
        runtime=rt, priority=cls) for cls in classes}
    iters, errors = 6, []
    tok_elems, bulk_elems = 1024, 256 * 1024  # 4 KiB tokens, 1 MiB bulk

    def hammer_token():
        try:
            eng = engines[PriorityClass.TOKEN]
            x = [np.full(tok_elems, float(i), np.float32) for i in range(8)]
            for _ in range(iters):
                devs = [t.wait(30.0) for t in eng.tx_many(x)]
                outs = [np.empty(tok_elems, np.float32) for _ in x]
                for i, t in enumerate(eng.rx_many(devs, out=outs)):
                    assert t.wait(30.0) is outs[i]
                for a, o in zip(x, outs):
                    np.testing.assert_array_equal(o, a)
        except BaseException as e:  # noqa: BLE001
            errors.append(e)

    def hammer_sync(cls, elems):
        try:
            eng = engines[cls]
            x = np.full(elems, 7.0, np.float32)
            for _ in range(iters):
                host = eng.rx_async(eng.tx_async(x).wait(30.0)).wait(30.0)
                flat = np.concatenate([np.asarray(h).reshape(-1)
                                       for h in host])
                np.testing.assert_array_equal(flat, x)
        except BaseException as e:  # noqa: BLE001
            errors.append(e)

    threads = ([threading.Thread(target=hammer_token) for _ in range(2)]
               + [threading.Thread(target=hammer_sync, args=(c, n))
                  for c, n in [(PriorityClass.SENSOR, 2048),
                               (PriorityClass.LAYER, 64 * 1024),
                               (PriorityClass.BULK, bulk_elems),
                               (PriorityClass.BULK, bulk_elems)]])
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors, errors
    # exact per-engine byte accounting: every submitted byte completed
    assert engines[PriorityClass.TOKEN].tx_bytes_total == \
        2 * iters * 8 * tok_elems * 4
    assert engines[PriorityClass.TOKEN].rx_bytes_total == \
        2 * iters * 8 * tok_elems * 4
    assert engines[PriorityClass.BULK].tx_bytes_total == \
        2 * iters * bulk_elems * 4
    s = rt.class_summary()
    for cls in classes:
        row = s[cls.value]
        assert row["completed"] == row["submitted"], cls
        assert row["completed"] > 0, cls
        assert row["completion_wakeups"] + row["wakeups_saved"] == \
            row["completed"], cls
    # BULK's wide window did real coalescing under flood
    assert s["bulk"]["wakeups_saved"] > 0
    # ...without holding TOKEN completions past the TOKEN class deadline
    # (1 ms): TOKEN's own 100 us budget bounds its added latency.
    tok_delay = s["token"]["coalesce_delay_p99_ms"]
    assert tok_delay == tok_delay and tok_delay <= 1.0  # not NaN, bounded
    for eng in engines.values():
        assert eng.slot_collisions == 0
        eng.close()
    rt.close()
