"""use_pallas_attention: model forward via the flash kernel (interpret
mode) must match the jnp attention path."""

import jax
import numpy as np

from repro.configs.registry import smoke_config
from repro.models.api import build_model


def test_pallas_attention_model_equivalence():
    cfg = smoke_config("qwen2.5-3b").replace(dtype="float32",
                                             attn_kv_chunk=64)
    m_ref = build_model(cfg)
    m_pal = build_model(cfg.replace(use_pallas_attention=True,
                                    pallas_interpret=True))
    params = m_ref.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 64), 0, cfg.vocab)
    batch = {"tokens": toks, "labels": toks}
    l1, _ = jax.jit(m_ref.forward)(params, batch)
    l2, _ = jax.jit(m_pal.forward)(params, batch)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), atol=1e-3)


def test_pallas_attention_swa_equivalence():
    cfg = smoke_config("h2o-danube-1.8b").replace(
        dtype="float32", attn_kv_chunk=64, sliding_window=32)
    m_ref = build_model(cfg)
    m_pal = build_model(cfg.replace(use_pallas_attention=True,
                                    pallas_interpret=True))
    params = m_ref.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 64), 0, cfg.vocab)
    batch = {"tokens": toks, "labels": toks}
    l1, _ = jax.jit(m_ref.forward)(params, batch)
    l2, _ = jax.jit(m_pal.forward)(params, batch)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), atol=1e-3)
