"""Sharding rules: every leaf's spec must be valid for the production mesh
(divisibility), and a reduced end-to-end shard_map/jit run must agree with
the single-device result."""

import numpy as np
import pytest

from conftest import run_in_subprocess

_RULES_CODE = r"""
import jax, numpy as np
from repro.configs.registry import ARCHS, get_config
from repro.dist.sharding import (batch_sharding_tree, cache_sharding,
                                 opt_state_sharding, param_sharding)
from repro.launch.mesh import make_production_mesh
from repro.models.api import build_model, input_specs, cache_specs
from repro.models.config import SHAPE_CELLS, cell_applicable
from repro.optim import adamw_init

mesh = make_production_mesh(multi_pod=@MP@)

def check(sds_tree, shardings):
    flat_s, _ = jax.tree_util.tree_flatten(sds_tree)
    flat_sh = jax.tree_util.tree_leaves(shardings)
    assert len(flat_s) == len(flat_sh)
    for sds, sh in zip(flat_s, flat_sh):
        spec = sh.spec
        for dim, names in enumerate(spec):
            if names is None:
                continue
            names = (names,) if isinstance(names, str) else names
            size = int(np.prod([mesh.shape[n] for n in names]))
            assert sds.shape[dim] % size == 0, (sds.shape, dim, spec)

for arch in ARCHS:
    cfg = get_config(arch)
    model = build_model(cfg)
    params = jax.eval_shape(model.init, jax.ShapeDtypeStruct((2,), "uint32"))
    check(params, param_sharding(params, mesh))
    opt = jax.eval_shape(adamw_init, params)
    check(opt, opt_state_sharding(opt, mesh))
    for cell in SHAPE_CELLS:
        ok, _ = cell_applicable(cfg, cell)
        if not ok:
            continue
        specs = input_specs(cfg, cell)
        check(specs, batch_sharding_tree(specs, mesh))
        if cell.kind == "decode":
            c = cache_specs(cfg, cell.global_batch, cell.seq_len)
            check(c, cache_sharding(c, mesh))
    print(arch, "ok")
"""

_E2E_CODE = r"""
import jax, jax.numpy as jnp, numpy as np
from repro.configs.registry import smoke_config
from repro.dist.sharding import batch_sharding_tree, param_sharding
from repro.models.api import build_model

mesh = jax.make_mesh((2, 4), ("data", "model"),
                     axis_types=(jax.sharding.AxisType.Auto,) * 2)
cfg = smoke_config("qwen2.5-3b").replace(dtype="float32")
model = build_model(cfg)
params = model.init(jax.random.PRNGKey(0))
batch = {"tokens": jnp.ones((4, 32), jnp.int32),
         "labels": jnp.ones((4, 32), jnp.int32)}
ref, _ = jax.jit(model.loss)(params, batch)

with mesh:
    p_sh = param_sharding(params, mesh)
    b_sh = batch_sharding_tree(batch, mesh)
    params_s = jax.device_put(params, p_sh)
    batch_s = jax.device_put(batch, b_sh)
    out, _ = jax.jit(model.loss, in_shardings=(p_sh, b_sh))(params_s, batch_s)
np.testing.assert_allclose(float(ref), float(out), rtol=1e-5)
print("e2e sharded loss matches:", float(ref))
"""


@pytest.mark.parametrize("multi_pod", ["False", "True"])
def test_sharding_rules_divisible_all_archs(multi_pod):
    out = run_in_subprocess(_RULES_CODE.replace("@MP@", multi_pod), n_devices=512)
    assert out.count("ok") == 10


def test_sharded_loss_matches_single_device():
    out = run_in_subprocess(_E2E_CODE, n_devices=8)
    assert "matches" in out
