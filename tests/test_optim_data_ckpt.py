"""Optimizer, schedules, compression, data pipeline, checkpointing."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpoint import (
    CheckpointManager,
    restore_latest,
    save_checkpoint,
)
from repro.core.transfer import TransferPolicy
from repro.data.pipeline import DataConfig, StagedPipeline, SyntheticLMSource
from repro.models.config import ModelConfig
from repro.optim import AdamWConfig, adamw_init, adamw_update
from repro.optim.compression import (
    compress_grads,
    decompress_grads,
    residual_zeros,
    wire_bytes,
)
from repro.optim.schedule import cosine_schedule

KEY = jax.random.PRNGKey(0)


def test_adamw_converges_on_quadratic():
    target = jnp.asarray([1.0, -2.0, 3.0])
    params = {"w": jnp.zeros((3,))}
    opt = adamw_init(params)
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0)
    for _ in range(200):
        grads = {"w": params["w"] - target}
        params, opt, _ = adamw_update(cfg, grads, opt, params)
    np.testing.assert_allclose(params["w"], target, atol=0.05)


def test_adamw_skips_nonfinite():
    params = {"w": jnp.ones((4,))}
    opt = adamw_init(params)
    cfg = AdamWConfig(lr=0.1)
    bad = {"w": jnp.asarray([1.0, jnp.nan, 1.0, 1.0])}
    new_params, new_opt, m = adamw_update(cfg, bad, opt, params)
    np.testing.assert_array_equal(new_params["w"], params["w"])
    assert int(new_opt["step"]) == 0
    assert float(m["step_ok"]) == 0.0


def test_grad_clip_bounds_update():
    params = {"w": jnp.zeros((2,))}
    opt = adamw_init(params)
    cfg = AdamWConfig(lr=1.0, grad_clip=1e-3, weight_decay=0.0)
    huge = {"w": jnp.full((2,), 1e9)}
    new_params, _, m = adamw_update(cfg, huge, opt, params)
    assert float(jnp.abs(new_params["w"]).max()) < 2.0
    assert float(m["grad_norm"]) > 1e8


def test_cosine_schedule_shape():
    assert float(cosine_schedule(0, warmup=10, total=100)) == 0.0
    assert float(cosine_schedule(10, warmup=10, total=100)) == pytest.approx(
        1.0, abs=1e-3)
    assert float(cosine_schedule(100, warmup=10, total=100)) == pytest.approx(
        0.1, abs=1e-3)


# ---- compression ----------------------------------------------------------

def test_compression_error_feedback_unbiased():
    g = {"w": jax.random.normal(KEY, (256,))}
    res = residual_zeros(g)
    acc = jnp.zeros((256,))
    acc_ref = jnp.zeros((256,))
    for i in range(50):
        comp, res = compress_grads(g, res, jax.random.fold_in(KEY, i))
        acc = acc + decompress_grads(comp)["w"]
        acc_ref = acc_ref + g["w"]
    # error feedback keeps the long-run average unbiased
    np.testing.assert_allclose(acc / 50, acc_ref / 50, atol=0.02)


def test_compression_wire_savings():
    g = {"w": jax.random.normal(KEY, (1024,))}
    comp, _ = compress_grads(g, residual_zeros(g), KEY)
    raw = 1024 * 4
    assert wire_bytes(jax.tree.map(lambda c: c.q, comp,
                                   is_leaf=lambda x: hasattr(x, "q"))) < raw / 3


# ---- data pipeline --------------------------------------------------------

def _cfg():
    return ModelConfig(name="t", family="dense", n_layers=1, d_model=8,
                       vocab=64, n_heads=2, n_kv_heads=2, d_ff=16)


@pytest.mark.parametrize("policy", [
    TransferPolicy.user_level_polling(),
    TransferPolicy.user_level_scheduled(),
    TransferPolicy.kernel_level(),
], ids=lambda p: p.tag)
def test_pipeline_modes_same_data(policy):
    """All three driver modes must deliver identical batches (determinism)."""
    src = SyntheticLMSource(DataConfig(global_batch=4, seq_len=16, seed=7),
                            _cfg())
    pipe = StagedPipeline(src, policy)
    batches = [next(pipe) for _ in range(3)]
    pipe.close()
    ref_src = SyntheticLMSource(DataConfig(global_batch=4, seq_len=16,
                                           seed=7), _cfg())
    for i, b in enumerate(batches):
        np.testing.assert_array_equal(np.asarray(b["tokens"]),
                                      ref_src.next_host_batch(i)["tokens"])


def test_pipeline_engine_staged_batches_identical():
    """Batches staged through a TransferEngine/ChannelGroup (cached layout,
    measured TX, optional striping) must equal plain device_put batches."""
    from repro.core.channels import ChannelGroup

    src = SyntheticLMSource(DataConfig(global_batch=4, seq_len=16, seed=7),
                            _cfg())
    group = ChannelGroup(TransferPolicy.kernel_level_ring(2), n_channels=2,
                         min_stripe_bytes=1 << 8)
    pipe = StagedPipeline(src, TransferPolicy.user_level_polling(),
                          engine=group)
    batches = [next(pipe) for _ in range(2)]
    pipe.close()
    ref_src = SyntheticLMSource(DataConfig(global_batch=4, seq_len=16,
                                           seed=7), _cfg())
    for i, b in enumerate(batches):
        ref = ref_src.next_host_batch(i)
        for k in ref:
            np.testing.assert_array_equal(np.asarray(b[k]), ref[k])
    assert group.layouts.misses == 1 and group.layouts.hits == 1
    group.close()


def test_pipeline_labels_are_shifted_tokens():
    src = SyntheticLMSource(DataConfig(global_batch=2, seq_len=8), _cfg())
    b = src.next_host_batch(0)
    np.testing.assert_array_equal(b["labels"][:, :-1], b["tokens"][:, 1:])


# ---- checkpointing --------------------------------------------------------

def test_checkpoint_roundtrip_bf16(tmp_path):
    state = {"params": {"w": jnp.ones((4, 4), jnp.bfloat16) * 1.5,
                        "b": jnp.arange(3, dtype=jnp.float32)},
             "step": jnp.asarray(7)}
    save_checkpoint(str(tmp_path), 7, state)
    restored = restore_latest(str(tmp_path), state)
    assert restored is not None
    step, tree = restored
    assert step == 7
    np.testing.assert_array_equal(np.asarray(tree["params"]["w"], np.float32),
                                  np.full((4, 4), 1.5))
    assert tree["params"]["w"].dtype == jnp.bfloat16


def test_checkpoint_gc_keeps_n(tmp_path):
    state = {"x": jnp.zeros((2,))}
    for s in (1, 2, 3, 4, 5):
        save_checkpoint(str(tmp_path), s, state, keep=2)
    files = [f for f in os.listdir(tmp_path) if f.endswith(".npz")]
    assert len(files) == 2
    step, _ = restore_latest(str(tmp_path), state)
    assert step == 5


def test_checkpoint_manager_async(tmp_path):
    mgr = CheckpointManager(str(tmp_path), every=2, async_write=True)
    state = {"x": jnp.arange(4, dtype=jnp.float32)}
    assert not mgr.maybe_save(1, state)
    assert mgr.maybe_save(2, state)
    mgr.wait()
    restored = mgr.restore_latest(state)
    assert restored is not None and restored[0] == 2


def test_checkpoint_no_tmp_left_behind(tmp_path):
    save_checkpoint(str(tmp_path), 1, {"x": jnp.zeros((2,))})
    assert not [f for f in os.listdir(tmp_path) if f.startswith(".tmp")]
