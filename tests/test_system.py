"""End-to-end behaviour: training convergence, restart, serving, NullHop."""


import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.accel.nullhop import NullHopExecutor
from repro.accel.roshambo import RoShamBoCNN
from repro.configs.registry import smoke_config
from repro.core.transfer import (
    Buffering,
    Management,
    Partitioning,
    TransferEngine,
    TransferPolicy,
)
from repro.data.pipeline import DataConfig, StagedPipeline, SyntheticLMSource
from repro.models.api import build_model
from repro.optim import AdamWConfig
from repro.serve.engine import ServeConfig, ServingEngine
from repro.train.loop import TrainConfig, Trainer
from repro.utils.timing import StepClock


def _train(cfg, steps, ckpt_dir="", policy=None, n_micro=1):
    model = build_model(cfg)
    tcfg = TrainConfig(steps=steps, n_microbatches=n_micro, warmup=2,
                       log_every=2, opt=AdamWConfig(lr=1e-3),
                       checkpoint_dir=ckpt_dir, checkpoint_every=4,
                       async_checkpoint=False)
    src = SyntheticLMSource(DataConfig(global_batch=4, seq_len=32), cfg)
    pipe = StagedPipeline(src, policy or TransferPolicy.kernel_level())
    tr = Trainer(model, tcfg)
    out = tr.run(pipe)
    pipe.close()
    return tr, out


def test_training_loss_decreases():
    cfg = smoke_config("qwen2.5-3b")
    tr, _ = _train(cfg, steps=12)
    assert tr.history[-1]["loss"] < tr.history[0]["loss"]


def test_microbatched_equals_unmicrobatched_loss():
    """Blocks-mode batch partitioning must not change the metrics."""
    cfg = smoke_config("granite-moe-1b-a400m").replace(
        dtype="float32", capacity_factor=32.0)
    tr1, _ = _train(cfg, steps=3, n_micro=1)
    tr2, _ = _train(cfg, steps=3, n_micro=2)
    assert tr1.history[0]["loss"] == pytest.approx(tr2.history[0]["loss"],
                                                   rel=2e-3)


def test_restart_resumes_from_checkpoint(tmp_path):
    cfg = smoke_config("h2o-danube-1.8b")
    d = str(tmp_path / "ckpt")
    _train(cfg, steps=8, ckpt_dir=d)
    tr2, out2 = _train(cfg, steps=12, ckpt_dir=d)
    assert out2["fault"].restarts == 1
    assert tr2.history[0]["step"] >= 8  # resumed, not from scratch


def test_serving_greedy_deterministic():
    cfg = smoke_config("qwen2.5-3b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = ServingEngine(model, params, ServeConfig(max_seq=64))
    prompts = np.ones((2, 8), np.int32)
    r1 = eng.generate(prompts, max_new_tokens=8)
    r2 = eng.generate(prompts, max_new_tokens=8)
    np.testing.assert_array_equal(r1[0].tokens, r2[0].tokens)
    assert r1[0].tokens.shape == (8,)


def test_serving_multichannel_matches_single():
    """Striped prompt TX / token RX (ChannelGroup) must generate the same
    tokens as the single-engine path."""
    from repro.core.channels import ChannelGroup

    cfg = smoke_config("qwen2.5-3b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompts = np.ones((2, 8), np.int32)
    single = ServingEngine(model, params, ServeConfig(max_seq=64))
    multi = ServingEngine(model, params, ServeConfig(max_seq=64,
                                                     n_channels=2))
    assert isinstance(multi.engine, ChannelGroup)
    r1 = single.generate(prompts, max_new_tokens=6)
    r2 = multi.generate(prompts, max_new_tokens=6)
    np.testing.assert_array_equal(r1[0].tokens, r2[0].tokens)
    single.close(), multi.close()


def test_serving_online_adaptation_matches_single():
    """The online-adaptive engine (rolling refit + safe-point plan swaps)
    must serve byte-identical greedy tokens — adaptation may change HOW
    bytes move, never WHAT arrives."""
    from repro.core.adaptive import AdaptiveChannelGroup

    cfg = smoke_config("qwen2.5-3b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompts = np.ones((2, 8), np.int32)
    single = ServingEngine(model, params, ServeConfig(max_seq=64))
    online = ServingEngine(model, params,
                           ServeConfig(max_seq=64, online_adaptation=True))
    assert isinstance(online.engine, AdaptiveChannelGroup)
    r1 = single.generate(prompts, max_new_tokens=6)
    r2 = online.generate(prompts, max_new_tokens=6)
    r3 = online.generate(prompts, max_new_tokens=6)  # across a safe point
    np.testing.assert_array_equal(r1[0].tokens, r2[0].tokens)
    np.testing.assert_array_equal(r2[0].tokens, r3[0].tokens)
    single.close(), online.close()


def test_straggler_detection():
    clock = StepClock(window=20, zscore_threshold=3.0)
    for _ in range(15):
        clock.record(0.10 + np.random.rand() * 0.001)
    assert clock.record(0.5)  # 5x step time -> straggler
    assert not clock.record(0.101)


# ---- NullHop / RoShamBo (the paper's workload) ----------------------------

def test_nullhop_streamed_equals_monolithic():
    cnn = RoShamBoCNN()
    params = cnn.init(jax.random.PRNGKey(1))
    frame = np.random.default_rng(1).standard_normal(
        (1, 64, 64, 1)).astype(np.float32)
    ref = np.asarray(cnn.apply(params, jnp.asarray(frame)))
    for policy in (TransferPolicy.user_level_polling(),
                   TransferPolicy(Management.INTERRUPT, Buffering.DOUBLE,
                                  Partitioning.BLOCKS, block_bytes=1 << 14)):
        res = NullHopExecutor(cnn, policy).run_frame(params, frame)
        np.testing.assert_allclose(res.logits, ref, rtol=1e-4, atol=1e-4)
        assert len(res.timing.layers) == 5
        assert res.timing.frame_s > 0
        assert all(0.0 <= s <= 1.0 for s in res.sparsity)


def test_streaming_executor_streams_params_per_layer():
    cnn = RoShamBoCNN()
    params = cnn.init(jax.random.PRNGKey(1))
    frame = np.random.default_rng(1).standard_normal(
        (1, 64, 64, 1)).astype(np.float32)
    ex = NullHopExecutor(cnn, TransferPolicy(Management.INTERRUPT,
                                             Buffering.DOUBLE,
                                             Partitioning.UNIQUE))
    res = ex.run_frame(params, frame)
    tx_bytes = sum(l.tx_bytes for l in res.timing.layers)
    assert tx_bytes > frame.nbytes  # params streamed per layer


def test_layer_transfer_bytes_in_100kb_regime():
    """The paper: RoShamBo transfer lengths are ~100 KB."""
    cnn = RoShamBoCNN()
    params = cnn.init(jax.random.PRNGKey(0))
    sizes = cnn.layer_transfer_bytes(params)
    assert len(sizes) == 5
    mid = sorted(s["tx_bytes"] for s in sizes)[2]
    assert 3e4 < mid < 3e6
