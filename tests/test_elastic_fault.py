"""Elastic re-meshing and fault-policy unit tests."""

import pytest

from repro.dist.elastic import MeshPlan, reshard_plan, shrink_mesh
from repro.dist.fault import FaultPolicy, FaultState


def test_shrink_keeps_model_axis():
    plan = shrink_mesh(384, model_parallel=16, multi_pod=True)
    assert plan.axis_names[plan.axis_names.index("model")] == "model"
    assert plan.shape[plan.axis_names.index("model")] == 16
    assert plan.n_devices <= 384


def test_shrink_single_pod():
    plan = shrink_mesh(240, model_parallel=16)
    assert plan.shape == (15, 16)
    assert plan.axis_names == ("data", "model")


def test_shrink_raises_when_model_axis_lost():
    with pytest.raises(ValueError):
        shrink_mesh(8, model_parallel=16)


def test_reshard_plan_data_only_change():
    old = shrink_mesh(512, model_parallel=16, multi_pod=True)
    new = shrink_mesh(384, model_parallel=16, multi_pod=True)
    plan = reshard_plan(256, old, new)
    assert plan["params_move"] is False  # TP width unchanged
    assert plan["grad_replicas"] == new.n_devices // 16


def test_reshard_plan_detects_tp_change():
    old = MeshPlan((16, 16), ("data", "model"))
    new = MeshPlan((32, 8), ("data", "model"))
    plan = reshard_plan(256, old, new)
    assert plan["params_move"] is True


def test_fault_state_counts():
    st = FaultState()
    import numpy as np
    rng = np.random.default_rng(0)
    for _ in range(30):
        st.record_step(0.1 + rng.random() * 1e-3, step_ok=1.0)
    assert st.record_step(1.0, step_ok=0.0)  # straggler + nonfinite
    assert st.stragglers_detected == 1
    assert st.steps_skipped_nonfinite == 1


def test_fault_policy_defaults_sane():
    p = FaultPolicy()
    assert p.checkpoint_every > 0 and p.keep_checkpoints >= 1
