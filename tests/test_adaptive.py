"""Online transfer adaptation: rolling refit math, hysteresis, plan swaps
at safe points, zero-copy RX, and the mid-swap concurrency stress test."""

import threading
import time

import numpy as np
import pytest

from repro.core.adaptive import (
    AdaptiveChannelGroup,
    AdaptiveConfig,
    OnlineTransferController,
    RollingFit,
    choose_management,
)
from repro.core.cost_model import TransferCostModel
from repro.core.transfer import (
    Management,
    TransferEngine,
    TransferPolicy,
    reassemble_chunks,
)

SIZES = (8 << 10, 64 << 10, 512 << 10, 2 << 20)


def _feed(fit_or_ctl, model, sizes=SIZES, repeats=8, mode="interrupt"):
    """Feed synthetic (n, t) samples drawn from ``model``."""
    for _ in range(repeats):
        for n in sizes:
            t = model.time_unique(n)
            if isinstance(fit_or_ctl, RollingFit):
                fit_or_ctl.add(n, t)
            else:
                fit_or_ctl.add_chunk_sample("tx", mode, n, t)


# ---- RollingFit ------------------------------------------------------------

def test_rolling_fit_recovers_model():
    m_true = TransferCostModel(t0_s=80e-6, bw_Bps=3e9)
    fit = RollingFit(window=128, ewma_halflife=64)
    _feed(fit, m_true)
    m = fit.fit(4)
    assert abs(m.t0_s - m_true.t0_s) / m_true.t0_s < 0.05
    assert abs(m.bw_Bps - m_true.bw_Bps) / m_true.bw_Bps < 0.05


def test_rolling_fit_converges_on_drift_trace():
    """After a regime change, the EWMA-weighted fit must track the NEW
    t0/BW once a window's worth of samples arrived — not the average of
    both regimes."""
    old = TransferCostModel(t0_s=50e-6, bw_Bps=4e9)
    new = TransferCostModel(t0_s=1e-3, bw_Bps=1e9)
    fit = RollingFit(window=128, ewma_halflife=8)
    _feed(fit, old, repeats=6)
    _feed(fit, new, repeats=10)
    m = fit.fit(4)
    assert abs(m.t0_s - new.t0_s) / new.t0_s < 0.25
    assert abs(m.bw_Bps - new.bw_Bps) / new.bw_Bps < 0.25


def test_rolling_fit_degenerate_size_returns_none():
    """A single payload size cannot separate t0 from BW: no fit, so the
    caller knows to probe."""
    fit = RollingFit(window=64)
    for _ in range(30):
        fit.add(1 << 20, 1e-3)
    assert fit.fit(4) is None
    assert fit.size_spread == 1.0


def test_rolling_fit_ttl_expires_stale_samples():
    fit = RollingFit(window=64, ttl_s=0.05)
    _feed(fit, TransferCostModel(t0_s=1e-4, bw_Bps=1e9), repeats=2)
    assert len(fit) > 0
    time.sleep(0.08)
    assert len(fit) == 0 and fit.fit(2) is None


# ---- controller: hysteresis + per-mode independence ------------------------

def _controller(**cfg_kw):
    cfg_kw.setdefault("min_samples", 8)
    cfg_kw.setdefault("refit_every", 1)
    cfg = AdaptiveConfig(**cfg_kw)
    model = TransferCostModel(t0_s=100e-6, bw_Bps=2e9)
    return OnlineTransferController(8 << 20, model=model, cfg=cfg), model


def test_hysteresis_suppresses_noise_but_not_drift():
    ctl, model = _controller(hysteresis=1.5)
    # noise: samples within ~15% of the planned model -> no replan
    noisy = TransferCostModel(t0_s=model.t0_s * 1.15,
                              bw_Bps=model.bw_Bps * 0.85)
    _feed(ctl, noisy)
    for _ in range(5):
        assert ctl.propose() is None
    assert ctl.suppressed >= 1 and ctl.replans == 0
    # drift: 5x t0 -> replan fires
    drifted = TransferCostModel(t0_s=model.t0_s * 5, bw_Bps=model.bw_Bps)
    _feed(ctl, drifted, repeats=20)
    plan = ctl.propose()
    assert plan is not None and ctl.replans == 1
    assert abs(plan.model.t0_s - drifted.t0_s) / drifted.t0_s < 0.3


def test_no_flapping_on_stationary_noise():
    """Repeated proposes on stationary noisy samples must not keep
    replanning (the plan-flapping failure mode)."""
    ctl, model = _controller(hysteresis=1.5)
    rng = np.random.default_rng(0)
    for _ in range(60):
        for n in SIZES:
            t = model.time_unique(n) * float(rng.uniform(0.9, 1.12))
            ctl.add_chunk_sample("tx", "interrupt", n, t)
        ctl.propose()
    assert ctl.replans <= 1  # at most one settle-in replan, then stable


def test_per_mode_fits_stay_independent():
    ctl, _ = _controller()
    poll = TransferCostModel(t0_s=5e-6, bw_Bps=1.5e9)
    intr = TransferCostModel(t0_s=200e-6, bw_Bps=3e9)
    _feed(ctl, poll, mode="polling")
    _feed(ctl, intr, mode="interrupt")
    models = ctl.models()
    mp = models[("tx", "polling")]
    mi = models[("tx", "interrupt")]
    assert abs(mp.t0_s - poll.t0_s) / poll.t0_s < 0.05
    assert abs(mi.t0_s - intr.t0_s) / intr.t0_s < 0.05
    assert abs(mp.bw_Bps - poll.bw_Bps) / poll.bw_Bps < 0.05
    assert abs(mi.bw_Bps - intr.bw_Bps) / intr.bw_Bps < 0.05


def test_choose_management_crossover():
    poll = TransferCostModel(t0_s=2e-6, bw_Bps=2e9)
    intr = TransferCostModel(t0_s=30e-6, bw_Bps=3e9)
    fits = {"polling": poll, "interrupt": intr}
    n_star = TransferCostModel.crossover_bytes(poll, intr)
    assert choose_management(fits, int(n_star // 2)) is Management.POLLING
    assert choose_management(fits, int(n_star * 2)) is Management.INTERRUPT
    # one-sided data: default to INTERRUPT
    assert choose_management({"interrupt": intr}, 64) is Management.INTERRUPT


def test_controller_replans_to_polling_below_crossover():
    """With per-mode fits on both sides and a small payload mix, the
    replanned policy must cross to the user-level polling driver."""
    ctl, model = _controller(hysteresis=1.1)
    small_sizes = (1 << 10, 4 << 10, 16 << 10, 64 << 10)
    poll = TransferCostModel(t0_s=2e-6, bw_Bps=2e9)
    intr = TransferCostModel(t0_s=500e-6, bw_Bps=2.5e9)
    for _ in range(8):
        for n in small_sizes:
            ctl.add_chunk_sample("tx", "polling", n, poll.time_unique(n))
            ctl.add_chunk_sample("tx", "interrupt", n, intr.time_unique(n))
    ctl._payloads.clear()
    ctl._payloads.append(16 << 10)  # typical payload: far below crossover
    plan = ctl.propose(force=True)
    assert plan is not None
    assert plan.policy.management is Management.POLLING
    assert plan.n_channels == 1


def test_rx_drift_alone_triggers_replan():
    """Serving decode is RX-dominated: an RX-only slowdown must trigger a
    replan even when the TX window shows no drift at all."""
    ctl, model = _controller(hysteresis=1.5)
    rx_healthy = TransferCostModel(t0_s=120e-6, bw_Bps=2e9)
    # steady TX + healthy RX: propose adopts the RX baseline, no replan
    for _ in range(3):
        _feed(ctl, model)
        for n in SIZES:
            ctl.add_chunk_sample("rx", "interrupt", n,
                                 rx_healthy.time_unique(n))
        ctl.propose()
    assert ctl.replans == 0
    # RX t0 inflates 10x while TX stays put
    rx_drifted = TransferCostModel(t0_s=1.2e-3, bw_Bps=1e9)
    for _ in range(20):
        _feed(ctl, model, repeats=1)
        for n in SIZES:
            ctl.add_chunk_sample("rx", "interrupt", n,
                                 rx_drifted.time_unique(n))
        ctl.propose()
    assert ctl.replans >= 1
    # the adopted plan is sized for the SLOWER direction (RX's bigger t0)
    assert ctl.plan.model.t0_s > model.t0_s * 2


def test_flip_back_to_interrupt_uses_interrupt_fit():
    """Crossing POLLING -> INTERRUPT must size blocks from the INTERRUPT
    mode's fit (its large t0), not the polling fit's tiny one."""
    ctl, _ = _controller(hysteresis=1.1)
    poll = TransferCostModel(t0_s=2e-6, bw_Bps=2e9)
    intr = TransferCostModel(t0_s=800e-6, bw_Bps=3e9)
    # start from a POLLING plan
    from repro.core.channels import ChannelPlan
    ctl.plan = ChannelPlan(n_channels=1,
                           policy=TransferPolicy.user_level_polling(),
                           model=poll, payload_bytes=16 << 10)
    ctl._tx_ref = poll
    _feed(ctl, poll, mode="polling")
    _feed(ctl, intr, mode="interrupt")
    ctl._payloads.clear()
    ctl._payloads.append(64 << 20)  # payload far ABOVE the crossover
    plan = ctl.propose(force=True)
    assert plan is not None
    assert plan.policy.management is Management.INTERRUPT
    # block size must reflect interrupt's ~800us t0 (t0*BW ~ 2.4 MB), not
    # polling's 2us (t0*BW ~ 4 KB)
    assert plan.policy.block_bytes >= (1 << 20)


# ---- adaptive group: swaps at safe points ---------------------------------

def _drifted_group(**cfg_kw):
    cfg_kw.setdefault("min_samples", 8)
    cfg_kw.setdefault("refit_every", 1)
    g = AdaptiveChannelGroup(
        8 << 20, model=TransferCostModel(t0_s=100e-6, bw_Bps=2e9),
        cfg=AdaptiveConfig(**cfg_kw))
    return g


def test_group_swaps_generation_on_forced_drift():
    g = _drifted_group()
    x = np.random.default_rng(0).standard_normal(1 << 18).astype(np.float32)
    np.testing.assert_array_equal(np.asarray(reassemble_chunks(g.tx(x))), x)
    layouts_before = g.layouts
    # inject a 10x-t0 regime and force the safe-point swap
    drifted = TransferCostModel(t0_s=4e-3, bw_Bps=1e9)
    _feed(g.controller, drifted, repeats=16)
    assert g.maybe_adapt(force=True) is True
    assert g.generation == 1 and g.swaps == 1
    # the new generation still transfers correctly and KEPT the layout
    # cache (a replan must not re-pay the one-time staging layout cost)
    assert g.layouts is layouts_before
    np.testing.assert_array_equal(np.asarray(reassemble_chunks(g.tx(x))), x)
    back = g.rx(g.tx(x))
    np.testing.assert_array_equal(
        np.concatenate([np.asarray(b).reshape(-1) for b in back]), x)
    g.close()


def test_group_defers_swap_while_ring_in_flight():
    """A pending plan must NOT be applied while a ticket is outstanding —
    the ring-drained safe-point rule."""
    g = _drifted_group()
    x = np.zeros(1 << 22, np.float32)  # large enough to stay in flight
    ticket = g.tx_async(x)
    _feed(g.controller, TransferCostModel(t0_s=4e-3, bw_Bps=1e9), repeats=16)
    plan = g.controller.propose(force=True)
    assert plan is not None
    with g._lock:
        g._pending_plan = plan
    if not ticket.complete:
        # in-flight: adapt must hold the old generation
        swapped_early = g.maybe_adapt()
        if not ticket.complete:
            assert not swapped_early and g.generation == 0
    ticket.wait()
    if g.generation == 0:
        # (the transfer may legally have completed DURING the first
        # maybe_adapt above, in which case the swap already applied —
        # only demand a swap here if it hasn't happened yet)
        assert g.maybe_adapt() is True  # drained now: swap applies
    assert g.generation == 1
    g.close()


def test_group_runs_streaming_executor():
    from repro.core.streaming import HostStreamingExecutor
    import jax
    import jax.numpy as jnp

    def apply_fn(params, x):
        (w,) = params
        return jnp.tanh(x @ w)

    jitted = jax.jit(apply_fn)
    rng = np.random.default_rng(3)
    layers = [(f"l{i}", [rng.standard_normal((32, 32)).astype(np.float32)],
               jitted) for i in range(4)]
    x = rng.standard_normal((2, 32)).astype(np.float32)
    g = _drifted_group()
    out, timing = HostStreamingExecutor(g).run(layers, x)
    y = jnp.asarray(x)
    for _, (w,), fn in layers:
        y = fn([jnp.asarray(w)], y)
    np.testing.assert_allclose(out, np.asarray(y), rtol=1e-5, atol=1e-5)
    assert len(timing.layers) == 4
    g.close()


# ---- warm-start persistence ------------------------------------------------

def test_warm_start_state_roundtrip(tmp_path):
    """save() -> load() must reproduce the plan, the drift references, and
    the seeded fit windows — the next session starts from this one's
    steady state instead of re-calibrating."""
    path = tmp_path / "transfer_state.json"
    ctl, model = _controller()
    _feed(ctl, TransferCostModel(t0_s=300e-6, bw_Bps=1.5e9), repeats=10)
    ctl.add_chunk_sample("rx", "interrupt", 1 << 20, 1e-3)
    ctl.propose(force=True)  # adopt the fitted state
    ctl.save(path)

    ctl2 = OnlineTransferController.load(path)
    assert ctl2.plan.policy == ctl.plan.policy
    assert ctl2.plan.n_channels == ctl.plan.n_channels
    assert abs(ctl2._tx_ref.t0_s - ctl._tx_ref.t0_s) < 1e-12
    # seeded windows: the loaded controller can fit IMMEDIATELY (no fresh
    # traffic, no calibration sweep)
    m = ctl2._fit_for("tx", "interrupt").fit(4)
    assert m is not None
    m_src = ctl._fit_for("tx", "interrupt").fit(4)
    assert abs(m.t0_s - m_src.t0_s) / m_src.t0_s < 0.05
    assert abs(m.bw_Bps - m_src.bw_Bps) / m_src.bw_Bps < 0.05


def test_rolling_fit_state_roundtrip():
    m_true = TransferCostModel(t0_s=120e-6, bw_Bps=2e9)
    fit = RollingFit(window=64)
    _feed(fit, m_true, repeats=4)
    clone = RollingFit.from_state(fit.to_state(), window=64)
    assert len(clone) == len(fit)
    m = clone.fit(4)
    assert abs(m.t0_s - m_true.t0_s) / m_true.t0_s < 0.05


def test_adaptive_group_warm_starts_from_state_file(tmp_path):
    """An AdaptiveChannelGroup with a state_path persists on close and the
    NEXT group skips calibration, seeding its first plan from the file."""
    path = tmp_path / "state.json"
    model = TransferCostModel(t0_s=100e-6, bw_Bps=2e9)
    g1 = AdaptiveChannelGroup(8 << 20, model=model, state_path=path)
    assert not g1.warm_started
    plan1 = g1.controller.plan
    g1.close()
    assert path.exists()

    g2 = AdaptiveChannelGroup(8 << 20, state_path=path)  # no model: would
    assert g2.warm_started                               # calibrate cold
    assert g2.plan.policy == plan1.policy
    assert g2.plan.n_channels == plan1.n_channels
    # and it still transfers
    x = np.arange(1 << 16, dtype=np.float32)
    np.testing.assert_array_equal(np.asarray(reassemble_chunks(g2.tx(x))), x)
    g2.close()


# ---- runtime dispatch latency feeds the crossover ---------------------------

def test_dispatch_latency_moves_crossover_to_polling():
    """The shared runtime's measured queue wait is a real cost of the
    interrupt driver that polling never pays: folding it into the
    crossover must flip a near-threshold payload back to POLLING."""
    poll = TransferCostModel(t0_s=2e-6, bw_Bps=2e9)
    intr = TransferCostModel(t0_s=30e-6, bw_Bps=3e9)
    fits = {"polling": poll, "interrupt": intr}
    n_star = TransferCostModel.crossover_bytes(poll, intr)
    payload = int(n_star * 2)  # above the uncontended crossover
    assert choose_management(fits, payload) is Management.INTERRUPT
    # under contention the interrupt path queues ~500us per descriptor
    assert choose_management(
        fits, payload, interrupt_extra_t0_s=500e-6) is Management.POLLING


def test_controller_crossover_uses_noted_dispatch_latency():
    ctl, _ = _controller(hysteresis=1.1)
    poll = TransferCostModel(t0_s=2e-6, bw_Bps=2e9)
    intr = TransferCostModel(t0_s=30e-6, bw_Bps=3e9)
    small = (1 << 10, 4 << 10, 16 << 10, 64 << 10, 256 << 10)
    for _ in range(8):
        for n in small:
            ctl.add_chunk_sample("tx", "polling", n, poll.time_unique(n))
            ctl.add_chunk_sample("tx", "interrupt", n, intr.time_unique(n))
    n_star = TransferCostModel.crossover_bytes(poll, intr)
    ctl._payloads.clear()
    ctl._payloads.append(int(n_star * 2))
    plan = ctl.propose(force=True)
    assert plan is not None
    assert plan.policy.management is Management.INTERRUPT
    # heavy serving contention: queue wait dwarfs the service-time fits
    for _ in range(32):
        ctl.note_dispatch_latency(2e-3)
    plan = ctl.propose(force=True)
    assert plan is not None
    assert plan.policy.management is Management.POLLING


def test_adaptive_group_ingests_runtime_dispatch_latency():
    """maybe_adapt() must pull the runtime's per-class dispatch latency
    into the controller (real serving traces drive the crossover)."""
    from repro.core.runtime import TransferRuntime

    with TransferRuntime(workers=1) as rt:
        g = AdaptiveChannelGroup(
            8 << 20, model=TransferCostModel(t0_s=100e-6, bw_Bps=2e9),
            runtime=rt, cfg=AdaptiveConfig(min_samples=8, refit_every=1))
        x = np.arange(1 << 16, dtype=np.float32)
        for _ in range(3):
            g.tx(x)
        g.maybe_adapt()
        assert g.controller._dispatch_t0_s > 0.0
        g.close()


# ---- zero-copy RX ----------------------------------------------------------

def test_rx_out_identity_and_zero_alloc_steady_state():
    """Steady-state rx(out=) must return the CALLER's buffer object every
    call and perform no per-call host DATA allocation (tracemalloc must
    not see the megabyte-scale payload being re-allocated)."""
    import tracemalloc

    eng = TransferEngine(TransferPolicy.user_level_polling())
    nbytes = 1 << 20
    dev = eng.tx(np.arange(nbytes // 4, dtype=np.int32))
    assert len(dev) == 1
    buf = np.empty(nbytes // 4, np.int32)
    eng.rx(dev, out=[buf])  # warm the path
    tracemalloc.start()
    for _ in range(5):
        res = eng.rx(dev, out=[buf])
        assert res[0] is buf  # identity: landed in place
    _, peak = tracemalloc.get_traced_memory()
    tracemalloc.stop()
    # bookkeeping objects only — never a fresh payload-sized buffer
    assert peak < nbytes // 2, f"steady-state RX allocated {peak} bytes"
    np.testing.assert_array_equal(buf, np.arange(nbytes // 4, dtype=np.int32))
    eng.close()


def test_rx_out_validation():
    eng = TransferEngine(TransferPolicy.kernel_level())
    dev = eng.tx(np.zeros(64, np.float32))
    with pytest.raises(ValueError):
        eng.rx(dev, out=[np.empty(63, np.float32)])  # size mismatch
    with pytest.raises(ValueError):
        eng.rx(dev, out=[])  # count mismatch
    ro = np.empty(64, np.float32)
    ro.flags.writeable = False
    with pytest.raises(ValueError):
        eng.rx(dev, out=[ro])
    # non-contiguous buffer: reshape(-1) would copy and the transfer would
    # silently land in a temporary — must be rejected up front
    col = np.empty((64, 2), np.float32)[:, 0]
    with pytest.raises(ValueError):
        eng.rx(dev, out=[col])
    eng.close()


def test_group_rx_out_flat_array_ordered_reassembly():
    """ChannelGroup.rx(out=<one flat array>) must write each striped chunk
    at its final offset in the caller's array."""
    from repro.core.channels import ChannelGroup

    g = ChannelGroup(TransferPolicy.kernel_level_ring(4, block_bytes=1 << 16),
                     n_channels=2, min_stripe_bytes=1 << 14)
    x = np.random.default_rng(1).standard_normal(200_003).astype(np.float32)
    chunks = g.tx(x)
    out = np.empty_like(x)
    res = g.rx(chunks, out=out)
    np.testing.assert_array_equal(out, x)
    assert all(np.shares_memory(out, r) for r in res)
    # a wrong-length per-array out list must fail fast and clearly, BEFORE
    # any channel wrote into caller memory
    assert len(chunks) > 1
    with pytest.raises(ValueError):
        g.rx(chunks, out=[np.empty_like(x)])
    g.close()


# ---- the fix: exact byte accounting under concurrent async traffic ---------

def test_async_byte_totals_exact_from_8_threads():
    """Counters updated on the async completion path must be lock-protected:
    8 threads of tx_async/rx_async, byte totals must match EXACTLY."""
    eng = TransferEngine(TransferPolicy.kernel_level_ring(4,
                                                          block_bytes=1 << 14))
    n_threads, iters, n_elems = 8, 6, 16 * 1024
    per_tx = n_elems * 4
    errors = []

    def worker(seed):
        try:
            x = np.full(n_elems, float(seed), np.float32)
            for _ in range(iters):
                chunks = eng.tx_async(x).wait()
                eng.rx_async(chunks).wait()
        except BaseException as e:  # noqa: BLE001
            errors.append(e)

    threads = [threading.Thread(target=worker, args=(i,))
               for i in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors, errors
    expected = n_threads * iters * per_tx
    assert eng.tx_bytes_total == expected
    assert eng.rx_bytes_total == expected
    assert eng.tx_count == n_threads * iters
    assert eng.rx_count == n_threads * iters
    assert sum(s.nbytes for s in eng.stats if s.direction == "tx") == expected
    assert sum(s.nbytes for s in eng.stats if s.direction == "rx") == expected
    eng.close()


# ---- stress: hammer engine + group through a mid-run plan swap -------------

@pytest.mark.stress
def test_stress_mid_run_plan_swap():
    """8 threads hammer one TransferEngine and one AdaptiveChannelGroup;
    between two traffic phases the group swaps its plan generation. No
    ring-safety bypass, no slot collisions, no lost completions."""
    eng = TransferEngine(TransferPolicy.kernel_level_ring(3,
                                                          block_bytes=1 << 14))
    group = _drifted_group(min_samples=8, refit_every=1)
    n_threads, iters, n_elems = 8, 4, 16 * 1024
    per_tx = n_elems * 4
    barrier = threading.Barrier(n_threads + 1)
    errors = []

    def hammer(seed):
        try:
            x = np.full(n_elems, float(seed), np.float32)
            for phase in range(2):
                barrier.wait(timeout=30)        # wait#1 / wait#2
                if phase == 1:
                    barrier.wait(timeout=30)    # wait#3: main swapped
                for _ in range(iters):
                    dev = eng.tx_async(x).wait()
                    host = eng.rx_async(dev).wait()
                    flat = np.concatenate([np.asarray(h).reshape(-1)
                                           for h in host])
                    np.testing.assert_array_equal(flat, x)
                    chunks = group.tx(x)
                    out = np.empty_like(x)
                    group.rx(chunks, out=out)
                    np.testing.assert_array_equal(out, x)
        except BaseException as e:  # noqa: BLE001
            errors.append(e)

    threads = [threading.Thread(target=hammer, args=(i,))
               for i in range(n_threads)]
    for t in threads:
        t.start()
    barrier.wait(timeout=30)  # wait#1: phase 0 traffic starts
    barrier.wait(timeout=30)  # wait#2: every thread finished phase 0
    # mid-run swap: threads are parked at wait#3, the ring is drained —
    # force the replan, then release phase 1 onto the NEW generation.
    _feed(group.controller, TransferCostModel(t0_s=4e-3, bw_Bps=1e9),
          repeats=16)
    swapped = group.maybe_adapt(force=True)
    barrier.wait(timeout=30)  # wait#3: phase 1 traffic starts
    for t in threads:
        t.join()
    assert not errors, errors
    assert swapped and group.swaps >= 1  # the mid-run swap happened

    # ring-safety invariants across EVERY generation's engines + the engine
    for e in [eng] + group.all_engines:
        assert e.slot_collisions == 0
        assert e.inflight_hwm <= e.policy.depth

    # no lost completions: every logical transfer recorded, bytes exact
    expected = n_threads * 2 * iters * per_tx
    assert eng.tx_bytes_total == expected
    assert eng.rx_bytes_total == expected
    # group TX also carries the controller's probe transfers — distinct
    # sizes, so filter to the hammer payload size and demand exactness
    g_tx = sum(s.nbytes for s in group.stats
               if s.direction == "tx" and s.nbytes == per_tx)
    g_rx = sum(s.nbytes for s in group.stats if s.direction == "rx")
    assert g_tx == expected
    assert g_rx == expected
    eng.close()
    group.close()


# ---- batched-submission amortization (tx_many/rx_many -> the fit) ----------

def test_amortized_cost_model_divides_only_t0():
    m = TransferCostModel(t0_s=100e-6, bw_Bps=5e9)
    a = m.amortized(8)
    assert a.t0_s == pytest.approx(m.t0_s / 8)
    assert a.bw_Bps == m.bw_Bps
    # a degenerate batch never INCREASES the overhead
    assert m.amortized(0.5).t0_s == m.t0_s


def test_batched_proportional_samples_fit_lower_t0():
    """Batched submission charges each descriptor a size-proportional
    share of ONE fused wall time; the rolling fit must recover the
    amortized t0 (t0/K), not the per-call overhead singles pay."""
    t0, bw, batch = 120e-6, 8e9, 32
    sizes = [1 << 10, 1 << 12, 1 << 14, 1 << 16]
    singles, batched = RollingFit(window=256), RollingFit(window=256)
    for _ in range(8):
        for n in sizes:
            singles.add(n, t0 + n / bw)
            batched.add(n, t0 / batch + n / bw)
    fs, fb = singles.fit(), batched.fit()
    assert fs is not None and fb is not None
    assert fs.t0_s == pytest.approx(t0, rel=0.05)
    assert fb.t0_s == pytest.approx(t0 / batch, rel=0.3)
    assert fb.t0_s < fs.t0_s / 8
    # bandwidth is NOT an amortization artifact: both fits agree on it
    assert fb.bw_Bps == pytest.approx(fs.bw_Bps, rel=0.05)


def test_batch_moves_crossover_back_to_interrupt():
    """Contention queue-wait pushes the crossover right (polling wins);
    a batched stream pays that wait once per GROUP, pulling it back left
    — the same payload flips back to the interrupt driver."""
    poll = TransferCostModel(t0_s=2e-6, bw_Bps=2e9)
    intr = TransferCostModel(t0_s=30e-6, bw_Bps=3e9)
    fits = {"polling": poll, "interrupt": intr}
    payload = int(TransferCostModel.crossover_bytes(poll, intr) * 2)
    extra = 500e-6  # measured per-descriptor dispatch wait under load
    assert choose_management(
        fits, payload, interrupt_extra_t0_s=extra) is Management.POLLING
    assert choose_management(
        fits, payload, interrupt_extra_t0_s=extra,
        batch=32.0) is Management.INTERRUPT


def test_controller_tracks_submit_batch_ewma():
    ctl, _ = _controller()
    assert ctl._batch_ewma == 1.0
    for _ in range(64):
        ctl.note_submit_batch(32)
    assert ctl._batch_ewma > 24.0  # EWMA converged toward the group size
    ctl.note_submit_batch(0)  # degenerate groups are ignored
    assert ctl._batch_ewma > 24.0


def test_engine_batched_samples_amortize_measured_t0():
    """End to end on the real engine: the chunk samples a tx_many batch
    records fit a materially lower t0 than one-submit-per-descriptor
    samples of the SAME payloads — the management-overhead amortization
    the serving layer feeds back into its crossover."""
    sizes = [1 << 10, 1 << 12, 1 << 14, 1 << 16]
    arrays = [np.zeros(n, np.uint8) for n in sizes] * 8  # 32 descriptors

    singles = TransferEngine(TransferPolicy.kernel_level_ring(4))
    batched = TransferEngine(TransferPolicy.kernel_level_ring(4))
    try:
        for a in arrays:
            singles.tx_async(a).wait(30.0)
        for t in batched.tx_many(arrays):
            t.wait(30.0)
        def fit(eng):
            ns = np.array([n for d, _m, n, _t in eng.chunk_samples
                           if d == "tx"], np.float64)
            ts = np.array([t for d, _m, _n, t in eng.chunk_samples
                           if d == "tx"], np.float64)
            assert len(ns) == len(arrays)
            return TransferCostModel.fit(ns, ts)
        t0_single = fit(singles).t0_s
        t0_batched = fit(batched).t0_s
        assert t0_batched < t0_single / 2, (t0_single, t0_batched)
    finally:
        singles.close()
        batched.close()
