"""Serving example: continuous batching over heterogeneous requests.

    PYTHONPATH=src python examples/serve_lm.py
"""

import time

import jax
import numpy as np

from repro.configs.registry import smoke_config
from repro.models.api import build_model
from repro.serve.continuous import ContinuousBatchingEngine, Request


def main():
    cfg = smoke_config("qwen2.5-3b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = ContinuousBatchingEngine(model, params, n_slots=4, max_seq=96)

    rng = np.random.default_rng(0)
    n_requests = 10
    for i in range(n_requests):
        eng.submit(Request(
            rid=i,
            prompt=rng.integers(0, cfg.vocab, rng.integers(6, 24)).astype(
                np.int32),
            max_new_tokens=int(rng.integers(4, 12))))

    t0 = time.perf_counter()
    done = eng.run_to_completion()
    dt = time.perf_counter() - t0
    total_tokens = sum(len(r.tokens) for r in done)
    print(f"served {len(done)} requests / {total_tokens} tokens in "
          f"{dt:.2f}s over {eng.steps} batched decode steps "
          f"({total_tokens / max(eng.steps, 1):.2f} tokens/step — slot "
          f"refill keeps the batch full)")
    for r in sorted(done, key=lambda r: r.rid)[:4]:
        print(f"  req{r.rid}: prompt_len={len(r.prompt)} -> {r.tokens}")


if __name__ == "__main__":
    main()
