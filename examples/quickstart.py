"""Quickstart: build an assigned architecture, train a few steps, serve it.

    PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.configs.registry import smoke_config
from repro.core.transfer import TransferPolicy
from repro.data.pipeline import DataConfig, StagedPipeline, SyntheticLMSource
from repro.models.api import build_model
from repro.optim import AdamWConfig
from repro.serve.engine import ServeConfig, ServingEngine
from repro.train.loop import TrainConfig, Trainer


def main():
    # 1. pick an architecture (reduced config; full ones need a pod)
    cfg = smoke_config("qwen2.5-3b")
    model = build_model(cfg)

    # 2. train briefly with the kernel-level (interrupt) staging policy
    tcfg = TrainConfig(steps=20, n_microbatches=2, warmup=2,
                       opt=AdamWConfig(lr=1e-3), log_every=5)
    source = SyntheticLMSource(DataConfig(global_batch=8, seq_len=64), cfg)
    pipe = StagedPipeline(source, TransferPolicy.kernel_level())
    trainer = Trainer(model, tcfg)
    out = trainer.run(pipe)
    pipe.close()
    print("loss:", [round(r["loss"], 3) for r in trainer.history])

    # 3. serve the trained params
    eng = ServingEngine(model, out["params"], ServeConfig(max_seq=128))
    res = eng.generate(np.ones((2, 16), np.int32), max_new_tokens=16)
    print("generated:", res[0].tokens.tolist())
    print(f"decode tok/s: {res[0].tokens_per_s:.1f}")


if __name__ == "__main__":
    main()
