"""The paper's experiment, end to end: compare the three driver modes on a
streamed per-layer CNN execution (NullHop + RoShamBo) and print a Table-I
style summary — then demo the SAME three modes as backends of the unified
TransferRuntime submit contract, with concurrent SENSOR-class frame
collection and the runtime's per-class QoS ledger.

    PYTHONPATH=src python examples/transfer_modes.py
"""

import threading
import time

import jax
import numpy as np

from repro.accel.nullhop import NullHopExecutor
from repro.accel.roshambo import RoShamBoCNN
from repro.core import (  # the curated facade — import surface types here
    Buffering,
    Management,
    Partitioning,
    PriorityClass,
    QosSpec,
    TransferEngine,
    TransferPolicy,
    TransferRuntime,
    backend_for,
)
from repro.core.transfer import Ticket

POLICIES = [
    ("user-level polling", TransferPolicy.user_level_polling()),
    ("user-level drv scheduled", TransferPolicy.user_level_scheduled()),
    ("kernel-level drv", TransferPolicy.kernel_level()),
    ("kernel drv + double/blocks", TransferPolicy(
        Management.INTERRUPT, Buffering.DOUBLE, Partitioning.BLOCKS,
        block_bytes=1 << 16)),
]


def main():
    cnn = RoShamBoCNN()
    params = cnn.init(jax.random.PRNGKey(0))
    frame = np.random.default_rng(0).standard_normal(
        (1, 64, 64, 1)).astype(np.float32)

    print(f"{'mode':28s} {'TX us/B':>9s} {'RX us/B':>9s} {'frame ms':>9s}")
    for name, policy in POLICIES:
        ex = NullHopExecutor(cnn, policy)
        ex.run_frame(params, frame)  # warmup (jit)
        best = None
        for _ in range(3):
            res = ex.run_frame(params, frame)
            if best is None or res.timing.frame_s < best.timing.frame_s:
                best = res
        t = best.timing
        print(f"{name:28s} {t.tx_us_per_byte:9.4f} {t.rx_us_per_byte:9.4f} "
              f"{t.frame_s * 1e3:9.2f}")
    print("\nper-layer output sparsity (NullHop skips zeros):",
          [round(s, 2) for s in best.sparsity])
    demo_unified_runtime()
    demo_coalescing()
    demo_fault_injection()


def demo_unified_runtime():
    """The paper's three managements as three backends of ONE submit
    contract: ``submit(fn) -> (done, out)``, wrapped by the same Ticket."""
    print("\n== unified runtime: one submit contract, three backends ==")
    x = np.random.default_rng(0).standard_normal(1 << 18).astype(np.float32)
    with TransferRuntime(workers=2) as rt:
        for mode in ("polling", "scheduled", "interrupt"):
            backend = backend_for(mode, runtime=rt,
                                  priority=PriorityClass.LAYER)
            t0 = time.perf_counter()
            done, out = backend.submit(
                lambda: jax.device_put(x).block_until_ready(), nbytes=x.nbytes)
            if hasattr(backend, "drain"):  # scheduled: runs on the caller
                backend.drain()
            Ticket(done, out).wait()
            print(f"  {mode:10s} submit->complete "
                  f"{(time.perf_counter() - t0) * 1e3:7.2f} ms")

        # QoS arbitration: TOKEN-class RX rides ahead of bulk LAYER TX
        # while a SENSOR-class background task keeps collecting "events"
        events = {"n": 0}
        unregister = rt.register_background(
            lambda: events.__setitem__("n", events["n"] + 1))
        bulk_eng = TransferEngine(TransferPolicy.kernel_level_ring(4),
                                  runtime=rt, priority=PriorityClass.LAYER)
        tok_eng = TransferEngine(TransferPolicy.kernel_level(),
                                 runtime=rt, priority=PriorityClass.TOKEN)
        tok_dev = tok_eng.tx(np.arange(8, dtype=np.int32))
        tok_out = np.empty(8, np.int32)
        stop = threading.Event()

        def flood():
            while not stop.is_set():
                bulk_eng.tx_async(x).wait()

        t = threading.Thread(target=flood, daemon=True)
        t.start()
        # the QosSpec submit context: class + tenant on one object (the
        # deprecated spelling was priority=PriorityClass.TOKEN)
        tok_qos = QosSpec(priority=PriorityClass.TOKEN, tenant="demo")
        lats = []
        for _ in range(50):
            t0 = time.perf_counter()
            tok_eng.rx_async(tok_dev, out=[tok_out], qos=tok_qos).wait()
            lats.append(time.perf_counter() - t0)
            time.sleep(0.002)
        stop.set()
        t.join(timeout=10)
        unregister()
        lats.sort()
        print(f"  token RX under bulk flood: p50 {lats[len(lats)//2]*1e3:.2f} "
              f"ms, max {lats[-1]*1e3:.2f} ms; sensor slices {events['n']}")
        print("  per-class ledger:")
        summary = rt.class_summary()
        for cls, row in summary.items():
            print(f"    {cls:7s} n={row['completed']:<5d} "
                  f"bytes={row['bytes_total']:<12d} "
                  f"dispatch p99 {row['dispatch_p99_ms']:.3f} ms")
        demo_row = summary["token"]["tenants"].get("demo")
        if demo_row:
            print(f"    token tenant 'demo': n={demo_row['completed']} "
                  f"bytes={demo_row['bytes_total']} dispatch p99 "
                  f"{demo_row['dispatch_p99_ms']:.3f} ms")
        bulk_eng.close()
        tok_eng.close()


def demo_coalescing():
    """Batched descriptor submission + completion coalescing: 32 token-
    sized RX descriptors as singles vs ONE rx_many ring transaction, and
    the per-class wakeup ledger a BULK burst leaves behind (see
    docs/coalescing.md)."""
    print("\n== coalescing: batched submission + completion vectors ==")
    n, elems = 32, 1024  # 32 descriptors x 4 KiB
    with TransferRuntime(workers=2) as rt:
        eng = TransferEngine(TransferPolicy.kernel_level_ring(8),
                             runtime=rt, priority=PriorityClass.TOKEN)
        arrays = [np.arange(elems, dtype=np.int32) + i for i in range(n)]
        devs = [t.wait() for t in eng.tx_many(arrays)]
        outs = [np.empty(elems, np.int32) for _ in range(n)]
        eng.rx_many(devs[:2], out=outs[:2])[1].wait()  # warm the RX path

        t0 = time.perf_counter()
        for d, o in zip(devs, outs):
            eng.rx_async([d], out=[o]).wait()
        singles_s = time.perf_counter() - t0

        t0 = time.perf_counter()
        for t in eng.rx_many(devs, out=outs):
            t.wait()
        batched_s = time.perf_counter() - t0
        print(f"  32 x 4 KiB token RX: singles "
              f"{singles_s / n * 1e6:6.1f} us/desc, one rx_many batch "
              f"{batched_s / n * 1e6:6.1f} us/desc "
              f"({singles_s / max(batched_s, 1e-9):.1f}x)")

        # completion vectors: a burst of BULK completions -> few wakeups
        h = rt.register("burst", PriorityClass.BULK)
        pairs = [h.submit(lambda: 1, nbytes=4096) for _ in range(64)]
        for ev, _out in pairs:
            ev.wait()
        row = rt.class_summary()["bulk"]
        print(f"  64 BULK completions -> {row['completion_wakeups']} "
              f"wakeups ({row['wakeups_saved']} saved, batch p50 "
              f"{row['coalesce_batch_p50']:.0f}, added delay p99 "
              f"{row['coalesce_delay_p99_ms']:.2f} ms)")
        h.close()
        eng.close()


def demo_fault_injection():
    """Self-healing under injected faults: a striped ChannelGroup retries
    dropped descriptors on sibling channels, quarantines a channel that
    keeps failing, and keeps every byte accounted for — all driven by the
    deterministic, seeded :class:`~repro.core.faults.FaultInjector`."""
    from repro.core.channels import ChannelGroup
    from repro.core.faults import FaultInjector, FaultPlan, FaultSpec, \
        RecoveryConfig

    print("\n== fault injection: retry on sibling, quarantine, heal ==")
    # channel 0 drops its first two descriptors, then behaves; two
    # consecutive faults trip the quarantine threshold
    inj = FaultInjector(FaultPlan(seed=7, specs=(
        FaultSpec(kind="drop", channel=0, max_injections=2),)))
    g = ChannelGroup(
        # 2 MiB blocks: each ~1.3 MiB stripe is ONE descriptor, so the two
        # scheduled drops land on two separate transfers (two consecutive
        # stripe-level faults), not inside one stripe's chunk chain
        TransferPolicy.kernel_level_ring(4, block_bytes=1 << 21),
        n_channels=3,
        engine_factory=inj.engine_factory(),
        recovery=RecoveryConfig(quarantine_after=2, max_retries=2,
                                drift_quarantine_ratio=None,
                                probe_interval_s=0.0))
    # 4 MiB: comfortably above 2x the minimum stripe size, so the payload
    # stripes across all three channels (sub-stripe traffic takes the
    # single-channel delegated path, which has no sibling to retry on)
    x = np.random.default_rng(1).standard_normal(1 << 20).astype(np.float32)
    for i in range(3):
        g.tx(x)  # faulted stripes transparently retry on a sibling
    print(f"  after 3 striped TX: quarantined={sorted(g.quarantined)} "
          f"(channel 0 pulled after 2 consecutive drops)")
    g.check_channel_health()  # probe succeeds -> channel 0 rejoins
    print(f"  after probe:        quarantined={sorted(g.quarantined)}")
    ledger = g.fault_state.summary()
    print("  fault ledger:", {k: ledger[k] for k in (
        "faults", "retries", "retry_successes", "quarantines",
        "unquarantines")})
    print("  injected events:", [(c, op, kind) for c, op, kind, *_ in
                                 inj.events])
    g.close()


if __name__ == "__main__":
    main()
