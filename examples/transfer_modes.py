"""The paper's experiment, end to end: compare the three driver modes on a
streamed per-layer CNN execution (NullHop + RoShamBo) and print a Table-I
style summary.

    PYTHONPATH=src python examples/transfer_modes.py
"""

import jax
import numpy as np

from repro.accel.nullhop import NullHopExecutor
from repro.accel.roshambo import RoShamBoCNN
from repro.core.transfer import (
    Buffering,
    Management,
    Partitioning,
    TransferPolicy,
)

POLICIES = [
    ("user-level polling", TransferPolicy.user_level_polling()),
    ("user-level drv scheduled", TransferPolicy.user_level_scheduled()),
    ("kernel-level drv", TransferPolicy.kernel_level()),
    ("kernel drv + double/blocks", TransferPolicy(
        Management.INTERRUPT, Buffering.DOUBLE, Partitioning.BLOCKS,
        block_bytes=1 << 16)),
]


def main():
    cnn = RoShamBoCNN()
    params = cnn.init(jax.random.PRNGKey(0))
    frame = np.random.default_rng(0).standard_normal(
        (1, 64, 64, 1)).astype(np.float32)

    print(f"{'mode':28s} {'TX us/B':>9s} {'RX us/B':>9s} {'frame ms':>9s}")
    for name, policy in POLICIES:
        ex = NullHopExecutor(cnn, policy)
        ex.run_frame(params, frame)  # warmup (jit)
        best = None
        for _ in range(3):
            res = ex.run_frame(params, frame)
            if best is None or res.timing.frame_s < best.timing.frame_s:
                best = res
        t = best.timing
        print(f"{name:28s} {t.tx_us_per_byte:9.4f} {t.rx_us_per_byte:9.4f} "
              f"{t.frame_s * 1e3:9.2f}")
    print("\nper-layer output sparsity (NullHop skips zeros):",
          [round(s, 2) for s in best.sparsity])


if __name__ == "__main__":
    main()
