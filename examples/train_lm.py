"""End-to-end driver: train a ~100M-param dense LM for a few hundred steps
with checkpointing, restart, and policy-driven data staging.

    PYTHONPATH=src python examples/train_lm.py [--steps 300]
"""

import argparse
import shutil

import jax

from repro.core.transfer import TransferPolicy
from repro.data.pipeline import DataConfig, StagedPipeline, SyntheticLMSource
from repro.models.api import build_model
from repro.models.config import ModelConfig
from repro.optim import AdamWConfig
from repro.train.loop import TrainConfig, Trainer


def lm_100m() -> ModelConfig:
    """~100M params: 12L, d=768, llama-style."""
    return ModelConfig(
        name="lm-100m", family="dense", n_layers=12, d_model=768,
        vocab=32000, n_heads=12, n_kv_heads=4, d_ff=2048,
        mlp="gated_silu", norm="rms", dtype="float32", remat=False,
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    cfg = lm_100m()
    model = build_model(cfg)
    n_params = sum(x.size for x in jax.tree.leaves(
        jax.eval_shape(model.init, jax.ShapeDtypeStruct((2,), "uint32"))))
    print(f"{cfg.name}: {n_params/1e6:.1f}M params")

    ckpt_dir = "/tmp/repro_lm100m_ckpt"
    if not args.resume:
        shutil.rmtree(ckpt_dir, ignore_errors=True)
    tcfg = TrainConfig(steps=args.steps, n_microbatches=2,
                       warmup=20, log_every=20,
                       opt=AdamWConfig(lr=6e-4),
                       checkpoint_dir=ckpt_dir, checkpoint_every=100)
    source = SyntheticLMSource(
        DataConfig(global_batch=args.batch, seq_len=args.seq), cfg)
    pipe = StagedPipeline(source, TransferPolicy.kernel_level())
    trainer = Trainer(model, tcfg)
    out = trainer.run(pipe)
    pipe.close()
    first, last = trainer.history[0], trainer.history[-1]
    print(f"loss {first['loss']:.3f} -> {last['loss']:.3f} over "
          f"{args.steps} steps; mean step {last['dt_s']*1e3:.0f}ms; "
          f"restarts={out['fault'].restarts}")
    assert last["loss"] < first["loss"], "loss must decrease"


if __name__ == "__main__":
    main()
