"""Fault-tolerance walkthrough: kill training mid-run, restart from the
latest checkpoint, then re-plan the mesh for a degraded device set.

    PYTHONPATH=src python examples/elastic_restart.py
"""

import shutil


from repro.configs.registry import smoke_config
from repro.core.transfer import TransferPolicy
from repro.data.pipeline import DataConfig, StagedPipeline, SyntheticLMSource
from repro.dist.elastic import reshard_plan, shrink_mesh
from repro.models.api import build_model
from repro.train.loop import TrainConfig, Trainer


def main():
    cfg = smoke_config("h2o-danube-1.8b")
    model = build_model(cfg)
    ckpt = "/tmp/repro_elastic_ckpt"
    shutil.rmtree(ckpt, ignore_errors=True)

    def make(steps):
        tcfg = TrainConfig(steps=steps, warmup=2, log_every=5,
                           checkpoint_dir=ckpt, checkpoint_every=5,
                           async_checkpoint=False)
        src = SyntheticLMSource(DataConfig(global_batch=4, seq_len=64), cfg)
        return Trainer(model, tcfg), StagedPipeline(
            src, TransferPolicy.kernel_level())

    # phase 1: run 10 steps (checkpoints at 5, 10), simulate a crash after
    t1, p1 = make(10)
    t1.run(p1)
    p1.close()
    print("phase 1 done (crash simulated after step 10)")

    # phase 2: a fresh Trainer resumes from step 10 automatically
    t2, p2 = make(20)
    out = t2.run(p2)
    p2.close()
    print(f"phase 2 resumed: restarts={out['fault'].restarts}, "
          f"steps logged from {t2.history[0]['step']}")
    assert out["fault"].restarts == 1
    assert t2.history[0]["step"] >= 10

    # phase 3: elastic re-plan — pretend a pod dropped: 512 -> 384 devices
    plan = shrink_mesh(384, model_parallel=16, multi_pod=True)
    print("degraded mesh plan:", plan)
    print(reshard_plan(256, shrink_mesh(512, model_parallel=16,
                                        multi_pod=True), plan))


if __name__ == "__main__":
    main()
