#!/usr/bin/env python
"""Bench regression gate: committed BENCH_transfer.json vs a fresh probe.

Two layers of checking, both with GENEROUS tolerances — this repo's
benchmarks run on noisy 2-core CI hosts (see the env notes in
``benchmarks/run.py`` and ``benchmarks/adaptive_drift.py``), where 2-3x
swings between runs are normal. The gate exists to catch *order-of-
magnitude* regressions (a perf path silently falling back to the seed
implementation, a QoS knob rotting into a no-op), not to re-certify the
committed numbers:

1. **structural** — the committed file must contain every section a full
   ``benchmarks/run.py`` writes, with the headline keys intact and the
   improvement ratios not *inverted* beyond noise (e.g. the staged ring
   must not have become slower than the seed pack).
2. **fresh probe** (skippable with ``--skip-fresh``) — two cheap live
   measurements compared against the committed numbers within a
   ``--tolerance``x factor (default 20x):
   - a staged-ring TX microbench vs the committed streaming_layers
     staged-ring us/byte;
   - a quick qos_contention run vs the committed arbitrated token-RX p99,
     plus sanity that preemptive chunking still actually preempts.

Exit 0 = pass; exit 1 = regression/missing data, with a reason per line.

Usage:
  PYTHONPATH=src python scripts/check_bench.py [--json BENCH_transfer.json]
      [--skip-fresh] [--tolerance 20]
"""

from __future__ import annotations

import argparse
import json
import pathlib
import sys
import time

REPO = pathlib.Path(__file__).resolve().parent.parent
DEFAULT_JSON = REPO / "BENCH_transfer.json"

# sections a full benchmarks/run.py writes, with their must-have keys
REQUIRED = {
    None: ["rows", "seed_pack_best", "staged_ring_best",
           "tx_us_per_byte_ratio_seed_over_ring",
           "frames_per_s_ratio_ring_over_seed"],
    "multichannel": ["rows", "single_ring_static", "multi_channel_best",
                     "tx_us_per_byte_ratio_single_ring_over_multi"],
    "adaptive_drift": ["rows", "recovery_ratio_static_over_online",
                       "final_plan"],
    "qos_contention": ["rows", "runtime_arbitrated_token_rx_p99_ms",
                       "p99_ratio_per_engine_over_runtime",
                       "p99_ratio_fifo_over_runtime",
                       "p99_ratio_hol_over_preempt",
                       "p99_ratio_reserved_lane_over_preempt",
                       "cap_bulk_share_uncapped", "cap_bulk_share_capped"],
    "fault_recovery": ["rows", "baseline_gbps", "faulted_gbps",
                       "recovered_gbps", "recovery_ratio", "degraded_ratio"],
    "coalescing": ["rows", "per_desc_us_b1", "per_desc_us_b8",
                   "per_desc_us_b32", "speedup_b8", "speedup_b32"],
    "staging_copy": ["rows", "pack_us_per_byte_few_large",
                     "sg_us_per_byte_few_large",
                     "pack_over_sg_us_per_byte_few_large",
                     "decision_few_large", "decision_many_small",
                     "crossover_segments"],
    "tenant_isolation": ["rows", "victim_p99_noflood_ms",
                         "victim_p99_flood_wfq_ms",
                         "victim_p99_flood_single_ms",
                         "isolation_ratio_wfq",
                         "isolation_ratio_single_tier",
                         "flood_cap_deferrals", "admission_sheds"],
}


def _structural(doc: dict, errors: list[str]) -> None:
    for section, keys in REQUIRED.items():
        sub = doc if section is None else doc.get(section)
        where = section or "streaming_layers (top level)"
        if not isinstance(sub, dict):
            errors.append(f"missing section: {where}")
            continue
        for key in keys:
            if key not in sub:
                errors.append(f"missing key: {where}.{key}")
    # improvement ratios must not be INVERTED past noise: a committed file
    # claiming the optimized path is >= 2x WORSE than its baseline means a
    # regression was committed, whatever produced it.
    ratio_floors = [
        ("tx_us_per_byte_ratio_seed_over_ring",
         doc.get("tx_us_per_byte_ratio_seed_over_ring"), 0.5),
        ("qos_contention.p99_ratio_per_engine_over_runtime",
         doc.get("qos_contention", {}).get(
             "p99_ratio_per_engine_over_runtime"), 0.5),
        ("qos_contention.p99_ratio_hol_over_preempt",
         doc.get("qos_contention", {}).get("p99_ratio_hol_over_preempt"),
         0.5),
        # the chaos lane's acceptance bar: quarantine+replan must keep
        # >= 80% of fault-free throughput with 1 of N channels stalled
        ("fault_recovery.recovery_ratio",
         doc.get("fault_recovery", {}).get("recovery_ratio"), 0.8),
        # batched-submission acceptance bar: rx_many at batch 32 must
        # amortize >= 2x of the per-descriptor overhead 32 singles pay
        # on 4 KiB token payloads (the coalescing tentpole's headline)
        ("coalescing.speedup_b32",
         doc.get("coalescing", {}).get("speedup_b32"), 2.0),
        # scatter-gather acceptance bar: killing the staging copy must keep
        # SG >= 1.5x lower TX us/B than the pack path on the few-large-
        # segments shape (the sg_vs_pack headline)
        ("staging_copy.pack_over_sg_us_per_byte_few_large",
         doc.get("staging_copy", {}).get(
             "pack_over_sg_us_per_byte_few_large"), 1.5),
    ]
    for name, val, floor in ratio_floors:
        if isinstance(val, (int, float)) and val < floor:
            errors.append(
                f"{name} = {val} < {floor}: the optimized path regressed "
                f"past its baseline in the committed file")
    # the pack-vs-SG crossover must land the right way on both acceptance
    # shapes: few large segments ride SG, many small arrays keep the pack
    # (a flipped decision means the cost-model pricing rotted)
    sc = doc.get("staging_copy", {})
    if "decision_few_large" in sc and sc["decision_few_large"] != "sg":
        errors.append(
            f"staging_copy.decision_few_large = {sc['decision_few_large']} "
            f"(expected 'sg'): the crossover no longer picks scatter-gather "
            f"for few large segments")
    if "decision_many_small" in sc and sc["decision_many_small"] != "pack":
        errors.append(
            f"staging_copy.decision_many_small = "
            f"{sc['decision_many_small']} (expected 'pack'): the crossover "
            f"no longer picks the staged pack for many small arrays")
    # tenant-isolation acceptance bar: with the second arbitration tier on,
    # a 1000-tenant zipf population's victim p99 under a megabyte-descriptor
    # flood must stay within 1.5x of the no-flood baseline (a CEILING, not a
    # floor), and the single-tier ablation must be measurably worse than the
    # two-tier run — equal-or-better means tier 2 rotted into a no-op
    ti = doc.get("tenant_isolation", {})
    wfq = ti.get("isolation_ratio_wfq")
    single = ti.get("isolation_ratio_single_tier")
    if isinstance(wfq, (int, float)) and wfq > 1.5:
        errors.append(
            f"tenant_isolation.isolation_ratio_wfq = {wfq} > 1.5: the "
            f"per-tenant WFQ tier is no longer isolating victims from the "
            f"flooding tenant in the committed file")
    if (isinstance(wfq, (int, float)) and isinstance(single, (int, float))
            and single <= wfq):
        errors.append(
            f"tenant_isolation: single-tier victim degradation {single}x <= "
            f"two-tier {wfq}x — the second arbitration tier is not buying "
            f"any isolation over the FIFO ablation")
    # a 50% BULK cap that does not reduce the BULK share at all means cap
    # enforcement rotted into a no-op
    qc = doc.get("qos_contention", {})
    off, on = qc.get("cap_bulk_share_uncapped"), qc.get(
        "cap_bulk_share_capped")
    if (isinstance(off, (int, float)) and isinstance(on, (int, float))
            and on >= off):
        errors.append(
            f"cap sweep: capped BULK share {on} >= uncapped {off} — the "
            f"class cap is not shifting bytes")


def _fresh_tx_probe(doc: dict, tol: float, errors: list[str]) -> None:
    """Staged-ring TX microbench vs the committed staged-ring us/byte."""
    import numpy as np
    from repro.core.transfer import TransferEngine, TransferPolicy

    committed = doc.get("staged_ring_best", {}).get("tx_us_per_byte")
    if not isinstance(committed, (int, float)):
        return  # structural check already flagged it
    eng = TransferEngine(TransferPolicy.kernel_level_ring(4,
                                                          block_bytes=1 << 20))
    x = np.zeros(8 << 20, np.uint8)
    eng.tx_async(x).wait()  # warm the device path (first put pays ~ms)
    best = float("inf")
    for _ in range(3):
        t0 = time.perf_counter()
        eng.tx_async(x).wait()
        best = min(best, time.perf_counter() - t0)
    eng.close()
    fresh = best * 1e6 / x.nbytes
    if fresh > committed * tol:
        errors.append(
            f"staged-ring TX regressed: fresh {fresh:.6f} us/B vs "
            f"committed {committed:.6f} (tolerance {tol}x)")
    print(f"fresh tx probe: {fresh:.6f} us/B "
          f"(committed {committed:.6f}, tol {tol}x)")


def _fresh_qos_probe(doc: dict, tol: float, errors: list[str]) -> None:
    """Quick qos_contention vs committed arbitrated p99 + preemption
    liveness."""
    sys.path.insert(0, str(REPO))
    from benchmarks import qos_contention

    committed = doc.get("qos_contention", {}).get(
        "runtime_arbitrated_token_rx_p99_ms")
    rows = qos_contention.run(quick=True)
    arb = next(r for r in rows if r["variant"] == "runtime-arbitrated")
    pre = next(r for r in rows if r["variant"] == "preempt-1w")
    if isinstance(committed, (int, float)) and (
            arb["token_rx_p99_ms"] > committed * tol):
        errors.append(
            f"token-RX p99 regressed: fresh {arb['token_rx_p99_ms']} ms vs "
            f"committed {committed} ms (tolerance {tol}x)")
    if pre["flood_preemptions"] == 0:
        errors.append(
            "preempt-1w ran with zero preemptions — preemptive chunked "
            "dispatch is not yielding (policy or runtime wiring rotted)")
    cap_on = next(r for r in rows if r["variant"] == "cap-50pct")
    cap_off = next(r for r in rows if r["variant"] == "cap-off")
    if cap_on["bulk_share"] >= cap_off["bulk_share"]:
        errors.append(
            f"fresh cap sweep: capped BULK share {cap_on['bulk_share']} >= "
            f"uncapped {cap_off['bulk_share']} — cap not enforced")
    # batched submission must still amortize AT ALL on a live host (the
    # 2x bar is enforced on the committed numbers; the fresh single-rep
    # probe only guards against rx_many rotting into per-descriptor cost)
    coal = next(r for r in rows if r["variant"] == "coalesce-headline")
    if coal["speedup_b32"] <= 1.0:
        errors.append(
            f"fresh coalescing sweep: batch-32 speedup "
            f"{coal['speedup_b32']} <= 1 — batched submission no longer "
            f"amortizes management overhead")
    print(f"fresh qos probe: arbitrated p99 {arb['token_rx_p99_ms']} ms "
          f"(committed {committed}), preemptions {pre['flood_preemptions']}, "
          f"bulk share {cap_off['bulk_share']} -> {cap_on['bulk_share']}, "
          f"coalescing b32 {coal['speedup_b32']}x")


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default=str(DEFAULT_JSON))
    ap.add_argument("--skip-fresh", action="store_true",
                    help="structural checks only (no live measurements)")
    ap.add_argument("--tolerance", type=float, default=20.0,
                    help="allowed fresh/committed factor before failing "
                         "(order-of-magnitude gate on a noisy host)")
    args = ap.parse_args()

    path = pathlib.Path(args.json)
    errors: list[str] = []
    if not path.exists():
        print(f"FAIL: {path} does not exist", file=sys.stderr)
        return 1
    try:
        doc = json.loads(path.read_text())
    except ValueError as e:
        print(f"FAIL: {path} is not valid JSON: {e}", file=sys.stderr)
        return 1

    _structural(doc, errors)
    if not args.skip_fresh and not errors:
        _fresh_tx_probe(doc, args.tolerance, errors)
        _fresh_qos_probe(doc, args.tolerance, errors)

    if errors:
        for e in errors:
            print(f"FAIL: {e}", file=sys.stderr)
        return 1
    print(f"check_bench OK ({path.name})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
