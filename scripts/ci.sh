#!/usr/bin/env bash
# Tier-1 verification + a transfer-bench smoke run, so the benchmarks can't
# silently rot. Run from the repo root:  bash scripts/ci.sh
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1: pytest =="
python -m pytest -x -q

echo "== smoke: transfer_sweep --quick =="
python benchmarks/transfer_sweep.py --quick --iters 2

echo "== smoke: multichannel_sweep --quick =="
python benchmarks/multichannel_sweep.py --quick

echo "CI OK"
