#!/usr/bin/env bash
# Tier-1 verification + transfer-bench smoke runs, so the benchmarks can't
# silently rot. Two pytest lanes: the fast lane excludes @pytest.mark.stress
# (quick signal on every change), the full lane then runs the stress suite
# so the concurrency invariants still gate CI. Run from the repo root:
#   bash scripts/ci.sh
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1 fast lane: pytest -m 'not stress' =="
python -m pytest -x -q -m "not stress"

echo "== full lane: stress suite (incl. 4-class runtime hammer) =="
python -m pytest -x -q -m "stress"

echo "== smoke: transfer_sweep --quick =="
python benchmarks/transfer_sweep.py --quick --iters 2

echo "== smoke: multichannel_sweep --quick =="
python benchmarks/multichannel_sweep.py --quick

echo "== smoke: adaptive_drift --quick =="
python benchmarks/adaptive_drift.py --quick

echo "== smoke: qos_contention --quick =="
python benchmarks/qos_contention.py --quick

echo "CI OK"
