#!/usr/bin/env bash
# Tier-1 verification + transfer-bench smoke runs, so the benchmarks can't
# silently rot. One entrypoint for local runs AND .github/workflows/ci.yml:
#
#   bash scripts/ci.sh                  # everything (fast + stress + smoke + chaos + lint)
#   bash scripts/ci.sh --lane fast      # pytest -m "not stress"
#   bash scripts/ci.sh --lane stress    # pytest -m "stress" (concurrency),
#                                       # with REPRO_VALIDATE_LOCKS=1 so every
#                                       # stress run doubles as a lock-order /
#                                       # guarded-by runtime check
#   bash scripts/ci.sh --lane smoke     # --quick benchmark smokes + the
#                                       # check_bench.py regression gate
#   bash scripts/ci.sh --lane chaos     # fault-injection suite + the
#                                       # fault_recovery >=80% throughput gate
#   bash scripts/ci.sh --lane lint      # ruff (if installed) + the concurrency
#                                       # analyzer (repro.analysis --fail-on-new)
set -euo pipefail
cd "$(dirname "$0")/.."

lane="all"
while [[ $# -gt 0 ]]; do
  case "$1" in
    --lane)
      lane="${2:?--lane needs fast|stress|smoke|chaos|lint}"
      shift 2
      ;;
    *)
      echo "unknown argument: $1 (usage: ci.sh [--lane fast|stress|smoke|chaos|lint])" >&2
      exit 2
      ;;
  esac
done

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

run_fast() {
  echo "== tier-1 fast lane: pytest -m 'not stress' =="
  python -m pytest -x -q -m "not stress"
}

run_stress() {
  echo "== stress lane: pytest -m 'stress' (incl. 4-class runtime hammer) =="
  # instrumented locks: record real acquisition order, fail the lane on a
  # lock-order inversion or a requires-lock breach (repro.analysis.validated)
  REPRO_VALIDATE_LOCKS=1 python -m pytest -x -q -m "stress"
}

run_smoke() {
  echo "== smoke: transfer_sweep --quick =="
  python benchmarks/transfer_sweep.py --quick --iters 2

  echo "== smoke: multichannel_sweep --quick =="
  python benchmarks/multichannel_sweep.py --quick

  echo "== smoke: adaptive_drift --quick =="
  python benchmarks/adaptive_drift.py --quick

  echo "== smoke: sg_vs_pack --quick =="
  python benchmarks/sg_vs_pack.py --quick

  echo "== smoke: tenant_isolation --quick (tier-2 heavy-hitter WFQ) =="
  python benchmarks/tenant_isolation.py --quick

  # no standalone qos_contention smoke: check_bench's fresh probe runs the
  # quick qos benchmark itself — which includes the rx_many coalescing
  # sweep (batch 1/8/32 amortization) — and gates on its numbers; running
  # it twice would just double the most expensive smoke on a 2-core host.
  echo "== gate: check_bench.py (committed BENCH_transfer.json vs fresh qos/tx/coalescing probes) =="
  python scripts/check_bench.py
}

run_chaos() {
  echo "== chaos lane: fault-injection suite (timeouts, retries, quarantine) =="
  python -m pytest -x -q tests/test_faults.py

  echo "== chaos lane: fault_recovery --quick (>= 80% throughput recovery gate) =="
  python benchmarks/fault_recovery.py --quick
}

run_lint() {
  if command -v ruff >/dev/null 2>&1; then
    echo "== lint: ruff check =="
    ruff check src tests benchmarks scripts
  else
    echo "== lint: ruff not installed; skipping (CI installs it via requirements-dev.txt) =="
  fi

  echo "== lint: concurrency analyzer (lock-order / guarded-by / blocking) =="
  python -m repro.analysis src --baseline analysis_baseline.json --fail-on-new
}

case "$lane" in
  fast)   run_fast ;;
  stress) run_stress ;;
  smoke)  run_smoke ;;
  chaos)  run_chaos ;;
  lint)   run_lint ;;
  all)    run_lint; run_fast; run_stress; run_smoke; run_chaos ;;
  *)
    echo "unknown lane: $lane (want fast|stress|smoke|chaos|lint)" >&2
    exit 2
    ;;
esac

echo "CI OK (lane: $lane)"
