from repro.kernels.ssd_scan.ops import ssd_intra_chunk  # noqa: F401
