"""Public wrapper: full SSD (kernel intra-chunk + jnp inter-chunk)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.ssd_scan.kernel import ssd_intra_chunk_call
from repro.kernels.ssd_scan.ref import ssd_intra_chunk_ref


def ssd_intra_chunk(x, dt, a, b, c, *, chunk: int, use_kernel: bool = True,
                    interpret: bool = False):
    if use_kernel:
        return ssd_intra_chunk_call(x, dt, a, b, c, chunk=chunk,
                                    interpret=interpret)
    return ssd_intra_chunk_ref(x, dt, a, b, c, chunk=chunk)


def ssd_full(x, dt, a, b, c, *, chunk: int, use_kernel: bool = True,
             interpret: bool = False,
             initial_state: jax.Array | None = None):
    """Complete SSD: kernel for the quadratic part, jnp recurrence across
    chunks. Semantics match repro.models.layers.ssm.ssd_chunked."""
    bs, s, h, p = x.shape
    g, n = b.shape[2], b.shape[3]
    nc = s // chunk
    rep = h // g
    y_diag, states, chunk_decay = ssd_intra_chunk(
        x, dt, a, b, c, chunk=chunk, use_kernel=use_kernel,
        interpret=interpret)

    s0 = (jnp.zeros((bs, h, p, n), jnp.float32) if initial_state is None
          else initial_state.astype(jnp.float32))

    def body(prev, inp):
        st_z, dec_z = inp
        new = prev * dec_z[..., None, None] + st_z
        return new, prev

    st_seq = states.transpose(1, 0, 2, 3, 4)  # [nc,B,H,P,N]
    dec_seq = chunk_decay.transpose(1, 0, 2)  # [nc,B,H]
    final, prev_states = jax.lax.scan(body, s0, (st_seq, dec_seq))

    # off-diagonal: y_off[q] = C_q . prev_state * exp(da_cs[q])
    dtc = dt.reshape(bs, nc, chunk, h).astype(jnp.float32)
    da_cs = jnp.cumsum(dtc * a[None, None, None, :], axis=2)  # [B,nc,Q,H]
    cc = jnp.repeat(c.reshape(bs, nc, chunk, g, n), rep, axis=3)
    y_off = jnp.einsum("bzqhn,zbhpn,bzqh->bzqhp", cc.astype(jnp.float32),
                       prev_states, jnp.exp(da_cs))
    y = y_diag + y_off.reshape(bs, s, h, p)
    return y.astype(x.dtype), final
