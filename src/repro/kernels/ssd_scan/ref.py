"""Pure-jnp oracle for the SSD intra-chunk kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_intra_chunk_ref(x: jax.Array, dt: jax.Array, a: jax.Array,
                        b: jax.Array, c: jax.Array, *, chunk: int):
    """Same contract as kernel.ssd_intra_chunk_call, all in jnp."""
    bs, s, h, p = x.shape
    g, n = b.shape[2], b.shape[3]
    nc = s // chunk
    rep = h // g

    xc = x.reshape(bs, nc, chunk, h, p)
    dtc = dt.reshape(bs, nc, chunk, h).astype(jnp.float32)
    bc = jnp.repeat(b.reshape(bs, nc, chunk, g, n), rep, axis=3)
    cc = jnp.repeat(c.reshape(bs, nc, chunk, g, n), rep, axis=3)

    da = dtc * a[None, None, None, :].astype(jnp.float32)  # [B,nc,Q,H]
    da_cs = jnp.cumsum(da, axis=2)

    diff = da_cs[:, :, :, None, :] - da_cs[:, :, None, :, :]  # [B,nc,Q,Q,H]
    q_idx = jnp.arange(chunk)
    tri = (q_idx[None, :] <= q_idx[:, None])[None, None, :, :, None]
    l_mat = jnp.where(tri, jnp.exp(diff), 0.0)

    xdt = xc * dtc[..., None].astype(xc.dtype)
    cb = jnp.einsum("bzqhn,bzkhn->bzqkh", cc, bc,
                    preferred_element_type=jnp.float32)
    att = (cb * l_mat).astype(x.dtype)
    y = jnp.einsum("bzqkh,bzkhp->bzqhp", att, xdt,
                   preferred_element_type=jnp.float32)

    decay_states = jnp.exp(da_cs[:, :, -1:, :] - da_cs).astype(x.dtype)
    st = jnp.einsum("bzkhn,bzkhp->bzhpn", bc * decay_states[..., None], xdt,
                    preferred_element_type=jnp.float32)
    dec = jnp.exp(da_cs[:, :, -1, :])
    return y.reshape(bs, s, h, p).astype(jnp.float32), st.astype(jnp.float32), dec
