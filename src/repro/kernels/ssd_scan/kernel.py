"""SSD intra-chunk kernel (Mamba2 state-space duality).

Computes, for each (batch, chunk) grid cell, the quadratic *intra-chunk*
part of the SSD algorithm plus the chunk's boundary-state contribution:

    y_diag[z]  = (C_z B_z^T * L_z) (x_z * dt_z)     [Q,H,P]
    states[z]  = sum_k decay_out[k] B_k (x_k dt_k)  [H,P,N]

The sequential inter-chunk recurrence (O(n_chunks) tiny updates) stays in
jnp — it is bandwidth-trivial. The chunk length Q is the BLOCKS knob: each
grid step's VMEM working set is Q*(H*P + 2*G*N) + H*Q^2, and Pallas
double-buffers consecutive chunks (the paper's overlap, again).

Grid: (B, n_chunks); heads stay inside the block (H*Q*Q f32 fits VMEM at
the assigned configs: 48*256*256*4 = 12.6 MiB).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax 0.4.x renamed CompilerParams -> TPUCompilerParams; jax >= 0.5 renames
# it back. Resolve whichever this jax provides.
_CompilerParams = getattr(pltpu, "TPUCompilerParams", None) or pltpu.CompilerParams


def _ssd_chunk_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, st_ref,
                      dec_ref, *, q: int, h: int, p: int, g: int, n: int):
    # refs (leading grid dims squeezed via index maps):
    # x: [Q,H,P]  dt: [Q,H]  a: [H]  b,c: [Q,G,N]
    x = x_ref[0, 0]
    dt = dt_ref[0, 0].astype(jnp.float32)
    a = a_ref[...].astype(jnp.float32)
    b = b_ref[0, 0]
    c = c_ref[0, 0]
    rep = h // g

    da = dt * a[None, :]  # [Q,H]
    da_cs = jnp.cumsum(da, axis=0)  # [Q,H]

    # L decay matrix per head: exp(segsum) lower-triangular
    diff = da_cs[:, None, :] - da_cs[None, :, :]  # [Q,Q,H]
    qi = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0)
    ki = jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    tri = (ki <= qi)[:, :, None]
    l_mat = jnp.where(tri, jnp.exp(diff), 0.0)  # [Q,Q,H]

    bh = jnp.repeat(b, rep, axis=1)  # [Q,H,N]
    ch = jnp.repeat(c, rep, axis=1)
    xdt = x * dt[..., None].astype(x.dtype)  # [Q,H,P]

    # cb[q,k,h] = sum_n c[q,h,n] b[k,h,n]
    cb = jnp.einsum("qhn,khn->qkh", ch, bh,
                    preferred_element_type=jnp.float32)
    att = (cb * l_mat).astype(x.dtype)  # [Q,Q,H]
    y_ref[0, 0] = jnp.einsum("qkh,khp->qhp", att, xdt,
                          preferred_element_type=jnp.float32).astype(y_ref.dtype)

    # chunk state: sum_k exp(da_cs[-1] - da_cs[k]) b_k (x_k dt_k)
    decay_states = jnp.exp(da_cs[-1][None, :] - da_cs).astype(x.dtype)  # [Q,H]
    st_ref[0, 0] = jnp.einsum("khn,khp->hpn", bh * decay_states[..., None],
                           xdt, preferred_element_type=jnp.float32
                           ).astype(st_ref.dtype)
    dec_ref[0, 0] = jnp.exp(da_cs[-1]).astype(dec_ref.dtype)  # [H]
    # also emit decay-in per position for the off-diagonal jnp pass
    # (folded into y by the caller: y += C_q . prev_state * exp(da_cs[q]))


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_intra_chunk_call(x: jax.Array, dt: jax.Array, a: jax.Array,
                         b: jax.Array, c: jax.Array, *, chunk: int,
                         interpret: bool = False):
    """x: [B,S,H,P]; dt: [B,S,H]; a: [H]; b,c: [B,S,G,N].

    Returns (y_diag [B,S,H,P] f32-accurate, states [B,nc,H,P,N] f32,
    chunk_decay [B,nc,H] f32)."""
    bs, s, h, p = x.shape
    g, n = b.shape[2], b.shape[3]
    assert s % chunk == 0
    nc = s // chunk
    grid = (bs, nc)
    kernel = functools.partial(_ssd_chunk_kernel, q=chunk, h=h, p=p, g=g, n=n)
    xc = x.reshape(bs, nc, chunk, h, p)
    dtc = dt.reshape(bs, nc, chunk, h)
    bc = b.reshape(bs, nc, chunk, g, n)
    cc = c.reshape(bs, nc, chunk, g, n)
    y, st, dec = pl.pallas_call(
        kernel,
        out_shape=(
            jax.ShapeDtypeStruct((bs, nc, chunk, h, p), jnp.float32),
            jax.ShapeDtypeStruct((bs, nc, h, p, n), jnp.float32),
            jax.ShapeDtypeStruct((bs, nc, h), jnp.float32),
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, chunk, h, p), lambda i, z: (i, z, 0, 0, 0)),
            pl.BlockSpec((1, 1, chunk, h), lambda i, z: (i, z, 0, 0)),
            pl.BlockSpec((h,), lambda i, z: (0,)),
            pl.BlockSpec((1, 1, chunk, g, n), lambda i, z: (i, z, 0, 0, 0)),
            pl.BlockSpec((1, 1, chunk, g, n), lambda i, z: (i, z, 0, 0, 0)),
        ],
        out_specs=(
            pl.BlockSpec((1, 1, chunk, h, p), lambda i, z: (i, z, 0, 0, 0)),
            pl.BlockSpec((1, 1, h, p, n), lambda i, z: (i, z, 0, 0, 0)),
            pl.BlockSpec((1, 1, h), lambda i, z: (i, z, 0)),
        ),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel")),
        interpret=interpret,
    )(xc, dtc, a, bc, cc)
    return y.reshape(bs, s, h, p), st, dec
