"""Pure-jnp oracle: SAME conv2d + bias + relu via lax.conv."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def conv2d_relu_ref(x: jax.Array, w: jax.Array, b: jax.Array, *,
                    relu: bool = True) -> jax.Array:
    y = jax.lax.conv_general_dilated(
        x, w, (1, 1), "SAME", dimension_numbers=("NHWC", "HWIO", "NHWC"))
    y = y + b[None, None, None, :]
    return jnp.maximum(y, 0.0) if relu else y
