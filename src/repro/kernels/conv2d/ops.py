"""Public wrapper: SAME-padded streamed conv2d (+bias, +relu)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.conv2d.kernel import conv2d_slabs


def conv2d_relu(x: jax.Array, w: jax.Array, b: jax.Array, *,
                tile_h: int = 8, relu: bool = True,
                interpret: bool = False) -> jax.Array:
    """x: [B, H, W, Cin]; w: [kh, kw, Cin, Cout] (SAME, stride 1).

    Builds overlapping row slabs (the streamed 'couple of rows' window)
    then runs the Pallas row-tile kernel."""
    bsz, h, wd, cin = x.shape
    kh, kw, _, cout = w.shape
    ph, pw = kh // 2, kw // 2
    xp = jnp.pad(x, ((0, 0), (ph, ph), (pw, pw), (0, 0)))
    tile_h = min(tile_h, h)
    while h % tile_h:
        tile_h -= 1
    nt = h // tile_h
    # overlapping slabs: slab t covers padded rows [t*tile_h, t*tile_h+slab_h)
    idx = (jnp.arange(nt)[:, None] * tile_h
           + jnp.arange(tile_h + kh - 1)[None, :])  # [nt, slab_h]
    slabs = xp[:, idx]  # [B, nt, slab_h, W+2pw, Cin]
    y = conv2d_slabs(slabs, w, b, tile_h=tile_h, relu=relu,
                     interpret=interpret)
    return y.reshape(bsz, h, wd, cout)
