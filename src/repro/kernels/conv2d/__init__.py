from repro.kernels.conv2d.ops import conv2d_relu  # noqa: F401
