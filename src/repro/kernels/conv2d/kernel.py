"""Row-streamed conv2d kernel — NullHop's MAC array, TPU-adapted.

NullHop streams feature-map rows: 'after a couple of rows are received, the
MACs start to operate'. The TPU analogue: the grid walks row-tiles of the
output; each step's BlockSpec DMAs a (tile_h + K - 1)-row input slab into
VMEM and issues K*K MXU dots of shape [(tile_h*W), Cin] x [Cin, Cout] — a
direct (im2col-free) convolution where the 3x3 taps become 9 shifted
matmuls, which is how a systolic MXU wants convs (vs the FPGA's spatial
MAC mesh; see DESIGN.md hardware-adaptation notes).

Overlapping row slabs can't be expressed as disjoint blocked windows, so
ops.py pre-pads and the index_map uses Element indexing on rows via an
input layout trick: the input is passed pre-sliced into overlapping slabs
[n_tiles, tile_h+K-1, W+2p, Cin] (built with one cheap gather in ops.py),
making every BlockSpec a plain disjoint block.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax 0.4.x renamed CompilerParams -> TPUCompilerParams; jax >= 0.5 renames
# it back. Resolve whichever this jax provides.
_CompilerParams = getattr(pltpu, "TPUCompilerParams", None) or pltpu.CompilerParams


def _conv_kernel(x_ref, w_ref, b_ref, o_ref, *, kh: int, kw: int,
                 tile_h: int, out_w: int, relu: bool):
    # x: [1, 1, tile_h+kh-1, out_w+kw-1, Cin]; w: [kh, kw, Cin, Cout]
    x = x_ref[0, 0]
    cin = x.shape[-1]
    cout = o_ref.shape[-1]
    acc = jnp.zeros((tile_h * out_w, cout), jnp.float32)
    for dy in range(kh):
        for dx in range(kw):
            patch = x[dy:dy + tile_h, dx:dx + out_w, :].reshape(
                tile_h * out_w, cin)
            acc += jnp.dot(patch, w_ref[dy, dx],
                           preferred_element_type=jnp.float32)
    acc += b_ref[...][None, :]
    if relu:
        acc = jnp.maximum(acc, 0.0)
    o_ref[0, 0] = acc.reshape(tile_h, out_w, cout).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("tile_h", "relu", "interpret"))
def conv2d_slabs(slabs: jax.Array, w: jax.Array, b: jax.Array, *,
                 tile_h: int, relu: bool = True,
                 interpret: bool = False) -> jax.Array:
    """slabs: [B, n_tiles, tile_h+kh-1, W+kw-1, Cin] (pre-overlapped);
    w: [kh, kw, Cin, Cout]. Returns [B, n_tiles, tile_h, W, Cout]."""
    bsz, nt, slab_h, slab_w, cin = slabs.shape
    kh, kw, _, cout = w.shape
    out_w = slab_w - (kw - 1)
    assert slab_h == tile_h + kh - 1
    kernel = functools.partial(_conv_kernel, kh=kh, kw=kw, tile_h=tile_h,
                               out_w=out_w, relu=relu)
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((bsz, nt, tile_h, out_w, cout),
                                       slabs.dtype),
        grid=(bsz, nt),
        in_specs=[
            pl.BlockSpec((1, 1, slab_h, slab_w, cin),
                         lambda i, t: (i, t, 0, 0, 0)),
            pl.BlockSpec((kh, kw, cin, cout), lambda i, t: (0, 0, 0, 0)),
            pl.BlockSpec((cout,), lambda i, t: (0,)),
        ],
        out_specs=pl.BlockSpec((1, 1, tile_h, out_w, cout),
                               lambda i, t: (i, t, 0, 0, 0)),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel")),
        interpret=interpret,
    )(slabs.reshape(bsz, nt, slab_h, slab_w, cin), w, b)
