"""Pure-jnp oracle for the streamed matmul."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def matmul_ref(x: jax.Array, w: jax.Array) -> jax.Array:
    return jnp.dot(x, w, preferred_element_type=jnp.float32).astype(x.dtype)
