from repro.kernels.streamed_matmul.ops import streamed_matmul  # noqa: F401
