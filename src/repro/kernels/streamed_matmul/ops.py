"""Public wrapper: policy-aware streamed matmul.

Selects UNIQUE vs BLOCKS from the TransferPolicy (the same object that
drives host staging), enforcing the VMEM budget for UNIQUE and deriving
MXU-aligned block sizes for BLOCKS from ``policy.block_bytes``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.transfer import Partitioning, TransferPolicy
from repro.kernels.streamed_matmul.kernel import matmul_blocks, matmul_unique

VMEM_BUDGET = 96 * 2**20  # leave headroom below the 128 MiB/core ceiling


def _align(x: int, m: int = 128) -> int:
    return max(m, (x // m) * m)


def _fits_vmem(m: int, k: int, n: int, itemsize: int) -> bool:
    return (m * k + k * n + m * n) * itemsize <= VMEM_BUDGET


def block_dims_for(policy: TransferPolicy, m: int, k: int, n: int,
                   itemsize: int) -> tuple[int, int, int]:
    """Derive (bm, bn, bk) from the policy's block_bytes: the K-stream
    working set (bm*bk + bk*bn) should be ~block_bytes, MXU-aligned."""
    target = max(policy.block_bytes // itemsize, 128 * 128)
    # square-ish tiles: bm=bn=bk=s with 3*s^2 = target
    s = _align(int((target / 3) ** 0.5))
    bm = min(_align(min(s, m)), m)
    bn = min(_align(min(s, n)), n)
    bk = min(_align(min(s, k)), k)
    # shrink to divisors
    while m % bm:
        bm -= 128
    while n % bn:
        bn -= 128
    while k % bk:
        bk -= 128
    return max(bm, 1), max(bn, 1), max(bk, 1)


def streamed_matmul(x: jax.Array, w: jax.Array,
                    policy: TransferPolicy | None = None, *,
                    interpret: bool = False) -> jax.Array:
    """[M, K] @ [K, N] under the transfer policy's partitioning mode."""
    policy = policy or TransferPolicy()
    m, k = x.shape
    _, n = w.shape
    itemsize = jnp.dtype(x.dtype).itemsize
    if (policy.partitioning is Partitioning.UNIQUE
            and _fits_vmem(m, k, n, itemsize)):
        return matmul_unique(x, w, interpret=interpret)
    if policy.partitioning is Partitioning.UNIQUE:
        raise ValueError(
            f"UNIQUE-mode matmul ({m}x{k})@({k}x{n}) exceeds the VMEM budget "
            f"({VMEM_BUDGET >> 20} MiB) — the paper's 8MB AXI-limit analogue. "
            f"Use BLOCKS partitioning.")
    bm, bn, bk = block_dims_for(policy, m, k, n, itemsize)
    if m % bm or n % bn or k % bk:
        raise ValueError(f"no aligned block decomposition for ({m},{k},{n})")
    return matmul_blocks(x, w, block_m=bm, block_n=bn, block_k=bk,
                         interpret=interpret)
