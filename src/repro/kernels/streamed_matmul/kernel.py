"""Streamed matmul kernel — the paper's DMA policy matrix at the HBM->VMEM
boundary.

The paper's axes map onto the grid/BlockSpec structure:

- UNIQUE mode   : one grid step; whole operands DMA'd to VMEM, one dot.
  (Only legal when everything fits VMEM — the AXI 'single long burst'.)
- BLOCKS mode   : tiled (M/bm, N/bn, K/bk) grid; each step DMAs one
  (bm x bk) x (bk x bn) working set. Pallas' pipelining machinery
  double-buffers revolving grid windows automatically — arriving block
  k+1 overlaps the MXU dot on block k, exactly the paper's double-buffer
  overlap. Block sizes are the 'packet length' knob: too small pays
  per-DMA overhead every step (the paper's small-transfer regime), too
  large overflows VMEM (the paper's 8MB AXI limit analogue).

The K axis is innermost and 'arbitrary' (sequential) so the f32 accumulator
scratch lives across K steps; M/N are parallel.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax 0.4.x renamed CompilerParams -> TPUCompilerParams; jax >= 0.5 renames
# it back. Resolve whichever this jax provides.
_CompilerParams = getattr(pltpu, "TPUCompilerParams", None) or pltpu.CompilerParams


def _matmul_kernel(x_ref, w_ref, o_ref, acc_ref, *, k_steps: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(x_ref[...], w_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == k_steps - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_m", "block_n", "block_k",
                                             "interpret"))
def matmul_blocks(x: jax.Array, w: jax.Array, *, block_m: int = 512,
                  block_n: int = 512, block_k: int = 512,
                  interpret: bool = False) -> jax.Array:
    """BLOCKS-mode matmul: [M, K] @ [K, N], tiled VMEM pipeline."""
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, (x.shape, w.shape)
    bm, bn, bk = min(block_m, m), min(block_n, n), min(block_k, k)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (
        f"shape ({m},{k})x({k},{n}) not divisible by blocks ({bm},{bn},{bk})")
    k_steps = k // bk
    grid = (m // bm, n // bn, k_steps)
    return pl.pallas_call(
        functools.partial(_matmul_kernel, k_steps=k_steps),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x, w)


def _matmul_unique_kernel(x_ref, w_ref, o_ref):
    o_ref[...] = jnp.dot(x_ref[...], w_ref[...],
                         preferred_element_type=jnp.float32
                         ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def matmul_unique(x: jax.Array, w: jax.Array, *,
                  interpret: bool = False) -> jax.Array:
    """UNIQUE-mode matmul: whole operands in one VMEM residency.

    VMEM budget check is the caller's job (ops.py enforces it) — this is
    the paper's 'send all the data at once' configuration."""
    m, k = x.shape
    _, n = w.shape
    return pl.pallas_call(
        _matmul_unique_kernel,
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        in_specs=[pl.BlockSpec((m, k), lambda: (0, 0)),
                  pl.BlockSpec((k, n), lambda: (0, 0))],
        out_specs=pl.BlockSpec((m, n), lambda: (0, 0)),
        interpret=interpret,
    )(x, w)
