"""Flash attention kernel: blocks-mode KV streaming with online softmax.

The TPU-native version of the model's jnp ``attention_blocks`` path: each
grid step DMAs one (block_q x block_kv) tile pair into VMEM, updates the
f32 accumulator/max/sum scratch, and Pallas double-buffers the revolving KV
tiles — the paper's double-buffered blocks DMA applied to the attention
score stream (NullHop's 'start computing after a couple of rows arrive').

Causal-aware grid: KV tiles strictly above the diagonal for every query in
the tile are skipped via pl.when (zero work, not just masked) — the
beyond-paper optimization measured in §Perf.

Grid: (batch*heads, q_tiles, kv_tiles), kv innermost ('arbitrary' so the
scratch carries across kv steps). GQA is handled by the kv index_map
(q-head -> kv-head, h // n_rep) so kv tiles are DMA'd once per group.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax 0.4.x renamed CompilerParams -> TPUCompilerParams; jax >= 0.5 renames
# it back. Resolve whichever this jax provides.
_CompilerParams = getattr(pltpu, "TPUCompilerParams", None) or pltpu.CompilerParams

NEG_INF = -2.0**30
_INV_LN2 = 1.4426950408889634


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  kv_steps: int, block_q: int, block_kv: int, scale: float,
                  causal: bool, window: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_start = qi * block_q
    kv_start = ki * block_kv

    def compute():
        q = q_ref[0]  # [bq, d]
        k = k_ref[0]  # [bkv, d]
        v = v_ref[0]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale  # [bq, bkv]
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (block_q,
                                                              block_kv), 0)
        kpos = kv_start + jax.lax.broadcasted_iota(jnp.int32, (block_q,
                                                               block_kv), 1)
        ok = jnp.ones((block_q, block_kv), jnp.bool_)
        if causal:
            ok &= kpos <= qpos
        if window > 0:
            ok &= qpos - kpos < window
        s = jnp.where(ok, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        alpha = jnp.exp2((m_prev - m_new) * _INV_LN2)
        p = jnp.exp2((s - m_new[:, None]) * _INV_LN2)
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    if causal:
        # skip tiles entirely above the diagonal (no valid kv for any q)
        pl.when(kv_start <= q_start + block_q - 1)(compute)
    elif window > 0:
        pl.when((kv_start <= q_start + block_q - 1)
                & (q_start - (kv_start + block_kv - 1) < window))(compute)
    else:
        compute()

    @pl.when(ki == kv_steps - 1)
    def _flush():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "block_q", "block_kv", "n_rep",
                     "interpret"))
def flash_attention_bhsd(q: jax.Array, k: jax.Array, v: jax.Array, *,
                         causal: bool = True, window: int = 0,
                         block_q: int = 512, block_kv: int = 512,
                         n_rep: int = 1, interpret: bool = False) -> jax.Array:
    """q: [BH, Sq, D]; k, v: [BHkv, Skv, D] with BH = BHkv * n_rep.

    Heads are flattened into the leading grid axis; the kv index_map maps
    query head -> kv head so GQA groups share kv tile DMAs."""
    bh, sq, d = q.shape
    skv = k.shape[1]
    bq = min(block_q, sq)
    bkv = min(block_kv, skv)
    assert sq % bq == 0 and skv % bkv == 0, (sq, skv, bq, bkv)
    kv_steps = skv // bkv
    grid = (bh, sq // bq, kv_steps)
    kernel = functools.partial(
        _flash_kernel, kv_steps=kv_steps, block_q=bq, block_kv=bkv,
        scale=1.0 / math.sqrt(d), causal=causal, window=window)
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((bh, sq, d), q.dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda h, i, j: (h, i, 0)),
            pl.BlockSpec((1, bkv, d), lambda h, i, j, n_rep=n_rep: (h // n_rep, j, 0)),
            pl.BlockSpec((1, bkv, d), lambda h, i, j, n_rep=n_rep: (h // n_rep, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda h, i, j: (h, i, 0)),
        scratch_shapes=[
            pltpu.VMEM((bq, d), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v)
