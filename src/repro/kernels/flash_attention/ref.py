"""Pure-jnp oracle for flash attention (materialised scores)."""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

NEG_INF = -2.0**30


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool = True, window: int = 0,
                  n_rep: int = 1) -> jax.Array:
    """q: [BH, Sq, D]; k, v: [BHkv, Skv, D]."""
    if n_rep > 1:
        k = jnp.repeat(k, n_rep, axis=0)
        v = jnp.repeat(v, n_rep, axis=0)
    d = q.shape[-1]
    s = jnp.einsum("bqd,bkd->bqk", q, k,
                   preferred_element_type=jnp.float32) / math.sqrt(d)
    sq, skv = q.shape[1], k.shape[1]
    qpos = jnp.arange(sq)[:, None]
    kpos = jnp.arange(skv)[None, :]
    ok = jnp.ones((sq, skv), bool)
    if causal:
        ok &= kpos <= qpos
    if window > 0:
        ok &= qpos - kpos < window
    s = jnp.where(ok[None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p.astype(q.dtype), v)
