"""Public wrapper: [B, S, H, Dh] GQA flash attention."""

from __future__ import annotations

import jax

from repro.kernels.flash_attention.kernel import flash_attention_bhsd


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int = 0,
                    block_q: int = 512, block_kv: int = 512,
                    interpret: bool = False) -> jax.Array:
    """q: [B, Sq, H, Dh]; k, v: [B, Skv, Hkv, Dh] -> [B, Sq, H, Dh]."""
    b, sq, h, dh = q.shape
    hkv = k.shape[2]
    n_rep = h // hkv
    qf = q.transpose(0, 2, 1, 3).reshape(b * h, sq, dh)
    kf = k.transpose(0, 2, 1, 3).reshape(b * hkv, k.shape[1], dh)
    vf = v.transpose(0, 2, 1, 3).reshape(b * hkv, v.shape[1], dh)
    of = flash_attention_bhsd(qf, kf, vf, causal=causal, window=window,
                              block_q=block_q, block_kv=block_kv,
                              n_rep=n_rep, interpret=interpret)
    return of.reshape(b, h, sq, dh).transpose(0, 2, 1, 3)
