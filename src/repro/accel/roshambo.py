"""RoShamBo CNN — the 5-conv-layer network the paper executes on NullHop.

Per Aimar et al. (NullHop, arXiv:1706.01406): 64x64x1 DVS histogram frames,
five 3x3 conv layers (with max-pool after most), classifying
rock/paper/scissors(/background) — 4 classes. Layer transfer sizes land in
the ~100 KB regime the paper highlights ("transfer lengths are in the order
of 100Kbytes, where kernel-level driver is still not obtaining its best
results").

Pure-JAX definition; executed per-layer by repro.accel.nullhop (streaming)
or monolithically via :meth:`RoShamBoCNN.apply` (fused oracle).
"""

from __future__ import annotations

import math
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class ConvSpec:
    name: str
    c_in: int
    c_out: int
    kernel: int = 3
    pool: bool = True  # 2x2 max pool after relu


@dataclass(frozen=True)
class RoShamBoConfig:
    input_hw: int = 64
    n_classes: int = 4
    layers: tuple[ConvSpec, ...] = (
        ConvSpec("conv1", 1, 16),
        ConvSpec("conv2", 16, 32),
        ConvSpec("conv3", 32, 64),
        ConvSpec("conv4", 64, 128),
        ConvSpec("conv5", 128, 128, pool=False),
    )
    dtype: str = "float32"


def roshambo_config() -> RoShamBoConfig:
    return RoShamBoConfig()


def conv2d(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """x: [B,H,W,Cin]; w: [K,K,Cin,Cout] (SAME padding, stride 1)."""
    y = jax.lax.conv_general_dilated(
        x, w, window_strides=(1, 1), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    return y + b[None, None, None, :]


def maxpool2(x: jax.Array) -> jax.Array:
    return jax.lax.reduce_window(x, -jnp.inf, jax.lax.max,
                                 (1, 2, 2, 1), (1, 2, 2, 1), "VALID")


class RoShamBoCNN:
    def __init__(self, cfg: RoShamBoConfig | None = None):
        self.cfg = cfg or roshambo_config()

    def init(self, key) -> dict:
        cfg = self.cfg
        dt = jnp.dtype(cfg.dtype)
        params: dict = {}
        hw = cfg.input_hw
        for spec in cfg.layers:
            key, k1 = jax.random.split(key)
            fan_in = spec.kernel * spec.kernel * spec.c_in
            params[spec.name] = {
                "w": (jax.random.normal(k1, (spec.kernel, spec.kernel,
                                             spec.c_in, spec.c_out))
                      * math.sqrt(2.0 / fan_in)).astype(dt),
                "b": jnp.zeros((spec.c_out,), dt),
            }
            if spec.pool:
                hw //= 2
        key, k1 = jax.random.split(key)
        feat = hw * hw * cfg.layers[-1].c_out
        params["fc"] = {
            "w": (jax.random.normal(k1, (feat, cfg.n_classes))
                  * math.sqrt(1.0 / feat)).astype(dt),
            "b": jnp.zeros((cfg.n_classes,), dt),
        }
        return params

    def layer_apply(self, spec: ConvSpec, p: dict, x: jax.Array) -> jax.Array:
        y = jax.nn.relu(conv2d(x, p["w"], p["b"]))
        return maxpool2(y) if spec.pool else y

    def apply(self, params: dict, x: jax.Array) -> jax.Array:
        """Monolithic forward (oracle for the streamed executor)."""
        for spec in self.cfg.layers:
            x = self.layer_apply(spec, params[spec.name], x)
        b = x.shape[0]
        return x.reshape(b, -1) @ params["fc"]["w"] + params["fc"]["b"]

    def layer_transfer_bytes(self, params: dict, batch: int = 1) -> list[dict]:
        """Per-layer TX (params + input fmap) / RX (output fmap) byte counts —
        the quantities Table I normalises by."""
        cfg = self.cfg
        out = []
        hw = cfg.input_hw
        itemsize = jnp.dtype(cfg.dtype).itemsize
        for spec in cfg.layers:
            tx = (int(np.prod(params[spec.name]["w"].shape)) +
                  params[spec.name]["b"].shape[0]) * itemsize
            tx += batch * hw * hw * spec.c_in * itemsize
            hw_out = hw // 2 if spec.pool else hw
            rx = batch * hw_out * hw_out * spec.c_out * itemsize
            out.append({"name": spec.name, "tx_bytes": tx, "rx_bytes": rx})
            hw = hw_out
        return out
