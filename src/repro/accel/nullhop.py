"""NullHop-style accelerator executor: per-layer streamed CNN execution.

Reproduces the paper's scenario 2 (Table I): each layer of the CNN is
executed as TX(params + input fmap) -> compute -> RX(output fmap), with the
transfer policy deciding how the TX/RX DMAs are managed. Built on
:class:`repro.core.streaming.HostStreamingExecutor`, so the three driver
modes and the buffering/partitioning knobs all apply.

Also models NullHop's sparsity awareness: the accelerator skips zero
activations (sparse feature-map encoding); we report the measured activation
sparsity per layer (ReLU output) alongside timings, since it determines the
effective RX payload on the real device.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.accel.roshambo import RoShamBoCNN
from repro.core.streaming import FrameTiming, HostStreamingExecutor
from repro.core.transfer import TransferEngine, TransferPolicy


@dataclass
class NullHopResult:
    logits: np.ndarray
    timing: FrameTiming
    sparsity: list[float]  # per-layer zero fraction of the output fmap
    policy_tag: str


class NullHopExecutor:
    """Executes a RoShamBoCNN per-layer under a transfer policy.

    ``staged=True`` (default) streams through the engine's cached
    :class:`~repro.core.transfer.StagedLayout` ring path — layer weights are
    laid out once and re-staged copy-free on every subsequent frame;
    ``staged=False`` keeps the seed per-frame pack path for comparison."""

    def __init__(self, cnn: RoShamBoCNN, policy: TransferPolicy, *,
                 staged: bool = True):
        self.cnn = cnn
        self.policy = policy
        self.staged = staged
        self.engine = TransferEngine(policy)

    def close(self) -> None:
        self.engine.close()

    def run_frame(self, params: dict, frame: np.ndarray) -> NullHopResult:
        """frame: [B, H, W, C]. Per-layer streamed execution + final FC."""
        cnn = self.cnn
        jitted = {}

        def make_apply(spec):
            def apply_fn(dev_params, x):
                w, b = dev_params
                return cnn.layer_apply(spec, {"w": w, "b": b}, x)
            if spec.name not in jitted:
                jitted[spec.name] = jax.jit(apply_fn)
            return jitted[spec.name]

        layers = []
        for spec in cnn.cfg.layers:
            p = params[spec.name]
            layers.append((spec.name, [np.asarray(p["w"]), np.asarray(p["b"])],
                           make_apply(spec)))

        executor = HostStreamingExecutor(self.engine, staged=self.staged)
        out_host, timing = executor.run(layers, np.asarray(frame))

        sparsity = []  # recompute per-layer zero fractions (oracle pass)
        x = jnp.asarray(frame)
        for spec in cnn.cfg.layers:
            x = cnn.layer_apply(spec, params[spec.name], x)
            sparsity.append(float((x == 0).mean()))

        # classifier head runs on the PS in the paper (host-side)
        feats = out_host.reshape(out_host.shape[0], -1)
        logits = feats @ np.asarray(params["fc"]["w"]) + np.asarray(params["fc"]["b"])
        return NullHopResult(logits, timing, sparsity, self.policy.tag)
