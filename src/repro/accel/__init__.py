"""NullHop-style CNN accelerator executor + the RoShamBo CNN (the paper's
real workload, Table I)."""

from repro.accel.roshambo import RoShamBoCNN, roshambo_config  # noqa: F401
from repro.accel.nullhop import NullHopExecutor  # noqa: F401
