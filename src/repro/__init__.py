"""repro — Streamline: a transfer-strategy-first JAX training/serving framework.

Reproduction + extension of Rios-Navarro et al., "Performance evaluation over
HW/SW co-design SoC memory transfers for a CNN accelerator" (2018), adapted to
TPU-class hardware: the paper's transfer-management policy matrix
(polling / scheduled / interrupt  ×  single / double buffer  ×  unique / blocks)
is implemented at the host<->HBM, HBM<->VMEM, and chip<->chip boundaries.
"""

__version__ = "1.0.0"

from repro.core.transfer import (  # noqa: F401
    Buffering,
    Management,
    Partitioning,
    TransferPolicy,
)
