"""Data pipeline with policy-driven host->device staging.

The paper's PS side collects DVS events, normalises them into frames, and
DMAs them to the accelerator. Our equivalent: a host-side source produces
token batches (synthetic LM stream here — deterministic, seeded), a
normalisation stage packs them, and the staging stage moves them to device
under a :class:`TransferPolicy`:

- POLLING   : device_put + block before the step (paper's user-level)
- SCHEDULED : staging tasks interleaved with source work on the cooperative
              scheduler
- INTERRUPT : background prefetch thread keeps a ring of ``policy.depth``
              device batches ready (single/double buffer are rings of depth
              1/2) — the kernel-driver mode, and the right default for
              training (stage batch k+1..k+depth during step k).

When a transfer ``engine`` (a :class:`~repro.core.transfer.TransferEngine`
or multi-channel :class:`~repro.core.channels.ChannelGroup`) is supplied and
no shardings are requested, batches stage through its cached
:class:`~repro.core.transfer.StagedLayout` — one reused staging buffer per
batch shape, measured TX stats, and (for a group) the batch payload striped
across channels.
"""

from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Any, Iterator

import jax
import numpy as np

from repro.core.qos import QosSpec
from repro.core.runtime import CooperativeScheduler, PriorityClass
from repro.core.transfer import Management, TransferPolicy
from repro.models.config import ModelConfig


@dataclass(frozen=True)
class DataConfig:
    global_batch: int
    seq_len: int
    seed: int = 0


class SyntheticLMSource:
    """Deterministic synthetic token stream (zipfian-ish unigram mix with
    local structure, so loss curves are non-trivial but reproducible)."""

    def __init__(self, cfg: DataConfig, model_cfg: ModelConfig):
        self.cfg = cfg
        self.model_cfg = model_cfg
        self._rng = np.random.default_rng(cfg.seed)
        v = model_cfg.vocab
        ranks = np.arange(1, v + 1, dtype=np.float64)
        self._probs = (1.0 / ranks) / np.sum(1.0 / ranks)

    def next_host_batch(self, step: int) -> dict[str, np.ndarray]:
        rng = np.random.default_rng(self.cfg.seed + step)
        b, s = self.cfg.global_batch, self.cfg.seq_len
        mc = self.model_cfg
        if mc.family == "vlm":
            s_text = s - mc.n_prefix_tokens
            toks = rng.choice(mc.vocab, size=(b, s_text), p=self._probs)
            return {
                "tokens": toks.astype(np.int32),
                "patch_embeds": rng.standard_normal(
                    (b, mc.n_prefix_tokens, mc.d_model)).astype(np.float32),
                "labels": np.roll(toks, -1, axis=1).astype(np.int32),
            }
        toks = rng.choice(mc.vocab, size=(b, s), p=self._probs)
        # local structure: repeat the previous token 20% of the time
        rep = rng.random((b, s)) < 0.2
        toks[:, 1:] = np.where(rep[:, 1:], toks[:, :-1], toks[:, 1:])
        batch = {
            "tokens": toks.astype(np.int32),
            "labels": np.roll(toks, -1, axis=1).astype(np.int32),
        }
        if mc.family == "audio":
            batch["frames"] = rng.standard_normal(
                (b, s, mc.d_model)).astype(np.float32)
        return batch


class StagedPipeline:
    """Iterator of device-resident batches under a transfer policy."""

    def __init__(self, source: SyntheticLMSource, policy: TransferPolicy,
                 shardings: Any | None = None, start_step: int = 0,
                 engine: Any | None = None):
        self.source = source
        self.policy = policy
        self.shardings = shardings
        self.engine = engine  # TransferEngine or ChannelGroup (optional)
        self.step = start_step
        # prefetch window = the policy's descriptor-ring depth (SINGLE=1,
        # DOUBLE=2, RING=N): batch k+depth stages while step k runs.
        self._q: "queue.Queue[Any]" = queue.Queue(maxsize=policy.depth)
        self._thread: threading.Thread | None = None
        self._stop = threading.Event()
        self._sched = (CooperativeScheduler()
                       if policy.management is Management.SCHEDULED else None)
        if policy.management is Management.INTERRUPT:
            self._thread = threading.Thread(target=self._prefetch_loop,
                                            daemon=True)
            self._thread.start()

    def _put_device(self, host_batch: dict) -> Any:
        if self.shardings is not None:
            return jax.device_put(host_batch, self.shardings)
        if self.engine is not None:
            # stage through the engine's cached layout: the staging buffer
            # is reused every step (same batch shapes), the TX is measured,
            # and a ChannelGroup stripes it across its rings. BULK class:
            # prefetch is throughput traffic — the shared runtime must
            # never let it queue ahead of token RX or sensor ingest.
            keys = sorted(host_batch)
            arrays = [np.ascontiguousarray(host_batch[k]) for k in keys]
            lay = self.engine.layouts.get(("batch", tuple(keys)), arrays)
            if (hasattr(self.engine, "tx_sg")
                    and hasattr(self.engine, "prefer_sg")
                    and self.engine.policy.management is Management.INTERRUPT
                    and self.engine.layouts.decide_sg(
                        ("batch", tuple(keys)), lay,
                        self.engine.prefer_sg)):
                # few large batch arrays: scatter-gather skips the staging
                # memcpy — each array is its own descriptor segment.
                dev = self.engine.tx_sg(
                    lay.sg_segments(arrays),
                    qos=QosSpec(priority=PriorityClass.BULK)).wait()
            else:
                dev = lay.unpack(self.engine.tx(
                    lay.pack(arrays),
                    qos=QosSpec(priority=PriorityClass.BULK)))
            # batch boundary, TX retired: safe point for an online-adaptive
            # engine to refit its cost model and swap plan generations
            # (no-op on plain engines/groups).
            self.engine.maybe_adapt()
            return dict(zip(keys, dev))
        return jax.device_put(host_batch)

    def _prefetch_loop(self) -> None:
        step = self.step
        while not self._stop.is_set():
            batch = self._put_device(self.source.next_host_batch(step))
            step += 1
            while not self._stop.is_set():
                try:
                    self._q.put(batch, timeout=0.1)
                    break
                except queue.Full:
                    continue

    def __iter__(self) -> Iterator[Any]:
        return self

    def __next__(self) -> Any:
        mgmt = self.policy.management
        if mgmt is Management.INTERRUPT:
            batch = self._q.get()
        elif mgmt is Management.SCHEDULED:
            out: list = []
            self._sched.submit(lambda: out.append(
                self._put_device(self.source.next_host_batch(self.step))))
            self._sched.drain()
            batch = out[0]
        else:  # POLLING
            batch = self._put_device(self.source.next_host_batch(self.step))
            jax.block_until_ready(batch)
        self.step += 1
        return batch

    def close(self) -> None:
        self._stop.set()
        # unblock a producer stuck on a full queue
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
