from repro.data.pipeline import DataConfig, SyntheticLMSource, StagedPipeline  # noqa: F401
