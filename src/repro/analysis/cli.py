"""CLI for the concurrency analyzer: ``python -m repro.analysis``.

Exit codes: 0 clean (or only baselined/waived findings with --fail-on-new),
1 findings, 2 usage error.
"""
from __future__ import annotations

import argparse
import sys
from pathlib import Path

from .baseline import load_baseline, split_new, write_baseline
from .model import PackageModel, extract_module, extract_package
from .rules import RULES, Finding, run_rules


def analyze_source(source: str, modname: str = "snippet",
                   rules=RULES) -> list[Finding]:
    """Run the rules over a single in-memory module (test fixtures)."""
    pkg = PackageModel()
    pkg.modules[modname] = extract_module(source, modname)
    return run_rules(pkg, rules)


def _default_root() -> Path:
    # .../src/repro/analysis/cli.py -> .../src
    return Path(__file__).resolve().parents[2]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="Concurrency static analysis for the repro transfer stack")
    ap.add_argument("root", nargs="?", default=None,
                    help="directory containing the repro package "
                         "(default: the installed src/ tree)")
    ap.add_argument("--rules", default=",".join(RULES),
                    help=f"comma-separated subset of: {', '.join(RULES)}")
    ap.add_argument("--baseline", default=None,
                    help="baseline JSON of grandfathered fingerprints")
    ap.add_argument("--fail-on-new", action="store_true",
                    help="fail only on findings not in --baseline")
    ap.add_argument("--write-baseline", default=None, metavar="PATH",
                    help="write current findings as the new baseline and exit 0")
    ap.add_argument("--show-waived", action="store_true",
                    help="also list findings suppressed by # lock-ok waivers")
    ap.add_argument("-q", "--quiet", action="store_true")
    args = ap.parse_args(argv)

    rules = tuple(r.strip() for r in args.rules.split(",") if r.strip())
    bad = [r for r in rules if r not in RULES]
    if bad:
        ap.error(f"unknown rule(s): {', '.join(bad)} (want subset of {', '.join(RULES)})")

    root = Path(args.root) if args.root else _default_root()
    if not (root / "repro").is_dir():
        ap.error(f"{root} does not contain a repro/ package")

    pkg = extract_package(root)
    findings = run_rules(pkg, rules)
    active = [f for f in findings if not f.waived]
    waived = [f for f in findings if f.waived]

    if args.write_baseline:
        write_baseline(args.write_baseline, findings)
        print(f"wrote {len(active)} fingerprint(s) to {args.write_baseline}")
        return 0

    baseline = load_baseline(args.baseline) if args.baseline else set()
    if args.fail_on_new:
        new, old = split_new(findings, baseline)
    else:
        new, old = active, []

    if not args.quiet:
        for f in new:
            print(f.render())
        if old:
            print(f"note: {len(old)} baselined finding(s) suppressed")
        if args.show_waived:
            for f in waived:
                print(f.render() + (f" [{f.waiver}]" if f.waiver else ""))
        n_mod = len(pkg.modules)
        n_skip = sum(1 for m in pkg.modules.values() if m.skipped)
        n_locks = sum(len(c.locks) for c in pkg.all_classes()) + sum(
            len(m.module_locks) for m in pkg.modules.values())
        print(f"analysis: {n_mod} modules ({n_skip} skipped), {n_locks} lock "
              f"classes, {len(new)} new / {len(old)} baselined / "
              f"{len(waived)} waived finding(s)")
    return 1 if new else 0


if __name__ == "__main__":  # pragma: no cover
    sys.exit(main())
