"""Concurrency static analysis + opt-in runtime lock validation.

Static side (``python -m repro.analysis``): lock-order cycle detection over
the package's static acquisition graph, ``# guarded-by:`` field checking,
blocking-call-under-lock linting and ``# requires-lock:`` call-site checks.
Runtime side (:mod:`repro.analysis.validated`): ``make_lock`` factories the
core modules use, which become order-validating wrappers under
``REPRO_VALIDATE_LOCKS=1``.

See docs/concurrency.md for the annotation syntax and canonical lock order.
"""
from .baseline import load_baseline, split_new, write_baseline  # noqa: F401
from .cli import analyze_source, main  # noqa: F401
from .model import PackageModel, extract_module, extract_package  # noqa: F401
from .rules import RULES, Finding, run_rules  # noqa: F401
from .validated import (  # noqa: F401
    LockAssertionError,
    LockOrderViolation,
    ValidatedLock,
    assert_held,
    enable,
    enabled,
    make_condition,
    make_lock,
    make_rlock,
    order_graph,
)
