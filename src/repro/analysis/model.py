"""AST extraction for the concurrency analyzer.

This module turns Python source into a lock-aware model of the package:

- which ``self.<attr>`` / module-level names are locks (``threading.Lock()``,
  ``RLock()``, ``Condition()``, or the :mod:`repro.analysis.validated`
  factories ``make_lock``/``make_rlock``/``make_condition``);
- per function, the sequence of lock *acquisitions* (``with self._lock:``
  scopes, plus sticky ``self._lock.acquire(...)`` calls, which hold for the
  remainder of the enclosing scope), *field accesses* (``self.<attr>`` loads
  and stores) and *calls* — each tagged with the statically-held lock set at
  that point;
- source-comment annotations:

  ``# guarded-by: <lockattr>``   on a ``self.<field> = ...`` assignment (same
                                 line or the line above) declares the field
                                 protected by that lock attribute;
  ``# requires-lock: <lockattr>`` on a ``def`` line (or the line above)
                                 declares the function must be called with the
                                 lock held — its body is analyzed as if held,
                                 and same-class call sites are checked;
  ``# lock-ok: <reason>``        waives any finding anchored to that line;
  ``# analysis: skip-module``    anywhere in the file skips the whole module
                                 (back-compat shims).

Static conventions (documented in docs/concurrency.md): nested ``def``s are
analyzed with an *empty* held set (they run later, on other threads), while
lambdas and comprehensions inherit the current held set (they overwhelmingly
execute in place in this codebase).
"""
from __future__ import annotations

import ast
import re
from dataclasses import dataclass, field
from pathlib import Path

GUARDED_RE = re.compile(r"#\s*guarded-by:\s*([A-Za-z_]\w*)")
REQUIRES_RE = re.compile(r"#\s*requires-lock:\s*([A-Za-z_]\w*(?:\s*,\s*[A-Za-z_]\w*)*)")
WAIVER_RE = re.compile(r"#\s*lock-ok\b:?\s*(?P<reason>[^#]*)")
SKIP_RE = re.compile(r"#\s*analysis:\s*skip-module")

# Call(func=...) shapes that create a lock. Attribute form matches
# threading.Lock / threading.RLock / threading.Condition; Name form matches
# the validated factories (however they were imported).
_LOCK_KINDS = {
    "Lock": "lock",
    "RLock": "rlock",
    "Condition": "condition",
    "make_lock": "lock",
    "make_rlock": "rlock",
    "make_condition": "condition",
}


@dataclass(frozen=True)
class LockDecl:
    """A lock *class*: one per declaration site, identified across instances."""

    id: str          # "TransferEngine._ring_lock" / "runtime._global_lock"
    kind: str        # lock | rlock | condition
    module: str
    line: int


@dataclass(frozen=True)
class Access:
    attr: str
    line: int
    write: bool
    held: tuple[str, ...]


@dataclass(frozen=True)
class CallSite:
    name: str                    # dotted best-effort: "self._record", "time.sleep"
    last: str                    # final attribute / name
    receiver: str                # "self" | "bare" | "other"
    line: int
    held: tuple[str, ...]
    receiver_lock: str | None    # lock id when the receiver itself is a lock


@dataclass(frozen=True)
class AcquireSite:
    lock_id: str
    line: int
    held: tuple[str, ...]        # held *before* this acquisition


@dataclass
class FunctionInfo:
    qualname: str                # "module:Class.method" or "module:func"
    module: str
    class_name: str | None
    name: str
    line: int
    requires: tuple[str, ...] = ()
    accesses: list[Access] = field(default_factory=list)
    calls: list[CallSite] = field(default_factory=list)
    acquires: list[AcquireSite] = field(default_factory=list)


@dataclass
class ClassInfo:
    name: str
    module: str
    line: int
    locks: dict[str, LockDecl] = field(default_factory=dict)       # attr -> decl
    guarded: dict[str, str] = field(default_factory=dict)          # field -> lock attr
    methods: dict[str, FunctionInfo] = field(default_factory=dict)  # top-level defs only


@dataclass
class ModuleInfo:
    name: str                    # dotted, e.g. "repro.core.transfer"
    path: Path
    skipped: bool = False
    classes: dict[str, ClassInfo] = field(default_factory=dict)
    functions: dict[str, FunctionInfo] = field(default_factory=dict)
    module_locks: dict[str, LockDecl] = field(default_factory=dict)
    imports: dict[str, str] = field(default_factory=dict)          # local name -> dotted origin
    waivers: dict[int, str] = field(default_factory=dict)          # line -> reason
    annotation_errors: list[tuple[int, str]] = field(default_factory=list)

    @property
    def basename(self) -> str:
        return self.name.rsplit(".", 1)[-1]


@dataclass
class PackageModel:
    modules: dict[str, ModuleInfo] = field(default_factory=dict)

    def all_functions(self):
        for mod in self.modules.values():
            yield from mod.functions.values()

    def all_classes(self):
        for mod in self.modules.values():
            yield from mod.classes.values()


# ---------------------------------------------------------------------------
# comment scanning


def _scan_comments(source: str):
    """Per-line annotation maps. Line numbers are 1-based, matching ast.
    ``pure`` holds lines that are comment-only: the "annotation on the line
    above" convention only applies to those, so a *trailing* comment never
    leaks onto the next statement."""
    guarded: dict[int, str] = {}
    requires: dict[int, tuple[str, ...]] = {}
    waivers: dict[int, str] = {}
    pure: set[int] = set()
    for i, text in enumerate(source.splitlines(), start=1):
        if "#" not in text:
            continue
        if text.lstrip().startswith("#"):
            pure.add(i)
        m = GUARDED_RE.search(text)
        if m:
            guarded[i] = m.group(1)
        m = REQUIRES_RE.search(text)
        if m:
            requires[i] = tuple(s.strip() for s in m.group(1).split(","))
        m = WAIVER_RE.search(text)
        if m:
            waivers[i] = (m.group("reason") or "").strip()
    return guarded, requires, waivers, pure


def _lock_kind_of_call(node: ast.expr) -> str | None:
    """Return lock kind if *node* is a lock-constructing call, else None."""
    if not isinstance(node, ast.Call):
        return None
    fn = node.func
    if isinstance(fn, ast.Attribute) and fn.attr in _LOCK_KINDS:
        return _LOCK_KINDS[fn.attr]
    if isinstance(fn, ast.Name) and fn.id in _LOCK_KINDS:
        return _LOCK_KINDS[fn.id]
    return None


def _dotted(expr: ast.expr) -> str | None:
    if isinstance(expr, ast.Name):
        return expr.id
    if isinstance(expr, ast.Attribute):
        base = _dotted(expr.value)
        return f"{base}.{expr.attr}" if base else f"?.{expr.attr}"
    return None


# ---------------------------------------------------------------------------
# function body walker


class _FnWalker:
    """Walks one function body tracking the statically-held lock set."""

    def __init__(self, mod: ModuleInfo, cls: ClassInfo | None, info: FunctionInfo,
                 local_locks: dict[str, str], requires_map: dict[int, tuple[str, ...]],
                 pure: set[int], out: list[FunctionInfo]):
        self.mod = mod
        self.cls = cls
        self.info = info
        self.local_locks = dict(local_locks)   # local var name -> lock id (closure-visible)
        self.requires_map = requires_map
        self.pure = pure
        self.out = out

    # -- lock expression resolution --------------------------------------

    def lock_for_expr(self, expr: ast.expr) -> str | None:
        if (isinstance(expr, ast.Attribute) and isinstance(expr.value, ast.Name)
                and expr.value.id in ("self", "cls") and self.cls is not None):
            decl = self.cls.locks.get(expr.attr)
            if decl is not None:
                return decl.id
        if isinstance(expr, ast.Name):
            if expr.id in self.local_locks:
                return self.local_locks[expr.id]
            decl = self.mod.module_locks.get(expr.id)
            if decl is not None:
                return decl.id
        return None

    # -- statements -------------------------------------------------------

    def walk_body(self, stmts: list[ast.stmt], held: tuple[str, ...]):
        sticky: tuple[str, ...] = ()
        for st in stmts:
            h = held + tuple(l for l in sticky if l not in held)
            sticky += self.walk_stmt(st, h)

    def walk_stmt(self, st: ast.stmt, held: tuple[str, ...]) -> tuple[str, ...]:
        """Process one statement; returns locks sticky-acquired by it."""
        if isinstance(st, (ast.FunctionDef, ast.AsyncFunctionDef)):
            self.walk_nested_def(st)
            return ()
        if isinstance(st, ast.ClassDef):
            for sub in st.body:
                if isinstance(sub, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    self.walk_nested_def(sub)
            return ()
        if isinstance(st, ast.With):
            h = held
            for item in st.items:
                lid = self.lock_for_expr(item.context_expr)
                self.visit_expr(item.context_expr, h)
                if lid is not None:
                    self.info.acquires.append(AcquireSite(lid, item.context_expr.lineno, h))
                    if lid not in h:
                        h = h + (lid,)
            self.walk_body(st.body, h)
            return ()
        if isinstance(st, ast.If):
            s = self.visit_expr(st.test, held)
            h = held + tuple(l for l in s if l not in held)
            self.walk_body(st.body, h)
            self.walk_body(st.orelse, h)
            return s
        if isinstance(st, ast.While):
            s = self.visit_expr(st.test, held)
            h = held + tuple(l for l in s if l not in held)
            self.walk_body(st.body, h)
            self.walk_body(st.orelse, h)
            return s
        if isinstance(st, ast.For):
            s = self.visit_expr(st.iter, held)
            self.visit_expr(st.target, held)
            h = held + tuple(l for l in s if l not in held)
            self.walk_body(st.body, h)
            self.walk_body(st.orelse, h)
            return s
        if isinstance(st, ast.Try):
            self.walk_body(st.body, held)
            for handler in st.handlers:
                self.walk_body(handler.body, held)
            self.walk_body(st.orelse, held)
            self.walk_body(st.finalbody, held)
            return ()
        if isinstance(st, (ast.Assign, ast.AugAssign, ast.AnnAssign)):
            sticky: tuple[str, ...] = ()
            # local lock creation: name = threading.Lock()
            value = st.value
            if value is not None:
                kind = _lock_kind_of_call(value)
                targets = st.targets if isinstance(st, ast.Assign) else [st.target]
                if kind is not None:
                    for t in targets:
                        if isinstance(t, ast.Name):
                            self.local_locks[t.id] = (
                                f"{self.info.qualname}.<local>.{t.id}")
                sticky = self.visit_expr(value, held)
            for t in (st.targets if isinstance(st, ast.Assign) else [st.target]):
                self.visit_expr(t, held)
            return sticky
        # generic: visit all child expressions
        sticky = ()
        for child in ast.iter_child_nodes(st):
            if isinstance(child, ast.expr):
                sticky += self.visit_expr(child, held)
        return sticky

    def walk_nested_def(self, fndef: ast.FunctionDef | ast.AsyncFunctionDef):
        qual = f"{self.info.qualname}.<locals>.{fndef.name}"
        requires = _requires_for(fndef, self.requires_map, self.pure)
        info = FunctionInfo(qual, self.mod.name, self.cls.name if self.cls else None,
                            fndef.name, fndef.lineno)
        sub = _FnWalker(self.mod, self.cls, info, self.local_locks,
                        self.requires_map, self.pure, self.out)
        held0 = sub.resolve_requires(requires, fndef.lineno)
        info.requires = held0
        # closures run later, typically on other threads: empty held set
        sub.walk_body(fndef.body, held0)
        self.out.append(info)

    def resolve_requires(self, names: tuple[str, ...], line: int) -> tuple[str, ...]:
        ids = []
        for n in names:
            lid = None
            if self.cls is not None and n in self.cls.locks:
                lid = self.cls.locks[n].id
            elif n in self.mod.module_locks:
                lid = self.mod.module_locks[n].id
            if lid is None:
                self.mod.annotation_errors.append(
                    (line, f"requires-lock names unknown lock {n!r}"))
            else:
                ids.append(lid)
        return tuple(ids)

    # -- expressions -------------------------------------------------------

    def visit_expr(self, expr: ast.expr, held: tuple[str, ...]) -> tuple[str, ...]:
        """Record accesses/calls; returns sticky-acquired lock ids."""
        sticky: tuple[str, ...] = ()
        if isinstance(expr, ast.Attribute):
            if isinstance(expr.value, ast.Name) and expr.value.id == "self":
                self.info.accesses.append(Access(
                    expr.attr, expr.lineno,
                    isinstance(expr.ctx, (ast.Store, ast.Del)), held))
            sticky += self.visit_expr(expr.value, held)
            return sticky
        if isinstance(expr, ast.Call):
            name = _dotted(expr.func) or "?"
            last = expr.func.attr if isinstance(expr.func, ast.Attribute) else name
            if isinstance(expr.func, ast.Attribute):
                base = expr.func.value
                if isinstance(base, ast.Name) and base.id in ("self", "cls"):
                    receiver = "self"
                else:
                    receiver = "other"
                receiver_lock = self.lock_for_expr(base)
            else:
                receiver = "bare"
                receiver_lock = None
            self.info.calls.append(CallSite(name, last, receiver, expr.lineno,
                                            held, receiver_lock))
            # sticky lock acquisition: <lockexpr>.acquire(...)
            if (last == "acquire" and isinstance(expr.func, ast.Attribute)):
                lid = self.lock_for_expr(expr.func.value)
                if lid is not None:
                    self.info.acquires.append(AcquireSite(lid, expr.lineno, held))
                    sticky += (lid,)
            sticky += self.visit_expr(expr.func, held)
            for a in expr.args:
                sticky += self.visit_expr(a, held)
            for kw in expr.keywords:
                sticky += self.visit_expr(kw.value, held)
            return sticky
        if isinstance(expr, ast.Lambda):
            # lambdas overwhelmingly execute in place here: inherit held set
            self.visit_expr(expr.body, held)
            for d in expr.args.defaults + expr.args.kw_defaults:
                if d is not None:
                    self.visit_expr(d, held)
            return ()
        if isinstance(expr, (ast.ListComp, ast.SetComp, ast.GeneratorExp, ast.DictComp)):
            for gen in expr.generators:
                self.visit_expr(gen.iter, held)
                self.visit_expr(gen.target, held)
                for cond in gen.ifs:
                    self.visit_expr(cond, held)
            if isinstance(expr, ast.DictComp):
                self.visit_expr(expr.key, held)
                self.visit_expr(expr.value, held)
            else:
                self.visit_expr(expr.elt, held)
            return ()
        for child in ast.iter_child_nodes(expr):
            if isinstance(child, ast.expr):
                sticky += self.visit_expr(child, held)
        return sticky


def _annotation_at(table: dict, line: int, pure: set[int]):
    """Annotation on *line* itself, or on a comment-only line above it."""
    if line in table:
        return table[line]
    if line - 1 in table and line - 1 in pure:
        return table[line - 1]
    return None


def _requires_for(fndef, requires_map, pure) -> tuple[str, ...]:
    return _annotation_at(requires_map, fndef.lineno, pure) or ()


# ---------------------------------------------------------------------------
# module extraction


def _collect_class_locks(mod: ModuleInfo, cls: ast.ClassDef,
                         guarded_at: dict[int, str], pure: set[int]) -> ClassInfo:
    info = ClassInfo(cls.name, mod.name, cls.lineno)
    for node in cls.body:
        # dataclass-style: `_lock: threading.Lock = None  # placeholder`
        if isinstance(node, ast.AnnAssign) and isinstance(node.target, ast.Name):
            ann = _annotation_at(guarded_at, node.lineno, pure)
            if ann is not None:
                info.guarded[node.target.id] = ann
        if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        for st in ast.walk(node):
            if not isinstance(st, ast.Assign):
                continue
            kind = _lock_kind_of_call(st.value)
            for t in st.targets:
                if (isinstance(t, ast.Attribute) and isinstance(t.value, ast.Name)
                        and t.value.id == "self"):
                    if kind is not None:
                        info.locks.setdefault(t.attr, LockDecl(
                            f"{cls.name}.{t.attr}", kind, mod.name, st.lineno))
                    # a multi-line assignment may carry the annotation on any
                    # of its physical lines (value ends on end_lineno)
                    ann = None
                    for line in range(st.lineno, (st.end_lineno or st.lineno) + 1):
                        if line in guarded_at:
                            ann = guarded_at[line]
                            break
                    if ann is None:
                        ann = _annotation_at(guarded_at, st.lineno, pure)
                    if ann is not None:
                        info.guarded[t.attr] = ann
    return info


def extract_module(source: str, modname: str, path: Path | str = "<memory>") -> ModuleInfo:
    mod = ModuleInfo(modname, Path(path))
    if SKIP_RE.search(source):
        mod.skipped = True
        return mod
    tree = ast.parse(source)
    guarded_at, requires_at, waivers, pure = _scan_comments(source)
    mod.waivers = waivers

    for node in tree.body:
        if isinstance(node, ast.Import):
            for alias in node.names:
                mod.imports[alias.asname or alias.name.split(".")[0]] = alias.name
        elif isinstance(node, ast.ImportFrom) and node.module and node.level == 0:
            for alias in node.names:
                mod.imports[alias.asname or alias.name] = f"{node.module}.{alias.name}"
        elif isinstance(node, ast.Assign):
            kind = _lock_kind_of_call(node.value)
            if kind is not None:
                for t in node.targets:
                    if isinstance(t, ast.Name):
                        mod.module_locks[t.id] = LockDecl(
                            f"{mod.basename}.{t.id}", kind, mod.name, node.lineno)

    # classes first (lock attrs must be known before walking bodies)
    for node in tree.body:
        if isinstance(node, ast.ClassDef):
            mod.classes[node.name] = _collect_class_locks(mod, node, guarded_at, pure)

    # validate guarded-by lock names
    for cls in mod.classes.values():
        for fld, lockattr in list(cls.guarded.items()):
            if lockattr not in cls.locks:
                mod.annotation_errors.append(
                    (cls.line, f"{cls.name}.{fld}: guarded-by names unknown "
                               f"lock {lockattr!r}"))
                del cls.guarded[fld]

    out: list[FunctionInfo] = []
    for node in tree.body:
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            info = FunctionInfo(f"{mod.name}:{node.name}", mod.name, None,
                                node.name, node.lineno)
            w = _FnWalker(mod, None, info, {}, requires_at, pure, out)
            held0 = w.resolve_requires(_requires_for(node, requires_at, pure),
                                       node.lineno)
            info.requires = held0
            w.walk_body(node.body, held0)
            out.append(info)
        elif isinstance(node, ast.ClassDef):
            cinfo = mod.classes[node.name]
            for sub in node.body:
                if not isinstance(sub, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    continue
                info = FunctionInfo(f"{mod.name}:{node.name}.{sub.name}", mod.name,
                                    node.name, sub.name, sub.lineno)
                w = _FnWalker(mod, cinfo, info, {}, requires_at, pure, out)
                held0 = w.resolve_requires(_requires_for(sub, requires_at, pure),
                                           sub.lineno)
                info.requires = held0
                w.walk_body(sub.body, held0)
                out.append(info)
                cinfo.methods[sub.name] = info

    for fn in out:
        mod.functions[fn.qualname] = fn
    return mod


def extract_package(root: Path, package: str = "repro",
                    exclude: tuple[str, ...] = ("repro/analysis",)) -> PackageModel:
    """Extract every module under *root* (the directory containing the package)."""
    pkg = PackageModel()
    pkg_dir = root / package
    for path in sorted(pkg_dir.rglob("*.py")):
        rel = path.relative_to(root)
        if any(str(rel).startswith(e) for e in exclude):
            continue
        modname = ".".join(rel.with_suffix("").parts)
        if modname.endswith(".__init__"):
            modname = modname[: -len(".__init__")]
        source = path.read_text()
        pkg.modules[modname] = extract_module(source, modname, path)
    return pkg
