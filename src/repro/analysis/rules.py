"""The concurrency rules: lock-order, guarded-by, blocking-under-lock,
requires-lock call-site checking, plus annotation hygiene.

All rules consume the :class:`~repro.analysis.model.PackageModel` and emit
:class:`Finding`s. ``# lock-ok:`` waivers (matched by line) suppress findings
at their anchor line; waived blocking sites also do not propagate through the
transitive call-graph (an accepted block is accepted everywhere).

Call resolution is name-based and deliberately conservative:

- ``self.method()`` resolves to the same class only;
- bare calls resolve to same-module functions, then package entities through
  the import map (constructors resolve to ``__init__``);
- ``obj.method()`` on an unknown receiver unions over every package class
  method of that name, *except* names in :data:`DENY_METHOD_NAMES` (common
  container/threading vocabulary like ``get``/``append``/``wait`` whose union
  would drown the graph in false edges).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from .model import FunctionInfo, PackageModel

RULES = ("lock-order", "guarded-by", "blocking", "requires-lock", "annotation")

# method names never union-resolved across classes (builtin container /
# threading / numpy vocabulary — a name match carries no signal)
DENY_METHOD_NAMES = {
    "get", "set", "add", "pop", "popleft", "append", "appendleft", "extend",
    "remove", "discard", "clear", "update", "items", "keys", "values", "copy",
    "sort", "sorted", "index", "count", "insert", "reverse", "setdefault",
    "join", "split", "strip", "startswith", "endswith", "format", "encode",
    "decode", "read", "write", "flush", "seek", "tell", "acquire", "release",
    "locked", "notify", "notify_all", "wait", "wait_for", "put", "put_nowait",
    "get_nowait", "empty", "qsize", "full", "task_done", "start", "run",
    "is_alive", "is_set", "mean", "std", "min", "max", "sum", "item",
    "tolist", "astype", "reshape", "result", "done", "total_seconds",
    # reporting vocabulary: 6+ unrelated classes define summary()
    "summary",
}

BLOCKING_DOTTED = {"time.sleep", "jax.device_put", "jax.device_get"}
BLOCKING_LAST = {"wait", "wait_for", "tx", "rx", "tx_async", "rx_async",
                 "block_until_ready"}


@dataclass(frozen=True)
class Finding:
    rule: str
    module: str
    path: str
    line: int
    context: str          # function/class qualname the finding anchors to
    message: str
    key: str              # line-number-free fingerprint component
    waived: bool = False
    waiver: str = ""

    @property
    def fingerprint(self) -> str:
        return f"{self.rule}:{self.module}:{self.context}:{self.key}"

    def render(self) -> str:
        tag = " (waived)" if self.waived else ""
        return f"{self.path}:{self.line}: [{self.rule}]{tag} {self.message}"


@dataclass
class _Index:
    methods_by_name: dict[str, list[FunctionInfo]] = field(default_factory=dict)
    funcs_by_qual: dict[str, FunctionInfo] = field(default_factory=dict)
    class_init: dict[str, FunctionInfo] = field(default_factory=dict)  # "mod.Cls" -> __init__
    lock_kinds: dict[str, str] = field(default_factory=dict)           # lock id -> kind


def _build_index(pkg: PackageModel) -> _Index:
    idx = _Index()
    for mod in pkg.modules.values():
        for decl in mod.module_locks.values():
            idx.lock_kinds[decl.id] = decl.kind
        for cls in mod.classes.values():
            for decl in cls.locks.values():
                idx.lock_kinds[decl.id] = decl.kind
            for name, fn in cls.methods.items():
                idx.methods_by_name.setdefault(name, []).append(fn)
                if name == "__init__":
                    idx.class_init[f"{mod.name}.{cls.name}"] = fn
        for fn in mod.functions.values():
            idx.funcs_by_qual[fn.qualname] = fn
    return idx


def _resolve(call, fn: FunctionInfo, pkg: PackageModel, idx: _Index):
    """Best-effort candidate callees for a call site."""
    mod = pkg.modules[fn.module]
    if call.receiver == "self":
        if fn.class_name is None:
            return []
        cls = mod.classes.get(fn.class_name)
        if cls is None:
            return []
        target = cls.methods.get(call.last)
        return [target] if target is not None else []
    if call.receiver == "bare":
        local = mod.functions.get(f"{mod.name}:{call.last}")
        if local is not None and local.class_name is None:
            return [local]
        cls = mod.classes.get(call.last)
        if cls is not None:
            init = cls.methods.get("__init__")
            return [init] if init is not None else []
        origin = mod.imports.get(call.last)
        if origin is not None:
            omod, _, oname = origin.rpartition(".")
            target_mod = pkg.modules.get(omod)
            if target_mod is not None:
                f = target_mod.functions.get(f"{omod}:{oname}")
                if f is not None and f.class_name is None:
                    return [f]
                init = idx.class_init.get(origin)
                if init is not None:
                    return [init]
        return []
    # receiver "other": module-alias call or union-by-name
    head = call.name.split(".", 1)[0]
    if head in mod.imports:
        origin = mod.imports[head]
        target_mod = pkg.modules.get(origin)
        if target_mod is not None:
            f = target_mod.functions.get(f"{origin}:{call.last}")
            return [f] if f is not None and f.class_name is None else []
        return []  # external module (time, jax, np, ...)
    if call.last in DENY_METHOD_NAMES:
        return []
    return idx.methods_by_name.get(call.last, [])


def _is_waived(mod, line: int) -> tuple[bool, str]:
    if line in mod.waivers:
        return True, mod.waivers[line]
    return False, ""


def _mk(pkg, rule, fn, line, message, key) -> Finding:
    mod = pkg.modules[fn.module]
    waived, reason = _is_waived(mod, line)
    return Finding(rule, fn.module, str(mod.path), line, fn.qualname,
                   message, key, waived, reason)


# ---------------------------------------------------------------------------
# transitive summaries


def _eventually_acquires(fn, pkg, idx, memo, active) -> dict[str, tuple]:
    """lock id -> example chain [(qualname, line), ...] leading to acquisition."""
    if fn.qualname in memo:
        return memo[fn.qualname]
    if fn.qualname in active:
        return {}
    active.add(fn.qualname)
    result: dict[str, tuple] = {}
    for acq in fn.acquires:
        result.setdefault(acq.lock_id, ((fn.qualname, acq.line),))
    for call in fn.calls:
        for callee in _resolve(call, fn, pkg, idx):
            sub = _eventually_acquires(callee, pkg, idx, memo, active)
            for lock_id, chain in sub.items():
                result.setdefault(lock_id, ((fn.qualname, call.line),) + chain)
    active.discard(fn.qualname)
    memo[fn.qualname] = result
    return result


def _blocking_sites(fn, pkg) -> list:
    """Direct blocking calls in *fn*, with the cond-wait exemption applied.
    Waived sites are excluded (accepted blocks don't propagate)."""
    mod = pkg.modules[fn.module]
    out = []
    for call in fn.calls:
        blocked = None
        if call.name in BLOCKING_DOTTED or call.last in BLOCKING_DOTTED:
            blocked = call.name
        elif call.last in BLOCKING_LAST:
            # waiting on a lock/condition you hold releases it: sanctioned
            if call.receiver_lock is not None and call.receiver_lock in call.held:
                continue
            blocked = call.name
        if blocked is None:
            continue
        if call.line in mod.waivers:
            continue
        out.append((call, blocked))
    return out


def _has_blocking(fn, pkg, idx, memo, active) -> tuple | None:
    """Example chain to a blocking call reachable from *fn*, or None."""
    if fn.qualname in memo:
        return memo[fn.qualname]
    if fn.qualname in active:
        return None
    active.add(fn.qualname)
    result = None
    mod = pkg.modules[fn.module]
    sites = _blocking_sites(fn, pkg)
    if sites:
        call, blocked = sites[0]
        result = ((fn.qualname, call.line, blocked),)
    else:
        for call in fn.calls:
            if call.line in mod.waivers:  # accepted sites don't propagate
                continue
            for callee in _resolve(call, fn, pkg, idx):
                sub = _has_blocking(callee, pkg, idx, memo, active)
                if sub is not None:
                    result = ((fn.qualname, call.line, call.name),) + sub
                    break
            if result is not None:
                break
    active.discard(fn.qualname)
    memo[fn.qualname] = result
    return result


# ---------------------------------------------------------------------------
# rules


def check_lock_order(pkg: PackageModel, idx: _Index) -> list[Finding]:
    # edge (held, acquired) -> (fn, line, example chain)
    edges: dict[tuple[str, str], tuple] = {}
    memo: dict = {}
    for fn in pkg.all_functions():
        mod = pkg.modules[fn.module]
        for acq in fn.acquires:
            if acq.line in mod.waivers:
                continue
            for h in acq.held:
                if h != acq.lock_id:
                    edges.setdefault((h, acq.lock_id), (fn, acq.line, ()))
        for call in fn.calls:
            if not call.held or call.line in mod.waivers:
                continue
            for callee in _resolve(call, fn, pkg, idx):
                acquired = _eventually_acquires(callee, pkg, idx, memo, set())
                for lock_id, chain in acquired.items():
                    for h in call.held:
                        if h != lock_id:
                            edges.setdefault((h, lock_id), (fn, call.line, chain))

    # SCCs over the lock graph (iterative Tarjan)
    graph: dict[str, list[str]] = {}
    for (a, b) in edges:
        graph.setdefault(a, []).append(b)
        graph.setdefault(b, [])
    index_of: dict[str, int] = {}
    low: dict[str, int] = {}
    on_stack: set[str] = set()
    stack: list[str] = []
    sccs: list[list[str]] = []
    counter = [0]

    def strongconnect(root):
        work = [(root, iter(graph[root]))]
        index_of[root] = low[root] = counter[0]
        counter[0] += 1
        stack.append(root)
        on_stack.add(root)
        while work:
            v, it = work[-1]
            advanced = False
            for w in it:
                if w not in index_of:
                    index_of[w] = low[w] = counter[0]
                    counter[0] += 1
                    stack.append(w)
                    on_stack.add(w)
                    work.append((w, iter(graph[w])))
                    advanced = True
                    break
                elif w in on_stack:
                    low[v] = min(low[v], index_of[w])
            if advanced:
                continue
            work.pop()
            if work:
                pv = work[-1][0]
                low[pv] = min(low[pv], low[v])
            if low[v] == index_of[v]:
                scc = []
                while True:
                    w = stack.pop()
                    on_stack.discard(w)
                    scc.append(w)
                    if w == v:
                        break
                sccs.append(scc)

    for node in graph:
        if node not in index_of:
            strongconnect(node)

    findings = []
    for scc in sccs:
        if len(scc) < 2:
            continue
        members = sorted(scc)
        detail = []
        for (a, b), (fn, line, chain) in sorted(edges.items()):
            if a in scc and b in scc:
                via = "".join(f" -> {q}:{ln}" for q, ln, *_ in chain)
                detail.append(f"{a} -> {b} at {fn.qualname}:{line}{via}")
        fn0, line0, _ = edges[next((a, b) for (a, b) in sorted(edges)
                                   if a in scc and b in scc)]
        findings.append(_mk(pkg, "lock-order", fn0, line0,
                            "lock-order cycle between {%s}; edges: %s"
                            % (", ".join(members), "; ".join(detail)),
                            key="<->".join(members)))
    return findings


def check_guarded_by(pkg: PackageModel, idx: _Index) -> list[Finding]:
    findings = []
    exempt = {"__init__", "__post_init__", "__del__"}
    for mod in pkg.modules.values():
        for cls in mod.classes.values():
            if not cls.guarded:
                continue
            guard_ids = {f: cls.locks[a].id for f, a in cls.guarded.items()}
            for fn in mod.functions.values():
                if fn.class_name != cls.name or fn.name in exempt:
                    continue
                seen_lines = set()
                for acc in fn.accesses:
                    lock_id = guard_ids.get(acc.attr)
                    if lock_id is None or lock_id in acc.held:
                        continue
                    if (acc.attr, acc.line) in seen_lines:
                        continue
                    seen_lines.add((acc.attr, acc.line))
                    verb = "write to" if acc.write else "read of"
                    findings.append(_mk(
                        pkg, "guarded-by", fn, acc.line,
                        f"{verb} {cls.name}.{acc.attr} without holding "
                        f"{lock_id} (declared guarded-by {cls.guarded[acc.attr]})",
                        key=f"{cls.name}.{acc.attr}@{fn.name}"))
    return findings


def check_blocking(pkg: PackageModel, idx: _Index) -> list[Finding]:
    findings = []
    memo: dict = {}
    for fn in pkg.all_functions():
        mod = pkg.modules[fn.module]
        seen_lines = set()
        # direct blocking calls under a held lock (including waived ones,
        # reported as waived)
        for call in fn.calls:
            if not call.held:
                continue
            blocked = None
            if call.name in BLOCKING_DOTTED or call.last in BLOCKING_DOTTED:
                blocked = call.name
            elif call.last in BLOCKING_LAST:
                if call.receiver_lock is not None and call.receiver_lock in call.held:
                    continue
                blocked = call.name
            if blocked is None or call.line in seen_lines:
                continue
            seen_lines.add(call.line)
            findings.append(_mk(
                pkg, "blocking", fn, call.line,
                f"blocking call {blocked}() while holding "
                f"{{{', '.join(call.held)}}}", key=f"{blocked}@{fn.name}"))
        # transitive: calls under a lock reaching a blocking site
        for call in fn.calls:
            if not call.held or call.line in seen_lines:
                continue
            if call.line in mod.waivers:
                # surface as a waived finding so --show-waived lists it
                chain_hit = None
                for callee in _resolve(call, fn, pkg, idx):
                    chain_hit = _has_blocking(callee, pkg, idx, memo, set())
                    if chain_hit:
                        break
                if chain_hit:
                    seen_lines.add(call.line)
                    findings.append(_mk(
                        pkg, "blocking", fn, call.line,
                        f"call {call.name}() under {{{', '.join(call.held)}}} "
                        f"reaches blocking {chain_hit[-1][2]}()",
                        key=f"via-{call.last}@{fn.name}"))
                continue
            for callee in _resolve(call, fn, pkg, idx):
                chain = _has_blocking(callee, pkg, idx, memo, set())
                if chain is None:
                    continue
                seen_lines.add(call.line)
                via = " -> ".join(f"{q}:{ln}" for q, ln, _ in chain)
                findings.append(_mk(
                    pkg, "blocking", fn, call.line,
                    f"call {call.name}() under {{{', '.join(call.held)}}} "
                    f"reaches blocking {chain[-1][2]}() via {via}",
                    key=f"via-{call.last}@{fn.name}"))
                break
    return findings


def check_requires_lock(pkg: PackageModel, idx: _Index) -> list[Finding]:
    """Same-class call sites of `# requires-lock:` functions must hold it."""
    findings = []
    for fn in pkg.all_functions():
        if fn.class_name is None:
            continue
        mod = pkg.modules[fn.module]
        cls = mod.classes.get(fn.class_name)
        if cls is None:
            continue
        for call in fn.calls:
            if call.receiver != "self":
                continue
            callee = cls.methods.get(call.last)
            if callee is None or not callee.requires:
                continue
            missing = [l for l in callee.requires if l not in call.held]
            if not missing:
                continue
            findings.append(_mk(
                pkg, "requires-lock", fn, call.line,
                f"call to {callee.qualname} (requires-lock) without holding "
                f"{{{', '.join(missing)}}}", key=f"{call.last}@{fn.name}"))
    return findings


def check_annotations(pkg: PackageModel, idx: _Index) -> list[Finding]:
    findings = []
    for mod in pkg.modules.values():
        for line, msg in mod.annotation_errors:
            findings.append(Finding(
                "annotation", mod.name, str(mod.path), line, mod.name, msg,
                key=msg))
    return findings


_CHECKS = {
    "lock-order": check_lock_order,
    "guarded-by": check_guarded_by,
    "blocking": check_blocking,
    "requires-lock": check_requires_lock,
    "annotation": check_annotations,
}


def run_rules(pkg: PackageModel, rules=RULES) -> list[Finding]:
    idx = _build_index(pkg)
    findings: list[Finding] = []
    for rule in rules:
        findings.extend(_CHECKS[rule](pkg, idx))
    findings.sort(key=lambda f: (f.path, f.line, f.rule))
    return findings
