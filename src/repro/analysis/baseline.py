"""Baseline (grandfathered-findings) support.

Fingerprints are line-number free (rule:module:context:key), so moving code
around does not churn the baseline — only genuinely new violations fail
``--fail-on-new``. The checked-in baseline should stay empty: deliberate
sites get inline ``# lock-ok:`` waivers instead, so the reason lives next to
the code. The baseline exists for incremental adoption (e.g. annotating a
new module with pre-existing debt).
"""
from __future__ import annotations

import json
from pathlib import Path

from .rules import Finding

_VERSION = 1


def load_baseline(path: str | Path) -> set[str]:
    data = json.loads(Path(path).read_text())
    if data.get("version") != _VERSION:
        raise ValueError(f"unsupported baseline version {data.get('version')!r}")
    return set(data.get("fingerprints", []))


def write_baseline(path: str | Path, findings: list[Finding]) -> None:
    fps = sorted({f.fingerprint for f in findings if not f.waived})
    Path(path).write_text(json.dumps(
        {"version": _VERSION, "fingerprints": fps}, indent=2) + "\n")


def split_new(findings: list[Finding], baseline: set[str]):
    """(new, grandfathered) — waived findings are never 'new'."""
    new, old = [], []
    for f in findings:
        if f.waived:
            continue
        (old if f.fingerprint in baseline else new).append(f)
    return new, old
