"""Opt-in runtime lock validation: the dynamic half of the static analyzer.

``make_lock`` / ``make_rlock`` / ``make_condition`` are drop-in factories the
core modules use instead of bare ``threading.Lock()`` etc. In production they
return the plain threading primitive (zero overhead). When
``REPRO_VALIDATE_LOCKS=1`` (or after :func:`enable`), they return a
:class:`ValidatedLock` that:

- records every (held -> acquired) pair into a process-global order graph and
  raises :class:`LockOrderViolation` the moment a real acquisition would
  close a cycle — the dynamic evidence backing the static lock-order rule;
- tracks the per-thread held stack so ``assert_held`` can verify
  ``# requires-lock:`` contracts at runtime (guarded-by access from the
  declared owner).

The stress CI lane exports the flag, so every stress run doubles as a
lock-discipline check. Only the stdlib is imported here: ``repro.core``
modules import this without creating an import cycle.
"""
from __future__ import annotations

import os
import threading

_FLAG = "REPRO_VALIDATE_LOCKS"
_forced: bool | None = None


def enabled() -> bool:
    if _forced is not None:
        return _forced
    return os.environ.get(_FLAG, "") not in ("", "0")


def enable(on: bool = True) -> None:
    """Programmatic override (tests); ``enable(None)`` restores env control."""
    global _forced
    _forced = on


class LockOrderViolation(RuntimeError):
    """A real acquisition closed a cycle in the observed lock-order graph."""


class LockAssertionError(RuntimeError):
    """A requires-lock function ran without its declared lock held."""


_tls = threading.local()


def _held() -> list[str]:
    held = getattr(_tls, "held", None)
    if held is None:
        held = _tls.held = []
    return held


class _OrderGraph:
    """Process-global observed lock-order graph. Leaf lock: nothing else is
    ever acquired while ``_mu`` is held."""

    def __init__(self):
        self._mu = threading.Lock()
        self._edges: dict[str, set[str]] = {}
        self.violations: list[str] = []

    def reset(self) -> None:
        with self._mu:
            self._edges.clear()
            self.violations.clear()

    def edges(self) -> dict[str, set[str]]:
        with self._mu:
            return {k: set(v) for k, v in self._edges.items()}

    def on_acquire(self, held: list[str], name: str) -> None:
        if not held:
            return
        with self._mu:
            new_edge = False
            for h in held:
                if h == name:
                    continue
                succ = self._edges.setdefault(h, set())
                if name not in succ:
                    succ.add(name)
                    new_edge = True
            if not new_edge:
                return
            # a cycle exists iff `name` now reaches one of the held locks
            targets = set(held) - {name}
            path = self._find_path(name, targets)
            if path is not None:
                msg = (f"lock-order inversion: acquiring {name} while holding "
                       f"{held}; prior order {' -> '.join(path)}")
                self.violations.append(msg)
                raise LockOrderViolation(msg)

    def _find_path(self, start: str, targets: set[str]) -> list[str] | None:
        stack = [(start, [start])]
        seen = {start}
        while stack:
            node, path = stack.pop()
            for nxt in self._edges.get(node, ()):
                if nxt in targets:
                    return path + [nxt]
                if nxt not in seen:
                    seen.add(nxt)
                    stack.append((nxt, path + [nxt]))
        return None


order_graph = _OrderGraph()


class ValidatedLock:
    """Lock wrapper recording per-thread acquisition order.

    Works as the backing lock of a ``threading.Condition`` (only ``acquire``
    and ``release`` are required; the Condition fallbacks handle the rest).
    """

    def __init__(self, name: str, factory=threading.Lock, reentrant: bool = False):
        self._name = name
        self._reentrant = reentrant
        self._inner = factory()

    @property
    def name(self) -> str:
        return self._name

    def acquire(self, blocking: bool = True, timeout: float = -1) -> bool:
        held = _held()
        if not (self._reentrant and self._name in held):
            order_graph.on_acquire(held, self._name)
        got = self._inner.acquire(blocking, timeout)
        if got:
            held.append(self._name)
        return got

    def release(self) -> None:
        held = _held()
        # remove the most recent occurrence (reentrant locks stack)
        for i in range(len(held) - 1, -1, -1):
            if held[i] == self._name:
                del held[i]
                break
        self._inner.release()

    def locked(self) -> bool:
        return self._inner.locked()

    def __enter__(self):
        self.acquire()
        return self

    def __exit__(self, *exc):
        self.release()
        return False


def make_lock(name: str):
    return ValidatedLock(name) if enabled() else threading.Lock()


def make_rlock(name: str):
    return (ValidatedLock(name, factory=threading.RLock, reentrant=True)
            if enabled() else threading.RLock())


def make_condition(name: str):
    return (threading.Condition(ValidatedLock(name))
            if enabled() else threading.Condition())


def held_names() -> tuple[str, ...]:
    return tuple(_held())


def assert_held(lock, what: str = "") -> None:
    """Runtime check for ``# requires-lock:`` functions. No-op unless
    validation is enabled AND the lock is a validated primitive."""
    if not enabled():
        return
    inner = getattr(lock, "_lock", lock)  # unwrap Condition
    if not isinstance(inner, ValidatedLock):
        return
    if inner.name not in _held():
        raise LockAssertionError(
            f"{what or 'caller'} requires {inner.name} but this thread holds "
            f"{_held() or 'no locks'}")
