"""Model zoo: composable JAX definitions for the 10 assigned architectures
plus the paper's own RoShamBo CNN (see repro.accel)."""

from repro.models.config import ModelConfig  # noqa: F401
from repro.models.api import build_model  # noqa: F401
