"""Encoder-decoder transformer (seamless-m4t backbone).

The audio frontend is a STUB per the assignment: ``input_specs`` supplies
precomputed frame embeddings [B, S_enc, D]; the encoder is a bidirectional
transformer over them; the decoder is a standard autoregressive stack with
cross-attention. Both stacks are scan-stacked like repro.models.lm.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers.attention import KVCache, attn_apply, attn_params
from repro.models.layers.mlp import mlp_apply, mlp_params
from repro.models.layers.norm import apply_norm, norm_params
from repro.models.lm import make_remat


def _dt(cfg):
    return jnp.dtype(cfg.dtype)


def init_enc_block(key, cfg: ModelConfig) -> dict:
    k1, k2 = jax.random.split(key)
    return {
        "ln1": norm_params(cfg.norm, cfg.d_model),
        "attn": attn_params(k1, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                            cfg.head_dim_, bias=cfg.qkv_bias, dtype=_dt(cfg)),
        "ln2": norm_params(cfg.norm, cfg.d_model),
        "mlp": mlp_params(k2, cfg.d_model, cfg.d_ff, cfg.mlp, _dt(cfg)),
    }


def init_dec_block(key, cfg: ModelConfig) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "ln1": norm_params(cfg.norm, cfg.d_model),
        "attn": attn_params(k1, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                            cfg.head_dim_, bias=cfg.qkv_bias, dtype=_dt(cfg)),
        "ln_x": norm_params(cfg.norm, cfg.d_model),
        "xattn": attn_params(k2, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                             cfg.head_dim_, bias=cfg.qkv_bias, dtype=_dt(cfg)),
        "ln2": norm_params(cfg.norm, cfg.d_model),
        "mlp": mlp_params(k3, cfg.d_model, cfg.d_ff, cfg.mlp, _dt(cfg)),
    }


def init_params(key, cfg: ModelConfig) -> dict:
    ke, kd, kemb, kh = jax.random.split(key, 4)
    enc = jax.vmap(lambda k: init_enc_block(k, cfg))(
        jax.random.split(ke, cfg.n_enc_layers))
    dec = jax.vmap(lambda k: init_dec_block(k, cfg))(
        jax.random.split(kd, cfg.n_layers))
    return {
        "embed": (jax.random.normal(kemb, (cfg.vocab_padded, cfg.d_model))
                  * (1.0 / math.sqrt(cfg.d_model))).astype(_dt(cfg)),
        "enc_blocks": enc,
        "dec_blocks": dec,
        "enc_norm": norm_params(cfg.norm, cfg.d_model),
        "final_norm": norm_params(cfg.norm, cfg.d_model),
        "lm_head": (jax.random.normal(kh, (cfg.d_model, cfg.vocab_padded))
                    * (1.0 / math.sqrt(cfg.d_model))).astype(_dt(cfg)),
    }


def encode(cfg: ModelConfig, params: dict, frames: jax.Array) -> jax.Array:
    """frames: [B, S_enc, D] (stub frontend output) -> encoder states."""

    def body(x, p):
        h, _ = attn_apply(p["attn"], apply_norm(cfg.norm, p["ln1"], x),
                          n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads,
                          head_dim=cfg.head_dim_, rope_theta=cfg.rope_theta,
                          kv_chunk=cfg.attn_kv_chunk,
                          blocks_threshold=cfg.attn_blocks_threshold,
                          causal=False)
        x = x + h
        x = x + mlp_apply(p["mlp"], apply_norm(cfg.norm, p["ln2"], x), cfg.mlp)
        return x, None

    fn = make_remat(cfg)(body)
    x, _ = jax.lax.scan(fn, frames.astype(_dt(cfg)), params["enc_blocks"])
    return apply_norm(cfg.norm, params["enc_norm"], x)


def _dec_block(cfg, p, x, enc, self_cache=None, cross_cache=None):
    h, new_self = attn_apply(p["attn"], apply_norm(cfg.norm, p["ln1"], x),
                             n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads,
                             head_dim=cfg.head_dim_, rope_theta=cfg.rope_theta,
                             kv_chunk=cfg.attn_kv_chunk,
                             blocks_threshold=cfg.attn_blocks_threshold,
                             cache=self_cache)
    x = x + h
    h, new_cross = attn_apply(p["xattn"], apply_norm(cfg.norm, p["ln_x"], x),
                              n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads,
                              head_dim=cfg.head_dim_, rope_theta=0.0,
                              kv_chunk=cfg.attn_kv_chunk,
                              blocks_threshold=cfg.attn_blocks_threshold,
                              xk=enc, cache=cross_cache, causal=False)
    x = x + h
    x = x + mlp_apply(p["mlp"], apply_norm(cfg.norm, p["ln2"], x), cfg.mlp)
    return x, new_self, new_cross


def forward(cfg: ModelConfig, params: dict, frames: jax.Array,
            tokens: jax.Array):
    """Training forward: logits over decoder positions, aux=0."""
    enc = encode(cfg, params, frames)
    x = params["embed"][tokens]

    def body(h, p):
        h, _, _ = _dec_block(cfg, p, h, enc)
        return h, None

    fn = make_remat(cfg)(body)
    x, _ = jax.lax.scan(fn, x, params["dec_blocks"])
    x = apply_norm(cfg.norm, params["final_norm"], x)
    logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"],
                        preferred_element_type=jnp.float32)
    return logits, jnp.zeros((), jnp.float32)


def init_dec_cache(cfg: ModelConfig, batch: int, s_max: int, s_enc: int):
    dt = _dt(cfg)
    def stack(c):
        return KVCache(
            jnp.broadcast_to(c.k[None], (cfg.n_layers,) + c.k.shape),
            jnp.broadcast_to(c.v[None], (cfg.n_layers,) + c.v.shape),
            jnp.zeros((cfg.n_layers,), jnp.int32),
        )
    return {
        "self": stack(KVCache.zeros(batch, s_max, cfg.n_kv_heads, cfg.head_dim_, dt)),
        "cross": stack(KVCache.zeros(batch, s_enc, cfg.n_kv_heads, cfg.head_dim_, dt)),
    }


def prefill(cfg: ModelConfig, params: dict, frames: jax.Array,
            tokens: jax.Array, s_max: int):
    """Encode + run decoder prompt, building self- and cross-caches.

    The cross-cache stores projected encoder K/V once (computed per layer
    during this pass) so decode steps never re-project encoder states."""
    enc = encode(cfg, params, frames)
    x = params["embed"][tokens]
    caches = init_dec_cache(cfg, x.shape[0], s_max, enc.shape[1])

    def body(h, inp):
        p, sc, cc = inp
        # first pass populates the cross cache: project enc k/v at length 0
        cc_filled = _fill_cross(cfg, p, enc, cc)
        h, new_self, _ = _dec_block(cfg, p, h, enc, self_cache=sc,
                                    cross_cache=cc_filled)
        return h, (new_self, cc_filled)

    fn = make_remat(cfg)(body)
    x, (new_self, new_cross) = jax.lax.scan(
        fn, x, (params["dec_blocks"], caches["self"], caches["cross"]))
    x = apply_norm(cfg.norm, params["final_norm"], x[:, -1:])
    logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"],
                        preferred_element_type=jnp.float32)
    return logits, {"self": new_self, "cross": new_cross}


def _fill_cross(cfg, p, enc, cc: KVCache) -> KVCache:
    b, s_enc, _ = enc.shape
    k = (enc @ p["xattn"]["wk"] + p["xattn"].get("bk", 0)).reshape(
        b, s_enc, cfg.n_kv_heads, cfg.head_dim_)
    v = (enc @ p["xattn"]["wv"] + p["xattn"].get("bv", 0)).reshape(
        b, s_enc, cfg.n_kv_heads, cfg.head_dim_)
    return KVCache(k.astype(cc.k.dtype), v.astype(cc.v.dtype),
                   jnp.asarray(s_enc, jnp.int32))


def decode_step(cfg: ModelConfig, params: dict, token: jax.Array, caches):
    """One decoder token against prebuilt self/cross caches."""
    x = params["embed"][token]

    def body(h, inp):
        p, sc, cc = inp
        h2, new_self, _ = _dec_block_cached(cfg, p, h, sc, cc)
        return h2, new_self

    x, new_self = jax.lax.scan(body, x, (params["dec_blocks"], caches["self"],
                                         caches["cross"]))
    x = apply_norm(cfg.norm, params["final_norm"], x)
    logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"],
                        preferred_element_type=jnp.float32)
    return logits, {"self": new_self, "cross": caches["cross"]}


def _dec_block_cached(cfg, p, x, self_cache: KVCache, cross_cache: KVCache):
    h, new_self = attn_apply(p["attn"], apply_norm(cfg.norm, p["ln1"], x),
                             n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads,
                             head_dim=cfg.head_dim_, rope_theta=cfg.rope_theta,
                             kv_chunk=cfg.attn_kv_chunk,
                             blocks_threshold=cfg.attn_blocks_threshold,
                             cache=self_cache)
    x = x + h
    # cross-attention straight against the cached projected encoder K/V
    from repro.models.layers.attention import attention
    b, s, _ = x.shape
    xq = apply_norm(cfg.norm, p["ln_x"], x)
    q = (xq @ p["xattn"]["wq"] + p["xattn"].get("bq", 0)).reshape(
        b, s, cfg.n_heads, cfg.head_dim_)
    o = attention(q, cross_cache.k, cross_cache.v, causal=False,
                  kv_valid=cross_cache.length, kv_chunk=cfg.attn_kv_chunk,
                  blocks_threshold=cfg.attn_blocks_threshold)
    x = x + o.reshape(b, s, cfg.n_heads * cfg.head_dim_) @ p["xattn"]["wo"]
    x = x + mlp_apply(p["mlp"], apply_norm(cfg.norm, p["ln2"], x), cfg.mlp)
    return x, new_self, cross_cache
