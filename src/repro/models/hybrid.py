"""Hybrid Mamba2 + shared-attention model (zamba2 backbone).

Zamba2's design: a deep stack of Mamba2 blocks, plus ONE shared transformer
block (attention + MLP over the concatenation [x, x_embed0], i.e. width
2*d_model) whose weights are reused at every application point, specialised
by per-application LoRA adapters (on the q projection and the MLP input
projection). The shared block runs before every group of
``hybrid_attn_every`` Mamba layers.

Scan layout: groups are a python loop (n_groups ~= 7 for zamba2-1.2b), the
mamba layers inside each group are a lax.scan over stacked params => HLO is
O(n_groups), not O(L).
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers.attention import KVCache, attention, attn_params
from repro.models.layers.mlp import mlp_params
from repro.models.layers.norm import apply_norm, norm_params
from repro.models.layers.rope import apply_rope
from repro.models.layers.ssm import mamba2_apply, mamba2_params, ssm_state_zeros
from repro.models.lm import make_remat


def _dt(cfg):
    return jnp.dtype(cfg.dtype)


def n_groups(cfg: ModelConfig) -> int:
    return math.ceil(cfg.n_layers / cfg.hybrid_attn_every)


def group_sizes(cfg: ModelConfig) -> list[int]:
    full, rem = divmod(cfg.n_layers, cfg.hybrid_attn_every)
    return [cfg.hybrid_attn_every] * full + ([rem] if rem else [])


def _head_dim2(cfg: ModelConfig) -> int:
    return (2 * cfg.d_model) // cfg.n_heads


def init_params(key, cfg: ModelConfig) -> dict:
    dt = _dt(cfg)
    d2 = 2 * cfg.d_model
    km, ks1, ks2, ks3, kl, ke, kh = jax.random.split(key, 7)

    def one_mamba(k):
        return {"ln1": norm_params(cfg.norm, cfg.d_model),
                "mixer": mamba2_params(k, cfg, dt)}

    groups = []
    for gi, size in enumerate(group_sizes(cfg)):
        kg = jax.random.fold_in(km, gi)
        groups.append(jax.vmap(one_mamba)(jax.random.split(kg, size)))

    shared = {
        "ln1": norm_params(cfg.norm, d2),
        "attn": attn_params(ks1, d2, cfg.n_heads, cfg.n_kv_heads,
                            _head_dim2(cfg), bias=False, dtype=dt),
        "ln2": norm_params(cfg.norm, d2),
        "mlp": mlp_params(ks2, d2, cfg.d_ff, cfg.mlp, dt),
        "proj_out": (jax.random.normal(ks3, (d2, cfg.d_model))
                     * (1.0 / math.sqrt(d2))).astype(dt),
    }
    r = cfg.hybrid_lora_rank
    ng = n_groups(cfg)
    mlp_width = 2 * cfg.d_ff if cfg.mlp == "gated_silu" else cfg.d_ff
    loras = {
        "a_q": (jax.random.normal(kl, (ng, d2, r)) * (1.0 / math.sqrt(d2))
                ).astype(dt),
        "b_q": jnp.zeros((ng, r, cfg.n_heads * _head_dim2(cfg)), dt),
        "a_mlp": (jax.random.normal(jax.random.fold_in(kl, 1), (ng, d2, r))
                  * (1.0 / math.sqrt(d2))).astype(dt),
        "b_mlp": jnp.zeros((ng, r, mlp_width), dt),
    }
    return {
        "embed": (jax.random.normal(ke, (cfg.vocab_padded, cfg.d_model))
                  * (1.0 / math.sqrt(cfg.d_model))).astype(dt),
        "groups": groups,
        "shared": shared,
        "loras": loras,
        "final_norm": norm_params(cfg.norm, cfg.d_model),
        "lm_head": (jax.random.normal(kh, (cfg.d_model, cfg.vocab_padded))
                    * (1.0 / math.sqrt(cfg.d_model))).astype(dt),
    }


def _shared_block(cfg: ModelConfig, shared: dict, loras: dict, gi: int,
                  x: jax.Array, x0: jax.Array, *, cache: KVCache | None = None):
    """Shared attention+MLP over concat([x, x0]) with group-gi LoRA.

    Returns (new_x [B,S,D], new_cache)."""
    d2 = 2 * cfg.d_model
    hd = _head_dim2(cfg)
    b, s, _ = x.shape
    h = jnp.concatenate([x, x0], axis=-1)
    hn = apply_norm(cfg.norm, shared["ln1"], h)

    p = shared["attn"]
    q = hn @ p["wq"] + (hn @ loras["a_q"][gi]) @ loras["b_q"][gi]  # LoRA on q
    q = q.reshape(b, s, cfg.n_heads, hd)
    k = (hn @ p["wk"]).reshape(b, s, cfg.n_kv_heads, hd)
    v = (hn @ p["wv"]).reshape(b, s, cfg.n_kv_heads, hd)
    offset = cache.length if cache is not None else 0
    pos = jnp.arange(s) + offset
    q = apply_rope(q, pos, cfg.rope_theta)
    k = apply_rope(k, pos, cfg.rope_theta)
    new_cache = None
    if cache is not None:
        ck = jax.lax.dynamic_update_slice_in_dim(cache.k, k.astype(cache.k.dtype),
                                                 cache.length, axis=1)
        cv = jax.lax.dynamic_update_slice_in_dim(cache.v, v.astype(cache.v.dtype),
                                                 cache.length, axis=1)
        new_cache = KVCache(ck, cv, cache.length + s)
        o = attention(q, ck, cv, causal=True, q_offset=offset,
                      kv_valid=cache.length + s, kv_chunk=cfg.attn_kv_chunk,
                      blocks_threshold=cfg.attn_blocks_threshold)
    else:
        o = attention(q, k, v, causal=True, kv_chunk=cfg.attn_kv_chunk,
                      blocks_threshold=cfg.attn_blocks_threshold)
    h = h + o.reshape(b, s, cfg.n_heads * hd) @ p["wo"]

    h2 = apply_norm(cfg.norm, shared["ln2"], h)
    z = h2 @ shared["mlp"]["wi"] + (h2 @ loras["a_mlp"][gi]) @ loras["b_mlp"][gi]
    if cfg.mlp == "gated_silu":
        gate, up = jnp.split(z, 2, axis=-1)
        z = jax.nn.silu(gate) * up
    else:
        z = jax.nn.gelu(z)
    h = h + z @ shared["mlp"]["wo"]
    return h @ shared["proj_out"], new_cache


def _mamba_group_scan(cfg, gparams, x, states=None):
    """Scan the mamba layers of one group. states: stacked SSMState or None."""

    def body(h, inp):
        if states is None:
            lp = inp
            hn = apply_norm(cfg.norm, lp["ln1"], h)
            out, _ = mamba2_apply(lp["mixer"], hn, cfg)
            return h + out, None
        lp, st = inp
        hn = apply_norm(cfg.norm, lp["ln1"], h)
        out, new_st = mamba2_apply(lp["mixer"], hn, cfg, state=st)
        return h + out, new_st

    fn = make_remat(cfg)(body)
    xs = gparams if states is None else (gparams, states)
    return jax.lax.scan(fn, x, xs)


def forward(cfg: ModelConfig, params: dict, tokens: jax.Array):
    """Training forward. Returns (logits [B,S,Vp], aux=0)."""
    x = params["embed"][tokens]
    x0 = x
    # remat the shared block: its [B,H,S,S] f32 scores otherwise sit in HBM
    # for the whole bwd (7 applications x ~2 GiB at train_4k)
    shared_fn = (jax.checkpoint(
        lambda sh, lo, gi, a, b: _shared_block(cfg, sh, lo, gi, a, b)[0],
        static_argnums=(2,)) if cfg.remat else
        lambda sh, lo, gi, a, b: _shared_block(cfg, sh, lo, gi, a, b)[0])
    for gi in range(n_groups(cfg)):
        h = shared_fn(params["shared"], params["loras"], gi, x, x0)
        x = x + h
        x, _ = _mamba_group_scan(cfg, params["groups"][gi], x)
    x = apply_norm(cfg.norm, params["final_norm"], x)
    logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"],
                        preferred_element_type=jnp.float32)
    return logits, jnp.zeros((), jnp.float32)


def init_cache(cfg: ModelConfig, batch: int, s_max: int):
    """Per-group: stacked SSM states + one shared-attn KV cache.

    For long-context decode the shared-attn cache is the only O(S) memory;
    SSM state is O(1) — this is why zamba2 runs the long_500k cell."""
    dt = _dt(cfg)
    st = ssm_state_zeros(cfg, batch, dt)
    hd = _head_dim2(cfg)
    caches = []
    for size in group_sizes(cfg):
        caches.append({
            "ssm": jax.tree.map(
                lambda a: jnp.broadcast_to(a[None], (size,) + a.shape), st),
            "kv": KVCache.zeros(batch, s_max, cfg.n_kv_heads, hd, dt),
        })
    return caches


def prefill(cfg: ModelConfig, params: dict, tokens: jax.Array, s_max: int):
    x = params["embed"][tokens]
    x0 = x
    caches = init_cache(cfg, x.shape[0], s_max)
    new_caches = []
    for gi in range(n_groups(cfg)):
        h, kv = _shared_block(cfg, params["shared"], params["loras"], gi, x, x0,
                              cache=caches[gi]["kv"])
        x = x + h
        x, ssm = _mamba_group_scan(cfg, params["groups"][gi], x,
                                   states=caches[gi]["ssm"])
        new_caches.append({"ssm": ssm, "kv": kv})
    x = apply_norm(cfg.norm, params["final_norm"], x[:, -1:])
    logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"],
                        preferred_element_type=jnp.float32)
    return logits, new_caches


def decode_step(cfg: ModelConfig, params: dict, token: jax.Array, caches):
    x = params["embed"][token]
    x0 = x
    new_caches = []
    for gi in range(n_groups(cfg)):
        h, kv = _shared_block(cfg, params["shared"], params["loras"], gi, x, x0,
                              cache=caches[gi]["kv"])
        x = x + h
        x, ssm = _mamba_group_scan(cfg, params["groups"][gi], x,
                                   states=caches[gi]["ssm"])
        new_caches.append({"ssm": ssm, "kv": kv})
    x = apply_norm(cfg.norm, params["final_norm"], x)
    logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"],
                        preferred_element_type=jnp.float32)
    return logits, new_caches
