"""Model configuration — one frozen dataclass covering every assigned family.

Families:
- ``dense``  : decoder-only transformer (stablelm, qwen2.5, internlm2, h2o-danube)
- ``vlm``    : dense backbone + stub patch-embedding prefix (pixtral)
- ``audio``  : encoder-decoder + stub frame-embedding frontend (seamless-m4t)
- ``moe``    : mixture-of-experts FFN (deepseek-moe, granite-moe)
- ``ssm``    : attention-free Mamba2 / SSD (mamba2-780m)
- ``hybrid`` : Mamba2 backbone + shared attention blocks (zamba2)
"""

from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | vlm | audio | moe | ssm | hybrid
    n_layers: int
    d_model: int
    vocab: int
    # -- attention (ignored for family="ssm") --
    n_heads: int = 0
    n_kv_heads: int = 0
    head_dim: int = 0  # 0 -> d_model // n_heads
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    sliding_window: int = 0  # 0 -> full causal attention
    # -- mlp --
    d_ff: int = 0
    mlp: str = "gated_silu"  # gated_silu | gelu
    norm: str = "rms"  # rms | ln
    tie_embeddings: bool = False
    # -- moe --
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    d_expert: int = 0  # per-expert FFN width (fine-grained MoE)
    capacity_factor: float = 1.25
    # §Perf B4: explicit expert-parallel with_sharding_constraints measured
    # NEUTRAL for inference (GSPMD already picks the EP layout once the
    # per-k dispatch of B3 is in place) and HARMFUL for training (the bwd
    # of the constrained einsums partially replicates: +213%% FLOPs,
    # +78%% collective). Default off; knob kept for future meshes.
    moe_ep_sharding: bool = False
    router_aux_coef: float = 0.01
    # -- ssm (mamba2 / SSD) --
    ssm_state: int = 0  # N
    ssm_expand: int = 2
    ssm_head_dim: int = 64  # P
    ssm_chunk: int = 256  # Q, SSD chunk length (the BLOCKS knob for SSM)
    ssm_conv_width: int = 4
    ssm_groups: int = 1  # G (B/C projection groups)
    # -- hybrid (zamba2): shared attention block every k mamba layers --
    hybrid_attn_every: int = 6
    hybrid_lora_rank: int = 128
    # -- enc-dec (seamless) --
    n_enc_layers: int = 0
    # -- modality frontend stubs --
    n_prefix_tokens: int = 0  # vlm: image patches per sample (stub embeddings)
    # -- numerics / compile knobs --
    dtype: str = "bfloat16"
    vocab_round: int = 256  # pad vocab so TP shards evenly
    attn_kv_chunk: int = 1024  # blocks-mode KV chunk size for long seqs
    # §Perf iteration A2: below this KV length, Unique-mode attention beats
    # Blocks (the paper's 'partitioning only pays for longer enough packets'):
    # the chunk scan's hoisted masks + f32 carries cost more HBM traffic than
    # the single materialised score block.
    attn_blocks_threshold: int = 4096
    use_scan: bool = True
    remat: bool = True
    # Dispatch self-attention to the Pallas flash kernel
    # (repro.kernels.flash_attention) — the production TPU path. The pure
    # jnp path stays the default because the CPU dry-run/tests cannot lower
    # Mosaic kernels; on hardware flip this on (or set interpret for CPU
    # functional checks).
    use_pallas_attention: bool = False
    pallas_interpret: bool = False
    # §Perf iteration A3: remat policy. "full" recomputes the whole block in
    # bwd (min memory); "dots_nb" saves weight-matmul outputs (no-batch-dim
    # dots) so projections aren't recomputed — trades a little HBM footprint
    # for less recompute traffic/FLOPs.
    remat_policy: str = "full"  # full | dots_nb
    # §Perf: preferred microbatch count for train cells (0 = auto, prefer 8).
    # zamba2 pins 16: at per-device micro-batch 2 GSPMD partially replicates
    # the wide (2*d_model) shared-attention einsums (+6x FLOPs).
    micro_override: int = 0
    # §Perf B5: chunked prefill (Blocks-mode on the prompt): bound per-token
    # intermediates (MoE dispatch, scores) to O(B*chunk). 0 = single-shot.
    prefill_chunk: int = 0

    # ---- derived ----
    @property
    def head_dim_(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // max(self.n_heads, 1)

    @property
    def vocab_padded(self) -> int:
        return _round_up(self.vocab, self.vocab_round)

    @property
    def d_inner(self) -> int:  # ssm inner width
        return self.ssm_expand * self.d_model

    @property
    def n_ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def conv_dim(self) -> int:
        return self.d_inner + 2 * self.ssm_groups * self.ssm_state

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def supports_long_context(self) -> bool:
        """True if the arch is sub-quadratic: SSM, hybrid, or sliding-window."""
        return self.family in ("ssm", "hybrid") or self.sliding_window > 0

    @property
    def has_decoder(self) -> bool:
        return True  # all assigned archs have an autoregressive decoder

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # ---- parameter count (for 6ND roofline math) ----
    def param_count(self) -> int:
        D, F, V, L = self.d_model, self.d_ff, self.vocab_padded, self.n_layers
        Dh, H, Hkv = self.head_dim_, self.n_heads, self.n_kv_heads
        emb = V * D * (1 if self.tie_embeddings else 2)
        if self.family == "ssm":
            per = self._ssm_params() + 2 * D  # norms
            return emb + L * per + D
        attn = D * H * Dh + 2 * D * Hkv * Dh + H * Dh * D
        if self.qkv_bias:
            attn += (H + 2 * Hkv) * Dh
        if self.family == "moe":
            E, Fe, S = self.n_experts, self.d_expert or F, self.n_shared_experts
            ff = E * (3 * D * Fe) + S * (3 * D * Fe) + D * E
        elif self.mlp == "gated_silu":
            ff = 3 * D * F
        else:
            ff = 2 * D * F
        per = attn + ff + 2 * D
        total = emb + L * per + D
        if self.family == "audio":
            # encoder stack (self-attn + mlp) + decoder cross-attn additions
            enc_per = attn + (3 * D * F if self.mlp == "gated_silu" else 2 * D * F) + 2 * D
            total += self.n_enc_layers * enc_per + L * (attn + D)  # cross attn
        if self.family == "hybrid":
            ssm_per = self._ssm_params() + 2 * D
            shared = attn + 3 * D * F + 2 * D
            n_app = math.ceil(L / self.hybrid_attn_every)
            lora = n_app * 2 * (2 * D * self.hybrid_lora_rank)
            return emb + L * ssm_per + shared + lora + D
        return total

    def _ssm_params(self) -> int:
        D, Din, N, G, H = (self.d_model, self.d_inner, self.ssm_state,
                           self.ssm_groups, self.n_ssm_heads)
        in_proj = D * (2 * Din + 2 * G * N + H)
        conv = self.conv_dim * self.ssm_conv_width + self.conv_dim
        out = Din * D
        return in_proj + conv + out + 3 * H + Din  # A_log, D, dt_bias, gate norm

    def active_param_count(self) -> int:
        """Params touched per token (MoE: shared + top_k experts only)."""
        if self.family != "moe":
            return self.param_count()
        D, L = self.d_model, self.n_layers
        E, Fe, S, K = (self.n_experts, self.d_expert or self.d_ff,
                       self.n_shared_experts, self.top_k)
        dense_total = self.param_count()
        inactive = L * (E - K) * (3 * D * Fe)
        return dense_total - inactive


@dataclass(frozen=True)
class ShapeCell:
    """One assigned (shape) cell: what gets lowered in the dry-run."""

    name: str  # train_4k | prefill_32k | decode_32k | long_500k
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPE_CELLS = (
    ShapeCell("train_4k", 4_096, 256, "train"),
    ShapeCell("prefill_32k", 32_768, 32, "prefill"),
    ShapeCell("decode_32k", 32_768, 128, "decode"),
    ShapeCell("long_500k", 524_288, 1, "decode"),
)


def cell_applicable(cfg: ModelConfig, cell: ShapeCell) -> tuple[bool, str]:
    """Whether a shape cell runs for this arch (per assignment rules)."""
    if cell.name == "long_500k" and not cfg.supports_long_context:
        return False, "long_500k skipped: pure full-attention arch (quadratic)"
    return True, ""
