"""Public model API: one entry point for every assigned architecture.

``build_model(cfg)`` returns a :class:`Model` of pure functions:

- ``init(key) -> params``
- ``forward(params, batch) -> (logits, aux)``           (teacher-forced)
- ``loss(params, batch) -> (scalar, metrics)``
- ``prefill(params, batch, s_max) -> (logits, cache)``
- ``decode(params, token, cache) -> (logits, cache)``
- ``init_cache(batch, s_max) -> cache``                 (for decode dry-runs)

Batches are dicts. Keys by family:
- dense/moe/ssm/hybrid: tokens [B,S], labels [B,S]
- vlm: tokens [B,S_text], patch_embeds [B,n_prefix,D], labels [B,S_text]
- audio: frames [B,S_enc,D], tokens [B,S_dec], labels [B,S_dec]
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.models import encdec, hybrid, lm
from repro.models.config import ModelConfig


def cross_entropy(logits: jax.Array, labels: jax.Array,
                  vocab: int) -> tuple[jax.Array, jax.Array]:
    """Mean next-token CE over valid positions (labels >= 0), and accuracy.

    logits: [B,S,Vp] float32; labels: [B,S] int32 (-1 = ignore)."""
    valid = labels >= 0
    safe = jnp.maximum(labels, 0)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, safe[..., None], axis=-1)[..., 0]
    nll = (logz - gold) * valid
    denom = jnp.maximum(valid.sum(), 1)
    acc = ((logits.argmax(-1) == safe) & valid).sum() / denom
    return nll.sum() / denom, acc


@dataclass(frozen=True)
class Model:
    cfg: ModelConfig
    init: Callable[..., Any]
    forward: Callable[..., Any]
    loss: Callable[..., Any]
    prefill: Callable[..., Any]
    decode: Callable[..., Any]
    init_cache: Callable[..., Any]


def build_model(cfg: ModelConfig) -> Model:
    fam = cfg.family

    # ---- forward ----
    if fam == "audio":
        def fwd(params, batch):
            return encdec.forward(cfg, params, batch["frames"], batch["tokens"])
    elif fam == "hybrid":
        def fwd(params, batch):
            return hybrid.forward(cfg, params, batch["tokens"])
    elif fam == "vlm":
        def fwd(params, batch):
            return lm.forward(cfg, params, batch["tokens"],
                              prefix_embeds=batch["patch_embeds"])
    else:
        def fwd(params, batch):
            return lm.forward(cfg, params, batch["tokens"])

    # ---- loss ----
    def loss(params, batch):
        logits, aux = fwd(params, batch)
        labels = batch["labels"]
        if fam == "vlm":  # loss only over text positions (after image prefix)
            logits = logits[:, cfg.n_prefix_tokens:]
        ce, acc = cross_entropy(logits, labels, cfg.vocab_padded)
        total = ce + cfg.router_aux_coef * aux
        return total, {"loss": ce, "aux": aux, "acc": acc}

    # ---- init ----
    if fam == "audio":
        init = lambda key: encdec.init_params(key, cfg)  # noqa: E731
    elif fam == "hybrid":
        init = lambda key: hybrid.init_params(key, cfg)  # noqa: E731
    else:
        init = lambda key: lm.init_params(key, cfg)  # noqa: E731

    # ---- prefill / decode ----
    if fam == "audio":
        def pre(params, batch, s_max):
            return encdec.prefill(cfg, params, batch["frames"],
                                  batch["tokens"], s_max)

        def dec(params, token, cache):
            return encdec.decode_step(cfg, params, token, cache)

        def icache(batch_size, s_max, s_enc=None):
            return encdec.init_dec_cache(cfg, batch_size, s_max,
                                         s_enc or s_max)
    elif fam == "hybrid":
        def pre(params, batch, s_max):
            return hybrid.prefill(cfg, params, batch["tokens"], s_max)

        def dec(params, token, cache):
            return hybrid.decode_step(cfg, params, token, cache)

        def icache(batch_size, s_max, s_enc=None):
            return hybrid.init_cache(cfg, batch_size, s_max)
    else:
        def pre(params, batch, s_max):
            pe = batch.get("patch_embeds") if fam == "vlm" else None
            s_tok = batch["tokens"].shape[1]
            if (cfg.prefill_chunk and pe is None
                    and s_tok % cfg.prefill_chunk == 0
                    and s_tok > cfg.prefill_chunk):
                return lm.prefill_chunked(cfg, params, batch["tokens"],
                                          s_max, chunk=cfg.prefill_chunk)
            return lm.prefill(cfg, params, batch["tokens"], s_max,
                              prefix_embeds=pe)

        def dec(params, token, cache):
            return lm.decode_step(cfg, params, token, cache)

        def icache(batch_size, s_max, s_enc=None):
            return lm.init_cache(cfg, batch_size, s_max)

    return Model(cfg=cfg, init=init, forward=fwd, loss=loss, prefill=pre,
                 decode=dec, init_cache=icache)


def input_specs(cfg: ModelConfig, cell, *, for_init: bool = False) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of a shape cell.

    No device allocation — shardable, weak-type-correct. ``decode`` cells
    describe the single-token step against a seq_len cache (built separately
    via cache_specs)."""
    b, s = cell.global_batch, cell.seq_len
    i32 = jnp.int32
    f = jnp.dtype(cfg.dtype)
    sds = jax.ShapeDtypeStruct
    if cell.kind == "decode":
        if cfg.family == "audio":
            return {"tokens": sds((b, 1), i32)}
        return {"tokens": sds((b, 1), i32)}
    if cfg.family == "audio":
        return {
            "frames": sds((b, s, cfg.d_model), f),
            "tokens": sds((b, s), i32),
            "labels": sds((b, s), i32),
        }
    if cfg.family == "vlm":
        s_text = s - cfg.n_prefix_tokens
        return {
            "tokens": sds((b, s_text), i32),
            "patch_embeds": sds((b, cfg.n_prefix_tokens, cfg.d_model), f),
            "labels": sds((b, s_text), i32),
        }
    return {"tokens": sds((b, s), i32), "labels": sds((b, s), i32)}


def cache_specs(cfg: ModelConfig, batch: int, s_max: int) -> Any:
    """ShapeDtypeStruct pytree matching init_cache output (for dry-runs)."""
    model = build_model(cfg)
    return jax.eval_shape(lambda: model.init_cache(batch, s_max))
