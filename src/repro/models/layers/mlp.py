"""Feed-forward blocks: gated SiLU (llama-style) and GELU (classic)."""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def mlp_params(key, d_model: int, d_ff: int, kind: str, dtype) -> dict:
    k1, k2 = jax.random.split(key)
    sd_in = 1.0 / math.sqrt(d_model)
    sd_out = 1.0 / math.sqrt(2.0 * d_ff)
    width = 2 * d_ff if kind == "gated_silu" else d_ff
    return {
        "wi": (jax.random.normal(k1, (d_model, width)) * sd_in).astype(dtype),
        "wo": (jax.random.normal(k2, (d_ff, d_model)) * sd_out).astype(dtype),
    }


def mlp_apply(p: dict, x: jax.Array, kind: str) -> jax.Array:
    h = x @ p["wi"]
    if kind == "gated_silu":
        gate, up = jnp.split(h, 2, axis=-1)
        h = jax.nn.silu(gate) * up
    else:
        h = jax.nn.gelu(h)
    return h @ p["wo"]
