"""Attention: GQA + RoPE + sliding window + KV cache + blocks-mode chunking.

The paper's Unique/Blocks partitioning shows up here as ``kv_chunk``: full
(unique) attention materialises the [S_q, S_kv] score block; blocks-mode
streams the KV sequence in chunks with an online-softmax accumulator
(flash-attention structure) so the working set is O(S_q x chunk) — the
HBM->VMEM analogue of streaming feature-map rows into NullHop's MAC array.
The Pallas kernel in repro.kernels.flash_attention implements the same
schedule with explicit VMEM BlockSpecs; this module is the pure-jnp path
used for CPU smoke tests and the dry-run.
"""

from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models.layers.rope import apply_rope

NEG_INF = -2.0**30  # large-but-finite; avoids NaN from (-inf) - (-inf)


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def _cache_write(dst: jax.Array, new: jax.Array, length) -> jax.Array:
    """Append `new` [B, s, Hkv, Dh] at position `length` (scalar, or [B] for
    per-slot lengths — continuous batching)."""
    length = jnp.asarray(length)
    new = new.astype(dst.dtype)
    if length.ndim == 0:
        return jax.lax.dynamic_update_slice_in_dim(dst, new, length, axis=1)
    b, s = new.shape[0], new.shape[1]
    rows = jnp.arange(b)[:, None]  # [B,1]
    cols = length[:, None] + jnp.arange(s)[None, :]  # [B,s]
    return dst.at[rows, cols].set(new)


class KVCache(NamedTuple):
    """Preallocated decode cache for one layer group.

    k, v: [B, S_max, Hkv, Dh]; length: [] int32 (tokens already cached)."""

    k: jax.Array
    v: jax.Array
    length: jax.Array

    @staticmethod
    def zeros(batch: int, s_max: int, n_kv: int, dh: int, dtype) -> "KVCache":
        return KVCache(
            jnp.zeros((batch, s_max, n_kv, dh), dtype),
            jnp.zeros((batch, s_max, n_kv, dh), dtype),
            jnp.zeros((), jnp.int32),
        )


def repeat_kv(x: jax.Array, n_rep: int) -> jax.Array:
    """[B, S, Hkv, Dh] -> [B, S, Hkv*n_rep, Dh]."""
    if n_rep == 1:
        return x
    b, s, h, d = x.shape
    return jnp.broadcast_to(x[:, :, :, None, :], (b, s, h, n_rep, d)).reshape(
        b, s, h * n_rep, d
    )


def _all_scalar(*xs) -> bool:
    return all(x is None or jnp.ndim(x) == 0 for x in xs)


def _as_vec(x) -> jax.Array:
    """Scalar or [B] -> [B?,1,1] broadcastable against [B,s_q,s_kv]."""
    a = jnp.asarray(x)
    if a.ndim == 0:
        return a.reshape(1, 1, 1)
    return a.reshape(-1, 1, 1)


def _ok_mask(s_q: int, s_kv: int, q_offset, *, causal: bool, window: int,
             kv_start=0, kv_valid=None) -> jax.Array:
    """Bool mask [B?, s_q, s_kv]; q_offset / kv_valid may be scalars or [B]
    (per-slot cache lengths — continuous batching)."""
    qpos = jnp.arange(s_q)[None, :, None] + _as_vec(q_offset)  # [B?,sq,1]
    kpos = (jnp.arange(s_kv)[None, None, :] + _as_vec(kv_start))  # [B?,1,skv]
    ok = jnp.ones((1, s_q, s_kv), bool)
    if causal:
        ok = ok & (kpos <= qpos)
    if window > 0:
        ok = ok & (qpos - kpos < window)
    if kv_valid is not None:
        ok = ok & (kpos < _as_vec(kv_valid))
    return ok


def _mask_bias(s_q: int, s_kv: int, q_offset: jax.Array | int, *,
               causal: bool, window: int,
               kv_start: jax.Array | int = 0) -> jax.Array:
    """[s_q, s_kv] additive bias (scalar-offset fast path)."""
    ok = _ok_mask(s_q, s_kv, q_offset, causal=causal, window=window,
                  kv_start=kv_start)[0]
    return jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)


def attention_unique(q: jax.Array, k: jax.Array, v: jax.Array, *,
                     causal: bool = True, window: int = 0,
                     q_offset: jax.Array | int = 0,
                     kv_valid: jax.Array | None = None,
                     kv_offset: jax.Array | int = 0) -> jax.Array:
    """Unique-mode attention: one [S_q, S_kv] score block.

    q: [B, S_q, H, Dh]; k, v: [B, S_kv, Hkv, Dh] (Hkv divides H).
    kv_valid: optional [] int — kv positions >= kv_valid are masked (cache)."""
    b, sq, h, dh = q.shape
    hkv = k.shape[2]
    k = repeat_kv(k, h // hkv)
    v = repeat_kv(v, h // hkv)
    scale = 1.0 / math.sqrt(dh)
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k, preferred_element_type=jnp.float32)
    if _all_scalar(q_offset, kv_offset, kv_valid):
        # 2-D additive-bias fast path: a [sq,skv] f32 bias keeps GSPMD's
        # head-sharded partitioning of the score einsums (a broadcast 4-D
        # pred mask was observed to force head replication: zamba2 train
        # FLOPs x6 — see EXPERIMENTS §Perf A4 revert notes).
        bias = _mask_bias(sq, k.shape[1], q_offset, causal=causal,
                          window=window, kv_start=kv_offset)
        scores = scores * scale + bias
        if kv_valid is not None:
            kpos_v = jnp.arange(k.shape[1]) + kv_offset
            scores = jnp.where(kpos_v[None, None, None, :] < kv_valid,
                               scores, NEG_INF)
    else:
        ok = _ok_mask(sq, k.shape[1], q_offset, causal=causal, window=window,
                      kv_start=kv_offset, kv_valid=kv_valid)  # [B?,sq,skv]
        scores = jnp.where(ok[:, None], scores * scale, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", probs, v)


def attention_blocks(q: jax.Array, k: jax.Array, v: jax.Array, *,
                     causal: bool = True, window: int = 0,
                     q_offset: jax.Array | int = 0,
                     kv_valid: jax.Array | None = None,
                     kv_chunk: int = 1024,
                     kv_offset: jax.Array | int = 0) -> jax.Array:
    """Blocks-mode attention: stream KV in chunks with online softmax.

    Same semantics as :func:`attention_unique`; working set O(S_q * kv_chunk).
    This is the paper's BLOCKS partitioning applied to the KV stream."""
    b, sq, h, dh = q.shape
    s_kv = k.shape[1]
    hkv = k.shape[2]
    if s_kv % kv_chunk:
        # pad kv to a chunk multiple; padded tail masked via kv_valid
        pad = kv_chunk - s_kv % kv_chunk
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kv_valid = jnp.asarray(s_kv if kv_valid is None else kv_valid, jnp.int32)
        s_kv = k.shape[1]
    n_chunks = s_kv // kv_chunk
    scale = 1.0 / math.sqrt(dh)
    n_rep = h // hkv

    kc = k.reshape(b, n_chunks, kv_chunk, hkv, dh).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(b, n_chunks, kv_chunk, hkv, dh).transpose(1, 0, 2, 3, 4)

    def body(carry, inp):
        acc, m, l = carry  # acc [B,H,Sq,Dh] f32; m,l [B,H,Sq] f32
        kcb, vcb, ci = inp
        kcb = repeat_kv(kcb, n_rep)
        vcb = repeat_kv(vcb, n_rep)
        s = jnp.einsum("bqhd,bkhd->bhqk", q, kcb,
                       preferred_element_type=jnp.float32) * scale
        if _all_scalar(q_offset, kv_offset) and kv_valid is None:
            s = s + _mask_bias(sq, kv_chunk, q_offset, causal=causal,
                               window=window,
                               kv_start=ci * kv_chunk + kv_offset)
        else:
            ok = _ok_mask(sq, kv_chunk, q_offset, causal=causal,
                          window=window,
                          kv_start=ci * kv_chunk + kv_offset,
                          kv_valid=kv_valid)
            s = jnp.where(ok[:, None], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(-1))
        alpha = jnp.exp2((m - m_new) * 1.4426950408889634)
        p = jnp.exp2((s - m_new[..., None]) * 1.4426950408889634)
        l = l * alpha + p.sum(-1)
        acc = acc * alpha[..., None] + jnp.einsum(
            "bhqk,bkhd->bhqd", p.astype(q.dtype), vcb,
            preferred_element_type=jnp.float32)
        return (acc, m_new, l), None

    acc0 = jnp.zeros((b, h, sq, dh), jnp.float32)
    m0 = jnp.full((b, h, sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, h, sq), jnp.float32)
    (acc, m, l), _ = jax.lax.scan(body, (acc0, m0, l0),
                                  (kc, vc, jnp.arange(n_chunks)))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.transpose(0, 2, 1, 3).astype(q.dtype)  # [B, Sq, H, Dh]


def attention(q, k, v, *, causal=True, window=0, q_offset=0, kv_valid=None,
              kv_chunk: int = 1024, blocks_threshold: int = 4096,
              kv_offset: jax.Array | int = 0) -> jax.Array:
    """Policy dispatch: Unique mode below the threshold, Blocks above.

    Mirrors the paper's finding that partitioning only pays off for 'longer
    enough packets' — short sequences keep the single-block fast path.
    kv_offset: absolute position of k[:, 0] (nonzero when the cache read was
    sliced, e.g. sliding-window decode)."""
    if k.shape[1] <= blocks_threshold:
        return attention_unique(q, k, v, causal=causal, window=window,
                                q_offset=q_offset, kv_valid=kv_valid,
                                kv_offset=kv_offset)
    return attention_blocks(q, k, v, causal=causal, window=window,
                            q_offset=q_offset, kv_valid=kv_valid,
                            kv_chunk=kv_chunk, kv_offset=kv_offset)


# ---------------------------------------------------------------------------
# Full attention block (params + apply), shared by every attention-bearing arch
# ---------------------------------------------------------------------------

def attn_params(key, d_model: int, n_heads: int, n_kv: int, head_dim: int,
                *, bias: bool, dtype) -> dict:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    sd = 1.0 / math.sqrt(d_model)
    p = {
        "wq": (jax.random.normal(k1, (d_model, n_heads * head_dim)) * sd).astype(dtype),
        "wk": (jax.random.normal(k2, (d_model, n_kv * head_dim)) * sd).astype(dtype),
        "wv": (jax.random.normal(k3, (d_model, n_kv * head_dim)) * sd).astype(dtype),
        "wo": (jax.random.normal(k4, (n_heads * head_dim, d_model))
               * (sd / math.sqrt(2.0))).astype(dtype),
    }
    if bias:
        p["bq"] = jnp.zeros((n_heads * head_dim,), dtype)
        p["bk"] = jnp.zeros((n_kv * head_dim,), dtype)
        p["bv"] = jnp.zeros((n_kv * head_dim,), dtype)
    return p


def attn_apply(p: dict, x: jax.Array, *, n_heads: int, n_kv: int,
               head_dim: int, rope_theta: float, window: int = 0,
               kv_chunk: int = 1024, blocks_threshold: int = 4096,
               use_pallas: bool = False, pallas_interpret: bool = False,
               cache: KVCache | None = None,
               positions: jax.Array | None = None,
               xk: jax.Array | None = None,
               causal: bool = True) -> tuple[jax.Array, KVCache | None]:
    """Self- (xk=None) or cross- (xk=encoder output) attention.

    With a cache: appends this call's K/V at cache.length and attends over
    the valid prefix (decode path). positions: [S] absolute positions for
    RoPE (defaults to arange, or cache.length offset when decoding)."""
    b, s, _ = x.shape
    src = x if xk is None else xk
    q = (x @ p["wq"] + p.get("bq", 0)).reshape(b, s, n_heads, head_dim)
    k = (src @ p["wk"] + p.get("bk", 0)).reshape(b, src.shape[1], n_kv, head_dim)
    v = (src @ p["wv"] + p.get("bv", 0)).reshape(b, src.shape[1], n_kv, head_dim)

    offset = cache.length if cache is not None else 0
    if positions is None:
        off = jnp.asarray(offset)
        positions = (jnp.arange(s)[None] + off.reshape(-1, 1)
                     if off.ndim else jnp.arange(s) + offset)
    if rope_theta > 0 and xk is None:  # no rope on cross-attention
        q = apply_rope(q, positions, rope_theta)
        k = apply_rope(k, positions if k.shape[1] == s
                       else jnp.arange(src.shape[1]), rope_theta)

    if (use_pallas and cache is None and xk is None
            and q.shape[1] == src.shape[1]):
        # production TPU path: VMEM-resident causal flash attention
        from repro.kernels.flash_attention.ops import flash_attention
        out = flash_attention(q, k, v, causal=causal, window=window,
                              interpret=pallas_interpret)
        return out.reshape(b, s, n_heads * head_dim) @ p["wo"], None

    new_cache = None
    if cache is not None and xk is None:
        ck = _cache_write(cache.k, k, cache.length)
        cv = _cache_write(cache.v, v, cache.length)
        new_cache = KVCache(ck, cv, cache.length + s)
        k, v = ck, cv
        kv_off = 0
        if window > 0 and ck.shape[1] > 2 * window and jnp.asarray(cache.length).ndim == 0:
            # §Perf iteration C1: sliding-window decode only ever attends the
            # last `window` positions — slice the cache read instead of
            # streaming the full 500k slab through the masked softmax.
            w_eff = min(_round_up(window + s, 128), ck.shape[1])
            start = jnp.clip(cache.length + s - w_eff, 0, ck.shape[1] - w_eff)
            k = jax.lax.dynamic_slice_in_dim(ck, start, w_eff, axis=1)
            v = jax.lax.dynamic_slice_in_dim(cv, start, w_eff, axis=1)
            kv_off = start
        out = attention(q, k, v, causal=causal, window=window, q_offset=offset,
                        kv_valid=cache.length + s, kv_chunk=kv_chunk,
                        blocks_threshold=blocks_threshold, kv_offset=kv_off)
    elif cache is not None:  # cross-attn with precomputed encoder cache
        out = attention(q, cache.k, cache.v, causal=False, kv_valid=cache.length,
                        kv_chunk=kv_chunk, blocks_threshold=blocks_threshold)
        new_cache = cache
    else:
        out = attention(q, k, v, causal=causal, window=window, kv_chunk=kv_chunk,
                        blocks_threshold=blocks_threshold)
    return out.reshape(b, s, n_heads * head_dim) @ p["wo"], new_cache
