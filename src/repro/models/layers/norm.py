"""Normalisation layers (pure functions over explicit params)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return ((x * jax.lax.rsqrt(var + eps)) * scale.astype(jnp.float32)).astype(dt)


def layer_norm(x: jax.Array, scale: jax.Array, bias: jax.Array,
               eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dt)


def norm_params(kind: str, d: int):
    if kind == "rms":
        return {"scale": jnp.ones((d,), jnp.float32)}
    return {"scale": jnp.ones((d,), jnp.float32), "bias": jnp.zeros((d,), jnp.float32)}


def apply_norm(kind: str, params, x: jax.Array) -> jax.Array:
    if kind == "rms":
        return rms_norm(x, params["scale"])
    return layer_norm(x, params["scale"], params["bias"])
