"""Mamba2 / SSD (state-space duality) block — chunked scan formulation.

Implements the SSD algorithm of Dao & Gu (arXiv:2405.21060): the sequence is
split into chunks of length Q; within a chunk the output is computed with a
quadratic (attention-like) masked matmul, and chunk-boundary states are
carried by a linear recurrence across chunks. The chunk length is literally
the paper's BLOCKS partitioning knob for the SSM family: it trades the
quadratic intra-chunk FLOPs against the sequential inter-chunk scan, exactly
like DMA block size trades per-chunk overhead against overlap.

Layout convention (following the Mamba2 reference):
  x  : [B, S, H, P]   (H = d_inner/P heads)
  dt : [B, S, H]      (softplus-ed, positive)
  A  : [H]            (negative; dA = dt * A)
  B_, C: [B, S, G, N] (G groups broadcast over heads)

The Pallas kernel in repro.kernels.ssd_scan implements the intra-chunk
quadratic part with explicit VMEM tiling; this module is the jnp reference
path (used by the dry-run and CPU tests).
"""

from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp


class SSMState(NamedTuple):
    """Decode-time recurrent state for one layer stack."""

    ssm: jax.Array  # [B, H, P, N] running state
    conv: jax.Array  # [B, W-1, conv_dim] causal-conv tail


def segsum(x: jax.Array) -> jax.Array:
    """Stable segment-sum: out[..., i, j] = sum_{k=j+1..i} x[..., k], -inf j>i.

    Produces the log of the lower-triangular decay matrix L."""
    q = x.shape[-1]
    x_cum = jnp.cumsum(x, axis=-1)
    diff = x_cum[..., :, None] - x_cum[..., None, :]
    mask = jnp.tril(jnp.ones((q, q), bool), 0)
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(x: jax.Array, dt: jax.Array, a: jax.Array, b: jax.Array,
                c: jax.Array, *, chunk: int,
                initial_state: jax.Array | None = None,
                return_final_state: bool = False):
    """Chunked SSD scan.

    x: [B, S, H, P]; dt: [B, S, H]; a: [H] (negative); b, c: [B, S, G, N].
    Returns y: [B, S, H, P] (and final state [B, H, P, N] if requested)."""
    bs, s, h, p = x.shape
    g, n = b.shape[2], b.shape[3]
    if s % chunk:
        raise ValueError(f"seq {s} not divisible by chunk {chunk}")
    nc = s // chunk
    rep = h // g

    # reshape into chunks
    xc = x.reshape(bs, nc, chunk, h, p)
    dtc = dt.reshape(bs, nc, chunk, h)
    bc = b.reshape(bs, nc, chunk, g, n)
    cc = c.reshape(bs, nc, chunk, g, n)
    bch = jnp.repeat(bc, rep, axis=3)  # broadcast groups to heads [B,nc,Q,H,N]
    cch = jnp.repeat(cc, rep, axis=3)

    da = dtc * a[None, None, None, :]  # [B, nc, Q, H] (negative)
    da_hbnq = da.transpose(0, 3, 1, 2)  # [B, H, nc, Q]
    da_cs = jnp.cumsum(da_hbnq, axis=-1)  # within-chunk cumsum

    # 1) intra-chunk (diagonal block) output: quadratic attention-like
    l_log = segsum(da_hbnq)  # [B, H, nc, Q, Q]
    cb = jnp.einsum("bzqhn,bzkhn->bhzqk", cch, bch)  # [B,H,nc,Q,Q]
    att = cb * jnp.exp(l_log)
    xdt = xc * dtc[..., None]  # [B, nc, Q, H, P]
    y_diag = jnp.einsum("bhzqk,bzkhp->bzqhp", att.astype(x.dtype), xdt)

    # 2) chunk-boundary states: state_z = sum_k exp(dA_cs[-1]-dA_cs[k]) B_k x_k
    decay_states = jnp.exp(da_cs[..., -1:] - da_cs)  # [B, H, nc, Q]
    states = jnp.einsum("bzkhn,bhzk,bzkhp->bzhpn", bch,
                        decay_states.transpose(0, 1, 2, 3), xdt)

    # 3) inter-chunk recurrence: carry state across chunks
    chunk_decay = jnp.exp(da_cs[..., -1])  # [B, H, nc]
    s0 = (jnp.zeros((bs, h, p, n), jnp.float32) if initial_state is None
          else initial_state.astype(jnp.float32))

    def scan_body(prev, inp):
        st_z, dec_z = inp  # [B,H,P,N], [B,H]
        new = prev * dec_z[..., None, None] + st_z.astype(jnp.float32)
        return new, prev  # emit state *entering* the chunk

    states_hbpn = states.transpose(1, 0, 2, 3, 4).astype(jnp.float32)  # [nc,B,H,P,N]
    decay_zb = chunk_decay.transpose(2, 0, 1)  # [nc, B, H]
    final, prev_states = jax.lax.scan(scan_body, s0, (states_hbpn, decay_zb))
    # prev_states: [nc, B, H, P, N] — state at each chunk start

    # 4) off-diagonal contribution: y_off = C_q . (decay_in[q] * prev_state)
    state_decay_out = jnp.exp(da_cs)  # [B, H, nc, Q]
    y_off = jnp.einsum("bzqhn,zbhpn,bhzq->bzqhp", cch,
                       prev_states, state_decay_out).astype(x.dtype)

    y = (y_diag + y_off).reshape(bs, s, h, p)
    if return_final_state:
        return y, final
    return y


def ssd_decode_step(state: jax.Array, x: jax.Array, dt: jax.Array,
                    a: jax.Array, b: jax.Array, c: jax.Array):
    """Single-token recurrent update. state: [B,H,P,N]; x: [B,H,P];
    dt: [B,H]; b,c: [B,G,N]. Returns (y [B,H,P], new_state)."""
    h = x.shape[1]
    g = b.shape[1]
    rep = h // g
    bh = jnp.repeat(b, rep, axis=1)  # [B,H,N]
    ch = jnp.repeat(c, rep, axis=1)
    da = jnp.exp(dt * a[None, :])  # [B,H]
    upd = jnp.einsum("bhn,bhp->bhpn", bh, x * dt[..., None])
    new = state * da[..., None, None] + upd.astype(jnp.float32)
    y = jnp.einsum("bhpn,bhn->bhp", new.astype(x.dtype), ch)
    return y, new


# ---------------------------------------------------------------------------
# Full Mamba2 block: in_proj -> causal conv -> SSD -> gated norm -> out_proj
# ---------------------------------------------------------------------------

def mamba2_params(key, cfg, dtype) -> dict:
    d, din, n, g, h, w = (cfg.d_model, cfg.d_inner, cfg.ssm_state,
                          cfg.ssm_groups, cfg.n_ssm_heads, cfg.ssm_conv_width)
    conv_dim = cfg.conv_dim
    k1, k2, k3, k4 = jax.random.split(key, 4)
    sd = 1.0 / math.sqrt(d)
    d_in_proj = 2 * din + 2 * g * n + h
    return {
        "in_proj": (jax.random.normal(k1, (d, d_in_proj)) * sd).astype(dtype),
        "conv_w": (jax.random.normal(k2, (w, conv_dim)) * (1.0 / math.sqrt(w))
                   ).astype(dtype),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, h)).astype(jnp.float32),
        "d_skip": jnp.ones((h,), jnp.float32),
        "dt_bias": jnp.full((h,), math.log(math.e - 1), jnp.float32),
        "norm_scale": jnp.ones((din,), jnp.float32),
        "out_proj": (jax.random.normal(k3, (din, d)) * (1.0 / math.sqrt(din))
                     ).astype(dtype),
    }


def _causal_conv(z: jax.Array, w: jax.Array, bias: jax.Array,
                 tail: jax.Array | None = None):
    """Depthwise causal conv1d. z: [B, S, C]; w: [W, C]. Returns (y, new_tail)."""
    width = w.shape[0]
    if tail is None:
        zp = jnp.pad(z, ((0, 0), (width - 1, 0), (0, 0)))
    else:
        zp = jnp.concatenate([tail.astype(z.dtype), z], axis=1)
    y = sum(zp[:, i : i + z.shape[1]] * w[i][None, None] for i in range(width))
    new_tail = zp[:, zp.shape[1] - (width - 1):]
    return jax.nn.silu(y + bias[None, None]), new_tail


def mamba2_apply(p: dict, x: jax.Array, cfg, *,
                 state: SSMState | None = None):
    """x: [B, S, D] -> ([B, S, D], new_state or None).

    With ``state`` (decode): S must be 1 and the recurrent path is used."""
    bsz, s, d = x.shape
    din, n, g, h, pp = (cfg.d_inner, cfg.ssm_state, cfg.ssm_groups,
                        cfg.n_ssm_heads, cfg.ssm_head_dim)
    zxbcdt = x @ p["in_proj"]
    z, xbc, dt = jnp.split(zxbcdt, [din, 2 * din + 2 * g * n], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # [B,S,H]
    a = -jnp.exp(p["a_log"])  # [H], negative

    if state is None or s > 1:
        tail = state.conv if state is not None else None
        xbc, new_tail = _causal_conv(xbc, p["conv_w"], p["conv_b"], tail)
        xs, b, c = jnp.split(xbc, [din, din + g * n], axis=-1)
        xh = xs.reshape(bsz, s, h, pp)
        bb = b.reshape(bsz, s, g, n)
        cc = c.reshape(bsz, s, g, n)
        pad = (-s) % cfg.ssm_chunk
        if pad:
            xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
            dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
            bb = jnp.pad(bb, ((0, 0), (0, pad), (0, 0), (0, 0)))
            cc = jnp.pad(cc, ((0, 0), (0, pad), (0, 0), (0, 0)))
        init = state.ssm if state is not None else None
        y, final = ssd_chunked(xh, dt, a, bb, cc, chunk=cfg.ssm_chunk,
                               initial_state=init, return_final_state=True)
        y = y[:, :s] + xh[:, :s] * p["d_skip"][None, None, :, None]
        new_state = SSMState(final, new_tail) if state is not None else None
    else:
        xbc, new_tail = _causal_conv(xbc, p["conv_w"], p["conv_b"], state.conv)
        xs, b, c = jnp.split(xbc, [din, din + g * n], axis=-1)
        xh = xs.reshape(bsz, h, pp)  # S == 1
        yh, new_ssm = ssd_decode_step(state.ssm, xh, dt[:, 0], a,
                                      b.reshape(bsz, g, n), c.reshape(bsz, g, n))
        y = (yh + xh * p["d_skip"][None, :, None])[:, None]
        new_state = SSMState(new_ssm, new_tail)

    y = y.reshape(bsz, s, din)
    # gated RMSNorm (mamba2's norm-before-out-proj)
    yf = y.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32))
    var = jnp.mean(jnp.square(yf), axis=-1, keepdims=True)
    yf = yf * jax.lax.rsqrt(var + 1e-6) * p["norm_scale"]
    return yf.astype(x.dtype) @ p["out_proj"], new_state


def ssm_state_zeros(cfg, batch: int, dtype) -> SSMState:
    return SSMState(
        jnp.zeros((batch, cfg.n_ssm_heads, cfg.ssm_head_dim, cfg.ssm_state),
                  jnp.float32),
        jnp.zeros((batch, cfg.ssm_conv_width - 1, cfg.conv_dim), dtype),
    )
