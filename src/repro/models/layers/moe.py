"""Mixture-of-experts FFN with capacity-based dispatch (Switch-style).

Supports fine-grained MoE (deepseek: 64 routed top-6 + 2 shared experts,
narrow d_expert) and classic MoE (granite: 32 routed top-8).

Dispatch is capacity-based gather/scatter: tokens are routed to at most
``capacity`` slots per expert; experts run as one batched einsum over
stacked weights [E, D, F] (sharded over the 'model' axis = expert
parallelism). FLOPs are O(top_k * tokens * D * F) — the active-parameter
count — so the roofline 'useful FLOPs' ratio stays honest.

The expert all-to-all is the MoE incarnation of the paper's TX/RX balance
problem: dispatch (TX) and combine (RX) share the same ICI links, and the
blocks-mode chunking in repro.core.pipeline_collectives applies to both.
"""

from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp


class MoEMetrics(NamedTuple):
    aux_loss: jax.Array  # load-balance loss (Switch)
    dropped_frac: jax.Array  # fraction of (token, slot) pairs over capacity


def _shard_experts(x: jax.Array, spec) -> jax.Array:
    """Constrain an expert-major intermediate to expert-parallel over the
    'model' axis. No-op when no mesh is active (CPU tests) or the expert
    count doesn't divide the axis."""
    try:
        return jax.lax.with_sharding_constraint(
            x, jax.sharding.PartitionSpec(*spec))
    except Exception:  # noqa: BLE001 — sharding hints must never break math
        return x


def moe_params(key, d_model: int, n_experts: int, d_expert: int,
               n_shared: int, dtype) -> dict:
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    sd_in = 1.0 / math.sqrt(d_model)
    sd_out = 1.0 / math.sqrt(2.0 * d_expert)
    p = {
        "router": (jax.random.normal(k1, (d_model, n_experts)) * sd_in)
        .astype(jnp.float32),
        "we_up": (jax.random.normal(k2, (n_experts, d_model, 2 * d_expert))
                  * sd_in).astype(dtype),
        "we_down": (jax.random.normal(k3, (n_experts, d_expert, d_model))
                    * sd_out).astype(dtype),
    }
    if n_shared:
        p["ws_up"] = (jax.random.normal(k4, (d_model, 2 * n_shared * d_expert))
                      * sd_in).astype(dtype)
        p["ws_down"] = (jax.random.normal(k5, (n_shared * d_expert, d_model))
                        * sd_out).astype(dtype)
    return p


def moe_apply(p: dict, x: jax.Array, *, top_k: int,
              capacity_factor: float = 1.25,
              ep_sharding: bool = True) -> tuple[jax.Array, MoEMetrics]:
    """x: [B, S, D] -> [B, S, D].

    Routing: softmax over experts, top-k, weights renormalised over the k.
    Tokens beyond an expert's capacity are dropped (their residual passes
    through) — standard capacity-based MoE semantics."""
    b, s, d = x.shape
    e = p["we_up"].shape[0]
    t = b * s
    xt = x.reshape(t, d)

    logits = (xt.astype(jnp.float32) @ p["router"])  # [T, E]
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, top_k)  # [T, K]
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    capacity = int(math.ceil(top_k * t / e * capacity_factor))
    capacity = max(capacity, 1)

    # position of each (token, slot) within its expert queue, k-major so the
    # primary expert of every token is seated before any secondary slots.
    # §Perf iteration B1: sort-based seat assignment — O(TK log TK) time and
    # O(TK) memory, replacing the one-hot cumsum whose [T*K, E] int32
    # materialisation dominated prefill_32k temp memory (105 GiB/device for
    # deepseek-moe: T=1M, K=6, E=64).
    flat_e = gate_idx.T.reshape(-1)  # [K*T], slot-major
    tk = flat_e.shape[0]
    order = jnp.argsort(flat_e, stable=True)  # seats grouped by expert
    sorted_e = flat_e[order]
    arange = jnp.arange(tk, dtype=jnp.int32)
    is_start = jnp.concatenate(
        [jnp.ones((1,), bool), sorted_e[1:] != sorted_e[:-1]])
    group_start = jax.lax.associative_scan(
        jnp.maximum, jnp.where(is_start, arange, 0))
    pos_sorted = arange - group_start
    pos = jnp.zeros((tk,), jnp.int32).at[order].set(pos_sorted)
    keep = pos < capacity
    dropped = 1.0 - keep.mean()

    # dispatch into [E, C, D].
    # §Perf iteration B2/B3: per-k-slot dispatch + combine. The slot-major
    # [K*T, D] formulation materialised 48 GiB replicated f32 intermediates
    # and a 48 GiB all-reduce per layer (GSPMD gathering from the expert-
    # sharded buffer); per-k loops keep every tensor either token-major
    # [T, D] (data-sharded) or expert-major [E, C, D] (model-sharded).
    slot = jnp.where(keep, flat_e * capacity + pos, e * capacity)  # OOB -> drop
    slot_k = slot.reshape(top_k, t)  # [K, T]
    buf = jnp.zeros((e * capacity + 1, d), x.dtype)
    for k in range(top_k):
        buf = buf.at[slot_k[k]].set(xt, mode="drop")
    xe = buf[:-1].reshape(e, capacity, d)
    ep = ("model", None, None)
    if ep_sharding:
        xe = _shard_experts(xe, ep)

    # expert FFN (gated silu), batched over experts
    h = jnp.einsum("ecd,edf->ecf", xe, p["we_up"])
    if ep_sharding:
        h = _shard_experts(h, ep)
    gate, up = jnp.split(h, 2, axis=-1)
    h = jax.nn.silu(gate) * up
    ye = jnp.einsum("ecf,efd->ecd", h, p["we_down"])  # [E, C, D]
    if ep_sharding:
        ye = _shard_experts(ye, ep)

    # combine: per-k gather (token-major, no scatter at all)
    yflat = ye.reshape(e * capacity, d)
    w = jnp.where(keep, gate_vals.T.reshape(-1), 0.0).astype(x.dtype)  # [K*T]
    w_k = w.reshape(top_k, t)
    out = jnp.zeros((t, d), x.dtype)
    for k in range(top_k):
        got = yflat[jnp.minimum(slot_k[k], e * capacity - 1)]  # [T, D]
        if ep_sharding:
            got = _shard_experts(got, ("data", None))  # token-major again
        out = out + got * w_k[k][:, None]

    # shared experts (always-on)
    if "ws_up" in p:
        hs = xt @ p["ws_up"]
        gs, us = jnp.split(hs, 2, axis=-1)
        out = out + (jax.nn.silu(gs) * us) @ p["ws_down"]

    # Switch aux loss: E * sum_e f_e * P_e
    f_e = jnp.zeros((e,), jnp.float32).at[flat_e].add(
        keep.astype(jnp.float32)) / jnp.maximum(keep.sum(), 1)
    p_e = probs.mean(0)
    aux = e * jnp.sum(f_e * p_e)
    return out.reshape(b, s, d), MoEMetrics(aux, dropped)
