"""Unified decoder-only LM covering dense / vlm / moe / ssm families.

Layers are scan-stacked (params have a leading [L] axis) so HLO size is
O(1) in depth — essential for the 512-device dry-run compiles and the
production remat policy. Families share the same skeleton:

    x -> [ block_0 ... block_{L-1} ] -> final_norm -> lm_head

where block is (norm -> mixer -> residual -> norm -> ffn -> residual) and
the mixer/ffn pair depends on the family (attention+MLP, attention+MoE,
or Mamba2 which fuses mixer+ffn in one block).
"""

from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers.attention import KVCache, attn_apply, attn_params
from repro.models.layers.mlp import mlp_apply, mlp_params
from repro.models.layers.moe import moe_apply, moe_params
from repro.models.layers.norm import apply_norm, norm_params
from repro.models.layers.ssm import (
    SSMState,
    mamba2_apply,
    mamba2_params,
    ssm_state_zeros,
)


def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


def make_remat(cfg: ModelConfig):
    """Block-level jax.checkpoint wrapper honouring cfg.remat_policy."""
    if not cfg.remat:
        return lambda f: f
    if cfg.remat_policy == "dots_nb":
        pol = jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
        return lambda f: jax.checkpoint(f, policy=pol)
    return jax.checkpoint


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def init_block_params(key, cfg: ModelConfig) -> dict:
    """Params for ONE block (caller vmaps over layers to stack)."""
    dt = _dtype(cfg)
    ks = jax.random.split(key, 4)
    if cfg.family == "ssm":
        return {
            "ln1": norm_params(cfg.norm, cfg.d_model),
            "mixer": mamba2_params(ks[0], cfg, dt),
        }
    p = {
        "ln1": norm_params(cfg.norm, cfg.d_model),
        "attn": attn_params(ks[0], cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                            cfg.head_dim_, bias=cfg.qkv_bias, dtype=dt),
        "ln2": norm_params(cfg.norm, cfg.d_model),
    }
    if cfg.family == "moe":
        p["moe"] = moe_params(ks[1], cfg.d_model, cfg.n_experts,
                              cfg.d_expert or cfg.d_ff, cfg.n_shared_experts, dt)
    else:
        p["mlp"] = mlp_params(ks[1], cfg.d_model, cfg.d_ff, cfg.mlp, dt)
    return p


def init_params(key, cfg: ModelConfig) -> dict:
    dt = _dtype(cfg)
    k_emb, k_blocks, k_head = jax.random.split(key, 3)
    blocks = jax.vmap(lambda k: init_block_params(k, cfg))(
        jax.random.split(k_blocks, cfg.n_layers))
    params = {
        "embed": (jax.random.normal(k_emb, (cfg.vocab_padded, cfg.d_model))
                  * (1.0 / math.sqrt(cfg.d_model))).astype(dt),
        "blocks": blocks,
        "final_norm": norm_params(cfg.norm, cfg.d_model),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = (
            jax.random.normal(k_head, (cfg.d_model, cfg.vocab_padded))
            * (1.0 / math.sqrt(cfg.d_model))).astype(dt)
    return params


# ---------------------------------------------------------------------------
# one block
# ---------------------------------------------------------------------------

def block_apply(cfg: ModelConfig, p: dict, x: jax.Array, *,
                cache: Any = None, positions=None):
    """Returns (x, new_cache, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    if cfg.family == "ssm":
        h, new_state = mamba2_apply(p["mixer"], apply_norm(cfg.norm, p["ln1"], x),
                                    cfg, state=cache)
        return x + h, new_state, aux
    h, new_cache = attn_apply(
        p["attn"], apply_norm(cfg.norm, p["ln1"], x),
        n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads, head_dim=cfg.head_dim_,
        rope_theta=cfg.rope_theta, window=cfg.sliding_window,
        kv_chunk=cfg.attn_kv_chunk, blocks_threshold=cfg.attn_blocks_threshold,
        use_pallas=cfg.use_pallas_attention, pallas_interpret=cfg.pallas_interpret,
        cache=cache, positions=positions)
    x = x + h
    h2 = apply_norm(cfg.norm, p["ln2"], x)
    if cfg.family == "moe":
        h2, metrics = moe_apply(p["moe"], h2, top_k=cfg.top_k,
                                capacity_factor=cfg.capacity_factor,
                                ep_sharding=cfg.moe_ep_sharding)
        aux = metrics.aux_loss
    else:
        h2 = mlp_apply(p["mlp"], h2, cfg.mlp)
    return x + h2, new_cache, aux


# ---------------------------------------------------------------------------
# forward passes
# ---------------------------------------------------------------------------

def _stack_scan(cfg: ModelConfig, params: dict, x: jax.Array, caches,
                positions):
    """Scan blocks over the stacked [L, ...] params (+ optional caches)."""

    def body(carry, layer_in):
        h = carry
        if caches is None:
            lp = layer_in
            h, _, aux = block_apply(cfg, lp, h, positions=positions)
            return h, aux
        lp, lc = layer_in
        h, nc, aux = block_apply(cfg, lp, h, cache=lc, positions=positions)
        return h, (nc, aux)

    fn = make_remat(cfg)(body)
    xs = params["blocks"] if caches is None else (params["blocks"], caches)
    x, out = jax.lax.scan(fn, x, xs)
    if caches is None:
        return x, None, out.sum()
    new_caches, aux = out
    return x, new_caches, aux.sum()


def embed_tokens(cfg: ModelConfig, params: dict, tokens: jax.Array,
                 prefix_embeds: jax.Array | None = None) -> jax.Array:
    x = params["embed"][tokens]
    if prefix_embeds is not None:  # vlm: image patches before text
        x = jnp.concatenate([prefix_embeds.astype(x.dtype), x], axis=1)
    return x


def logits_from_hidden(cfg: ModelConfig, params: dict, x: jax.Array) -> jax.Array:
    x = apply_norm(cfg.norm, params["final_norm"], x)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    return jnp.einsum("bsd,dv->bsv", x, head, preferred_element_type=jnp.float32)


def forward(cfg: ModelConfig, params: dict, tokens: jax.Array, *,
            prefix_embeds: jax.Array | None = None):
    """Training forward: tokens [B, S_text] -> logits [B, S, Vp], aux."""
    x = embed_tokens(cfg, params, tokens, prefix_embeds)
    positions = jnp.arange(x.shape[1])
    x, _, aux = _stack_scan(cfg, params, x, None, positions)
    return logits_from_hidden(cfg, params, x), aux


def init_cache(cfg: ModelConfig, batch: int, s_max: int):
    """Stacked [L, ...] decode cache."""
    dt = _dtype(cfg)
    if cfg.family == "ssm":
        st = ssm_state_zeros(cfg, batch, dt)
        return jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (cfg.n_layers,) + a.shape), st)
    kv = KVCache.zeros(batch, s_max, cfg.n_kv_heads, cfg.head_dim_, dt)
    return KVCache(
        jnp.broadcast_to(kv.k[None], (cfg.n_layers,) + kv.k.shape),
        jnp.broadcast_to(kv.v[None], (cfg.n_layers,) + kv.v.shape),
        jnp.zeros((cfg.n_layers,), jnp.int32),
    )


def prefill(cfg: ModelConfig, params: dict, tokens: jax.Array, s_max: int, *,
            prefix_embeds: jax.Array | None = None):
    """Fill the cache from a prompt; returns (last_logits, cache)."""
    x = embed_tokens(cfg, params, tokens, prefix_embeds)
    caches = init_cache(cfg, x.shape[0], s_max)
    positions = jnp.arange(x.shape[1])
    x, new_caches, _ = _stack_scan(cfg, params, x, caches, positions)
    return logits_from_hidden(cfg, params, x[:, -1:]), new_caches


def prefill_chunked(cfg: ModelConfig, params: dict, tokens: jax.Array,
                    s_max: int, *, chunk: int = 4096,
                    prefix_embeds: jax.Array | None = None):
    """Blocks-mode prefill: run the prompt through the stack in sequence
    chunks, carrying the KV cache between chunks.

    Bounds every per-token intermediate (attention scores, MoE dispatch
    buffers) to O(B*chunk) instead of O(B*S) — the paper's Blocks
    partitioning applied to the prompt dimension. Semantically identical to
    :func:`prefill` (causal attention never looks ahead)."""
    x = embed_tokens(cfg, params, tokens, prefix_embeds)
    s = x.shape[1]
    caches = init_cache(cfg, x.shape[0], s_max)
    if s % chunk:
        raise ValueError(f"prompt length {s} not divisible by chunk {chunk}")
    last = None
    for c0 in range(0, s, chunk):
        xc = x[:, c0 : c0 + chunk]
        xc, caches, _ = _stack_scan(cfg, params, xc, caches, None)
        last = xc[:, -1:]
    return logits_from_hidden(cfg, params, last), caches


def decode_step(cfg: ModelConfig, params: dict, token: jax.Array, caches):
    """One decode step. token: [B, 1]; caches from prefill/init_cache."""
    x = embed_tokens(cfg, params, token)
    x, new_caches, _ = _stack_scan(cfg, params, x, caches, None)
    return logits_from_hidden(cfg, params, x), new_caches
