"""Production meshes.

Single pod : (data=16, model=16)           = 256 chips (TPU v5e pod)
Multi-pod  : (pod=2, data=16, model=16)    = 512 chips

Functions, not module-level constants: importing this module must never
touch jax device state (the dry-run sets XLA_FLAGS before first jax init).
"""

from __future__ import annotations

import jax

from repro.utils.jax_compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_local_mesh(model_parallel: int = 1):
    """Mesh over whatever devices exist (tests / smoke runs)."""
    n = len(jax.devices())
    data = n // model_parallel
    return make_mesh((data, model_parallel), ("data", "model"))
