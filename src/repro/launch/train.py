"""End-to-end training driver.

  PYTHONPATH=src python -m repro.launch.train --arch qwen2.5-3b --smoke \
      --steps 50 --batch 8 --seq 128 --policy interrupt

Runs the real Trainer (fault-tolerant loop, policy-driven data staging,
async checkpoints) on this machine's devices. --smoke selects the reduced
same-family config (the full configs need a pod; use launch.dryrun for
those). The transfer policy chooses the paper's driver mode for host->device
batch staging — the measured difference is printed at the end.
"""

from __future__ import annotations

import argparse
import json

import jax

from repro.configs.registry import ARCHS, get_config, smoke_config
from repro.core.transfer import Buffering, Management, Partitioning, TransferPolicy
from repro.data.pipeline import DataConfig, StagedPipeline, SyntheticLMSource
from repro.models.api import build_model
from repro.optim import AdamWConfig
from repro.train.loop import TrainConfig, Trainer

POLICIES = {
    "polling": TransferPolicy.user_level_polling,
    "scheduled": TransferPolicy.user_level_scheduled,
    "interrupt": TransferPolicy.kernel_level,
    "interrupt-double-blocks": lambda: TransferPolicy(
        Management.INTERRUPT, Buffering.DOUBLE, Partitioning.BLOCKS),
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCHS, default="qwen2.5-3b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--policy", choices=sorted(POLICIES), default="interrupt")
    ap.add_argument("--checkpoint-dir", default="")
    ap.add_argument("--checkpoint-every", type=int, default=50)
    args = ap.parse_args()

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    model = build_model(cfg)
    tcfg = TrainConfig(
        steps=args.steps, n_microbatches=args.microbatches,
        warmup=max(args.steps // 10, 1),
        opt=AdamWConfig(lr=args.lr),
        checkpoint_dir=args.checkpoint_dir,
        checkpoint_every=args.checkpoint_every)
    policy = POLICIES[args.policy]()
    source = SyntheticLMSource(
        DataConfig(global_batch=args.batch, seq_len=args.seq), cfg)
    pipe = StagedPipeline(source, policy)
    trainer = Trainer(model, tcfg)
    print(f"training {cfg.name} for {args.steps} steps "
          f"(policy={policy.tag}, devices={len(jax.devices())})")
    out = trainer.run(pipe)
    pipe.close()
    for row in trainer.history:
        print(json.dumps({k: round(v, 4) for k, v in row.items()}))
    f = out["fault"]
    print(f"done. restarts={f.restarts} stragglers={f.stragglers_detected} "
          f"skipped_nonfinite={f.steps_skipped_nonfinite}")


if __name__ == "__main__":
    main()
