"""Trip-count-aware cost analysis over compiled HLO text.

XLA's built-in ``compiled.cost_analysis()`` counts each while-loop body ONCE,
which silently undercounts scanned layer stacks by O(n_layers x
n_microbatches) — fatal for roofline math. This module re-derives the three
roofline quantities exactly by walking the HLO call graph with the
``known_trip_count`` annotations the CPU/TPU pipelines attach to lowered
scans:

- FLOPs              : dot / convolution ops (MXU work; elementwise VPU work
                       is negligible at LM shapes and excluded, as in
                       standard MFU accounting)
- bytes accessed     : per op, operand bytes + result bytes; fusions are
                       costed at the call site only (their internals stay in
                       registers/VMEM), which matches real HBM traffic far
                       better than summing fused sub-ops
- collective bytes   : effective ring bytes per collective (see
                       repro.launch.hlo_analysis for the per-kind factors),
                       multiplied up through loop trip counts

Validated against XLA's own cost_analysis on fully-unrolled variants
(tests/test_hlo_cost.py).
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "token": 0, "opaque": 0,
}

_SHAPE_TOKEN = re.compile(r"([a-z]\d+|pred|bf16|token|opaque)\[([\d,]*)\]")


def shape_bytes(type_str: str) -> int:
    """Total bytes of a (possibly tuple) HLO type string."""
    total = 0
    for dt, dims in _SHAPE_TOKEN.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def shape_dims(type_str: str) -> list[int]:
    """Dims of the FIRST array shape in the type string."""
    m = _SHAPE_TOKEN.search(type_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


@dataclass
class Op:
    name: str
    type_str: str
    opcode: str
    operands: list[str]
    line: str


@dataclass
class Computation:
    name: str
    ops: dict = field(default_factory=dict)  # name -> Op
    order: list = field(default_factory=list)


@dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    collective_bytes: float = 0.0
    collective_by_kind: dict = field(default_factory=dict)

    def __add__(self, o: "Cost") -> "Cost":
        kinds = dict(self.collective_by_kind)
        for k, v in o.collective_by_kind.items():
            kinds[k] = kinds.get(k, 0.0) + v
        return Cost(self.flops + o.flops, self.bytes + o.bytes,
                    self.collective_bytes + o.collective_bytes, kinds)

    def __mul__(self, n: float) -> "Cost":
        return Cost(self.flops * n, self.bytes * n,
                    self.collective_bytes * n,
                    {k: v * n for k, v in self.collective_by_kind.items()})


_COMP_HEADER = re.compile(r"^(?:ENTRY )?%?([\w.\-]+) (?:\([^{]*\))?.*\{\s*$")
_OP_LINE = re.compile(
    r"^\s+(?:ROOT )?%?([\w.\-]+) = ((?:\([^)]*\)|[a-z]\d*[\w]*\[[\d,]*\]"
    r"(?:\{[^}]*\})?)) ([\w\-]+)\((.*)$")
_OPERAND = re.compile(r"%([\w.\-]+)")
_TRIP = re.compile(r"known_trip_count\D*(\d+)")
_CALLS = re.compile(r"calls=%?([\w.\-]+)")
_TO_APPLY = re.compile(r"to_apply=%?([\w.\-]+)")
_BODY = re.compile(r"body=%?([\w.\-]+)")
_COND = re.compile(r"condition=%?([\w.\-]+)")
_CONTRACT = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_BATCH = re.compile(r"lhs_batch_dims=\{([\d,]*)\}")
_WINDOW_SIZE = re.compile(r"window=\{[^}]*size=([\dx]+)")
_FEATURE_GROUPS = re.compile(r"feature_group_count=(\d+)")


def parse_module(hlo_text: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    cur: Computation | None = None
    for raw in hlo_text.splitlines():
        line = raw.rstrip()
        if not line:
            continue
        if not line.startswith(" ") and line.endswith("{"):
            m = _COMP_HEADER.match(line.strip())
            if m:
                cur = Computation(m.group(1))
                comps[cur.name] = cur
            continue
        if line.strip() == "}":
            continue
        if cur is None:
            continue
        m = _OP_LINE.match(line)
        if not m:
            continue
        name, type_str, opcode, rest = m.groups()
        # operand names: only up to the closing paren of the op call
        depth, end = 0, len(rest)
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                if depth == 0:
                    end = i
                    break
                depth -= 1
        operands = _OPERAND.findall(rest[:end])
        op = Op(name, type_str, opcode, operands, line.strip())
        cur.ops[name] = op
        cur.order.append(name)
    return comps


def _operand_type(comp: Computation, comps: dict, name: str) -> str:
    op = comp.ops.get(name)
    return op.type_str if op else ""


def _dot_flops(comp: Computation, op: Op) -> float:
    out_elems = 1
    for d in shape_dims(op.type_str):
        out_elems *= d
    lhs = comp.ops.get(op.operands[0]) if op.operands else None
    if lhs is None:
        return 0.0
    lhs_dims = shape_dims(lhs.type_str)
    m = _CONTRACT.search(op.line)
    contract = [int(x) for x in m.group(1).split(",") if x] if m else []
    k = 1
    for c in contract:
        if c < len(lhs_dims):
            k *= lhs_dims[c]
    return 2.0 * out_elems * k


def _conv_flops(comp: Computation, op: Op) -> float:
    out_elems = 1
    for d in shape_dims(op.type_str):
        out_elems *= d
    rhs = comp.ops.get(op.operands[1]) if len(op.operands) > 1 else None
    if rhs is None:
        return 0.0
    m = _WINDOW_SIZE.search(op.line)
    spatial = 1
    if m:
        for s in m.group(1).split("x"):
            spatial *= int(s)
    rhs_dims = shape_dims(rhs.type_str)
    # kernel layout has input-feature dim; approximate as elems/(spatial*Cout)
    cout = shape_dims(op.type_str)[-1] if shape_dims(op.type_str) else 1
    cin = 1
    if rhs_dims:
        total = 1
        for d in rhs_dims:
            total *= d
        cin = max(total // max(spatial * cout, 1), 1)
    g = 1
    mg = _FEATURE_GROUPS.search(op.line)
    if mg:
        g = int(mg.group(1))
    return 2.0 * out_elems * spatial * cin / g


_COLL_KINDS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")


def _collective_cost(comp: Computation, op: Op, world: int) -> tuple[str, float]:
    from repro.launch.hlo_analysis import _group_size  # shared parser
    kind = op.opcode.replace("-start", "")
    size = shape_bytes(op.type_str)
    if op.opcode.endswith("-start") and op.type_str.startswith("("):
        size //= 2  # start ops carry (operand, result) tuples
    g = _group_size(op.line, world)
    frac = (g - 1) / g if g > 1 else 0.0
    if kind == "all-gather":
        eff = size * frac
    elif kind == "all-reduce":
        eff = 2 * size * frac
    elif kind == "reduce-scatter":
        eff = size * frac * g
    elif kind == "all-to-all":
        eff = size * frac
    elif kind == "collective-permute":
        eff = size
    else:
        return kind, 0.0
    return kind, eff


class HloCostModel:
    """Walks the call graph once per computation (memoized)."""

    # opcodes that don't move HBM bytes at the call site
    _FREE = {"parameter", "constant", "tuple", "get-tuple-element",
             "bitcast", "copy-done", "all-gather-done", "all-reduce-done",
             "collective-permute-done", "async-done", "after-all"}

    def __init__(self, hlo_text: str, world: int = 1):
        self.comps = parse_module(hlo_text)
        self.world = world
        self._memo: dict[str, Cost] = {}

    def entry_cost(self) -> Cost:
        entry = None
        for name, comp in self.comps.items():
            if "main" in name:
                entry = comp
        if entry is None:  # fall back to the last computation
            entry = list(self.comps.values())[-1]
        return self._comp_cost(entry.name)

    def _comp_cost(self, name: str) -> Cost:
        if name in self._memo:
            return self._memo[name]
        comp = self.comps.get(name)
        if comp is None:
            return Cost()
        self._memo[name] = Cost()  # cycle guard
        total = Cost()
        for op_name in comp.order:
            total = total + self._op_cost(comp, comp.ops[op_name])
        self._memo[name] = total
        return total

    def _op_bytes(self, comp: Computation, op: Op) -> float:
        if op.opcode in self._FREE:
            return 0.0
        b = float(shape_bytes(op.type_str))
        for o in op.operands:
            b += shape_bytes(_operand_type(comp, self.comps, o))
        return b

    def _op_cost(self, comp: Computation, op: Op) -> Cost:
        oc = op.opcode
        if oc == "while":
            m = _TRIP.search(op.line)
            n = int(m.group(1)) if m else 1
            body = _BODY.search(op.line)
            cond = _COND.search(op.line)
            c = Cost()
            if body:
                c = c + self._comp_cost(body.group(1)) * n
            if cond:
                c = c + self._comp_cost(cond.group(1)) * (n + 1)
            return c
        if oc in ("call", "custom-call"):
            m = _TO_APPLY.search(op.line)
            c = Cost(bytes=self._op_bytes(comp, op))
            if m:
                c = c + self._comp_cost(m.group(1))
            return c
        if oc == "fusion":
            m = _CALLS.search(op.line)
            inner = self._comp_cost(m.group(1)) if m else Cost()
            # bytes at the call boundary only; flops/collectives from inside
            return Cost(flops=inner.flops,
                        bytes=self._op_bytes(comp, op),
                        collective_bytes=inner.collective_bytes,
                        collective_by_kind=inner.collective_by_kind)
        if oc == "conditional":
            # cost the worst branch (dry-run upper bound)
            branches = re.findall(r"(?:branch_computations=\{([^}]*)\}|"
                                  r"(?:true|false)_computation=%?([\w.\-]+))",
                                  op.line)
            names = []
            for grp in branches:
                for g in grp:
                    if g:
                        names += [x.strip().lstrip("%") for x in g.split(",")]
            costs = [self._comp_cost(n) for n in names if n]
            best = max(costs, key=lambda c: c.flops + c.bytes, default=Cost())
            return best + Cost(bytes=self._op_bytes(comp, op))
        if oc.replace("-start", "") in _COLL_KINDS:
            kind, eff = _collective_cost(comp, op, self.world)
            return Cost(bytes=self._op_bytes(comp, op),
                        collective_bytes=eff, collective_by_kind={kind: eff})
        if oc == "dot":
            return Cost(flops=_dot_flops(comp, op),
                        bytes=self._op_bytes(comp, op))
        if oc == "convolution":
            return Cost(flops=_conv_flops(comp, op),
                        bytes=self._op_bytes(comp, op))
        return Cost(bytes=self._op_bytes(comp, op))


def analyze(hlo_text: str, world: int = 1) -> Cost:
    return HloCostModel(hlo_text, world).entry_cost()


# ---------------------------------------------------------------------------
# Profiling: top traffic contributors (drives §Perf iterations)
# ---------------------------------------------------------------------------

def computation_multipliers(model: HloCostModel, entry: str | None = None
                            ) -> dict[str, int]:
    """Total execution count of each computation (trip counts multiplied
    down the call chain) — the 'x288' factors in the §Perf profiles."""
    mult: dict[str, int] = {}

    def visit(name: str, factor: int, depth: int = 0) -> None:
        if depth > 64:
            return
        mult[name] = mult.get(name, 0) + factor
        comp = model.comps.get(name)
        if comp is None:
            return
        for op in comp.ops.values():
            if op.opcode == "while":
                tr = _TRIP.search(op.line)
                n = int(tr.group(1)) if tr else 1
                b = _BODY.search(op.line)
                c = _COND.search(op.line)
                if b:
                    visit(b.group(1), factor * n, depth + 1)
                if c:
                    visit(c.group(1), factor * (n + 1), depth + 1)
            elif op.opcode == "call":
                ta = _TO_APPLY.search(op.line)
                if ta:
                    visit(ta.group(1), factor, depth + 1)

    if entry is None:
        cands = [n for n in model.comps if "main" in n]
        entry = cands[-1] if cands else list(model.comps)[-1]
    visit(entry, 1)
    return mult


def top_traffic_ops(hlo_text: str, world: int = 1, n: int = 20
                    ) -> list[dict]:
    """Rank ops by effective HBM bytes (op bytes x execution count).

    The dry-run's --profile flag prints this; §Perf iterations start here."""
    model = HloCostModel(hlo_text, world)
    mult = computation_multipliers(model)
    skip = {"while", "parameter", "constant", "tuple", "get-tuple-element",
            "bitcast"}
    rows = []
    for cname, factor in mult.items():
        comp = model.comps[cname]
        for op in comp.ops.values():
            if op.opcode in skip:
                continue
            b = model._op_bytes(comp, op)
            eff = b * factor
            if eff > 0:
                rows.append({"effective_bytes": eff, "opcode": op.opcode,
                             "shape": op.type_str[:64], "count": factor,
                             "computation": cname[:48]})
    rows.sort(key=lambda r: -r["effective_bytes"])
    return rows[:n]
