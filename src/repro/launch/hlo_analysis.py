"""Extract roofline terms from a compiled (SPMD-partitioned) HLO module.

cost_analysis() gives per-device FLOPs and bytes, but NOT collective
traffic; we parse the post-partitioning HLO text and sum the bytes moved by
every collective op, with ring-algorithm effective-bytes factors:

  all-gather       : result_bytes * (g-1)/g      per device
  reduce-scatter   : operand_bytes * (g-1)/g     (operand = g * result)
  all-reduce       : 2 * operand_bytes * (g-1)/g (RS + AG phases)
  all-to-all       : operand_bytes * (g-1)/g
  collective-permute: operand_bytes

g = collective group size, parsed from replica_groups (explicit or iota).
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field


_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLLECTIVE_KINDS = ("all-gather", "all-reduce", "reduce-scatter",
                     "all-to-all", "collective-permute")


def _shape_bytes(shape_str: str) -> int:
    """'bf16[256,1024]' -> bytes. Tuples handled by caller."""
    m = _SHAPE_RE.match(shape_str)
    if not m:
        return 0
    dt, dims = m.groups()
    if dt not in _DTYPE_BYTES:
        return 0
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES[dt]


def _group_size(line: str, world: int) -> int:
    # iota format: replica_groups=[64,8]<=[512] -> 64 groups of 8
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]<=\[", line)
    if m:
        return int(m.group(2))
    # explicit: replica_groups={{0,1,2,3},{...}} -> size of first group
    m = re.search(r"replica_groups=\{\{([\d,]+)\}", line)
    if m:
        return len(m.group(1).split(","))
    # collective-permute has source_target_pairs instead
    if "source_target_pairs" in line:
        return 2
    return world


@dataclass
class CollectiveStats:
    bytes_by_kind: dict = field(default_factory=dict)
    count_by_kind: dict = field(default_factory=dict)

    @property
    def total_bytes(self) -> float:
        return float(sum(self.bytes_by_kind.values()))

    def row(self) -> dict:
        return {"collective_bytes": self.total_bytes,
                "by_kind": {k: float(v) for k, v in self.bytes_by_kind.items()},
                "counts": dict(self.count_by_kind)}


def collective_bytes(hlo_text: str, world: int) -> CollectiveStats:
    """Sum effective bytes moved per device by collectives in HLO text."""
    stats = CollectiveStats()
    for line in hlo_text.splitlines():
        s = line.strip()
        # result-shape = op-name(...) — match '<shape> <kind>(' and start ops
        m = re.match(r"%?[\w.\-]+ = ((?:\([^)]*\)|\w+\[[\d,]*\][^ ]*)) "
                     r"(all-gather|all-reduce|reduce-scatter|all-to-all|"
                     r"collective-permute)(-start)?\(", s)
        if not m:
            continue
        shape_str, kind, started = m.group(1), m.group(2), m.group(3)
        # tuple shapes (var-operand all-reduce / -start ops): sum elements
        if shape_str.startswith("("):
            inner = shape_str[1:-1]
            size = sum(_shape_bytes(p.strip())
                       for p in re.findall(r"\w+\[[\d,]*\]", inner))
            if started:  # start ops carry (operand, result [, ctx]) tuples
                size //= 2
        else:
            size = _shape_bytes(shape_str)
        g = _group_size(s, world)
        frac = (g - 1) / g if g > 1 else 0.0
        if kind == "all-gather":
            eff = size * frac  # size = gathered result
        elif kind == "all-reduce":
            eff = 2 * size * frac
        elif kind == "reduce-scatter":
            eff = size * frac * g  # size = scattered result; operand = g*size
        elif kind == "all-to-all":
            eff = size * frac
        else:  # collective-permute
            eff = size
        stats.bytes_by_kind[kind] = stats.bytes_by_kind.get(kind, 0.0) + eff
        stats.count_by_kind[kind] = stats.count_by_kind.get(kind, 0) + 1
    return stats


def duplicate_fusion_count(hlo_text: str) -> int:
    """Rough remat indicator: repeated identical fusion shapes (same op name
    root repeated) — used in §Perf iteration notes."""
    names = re.findall(r"%(fusion[\w.\-]*) =", hlo_text)
    return len(names) - len(set(names))
