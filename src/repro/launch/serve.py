"""Serving driver: batched generation with a policy-driven engine.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-3b --smoke \
      --batch 4 --prompt-len 32 --new-tokens 32
"""

from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs.registry import ARCHS, get_config, smoke_config
from repro.models.api import build_model
from repro.serve.engine import ServeConfig, ServingEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCHS, default="qwen2.5-3b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    max_seq = args.prompt_len + args.new_tokens + 8
    eng = ServingEngine(model, params,
                        ServeConfig(max_batch=args.batch, max_seq=max_seq,
                                    temperature=args.temperature))
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab, (args.batch, args.prompt_len),
                           dtype=np.int32)
    extra = {}
    if cfg.family == "vlm":
        extra["patch_embeds"] = rng.standard_normal(
            (args.batch, cfg.n_prefix_tokens, cfg.d_model)).astype(np.float32)
    if cfg.family == "audio":
        extra["frames"] = rng.standard_normal(
            (args.batch, args.prompt_len, cfg.d_model)).astype(np.float32)
    res = eng.generate(prompts, max_new_tokens=args.new_tokens,
                       extra_inputs=extra or None)
    for i, r in enumerate(res):
        print(f"req{i}: prefill={r.prefill_s*1e3:.1f}ms "
              f"decode={r.decode_s*1e3:.1f}ms tok/s={r.tokens_per_s:.1f} "
              f"tokens={r.tokens[:8].tolist()}...")


if __name__ == "__main__":
    main()
