import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

Proves the distribution config is coherent without hardware: params,
optimizer state, batches and caches are ShapeDtypeStructs (no allocation);
jit(...).lower(...).compile() must succeed on the production meshes, and
the compiled artifact yields the roofline terms (FLOPs, bytes, collective
traffic, per-device memory).

Usage:
  python -m repro.launch.dryrun --arch qwen2.5-3b --shape train_4k
  python -m repro.launch.dryrun --all --out artifacts/dryrun.jsonl
  python -m repro.launch.dryrun --all --multi-pod
"""

import argparse
import json
import sys
import time
import traceback

import jax
import numpy as np

from repro.configs.registry import ARCHS, get_config
from repro.dist.sharding import (
    batch_sharding_tree,
    cache_sharding,
    opt_state_sharding,
    param_sharding,
)
from repro.launch.hlo_cost import analyze
from repro.launch.mesh import make_production_mesh
from repro.models.api import build_model, input_specs
from repro.models.config import SHAPE_CELLS, cell_applicable
from repro.optim import AdamWConfig, adamw_init
from repro.train.loop import TrainConfig, make_train_step

# TPU v5e roofline constants (per chip)
PEAK_FLOPS = 197e12
HBM_BPS = 819e9
ICI_BPS = 50e9 * 4  # 4 usable ICI links/chip on a 2D torus


def _microbatches(global_batch: int, batch_shards: int) -> int:
    """Prefer 8 microbatches (grad-accum traffic halves vs 16 — §Perf A1),
    falling back to whatever still shards evenly."""
    for n in (8, 16, 4, 2, 1):
        if global_batch % (n * batch_shards) == 0:
            return n
    return 1


def build_cell(cfg, cell, mesh, *, n_micro=None):
    """Returns (fn, example_args, in_shardings, donate) for the cell."""
    model = build_model(cfg)
    key_sds = jax.ShapeDtypeStruct((2,), jax.numpy.uint32)
    params_sds = jax.eval_shape(model.init, key_sds)
    p_sh = param_sharding(params_sds, mesh)

    if cell.kind == "train":
        from repro.dist.sharding import batch_axis_size
        n_micro = n_micro or cfg.micro_override or _microbatches(
            cell.global_batch, batch_axis_size(mesh))
        tcfg = TrainConfig(steps=10_000, n_microbatches=n_micro,
                           opt=AdamWConfig())
        step = make_train_step(model, tcfg)
        opt_sds = jax.eval_shape(adamw_init, params_sds)
        o_sh = opt_state_sharding(opt_sds, mesh)
        batch_sds = input_specs(cfg, cell)
        b_sh = batch_sharding_tree(batch_sds, mesh)
        return (step, (params_sds, opt_sds, batch_sds), (p_sh, o_sh, b_sh),
                (0, 1), {"n_microbatches": n_micro})

    if cell.kind == "prefill":
        batch_sds = input_specs(cfg, cell)
        batch_sds.pop("labels", None)
        b_sh = batch_sharding_tree(batch_sds, mesh)
        s_max = cell.seq_len

        def pre(params, batch):
            return model.prefill(params, batch, s_max)

        return pre, (params_sds, batch_sds), (p_sh, b_sh), (), {}

    # decode: one token against a seq_len cache
    tok_sds = jax.ShapeDtypeStruct((cell.global_batch, 1), jax.numpy.int32)
    cache_sds = jax.eval_shape(
        lambda: model.init_cache(cell.global_batch, cell.seq_len))
    c_sh = cache_sharding(cache_sds, mesh)
    t_sh = batch_sharding_tree({"t": tok_sds}, mesh)["t"]
    return (model.decode, (params_sds, tok_sds, cache_sds),
            (p_sh, t_sh, c_sh), (2,), {})


def run_cell(arch: str, cell, *, multi_pod: bool = False,
             profile: bool = False) -> dict:
    cfg = get_config(arch)
    ok, why = cell_applicable(cfg, cell)
    rec = {"arch": arch, "shape": cell.name, "kind": cell.kind,
           "multi_pod": multi_pod, "seq_len": cell.seq_len,
           "global_batch": cell.global_batch}
    if not ok:
        rec.update({"status": "skipped", "reason": why})
        return rec
    mesh = make_production_mesh(multi_pod=multi_pod)
    world = int(np.prod(list(mesh.shape.values())))
    t0 = time.time()
    try:
        fn, args, shardings, donate, extra = build_cell(cfg, cell, mesh)
        rec.update(extra)
        with mesh:
            jitted = jax.jit(fn, in_shardings=shardings,
                             donate_argnums=donate)
            lowered = jitted.lower(*args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
        mem = compiled.memory_analysis()
        xla_cost = compiled.cost_analysis()
        hlo = compiled.as_text()
        # trip-count-aware analysis (XLA's counts while bodies once)

        cost = analyze(hlo, world)
        coll = cost
        flops = float(cost.flops)
        bytes_accessed = float(cost.bytes)
        n_active = cfg.active_param_count() - cfg.vocab_padded * cfg.d_model * (
            1 if cfg.tie_embeddings else 2)
        tokens = cell.global_batch * (cell.seq_len if cell.kind != "decode" else 1)
        model_flops = (6 if cell.kind == "train" else 2) * n_active * tokens
        rec.update({
            "status": "ok",
            "lower_s": round(t_lower, 2),
            "compile_s": round(t_compile, 2),
            "world": world,
            # cost_analysis is per-device (post-SPMD module)
            "flops_per_device": flops,
            "bytes_per_device": bytes_accessed,
            "xla_flops_once": float(xla_cost.get("flops", 0.0)),
            "collective_bytes_per_device": float(coll.collective_bytes),
            "collectives": {k: float(v)
                            for k, v in coll.collective_by_kind.items()},
            "model_flops_total": float(model_flops),
            "useful_flops_ratio": float(model_flops / max(flops * world, 1)),
            "compute_term_s": flops / PEAK_FLOPS,
            "memory_term_s": bytes_accessed / HBM_BPS,
            "collective_term_s": float(coll.collective_bytes) / ICI_BPS,
            "memory_analysis": _mem_dict(mem),
        })
        dom = max(("compute_term_s", "memory_term_s", "collective_term_s"),
                  key=lambda k: rec[k])
        rec["bottleneck"] = dom.replace("_term_s", "")
        if profile:
            from repro.launch.hlo_cost import top_traffic_ops
            rec["top_traffic_ops"] = top_traffic_ops(hlo, world, n=15)
    except Exception as e:  # noqa: BLE001 — record the failure, keep sweeping
        rec.update({"status": "error", "error": f"{type(e).__name__}: {e}",
                    "traceback": traceback.format_exc()[-2000:]})
    return rec


def _mem_dict(mem) -> dict:
    out = {}
    for k in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "alias_size_in_bytes",
              "generated_code_size_in_bytes"):
        v = getattr(mem, k, None)
        if v is not None:
            out[k] = int(v)
    return out


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCHS)
    ap.add_argument("--shape", choices=[c.name for c in SHAPE_CELLS])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default="")
    ap.add_argument("--profile", action="store_true")
    args = ap.parse_args()

    cells = [c for c in SHAPE_CELLS if not args.shape or c.name == args.shape]
    archs = list(ARCHS) if args.all or not args.arch else [args.arch]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    n_fail = 0
    out_f = open(args.out, "a") if args.out else None
    for arch in archs:
        for cell in cells:
            for mp in meshes:
                rec = run_cell(arch, cell, multi_pod=mp,
                               profile=args.profile)
                tag = "POD2" if mp else "POD1"
                line = (f"[{tag}] {arch:22s} {cell.name:12s} "
                        f"{rec['status']:8s}")
                if rec["status"] == "ok":
                    line += (f" compile={rec['compile_s']:.1f}s "
                             f"bottleneck={rec['bottleneck']:10s} "
                             f"useful={rec['useful_flops_ratio']:.2f}")
                elif rec["status"] == "error":
                    line += " " + rec["error"][:120]
                    n_fail += 1
                print(line, flush=True)
                if out_f:
                    slim = {k: v for k, v in rec.items() if k != "traceback"}
                    out_f.write(json.dumps(slim) + "\n")
                    out_f.flush()
    if out_f:
        out_f.close()
    return 1 if n_fail else 0


if __name__ == "__main__":
    sys.exit(main())
