"""Continuous batching: slot-based decode with per-request admission.

The batched decode step never stops for stragglers: each of the B slots
holds an independent request; finished slots are refilled by prefilling the
next queued prompt (batch=1) and splicing its KV cache into the slot. This
is the serving-side incarnation of the paper's scheduled/interrupt modes —
the engine never blocks the whole batch on one request's completion, just
as the kernel driver never blocks the PS on one DMA.

Token movement rides the same :class:`~repro.core.transfer.TransferEngine`
(or :class:`~repro.core.channels.ChannelGroup`) as the rest of the system:
prompt admission is a measured TX, each decode step's token batch is a
measured RX (issued ``rx_async`` under INTERRUPT so the device->host copy
overlaps the host-side slot bookkeeping) — the paper's balanced TX/RX goal
applied to serving, with per-transfer stats in ``engine.stats``.

Supports the KV-cache families (dense / moe / vlm); the cache carries
per-slot lengths [L, B] so heterogeneous requests decode correctly in one
batch (the attention layer handles vector cache lengths).
"""

from __future__ import annotations

import collections
from dataclasses import dataclass, field
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.qos import (
    AdmissionController,
    AdmissionDecision,
    AdmissionPolicy,
    QosSpec,
    warn_deprecated_kwarg,
)
from repro.core.runtime import PriorityClass
from repro.core.transfer import (
    Management,
    TransferEngine,
    TransferPolicy,
    reassemble_chunks,
)
from repro.models.api import Model


@dataclass
class Request:
    rid: int
    prompt: np.ndarray  # [S_prompt]
    max_new_tokens: int = 32
    tokens: list = field(default_factory=list)
    done: bool = False
    # submit context for this request's transfers (tenant, weight, caps);
    # merges over the engine's base qos. None = engine defaults.
    qos: QosSpec | None = None


def _splice_slot(batch_cache: Any, one_cache: Any, slot: int,
                 batch_dim_of) -> Any:
    """Write a batch-1 cache into slot `slot` of the batched cache."""

    def fn(dst, src):
        bd = batch_dim_of(dst)
        if bd is None:
            return dst
        if src.ndim == dst.ndim - 1:  # scalar-per-layer length -> [L, 1]
            src = src[..., None]
        start = [0] * dst.ndim
        start[bd] = slot
        return jax.lax.dynamic_update_slice(dst, src.astype(dst.dtype),
                                            tuple(start))

    return jax.tree.map(fn, batch_cache, one_cache)


class ContinuousBatchingEngine:
    """Admits requests into B decode slots; one jitted step serves all."""

    def __init__(self, model: Model, params: Any, *, n_slots: int = 4,
                 max_seq: int = 256, eos_token: int = -1,
                 transfer: "TransferEngine | Any | None" = None,
                 class_caps: "dict[str, float] | None" = None,
                 rx_timeout_s: float | None = 60.0,
                 qos: QosSpec | None = None,
                 admission: AdmissionPolicy | None = None):
        self.model = model
        self.params = params
        self.n_slots = n_slots
        self.max_seq = max_seq
        self.eos = eos_token
        # DEPRECATED kwargs fold into the base QosSpec: class_caps ->
        # qos.class_caps, rx_timeout_s -> qos.timeout_s (the liveness
        # bound on every decoded-token RX wait; None = unbounded).
        if class_caps is not None:
            warn_deprecated_kwarg(
                "ContinuousBatchingEngine(class_caps=...)",
                "ContinuousBatchingEngine(qos=QosSpec(class_caps=...))")
        if rx_timeout_s != 60.0:
            warn_deprecated_kwarg(
                "ContinuousBatchingEngine(rx_timeout_s=...)",
                "ContinuousBatchingEngine(qos=QosSpec(timeout_s=...))")
        self.qos = QosSpec(timeout_s=rx_timeout_s,
                           class_caps=class_caps).merged(qos)
        self.rx_timeout_s = self.qos.timeout_s
        # token RXs ride TOKEN class unless the base spec overrides.
        self._tok_qos = QosSpec(priority=PriorityClass.TOKEN).merged(
            self.qos)
        # token movement (prompt TX, decoded-token RX) on a real engine —
        # callers may hand in a shared TransferEngine or ChannelGroup, which
        # close() then leaves alone (we only close what we created).
        self._owns_transfer = transfer is None
        self.transfer = transfer or TransferEngine(
            TransferPolicy.kernel_level())
        if self.qos.class_caps:
            # per-class bandwidth ceilings (PriorityClass value -> bytes/s)
            # on the runtime behind the transfer surface: bulk prefetch
            # sharing this engine's runtime can be budgeted so decode-token
            # RX keeps its headroom.
            for name, bps in self.qos.class_caps.items():
                self.transfer.set_class_cap(PriorityClass(name), bps)
        # admission valve: submit() sheds a tenant whose backlog (host
        # queue + runtime-queued descriptors) or whose class's deadline-
        # miss rate crosses the policy thresholds. Runtime read lazily —
        # engines register with the shared runtime on first submit.
        self.admission = AdmissionController(
            runtime=lambda: self.transfer.runtime,
            policy=admission, cls=PriorityClass.TOKEN)
        if model.cfg.family in ("ssm", "hybrid"):
            raise NotImplementedError(
                "continuous batching currently supports KV-cache families")
        self.queue: "collections.deque[Request]" = collections.deque()
        self.slots: list[Request | None] = [None] * n_slots
        cache = model.init_cache(n_slots, max_seq)
        # per-slot lengths: [L] -> [L, B]
        self.cache = cache._replace(
            length=jnp.zeros((model.cfg.n_layers, n_slots), jnp.int32))
        self.tokens = jnp.zeros((n_slots, 1), jnp.int32)
        self.lengths = np.zeros(n_slots, np.int64)
        # decoded-token landing zone: every step's RX writes this buffer in
        # place (rx_async out=), so steady-state decode does zero per-step
        # host allocation on the detokenize path.
        self._tok_host = np.empty(n_slots, np.int32)
        self._decode = jax.jit(model.decode)
        self._prefill1 = jax.jit(lambda p, b: model.prefill(p, b, max_seq))
        self.steps = 0
        self.completed: list[Request] = []

    # -- cache plumbing ------------------------------------------------------
    def _batch_dim_of(self, leaf) -> int | None:
        if leaf.ndim >= 2 and leaf.shape[1] == self.n_slots:
            return 1  # stacked [L, B, ...]
        if leaf.ndim >= 1 and leaf.shape[0] == self.n_slots:
            return 0
        return None

    def submit(self, req: Request) -> AdmissionDecision:
        """Enqueue ``req`` unless admission sheds it. Always returns the
        explicit :class:`AdmissionDecision` — a ``shed`` decision means
        the request was NOT enqueued (check ``decision.admitted``); the
        caller backs off ``retry_after_s`` and resubmits. Never hangs,
        never silently drops."""
        spec = self.qos.merged(req.qos)
        tenant = spec.effective_tenant
        backlog = sum(
            1 for r in self.queue
            if self.qos.merged(r.qos).effective_tenant == tenant)
        decision = self.admission.decide(
            tenant, cls=self._tok_qos.priority, extra_depth=backlog)
        if decision.admitted:
            self.queue.append(req)
        return decision

    def _admit(self) -> None:
        admits: list[tuple[int, Request]] = []
        for slot in range(self.n_slots):
            if self.slots[slot] is None and self.queue:
                admits.append((slot, self.queue.popleft()))
        if not admits:
            return
        prompts = [np.ascontiguousarray(r.prompt[None], dtype=np.int32)
                   for _s, r in admits]
        specs = [self.qos.merged(r.qos) for _s, r in admits]
        # with several admissions pending, the (ragged) prompts go down as
        # ONE scatter-gather ring transaction — each prompt its own
        # descriptor segment, no per-prompt management overhead and no
        # staging copy (ragged shapes cannot share a packed payload
        # without padding anyway). One SG transaction carries ONE submit
        # context, so the batch rides SG only when every pending request
        # resolves to the same spec; mixed-tenant admissions fall back to
        # per-prompt TX to keep tenant attribution exact.
        if (len(admits) > 1 and all(s == specs[0] for s in specs)
                and self.transfer.policy.management is Management.INTERRUPT
                and hasattr(self.transfer, "tx_sg")):
            devs = self.transfer.tx_sg(prompts, qos=specs[0]).wait()
            prompt_devs = [d.reshape(p.shape)
                           for d, p in zip(devs, prompts)]
        else:
            prompt_devs = [
                reassemble_chunks(
                    self.transfer.tx(p, qos=s)).reshape(p.shape)
                for p, s in zip(prompts, specs)]
        for (slot, req), prompt_dev in zip(admits, prompt_devs):
            logits, one_cache = self._prefill1(
                self.params, {"tokens": prompt_dev})
            first = int(np.asarray(
                logits[0, -1, : self.model.cfg.vocab].argmax(-1)))
            req.tokens.append(first)
            self.cache = _splice_slot(self.cache, one_cache, slot,
                                      self._batch_dim_of)
            self.tokens = self.tokens.at[slot, 0].set(first)
            self.lengths[slot] = len(req.prompt) + 1
            self.slots[slot] = req

    def _retire(self) -> None:
        for slot, req in enumerate(self.slots):
            if req is None:
                continue
            hit_eos = self.eos >= 0 and req.tokens and req.tokens[-1] == self.eos
            if (len(req.tokens) >= req.max_new_tokens or hit_eos
                    or self.lengths[slot] >= self.max_seq - 1):
                req.done = True
                self.completed.append(req)
                self.slots[slot] = None

    def step(self) -> int:
        """Admit, decode one token for every active slot, retire. Returns
        the number of active slots served."""
        self._admit()
        active = [s for s, r in enumerate(self.slots) if r is not None]
        if not active:
            return 0
        logits, self.cache = self._decode(self.params, self.tokens,
                                          self.cache)
        tok_dev = logits[:, -1, : self.model.cfg.vocab].argmax(-1)
        # next-step input stays device-resident; only the bookkeeping copy
        # crosses back to the host, as a measured RX on the engine. Under
        # INTERRUPT it rides a shared-runtime worker at TOKEN priority
        # (arbitrated ahead of bulk layer TX) while the next-step input
        # prep dispatches. With more than one active slot the per-request
        # tokens go down as ONE rx_many ring transaction — per-slot
        # tickets, one completion handoff — instead of paying the
        # per-descriptor management overhead per request (the batched-
        # submission consumer the coalescing tentpole was built for).
        interrupt = (
            self.transfer.policy.management is Management.INTERRUPT)
        if (interrupt and len(active) > 1
                and hasattr(self.transfer, "rx_many")):
            tickets = self.transfer.rx_many(
                [tok_dev[s:s + 1] for s in active],
                out=[self._tok_host[s:s + 1] for s in active],
                qos=self._tok_qos)
            self.tokens = tok_dev[:, None].astype(jnp.int32)
            for t in tickets:
                t.wait(self.rx_timeout_s)
            # per-slot landings wrote self._tok_host in place (inactive
            # slots keep stale values and are never read below).
            nxt = self._tok_host
        else:
            out = [self._tok_host]  # reused every step: zero-copy detok
            ticket = (self.transfer.rx_async([tok_dev], out=out,
                                             qos=self._tok_qos)
                      if interrupt else None)
            self.tokens = tok_dev[:, None].astype(jnp.int32)
            nxt = (ticket.wait(self.rx_timeout_s)[0] if ticket
                   else self.transfer.rx([tok_dev], out=out,
                                         qos=self._tok_qos)[0])
        nxt = np.asarray(nxt).reshape(-1)
        for slot in active:
            self.slots[slot].tokens.append(int(nxt[slot]))
            self.lengths[slot] += 1
        self.steps += 1
        self._retire()
        # the step's RX ticket is retired — a drained-ring safe point for an
        # online-adaptive transfer engine to swap plan generations (no-op
        # on plain engines/groups).
        self.transfer.maybe_adapt()
        return len(active)

    def run_to_completion(self, max_steps: int = 10_000) -> list[Request]:
        while (self.queue or any(s is not None for s in self.slots)):
            if self.steps >= max_steps:  # check BEFORE stepping: exactly
                break                    # max_steps decode steps, not +1
            if self.step() == 0 and not self.queue:
                break
        return self.completed

    def fault_summary(self) -> dict[str, Any]:
        """Deadline-miss / retry / quarantine rates of the transfer surface
        (zeroed recovery columns on a bare engine — no sibling channels)."""
        f = getattr(self.transfer, "fault_summary", None)
        if f is not None:
            return f()
        s = self.transfer.summary()
        csf = int(s.get("checksum_failures", 0))
        return {"faults": {"faults": csf, "timeouts": 0,
                           "checksum_failures": csf,
                           "retries": 0, "retry_successes": 0,
                           "quarantines": 0, "unquarantines": 0,
                           "faults_by_channel": {}},
                "quarantined": []}

    def admission_summary(self) -> dict[str, Any]:
        """Accept/queue/shed counts of the submit() valve, with per-tenant
        rows for tenants that were ever queued or shed."""
        return self.admission.summary()

    def close(self) -> None:
        if self._owns_transfer:
            self.transfer.close()
