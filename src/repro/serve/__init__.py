from repro.serve.engine import ServeConfig, ServingEngine  # noqa: F401
from repro.serve.continuous import ContinuousBatchingEngine, Request  # noqa: F401
