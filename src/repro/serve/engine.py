"""Batched serving engine: prefill + decode with slot-based batching.

Request flow (NullHop analogy is direct — the paper's accelerator serves
classification frames streamed by the PS):
- requests enter a host-side queue (the PS side);
- the engine batches up to ``max_batch`` prompts, prefills them into the
  KV cache, then decodes steps for the whole batch (continuous-batching
  lite: finished slots are refilled between decode bursts);
- token transfers host<->device go through a per-engine
  :class:`TransferEngine` (a decoded token is an RX; new prompts are TX) —
  measured like every other transfer. Each ServingEngine owns its own
  completion worker pool, so concurrent engines never serialize through a
  shared thread, and under INTERRUPT management the RX of decode step t
  overlaps decode step t+1 (the paper's balanced TX/RX applied to serving).

The decode step itself is the jitted function the decode_32k / long_500k
dry-run cells lower.
"""

from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.channels import ChannelGroup
from repro.core.transfer import (
    Management,
    TransferEngine,
    TransferPolicy,
    reassemble_chunks,
)
from repro.models.api import Model


@dataclass(frozen=True)
class ServeConfig:
    max_batch: int = 8
    max_seq: int = 256
    temperature: float = 0.0  # 0 => greedy
    eos_token: int = -1  # -1 => run to max_new_tokens
    seed: int = 0
    # >1: stripe prompt TX across a ChannelGroup (with adaptive_transfer it
    # is the planner's channel CEILING; 1 there means "planner's choice")
    n_channels: int = 1
    adaptive_transfer: bool = False  # calibrate + fit policy at construction


@dataclass
class RequestResult:
    prompt: np.ndarray
    tokens: np.ndarray
    prefill_s: float
    decode_s: float

    @property
    def tokens_per_s(self) -> float:
        n = len(self.tokens)
        return n / self.decode_s if self.decode_s > 0 else float("inf")


class ServingEngine:
    def __init__(self, model: Model, params: Any, cfg: ServeConfig,
                 policy: TransferPolicy | None = None):
        self.model = model
        self.params = params
        self.cfg = cfg
        if cfg.adaptive_transfer:
            if policy is not None:
                raise ValueError(
                    "adaptive_transfer fits the policy from calibration; "
                    "passing an explicit policy alongside it would be "
                    "silently ignored — choose one")
            # fit the policy to THIS host: calibrate, then size block /
            # ring depth / channel count for the prompt-batch payload. The
            # default n_channels=1 leaves the count to the planner (up to 4).
            prompt_bytes = cfg.max_batch * cfg.max_seq * 4  # int32 tokens
            self.engine = ChannelGroup.auto(
                prompt_bytes,
                max_channels=cfg.n_channels if cfg.n_channels > 1 else 4)
            self.policy = self.engine.policy
        elif cfg.n_channels > 1:
            self.policy = policy or TransferPolicy.kernel_level_ring()
            self.engine = ChannelGroup(self.policy,
                                       n_channels=cfg.n_channels)
        else:
            self.policy = policy or TransferPolicy.kernel_level()
            self.engine = TransferEngine(self.policy)
        self._prefill = jax.jit(
            lambda p, b: model.prefill(p, b, cfg.max_seq))
        self._decode = jax.jit(model.decode, donate_argnums=(2,))
        self._key = jax.random.PRNGKey(cfg.seed)

    def close(self) -> None:
        self.engine.close()

    def _sample(self, logits: jax.Array) -> jax.Array:
        logits = logits[:, -1, : self.model.cfg.vocab]
        if self.cfg.temperature <= 0:
            return logits.argmax(-1)[:, None].astype(jnp.int32)
        self._key, sub = jax.random.split(self._key)
        return jax.random.categorical(
            sub, logits / self.cfg.temperature)[:, None].astype(jnp.int32)

    def _tx_prompts(self, prompts: np.ndarray) -> jax.Array:
        """Stage the prompt batch through the transfer engine (measured TX)."""
        arr = np.ascontiguousarray(prompts, dtype=np.int32)
        return reassemble_chunks(self.engine.tx(arr)).reshape(arr.shape)

    def generate(self, prompts: np.ndarray, max_new_tokens: int = 32,
                 extra_inputs: dict | None = None) -> list[RequestResult]:
        """prompts: [B, S_prompt] int32 (already padded/batched)."""
        b = prompts.shape[0]
        batch = {"tokens": self._tx_prompts(prompts)}
        if extra_inputs:
            batch.update({k: jnp.asarray(v) for k, v in extra_inputs.items()})
        overlap_rx = self.policy.management is Management.INTERRUPT

        t0 = time.perf_counter()
        logits, cache = self._prefill(self.params, batch)
        tok = self._sample(logits)
        jax.block_until_ready(tok)
        prefill_s = time.perf_counter() - t0

        t0 = time.perf_counter()
        if overlap_rx:
            # token t streams back on a completion worker while step t+1
            # decodes — the decode loop never blocks on device->host copies.
            tickets = [self.engine.rx_async([tok])]
            for _ in range(max_new_tokens - 1):
                logits, cache = self._decode(self.params, tok, cache)
                tok = self._sample(logits)
                tickets.append(self.engine.rx_async([tok]))
            toks = np.concatenate([t.wait()[0] for t in tickets], axis=1)
        else:
            out = [tok]
            for _ in range(max_new_tokens - 1):
                logits, cache = self._decode(self.params, tok, cache)
                tok = self._sample(logits)
                out.append(tok)
            toks = np.concatenate(
                [self.engine.rx([t])[0].reshape(t.shape) for t in out], axis=1)
        decode_s = time.perf_counter() - t0

        return [RequestResult(prompts[i], toks[i], prefill_s, decode_s)
                for i in range(b)]
