"""Batched serving engine: prefill + decode with slot-based batching.

Request flow (NullHop analogy is direct — the paper's accelerator serves
classification frames streamed by the PS):
- requests enter a host-side queue (the PS side);
- the engine batches up to ``max_batch`` prompts, prefills them into the
  KV cache, then decodes steps for the whole batch (continuous-batching
  lite: finished slots are refilled between decode bursts);
- token transfers host<->device go through a per-engine
  :class:`TransferEngine` (a decoded token is an RX; new prompts are TX) —
  measured like every other transfer. Each ServingEngine owns its own
  completion worker pool, so concurrent engines never serialize through a
  shared thread, and under INTERRUPT management the RX of decode step t
  overlaps decode step t+1 (the paper's balanced TX/RX applied to serving).

The decode step itself is the jitted function the decode_32k / long_500k
dry-run cells lower.
"""

from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.adaptive import AdaptiveChannelGroup, AdaptiveConfig
from repro.core.channels import ChannelGroup
from repro.core.qos import (
    AdmissionController,
    AdmissionError,
    AdmissionPolicy,
    QosSpec,
    warn_deprecated_kwarg,
)
from repro.core.runtime import PriorityClass
from repro.core.transfer import (
    Management,
    TransferEngine,
    TransferPolicy,
    reassemble_chunks,
)
from repro.models.api import Model


@dataclass(frozen=True)
class ServeConfig:
    max_batch: int = 8
    max_seq: int = 256
    temperature: float = 0.0  # 0 => greedy
    eos_token: int = -1  # -1 => run to max_new_tokens
    seed: int = 0
    # >1: stripe prompt TX across a ChannelGroup (with adaptive_transfer it
    # is the planner's channel CEILING; 1 there means "planner's choice")
    n_channels: int = 1
    adaptive_transfer: bool = False  # calibrate + fit policy at construction
    # keep refitting the fitted policy from live traffic and swap plans at
    # safe points (implies adaptive_transfer's construction-time calibration)
    online_adaptation: bool = False
    # warm-start persistence: with online_adaptation, load the first plan
    # from this file when it exists and save the fitted state on close()
    # — a restarted server skips the calibration sweep.
    transfer_state_path: str | None = None
    # DEPRECATED: class_caps / rx_timeout_s / rx_group now live on ``qos``
    # (QosSpec.class_caps / .timeout_s / .rx_group). Setting them away from
    # their defaults still works for one release — each folds into the
    # engine's base QosSpec and warns.
    class_caps: "dict[str, float] | None" = None
    rx_timeout_s: float | None = 60.0
    rx_group: int = 8
    # the engine's base submit context: per-class bandwidth ceilings
    # (class_caps — the ZynqNet per-class budget), the decoded-token RX
    # liveness bound (timeout_s; None = unbounded waits), the token-RX
    # batching factor (rx_group; 1 = one rx_async per step), plus tenant /
    # weight / per-tenant cap defaults for every transfer this engine
    # submits. Per-call generate(qos=...) merges over it.
    qos: QosSpec | None = None
    # admission thresholds (tenant queue depth / deadline-miss rate) the
    # engine sheds on; None = default AdmissionPolicy (generous — a
    # single-tenant process never trips it).
    admission: AdmissionPolicy | None = None

    def __post_init__(self) -> None:
        if self.class_caps is not None:
            warn_deprecated_kwarg("ServeConfig(class_caps=...)",
                                  "ServeConfig(qos=QosSpec(class_caps=...))")
        if self.rx_timeout_s != 60.0:
            warn_deprecated_kwarg("ServeConfig(rx_timeout_s=...)",
                                  "ServeConfig(qos=QosSpec(timeout_s=...))")
        if self.rx_group != 8:
            warn_deprecated_kwarg("ServeConfig(rx_group=...)",
                                  "ServeConfig(qos=QosSpec(rx_group=...))")


@dataclass
class RequestResult:
    prompt: np.ndarray
    tokens: np.ndarray
    prefill_s: float
    decode_s: float

    @property
    def tokens_per_s(self) -> float:
        n = len(self.tokens)
        return n / self.decode_s if self.decode_s > 0 else float("inf")


class ServingEngine:
    def __init__(self, model: Model, params: Any, cfg: ServeConfig,
                 policy: TransferPolicy | None = None):
        self.model = model
        self.params = params
        self.cfg = cfg
        # the engine's base submit context: legacy ServeConfig knobs fold
        # in first (they already warned at ServeConfig construction), then
        # cfg.qos overrides field-wise. Token-RX submissions further merge
        # TOKEN priority and the per-call generate(qos=...) spec on top.
        self.qos = QosSpec(
            timeout_s=cfg.rx_timeout_s,
            rx_group=cfg.rx_group,
            class_caps=cfg.class_caps,
        ).merged(cfg.qos)
        if cfg.adaptive_transfer or cfg.online_adaptation:
            if policy is not None:
                raise ValueError(
                    "adaptive_transfer fits the policy from calibration; "
                    "passing an explicit policy alongside it would be "
                    "silently ignored — choose one")
            # fit the policy to THIS host: calibrate, then size block /
            # ring depth / channel count for the prompt-batch payload. The
            # default n_channels=1 leaves the count to the planner (up to 4).
            prompt_bytes = cfg.max_batch * cfg.max_seq * 4  # int32 tokens
            max_ch = cfg.n_channels if cfg.n_channels > 1 else 4
            if cfg.online_adaptation:
                # construction-time calibration PLUS rolling refit: the
                # engine keeps re-fitting t0/BW from live token/prompt
                # traffic and swaps plans between requests (safe points).
                # A state_path warm-starts the first plan from the last
                # session's fit; the runtime's TOKEN-class dispatch
                # latencies feed the controller's polling/interrupt
                # crossover from real serving traces.
                self.engine = AdaptiveChannelGroup(
                    prompt_bytes, cfg=AdaptiveConfig(max_channels=max_ch),
                    priority=PriorityClass.TOKEN,
                    state_path=cfg.transfer_state_path)
            else:
                self.engine = ChannelGroup.auto(prompt_bytes,
                                                max_channels=max_ch)
            self.policy = self.engine.policy
        elif cfg.n_channels > 1:
            self.policy = policy or TransferPolicy.kernel_level_ring()
            self.engine = ChannelGroup(self.policy,
                                       n_channels=cfg.n_channels)
        else:
            self.policy = policy or TransferPolicy.kernel_level()
            self.engine = TransferEngine(self.policy)
        if self.qos.class_caps:
            # enforced on the shared runtime behind this engine's transfer
            # surface; an adaptive engine also folds its own class's cap
            # into the planner (set_class_cap handles both).
            for name, bps in self.qos.class_caps.items():
                self.engine.set_class_cap(PriorityClass(name), bps)
        # admission guards the TOKEN class (where decode-loop RXs queue):
        # runtime is read lazily — engines register with the shared runtime
        # on first submit, not at construction.
        self.admission = AdmissionController(
            runtime=lambda: self.engine.runtime,
            policy=cfg.admission, cls=PriorityClass.TOKEN)
        self._prefill = jax.jit(
            lambda p, b: model.prefill(p, b, cfg.max_seq))
        self._decode = jax.jit(model.decode, donate_argnums=(2,))
        self._key = jax.random.PRNGKey(cfg.seed)
        # decoded-token landing zone, reused across generate() calls: each
        # step's RX writes row t in place (rx_async out=), so the steady
        # state detokenize path allocates nothing per token.
        self._tok_buf = np.empty((0, 0), np.int32)

    def close(self) -> None:
        self.engine.close()

    def fault_summary(self) -> dict[str, Any]:
        """Fault / recovery rates of the transfer surface behind this
        engine: deadline misses (timeouts), stripe retries + successes,
        checksum failures, quarantine transitions. Channel groups and
        adaptive facades report their shared ledger; a bare engine reports
        its own counters with the recovery columns zeroed (no sibling to
        retry on)."""
        f = getattr(self.engine, "fault_summary", None)
        if f is not None:
            return f()
        s = self.engine.summary()
        csf = int(s.get("checksum_failures", 0))
        return {"faults": {"faults": csf, "timeouts": 0,
                           "checksum_failures": csf,
                           "retries": 0, "retry_successes": 0,
                           "quarantines": 0, "unquarantines": 0,
                           "faults_by_channel": {}},
                "quarantined": []}

    def admission_summary(self) -> dict[str, Any]:
        """Accept/queue/shed counts of this engine's admission valve,
        with per-tenant rows for tenants that were ever queued or shed."""
        return self.admission.summary()

    def _sample(self, logits: jax.Array) -> jax.Array:
        logits = logits[:, -1, : self.model.cfg.vocab]
        if self.cfg.temperature <= 0:
            return logits.argmax(-1)[:, None].astype(jnp.int32)
        self._key, sub = jax.random.split(self._key)
        return jax.random.categorical(
            sub, logits / self.cfg.temperature)[:, None].astype(jnp.int32)

    def _tx_prompts(self, prompts: np.ndarray,
                    extra_inputs: dict | None = None,
                    qos: QosSpec | None = None) -> dict:
        """Stage the prompt batch (and any side inputs) through the transfer
        engine as the prefill batch dict. With side inputs on an SG-capable
        INTERRUPT engine, prompts + extras ride ONE scatter-gather ring slot
        (one logical descriptor, zero staging copy) instead of a measured
        prompt TX plus unmeasured ``device_put`` calls."""
        arr = np.ascontiguousarray(prompts, dtype=np.int32)
        extra = {k: np.ascontiguousarray(v)
                 for k, v in (extra_inputs or {}).items()}
        if (extra
                and self.engine.policy.management is Management.INTERRUPT
                and hasattr(self.engine, "tx_sg")):
            keys = sorted(extra)
            devs = self.engine.tx_sg([arr] + [extra[k] for k in keys],
                                     qos=qos).wait()
            batch = {"tokens": devs[0].reshape(arr.shape)}
            batch.update(dict(zip(keys, devs[1:])))
            return batch
        batch = {"tokens": reassemble_chunks(
            self.engine.tx(arr, qos=qos)).reshape(arr.shape)}
        batch.update({k: jnp.asarray(v) for k, v in extra.items()})
        return batch

    def generate(self, prompts: np.ndarray, max_new_tokens: int = 32,
                 extra_inputs: dict | None = None, *,
                 qos: QosSpec | None = None) -> list[RequestResult]:
        """prompts: [B, S_prompt] int32 (already padded/batched).

        ``qos`` merges over the engine's base spec (``ServeConfig.qos``):
        tag the request's transfers with a tenant / weight / caps, override
        the token-RX deadline or batching factor per call. Admission runs
        first: a shed request raises :class:`AdmissionError` carrying the
        :class:`AdmissionDecision` (explicit backpressure, never a hang).

        NOT reentrant: one generate() at a time per ServingEngine (the
        sampling key, KV-cache donation, and the reused ``_tok_buf`` token
        matrix are engine state). Concurrent serving is the
        ContinuousBatchingEngine's job; multiple ServingEngines may run in
        parallel (each owns its transfer rings and buffers)."""
        spec = self.qos.merged(qos)
        # token RXs ride TOKEN class unless the caller's spec overrides;
        # prompt TX keeps the engine's own default class (spec carries no
        # priority unless the caller set one).
        tok_spec = QosSpec(priority=PriorityClass.TOKEN).merged(spec)
        decision = self.admission.decide(spec.effective_tenant,
                                         cls=tok_spec.priority)
        if not decision.admitted:
            raise AdmissionError(decision)
        b = prompts.shape[0]
        max_new_tokens = max(1, max_new_tokens)  # prefill always emits one
        batch = self._tx_prompts(prompts, extra_inputs, qos=spec)
        # read the CURRENT policy off the engine: an online-adaptive engine
        # may have swapped plan generations since construction.
        overlap_rx = self.engine.policy.management is Management.INTERRUPT

        t0 = time.perf_counter()
        logits, cache = self._prefill(self.params, batch)
        tok = self._sample(logits)
        jax.block_until_ready(tok)
        prefill_s = time.perf_counter() - t0

        if self._tok_buf.shape != (max_new_tokens, b):
            self._tok_buf = np.empty((max_new_tokens, b), np.int32)

        t0 = time.perf_counter()
        if overlap_rx:
            # token t streams back on a completion worker while step t+1
            # decodes — the decode loop never blocks on device->host copies,
            # and each token lands in its reused row of _tok_buf (zero
            # per-token host allocation). TOKEN priority: the shared
            # runtime dispatches these tiny RXs ahead of bulk layer TX, so
            # decode latency is protected under contention. With
            # ``rx_group > 1`` the pending tokens flush as ONE rx_many
            # ring transaction per group — per-token tickets, one
            # completion handoff — amortizing the per-descriptor
            # management overhead the paper showed dominates small
            # packets; tokens stay device-resident until their group
            # flushes, which costs nothing (decode reads them on device).
            group = max(1, int(spec.rx_group or 1))
            batched = group > 1 and hasattr(self.engine, "rx_many")
            tickets: list = []
            pend_toks: list = [tok]
            pend_rows: list = [self._tok_buf[0]]

            def flush() -> None:
                if batched and len(pend_toks) > 1:
                    tickets.extend(self.engine.rx_many(
                        list(pend_toks), out=list(pend_rows), qos=tok_spec))
                else:
                    tickets.extend(self.engine.rx_async(
                        [p], out=[r], qos=tok_spec)
                        for p, r in zip(pend_toks, pend_rows))
                pend_toks.clear()
                pend_rows.clear()

            if not batched:
                flush()  # per-step submission: overlap every RX
            for step in range(max_new_tokens - 1):
                logits, cache = self._decode(self.params, tok, cache)
                tok = self._sample(logits)
                pend_toks.append(tok)
                pend_rows.append(self._tok_buf[step + 1])
                if not batched or len(pend_toks) >= group:
                    flush()
            if pend_toks:
                flush()
            for t in tickets:
                t.wait(spec.timeout_s)
            toks = self._tok_buf.T
        else:
            for step in range(max_new_tokens):
                if step:
                    logits, cache = self._decode(self.params, tok, cache)
                    tok = self._sample(logits)
                self.engine.rx([tok], out=[self._tok_buf[step]],
                               qos=tok_spec)
            toks = self._tok_buf.T
        decode_s = time.perf_counter() - t0
        # request boundary = safe point: let an adaptive engine swap plans
        # (no-op on plain engines/groups).
        self.engine.maybe_adapt()

        # one copy per REQUEST (not per token): results must outlive the
        # reused _tok_buf, which the next generate() call overwrites.
        return [RequestResult(prompts[i], toks[i].copy(), prefill_s, decode_s)
                for i in range(b)]
