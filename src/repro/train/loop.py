"""Fault-tolerant training loop with microbatched (blocks-mode) steps.

Microbatching IS the paper's Blocks partitioning applied to the batch
dimension: the global batch is split into ``n_microbatches`` chunks scanned
on-device, bounding activation memory exactly like chunked DMA bounds
staging-buffer memory. Gradients accumulate in f32.

Loop-level fault tolerance (see repro.dist.fault):
- restart: Trainer.run resumes from the latest checkpoint if one exists;
- async checkpoints via CheckpointManager (INTERRUPT-mode writes);
- straggler detection on per-step wall time;
- non-finite steps are skipped inside adamw_update (weights untouched).
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.dist.fault import FaultState
from repro.models.api import Model
from repro.optim import AdamWConfig, adamw_init, adamw_update
from repro.optim.schedule import cosine_schedule


@dataclass(frozen=True)
class TrainConfig:
    steps: int = 100
    n_microbatches: int = 1
    warmup: int = 10
    log_every: int = 10
    opt: AdamWConfig = field(default_factory=AdamWConfig)
    checkpoint_dir: str = ""
    checkpoint_every: int = 100
    async_checkpoint: bool = True


def make_train_step(model: Model, tcfg: TrainConfig) -> Callable:
    """Build the jit-able (params, opt_state, batch) -> (...) step."""
    n_micro = tcfg.n_microbatches
    grad_fn = jax.value_and_grad(model.loss, has_aux=True)

    def train_step(params, opt_state, batch):
        if n_micro == 1:
            (loss, metrics), grads = grad_fn(params, batch)
            grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        else:
            micro = jax.tree.map(
                lambda x: x.reshape((n_micro, x.shape[0] // n_micro)
                                    + x.shape[1:]), batch)

            def body(acc, mb):
                gacc, lacc, aacc = acc
                (loss, m), g = grad_fn(params, mb)
                gacc = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), gacc, g)
                return (gacc, lacc + m["loss"], aacc + m["acc"]), None

            gacc0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (gacc, lsum, asum), _ = jax.lax.scan(
                body, (gacc0, jnp.zeros((), jnp.float32),
                       jnp.zeros((), jnp.float32)), micro)
            grads = jax.tree.map(lambda g: g / n_micro, gacc)
            loss = lsum / n_micro
            metrics = {"loss": loss, "acc": asum / n_micro,
                       "aux": jnp.zeros(())}
        lr_scale = cosine_schedule(opt_state["step"], warmup=tcfg.warmup,
                                   total=tcfg.steps)
        params, opt_state, om = adamw_update(tcfg.opt, grads, opt_state,
                                             params, lr_scale)
        metrics = dict(metrics)
        metrics.update(om)
        return params, opt_state, metrics

    return train_step


@dataclass
class Trainer:
    model: Model
    tcfg: TrainConfig
    jit_kwargs: dict = field(default_factory=dict)
    fault: FaultState = field(default_factory=FaultState)
    history: list[dict] = field(default_factory=list)

    def run(self, data_iter, key=None, initial_state=None) -> dict:
        """Train for tcfg.steps; restart-safe. Returns final state dict."""
        key = key if key is not None else jax.random.PRNGKey(0)
        step_fn = jax.jit(make_train_step(self.model, self.tcfg),
                          donate_argnums=(0, 1), **self.jit_kwargs)

        ckpt = None
        start_step = 0
        if self.tcfg.checkpoint_dir:
            ckpt = CheckpointManager(self.tcfg.checkpoint_dir,
                                     every=self.tcfg.checkpoint_every,
                                     async_write=self.tcfg.async_checkpoint)
        if initial_state is not None:
            params, opt_state = initial_state
        else:
            params = self.model.init(key)
            opt_state = adamw_init(params)
            if ckpt is not None:
                restored = ckpt.restore_latest(
                    {"params": params, "opt": opt_state})
                if restored is not None:
                    start_step = restored[0]
                    params = restored[1]["params"]
                    opt_state = restored[1]["opt"]
                    self.fault.restarts += 1

        metrics = {}
        for step in range(start_step, self.tcfg.steps):
            batch = next(data_iter)
            t0 = time.perf_counter()
            params, opt_state, metrics = step_fn(params, opt_state, batch)
            jax.block_until_ready(metrics["loss"])
            dt = time.perf_counter() - t0
            self.fault.record_step(dt, float(metrics["step_ok"]))
            if step % self.tcfg.log_every == 0 or step == self.tcfg.steps - 1:
                row = {k: float(v) for k, v in metrics.items()}
                row["step"] = step
                row["dt_s"] = dt
                self.history.append(row)
            if ckpt is not None:
                ckpt.maybe_save(step + 1, {"params": params, "opt": opt_state})
        if ckpt is not None:
            ckpt.wait()
        return {"params": params, "opt_state": opt_state, "metrics": metrics,
                "fault": self.fault}
