"""Timing helpers for measured (host-side) benchmarks."""

from __future__ import annotations

import statistics
import time
from dataclasses import dataclass, field
from typing import Callable


@dataclass
class Timer:
    """Accumulates wall-clock samples; reports robust statistics."""

    samples_s: list[float] = field(default_factory=list)

    def time(self, fn: Callable[[], object]) -> object:
        t0 = time.perf_counter()
        out = fn()
        self.samples_s.append(time.perf_counter() - t0)
        return out

    @property
    def median_s(self) -> float:
        return statistics.median(self.samples_s) if self.samples_s else float("nan")

    @property
    def mean_s(self) -> float:
        return statistics.fmean(self.samples_s) if self.samples_s else float("nan")

    @property
    def min_s(self) -> float:
        return min(self.samples_s) if self.samples_s else float("nan")


def bench(fn: Callable[[], object], *, warmup: int = 2, iters: int = 5) -> Timer:
    """Run ``fn`` ``warmup`` + ``iters`` times; return a Timer with the iters."""
    for _ in range(warmup):
        fn()
    t = Timer()
    for _ in range(iters):
        t.time(fn)
    return t


@dataclass
class StepClock:
    """Per-step timing with straggler detection (z-score over a rolling window).

    Used by the training loop: on a real multi-host cluster each host feeds its
    step time; a straggling host shows up as a persistent positive z-score and
    the loop can trigger mitigation (checkpoint + re-mesh without it).
    """

    window: int = 50
    zscore_threshold: float = 4.0
    _times: list[float] = field(default_factory=list)

    def record(self, dt_s: float) -> bool:
        """Record a step time. Returns True if this step is a straggler outlier."""
        self._times.append(dt_s)
        hist = self._times[-self.window :]
        if len(hist) < 10:
            return False
        mu = statistics.fmean(hist[:-1])
        sd = statistics.pstdev(hist[:-1]) or 1e-9
        return (dt_s - mu) / sd > self.zscore_threshold
