"""Compat layer for the jax version span this repo runs on (0.4.x .. current).

Newer jax renamed or added several APIs the code and tests use; resolve them
once here so call sites stay on the modern spelling:

- :func:`make_mesh` — drops ``axis_types`` where unsupported (pre-0.5 jax
  has no explicit-sharding axis types; Auto was the only behaviour);
- :func:`shard_map` — ``jax.shard_map`` (new) or
  ``jax.experimental.shard_map`` (0.4.x);
- :func:`pvary` — identity on jax versions without varying-axis tracking
  (pre-0.6 shard_map does not type-check axis variance, so marking is a
  no-op there).
"""

from __future__ import annotations

import jax

if hasattr(jax, "shard_map"):
    shard_map = jax.shard_map
else:  # jax 0.4.x
    from jax.experimental.shard_map import shard_map  # noqa: F401


def make_mesh(shape, axis_names):
    """jax.make_mesh with Auto axis types when the concept exists."""
    if hasattr(jax.sharding, "AxisType"):
        return jax.make_mesh(
            shape, axis_names,
            axis_types=(jax.sharding.AxisType.Auto,) * len(axis_names))
    return jax.make_mesh(shape, axis_names)


def pvary(x, axis_names):
    if hasattr(jax.lax, "pvary"):
        return jax.lax.pvary(x, axis_names)
    return x


def axis_size(axis_name) -> int:
    """Static size of a named mapped axis (inside shard_map/pmap)."""
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(axis_name)
    size = jax.core.axis_frame(axis_name)  # jax 0.4.x: returns the int
    return size if isinstance(size, int) else size.size
