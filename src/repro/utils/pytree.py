"""Small pytree utilities used across the substrate."""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np


def tree_bytes(tree: Any) -> int:
    """Total bytes of all array leaves (ShapeDtypeStructs count too)."""
    leaves = jax.tree_util.tree_leaves(tree)
    total = 0
    for leaf in leaves:
        if hasattr(leaf, "shape") and hasattr(leaf, "dtype"):
            total += int(np.prod(leaf.shape)) * jnp.dtype(leaf.dtype).itemsize
    return total


def tree_params(tree: Any) -> int:
    """Total element count of all array leaves."""
    leaves = jax.tree_util.tree_leaves(tree)
    return sum(int(np.prod(l.shape)) for l in leaves if hasattr(l, "shape"))


def tree_zeros_like(tree: Any) -> Any:
    return jax.tree.map(jnp.zeros_like, tree)


def tree_cast(tree: Any, dtype) -> Any:
    return jax.tree.map(
        lambda x: x.astype(dtype) if jnp.issubdtype(x.dtype, jnp.floating) else x,
        tree,
    )


def tree_finite(tree: Any) -> jax.Array:
    """Scalar bool: every floating leaf is finite. Used for NaN-guarded updates."""
    leaves = [
        jnp.isfinite(l).all()
        for l in jax.tree_util.tree_leaves(tree)
        if jnp.issubdtype(jnp.asarray(l).dtype, jnp.floating)
    ]
    if not leaves:
        return jnp.array(True)
    return jnp.stack(leaves).all()


def global_norm(tree: Any) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))
