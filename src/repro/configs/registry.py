"""Registry mapping --arch ids to ModelConfig builders."""

from __future__ import annotations

import importlib

from repro.models.config import ModelConfig

_ARCH_MODULES = {
    "seamless-m4t-medium": "repro.configs.seamless_m4t_medium",
    "stablelm-12b": "repro.configs.stablelm_12b",
    "qwen2.5-3b": "repro.configs.qwen2_5_3b",
    "internlm2-20b": "repro.configs.internlm2_20b",
    "h2o-danube-1.8b": "repro.configs.h2o_danube_1_8b",
    "pixtral-12b": "repro.configs.pixtral_12b",
    "deepseek-moe-16b": "repro.configs.deepseek_moe_16b",
    "granite-moe-1b-a400m": "repro.configs.granite_moe_1b_a400m",
    "mamba2-780m": "repro.configs.mamba2_780m",
    "zamba2-1.2b": "repro.configs.zamba2_1_2b",
    "roshambo-nullhop": "repro.configs.roshambo",
}

ARCHS = tuple(k for k in _ARCH_MODULES if k != "roshambo-nullhop")


def get_config(name: str, **overrides) -> ModelConfig:
    if name not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_ARCH_MODULES)}")
    mod = importlib.import_module(_ARCH_MODULES[name])
    cfg = mod.config()
    return cfg.replace(**overrides) if overrides else cfg


def list_archs() -> tuple[str, ...]:
    return ARCHS


def smoke_config(name: str) -> ModelConfig:
    """Reduced same-family config for CPU smoke tests."""
    mod = importlib.import_module(_ARCH_MODULES[name])
    return mod.smoke_config()
