"""seamless-m4t-medium [audio]: enc-dec multimodal backbone.

12L d_model=1024 16H (MHA kv=16) d_ff=4096 vocab=256206 [arXiv:2308.11596; hf].
The speech frontend (conformer feature extractor) is a STUB per the
assignment: input_specs supplies precomputed frame embeddings. Adaptation
note (DESIGN.md): original uses learned positions; we use RoPE on the
decoder self-attention (TPU-idiomatic, no semantic impact for perf study).
"""

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="seamless-m4t-medium", family="audio",
        n_layers=12, n_enc_layers=12, d_model=1024, vocab=256206,
        n_heads=16, n_kv_heads=16, d_ff=4096,
        mlp="gelu", norm="ln", rope_theta=10_000.0,
    )


def smoke_config() -> ModelConfig:
    return config().replace(
        name="seamless-smoke", n_layers=2, n_enc_layers=2, d_model=64,
        vocab=512, n_heads=4, n_kv_heads=4, d_ff=128, remat=False,
        attn_kv_chunk=64,
    )
