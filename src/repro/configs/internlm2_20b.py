"""internlm2-20b [dense]: 48L d_model=6144 48H (GQA kv=8) d_ff=16384
vocab=92544 [arXiv:2403.17297; hf]."""

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="internlm2-20b", family="dense",
        n_layers=48, d_model=6144, vocab=92544,
        n_heads=48, n_kv_heads=8, d_ff=16384,
        mlp="gated_silu", norm="rms", rope_theta=1_000_000.0,
    )


def smoke_config() -> ModelConfig:
    return config().replace(
        name="internlm2-smoke", n_layers=2, d_model=96, vocab=512,
        n_heads=6, n_kv_heads=2, d_ff=192, remat=False, attn_kv_chunk=64,
    )
