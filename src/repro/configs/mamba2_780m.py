"""mamba2-780m [ssm]: 48L d_model=1536 (attention-free) vocab=50280,
ssm_state=128, SSD (state-space duality) [arXiv:2405.21060; unverified].
d_inner=3072, 48 SSD heads of dim 64. O(1) decode state => runs long_500k."""

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-780m", family="ssm",
        n_layers=48, d_model=1536, vocab=50280,
        ssm_state=128, ssm_expand=2, ssm_head_dim=64, ssm_chunk=256,
        norm="rms",
    )


def smoke_config() -> ModelConfig:
    return config().replace(
        name="mamba2-smoke", n_layers=2, d_model=64, vocab=512,
        ssm_state=16, ssm_head_dim=16, ssm_chunk=16, remat=False,
    )
