"""qwen2.5-3b [dense]: 36L d_model=2048 16H (GQA kv=2) d_ff=11008
vocab=151936, QKV bias [hf:Qwen; hf]."""

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2.5-3b", family="dense",
        n_layers=36, d_model=2048, vocab=151936,
        n_heads=16, n_kv_heads=2, d_ff=11008, qkv_bias=True,
        mlp="gated_silu", norm="rms", rope_theta=1_000_000.0,
    )


def smoke_config() -> ModelConfig:
    return config().replace(
        name="qwen-smoke", n_layers=2, d_model=64, vocab=512,
        n_heads=4, n_kv_heads=2, d_ff=128, remat=False, attn_kv_chunk=64,
    )
