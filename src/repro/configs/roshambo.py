"""roshambo-nullhop: the paper's own workload (not an LM; used by the
Table I benchmark and examples, not by the LM dry-run)."""

from repro.accel.roshambo import RoShamBoConfig


def config() -> RoShamBoConfig:
    return RoShamBoConfig()


def smoke_config() -> RoShamBoConfig:
    return RoShamBoConfig(input_hw=16)
