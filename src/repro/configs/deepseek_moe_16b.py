"""deepseek-moe-16b [moe]: 28L d_model=2048 16H (MHA kv=16) vocab=102400,
fine-grained MoE: 64 routed experts top-6 + 2 shared, d_expert=1408
[arXiv:2401.06066; hf].

Simplification (noted in DESIGN.md): the real model's layer 0 is a dense
FFN; we keep all 28 layers MoE so the stack is scan-homogeneous (changes
<2% of params, none of the routing/transfer behaviour under study)."""

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-moe-16b", family="moe",
        n_layers=28, d_model=2048, vocab=102400,
        n_heads=16, n_kv_heads=16, d_ff=1408,
        n_experts=64, top_k=6, n_shared_experts=2, d_expert=1408,
        prefill_chunk=8192,  # §Perf B5: bounds MoE dispatch temp to <16GiB HBM
        mlp="gated_silu", norm="rms", rope_theta=10_000.0,
    )


def smoke_config() -> ModelConfig:
    return config().replace(
        name="deepseek-smoke", n_layers=2, d_model=64, vocab=512,
        n_heads=4, n_kv_heads=4, d_ff=64, n_experts=8, top_k=2,
        n_shared_experts=1, d_expert=64, remat=False, attn_kv_chunk=64,
    )
