"""h2o-danube-1.8b [dense]: 24L d_model=2560 32H (GQA kv=8) d_ff=6912
vocab=32000, llama+mistral mix with sliding-window attention
[arXiv:2401.16818; hf]. SWA window 4096 makes it sub-quadratic, so this
arch RUNS the long_500k cell."""

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="h2o-danube-1.8b", family="dense",
        n_layers=24, d_model=2560, vocab=32000,
        n_heads=32, n_kv_heads=8, d_ff=6912, sliding_window=4096,
        mlp="gated_silu", norm="rms", rope_theta=10_000.0,
    )


def smoke_config() -> ModelConfig:
    return config().replace(
        name="danube-smoke", n_layers=2, d_model=64, vocab=512,
        n_heads=4, n_kv_heads=2, d_ff=128, sliding_window=32,
        remat=False, attn_kv_chunk=64,
    )
