"""zamba2-1.2b [hybrid]: 38L d_model=2048 32H (MHA kv=32) d_ff=8192
vocab=32000, ssm_state=64 — Mamba2 stack + ONE shared attention block
(width 2*d_model) applied every 6 mamba layers with per-application LoRA
(r=128) [arXiv:2411.15242; hf]. SSM state is O(1) => runs long_500k."""

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="zamba2-1.2b", family="hybrid",
        n_layers=38, d_model=2048, vocab=32000,
        n_heads=32, n_kv_heads=32, d_ff=8192,
        ssm_state=64, ssm_expand=2, ssm_head_dim=64, ssm_chunk=256,
        hybrid_attn_every=6, hybrid_lora_rank=128,
        micro_override=16,
        mlp="gated_silu", norm="rms", rope_theta=10_000.0,
    )


def smoke_config() -> ModelConfig:
    return config().replace(
        name="zamba2-smoke", n_layers=5, d_model=64, vocab=512,
        n_heads=4, n_kv_heads=4, d_ff=128, ssm_state=16, ssm_head_dim=16,
        ssm_chunk=16, hybrid_attn_every=2, hybrid_lora_rank=8,
        remat=False, attn_kv_chunk=64,
    )
