"""granite-moe-1b-a400m [moe]: 24L d_model=1024 16H (GQA kv=8) vocab=49155,
32 experts top-8, d_expert=512 [hf:ibm-granite; hf]. Tied embeddings."""

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="granite-moe-1b-a400m", family="moe",
        n_layers=24, d_model=1024, vocab=49155,
        n_heads=16, n_kv_heads=8, d_ff=512,
        n_experts=32, top_k=8, n_shared_experts=0, d_expert=512,
        tie_embeddings=True,
        mlp="gated_silu", norm="rms", rope_theta=10_000.0,
    )


def smoke_config() -> ModelConfig:
    return config().replace(
        name="granite-smoke", n_layers=2, d_model=64, vocab=512,
        n_heads=4, n_kv_heads=2, d_ff=32, n_experts=4, top_k=2,
        d_expert=32, remat=False, attn_kv_chunk=64,
    )
