"""stablelm-12b [dense]: 40L d_model=5120 32H (GQA kv=8) d_ff=13824
vocab=100352 [hf:stabilityai; hf]. head_dim 160."""

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="stablelm-12b", family="dense",
        n_layers=40, d_model=5120, vocab=100352,
        n_heads=32, n_kv_heads=8, d_ff=13824,
        mlp="gated_silu", norm="ln", rope_theta=10_000.0,
    )


def smoke_config() -> ModelConfig:
    return config().replace(
        name="stablelm-smoke", n_layers=2, d_model=64, vocab=512,
        n_heads=4, n_kv_heads=2, d_ff=160, remat=False, attn_kv_chunk=64,
    )
