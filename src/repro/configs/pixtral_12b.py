"""pixtral-12b [vlm]: 40L d_model=5120 32H (GQA kv=8) d_ff=14336
vocab=131072 [hf:mistralai/Pixtral-12B-2409; unverified].

Backbone only per the assignment: the pixtral ViT frontend is a STUB —
input_specs supplies precomputed patch embeddings [B, n_prefix, D] that are
prepended to the text sequence. head_dim=128 (mistral-nemo convention)."""

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="pixtral-12b", family="vlm",
        n_layers=40, d_model=5120, vocab=131072,
        n_heads=32, n_kv_heads=8, head_dim=128, d_ff=14336,
        n_prefix_tokens=256,
        mlp="gated_silu", norm="rms", rope_theta=1_000_000.0,
    )


def smoke_config() -> ModelConfig:
    return config().replace(
        name="pixtral-smoke", n_layers=2, d_model=64, vocab=512,
        n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128,
        n_prefix_tokens=8, remat=False, attn_kv_chunk=64,
    )
