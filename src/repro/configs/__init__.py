"""Architecture registry: the 10 assigned configs + the paper's RoShamBo CNN."""

from repro.configs.registry import ARCHS, get_config, list_archs  # noqa: F401
