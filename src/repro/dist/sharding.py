"""Sharding rules: pytrees -> NamedShardings on the production meshes.

The rules are deliberately conservative — an axis is only assigned to a
tensor dimension when the dimension is exactly divisible by the mesh extent,
otherwise the leaf (dimension) stays replicated. Replication is always
*correct* (GSPMD inserts no resharding error, just more memory), so every
spec these functions emit is safe on any mesh; the rules only decide what is
profitably partitioned:

- parameters / optimizer state: the model (tensor-parallel) axis on the last
  divisible dimension (output features), falling back to the largest;
- batches: the data-parallel axes (``pod`` x ``data`` when both exist) on the
  leading (batch) dimension;
- KV/SSM caches: data-parallel axes on the slot/batch dimension (dim 1 of
  the layer-stacked layout).
"""

from __future__ import annotations

import math
from typing import Any

import jax
from jax.sharding import NamedSharding, PartitionSpec as P


def _sizes(mesh) -> dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def _data_axes(sizes: dict[str, int], extent: int) -> tuple[str, ...] | None:
    """Largest data-parallel axis group whose product divides ``extent``."""
    for names in (("pod", "data"), ("data",)):
        if all(n in sizes for n in names):
            total = math.prod(sizes[n] for n in names)
            if extent >= total and extent % total == 0:
                return names
    return None


def _param_spec(shape: tuple[int, ...], sizes: dict[str, int]) -> P:
    model = sizes.get("model", 1)
    ndim = len(shape)
    spec: list[Any] = [None] * ndim
    if model > 1 and ndim >= 1:
        # prefer the trailing (output-feature) dim, then the largest
        for d in sorted(range(ndim),
                        key=lambda d: (d == ndim - 1, shape[d]),
                        reverse=True):
            if shape[d] >= model and shape[d] % model == 0:
                spec[d] = "model"
                break
    return P(*spec)


def _named(mesh, spec: P) -> NamedSharding:
    return NamedSharding(mesh, spec)


def param_sharding(params: Any, mesh) -> Any:
    """Tensor-parallel sharding for a parameter pytree."""
    sizes = _sizes(mesh)
    return jax.tree.map(
        lambda leaf: _named(mesh, _param_spec(tuple(leaf.shape), sizes)),
        params)


def opt_state_sharding(opt_state: Any, mesh) -> Any:
    """Optimizer state mirrors the parameter rules (moments are
    parameter-shaped; scalars like step counters replicate)."""
    return param_sharding(opt_state, mesh)


def batch_sharding_tree(batch: Any, mesh) -> Any:
    """Data-parallel sharding for an input-batch pytree (batch dim 0)."""
    sizes = _sizes(mesh)

    def spec_for(leaf) -> NamedSharding:
        shape = tuple(leaf.shape)
        spec: list[Any] = [None] * len(shape)
        if shape:
            axes = _data_axes(sizes, shape[0])
            if axes is not None:
                spec[0] = axes if len(axes) > 1 else axes[0]
        return _named(mesh, P(*spec))

    return jax.tree.map(spec_for, batch)


def cache_sharding(cache: Any, mesh) -> Any:
    """Decode-cache sharding: slots (batch) on the data axes. Cache leaves
    are layer-stacked ``[L, B, ...]``; per-layer lengths ``[L]`` replicate."""
    sizes = _sizes(mesh)

    def spec_for(leaf) -> NamedSharding:
        shape = tuple(leaf.shape)
        spec: list[Any] = [None] * len(shape)
        if len(shape) >= 2:
            axes = _data_axes(sizes, shape[1])
            if axes is not None:
                spec[1] = axes if len(axes) > 1 else axes[0]
        return _named(mesh, P(*spec))

    return jax.tree.map(spec_for, cache)
