"""Fault policy and per-run fault-event accounting.

Two consumers share this module:

- :class:`repro.train.loop.Trainer` — every step's wall time and
  finite-ness verdict flow through :meth:`FaultState.record_step`, which
  flags stragglers (z-score over a rolling window, via
  :class:`repro.utils.timing.StepClock`) and counts steps the optimizer
  skipped because of non-finite gradients. Restart counting is incremented
  by the loop when it resumes from a checkpoint.
- the transfer stack's self-healing layer (``repro.core.faults`` and the
  channel-group retry/quarantine machinery) — :class:`TransferFaultState`
  is its ledger: one thread-safe counter block per engine/group recording
  descriptor timeouts, stripe retries, checksum failures and channel
  quarantine transitions, so serving engines can expose deadline-miss and
  retry rates without reaching into channel internals.
"""

from __future__ import annotations

from dataclasses import dataclass, field

from repro.analysis.validated import make_lock
from repro.utils.timing import StepClock


@dataclass(frozen=True)
class FaultPolicy:
    """Knobs for loop-level fault tolerance. Defaults match the trainer."""

    checkpoint_every: int = 100
    keep_checkpoints: int = 3
    straggler_window: int = 50
    straggler_zscore: float = 4.0
    skip_nonfinite: bool = True
    max_restarts: int = 16


@dataclass
class FaultState:
    """Mutable per-run fault counters (one per Trainer)."""

    policy: FaultPolicy = field(default_factory=FaultPolicy)
    restarts: int = 0
    stragglers_detected: int = 0
    steps_skipped_nonfinite: int = 0
    steps_recorded: int = 0
    _clock: StepClock | None = None

    def __post_init__(self) -> None:
        if self._clock is None:
            self._clock = StepClock(window=self.policy.straggler_window,
                                    zscore_threshold=self.policy.straggler_zscore)

    def record_step(self, dt_s: float, step_ok: float = 1.0) -> bool:
        """Record one step; returns True if the step was anomalous
        (straggler wall time and/or skipped as non-finite)."""
        self.steps_recorded += 1
        straggler = self._clock.record(dt_s)
        if straggler:
            self.stragglers_detected += 1
        skipped = step_ok < 0.5
        if skipped:
            self.steps_skipped_nonfinite += 1
        return straggler or skipped

    def summary(self) -> dict[str, int]:
        return {
            "steps": self.steps_recorded,
            "restarts": self.restarts,
            "stragglers": self.stragglers_detected,
            "skipped_nonfinite": self.steps_skipped_nonfinite,
        }


class TransferFaultState:
    """Thread-safe fault ledger for one transfer surface (engine / channel
    group / adaptive facade — an adaptive facade hands ONE instance to every
    plan generation, so counters survive safe-point swaps).

    Counter semantics: ``faults`` is every observed fault event (injected
    or organic — timeouts and checksum failures are also counted in their
    own columns); ``retries``/``retry_successes`` track the channel layer's
    resubmit-on-sibling path; ``quarantines``/``unquarantines`` count
    rotation transitions. ``faults_by_channel`` attributes events to the
    channel index that raised them; ``faults_by_tenant`` attributes them
    to the QosSpec tenant whose transfer hit the fault (fault/retry/
    quarantine columns per tenant), so a misbehaving tenant's retries are
    billable instead of vanishing into the per-class aggregate."""

    def __init__(self) -> None:
        self._lock = make_lock("TransferFaultState._lock")
        self.faults = 0  # guarded-by: _lock
        self.timeouts = 0  # guarded-by: _lock
        self.checksum_failures = 0  # guarded-by: _lock
        self.retries = 0  # guarded-by: _lock
        self.retry_successes = 0  # guarded-by: _lock
        self.quarantines = 0  # guarded-by: _lock
        self.unquarantines = 0  # guarded-by: _lock
        self.faults_by_channel: dict[int, int] = {}  # guarded-by: _lock
        self.faults_by_tenant: dict[str, dict[str, int]] = {}  # guarded-by: _lock

    def _tenant_row(self, tenant: str) -> dict[str, int]:  # requires-lock: _lock
        row = self.faults_by_tenant.get(tenant)
        if row is None:
            row = self.faults_by_tenant[tenant] = {
                "faults": 0, "timeouts": 0, "checksum_failures": 0,
                "retries": 0, "retry_successes": 0, "quarantines": 0}
        return row

    def record_fault(self, channel: int | None = None, *,
                     timeout: bool = False, checksum: bool = False,
                     tenant: str | None = None) -> None:
        with self._lock:
            self.faults += 1
            if timeout:
                self.timeouts += 1
            if checksum:
                self.checksum_failures += 1
            if channel is not None:
                self.faults_by_channel[channel] = (
                    self.faults_by_channel.get(channel, 0) + 1)
            if tenant is not None:
                row = self._tenant_row(tenant)
                row["faults"] += 1
                row["timeouts"] += int(timeout)
                row["checksum_failures"] += int(checksum)

    def record_retry(self, *, success: bool,
                     tenant: str | None = None) -> None:
        with self._lock:
            self.retries += 1
            if success:
                self.retry_successes += 1
            if tenant is not None:
                row = self._tenant_row(tenant)
                row["retries"] += 1
                row["retry_successes"] += int(success)

    def record_quarantine(self, channel: int, *, on: bool,
                          tenant: str | None = None) -> None:
        with self._lock:
            if on:
                self.quarantines += 1
            else:
                self.unquarantines += 1
            if tenant is not None and on:
                self._tenant_row(tenant)["quarantines"] += 1

    def summary(self) -> dict[str, int | dict]:
        with self._lock:
            return {
                "faults": self.faults,
                "timeouts": self.timeouts,
                "checksum_failures": self.checksum_failures,
                "retries": self.retries,
                "retry_successes": self.retry_successes,
                "quarantines": self.quarantines,
                "unquarantines": self.unquarantines,
                "faults_by_channel": dict(self.faults_by_channel),
                "faults_by_tenant": {t: dict(row) for t, row
                                     in self.faults_by_tenant.items()},
            }
