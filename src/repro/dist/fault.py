"""Fault policy and per-run fault-event accounting.

Used by :class:`repro.train.loop.Trainer`: every step's wall time and
finite-ness verdict flow through :meth:`FaultState.record_step`, which flags
stragglers (z-score over a rolling window, via
:class:`repro.utils.timing.StepClock`) and counts steps the optimizer
skipped because of non-finite gradients. Restart counting is incremented by
the loop when it resumes from a checkpoint.
"""

from __future__ import annotations

from dataclasses import dataclass, field

from repro.utils.timing import StepClock


@dataclass(frozen=True)
class FaultPolicy:
    """Knobs for loop-level fault tolerance. Defaults match the trainer."""

    checkpoint_every: int = 100
    keep_checkpoints: int = 3
    straggler_window: int = 50
    straggler_zscore: float = 4.0
    skip_nonfinite: bool = True
    max_restarts: int = 16


@dataclass
class FaultState:
    """Mutable per-run fault counters (one per Trainer)."""

    policy: FaultPolicy = field(default_factory=FaultPolicy)
    restarts: int = 0
    stragglers_detected: int = 0
    steps_skipped_nonfinite: int = 0
    steps_recorded: int = 0
    _clock: StepClock | None = None

    def __post_init__(self) -> None:
        if self._clock is None:
            self._clock = StepClock(window=self.policy.straggler_window,
                                    zscore_threshold=self.policy.straggler_zscore)

    def record_step(self, dt_s: float, step_ok: float = 1.0) -> bool:
        """Record one step; returns True if the step was anomalous
        (straggler wall time and/or skipped as non-finite)."""
        self.steps_recorded += 1
        straggler = self._clock.record(dt_s)
        if straggler:
            self.stragglers_detected += 1
        skipped = step_ok < 0.5
        if skipped:
            self.steps_skipped_nonfinite += 1
        return straggler or skipped

    def summary(self) -> dict[str, int]:
        return {
            "steps": self.steps_recorded,
            "restarts": self.restarts,
            "stragglers": self.stragglers_detected,
            "skipped_nonfinite": self.steps_skipped_nonfinite,
        }
