"""Elastic re-meshing: plan the device mesh after hosts join or leave.

When a host dies mid-run the fleet shrinks; the replacement mesh must keep
the model-parallel axis intact (tensor-parallel shards are not
re-partitionable without moving parameter bytes) while giving up
data-parallel replicas. :func:`shrink_mesh` computes that plan;
:func:`reshard_plan` says what a transition between two plans actually costs
— the distributed analogue of the paper's question "how many bytes must move,
and who is blocked while they do".
"""

from __future__ import annotations

import math
from dataclasses import dataclass


@dataclass(frozen=True)
class MeshPlan:
    """A named device-mesh shape, e.g. (data, model) or (pod, data, model)."""

    shape: tuple[int, ...]
    axis_names: tuple[str, ...]

    def __post_init__(self) -> None:
        if len(self.shape) != len(self.axis_names):
            raise ValueError("shape and axis_names must align")

    @property
    def n_devices(self) -> int:
        return math.prod(self.shape)

    def axis_size(self, name: str) -> int:
        return self.shape[self.axis_names.index(name)]


def shrink_mesh(n_devices: int, model_parallel: int,
                multi_pod: bool = False) -> MeshPlan:
    """Largest mesh of at most ``n_devices`` that preserves the model axis.

    Single-pod: (data, model). Multi-pod: (pod, data, model) with the pod
    axis the largest power of two dividing the data extent (gradient
    all-reduces stay hierarchical: intra-pod ring, then inter-pod)."""
    if model_parallel < 1:
        raise ValueError("model_parallel must be >= 1")
    data = n_devices // model_parallel
    if data < 1:
        raise ValueError(
            f"cannot keep model axis of {model_parallel} with only "
            f"{n_devices} devices"
        )
    if not multi_pod:
        return MeshPlan((data, model_parallel), ("data", "model"))
    pods = 1
    while data % (pods * 2) == 0:
        pods *= 2
    return MeshPlan((pods, data // pods, model_parallel),
                    ("pod", "data", "model"))


def reshard_plan(param_millions: float, old: MeshPlan,
                 new: MeshPlan) -> dict:
    """Cost plan for moving a run from ``old`` to ``new``.

    If the model-parallel width changed, every parameter shard must be
    re-partitioned (params move); otherwise only the optimizer state of
    vanished data replicas is re-materialised from the survivors' copy."""
    model_old = old.axis_size("model")
    model_new = new.axis_size("model")
    params_move = model_old != model_new
    grad_replicas = new.n_devices // model_new
    param_bytes = param_millions * 1e6 * 2  # bf16 resting precision
    bytes_to_move = param_bytes if params_move else 0.0
    return {
        "params_move": params_move,
        "grad_replicas": grad_replicas,
        "model_parallel": model_new,
        "devices_lost": max(0, old.n_devices - new.n_devices),
        "bytes_to_move": bytes_to_move,
    }
