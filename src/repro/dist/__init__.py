"""repro.dist — elastic re-meshing and fault-tolerance policy.

The distributed-systems face of the paper's lesson: just as the transfer
engine bounds how long the PS is blocked on one DMA, the training loop must
bound how long the fleet is blocked on one failed or straggling host.
:mod:`repro.dist.elastic` plans the shrunken device mesh after a host loss;
:mod:`repro.dist.fault` tracks restarts, stragglers, and skipped non-finite
steps for the :class:`repro.train.loop.Trainer`;
:mod:`repro.dist.sharding` maps parameter/batch/cache pytrees to
NamedShardings on the production meshes.
"""

from repro.dist.elastic import MeshPlan, reshard_plan, shrink_mesh  # noqa: F401
from repro.dist.fault import (  # noqa: F401
    FaultPolicy,
    FaultState,
    TransferFaultState,
)
from repro.dist.sharding import (  # noqa: F401
    batch_sharding_tree,
    cache_sharding,
    opt_state_sharding,
    param_sharding,
)
