"""Checkpointing: atomic, restartable, optionally async (INTERRUPT-mode).

Format: one .npz per checkpoint (flattened pytree paths -> arrays) plus a
small JSON manifest; writes go to a temp name and rename atomically so a
crash mid-write never corrupts the latest checkpoint. RX (device->host) of
the state is itself a policy-driven transfer: the async mode stages the
device_get + write on a private completion worker (the kernel-driver
pattern) so
training continues during the write — the paper's 'free the PS for other
tasks' argument, applied to checkpointing.

On a multi-host cluster each host writes its addressable shards
(process-sliced paths); here single-process writes the full tree.
"""

from __future__ import annotations

import json
import os
import jax.numpy as jnp
import threading
import time
from dataclasses import dataclass
from typing import Any

import jax
import numpy as np

from repro.analysis.validated import make_lock
from repro.core.runtime import DedicatedWorkerPool
from repro.core.transfer import Ticket


def _flatten(tree: Any) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                       for k in path)
        arr = np.asarray(jax.device_get(leaf))
        if arr.dtype.kind == "V" or str(arr.dtype) == "bfloat16":
            # np.savez cannot persist ml_dtypes; store widened, restore casts
            arr = arr.astype(np.float32)
        flat[key] = arr
    return flat


def _unflatten_into(template: Any, flat: dict[str, np.ndarray]) -> Any:
    paths, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for path, leaf in paths:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                       for k in path)
        arr = np.asarray(flat[key])
        if arr.dtype != leaf.dtype:  # widened on save (e.g. bf16 -> f32)
            arr = np.asarray(jnp.asarray(arr).astype(leaf.dtype))
        leaves.append(arr.reshape(leaf.shape))
    return jax.tree_util.tree_unflatten(treedef, leaves)


def save_checkpoint(directory: str, step: int, state: Any, *,
                    keep: int = 3) -> str:
    os.makedirs(directory, exist_ok=True)
    flat = _flatten(state)
    tmp = os.path.join(directory, f".tmp-step-{step}.npz")
    final = os.path.join(directory, f"step-{step:08d}.npz")
    np.savez(tmp, **flat)
    os.replace(tmp, final)  # atomic
    manifest = os.path.join(directory, "manifest.json")
    entries = []
    if os.path.exists(manifest):
        with open(manifest) as f:
            entries = json.load(f)["checkpoints"]
    entries = [e for e in entries if e["step"] != step]
    entries.append({"step": step, "file": os.path.basename(final),
                    "time": time.time()})
    entries.sort(key=lambda e: e["step"])
    # GC old checkpoints
    while len(entries) > keep:
        old = entries.pop(0)
        try:
            os.remove(os.path.join(directory, old["file"]))
        except FileNotFoundError:
            pass
    with open(manifest, "w") as f:
        json.dump({"checkpoints": entries}, f)
    return final


def restore_latest(directory: str, template: Any) -> tuple[int, Any] | None:
    manifest = os.path.join(directory, "manifest.json")
    if not os.path.exists(manifest):
        return None
    with open(manifest) as f:
        entries = json.load(f)["checkpoints"]
    if not entries:
        return None
    last = entries[-1]
    with np.load(os.path.join(directory, last["file"])) as z:
        flat = {k: z[k] for k in z.files}
    return last["step"], _unflatten_into(template, flat)


@dataclass
class CheckpointManager:
    """Periodic checkpoints with sync (POLLING) or async (INTERRUPT) writes."""

    directory: str
    every: int = 100
    keep: int = 3
    async_write: bool = True
    _pending: Ticket | None = None  # guarded-by: _lock
    _lock: threading.Lock = None  # type: ignore[assignment]
    _pool: DedicatedWorkerPool = None  # type: ignore[assignment]

    def __post_init__(self):
        self._lock = make_lock("CheckpointManager._lock")
        # one DEDICATED writer worker per manager: a multi-second write
        # must never occupy a shared TransferRuntime worker (that is
        # the head-of-line blocking the runtime's QoS exists to stop)
        self._pool = DedicatedWorkerPool(workers=1)

    def maybe_save(self, step: int, state: Any) -> bool:
        if step == 0 or step % self.every:
            return False
        if not self.async_write:
            save_checkpoint(self.directory, step, state, keep=self.keep)
            return True
        self.wait()  # never two writers racing (buffer-in-flight rule)
        # snapshot to host NOW (device buffers may be donated next step),
        # write on the completion thread.
        flat_state = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), state)
        done, out = self._pool.submit(
            lambda: save_checkpoint(self.directory, step, flat_state,
                                    keep=self.keep))
        with self._lock:
            self._pending = Ticket(done, out)
        return True

    def wait(self) -> None:
        with self._lock:
            if self._pending is not None:
                # the lock IS the never-two-writers rule: a second saver
                # must queue behind the in-flight write, and only
                # maybe_save/wait ever contend on this lock.
                self._pending.wait()  # lock-ok: serializes writers by design
                self._pending = None

    def restore_latest(self, template: Any):
        return restore_latest(self.directory, template)
