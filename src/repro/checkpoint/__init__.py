from repro.checkpoint.checkpoint import (  # noqa: F401
    CheckpointManager,
    restore_latest,
    save_checkpoint,
)
