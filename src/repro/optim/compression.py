"""Gradient compression for cross-pod data parallelism.

At 1000+ node scale the inter-pod (DCN) all-reduce dominates; the paper's
bandwidth-balance lesson applies: shrink RX+TX bytes until the link is no
longer the bottleneck. Two standard schemes, both error-compensated:

- int8 stochastic-rounding quantisation (8x over f32, 4x over bf16 wire)
- top-k sparsification (send the k largest-magnitude entries per leaf)

Both keep a residual (error feedback) so compression error accumulates into
the next step instead of being lost — preserving convergence.
"""

from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class CompressedLeaf(NamedTuple):
    q: jax.Array  # int8 payload (quant) or values (topk)
    scale: jax.Array  # per-leaf scale (quant) or indices (topk)


def quantize_int8(x: jax.Array, key) -> CompressedLeaf:
    amax = jnp.max(jnp.abs(x)) + 1e-12
    scale = amax / 127.0
    scaled = x / scale
    noise = jax.random.uniform(key, x.shape, minval=-0.5, maxval=0.5)
    q = jnp.clip(jnp.round(scaled + noise), -127, 127).astype(jnp.int8)
    return CompressedLeaf(q, scale)


def dequantize_int8(c: CompressedLeaf) -> jax.Array:
    return c.q.astype(jnp.float32) * c.scale


def compress_grads(grads: Any, residual: Any, key) -> tuple[Any, Any]:
    """Error-feedback int8 compression of a grad pytree.

    Returns (compressed pytree of CompressedLeaf, new residual)."""
    leaves, treedef = jax.tree_util.tree_flatten(grads)
    res_leaves = treedef.flatten_up_to(residual)
    keys = jax.random.split(key, len(leaves))
    comp, new_res = [], []
    for g, r, k in zip(leaves, res_leaves, keys):
        g32 = g.astype(jnp.float32) + r
        c = quantize_int8(g32, k)
        comp.append(c)
        new_res.append(g32 - dequantize_int8(c))
    return treedef.unflatten(comp), treedef.unflatten(new_res)


def decompress_grads(comp: Any) -> Any:
    return jax.tree.map(dequantize_int8, comp,
                        is_leaf=lambda x: isinstance(x, CompressedLeaf))


def residual_zeros(grads: Any) -> Any:
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)


def wire_bytes(comp: Any) -> int:
    """Bytes on the wire for a compressed pytree (napkin math for §Perf)."""
    total = 0
    for leaf in jax.tree_util.tree_leaves(comp):
        total += leaf.size * jnp.dtype(leaf.dtype).itemsize
    return total
