"""AdamW with mixed precision + optional gradient compression hooks.

Pure-JAX (no optax): params are kept in the model compute dtype (bf16); the
optimizer state carries an f32 master copy plus f32 first/second moments.
State leaves get their own (finer) sharding than params — see
repro.dist.sharding.opt_state_spec — giving ZeRO-1-style sharded optimizer
memory across the ('data','model') mesh.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp

from repro.utils.pytree import global_norm, tree_finite


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    skip_nonfinite: bool = True  # fault tolerance: skip bad steps


def adamw_init(params: Any) -> dict:
    # copy=True: an f32 leaf's master must NOT alias the param buffer
    # (both are donated by the train step).
    f32 = lambda x: jnp.array(x, dtype=jnp.float32, copy=True)  # noqa: E731
    return {
        "step": jnp.zeros((), jnp.int32),
        "master": jax.tree.map(f32, params),
        "m": jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32), params),
        "v": jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32), params),
    }


def adamw_update(cfg: AdamWConfig, grads: Any, opt_state: dict, params: Any,
                 lr_scale: jax.Array | float = 1.0) -> tuple[Any, dict, dict]:
    """Returns (new_params, new_opt_state, metrics)."""
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))
    step = opt_state["step"] + 1
    t = step.astype(jnp.float32)
    bc1 = 1.0 - cfg.b1 ** t
    bc2 = 1.0 - cfg.b2 ** t
    lr = cfg.lr * lr_scale

    def upd(g, m, v, master):
        g = g.astype(jnp.float32) * clip
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        update = (m / bc1) / (jnp.sqrt(v / bc2) + cfg.eps)
        if master.ndim >= 2:  # decoupled weight decay on matrices only
            update = update + cfg.weight_decay * master
        return m, v, master - lr * update

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_m = treedef.flatten_up_to(opt_state["m"])
    flat_v = treedef.flatten_up_to(opt_state["v"])
    flat_ma = treedef.flatten_up_to(opt_state["master"])
    out = [upd(g, m, v, ma) for g, m, v, ma in zip(flat_g, flat_m, flat_v, flat_ma)]
    new_m = treedef.unflatten([o[0] for o in out])
    new_v = treedef.unflatten([o[1] for o in out])
    new_master = treedef.unflatten([o[2] for o in out])

    if cfg.skip_nonfinite:
        ok = tree_finite(grads)
        keep = lambda new, old: jax.tree.map(  # noqa: E731
            lambda n, o: jnp.where(ok, n, o), new, old)
        new_m = keep(new_m, opt_state["m"])
        new_v = keep(new_v, opt_state["v"])
        new_master = keep(new_master, opt_state["master"])
        step = jnp.where(ok, step, opt_state["step"])
    else:
        ok = jnp.array(True)

    new_params = jax.tree.map(lambda ma, p: ma.astype(p.dtype), new_master, params)
    new_state = {"step": step, "master": new_master, "m": new_m, "v": new_v}
    metrics = {"grad_norm": gnorm, "step_ok": ok.astype(jnp.float32)}
    return new_params, new_state, metrics
