"""Back-compat shim — :class:`CooperativeScheduler` moved to
:mod:`repro.core.runtime`, where it is the user-level 'scheduled' backend
of the unified TransferRuntime interface (the paper's three management
modes as three backends of one abstraction). Import from there."""

from repro.core.runtime import (  # noqa: F401
    CooperativeScheduler,
    SchedulerStats,
)
