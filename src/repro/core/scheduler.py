# analysis: skip-module — deprecated re-export shim, no locks of its own
"""Back-compat shim — :class:`CooperativeScheduler` moved to
:mod:`repro.core.runtime`, where it is the user-level 'scheduled' backend
of the unified TransferRuntime interface (the paper's three management
modes as three backends of one abstraction; see
:class:`repro.core.runtime.ScheduledBackend`). Import from there."""

import warnings

from repro.core.runtime import (  # noqa: F401
    CooperativeScheduler,
    SchedulerStats,
)

warnings.warn(
    "repro.core.scheduler is deprecated; import CooperativeScheduler from "
    "the repro.core facade (SchedulerStats stays in repro.core.runtime, the "
    "'scheduled' management backend — see repro.core.runtime."
    "ScheduledBackend). The shim will be removed next release.",
    DeprecationWarning,
    stacklevel=2,
)
