"""Cooperative scheduler — the paper's 'user-level scheduled' driver.

The paper's intermediate mode keeps everything at user level but routes DMA
requests through a scheduler so the application is never stuck in a dead-lock
wait: between DMA chunks the scheduler runs other registered tasks (in the
paper: collecting DVS events and normalising them into frames).

This is a plain round-robin cooperative scheduler: ``submit`` enqueues a
transfer task, ``register_background`` adds a recurring task that is given a
slice between transfer tasks, ``drain`` runs until the transfer queue is
empty. Single-threaded by design — the point of this mode is avoiding
threads/interrupts while still not monopolising the CPU."""

from __future__ import annotations

import collections
import time
from dataclasses import dataclass, field
from typing import Callable


@dataclass
class SchedulerStats:
    transfer_tasks_run: int = 0
    background_slices_run: int = 0
    drain_calls: int = 0
    total_background_s: float = 0.0


class CooperativeScheduler:
    def __init__(self, background_budget_s: float = 50e-6):
        self._transfers: collections.deque[Callable[[], None]] = collections.deque()
        self._background: list[Callable[[], None]] = []
        self._bg_cursor = 0
        self.background_budget_s = background_budget_s
        self.stats = SchedulerStats()

    def submit(self, task: Callable[[], None]) -> None:
        self._transfers.append(task)

    def register_background(self, task: Callable[[], None]) -> None:
        """Register a recurring background task (e.g. data normalisation)."""
        self._background.append(task)

    def _run_background_slice(self) -> None:
        if not self._background:
            return
        t0 = time.perf_counter()
        # round-robin through background tasks within the budget
        while time.perf_counter() - t0 < self.background_budget_s:
            task = self._background[self._bg_cursor % len(self._background)]
            self._bg_cursor += 1
            task()
            self.stats.background_slices_run += 1
            if not self._background:
                break
        self.stats.total_background_s += time.perf_counter() - t0

    def drain(self) -> None:
        """Run transfer tasks to completion, interleaving background slices."""
        self.stats.drain_calls += 1
        while self._transfers:
            task = self._transfers.popleft()
            task()
            self.stats.transfer_tasks_run += 1
            self._run_background_slice()
