"""Host<->device descriptor-ring transfer engine with the paper's policy matrix.

The paper evaluates how the *software policy* controlling DMA between the
processing system (PS) and programmable logic (PL) determines delivered
bandwidth. The three managements map onto JAX host<->device semantics:

- ``POLLING``   — user-level polling driver: issue the transfer and spin-wait
  (``block_until_ready``) before touching the data. Lowest per-transfer
  latency; host is blocked for the duration (the paper's warning: for large
  CNNs this blocks the whole system).
- ``SCHEDULED`` — user-level scheduled driver: transfers are enqueued on a
  cooperative scheduler which interleaves them with other registered tasks
  (sensor collection / normalization in the paper; data-prep and metric tasks
  here). Slightly higher latency, no dead-lock waits.
- ``INTERRUPT`` — kernel-level interrupt driver: descriptors are staged
  onto the process-shared :class:`~repro.core.runtime.TransferRuntime`
  (the interrupt controller: one bounded worker pool arbitrating every
  engine's completions by priority class); the caller gets a ticket and
  is *notified* (callback / event) on completion. Highest fixed overhead
  per transfer, best overlap, memory-safety enforced (a staging slot
  cannot be re-staged before completion — the engine raises, mirroring
  the kernel driver's protection role). Each engine registers with a
  :class:`~repro.core.runtime.PriorityClass` (default ``LAYER``); token
  streams register ``TOKEN``, prefetch ``BULK`` — individual calls may
  override via ``priority=``.

Descriptor ring
---------------
Buffering is a *ring* of N staging slots (the scatter-gather descriptor ring
of the Xilinx AXI-DMA driver): chunk k+N can only be staged once chunk k's
descriptor completed. ``Buffering.SINGLE`` and ``Buffering.DOUBLE`` are the
degenerate rings of depth 1 and 2; ``Buffering.RING`` plus
``TransferPolicy.ring_depth`` generalises to any depth, so the in-flight
window (and therefore the achievable TX/compute/RX overlap) is a tunable
policy knob instead of a hard-coded pair of buffers.

Staged layouts
--------------
:class:`StagedLayout` precomputes the pack plan (offset / shape / dtype per
array) for a fixed set of host arrays ONCE and owns a preallocated staging
buffer that is reused for every subsequent frame: per-frame cost is at most
one memcpy into the staging buffer — and zero when the arrays are unchanged
since the last pack (the steady state of inference weight streaming). The
per-engine :class:`LayoutCache` keys layouts by caller-chosen identity
(e.g. layer name), so ``pack``/``unpack`` never re-derive offsets or
re-allocate across frames. This is the ZynqNet lesson: staging *layout* is a
one-time cost, not a per-frame one.

Partitioning: ``UNIQUE`` sends the payload in one transfer; ``BLOCKS`` splits
it into ``block_bytes`` chunks (only BLOCKS lets a depth>=2 ring overlap
within a single logical transfer).

Everything here is *measured*, not simulated: on this container the device is
CPU, but the staging/copy/dispatch structure (and therefore the relative
behaviour the paper studies — fixed overhead vs per-byte cost, overlap gains)
is real.
"""

from __future__ import annotations

import collections
import enum
import math
import threading
import time
import zlib
from dataclasses import dataclass, replace
from typing import Any, Callable, Sequence

import jax
import numpy as np

from repro.analysis.validated import make_lock
from repro.core.runtime import (
    PREEMPTIBLE_CLASSES,
    CooperativeScheduler,
    PreemptibleWork,
    PriorityClass,
    RuntimeHandle,
    TransferChecksumError,
    TransferFaultError,
    TransferRuntime,
    TransferTimeoutError,
    get_runtime,
)
from repro.core.qos import QosSpec, resolve_submit_qos

__all__ = [  # re-exports: the fault taxonomy lives in runtime (no cycle)
    "Management", "Buffering", "Partitioning", "TransferPolicy",
    "TransferStats", "TransferEngine", "Ticket", "SGTicket", "StagedLayout",
    "LayoutCache", "BufferInFlightError", "TransferFaultError",
    "TransferTimeoutError", "TransferChecksumError", "reassemble_chunks",
    "carve_flat_out", "choose_sg", "sg_crossover_segments",
    "host_copy_bw_Bps",
]

# Per-engine rolling window of (direction, management, nbytes, seconds)
# chunk samples — the online cost-model refit (repro.core.adaptive) fits
# t(n) = t0 + n/BW from these, so the window must bound memory on its own.
_CHUNK_SAMPLE_WINDOW = 512
# Per-engine/group window of recorded TransferStats (recent history for
# summaries/tests; exact lifetime totals live in the *_total counters).
_STATS_WINDOW = 4096
# Per-engine rolling window of grouped-transaction samples
# (direction, n_segments, total_bytes, wall_s) from _submit_many — the
# pack-vs-SG crossover refits the effective per-segment overhead from these.
_SG_SAMPLE_WINDOW = 64
# pack-vs-SG fallback rule when no cost model is fitted yet: SG only for
# layer sets that are unambiguously "few large arrays" (the shape where
# dodging the staging memcpy cannot lose to per-segment overhead).
_SG_FALLBACK_MAX_SEGMENTS = 16
_SG_FALLBACK_MIN_SEG_BYTES = 1 << 18


class Management(enum.Enum):
    POLLING = "polling"
    SCHEDULED = "scheduled"
    INTERRUPT = "interrupt"


class Buffering(enum.Enum):
    SINGLE = "single"
    DOUBLE = "double"
    RING = "ring"  # generalized descriptor ring; depth from TransferPolicy


class Partitioning(enum.Enum):
    UNIQUE = "unique"
    BLOCKS = "blocks"


_DEFAULT_RING_DEPTH = 4


@dataclass(frozen=True)
class TransferPolicy:
    """The paper's full policy point. Carried in model/run configs.

    ``ring_depth``: number of staging slots in the descriptor ring. 0 means
    "derive from ``buffering``" (SINGLE=1, DOUBLE=2, RING=4); any positive
    value overrides it. ``completion_workers`` is a sizing HINT for the
    shared :class:`~repro.core.runtime.TransferRuntime` worker cap (the
    per-engine pools it used to size are retired — completions dispatch
    on the process-wide runtime).
    """

    management: Management = Management.INTERRUPT
    buffering: Buffering = Buffering.DOUBLE
    partitioning: Partitioning = Partitioning.BLOCKS
    block_bytes: int = 1 << 20  # 1 MiB default chunk (paper crossover region)
    ring_depth: int = 0  # 0 => derived from buffering
    completion_workers: int = 2
    # preemptive chunked dispatch (INTERRUPT only): LAYER/BULK TX chunks
    # bigger than this are submitted as resumable segment iterators
    # (:class:`~repro.core.runtime.PreemptibleWork`) so the shared runtime
    # can yield mid-chunk to TOKEN/SENSOR arrivals. 0 disables it (whole
    # chunks stay the non-preemptive unit — the PR-4 behaviour). Sized by
    # the fitted cost model (:meth:`~repro.core.cost_model.
    # TransferCostModel.preempt_chunk_bytes`) in adaptive plans.
    preempt_chunk_bytes: int = 0
    # opt-in end-to-end integrity: crc32 per descriptor, verified when the
    # RX payload lands on the host. A mismatch raises
    # :class:`~repro.core.runtime.TransferChecksumError` (a retryable
    # TransferFaultError — the channel layer resubmits the stripe on a
    # sibling ring). On real HW the expected crc rides the TX-computed
    # descriptor metadata; on this backend it is computed from the device
    # buffer just before the landing copy.
    checksum: bool = False
    # per-descriptor deadline for the engine's own INTERNAL ticket waits
    # (the ring back-pressure waits inside sync tx/rx): None = unbounded
    # (pre-fault-layer behaviour). Callers of the async API bound their own
    # waits via ``Ticket.wait(timeout=)``.
    descriptor_timeout_s: float | None = None

    def __post_init__(self) -> None:
        if self.ring_depth < 0:
            raise ValueError(f"ring_depth must be >= 0, got {self.ring_depth}")
        if self.completion_workers < 1:
            raise ValueError("completion_workers must be >= 1")
        if self.preempt_chunk_bytes < 0:
            raise ValueError(
                f"preempt_chunk_bytes must be >= 0, got "
                f"{self.preempt_chunk_bytes}")
        if (self.descriptor_timeout_s is not None
                and self.descriptor_timeout_s <= 0):
            raise ValueError(
                f"descriptor_timeout_s must be positive or None, got "
                f"{self.descriptor_timeout_s}")

    @property
    def depth(self) -> int:
        """Effective descriptor-ring depth (in-flight staging slots)."""
        if self.ring_depth > 0:
            return self.ring_depth
        return {Buffering.SINGLE: 1, Buffering.DOUBLE: 2,
                Buffering.RING: _DEFAULT_RING_DEPTH}[self.buffering]

    def with_(self, **kw) -> "TransferPolicy":
        return replace(self, **kw)

    @property
    def tag(self) -> str:
        base = (
            f"{self.management.value}-{self.buffering.value}-"
            f"{self.partitioning.value}"
        )
        if self.ring_depth > 0 or self.buffering is Buffering.RING:
            base += f"-d{self.depth}"
        return base

    @staticmethod
    def user_level_polling() -> "TransferPolicy":
        return TransferPolicy(Management.POLLING, Buffering.SINGLE, Partitioning.UNIQUE)

    @staticmethod
    def user_level_scheduled() -> "TransferPolicy":
        return TransferPolicy(
            Management.SCHEDULED, Buffering.SINGLE, Partitioning.UNIQUE
        )

    @staticmethod
    def kernel_level() -> "TransferPolicy":
        return TransferPolicy(
            Management.INTERRUPT, Buffering.SINGLE, Partitioning.UNIQUE
        )

    @staticmethod
    def kernel_level_ring(depth: int = _DEFAULT_RING_DEPTH,
                          block_bytes: int = 1 << 20) -> "TransferPolicy":
        """The recommended hot-path policy: interrupt-driven depth-N ring."""
        return TransferPolicy(Management.INTERRUPT, Buffering.RING,
                              Partitioning.BLOCKS, block_bytes=block_bytes,
                              ring_depth=depth)


@dataclass
class TransferStats:
    """Measured outcome of one logical transfer (possibly many chunks)."""

    nbytes: int
    wall_s: float
    n_chunks: int
    direction: str  # "tx" (host->device) or "rx" (device->host)
    policy_tag: str
    management: str = ""  # Management mode the transfer ran under

    @property
    def us_per_byte(self) -> float:
        return (self.wall_s * 1e6) / max(self.nbytes, 1)

    @property
    def gbps(self) -> float:
        return self.nbytes / max(self.wall_s, 1e-12) / 1e9

    def row(self) -> str:
        return (
            f"{self.policy_tag},{self.direction},{self.nbytes},"
            f"{self.wall_s * 1e3:.4f},{self.us_per_byte:.6f},{self.n_chunks}"
        )


def _payload_nbytes(payload: Any, direction: str) -> int:
    """Byte size of one chunk — the fair-queuing cost the runtime charges."""
    if direction == "tx":
        return int(np.asarray(payload).nbytes)
    return int(payload.size) * payload.dtype.itemsize


class Ticket:
    """Handle for an in-flight INTERRUPT-mode transfer.

    ``wait(timeout=)`` bounds the wait: past the deadline it escalates to
    the issuing engine's runtime-level timeout scan (``on_timeout``) —
    still-queued stale descriptors are cancelled with
    :class:`~repro.core.runtime.TransferTimeoutError`, which then surfaces
    here — and raises ``TransferTimeoutError`` itself if the descriptor is
    stuck in service (the one state a scan cannot unstick). A lost
    completion is an error the caller can retry, never a hang."""

    def __init__(self, done: threading.Event, out: list,
                 on_timeout: Callable[[float], None] | None = None):
        self._done = done
        self._out = out
        self._on_timeout = on_timeout

    def wait(self, timeout: float | None = None) -> Any:
        if not self._done.wait(timeout):
            if self._on_timeout is not None:
                try:
                    self._on_timeout(timeout)
                except Exception:
                    pass  # escalation is best-effort; we raise below anyway
            # the scan completes cancelled tickets synchronously; a short
            # grace covers a completion racing the deadline.
            if not self._done.wait(0.05):
                raise TransferTimeoutError(
                    f"ticket not complete after {timeout:.3f}s (descriptor "
                    "in service or completion dropped)")
        result = self._out[0]
        if isinstance(result, BaseException):
            raise result
        return result

    @property
    def complete(self) -> bool:
        return self._done.is_set()


class SGTicket:
    """Handle for one logical scatter-gather transfer: K segments riding ONE
    ring slot and ONE runtime descriptor, tracked per segment (the SG
    descriptor chain of SNIPPETS.md Snippet 1 — the ISSUE_RD/WAIT_CPL loop
    walks the segment list, one logical completion at the end).

    ``wait`` reassembles results in segment order and re-raises the first
    segment error; ``wait_each`` keeps faults isolated to their own segment —
    sibling segments still yield their results (the mid-segment fault
    isolation contract)."""

    __slots__ = ("tickets",)

    def __init__(self, tickets: Sequence[Ticket]):
        self.tickets = list(tickets)

    def __len__(self) -> int:
        return len(self.tickets)

    @property
    def complete(self) -> bool:
        return all(t.complete for t in self.tickets)

    def wait(self, timeout: float | None = None) -> list:
        """Ordered per-segment results (``timeout`` bounds each segment
        wait); the first failed segment re-raises here."""
        return [t.wait(timeout) for t in self.tickets]

    def wait_each(self, timeout: float | None = None) -> list:
        """Ordered per-segment results with faults ISOLATED: a failed
        segment contributes its exception object in place, siblings their
        results — nothing raises."""
        out: list = []
        for t in self.tickets:
            try:
                out.append(t.wait(timeout))
            except BaseException as e:  # noqa: BLE001 — isolation contract
                out.append(e)
        return out


class BufferInFlightError(RuntimeError):
    """Raised when a staging buffer is re-used before its transfer completed.

    This is the memory-protection role of the paper's kernel-level driver:
    user-level code could silently corrupt a physical buffer still owned by
    the DMA engine; the kernel driver forbids it. So do we."""


# ---------------------------------------------------------------------------
# Staged layouts: precomputed pack plans + reusable staging buffers
# ---------------------------------------------------------------------------

def reassemble_chunks(chunks: Sequence[jax.Array]) -> jax.Array:
    """Flatten a tx() chunk list back into one flat device array."""
    import jax.numpy as jnp

    if len(chunks) == 1:
        return chunks[0].reshape(-1)
    return jnp.concatenate([c.reshape(-1) for c in chunks])


def _bitcast_from_bytes(seg: jax.Array, shape: tuple, dtype: np.dtype) -> jax.Array:
    """Reinterpret a flat uint8 device segment as ``dtype`` with ``shape``."""
    import jax.numpy as jnp

    if dtype == np.uint8:
        return seg.reshape(shape)
    if dtype == np.bool_:
        # packed bools are 0/1 bytes; bitcast to bool isn't supported
        return (seg != 0).reshape(shape)
    if dtype.itemsize == 1:
        return jax.lax.bitcast_convert_type(seg, dtype).reshape(shape)
    return jax.lax.bitcast_convert_type(
        seg.reshape(shape + (dtype.itemsize,)), jnp.dtype(dtype))


class StagedLayout:
    """Precomputed pack/unpack plan for a fixed list of host arrays.

    Computes (offset, shape, dtype, nbytes) per array once and preallocates a
    single pinned-style uint8 staging buffer. ``pack`` copies each array into
    its slot (skipping the copy entirely when the same array objects were
    packed last time and ``force=False``); ``unpack`` slices/bitcasts device
    chunks back into per-array device views using the cached offsets. Neither
    allocates host memory after construction.
    """

    __slots__ = ("specs", "nbytes", "_staging", "_payload", "_busy",
                 "_last_arrays", "pack_count", "copy_count", "_pool")

    def __init__(self, arrays: Sequence[np.ndarray], *,
                 pool: "Any | None" = None):
        specs = []
        off = 0
        for a in arrays:
            a = np.asarray(a)
            specs.append((off, a.shape, np.dtype(a.dtype), a.nbytes))
            off += a.nbytes
        self.specs: tuple = tuple(specs)
        self.nbytes = off
        # ``pool`` (e.g. repro.core.channels.StagingPool) recycles staging
        # buffers across layouts, so a shape change (layout eviction) does
        # not cost a fresh allocation on the next frame.
        self._pool = pool
        if pool is not None:
            self._staging = pool.acquire(max(off, 1))
        else:
            self._staging = np.empty(max(off, 1), np.uint8)
        self._payload = self._staging[:off]  # stable view, identity-checkable
        self._busy: threading.Event | None = None  # set by engine on async tx
        # strong refs to the arrays staged last: identity comparison against
        # live objects is sound, whereas remembering bare id()s is not (a
        # freed array's id can be reused by a new allocation)
        self._last_arrays: tuple | None = None
        self.pack_count = 0
        self.copy_count = 0

    @property
    def staging(self) -> np.ndarray:
        return self._payload

    def matches(self, arrays: Sequence[np.ndarray]) -> bool:
        if len(arrays) != len(self.specs):
            return False
        return all(
            np.asarray(a).shape == shape and np.dtype(np.asarray(a).dtype) == dtype
            for a, (_, shape, dtype, _) in zip(arrays, self.specs)
        )

    def _check_not_busy(self, wait: bool) -> None:
        busy = self._busy
        if busy is not None and not busy.is_set():
            if wait:
                busy.wait()
            else:
                raise BufferInFlightError(
                    "StagedLayout staging buffer re-packed while its transfer "
                    "is in flight; wait for the ticket or pass wait=True"
                )

    def pack(self, arrays: Sequence[np.ndarray], *, wait: bool = True,
             force: bool = False) -> np.ndarray:
        """Copy ``arrays`` into the staging buffer; returns the SAME ndarray
        view object every call. When the identical array objects were packed
        last time, the memcpy is skipped (callers mutating arrays in place
        must pass ``force=True``)."""
        if not self.matches(arrays):
            raise ValueError("array shapes/dtypes do not match this layout")
        self._check_not_busy(wait)
        self.pack_count += 1
        unchanged = (
            not force
            and self._last_arrays is not None
            and len(arrays) == len(self._last_arrays)
            and all(a is b for a, b in zip(arrays, self._last_arrays))
        )
        if not unchanged:
            for (off, shape, dtype, nb), a in zip(self.specs, arrays):
                if nb == 0:
                    continue
                dst = self._staging[off:off + nb].view(dtype)
                np.copyto(dst, np.asarray(a).reshape(-1))
            self._last_arrays = tuple(arrays)
            self.copy_count += 1
        return self._payload

    def unpack(self, chunks: Sequence[jax.Array]) -> list[jax.Array]:
        """Slice device chunk(s) of a packed payload back into per-array
        device views, using the cached offsets (no host round-trip)."""
        flat = reassemble_chunks(chunks)
        return [
            _bitcast_from_bytes(flat[off:off + nb], shape, dtype)
            for off, shape, dtype, nb in self.specs
        ]

    def seg_sizes(self) -> list[int]:
        """Per-array byte sizes — the segment list the pack-vs-SG decision
        prices."""
        return [nb for _off, _shape, _dtype, nb in self.specs]

    def sg_segments(self, arrays: Sequence[np.ndarray]) -> list[tuple]:
        """The whole-array SG segment list for this layer set: the
        zero-copy alternative to :meth:`pack` (no staging buffer touched,
        no busy window — each array IS its own descriptor segment)."""
        if not self.matches(arrays):
            raise ValueError("array shapes/dtypes do not match this layout")
        return [(np.asarray(a), 0, nb)
                for a, (_off, _shape, _dtype, nb) in zip(arrays, self.specs)]

    def prefer_sg(self, model: Any, *, seg_t0_s: float | None = None,
                  copy_bw_Bps: float | None = None) -> bool:
        """Pack-vs-SG decision for this layer set, priced by a fitted
        :class:`~repro.core.cost_model.TransferCostModel` (see
        :func:`choose_sg`)."""
        return choose_sg(self.seg_sizes(), model, seg_t0_s=seg_t0_s,
                         copy_bw_Bps=copy_bw_Bps)

    def release(self) -> None:
        """Return the staging buffer to the pool; the layout is dead after.

        A buffer whose transfer is still in flight is orphaned instead of
        pooled (handing it to a new layout mid-DMA is the corruption the
        kernel driver exists to prevent)."""
        if self._pool is None or self._staging is None:
            return
        busy = self._busy
        if busy is None or busy.is_set():
            self._pool.release(self._staging)
        self._staging = None
        self._payload = None


class LayoutCache:
    """Per-engine cache of :class:`StagedLayout` keyed by caller identity
    (layer name/index). A hit returns the SAME layout object — and therefore
    the same preallocated staging buffer — frame after frame. An optional
    staging ``pool`` is threaded into every layout so evicted layouts recycle
    their buffers instead of leaking the allocation."""

    def __init__(self, pool: Any | None = None) -> None:
        self._lock = make_lock("LayoutCache._lock")  # serving/pipeline hit one
        self._layouts: dict[Any, StagedLayout] = {}  # guarded-by: _lock
        self._pool = pool
        self.hits = 0                  # guarded-by: _lock
        self.misses = 0                # guarded-by: _lock
        # per-layer-set pack-vs-SG memo: one decision per key per refit
        # generation (invalidate_sg() clears on controller replans), so the
        # hot path never re-prices a layer set it already decided.
        self._sg_choice: dict[Any, bool] = {}  # guarded-by: _lock

    def get(self, key: Any, arrays: Sequence[np.ndarray]) -> StagedLayout:
        with self._lock:
            lay = self._layouts.get(key)
            if lay is not None and lay.matches(arrays):
                self.hits += 1
                return lay
            if lay is not None:
                lay.release()  # stale shapes: recycle the old staging buffer
                self._sg_choice.pop(key, None)  # shapes changed: re-decide
            lay = StagedLayout(arrays, pool=self._pool)
            self._layouts[key] = lay
            self.misses += 1
            return lay

    def decide_sg(self, key: Any, layout: StagedLayout,
                  decide: Callable[[list[int]], bool]) -> bool:
        """Per-layer-set pack-vs-SG decision, memoized per key.

        ``layout`` is the key's resolved :class:`StagedLayout` (the caller
        already holds it from :meth:`get` — no second lookup, no hit-count
        skew). ``decide(seg_sizes)`` (typically ``engine.prefer_sg``) runs
        once per key/shape/refit generation; repeat frames hit the memo. A
        shape change on the key or :meth:`invalidate_sg` re-prices."""
        with self._lock:
            hit = self._sg_choice.get(key)
            if hit is not None:
                return hit
        choice = bool(decide(layout.seg_sizes()))
        with self._lock:
            self._sg_choice[key] = choice
        return choice

    def invalidate_sg(self) -> None:
        """Drop every memoized pack-vs-SG decision (the online controller
        calls this after a refit moved the crossover)."""
        with self._lock:
            self._sg_choice.clear()

    def __len__(self) -> int:
        with self._lock:
            return len(self._layouts)


def _check_out(arrays: Sequence[Any],
               out: Sequence[np.ndarray] | None) -> list:
    """Validate caller-owned RX destination buffers against device arrays.

    Each buffer must be writable, C-contiguous, and byte-size-matched to
    its array; dtype may differ (the copy is a byte-level landing, the
    caller keeps whatever view it allocated). Contiguity is load-bearing:
    ``reshape(-1)`` on a non-contiguous buffer would return a COPY and the
    transfer would silently land in a temporary instead of the caller's
    memory."""
    if out is None:
        return [None] * len(arrays)
    outs = list(out)
    if len(outs) != len(arrays):
        raise ValueError(
            f"out= needs one buffer per device array "
            f"(got {len(outs)} buffers for {len(arrays)} arrays)")
    for i, (a, o) in enumerate(zip(arrays, outs)):
        need = int(a.size) * a.dtype.itemsize
        o = np.asarray(o)
        if not o.flags.writeable:
            raise ValueError(f"out[{i}] is not writable")
        if not o.flags.c_contiguous:
            raise ValueError(
                f"out[{i}] is not C-contiguous; the RX landing would copy "
                f"into a temporary instead of the caller's buffer")
        if o.nbytes != need:
            raise ValueError(
                f"out[{i}] holds {o.nbytes} bytes but the device array "
                f"needs {need}")
        outs[i] = o
    return outs


def carve_flat_out(out: np.ndarray, arrays: Sequence[Any]) -> list[np.ndarray]:
    """Carve ONE caller-owned flat buffer into per-array byte-range views
    (zero-copy), in array order — the striped-RX landing zone."""
    total = sum(int(a.size) * a.dtype.itemsize for a in arrays)
    if not out.flags.writeable:
        raise ValueError("out= flat buffer is not writable")
    if not out.flags.c_contiguous:
        raise ValueError("out= flat buffer must be C-contiguous")
    if out.nbytes != total:
        raise ValueError(
            f"out= holds {out.nbytes} bytes but the payload needs {total}")
    flat = out.reshape(-1).view(np.uint8)
    views, off = [], 0
    for a in arrays:
        nb = int(a.size) * a.dtype.itemsize
        views.append(flat[off:off + nb])
        off += nb
    return views


# ---------------------------------------------------------------------------
# Scatter-gather segments: zero-copy descriptor lists instead of staging packs
# ---------------------------------------------------------------------------

def _sg_segment_views(segments: Sequence[Any],
                      direction: str) -> tuple[list, list[int]]:
    """Normalize SG ``(array, offset, nbytes)`` segments to zero-copy views.

    A bare array is shorthand for a whole-array segment. Whole-array
    segments keep their shape/dtype (TX lands them as shaped device
    arrays — no unpack bitcast needed); partial segments must be
    itemsize-aligned and become flat element-range views. Nothing is
    staged or copied here — eliminating that memcpy is the point of the
    SG form."""
    views: list = []
    sizes: list[int] = []
    for i, seg in enumerate(segments):
        if isinstance(seg, (tuple, list)) and len(seg) == 3:
            a, off, nb = seg
        else:
            a, off, nb = seg, 0, None
        if direction == "tx":
            a = np.asarray(a)
        total = int(a.size) * a.dtype.itemsize
        off = int(off)
        nb = total - off if nb is None else int(nb)
        if off < 0 or nb < 0 or off + nb > total:
            raise ValueError(
                f"SG segment {i}: byte range [{off}, {off + nb}) outside "
                f"the {total}-byte array")
        if off == 0 and nb == total:
            views.append(a)
        else:
            item = a.dtype.itemsize
            if off % item or nb % item:
                raise ValueError(
                    f"SG segment {i}: partial range ({off}, {nb}) not "
                    f"aligned to the {item}-byte array itemsize")
            if direction == "tx" and not a.flags.c_contiguous:
                raise ValueError(
                    f"SG segment {i}: partial TX range of a non-contiguous "
                    f"array would copy into a temporary — the staging "
                    f"memcpy SG exists to avoid")
            views.append(a.reshape(-1)[off // item:(off + nb) // item])
        sizes.append(nb)
    return views, sizes


_copy_bw_lock = make_lock("transfer._copy_bw_lock")
_copy_bw_Bps: float | None = None  # guarded-by: _copy_bw_lock


def host_copy_bw_Bps() -> float:
    """Measured host staging-memcpy bandwidth (bytes/s), cached per process.

    This is the per-byte price of ``StagedLayout.pack`` that the SG form
    refuses to pay; the pack-vs-SG decision charges the pack side with it.
    Measured once (best of 3 over an 8 MiB copy), not assumed."""
    global _copy_bw_Bps
    with _copy_bw_lock:
        if _copy_bw_Bps is not None:
            return _copy_bw_Bps
        src = np.ones(8 << 20, np.uint8)
        dst = np.empty_like(src)
        np.copyto(dst, src)  # warm: page the buffers in before timing
        best = float("inf")
        for _ in range(3):
            t0 = time.perf_counter()
            np.copyto(dst, src)
            best = min(best, time.perf_counter() - t0)
        _copy_bw_Bps = src.nbytes / max(best, 1e-9)
        return _copy_bw_Bps


def choose_sg(sizes: Sequence[int], model: Any, *,
              seg_t0_s: float | None = None,
              copy_bw_Bps: float | None = None) -> bool:
    """Pack-vs-SG decision for one segment-size list, priced by a fitted
    ``t(n) = t0 + n/BW`` cost model (duck-typed: anything with ``t0_s`` /
    ``bw_Bps``).

    - pack: one descriptor over the packed total, PLUS the staging memcpy
      — ``t0 + total/BW + total/copy_BW``.
    - SG:   one ring transaction walking K segment descriptors, zero copy
      — ``t0 + K*seg_t0 + total/BW`` (``seg_t0`` is the per-segment walk
      cost; defaults to the full ``t0`` until a live refit shrinks it).

    Link and base management terms cancel, so SG wins exactly when
    ``K * seg_t0 < total / copy_BW``: few large arrays -> SG (the memcpy
    dominates), many small arrays -> pack (the segment walk dominates)."""
    k = len(sizes)
    if k == 0:
        return False
    total = int(sum(sizes))
    seg_t0 = float(model.t0_s) if seg_t0_s is None else float(seg_t0_s)
    copy_bw = host_copy_bw_Bps() if copy_bw_Bps is None else float(copy_bw_Bps)
    return k * max(seg_t0, 1e-9) < total / max(copy_bw, 1.0)


def sg_crossover_segments(total_bytes: int, model: Any, *,
                          seg_t0_s: float | None = None,
                          copy_bw_Bps: float | None = None) -> float:
    """Segment count at which pack starts beating SG for a fixed total
    payload (the recorded crossover point): ``K* = total/(copy_BW*seg_t0)``."""
    seg_t0 = float(model.t0_s) if seg_t0_s is None else float(seg_t0_s)
    copy_bw = host_copy_bw_Bps() if copy_bw_Bps is None else float(copy_bw_Bps)
    return int(total_bytes) / (max(copy_bw, 1.0) * max(seg_t0, 1e-9))


def _split(arr: np.ndarray, policy: TransferPolicy) -> list[np.ndarray]:
    """Partition a flat view of ``arr`` according to the policy."""
    flat = arr.reshape(-1)
    if policy.partitioning is Partitioning.UNIQUE or flat.nbytes <= policy.block_bytes:
        return [flat]
    per_chunk = max(1, policy.block_bytes // max(flat.itemsize, 1))
    n = math.ceil(flat.size / per_chunk)
    return [flat[i * per_chunk : (i + 1) * per_chunk] for i in range(n)]


def _preempt_segments(flat: np.ndarray, seg_bytes: int) -> list[np.ndarray]:
    """Sub-slice one TX chunk into preemption segments (flat views)."""
    per = max(1, seg_bytes // max(flat.itemsize, 1))
    n = math.ceil(flat.size / per)
    return [flat[i * per: (i + 1) * per] for i in range(n)]


def _flatten_chunk_results(results: list) -> list:
    """Splice preemptible groups' per-segment device arrays back into a
    flat chunk list (segments are contiguous sub-slices in order, so the
    flattened list reassembles exactly like the unsplit chunks)."""
    out: list = []
    for r in results:
        if type(r) is list:
            out.extend(r)
        else:
            out.append(r)
    return out


class TransferEngine:
    """Executes host->device (TX) and device->host (RX) transfers under a
    :class:`TransferPolicy`, recording measured :class:`TransferStats`.

    The engine owns the descriptor ring (the paper's staging buffers in the
    *physical* space, generalised to depth N) and a :class:`LayoutCache` of
    reusable staging layouts. Under INTERRUPT management, completion
    dispatch rides the process-shared
    :class:`~repro.core.runtime.TransferRuntime` (pass ``runtime=`` for a
    private one): the engine registers with a ``priority`` class and the
    runtime arbitrates its completions against every other stream's. It
    enforces completion ordering: a ring slot is only re-acquired once its
    descriptor completed."""

    def __init__(self, policy: TransferPolicy, device: jax.Device | None = None,
                 scheduler: "CooperativeScheduler | None" = None,
                 runtime: TransferRuntime | None = None,
                 priority: PriorityClass = PriorityClass.LAYER,
                 qos: QosSpec | None = None):
        self.policy = policy
        self.device = device or jax.devices()[0]
        # the engine's default submit context: every tx/rx inherits it, a
        # per-call qos= overrides only the fields it sets. ``priority``
        # stays as the class shorthand (not deprecated at construction —
        # only per-call priority= kwargs are).
        self.qos = QosSpec(priority=priority).merged(qos)
        self.priority = self.qos.priority
        # bounded: one record per logical transfer (per decoded token on
        # the serving path) — unbounded history would leak in a
        # long-running server; aggregates live in the *_total counters.
        self.stats: "collections.deque[TransferStats]" = collections.deque(
            maxlen=_STATS_WINDOW)        # guarded-by: _stats_lock
        self.layouts = LayoutCache()
        # descriptor ring: one completion event per staging slot
        self._ring_lock = make_lock("TransferEngine._ring_lock")
        self._buffers_busy: list[threading.Event | None] = \
            [None] * policy.depth         # guarded-by: _ring_lock
        self._buf_idx = 0                 # guarded-by: _ring_lock
        self._slot_held = [False] * policy.depth  # guarded-by: _ring_lock
        self._inflight = 0                # guarded-by: _ring_lock
        # two concurrent holders of one slot (bug)
        self.slot_collisions = 0          # guarded-by: _ring_lock
        # high-water mark of concurrent descriptors
        self.max_inflight = 0             # guarded-by: _ring_lock
        # high-water mark of concurrently HELD slots
        self.inflight_hwm = 0             # guarded-by: _ring_lock
        self._stats_lock = make_lock("TransferEngine._stats_lock")
        # aggregate byte/transfer counters, mutated ONLY under _stats_lock —
        # the async completion path records from worker threads, so an
        # unlocked read-modify-write here silently drops bytes under load.
        self.tx_bytes_total = 0           # guarded-by: _stats_lock
        self.rx_bytes_total = 0           # guarded-by: _stats_lock
        self.tx_count = 0                 # guarded-by: _stats_lock
        self.rx_count = 0                 # guarded-by: _stats_lock
        self._observers: list[Callable[[TransferStats], None]] = \
            []                            # guarded-by: _stats_lock
        # bounded deque: append/popleft are GIL-atomic, so samplers (workers)
        # and the refit consumer need no extra lock here.
        self.chunk_samples: "collections.deque[tuple[str, str, int, float]]" \
            = collections.deque(maxlen=_CHUNK_SAMPLE_WINDOW)
        # grouped-transaction samples (direction, n_segments, total_bytes,
        # wall_s) from _submit_many — same GIL-atomic deque discipline; the
        # pack-vs-SG crossover refits the per-segment walk cost from these.
        self.sg_samples: "collections.deque[tuple[str, int, int, float]]" \
            = collections.deque(maxlen=_SG_SAMPLE_WINDOW)
        # monotone count of chunk samples ever taken: per-channel health
        # monitors PEEK the newest (chunk_seq - last_seen) entries instead
        # of popping, so they can coexist with the destructive
        # ingest_chunks() refit consumer.
        self.chunk_seq = 0                # guarded-by: _stats_lock
        # fault-layer ledger (exact lifetime totals)
        self.checksum_failures = 0        # guarded-by: _stats_lock
        self.chunks_cancelled = 0         # guarded-by: _stats_lock
        self._runtime = runtime
        # concurrent first-submit must not double-register (leak)
        self._handle_lock = make_lock("TransferEngine._handle_lock")
        self._handle: RuntimeHandle | None = None  # guarded-by: _handle_lock
        self._closed = False              # guarded-by: _handle_lock
        if scheduler is None and policy.management is Management.SCHEDULED:
            scheduler = CooperativeScheduler()
        self._scheduler = scheduler

    def _resolve_qos(self, where: str, qos: QosSpec | None,
                     priority: PriorityClass | None) -> QosSpec:
        """One submit call's effective context: per-call qos > engine
        default. A legacy ``priority=`` kwarg folds in through the
        deprecation shim (:func:`repro.core.qos.resolve_submit_qos`)."""
        spec = resolve_submit_qos(f"{type(self).__name__}.{where}",
                                  qos, priority)
        return self.qos.merged(spec)

    # -- runtime registration (lazy so POLLING engines never touch it) ------
    def _runtime_handle(self) -> RuntimeHandle:
        if self._closed:  # lock-ok: racy fast-fail; re-checked under lock below
            raise RuntimeError("submit on a closed TransferEngine")
        h = self._handle  # lock-ok: double-checked init; re-read under lock
        if h is None:
            with self._handle_lock:
                if self._closed:
                    raise RuntimeError("submit on a closed TransferEngine")
                h = self._handle
                if h is None:
                    if self._runtime is None:
                        self._runtime = get_runtime()
                    h = self._handle = self._runtime.register(
                        self, self.priority,
                        workers_hint=self.policy.completion_workers)
        return h

    @property
    def runtime(self) -> TransferRuntime | None:
        """The runtime this engine's completions dispatch on (resolved for
        INTERRUPT engines; ``None`` for polling/scheduled engines that were
        not handed one explicitly)."""
        if (self._runtime is None
                and not self._closed  # lock-ok: advisory read, benign race
                and self.policy.management is Management.INTERRUPT):
            self._runtime = get_runtime()
        return self._runtime

    def close(self, timeout: float = 5.0) -> None:
        """Drain this engine's in-flight descriptors (bounded by
        ``timeout`` — stragglers are cancelled, never waited on forever)
        and deregister from the shared runtime, so a late completion can
        never fire into a dead engine. Idempotent; the engine rejects
        submissions after."""
        with self._handle_lock:
            if self._closed:
                return
            self._closed = True
            h, self._handle = self._handle, None
        if h is not None:
            h.close(timeout)

    def _escalate_timeout(self, waited_s: float | None) -> None:
        """Ticket.wait(timeout=) deadline blew: run the runtime-level
        timeout scan so a dropped completion resolves every ticket staged
        behind it (TransferTimeoutError, not a hang)."""
        rt = self._runtime
        if rt is not None and waited_s is not None:
            rt.scan_timeouts(max(float(waited_s), 1e-3))

    def maybe_adapt(self, *, force: bool = False) -> bool:
        """Engine-surface hook for safe-point adaptation. A plain engine
        has no online controller — executors call this unconditionally at
        frame/batch/request boundaries; repro.core.adaptive overrides it."""
        return False

    def set_class_cap(self, cls: PriorityClass,
                      bytes_per_s: float | None) -> None:
        """Enforce (or clear, with None) a bytes/s ceiling for ``cls`` on
        the runtime this engine dispatches on — the engine-surface spelling
        of :meth:`~repro.core.runtime.TransferRuntime.set_class_cap`
        (ChannelGroup / AdaptiveChannelGroup duck-type it)."""
        rt = self.runtime
        if rt is None:
            raise RuntimeError(
                "set_class_cap needs an INTERRUPT-managed engine (polling/"
                "scheduled engines have no shared runtime to enforce caps)")
        rt.set_class_cap(cls, bytes_per_s)

    def __enter__(self) -> "TransferEngine":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # -- staging-ring safety (kernel-driver protection semantics) ----------
    def _acquire_buffer(self) -> tuple[int, threading.Event]:
        """Reserve the next descriptor-ring slot; returns ``(idx, release)``.

        The caller owns the slot until it fires ``release`` (via
        :meth:`_release_buffer`). Reservation installs a fresh completion
        event under the ring lock *before* waiting on the previous holder, so
        concurrent acquirers of the same slot chain FIFO on each other's
        events instead of racing ``_buf_idx`` / colliding on a slot.
        """
        with self._ring_lock:
            idx = self._buf_idx % len(self._buffers_busy)
            prev = self._buffers_busy[idx]
            if (prev is not None and not prev.is_set()
                    and self.policy.management is not Management.INTERRUPT):
                raise BufferInFlightError(
                    f"staging slot {idx} reused before completion "
                    f"(policy={self.policy.tag}); use INTERRUPT management or "
                    f"a deeper ring"
                )
            release = threading.Event()
            self._buffers_busy[idx] = release
            self._buf_idx += 1
        if prev is not None:
            prev.wait()  # kernel driver: safe, waits for completion
        with self._ring_lock:
            if self._slot_held[idx]:
                self.slot_collisions += 1
            self._slot_held[idx] = True
            self._inflight += 1
            self.inflight_hwm = max(self.inflight_hwm, self._inflight)
            self.max_inflight = max(self.max_inflight, self._inflight)
        return idx, release

    def _release_buffer(self, idx: int, release: threading.Event) -> None:
        """Free a ring slot; wakes the next acquirer chained on ``release``."""
        with self._ring_lock:
            self._slot_held[idx] = False
            self._inflight -= 1
        release.set()

    def add_observer(self, fn: Callable[[TransferStats], None]) -> None:
        """Subscribe to every recorded stat (the online-refit feed). The
        observer runs on whichever thread completes the transfer; it must be
        cheap and must not issue transfers on this engine."""
        with self._stats_lock:
            self._observers.append(fn)

    def _record(self, stats: TransferStats) -> None:
        if not stats.management:
            stats.management = self.policy.management.value
        with self._stats_lock:
            self.stats.append(stats)
            if stats.direction == "tx":
                self.tx_bytes_total += stats.nbytes
                self.tx_count += 1
            else:
                self.rx_bytes_total += stats.nbytes
                self.rx_count += 1
            observers = list(self._observers)
        for fn in observers:
            fn(stats)

    # -- TX: host -> device -------------------------------------------------
    def tx(self, host_array: np.ndarray,
           priority: PriorityClass | None = None, *,
           qos: QosSpec | None = None) -> list[jax.Array]:
        """Transfer ``host_array`` to the device; returns device chunk list.
        ``qos`` overrides the engine's submit context for this transfer
        (``priority=`` is the deprecated spelling of ``qos.priority``)."""
        spec = self._resolve_qos("tx", qos, priority)
        chunks = _split(np.asarray(host_array), self.policy)
        t0 = time.perf_counter()
        out = self._run_chunks(
            [(c, "tx", None) for c in chunks], spec,
        )
        wall = time.perf_counter() - t0
        self._record(
            TransferStats(host_array.nbytes, wall, len(chunks), "tx", self.policy.tag)
        )
        return out

    # -- RX: device -> host -------------------------------------------------
    def rx(self, device_arrays: Sequence[jax.Array],
           out: Sequence[np.ndarray] | None = None,
           priority: PriorityClass | None = None, *,
           qos: QosSpec | None = None) -> list[np.ndarray]:
        """Transfer device arrays back to host memory.

        ``out``: optional caller-owned destination buffers, one per device
        array (matching byte sizes). When given, results are written IN
        PLACE and the returned list contains the caller's own buffer
        objects — the zero-copy detokenize path."""
        spec = self._resolve_qos("rx", qos, priority)
        arrays = list(device_arrays)
        outs = _check_out(arrays, out)
        nbytes = sum(int(a.size) * a.dtype.itemsize for a in arrays)
        t0 = time.perf_counter()
        result = self._run_chunks(
            [(a, "rx", o) for a, o in zip(arrays, outs)], spec)
        wall = time.perf_counter() - t0
        self._record(
            TransferStats(nbytes, wall, len(arrays), "rx", self.policy.tag)
        )
        return result

    def _preempt_segments_for(self, payload, direction: str,
                              cls: PriorityClass) -> list[np.ndarray] | None:
        """Sub-slices for preemptive chunked dispatch, or None to submit
        the chunk whole. TX only (an RX payload is one device array — the
        host cannot sub-slice the device_get), and only for throughput
        classes: a TOKEN/SENSOR descriptor is the traffic preemption
        protects, not the traffic it splits."""
        n = self.policy.preempt_chunk_bytes
        if n <= 0 or direction != "tx" or cls not in PREEMPTIBLE_CLASSES:
            return None
        flat = payload
        if int(flat.nbytes) <= n:
            return None
        return _preempt_segments(flat, n)

    # -- chunk executor under the three managements -------------------------
    def _one(self, payload, direction: str, out: np.ndarray | None = None):
        """Move ONE chunk (subclasses override to inject synthetic timing)."""
        if direction == "tx":
            r = jax.device_put(payload, self.device)
            r.block_until_ready()
            return r
        host = np.asarray(jax.device_get(payload))
        if out is None:
            return host
        # zero-copy RX: land the bytes in the CALLER's buffer; the only
        # steady-state work is the one unavoidable device->host copy. On
        # the CPU backend ``device_get`` returns a VIEW of the device
        # buffer (verified: shares memory, tracemalloc-silent), so this is
        # exactly one memcpy and zero allocations; on an accelerator
        # backend device_get itself is the DMA and the copyto is the
        # host-side landing (a dlpack/pinned-buffer path could fuse them).
        np.copyto(out.reshape(-1).view(np.uint8),
                  host.reshape(-1).view(np.uint8))
        return out

    @staticmethod
    def _crc32(arr: np.ndarray) -> int:
        return zlib.crc32(
            np.ascontiguousarray(arr).reshape(-1).view(np.uint8))

    def _one_timed(self, payload, direction: str,
                   out: np.ndarray | None = None):
        """_one plus a (direction, mode, nbytes, seconds) chunk sample —
        the per-descriptor timings the online refit fits t0/BW from.
        With ``policy.checksum`` the RX landing is crc32-verified against
        the device buffer (outside the timed region: integrity work must
        not pollute the bandwidth fit)."""
        if direction == "tx":
            nbytes = int(np.asarray(payload).nbytes)
        else:
            nbytes = int(payload.size) * payload.dtype.itemsize
        verify = direction == "rx" and self.policy.checksum
        if verify:
            # on real HW this crc is TX-side descriptor metadata; here the
            # reference is the device buffer just before the landing copy.
            expect = self._crc32(np.asarray(jax.device_get(payload)))
        t0 = time.perf_counter()
        r = self._one(payload, direction, out)
        dt = time.perf_counter() - t0
        self.chunk_samples.append(
            (direction, self.policy.management.value, nbytes, dt))
        with self._stats_lock:
            self.chunk_seq += 1
        if verify and self._crc32(np.asarray(r)) != expect:
            with self._stats_lock:
                self.checksum_failures += 1
            rt = self._runtime
            if rt is not None:
                rt.note_fault(self.priority, faults=1)
            raise TransferChecksumError(
                f"rx descriptor failed crc32 verification ({nbytes} B); "
                "payload corrupted in flight")
        return r

    def _run_chunks(self, items: list[tuple[Any, str, Any]],
                    qos: QosSpec) -> list:
        mgmt = self.policy.management
        if mgmt is Management.POLLING:
            # user-level polling: issue, then spin until ready, per chunk.
            results = []
            for payload, direction, dst in items:
                idx, release = self._acquire_buffer()
                try:
                    r = self._one_timed(payload, direction, dst)
                finally:
                    self._release_buffer(idx, release)
                results.append(r)
            return results

        if mgmt is Management.SCHEDULED:
            # cooperative scheduler: each chunk is a task; the scheduler may
            # interleave other registered work between chunks.
            results: list = [None] * len(items)

            def make_task(i, payload, direction, dst):
                def task():
                    idx, release = self._acquire_buffer()
                    try:
                        results[i] = self._one_timed(payload, direction, dst)
                    finally:
                        self._release_buffer(idx, release)

                return task

            for i, (payload, direction, dst) in enumerate(items):
                self._scheduler.submit(make_task(i, payload, direction, dst))
            self._scheduler.drain()
            return results

        # INTERRUPT: stage chunks onto the descriptor ring. Up to ``depth``
        # descriptors are in flight at once; chunk k+depth can only be staged
        # after chunk k's completion fires (ring reuse rule). Slot release
        # happens on the runtime's completion worker, so acquisition (which
        # may chain on a prior holder) never waits on work that cannot
        # progress. LAYER/BULK TX chunks above ``preempt_chunk_bytes`` go in
        # as resumable segment iterators (one ring slot, many yield points),
        # so the runtime can park them mid-chunk for TOKEN/SENSOR arrivals;
        # their per-segment device arrays are spliced back into the chunk
        # list below (contiguous order — reassembly is unchanged).
        handle = self._runtime_handle()
        depth = self.policy.depth
        cls = qos.priority or self.priority
        wait_s = (qos.timeout_s if qos.timeout_s is not None
                  else self.policy.descriptor_timeout_s)
        tickets: list[Ticket | None] = [None] * len(items)
        results: list = [None] * len(items)
        inflight: list[int] = []
        first_err: BaseException | None = None
        for i, (payload, direction, dst) in enumerate(items):
            while len(inflight) >= depth and first_err is None:
                j = inflight.pop(0)
                try:
                    results[j] = tickets[j].wait(wait_s)
                except BaseException as e:
                    # do NOT leave with own chunks still in service: stop
                    # submitting, drain the rest below, then raise.
                    first_err = e
            if first_err is not None:
                break
            idx, release = self._acquire_buffer()

            segs = self._preempt_segments_for(payload, direction, cls)
            if segs is not None:
                submit_obj: Any = PreemptibleWork(
                    [(lambda s=s: self._one_timed(s, "tx")) for s in segs],
                    collect=list,
                    finalize=lambda err, idx=idx, release=release:
                        self._release_buffer(idx, release))
            else:
                def work(p=payload, d=direction, o=dst, idx=idx,
                         release=release):
                    try:
                        return self._one_timed(p, d, o)
                    finally:
                        self._release_buffer(idx, release)
                submit_obj = work

            # on_cancel: a descriptor cancelled while queued (runtime
            # teardown) never runs ``work`` — its ring slot must still be
            # freed or every later acquirer of that slot deadlocks. A
            # submit() that RAISES (engine/runtime closed concurrently)
            # leaks the same slot; release it before surfacing.
            try:
                done, out = handle.submit(
                    submit_obj, nbytes=_payload_nbytes(payload, direction),
                    qos=qos,
                    on_cancel=lambda err, idx=idx, release=release:
                        self._release_buffer(idx, release))
            except BaseException as e:
                self._release_buffer(idx, release)
                first_err = e  # drain already-submitted chunks, then raise
                break
            tickets[i] = Ticket(done, out, on_timeout=self._escalate_timeout)
            inflight.append(i)
            with self._ring_lock:
                # under the ring lock: racing _acquire_buffer also updates
                # this high-water mark, and lost updates hide depth bugs.
                self.max_inflight = max(self.max_inflight, len(inflight))
        for j in inflight:
            try:
                results[j] = tickets[j].wait(wait_s)
            except BaseException as e:
                if first_err is None:
                    first_err = e
        if first_err is not None:
            raise first_err
        return _flatten_chunk_results(results)

    # -- async API (INTERRUPT only): returns a ticket, caller is "interrupted"
    def _submit_async(self, payloads: list, direction: str, nbytes: int,
                      callback: Callable[[list], None] | None,
                      layout: StagedLayout | None,
                      outs: Sequence[np.ndarray | None] | None = None,
                      qos: QosSpec | None = None) -> Ticket:
        """Stage ``payloads`` as ring descriptors, one per chunk.

        Ring slots are acquired on the *caller* thread, so a full ring
        back-pressures the submitter (the AXI-DMA enqueue semantics) and the
        in-flight descriptor count stays <= ``policy.depth`` even across
        concurrent async callers — the completion workers themselves never
        wait on a slot, so slot hand-off always makes progress. The ticket's
        master event fires after the LAST chunk completes; any chunk error is
        re-raised from ``Ticket.wait``.

        ``callback`` runs ON a shared runtime worker. Like an IRQ handler,
        it must not issue transfers (acquisition can block the worker on a
        slot only this runtime can release — self-deadlock); hand follow-up
        transfers to another thread via the ticket instead."""
        handle = self._runtime_handle()
        master = threading.Event()
        ticket_out: list = []
        results: list = [None] * len(payloads)
        # t0 is stamped when the FIRST chunk starts executing on a worker,
        # so recorded TransferStats measure the transfer itself — not the
        # caller's ring back-pressure or queue wait (keeps us/byte
        # comparable with the synchronous paths across PRs).
        state = {"remaining": len(payloads), "error": None, "t0": None}
        state_lock = threading.Lock()
        # first chunk error aborts the chain: chunks still queued behind it
        # short-circuit on dispatch (counted in ``chunks_cancelled``)
        # instead of moving bytes for a transfer that already failed.
        aborted = threading.Event()

        # Mark the staging buffer busy BEFORE any descriptor is submitted: a
        # re-pack racing this call could otherwise slip between submit() and
        # the flag assignment and corrupt the in-flight payload.
        if layout is not None:
            layout._busy = master

        if not payloads:
            ticket_out.append(results)
            master.set()
            return Ticket(master, ticket_out)

        def finish_one(err: BaseException | None) -> None:
            if err is not None:
                aborted.set()
            with state_lock:
                if err is not None and state["error"] is None:
                    state["error"] = err
                state["remaining"] -= 1
                last = state["remaining"] == 0
            if not last:
                return
            first_err = state["error"]
            if first_err is not None:
                ticket_out.append(first_err)
            else:
                wall = time.perf_counter() - (state["t0"]
                                              or time.perf_counter())
                self._record(TransferStats(
                    nbytes, wall, len(payloads), direction,
                    self.policy.tag))
                # preemptible chunks landed per-segment lists: splice them
                # back into one flat, ordered chunk list for the caller.
                flat_results = _flatten_chunk_results(results)
                ticket_out.append(flat_results)
                if callback is not None:
                    try:
                        callback(flat_results)
                    except BaseException as e:  # surfaced at wait()
                        ticket_out[0] = e
            master.set()

        qos = qos if qos is not None else self.qos
        cls = qos.priority or self.priority
        for i, payload in enumerate(payloads):
            idx, release = self._acquire_buffer()
            dst = outs[i] if outs is not None else None

            def work(i=i, p=payload, o=dst, idx=idx, release=release):
                err = None
                if aborted.is_set():
                    # a sibling chunk already failed the master ticket:
                    # skip the payload move, release the slot, and step the
                    # completion protocol with a non-primary error (the
                    # sibling's error stays first in ticket_out).
                    with self._stats_lock:
                        self.chunks_cancelled += 1
                    self._release_buffer(idx, release)
                    finish_one(RuntimeError(
                        "chunk cancelled: sibling chunk of this transfer "
                        "failed"))
                    return None
                with state_lock:
                    if state["t0"] is None:
                        state["t0"] = time.perf_counter()
                try:
                    results[i] = self._one_timed(p, direction, o)
                except BaseException as e:
                    err = e
                finally:
                    self._release_buffer(idx, release)
                    finish_one(err)

            def cancelled(err, idx=idx, release=release):
                # queued chunk cancelled at teardown: ``work`` never runs,
                # so the slot release and the master-ticket completion
                # protocol must run here — otherwise Ticket.wait() hangs
                # forever and the layout stays busy.
                self._release_buffer(idx, release)
                finish_one(err)

            segs = self._preempt_segments_for(payload, direction, cls)
            if segs is not None:
                # resumable segment iterator: the runtime may park this
                # chunk mid-flight for a TOKEN/SENSOR arrival. The segment
                # results land in results[i] via collect; finalize mirrors
                # ``work``'s finally (slot release + master-ticket step)
                # and runs exactly once — a queued/parked cancellation
                # takes ``cancelled`` instead.
                def seg_thunk(s):
                    def run():
                        if aborted.is_set():
                            # raising aborts the PreemptibleWork; its
                            # finalize releases the slot + steps the master
                            # ticket (the sibling's error stays first).
                            with self._stats_lock:
                                self.chunks_cancelled += 1
                            raise RuntimeError(
                                "chunk cancelled: sibling chunk of this "
                                "transfer failed")
                        with state_lock:
                            if state["t0"] is None:
                                state["t0"] = time.perf_counter()
                        return self._one_timed(s, direction)
                    return run

                def collect(parts, i=i):
                    results[i] = list(parts)
                    return results[i]

                submit_obj: Any = PreemptibleWork(
                    [seg_thunk(s) for s in segs],
                    collect=collect,
                    finalize=lambda err, idx=idx, release=release: (
                        self._release_buffer(idx, release),
                        finish_one(err)))
            else:
                submit_obj = work

            try:
                handle.submit(submit_obj,
                              nbytes=_payload_nbytes(payload, direction),
                              qos=qos, on_cancel=cancelled)
            except BaseException as e:
                # engine/runtime closed mid-loop: this chunk and every
                # unsubmitted one after it must still be accounted on the
                # master ticket (or wait() hangs and the layout stays
                # busy); its slot must be freed.
                self._release_buffer(idx, release)
                for _ in range(len(payloads) - i):
                    finish_one(e)
                break
        return Ticket(master, ticket_out, on_timeout=self._escalate_timeout)

    def tx_async(self, host_array: np.ndarray,
                 callback: Callable[[list], None] | None = None,
                 layout: StagedLayout | None = None,
                 priority: PriorityClass | None = None, *,
                 qos: QosSpec | None = None) -> Ticket:
        """Asynchronous TX. When ``layout`` is given (its staging buffer is
        the payload), the layout is marked busy until completion so an unsafe
        re-pack raises :class:`BufferInFlightError`."""
        if self.policy.management is not Management.INTERRUPT:
            raise ValueError("tx_async requires INTERRUPT management")
        spec = self._resolve_qos("tx_async", qos, priority)
        arr = np.asarray(host_array)
        chunks = _split(arr, self.policy)
        return self._submit_async(chunks, "tx", int(arr.nbytes), callback,
                                  layout, qos=spec)

    def rx_async(self, device_arrays: Sequence[jax.Array],
                 callback: Callable[[list], None] | None = None,
                 out: Sequence[np.ndarray] | None = None,
                 priority: PriorityClass | None = None, *,
                 qos: QosSpec | None = None) -> Ticket:
        """Asynchronous RX: device arrays stream back to host on a completion
        worker while the caller keeps computing. ``wait()`` returns the host
        ndarray list.

        ``out``: caller-owned destination buffers (one per array, byte sizes
        matching). The completion worker writes each result IN PLACE and the
        ticket yields the caller's own buffer objects — steady state does
        zero per-call host allocations (the serving detokenize path)."""
        if self.policy.management is not Management.INTERRUPT:
            raise ValueError("rx_async requires INTERRUPT management")
        spec = self._resolve_qos("rx_async", qos, priority)
        arrays = list(device_arrays)
        outs = _check_out(arrays, out)
        nbytes = sum(int(a.size) * a.dtype.itemsize for a in arrays)
        return self._submit_async(arrays, "rx", nbytes, callback, None,
                                  outs=outs if out is not None else None,
                                  qos=spec)

    # -- batched descriptor submission (one ring transaction, many tickets) --
    def _submit_many(self, payloads: list, direction: str,
                     sizes: list[int],
                     outs: Sequence[np.ndarray] | None,
                     qos: QosSpec) -> list[Ticket]:
        """Submit a GROUP of small logical descriptors as ONE ring
        transaction: one slot, one runtime descriptor (``units=len``), one
        completion handoff — the paper's management-overhead amortization
        applied at the submission side. Each logical descriptor still gets
        its own :class:`Ticket`; a per-descriptor failure errors only its
        ticket, siblings resolve normally (exactly-once slot release).

        The fast path fuses the whole group into ONE ``device_put`` /
        ``device_get`` call (the list-form pytree API), charging each
        descriptor a size-proportional share of the fused wall time in
        ``chunk_samples`` — honest amortized per-descriptor costs for the
        online refit. Engines that override ``_one`` (fault injection,
        modelled timing) take the per-payload loop instead, so injection
        seams and synthetic costs stay per-descriptor."""
        handle = self._runtime_handle()
        n = len(payloads)
        events = [threading.Event() for _ in range(n)]
        out_lists: list[list] = [[] for _ in range(n)]
        tickets = [Ticket(events[i], out_lists[i],
                          on_timeout=self._escalate_timeout)
                   for i in range(n)]
        if n == 0:
            return tickets
        total = sum(sizes)
        mode = self.policy.management.value

        def resolve(errs: list, results: list, wall: float) -> None:
            # single completion handoff for the whole group: one recorded
            # TransferStats (successful bytes/descriptors only — exact
            # accounting), then every ticket resolves in submission order.
            ok_bytes = sum(sz for sz, e in zip(sizes, errs) if e is None)
            ok_n = sum(1 for e in errs if e is None)
            if ok_n:
                self._record(TransferStats(ok_bytes, wall, ok_n, direction,
                                           self.policy.tag))
                if ok_n > 1 and wall > 0.0:
                    # grouped-transaction sample: the SG/batched crossover
                    # refits the per-segment walk cost from (k, total, wall)
                    self.sg_samples.append((direction, ok_n, ok_bytes, wall))
            for i in range(n):
                out_lists[i].append(
                    errs[i] if errs[i] is not None else results[i])
                events[i].set()

        # ONE ring slot for the whole transaction, acquired caller-side
        # (back-pressure semantics identical to _submit_async).
        idx, release = self._acquire_buffer()

        def work():
            results: list = [None] * n
            errs: list[BaseException | None] = [None] * n
            t0 = time.perf_counter()
            try:
                fused = (n > 1 and not self.policy.checksum
                         and type(self)._one is TransferEngine._one)
                if fused:
                    try:
                        tf0 = time.perf_counter()
                        if direction == "tx":
                            put = jax.device_put(list(payloads), self.device)
                            jax.block_until_ready(put)
                            results = list(put)
                        else:
                            hosts = jax.device_get(list(payloads))
                            for i, h in enumerate(hosts):
                                h = np.asarray(h)
                                o = outs[i] if outs is not None else None
                                if o is None:
                                    results[i] = h
                                else:
                                    np.copyto(
                                        o.reshape(-1).view(np.uint8),
                                        h.reshape(-1).view(np.uint8))
                                    results[i] = o
                        t_fused = time.perf_counter() - tf0
                        for i, sz in enumerate(sizes):
                            self.chunk_samples.append(
                                (direction, mode, sz,
                                 t_fused * sz / max(total, 1)))
                        with self._stats_lock:
                            self.chunk_seq += n
                    except BaseException:
                        # fused call failed as a whole: re-run per payload
                        # so the failure is attributed per descriptor.
                        fused = False
                        results = [None] * n
                if not fused:
                    for i, p in enumerate(payloads):
                        o = outs[i] if outs is not None else None
                        try:
                            results[i] = self._one_timed(p, direction, o)
                        except BaseException as e:
                            errs[i] = e
            finally:
                self._release_buffer(idx, release)
                resolve(errs, results, time.perf_counter() - t0)

        def cancelled(err: BaseException) -> None:
            # the group descriptor was cancelled while queued: ``work``
            # never runs, so the slot release and every ticket's error
            # handoff happen here (exactly once).
            with self._stats_lock:
                self.chunks_cancelled += n
            self._release_buffer(idx, release)
            resolve([err] * n, [None] * n, 0.0)

        try:
            handle.submit(work, nbytes=total, qos=qos,
                          on_cancel=cancelled, units=n)
        except BaseException as e:
            # engine/runtime closed concurrently: free the slot and error
            # every ticket (uniform with the async API — errors surface at
            # wait(), never from the submit call).
            self._release_buffer(idx, release)
            resolve([e] * n, [None] * n, 0.0)
        return tickets

    def tx_many(self, host_arrays: Sequence[np.ndarray],
                priority: PriorityClass | None = None, *,
                qos: QosSpec | None = None) -> list[Ticket]:
        """Batched TX: submit K small host arrays as one ring transaction
        with per-array tickets. Each array is one logical descriptor (no
        chunk split — the point is amortizing management overhead over
        SMALL payloads; use :meth:`tx_async` for large ones)."""
        if self.policy.management is not Management.INTERRUPT:
            raise ValueError("tx_many requires INTERRUPT management")
        spec = self._resolve_qos("tx_many", qos, priority)
        arrays = [np.asarray(a) for a in host_arrays]
        sizes = [int(a.nbytes) for a in arrays]
        return self._submit_many(arrays, "tx", sizes, None, spec)

    def rx_many(self, device_arrays: Sequence[jax.Array],
                out: Sequence[np.ndarray] | None = None,
                priority: PriorityClass | None = None, *,
                qos: QosSpec | None = None) -> list[Ticket]:
        """Batched RX: K device arrays come back as one ring transaction
        with per-array tickets; ``out`` keeps rx_async's zero-copy landing
        contract per descriptor. ``tickets[i].wait()`` returns the bare
        host array (not a chunk list)."""
        if self.policy.management is not Management.INTERRUPT:
            raise ValueError("rx_many requires INTERRUPT management")
        spec = self._resolve_qos("rx_many", qos, priority)
        arrays = list(device_arrays)
        outs = _check_out(arrays, out)
        sizes = [int(a.size) * a.dtype.itemsize for a in arrays]
        return self._submit_many(arrays, "rx", sizes,
                                 outs if out is not None else None, spec)

    # -- scatter-gather descriptors (one slot, K segments, zero staging copy)
    def tx_sg(self, segments: Sequence[Any],
              priority: PriorityClass | None = None, *,
              qos: QosSpec | None = None) -> SGTicket:
        """Scatter-gather TX: a logical transfer submitted as a list of
        ``(array, offset, nbytes)`` segments (bare arrays = whole-array
        segments) that occupies ONE ring slot and ONE runtime descriptor
        (``units=K``), with per-segment completion tracking and ordered
        reassembly — and ZERO staging memcpy: each segment view goes
        straight into the device put (the SG descriptor chain of the BSA
        DMA engine, SNIPPETS.md Snippet 1). Whole-array segments come back
        as shaped device arrays, so no unpack bitcast is needed either."""
        if self.policy.management is not Management.INTERRUPT:
            raise ValueError("tx_sg requires INTERRUPT management")
        spec = self._resolve_qos("tx_sg", qos, priority)
        views, sizes = _sg_segment_views(segments, "tx")
        return SGTicket(self._submit_many(views, "tx", sizes, None, spec))

    def rx_sg(self, segments: Sequence[Any],
              out: "np.ndarray | Sequence[np.ndarray] | None" = None,
              priority: PriorityClass | None = None, *,
              qos: QosSpec | None = None) -> SGTicket:
        """Scatter-gather RX, mirroring :meth:`tx_sg`. ``out`` keeps the
        zero-copy landing contract per segment: a sequence of per-segment
        buffers, or ONE flat array carved at segment boundaries (the
        striped reassembly landing zone)."""
        if self.policy.management is not Management.INTERRUPT:
            raise ValueError("rx_sg requires INTERRUPT management")
        spec = self._resolve_qos("rx_sg", qos, priority)
        views, sizes = _sg_segment_views(segments, "rx")
        outs = None
        if out is not None:
            outs = (carve_flat_out(out, views) if isinstance(out, np.ndarray)
                    else _check_out(views, out))
        return SGTicket(self._submit_many(views, "rx", sizes, outs, spec))

    def _sg_fit(self) -> Any | None:
        """Fit ``t(n) = t0 + n/BW`` from this engine's own recent TX chunk
        samples — the model the standalone pack-vs-SG decision prices with
        when no online controller is attached. None until there are enough
        samples spanning a real size range (a degenerate fit would put the
        crossover anywhere)."""
        samples = [(n, t) for d, _m, n, t in list(self.chunk_samples)
                   if d == "tx" and n > 0 and t > 0]
        if len(samples) < 8:
            return None
        ns = np.array([s[0] for s in samples], float)
        if ns.max() < 4 * max(ns.min(), 1.0):
            return None
        ts = np.array([s[1] for s in samples], float)
        from repro.core.cost_model import TransferCostModel  # no cycle: lazy
        return TransferCostModel.fit(ns, ts)

    def sg_seg_t0_s(self, model: Any | None = None) -> float | None:
        """Effective per-segment walk cost under grouped submission,
        estimated from recent ``_submit_many`` transactions: each sample
        gives ``seg_t0 ~= (wall - t0 - total/BW) / K``. Median over the
        window (robust to one preempted outlier); None without data."""
        m = model if model is not None else self._sg_fit()
        if m is None:
            return None
        est = [max((wall - m.t0_s - total / m.bw_Bps) / k, 1e-7)
               for _d, k, total, wall in list(self.sg_samples) if k > 1]
        if not est:
            return None
        return float(np.median(np.array(est)))

    def prefer_sg(self, sizes: Sequence[int],
                  model: Any | None = None) -> bool:
        """Pack-vs-SG decision for one layer set (see :func:`choose_sg`),
        with the engine's best current knowledge: an explicit fitted
        ``model`` wins; else a fit from the engine's own chunk samples;
        else the structural few-large-arrays fallback.
        AdaptiveChannelGroup overrides this with the controller's live
        refit."""
        sizes = [int(s) for s in sizes]
        m = model if model is not None else self._sg_fit()
        if m is None:
            return (0 < len(sizes) <= _SG_FALLBACK_MAX_SEGMENTS
                    and min(sizes) >= _SG_FALLBACK_MIN_SEG_BYTES)
        return choose_sg(sizes, m, seg_t0_s=self.sg_seg_t0_s(m))

    # -- reporting -----------------------------------------------------------
    def summary(self) -> dict[str, float]:
        # snapshot under the lock: workers append records + bump the fault
        # ledger concurrently, and iterating a deque being appended from
        # another thread can skip/duplicate entries
        with self._stats_lock:
            records = list(self.stats)
            checksum_failures = self.checksum_failures
            chunks_cancelled = self.chunks_cancelled
        tx = [s for s in records if s.direction == "tx"]
        rx = [s for s in records if s.direction == "rx"]
        def agg(ss):
            if not ss:
                return {"us_per_byte": float("nan"), "gbps": float("nan")}
            tot_b = sum(s.nbytes for s in ss)
            tot_t = sum(s.wall_s for s in ss)
            return {"us_per_byte": tot_t * 1e6 / max(tot_b, 1),
                    "gbps": tot_b / max(tot_t, 1e-12) / 1e9}
        return {"tx": agg(tx), "rx": agg(rx),  # type: ignore[return-value]
                "checksum_failures": checksum_failures,
                "chunks_cancelled": chunks_cancelled}
