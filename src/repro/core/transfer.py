"""Host<->device transfer engine with the paper's policy matrix.

The paper evaluates how the *software policy* controlling DMA between the
processing system (PS) and programmable logic (PL) determines delivered
bandwidth. The three managements map onto JAX host<->device semantics:

- ``POLLING``   — user-level polling driver: issue the transfer and spin-wait
  (``block_until_ready``) before touching the data. Lowest per-transfer
  latency; host is blocked for the duration (the paper's warning: for large
  CNNs this blocks the whole system).
- ``SCHEDULED`` — user-level scheduled driver: transfers are enqueued on a
  cooperative scheduler which interleaves them with other registered tasks
  (sensor collection / normalization in the paper; data-prep and metric tasks
  here). Slightly higher latency, no dead-lock waits.
- ``INTERRUPT`` — kernel-level interrupt driver: transfers run on a background
  completion thread; the caller gets a ticket and is *notified* (callback /
  event) on completion. Highest fixed overhead per transfer, best overlap,
  memory-safety enforced (a buffer cannot be re-staged before completion —
  the engine raises, mirroring the kernel driver's protection role).

Buffering: ``SINGLE`` stages through one pinned buffer; ``DOUBLE`` alternates
two, so chunk *k+1* is staged while chunk *k* is in flight.

Partitioning: ``UNIQUE`` sends the payload in one transfer; ``BLOCKS`` splits
it into ``block_bytes`` chunks (only BLOCKS lets DOUBLE buffering overlap).

Everything here is *measured*, not simulated: on this container the device is
CPU, but the staging/copy/dispatch structure (and therefore the relative
behaviour the paper studies — fixed overhead vs per-byte cost, overlap gains)
is real.
"""

from __future__ import annotations

import enum
import math
import queue
import threading
import time
from dataclasses import dataclass, field, replace
from typing import Any, Callable, Sequence

import jax
import numpy as np


class Management(enum.Enum):
    POLLING = "polling"
    SCHEDULED = "scheduled"
    INTERRUPT = "interrupt"


class Buffering(enum.Enum):
    SINGLE = "single"
    DOUBLE = "double"


class Partitioning(enum.Enum):
    UNIQUE = "unique"
    BLOCKS = "blocks"


@dataclass(frozen=True)
class TransferPolicy:
    """The paper's full policy point. Carried in model/run configs."""

    management: Management = Management.INTERRUPT
    buffering: Buffering = Buffering.DOUBLE
    partitioning: Partitioning = Partitioning.BLOCKS
    block_bytes: int = 1 << 20  # 1 MiB default chunk (paper crossover region)

    def with_(self, **kw) -> "TransferPolicy":
        return replace(self, **kw)

    @property
    def tag(self) -> str:
        return (
            f"{self.management.value}-{self.buffering.value}-"
            f"{self.partitioning.value}"
        )

    @staticmethod
    def user_level_polling() -> "TransferPolicy":
        return TransferPolicy(Management.POLLING, Buffering.SINGLE, Partitioning.UNIQUE)

    @staticmethod
    def user_level_scheduled() -> "TransferPolicy":
        return TransferPolicy(
            Management.SCHEDULED, Buffering.SINGLE, Partitioning.UNIQUE
        )

    @staticmethod
    def kernel_level() -> "TransferPolicy":
        return TransferPolicy(
            Management.INTERRUPT, Buffering.SINGLE, Partitioning.UNIQUE
        )


@dataclass
class TransferStats:
    """Measured outcome of one logical transfer (possibly many chunks)."""

    nbytes: int
    wall_s: float
    n_chunks: int
    direction: str  # "tx" (host->device) or "rx" (device->host)
    policy_tag: str

    @property
    def us_per_byte(self) -> float:
        return (self.wall_s * 1e6) / max(self.nbytes, 1)

    @property
    def gbps(self) -> float:
        return self.nbytes / max(self.wall_s, 1e-12) / 1e9

    def row(self) -> str:
        return (
            f"{self.policy_tag},{self.direction},{self.nbytes},"
            f"{self.wall_s * 1e3:.4f},{self.us_per_byte:.6f},{self.n_chunks}"
        )


class _CompletionThread:
    """The 'kernel-level interrupt driver': a background worker that executes
    staged transfer descriptors and fires completion callbacks.

    Mirrors the Xilinx AXI-DMA driver structure: a descriptor queue
    (scatter-gather ring), a privileged worker, and interrupt-style
    notification (here: ``threading.Event`` + optional callback)."""

    def __init__(self) -> None:
        self._q: "queue.Queue[tuple[Callable[[], Any], threading.Event, list]]" = (
            queue.Queue()
        )
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self) -> None:
        while True:
            fn, done, out = self._q.get()
            try:
                out.append(fn())
            except BaseException as e:  # surfaced at wait()
                out.append(e)
            done.set()

    def submit(self, fn: Callable[[], Any]) -> tuple[threading.Event, list]:
        done = threading.Event()
        out: list = []
        self._q.put((fn, done, out))
        return done, out


_COMPLETION: _CompletionThread | None = None
_COMPLETION_LOCK = threading.Lock()


def _completion_thread() -> _CompletionThread:
    global _COMPLETION
    with _COMPLETION_LOCK:
        if _COMPLETION is None:
            _COMPLETION = _CompletionThread()
        return _COMPLETION


class Ticket:
    """Handle for an in-flight INTERRUPT-mode transfer."""

    def __init__(self, done: threading.Event, out: list):
        self._done = done
        self._out = out

    def wait(self) -> Any:
        self._done.wait()
        result = self._out[0]
        if isinstance(result, BaseException):
            raise result
        return result

    @property
    def complete(self) -> bool:
        return self._done.is_set()


class BufferInFlightError(RuntimeError):
    """Raised when a staging buffer is re-used before its transfer completed.

    This is the memory-protection role of the paper's kernel-level driver:
    user-level code could silently corrupt a physical buffer still owned by
    the DMA engine; the kernel driver forbids it. So do we."""


def _split(arr: np.ndarray, policy: TransferPolicy) -> list[np.ndarray]:
    """Partition a flat view of ``arr`` according to the policy."""
    flat = arr.reshape(-1)
    if policy.partitioning is Partitioning.UNIQUE or flat.nbytes <= policy.block_bytes:
        return [flat]
    per_chunk = max(1, policy.block_bytes // max(flat.itemsize, 1))
    n = math.ceil(flat.size / per_chunk)
    return [flat[i * per_chunk : (i + 1) * per_chunk] for i in range(n)]


class TransferEngine:
    """Executes host->device (TX) and device->host (RX) transfers under a
    :class:`TransferPolicy`, recording measured :class:`TransferStats`.

    The engine owns the staging buffers (the paper's single/double buffer in
    the *physical* space) and enforces completion ordering."""

    def __init__(self, policy: TransferPolicy, device: jax.Device | None = None,
                 scheduler: "CooperativeScheduler | None" = None):
        self.policy = policy
        self.device = device or jax.devices()[0]
        self.stats: list[TransferStats] = []
        self._buffers_busy: list[threading.Event | None] = [None, None]
        self._buf_idx = 0
        # SCHEDULED mode needs a scheduler; lazily import to avoid cycle.
        if scheduler is None and policy.management is Management.SCHEDULED:
            from repro.core.scheduler import CooperativeScheduler

            scheduler = CooperativeScheduler()
        self._scheduler = scheduler

    # -- staging-buffer safety (kernel-driver protection semantics) --------
    def _acquire_buffer(self) -> int:
        n_buf = 2 if self.policy.buffering is Buffering.DOUBLE else 1
        idx = self._buf_idx % n_buf
        busy = self._buffers_busy[idx]
        if busy is not None and not busy.is_set():
            if self.policy.management is Management.INTERRUPT:
                busy.wait()  # kernel driver: safe, waits for completion
            else:
                raise BufferInFlightError(
                    f"staging buffer {idx} reused before completion "
                    f"(policy={self.policy.tag}); use INTERRUPT management or "
                    f"DOUBLE buffering"
                )
        self._buf_idx += 1
        return idx

    # -- TX: host -> device -------------------------------------------------
    def tx(self, host_array: np.ndarray) -> list[jax.Array]:
        """Transfer ``host_array`` to the device; returns device chunk list."""
        chunks = _split(np.asarray(host_array), self.policy)
        t0 = time.perf_counter()
        out = self._run_chunks(
            [(c, "tx") for c in chunks],
        )
        wall = time.perf_counter() - t0
        self.stats.append(
            TransferStats(host_array.nbytes, wall, len(chunks), "tx", self.policy.tag)
        )
        return out

    # -- RX: device -> host -------------------------------------------------
    def rx(self, device_arrays: Sequence[jax.Array]) -> list[np.ndarray]:
        """Transfer device arrays back to host memory."""
        nbytes = sum(int(a.size) * a.dtype.itemsize for a in device_arrays)
        t0 = time.perf_counter()
        out = self._run_chunks([(a, "rx") for a in device_arrays])
        wall = time.perf_counter() - t0
        self.stats.append(
            TransferStats(nbytes, wall, len(device_arrays), "rx", self.policy.tag)
        )
        return out

    # -- chunk executor under the three managements -------------------------
    def _one(self, payload, direction: str):
        if direction == "tx":
            return jax.device_put(payload, self.device)
        return np.asarray(jax.device_get(payload))

    def _run_chunks(self, items: list[tuple[Any, str]]) -> list:
        mgmt = self.policy.management
        if mgmt is Management.POLLING:
            # user-level polling: issue, then spin until ready, per chunk.
            results = []
            for payload, direction in items:
                self._acquire_buffer()
                r = self._one(payload, direction)
                if direction == "tx":
                    r.block_until_ready()
                results.append(r)
            return results

        if mgmt is Management.SCHEDULED:
            # cooperative scheduler: each chunk is a task; the scheduler may
            # interleave other registered work between chunks.
            results: list = [None] * len(items)

            def make_task(i, payload, direction):
                def task():
                    self._acquire_buffer()
                    r = self._one(payload, direction)
                    if direction == "tx":
                        r.block_until_ready()
                    results[i] = r

                return task

            for i, (payload, direction) in enumerate(items):
                self._scheduler.submit(make_task(i, payload, direction))
            self._scheduler.drain()
            return results

        # INTERRUPT: stage every chunk onto the completion thread. With DOUBLE
        # buffering, chunk k+1 is staged while k is in flight (true overlap).
        thread = _completion_thread()
        depth = 2 if self.policy.buffering is Buffering.DOUBLE else 1
        tickets: list[Ticket | None] = [None] * len(items)
        results: list = [None] * len(items)
        inflight: list[int] = []
        for i, (payload, direction) in enumerate(items):
            while len(inflight) >= depth:
                j = inflight.pop(0)
                results[j] = tickets[j].wait()
            idx = self._acquire_buffer()
            done, out = thread.submit(
                lambda p=payload, d=direction: self._one(p, d)
            )
            self._buffers_busy[idx] = done
            tickets[i] = Ticket(done, out)
            inflight.append(i)
        for j in inflight:
            results[j] = tickets[j].wait()
        return results

    # -- async API (INTERRUPT only): returns a ticket, caller is "interrupted"
    def tx_async(self, host_array: np.ndarray,
                 callback: Callable[[list], None] | None = None) -> Ticket:
        if self.policy.management is not Management.INTERRUPT:
            raise ValueError("tx_async requires INTERRUPT management")
        thread = _completion_thread()
        chunks = _split(np.asarray(host_array), self.policy)

        def work():
            # NB: runs ON the completion thread — execute chunks inline
            # (re-entering the descriptor queue here would self-deadlock,
            # like an IRQ handler waiting on its own IRQ).
            out = []
            for c in chunks:
                r = jax.device_put(c, self.device)
                r.block_until_ready()
                out.append(r)
            if callback is not None:
                callback(out)
            return out

        done, out = thread.submit(work)
        return Ticket(done, out)

    # -- reporting -----------------------------------------------------------
    def summary(self) -> dict[str, float]:
        tx = [s for s in self.stats if s.direction == "tx"]
        rx = [s for s in self.stats if s.direction == "rx"]
        def agg(ss):
            if not ss:
                return {"us_per_byte": float("nan"), "gbps": float("nan")}
            tot_b = sum(s.nbytes for s in ss)
            tot_t = sum(s.wall_s for s in ss)
            return {"us_per_byte": tot_t * 1e6 / max(tot_b, 1),
                    "gbps": tot_b / max(tot_t, 1e-12) / 1e9}
        return {"tx": agg(tx), "rx": agg(rx)}  # type: ignore[return-value]
