"""The unified QoS submit context + serving-side admission control.

One object — :class:`QosSpec` — carries every quality-of-service knob a
transfer submission can set, through every layer of the stack::

    engine.tx(arr, qos=QosSpec(priority=PriorityClass.TOKEN,
                               tenant="user-42", weight=2.0))

Before this module the knobs were scattered: ``priority=`` on the eight
engine submit methods, ``class_caps=`` / ``rx_timeout_s=`` / ``rx_group=``
on :class:`~repro.serve.engine.ServeConfig` and
:class:`~repro.serve.continuous.ContinuousBatchingEngine`. Those kwargs
still work for one release of compat, but they are deprecation shims:
each builds a ``QosSpec`` internally and emits a ``DeprecationWarning``
(see :func:`resolve_submit_qos`). The arbitration they produce is
identical — the shim IS the new path.

Tenancy (PR 10) rides the same object: ``tenant`` names a flow inside the
descriptor's priority class, ``weight`` its byte-weighted fair share
among the class's tenants, ``cap_bytes_per_s``/``burst_s`` its private
token bucket under the class cap (the cap *tree* — see
:mod:`repro.core.runtime`). ``deadline_s`` overrides the class EDF
deadline per submission; ``timeout_s`` bounds serving-side ticket waits;
``rx_group`` sets the serving token-RX batching factor.

Admission control
-----------------
The serving layer must shed load *before* the accelerator queue backs up
(NEURAghe's host-side co-scheduling argument): :class:`AdmissionController`
turns two runtime signals — a tenant's queued-descriptor depth and the
class's recent deadline-miss rate — into an explicit
:class:`AdmissionDecision` (``accept`` / ``queue`` / ``shed`` plus a
retry-after hint). A shed submitter gets the decision (or
:class:`AdmissionError` on the synchronous paths), never a hang and never
a silently collapsed p99. Thresholds live in :class:`AdmissionPolicy`.
"""

from __future__ import annotations

import warnings
from dataclasses import dataclass, replace
from typing import Any, Mapping

from repro.analysis.validated import make_lock
from repro.core.runtime import DEFAULT_TENANT, PriorityClass

__all__ = [
    "DEFAULT_TENANT",
    "QosSpec",
    "AdmissionPolicy",
    "AdmissionDecision",
    "AdmissionController",
    "AdmissionError",
    "resolve_submit_qos",
    "warn_deprecated_kwarg",
]

# DEFAULT_TENANT (re-exported from the runtime): the flow every untagged
# submission lands in. One shared flow means untagged traffic arbitrates
# exactly like the pre-tenancy runtime did — single-tenant processes see
# byte-identical scheduling.


@dataclass(frozen=True)
class QosSpec:
    """The submit context: class, tenant, share, caps, deadlines.

    Every field defaults to ``None`` ("unset"), so specs merge: an engine
    holds a base spec, a per-call spec overrides only the fields it sets
    (:meth:`merged`). Resolution order is per-call > engine default >
    runtime class defaults.

    ``priority``
        Arbitration class (:class:`~repro.core.runtime.PriorityClass`).
    ``tenant``
        Flow id inside the class; unset maps to :data:`DEFAULT_TENANT`.
    ``weight``
        Byte-weighted fair share among the class's tenants (tier-2 WFQ).
    ``cap_bytes_per_s`` / ``burst_s``
        Per-tenant token-bucket ceiling; bounded above by the class cap
        (both buckets must clear for a dispatch — the cap tree).
    ``deadline_s``
        Per-submission EDF deadline override (else the class default).
    ``timeout_s``
        Serving-side ticket-wait bound (was ``rx_timeout_s``).
    ``rx_group``
        Serving token-RX batching factor (was ``ServeConfig.rx_group``).
    ``class_caps``
        Class-name -> bytes/s ceilings applied at engine construction
        (was ``ServeConfig.class_caps``).
    """

    priority: PriorityClass | None = None
    tenant: str | None = None
    weight: float | None = None
    cap_bytes_per_s: float | None = None
    burst_s: float | None = None
    deadline_s: float | None = None
    timeout_s: float | None = None
    rx_group: int | None = None
    class_caps: Mapping[str, float] | None = None

    def merged(self, override: "QosSpec | None") -> "QosSpec":
        """This spec with ``override``'s SET fields taking precedence."""
        if override is None:
            return self
        kw = {f: v for f, v in (
            ("priority", override.priority),
            ("tenant", override.tenant),
            ("weight", override.weight),
            ("cap_bytes_per_s", override.cap_bytes_per_s),
            ("burst_s", override.burst_s),
            ("deadline_s", override.deadline_s),
            ("timeout_s", override.timeout_s),
            ("rx_group", override.rx_group),
            ("class_caps", override.class_caps),
        ) if v is not None}
        return replace(self, **kw) if kw else self

    def with_(self, **kw: Any) -> "QosSpec":
        """A copy with the given fields replaced."""
        return replace(self, **kw)

    @property
    def effective_tenant(self) -> str:
        return self.tenant if self.tenant is not None else DEFAULT_TENANT


def warn_deprecated_kwarg(old: str, new: str, *, stacklevel: int = 3) -> None:
    """One canonical deprecation message shape for every legacy QoS kwarg."""
    warnings.warn(
        f"{old} is deprecated; pass {new} instead (the legacy kwarg builds "
        f"the same QosSpec internally and will be removed next release)",
        DeprecationWarning, stacklevel=stacklevel)


def resolve_submit_qos(where: str, qos: "QosSpec | PriorityClass | None",
                       priority: PriorityClass | None) -> "QosSpec | None":
    """Normalise one submit call's ``(qos=, priority=)`` pair to a QosSpec.

    The deprecation shim behind every engine submit method: a legacy
    ``priority=`` kwarg (or a bare :class:`PriorityClass` passed where
    ``qos`` now sits positionally) folds into a ``QosSpec`` and warns.
    Returns ``None`` when neither was given (caller applies its default)."""
    if isinstance(qos, PriorityClass):  # old positional priority call shape
        if priority is not None:
            raise TypeError(
                f"{where}: got both a positional PriorityClass and "
                f"priority=; pass one qos=QosSpec(...) instead")
        qos, priority = None, qos
    if priority is not None:
        warn_deprecated_kwarg(
            f"{where}(priority=...)",
            f"{where}(qos=QosSpec(priority=...))", stacklevel=4)
        if qos is None:
            return QosSpec(priority=priority)
        if qos.priority is not None and qos.priority is not priority:
            raise ValueError(
                f"{where}: qos.priority={qos.priority} conflicts with "
                f"deprecated priority={priority}")
        return qos.with_(priority=priority)
    return qos


# ---------------------------------------------------------------------------
# Admission control (the serving-side backpressure valve)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class AdmissionPolicy:
    """Thresholds the serving layer sheds on. Defaults are deliberately
    generous — admission exists to stop a *flooding* tenant, not to shave
    a busy one; a single-tenant process never trips them.

    ``queue_depth``: a tenant with this many queued-but-undispatched
    descriptors gets ``queue`` decisions (admitted, but told to back
    off). ``shed_depth``: above this the tenant is shed outright.
    ``shed_miss_rate``: when the class's recent deadline-miss fraction
    (over ``miss_window_s``) crosses this, NEW tenants are shed too —
    the runtime as a whole is past its deadline budget and queueing more
    only moves the collapse downstream. ``retry_after_s``: base backoff
    hint; the decision scales it with queue pressure."""

    queue_depth: int = 64
    shed_depth: int = 256
    shed_miss_rate: float = 0.5
    miss_window_s: float = 5.0
    retry_after_s: float = 0.05


@dataclass(frozen=True)
class AdmissionDecision:
    """The explicit backpressure signal: what happened to one submission
    attempt and when to retry. ``action`` is ``accept`` / ``queue`` /
    ``shed``; only ``shed`` means the request was NOT enqueued."""

    action: str
    tenant: str
    reason: str = ""
    retry_after_s: float | None = None
    queue_depth: int = 0
    miss_rate: float = 0.0

    @property
    def admitted(self) -> bool:
        return self.action != "shed"


class AdmissionError(RuntimeError):
    """Raised by synchronous serving paths when admission sheds the call
    (the async path returns the :class:`AdmissionDecision` instead)."""

    def __init__(self, decision: AdmissionDecision):
        hint = (f"; retry after {decision.retry_after_s:.3f}s"
                if decision.retry_after_s else "")
        super().__init__(
            f"admission shed tenant {decision.tenant!r}: "
            f"{decision.reason}{hint}")
        self.decision = decision


class AdmissionController:
    """Turns runtime pressure signals into accept/queue/shed decisions.

    Stateless with respect to the runtime (it only *reads*
    ``tenant_depth`` and ``deadline_miss_rate``); keeps its own decision
    ledger so ``fault_summary()``-style surfaces can report shed counts
    per tenant. With no runtime attached every decision is ``accept`` —
    a polling engine has no queue to protect."""

    def __init__(self, runtime: Any = None,
                 policy: AdmissionPolicy | None = None,
                 cls: PriorityClass = PriorityClass.TOKEN):
        self.policy = policy or AdmissionPolicy()
        self.cls = cls
        self._runtime = runtime
        self._lock = make_lock("AdmissionController._lock")
        self.accepts = 0                               # guarded-by: _lock
        self.queued = 0                                # guarded-by: _lock
        self.sheds = 0                                 # guarded-by: _lock
        self._by_tenant: dict[str, dict[str, int]] = {}  # guarded-by: _lock

    @property
    def runtime(self) -> Any:
        return self._runtime() if callable(self._runtime) else self._runtime

    def _note(self, tenant: str, action: str) -> None:
        with self._lock:
            row = self._by_tenant.setdefault(
                tenant, {"accept": 0, "queue": 0, "shed": 0})
            row[action] += 1
            if action == "accept":
                self.accepts += 1
            elif action == "queue":
                self.queued += 1
            else:
                self.sheds += 1

    def decide(self, tenant: str | None = None, *,
               cls: PriorityClass | None = None,
               extra_depth: int = 0) -> AdmissionDecision:
        """One admission decision for ``tenant`` at class ``cls``.

        ``extra_depth`` adds serving-layer backlog the runtime cannot see
        (e.g. a continuous-batching engine's host-side request queue) to
        the tenant's queued-descriptor depth before thresholding."""
        tenant = tenant if tenant is not None else DEFAULT_TENANT
        cls = cls or self.cls
        pol = self.policy
        rt = self.runtime
        depth = max(0, int(extra_depth))
        miss = 0.0
        if rt is not None:
            depth += rt.tenant_depth(cls, tenant)
            miss = rt.deadline_miss_rate(cls, ttl_s=pol.miss_window_s)
        if depth >= pol.shed_depth:
            d = AdmissionDecision(
                "shed", tenant,
                reason=(f"tenant queue depth {depth} >= shed threshold "
                        f"{pol.shed_depth}"),
                retry_after_s=pol.retry_after_s * max(
                    1.0, depth / max(pol.shed_depth, 1)),
                queue_depth=depth, miss_rate=miss)
        elif miss >= pol.shed_miss_rate and depth > 0:
            # a backlogged tenant on a runtime already missing deadlines:
            # more queueing cannot meet any deadline — shed with a hint
            # sized to the miss window (the time scale of the collapse).
            d = AdmissionDecision(
                "shed", tenant,
                reason=(f"deadline-miss rate {miss:.2f} >= "
                        f"{pol.shed_miss_rate} with tenant backlog {depth}"),
                retry_after_s=pol.miss_window_s / 2,
                queue_depth=depth, miss_rate=miss)
        elif depth >= pol.queue_depth:
            d = AdmissionDecision(
                "queue", tenant,
                reason=(f"tenant queue depth {depth} >= queue threshold "
                        f"{pol.queue_depth}"),
                retry_after_s=pol.retry_after_s,
                queue_depth=depth, miss_rate=miss)
        else:
            d = AdmissionDecision("accept", tenant, queue_depth=depth,
                                  miss_rate=miss)
        self._note(tenant, d.action)
        return d

    def summary(self) -> dict[str, Any]:
        with self._lock:
            return {
                "accepts": self.accepts,
                "queued": self.queued,
                "sheds": self.sheds,
                "by_tenant": {t: dict(row)
                              for t, row in self._by_tenant.items()
                              if row["shed"] or row["queue"]},
            }
