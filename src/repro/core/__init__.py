"""repro.core — the paper's contribution: the transfer-strategy engine.

Implements the policy matrix evaluated by Rios-Navarro et al. (2018) —
management (polling / scheduled / interrupt), buffering (single / double),
partitioning (unique / blocks) — at every memory boundary of a TPU system:

- completion dispatch: :mod:`repro.core.runtime` (ONE shared interrupt-style
                     TransferRuntime arbitrating every engine's completions
                     by QoS class — the paper's kernel driver, centralized)
- submit context   : :mod:`repro.core.qos` (:class:`QosSpec` — class, tenant,
                     weight, caps, deadlines on ONE object — plus serving-side
                     admission control)
- host <-> device  : :mod:`repro.core.transfer` (measured on this machine)
- multi-channel    : :mod:`repro.core.channels` (striped rings + adaptive
                     cost-model policy, the NEURAghe/ZynqNet lesson)
- online adaptation: :mod:`repro.core.adaptive` (rolling t0/BW refit,
                     hysteresis-gated replans applied at ring-drain points)
- HBM  <-> VMEM    : :mod:`repro.kernels` grids parameterized by the policy
- chip <-> chip    : :mod:`repro.core.pipeline_collectives` (blocks-mode rings)
- per-layer stream : :mod:`repro.core.streaming` (the NullHop execution model)

``__all__`` below is the curated public surface — import from here
(``from repro.core import TransferEngine, QosSpec``), not from the
submodules, which stay free to reshuffle internals.
"""

from repro.core.runtime import (
    ClassQos,
    CooperativeScheduler,
    PollingBackend,
    PriorityClass,
    ScheduledBackend,
    TransferRuntime,
    backend_for,
    get_runtime,
    set_runtime,
)
from repro.core.qos import (
    DEFAULT_TENANT,
    AdmissionController,
    AdmissionDecision,
    AdmissionError,
    AdmissionPolicy,
    QosSpec,
)
from repro.core.transfer import (
    Buffering,
    BufferInFlightError,
    LayoutCache,
    Management,
    Partitioning,
    StagedLayout,
    TransferPolicy,
    TransferEngine,
    TransferStats,
)
from repro.core.channels import (
    ChannelGroup,
    ChannelPlan,
    StagingPool,
    calibrate_transfer,
    plan_channels,
)
from repro.core.adaptive import (
    AdaptiveChannelGroup,
    AdaptiveConfig,
    OnlineTransferController,
    RollingFit,
    choose_management,
)
from repro.core.cost_model import TransferCostModel

__all__ = [
    # runtime (completion dispatch + two-tier arbitration)
    "ClassQos",
    "CooperativeScheduler",
    "PollingBackend",
    "PriorityClass",
    "ScheduledBackend",
    "TransferRuntime",
    "backend_for",
    "get_runtime",
    "set_runtime",
    # qos (the unified submit context + admission control)
    "DEFAULT_TENANT",
    "AdmissionController",
    "AdmissionDecision",
    "AdmissionError",
    "AdmissionPolicy",
    "QosSpec",
    # transfer (single-engine policy matrix)
    "Buffering",
    "BufferInFlightError",
    "LayoutCache",
    "Management",
    "Partitioning",
    "StagedLayout",
    "TransferPolicy",
    "TransferEngine",
    "TransferStats",
    # channels (striped rings)
    "ChannelGroup",
    "ChannelPlan",
    "StagingPool",
    "calibrate_transfer",
    "plan_channels",
    # adaptive (online controller)
    "AdaptiveChannelGroup",
    "AdaptiveConfig",
    "OnlineTransferController",
    "RollingFit",
    "choose_management",
    # cost model
    "TransferCostModel",
]
