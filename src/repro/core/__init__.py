"""repro.core — the paper's contribution: the transfer-strategy engine.

Implements the policy matrix evaluated by Rios-Navarro et al. (2018) —
management (polling / scheduled / interrupt), buffering (single / double),
partitioning (unique / blocks) — at every memory boundary of a TPU system:

- completion dispatch: :mod:`repro.core.runtime` (ONE shared interrupt-style
                     TransferRuntime arbitrating every engine's completions
                     by QoS class — the paper's kernel driver, centralized)
- host <-> device  : :mod:`repro.core.transfer` (measured on this machine)
- multi-channel    : :mod:`repro.core.channels` (striped rings + adaptive
                     cost-model policy, the NEURAghe/ZynqNet lesson)
- online adaptation: :mod:`repro.core.adaptive` (rolling t0/BW refit,
                     hysteresis-gated replans applied at ring-drain points)
- HBM  <-> VMEM    : :mod:`repro.kernels` grids parameterized by the policy
- chip <-> chip    : :mod:`repro.core.pipeline_collectives` (blocks-mode rings)
- per-layer stream : :mod:`repro.core.streaming` (the NullHop execution model)
"""

from repro.core.runtime import (  # noqa: F401
    CooperativeScheduler,
    PollingBackend,
    PriorityClass,
    QosSpec,
    ScheduledBackend,
    TransferRuntime,
    backend_for,
    get_runtime,
    set_runtime,
)
from repro.core.transfer import (  # noqa: F401
    Buffering,
    BufferInFlightError,
    LayoutCache,
    Management,
    Partitioning,
    StagedLayout,
    TransferPolicy,
    TransferEngine,
    TransferStats,
)
from repro.core.channels import (  # noqa: F401
    ChannelGroup,
    ChannelPlan,
    StagingPool,
    calibrate_transfer,
    plan_channels,
)
from repro.core.adaptive import (  # noqa: F401
    AdaptiveChannelGroup,
    AdaptiveConfig,
    OnlineTransferController,
    RollingFit,
    choose_management,
)
from repro.core.cost_model import TransferCostModel  # noqa: F401
