"""Online transfer adaptation: rolling cost-model refit + safe plan swaps.

The paper's central observation is that delivered PS<->PL throughput is set
by the *software management* of the DMA engine, not by the AXI bus — and
that the right management flips with packet size. The user-level polling
driver has the lowest fixed overhead ``t0`` but blocks the host; the
kernel-level interrupt driver pays a much larger ``t0`` (syscall, context
switch, IRQ dispatch) yet sustains better bandwidth and overlap, so it wins
only for "longer enough packets": the crossover payload solves

    t0_poll + n / BW_poll  =  t0_intr + n / BW_intr.

PR 2 fit that two-parameter model ``t(n) = t0 + n/BW`` ONCE, at
:class:`~repro.core.channels.ChannelGroup` construction. But ``t0`` and
``BW`` are not constants of the machine: they drift with host load,
allocator state, and thermal/cgroup throttling (the ROADMAP's "plan goes
stale" item; NEURAghe and ZynqNet both re-partition per layer for the same
reason). This module closes the loop:

:class:`RollingFit`
    Bounded window of measured (nbytes, seconds) *chunk* samples with
    EWMA-decayed weighted least squares — recent samples dominate, so a
    step change in t0/BW is visible within a window instead of being
    averaged into history. Fits are kept separately per direction and per
    :class:`~repro.core.transfer.Management` mode, since the paper's whole
    point is that those curves differ.

:class:`OnlineTransferController`
    Consumes per-descriptor chunk samples (every
    :class:`~repro.core.transfer.TransferEngine` records them) plus
    logical :class:`~repro.core.transfer.TransferStats`, refits on a
    cadence, and proposes a new :class:`~repro.core.channels.ChannelPlan`
    only when the fitted t0/BW drifted past a hysteresis ratio — noisy
    samples must not flap the plan. The proposal re-runs
    :func:`~repro.core.channels.plan_channels` (channel count, block_bytes,
    ring_depth) and re-evaluates the polling-vs-interrupt crossover from
    the per-mode fits.

:class:`AdaptiveChannelGroup`
    An engine facade that duck-types :class:`TransferEngine` /
    :class:`ChannelGroup` (``policy`` / ``layouts`` / ``tx`` / ``rx`` /
    ``tx_async`` / ``rx_async`` / ``close`` / ``summary``) and applies
    accepted plans ONLY at safe points: a generation is swapped when no
    transfer issued through the facade is still in flight — the ring is
    drained, no slots are held, so the swap can never orphan a descriptor
    or corrupt a staging buffer. Staging layouts and the staging pool
    persist across generations (a replan must not re-pay the one-time
    layout cost). Uniform traffic (every payload the same size) cannot
    separate t0 from BW, so the facade injects a few tiny probe transfers
    when the window is size-degenerate — the online equivalent of the
    paper's packet-size sweep.
"""

from __future__ import annotations

import collections
import json
import os
import pathlib
import threading
import time
from dataclasses import dataclass
from typing import Any, Callable, Sequence

import jax
import numpy as np

from repro.analysis.validated import assert_held, make_lock, make_rlock
from repro.core.channels import (
    ChannelGroup,
    ChannelPlan,
    StagingPool,
    calibrate_transfer,
    plan_channels,
)
from repro.core.cost_model import TransferCostModel
from repro.core.faults import RecoveryConfig
from repro.core.qos import QosSpec, resolve_submit_qos
from repro.core.runtime import PriorityClass, TransferRuntime
from repro.dist.fault import TransferFaultState
from repro.core.transfer import (
    Buffering,
    Partitioning,
    LayoutCache,
    Management,
    SGTicket,
    StagedLayout,
    Ticket,
    TransferEngine,
    TransferPolicy,
    TransferStats,
    _sg_segment_views,
    carve_flat_out,
    choose_sg,
    reassemble_chunks,
    sg_crossover_segments,
)


@dataclass(frozen=True)
class AdaptiveConfig:
    """Knobs of the online controller."""

    window: int = 256          # chunk samples kept per (direction, mode)
    min_samples: int = 12      # no refit below this many samples
    refit_every: int = 8       # consider a refit every N logical transfers
    hysteresis: float = 1.5    # replan only past this t0/BW factor drift
    ewma_halflife: float = 32  # sample-age halflife for fit weights
    min_size_spread: float = 4.0  # max/min sample size needed to fit t0+BW
    # wall-clock TTL: samples older than this leave the fit window. When
    # the only small-size samples (probes) expire, the window goes
    # size-degenerate and the facade re-probes — so probe freshness is
    # self-regulating with cadence ~ttl, and a regime change can never be
    # straddled by mixing old-regime smalls with new-regime larges (which
    # fits a spurious slope).
    sample_ttl_s: float = 5.0
    max_channels: int = 4
    completion_workers: int = 2   # per-engine workers in replanned policies
    probe_sizes: tuple = (16 << 10, 128 << 10)  # degenerate-window probes
    # preemptive chunked dispatch: target per-segment service time for the
    # fitted TransferPolicy.preempt_chunk_bytes on every plan (adaptive
    # consumers share the runtime with latency traffic, so mid-chunk yield
    # points are worth their per-dispatch cost here). None disables —
    # plan_channels keeps preemption OFF by default for streaming-only
    # groups. Conservative 1 ms: the fitted overhead floor wins below it.
    preempt_target_s: float | None = 1e-3


class RollingFit:
    """Rolling (nbytes, seconds) window + EWMA-weighted least squares.

    Samples carry a wall-clock stamp and expire after ``ttl_s``: a fit must
    never straddle a regime change by pairing old-regime small transfers
    with new-regime large ones — that fits a steep spurious slope instead
    of the new t0/BW."""

    def __init__(self, window: int = 256, ewma_halflife: float = 32,
                 min_size_spread: float = 4.0, ttl_s: float = 5.0):
        self._lock = make_lock("RollingFit._lock")
        self._samples: "collections.deque[tuple[int, float, float]]" = (
            collections.deque(maxlen=window))  # guarded-by: _lock
        self.ewma_halflife = max(float(ewma_halflife), 1.0)
        self.min_size_spread = min_size_spread
        self.ttl_s = float(ttl_s)

    def add(self, nbytes: int, seconds: float) -> None:
        if nbytes <= 0 or seconds <= 0:
            return
        with self._lock:
            self._samples.append((int(nbytes), float(seconds),
                                  time.monotonic()))

    def _fresh(self) -> list[tuple[int, float]]:
        cutoff = time.monotonic() - self.ttl_s
        with self._lock:
            while self._samples and self._samples[0][2] < cutoff:
                self._samples.popleft()
            return [(n, t) for n, t, _ in self._samples]

    def __len__(self) -> int:
        return len(self._fresh())

    @property
    def size_spread(self) -> float:
        ns = [n for n, _ in self._fresh()]
        if not ns:
            return 1.0
        return max(ns) / max(min(ns), 1)

    def fit(self, min_samples: int = 2) -> TransferCostModel | None:
        """Weighted fit of t = t0 + n/BW over the fresh window; ``None``
        when the window is too small or size-degenerate (a single payload
        size cannot separate fixed overhead from per-byte cost — the
        caller should probe)."""
        samples = self._fresh()
        if len(samples) < max(min_samples, 2):
            return None
        ns = np.array([n for n, _ in samples], np.float64)
        ts = np.array([t for _, t in samples], np.float64)
        if ns.max() / max(ns.min(), 1.0) < self.min_size_spread:
            return None
        # newest sample gets weight 1, a sample ``halflife`` entries older
        # gets 1/2 — the drifted regime out-weighs the stale one quickly.
        age = np.arange(len(samples) - 1, -1, -1, dtype=np.float64)
        w = 0.5 ** (age / self.ewma_halflife)
        m = TransferCostModel.fit_weighted(ns, ts, w)
        # a non-positive fitted slope (one stalled small-chunk sample can
        # make small transfers look slower than large ones) gets clamped
        # to an absurd bandwidth by fit_weighted; adopting it would read
        # as enormous fake drift and force a spurious replan. A fitted BW
        # far above anything actually OBSERVED is the same pathology.
        bw_observed = float((ns / ts).max())
        if m.bw_Bps > 50.0 * bw_observed:
            return None
        return m

    # -- warm-start persistence ---------------------------------------------
    def to_state(self) -> dict:
        """Serializable snapshot: samples carry their AGE (monotonic stamps
        don't survive a process), newest last."""
        now = time.monotonic()
        with self._lock:
            return {"samples": [[int(n), float(t), round(now - ts, 6)]
                                for n, t, ts in self._samples]}

    @classmethod
    def from_state(cls, state: dict, *, window: int = 256,
                   ewma_halflife: float = 32, min_size_spread: float = 4.0,
                   ttl_s: float = 5.0, refresh: bool = True) -> "RollingFit":
        """Rebuild a window from :meth:`to_state`. With ``refresh`` (the
        warm-start default) samples are restamped as fresh — the point is
        seeding the NEW session's first fit from the old session's
        steady state, not replaying wall-clock ages that the TTL would
        expire on arrival. Live traffic then out-weighs the seed within a
        halflife."""
        fit = cls(window=window, ewma_halflife=ewma_halflife,
                  min_size_spread=min_size_spread, ttl_s=ttl_s)
        now = time.monotonic()
        for n, t, age in state.get("samples", []):
            stamp = now if refresh else now - float(age)
            fit._samples.append((int(n), float(t), stamp))
        return fit


def choose_management(tx_fits: dict[str, TransferCostModel],
                      payload_bytes: int,
                      current: Management = Management.INTERRUPT,
                      interrupt_extra_t0_s: float = 0.0,
                      batch: float = 1.0
                      ) -> Management:
    """Polling-vs-interrupt crossover from the per-mode TX fits.

    The paper's Fig. 4: the user-level polling driver wins below the
    crossover payload, the kernel interrupt driver above it. With a fit
    for only one mode there is nothing to compare — keep ``current``
    (the mode we're running produces samples, the other mode's window
    empties after its TTL; flipping on missing data would evict a
    measured-good choice for an unmeasured one).

    ``interrupt_extra_t0_s``: queue-wait the interrupt path pays beyond
    its per-descriptor service time — the shared runtime's measured
    per-class dispatch latency under the CURRENT traffic mix. Polling
    never queues, so under contention the crossover moves right (exactly
    the paper's arbitration-overhead term, now measured from real serving
    traces instead of assumed zero).

    ``batch``: observed tx_many/rx_many group size of this stream (EWMA;
    1.0 = singles). A batched group pays the interrupt path's dispatch
    wait ONCE for the whole group, so the per-descriptor extra-t0 is
    amortized by ``batch`` and the crossover moves back LEFT — batching
    makes the interrupt driver win at smaller payloads, the tentpole's
    whole point. The fitted t0 is NOT divided here: batched chunk samples
    already carry amortized per-descriptor times, and dividing again
    would double-count the saving."""
    poll = tx_fits.get(Management.POLLING.value)
    intr = tx_fits.get(Management.INTERRUPT.value)
    if poll is None or intr is None:
        return current
    if interrupt_extra_t0_s > 0.0:
        extra = interrupt_extra_t0_s / max(float(batch), 1.0)
        intr = TransferCostModel(t0_s=intr.t0_s + extra,
                                 bw_Bps=intr.bw_Bps)
    n_star = TransferCostModel.crossover_bytes(poll, intr)
    return Management.POLLING if payload_bytes < n_star else Management.INTERRUPT


class OnlineTransferController:
    """Refit-and-replan logic, separated from transfer plumbing for tests.

    ``record`` ingests logical transfer stats (payload sizing + cadence);
    ``ingest_chunks`` drains per-descriptor samples from engines into the
    per-(direction, mode) :class:`RollingFit` windows; ``propose`` refits
    and returns a new plan only when drift beats the hysteresis."""

    def __init__(self, payload_bytes: int, *,
                 model: TransferCostModel | None = None,
                 cfg: AdaptiveConfig | None = None,
                 device: jax.Device | None = None):
        self.cfg = cfg or AdaptiveConfig()
        # RLock: propose() holds it end-to-end (plan/counter updates must
        # be atomic across concurrent submitters) and calls _fit_for, which
        # also guards the fits dict for the sample-ingestion paths.
        self._lock = make_rlock("OnlineTransferController._lock")
        if model is None:
            model = calibrate_transfer(device)
        self.plan: ChannelPlan = plan_channels(  # guarded-by: _lock
            payload_bytes, model=model, max_channels=self.cfg.max_channels,
            completion_workers=self.cfg.completion_workers,
            preempt_target_s=self.cfg.preempt_target_s)
        # drift references: the per-direction fits the current plan was
        # adopted under. RX gets its own reference — serving decode is
        # RX-dominated, and TX-only drift detection would never see an
        # RX slowdown (the ring/block policy governs both directions).
        self._tx_ref: TransferCostModel = model  # guarded-by: _lock
        self._rx_ref: TransferCostModel | None = None  # guarded-by: _lock
        self._fits: dict[tuple[str, str], RollingFit] = {}  # guarded-by: _lock
        # guarded-by: _lock
        self._payloads: "collections.deque[int]" = collections.deque(maxlen=32)
        self._payloads.append(max(int(payload_bytes), 1))
        self._since_refit = 0  # guarded-by: _lock
        self._has_logical = False  # guarded-by: _lock (stats own cadence)
        # EWMA of the shared runtime's per-class dispatch latency for this
        # stream — the interrupt driver's measured queue-wait, folded into
        # the crossover decision (see choose_management).
        self._dispatch_t0_s = 0.0  # guarded-by: _lock
        # EWMA of the tx_many/rx_many group size observed on this stream
        # (1.0 = singles): the dispatch queue-wait above is paid once per
        # GROUP, so the crossover amortizes it by this factor.
        self._batch_ewma = 1.0  # guarded-by: _lock
        # enforced bytes/s ceiling on this stream's priority class (the
        # runtime's set_class_cap): plans are sized against the EFFECTIVE
        # (post-cap) bandwidth — a capped stream must not chase block/
        # channel choices tuned for throughput it is not allowed to have.
        # Drift detection still runs on the RAW fits (the link itself did
        # not change when an operator set a cap).
        self._bw_cap_Bps: float | None = None  # guarded-by: _lock
        # healthy-channel ceiling from the self-healing layer: when the
        # channel group quarantines rings, plans must be sized for the
        # channels actually in rotation, not the configured maximum —
        # "replan around the reduced channel set". None = no restriction.
        self._channel_limit: int | None = None  # guarded-by: _lock
        # EWMA of the per-segment descriptor-walk cost under grouped (SG /
        # tx_many) submission, refit from live grouped-transaction samples:
        # the pack-vs-SG crossover prices the SG side with this instead of
        # assuming a full t0 per segment. None until the first SG/batched
        # transaction lands.
        self._sg_seg_t0_s: float | None = None  # guarded-by: _lock
        # the seg-t0 value the last memoized pack-vs-SG decisions were
        # priced with; drifting past the hysteresis signals consumers to
        # drop their per-layer-set memos (LayoutCache.invalidate_sg).
        self._sg_ref_seg_t0_s: float | None = None  # guarded-by: _lock
        self.refits = 0  # guarded-by: _lock
        self.replans = 0  # guarded-by: _lock
        self.suppressed = 0  # guarded-by: _lock (hysteresis kept the plan)
        self.needs_probe = False  # guarded-by: _lock

    def _fit_for(self, direction: str, mode: str) -> RollingFit:
        key = (direction, mode)
        with self._lock:
            fit = self._fits.get(key)
            if fit is None:
                fit = self._fits[key] = RollingFit(
                    self.cfg.window, self.cfg.ewma_halflife,
                    self.cfg.min_size_spread, self.cfg.sample_ttl_s)
            return fit

    # -- sample ingestion ---------------------------------------------------
    def record(self, stats: TransferStats) -> None:
        """Observer hook for logical transfers: tracks the payload mix the
        plan should be sized for, and the refit cadence."""
        with self._lock:
            if stats.direction == "tx":
                self._payloads.append(stats.nbytes)
            self._has_logical = True
            self._since_refit += 1

    def add_chunk_sample(self, direction: str, mode: str, nbytes: int,
                         seconds: float) -> None:
        self._fit_for(direction, mode).add(nbytes, seconds)
        with self._lock:
            # chunk arrivals drive the refit cadence ONLY when no logical
            # stats flow (a controller fed samples directly: tests,
            # replayed traces). With live traffic, counting both would
            # refit nearly every transfer — documented cadence is per
            # logical transfer.
            if not self._has_logical:
                self._since_refit += 1

    def ingest_chunks(self, engines: Sequence[TransferEngine]) -> int:
        """Drain every engine's chunk-sample deque into the fit windows."""
        n = 0
        for eng in engines:
            dq = eng.chunk_samples
            while True:
                try:
                    direction, mode, nbytes, seconds = dq.popleft()
                except IndexError:
                    break
                self.add_chunk_sample(direction, mode, nbytes, seconds)
                n += 1
        return n

    def note_dispatch_latency(self, seconds: float,
                              alpha: float = 0.25) -> None:
        """Fold a measured runtime dispatch latency (queue wait before a
        descriptor starts service) into the interrupt-mode effective t0
        used by the crossover decision. EWMA so serving bursts show up
        quickly and idle periods decay back toward zero."""
        if seconds < 0:
            return
        with self._lock:
            self._dispatch_t0_s = ((1 - alpha) * self._dispatch_t0_s
                                   + alpha * float(seconds))

    def note_submit_batch(self, n: int, alpha: float = 0.25) -> None:
        """Fold an observed tx_many/rx_many group size into the batch EWMA
        the crossover amortizes dispatch latency by. Single submits call
        this with 1 (or not at all — the EWMA decays toward 1 only through
        explicit singles, so a steady batched stream keeps its factor)."""
        if n < 1:
            return
        with self._lock:
            self._batch_ewma = ((1 - alpha) * self._batch_ewma
                                + alpha * float(n))

    # -- pack-vs-SG crossover -----------------------------------------------
    def ingest_sg(self, engines: Sequence[TransferEngine]) -> bool:
        """Drain every engine's grouped-transaction samples and refit the
        per-segment walk cost the pack-vs-SG crossover prices with: each
        ``(k, total, wall)`` sample gives ``seg_t0 ~= (wall - t0 -
        total/BW)/k`` against the current plan's fitted model, folded into
        an EWMA. Returns True when the refit cost drifted past the config
        hysteresis since the last True — callers drop their memoized
        per-layer-set decisions (``LayoutCache.invalidate_sg``) then."""
        with self._lock:
            m = self.plan.model
        for eng in engines:
            dq = getattr(eng, "sg_samples", None)
            if dq is None:
                continue
            while True:
                try:
                    _d, k, total, wall = dq.popleft()
                except IndexError:
                    break
                if k <= 1 or wall <= 0.0:
                    continue
                est = max((wall - m.t0_s - total / m.bw_Bps) / k, 1e-7)
                with self._lock:
                    cur = self._sg_seg_t0_s
                    self._sg_seg_t0_s = (est if cur is None
                                         else 0.75 * cur + 0.25 * est)
        with self._lock:
            cur, ref = self._sg_seg_t0_s, self._sg_ref_seg_t0_s
            if cur is None:
                return False
            if ref is not None and max(cur / ref, ref / cur) \
                    < self.cfg.hysteresis:
                return False
            self._sg_ref_seg_t0_s = cur
            return ref is not None  # first fit: nothing memoized yet

    def sg_seg_t0_s(self) -> float | None:
        """Current refit per-segment walk cost (None before any grouped
        transaction landed — consumers fall back to the full t0)."""
        with self._lock:
            return self._sg_seg_t0_s

    def prefer_sg(self, sizes: Sequence[int]) -> bool:
        """Live pack-vs-SG decision for one layer set: prices
        :func:`~repro.core.transfer.choose_sg` with the plan's fitted
        model and the refit per-segment walk cost."""
        with self._lock:
            m = self.plan.model
            seg = self._sg_seg_t0_s
        return choose_sg(sizes, m, seg_t0_s=seg)

    def sg_crossover(self, total_bytes: int) -> float:
        """Segment count where pack starts beating SG for ``total_bytes``,
        under the current fits (the recorded crossover point)."""
        with self._lock:
            m = self.plan.model
            seg = self._sg_seg_t0_s
        return sg_crossover_segments(total_bytes, m, seg_t0_s=seg)

    def set_bandwidth_cap(self, bytes_per_s: float | None) -> None:
        """Tell the planner this stream's class is capped at ``bytes_per_s``
        (None clears). Subsequent :meth:`propose` calls size plans against
        min(fitted BW, cap)."""
        with self._lock:
            self._bw_cap_Bps = (float(bytes_per_s)
                                if bytes_per_s and bytes_per_s > 0 else None)

    # -- self-healing hooks -------------------------------------------------
    @property
    def _max_channels(self) -> int:
        with self._lock:  # reentrant: also read under replan/propose
            limit = self._channel_limit
        if limit is None:
            return self.cfg.max_channels
        return max(1, min(self.cfg.max_channels, limit))

    def set_channel_limit(self, n: int | None) -> None:
        """Bound future plans to ``n`` channels (None clears). Set by the
        facade when the channel group quarantines/releases rings."""
        with self._lock:
            self._channel_limit = None if n is None else max(1, int(n))

    def replan_channels(self, limit: int | None) -> ChannelPlan | None:
        """Immediate channel-count replan for a quarantine transition: keep
        the current fitted model and policy family, rebuild the plan bounded
        to ``limit`` healthy channels. Unlike :meth:`propose` this does not
        wait for refit cadence or drift — losing a ring to quarantine IS the
        event, no hysteresis applies. Returns the new plan, or None when the
        current plan already fits the bound (e.g. polling's single channel,
        or a limit at/above the planned channel count)."""
        with self._lock:
            self.set_channel_limit(limit)
            if self.plan.policy.management is not Management.INTERRUPT:
                return None
            model = self.plan.model
            if (self._bw_cap_Bps is not None
                    and model.bw_Bps > self._bw_cap_Bps):
                model = TransferCostModel(t0_s=model.t0_s,
                                          bw_Bps=self._bw_cap_Bps)
            plan = plan_channels(  # lock-ok: model= given, calibrate unreachable
                self.payload_bytes, model=model,
                max_channels=self._max_channels,
                completion_workers=self.cfg.completion_workers,
                preempt_target_s=self.cfg.preempt_target_s)
            if (plan.policy == self.plan.policy
                    and plan.n_channels == self.plan.n_channels):
                return None
            self.replans += 1
            self.plan = plan
            return plan

    # -- fitted state -------------------------------------------------------
    def models(self) -> dict[tuple[str, str], TransferCostModel]:
        """Latest per-(direction, mode) fits (only windows that can fit)."""
        with self._lock:
            fits = dict(self._fits)
        out = {}
        for key, fit in fits.items():
            m = fit.fit(self.cfg.min_samples)
            if m is not None:
                out[key] = m
        return out

    @property
    def payload_bytes(self) -> int:
        """Plan for the LARGE payloads in the recent mix: striping decisions
        are about the big transfers, not the token-sized ones between."""
        with self._lock:  # reentrant: propose/replan read it under the lock
            return max(self._payloads) if self._payloads else 1

    # -- the decision -------------------------------------------------------
    def propose(self, *, force: bool = False) -> ChannelPlan | None:
        """Refit; return a replacement plan iff t0/BW drifted past the
        hysteresis threshold (or ``force``). ``None`` means: keep flying.

        Holds the controller lock end-to-end: concurrent submitters must
        not interleave plan/counter updates, or ``self.plan`` could end up
        holding a different fit than the plan actually installed."""
        with self._lock:
            if not force and self._since_refit < self.cfg.refit_every:
                return None
            self._since_refit = 0
            mode = self.plan.policy.management.value
            fit = self._fit_for("tx", mode)
            m = fit.fit(self.cfg.min_samples)
            if m is None:
                # window too small or size-degenerate: facade should probe
                self.needs_probe = len(fit) >= self.cfg.min_samples
                return None
            self.needs_probe = False
            self.refits += 1
            rx_m = self._fit_for("rx", mode).fit(self.cfg.min_samples)
            drift = TransferCostModel.drift_ratio(self._tx_ref, m)
            if rx_m is not None:
                if self._rx_ref is None:
                    self._rx_ref = rx_m  # first RX visibility: baseline it
                else:
                    drift = max(drift, TransferCostModel.drift_ratio(
                        self._rx_ref, rx_m))
            if not force and drift < self.cfg.hysteresis:
                self.suppressed += 1
                return None
            payload = self.payload_bytes
            tx_fits = {md: mm for (d, md), mm in self.models().items()
                       if d == "tx"}
            tx_fits.setdefault(mode, m)
            mgmt = choose_management(
                tx_fits, payload, current=self.plan.policy.management,
                interrupt_extra_t0_s=self._dispatch_t0_s,
                batch=self._batch_ewma)
            if mgmt is Management.POLLING:
                # below the crossover the user-level polling driver wins:
                # one channel, one un-partitioned transfer, no worker pool.
                plan = ChannelPlan(n_channels=1,
                                   policy=TransferPolicy.user_level_polling(),
                                   model=tx_fits.get(mgmt.value, m),
                                   payload_bytes=payload)
            else:
                # size the plan from the fit of the mode it will RUN under
                # (flipping polling->interrupt must not size blocks from
                # polling's tiny t0), folded with the RX fit — the ring
                # serves both directions, so plan for the slower one.
                m_tx = tx_fits.get(Management.INTERRUPT.value, m)
                m_plan = m_tx if rx_m is None else TransferCostModel(
                    t0_s=max(m_tx.t0_s, rx_m.t0_s),
                    bw_Bps=min(m_tx.bw_Bps, rx_m.bw_Bps))
                if (self._bw_cap_Bps is not None
                        and m_plan.bw_Bps > self._bw_cap_Bps):
                    # effective (post-cap) bandwidth: the runtime's token
                    # bucket is the binding constraint, not the link fit —
                    # blocks/channels sized past the ceiling would just
                    # queue behind the bucket.
                    m_plan = TransferCostModel(t0_s=m_plan.t0_s,
                                               bw_Bps=self._bw_cap_Bps)
                plan = plan_channels(  # lock-ok: model= given, calibrate unreachable
                    payload, model=m_plan, max_channels=self._max_channels,
                    completion_workers=self.cfg.completion_workers,
                    preempt_target_s=self.cfg.preempt_target_s)
            # adoption (either outcome below) re-baselines drift detection
            # on the fits that produced this decision.
            self._tx_ref = tx_fits.get(plan.policy.management.value, m)
            if rx_m is not None:
                self._rx_ref = rx_m
            if (plan.policy == self.plan.policy
                    and plan.n_channels == self.plan.n_channels):
                # same physical plan, refreshed model: adopt the fit (so
                # future drift is measured against it) but don't swap
                # generations — rebuilding identical rings buys nothing
                # and perturbs traffic.
                self.plan = plan
                self.suppressed += 1
                return None
            self.replans += 1
            self.plan = plan
            return plan

    # -- warm-start persistence ---------------------------------------------
    _STATE_VERSION = 1

    def save(self, path: "str | os.PathLike") -> None:
        """Persist the fitted state (plan, drift references, per-mode fit
        windows) so the NEXT session seeds its first :class:`ChannelPlan`
        from this session's steady state instead of re-calibrating.
        Atomic write (tmp + rename): a crash mid-save never corrupts the
        warm-start file."""
        with self._lock:
            state = {
                "version": self._STATE_VERSION,
                "payload_bytes": self.payload_bytes,
                "plan": _plan_to_state(self.plan),
                "tx_ref": {"t0_s": self._tx_ref.t0_s,
                           "bw_Bps": self._tx_ref.bw_Bps},
                "rx_ref": (None if self._rx_ref is None else
                           {"t0_s": self._rx_ref.t0_s,
                            "bw_Bps": self._rx_ref.bw_Bps}),
                "fits": {f"{d}:{m}": fit.to_state()
                         for (d, m), fit in self._fits.items()},
            }
        path = pathlib.Path(path)
        tmp = path.with_suffix(path.suffix + ".tmp")
        tmp.write_text(json.dumps(state, indent=2) + "\n")
        os.replace(tmp, path)

    @classmethod
    def load(cls, path: "str | os.PathLike", *,
             cfg: AdaptiveConfig | None = None,
             device: jax.Device | None = None) -> "OnlineTransferController":
        """Rebuild a controller from :meth:`save` — NO calibration sweep:
        the saved fit is the model, the saved plan is the first plan, and
        the fit windows are re-seeded (restamped fresh) so the first
        ``propose()`` has data to detect drift against."""
        state = json.loads(pathlib.Path(path).read_text())
        if state.get("version") != cls._STATE_VERSION:
            raise ValueError(
                f"warm-start state version {state.get('version')!r} != "
                f"{cls._STATE_VERSION} ({path})")
        cfg = cfg or AdaptiveConfig()
        model = TransferCostModel(**state["tx_ref"])
        ctl = cls(state["payload_bytes"], model=model, cfg=cfg, device=device)
        ctl.plan = _plan_from_state(state["plan"])
        ctl._tx_ref = model
        ctl._rx_ref = (None if state.get("rx_ref") is None else
                       TransferCostModel(**state["rx_ref"]))
        for key, fstate in state.get("fits", {}).items():
            direction, mode = key.split(":", 1)
            ctl._fits[(direction, mode)] = RollingFit.from_state(
                fstate, window=cfg.window, ewma_halflife=cfg.ewma_halflife,
                min_size_spread=cfg.min_size_spread, ttl_s=cfg.sample_ttl_s)
        return ctl


def _plan_to_state(plan: ChannelPlan) -> dict:
    p = plan.policy
    return {
        "n_channels": plan.n_channels,
        "payload_bytes": plan.payload_bytes,
        "model": {"t0_s": plan.model.t0_s, "bw_Bps": plan.model.bw_Bps},
        "policy": {
            "management": p.management.value,
            "buffering": p.buffering.value,
            "partitioning": p.partitioning.value,
            "block_bytes": p.block_bytes,
            "ring_depth": p.ring_depth,
            "completion_workers": p.completion_workers,
            "preempt_chunk_bytes": p.preempt_chunk_bytes,
        },
    }


def _plan_from_state(state: dict) -> ChannelPlan:
    ps = state["policy"]
    policy = TransferPolicy(
        management=Management(ps["management"]),
        buffering=Buffering(ps["buffering"]),
        partitioning=Partitioning(ps["partitioning"]),
        block_bytes=int(ps["block_bytes"]),
        ring_depth=int(ps["ring_depth"]),
        completion_workers=int(ps["completion_workers"]),
        # absent in pre-cap/preemption state files: those plans ran with
        # whole-chunk dispatch, keep that on warm start.
        preempt_chunk_bytes=int(ps.get("preempt_chunk_bytes", 0)),
    )
    return ChannelPlan(n_channels=int(state["n_channels"]), policy=policy,
                       model=TransferCostModel(**state["model"]),
                       payload_bytes=int(state["payload_bytes"]))


class AdaptiveChannelGroup:
    """Self-tuning transfer engine: a :class:`ChannelGroup` (or, below the
    polling crossover, a bare :class:`TransferEngine`) per plan generation,
    swapped at safe points as the online controller replans.

    Duck-types the engine surface the executors use. Safe-point rule: a new
    generation is installed only when every ticket issued through this
    facade has completed — ring drained, no slots in flight — and the swap
    happens on the *submitting* thread, never on a completion worker (a
    worker closing its own pool would self-deadlock). The layout cache and
    staging pool are facade-owned and survive swaps."""

    def __init__(self, payload_bytes: int, *,
                 cfg: AdaptiveConfig | None = None,
                 model: TransferCostModel | None = None,
                 devices: Sequence[jax.Device] | None = None,
                 pool: StagingPool | None = None,
                 engine_factory: Callable[..., TransferEngine] | None = None,
                 runtime: TransferRuntime | None = None,
                 priority: PriorityClass = PriorityClass.LAYER,
                 state_path: "str | os.PathLike | None" = None,
                 recovery: RecoveryConfig | None = None,
                 fault_state: TransferFaultState | None = None,
                 qos: QosSpec | None = None):
        self.cfg = cfg or AdaptiveConfig()
        self._devices = devices
        self._factory = engine_factory
        self._runtime = runtime
        self.qos = QosSpec(priority=priority).merged(qos)
        self.priority = self.qos.priority
        self.state_path = state_path
        # ONE fault ledger across every plan generation: counters must
        # survive safe-point swaps, or a replan would erase the very
        # fault history that triggered it.
        self.recovery = recovery or RecoveryConfig()
        self.fault_state = fault_state or TransferFaultState()
        self.staging_pool = pool or StagingPool()
        self.layouts = LayoutCache(pool=self.staging_pool)
        # warm start: a previous session's steady-state fit seeds the first
        # plan (no calibration sweep); otherwise calibrate as before. The
        # state file is a CACHE: corrupt, version-mismatched, or sized for
        # a very different payload -> fall back to a cold start, never
        # fail construction over it.
        self.controller = None
        self.warm_started = False
        if (state_path is not None and model is None
                and os.path.exists(state_path)):
            try:
                ctl = OnlineTransferController.load(
                    state_path, cfg=self.cfg,
                    device=devices[0] if devices else None)
                saved = ctl.payload_bytes
                if not (payload_bytes / 4 <= saved <= payload_bytes * 4):
                    raise ValueError(
                        f"saved plan sized for {saved} bytes, caller asked "
                        f"for {payload_bytes} — too far apart to reuse")
                # the new session's payload joins the mix the planner sees
                ctl._payloads.append(max(int(payload_bytes), 1))
                self.controller = ctl
                self.warm_started = True
            except Exception:  # noqa: BLE001 — stale cache, cold-start
                self.controller = None
        if self.controller is None:
            self.controller = OnlineTransferController(
                payload_bytes, model=model, cfg=self.cfg,
                device=devices[0] if devices else None)
        # bounded: one record lands here per logical transfer (per decoded
        # token in serving) — an unbounded list would grow forever in a
        # long-running server and defeat the zero-alloc steady state.
        self._lock = make_lock("AdaptiveChannelGroup._lock")
        self.stats: "collections.deque[TransferStats]" = collections.deque(
            maxlen=4096)  # guarded-by: _lock
        self._outstanding: list[Ticket] = []  # guarded-by: _lock
        # submitters currently between _enter() and their ticket being
        # tracked (or their sync transfer finishing): the swap must also
        # wait these out, or it could close an engine under a submit.
        self._entrants = 0  # guarded-by: _lock
        self._pending_plan: ChannelPlan | None = None  # guarded-by: _lock
        self.generation = 0  # guarded-by: _lock
        self.swaps = 0  # guarded-by: _lock
        self.all_engines: list[TransferEngine] = []  # every generation's
        self._group = self._build(self.controller.plan)

    # -- generation lifecycle ------------------------------------------------
    def _build(self, plan: ChannelPlan):
        if plan.policy.management is Management.INTERRUPT:
            g = ChannelGroup(plan.policy, n_channels=plan.n_channels,
                             devices=self._devices, pool=self.staging_pool,
                             plan=plan, engine_factory=self._factory,
                             layouts=self.layouts, runtime=self._runtime,
                             priority=self.priority,
                             recovery=self.recovery,
                             fault_state=self.fault_state,
                             qos=self.qos)
            engines = list(g.engines)
        else:
            factory = self._factory or TransferEngine
            g = factory(plan.policy,
                        device=self._devices[0] if self._devices else None,
                        runtime=self._runtime, priority=self.priority)
            engines = [g]
        self.all_engines.extend(engines)
        # keep only the most recent generations' engines (diagnostics /
        # invariant checks); retired engines pinned forever would leak
        # their stats lists across many swaps.
        del self.all_engines[:-32]
        g.add_observer(self._on_stats)
        return g

    def _on_stats(self, stats: TransferStats) -> None:
        with self._lock:
            self.stats.append(stats)
        self.controller.record(stats)

    @property
    def plan(self) -> ChannelPlan:
        return self.controller.plan

    @property
    def policy(self) -> TransferPolicy:
        return self._group.policy

    @property
    def n_channels(self) -> int:
        return getattr(self._group, "n_channels", 1)

    @property
    def engines(self) -> list[TransferEngine]:
        return getattr(self._group, "engines", [self._group])

    def close(self) -> None:
        """Idempotent; persists the fitted state first when ``state_path``
        was given (the next session warm-starts from it)."""
        if getattr(self, "_facade_closed", False):
            return
        self._facade_closed = True
        try:
            if self.state_path is not None:
                try:
                    self.save_state(self.state_path)
                except Exception:  # noqa: BLE001 — persistence is
                    pass           # best-effort; teardown must not fail
        finally:
            self._group.close()  # engines MUST deregister even if save blew

    def save_state(self, path: "str | os.PathLike | None" = None) -> None:
        """Persist the controller's fitted state for warm-starting."""
        target = path if path is not None else self.state_path
        if target is None:
            raise ValueError("no state path given")
        self.controller.save(target)

    def __enter__(self) -> "AdaptiveChannelGroup":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # -- adaptation ----------------------------------------------------------
    def _drained(self) -> bool:  # requires-lock: _lock
        """True when nothing issued through the facade is still in flight
        (no live ticket, no submitter mid-issue). Caller must hold the
        lock."""
        assert_held(self._lock, "_drained")
        self._outstanding = [t for t in self._outstanding if not t.complete]
        return not self._outstanding and self._entrants == 0

    def _swap_locked(self) -> None:  # requires-lock: _lock
        """Install the pending generation. Caller holds the lock and has
        verified the drain; runs on a submitting thread only."""
        assert_held(self._lock, "_swap_locked")
        plan, self._pending_plan = self._pending_plan, None
        old = self._group
        self._group = self._build(plan)
        self.generation += 1
        self.swaps += 1
        # a new generation means a new cost world (mode/chunking changed):
        # memoized pack-vs-SG decisions were priced against the old plan.
        self.layouts.invalidate_sg()
        # old generation is fully drained, so close() drain-deregisters
        # immediately; the retired engines permanently reject submits
        # (nothing holds them — the facade now routes to the new build).
        old.close()

    @property
    def runtime(self) -> TransferRuntime | None:
        """The shared runtime the current generation dispatches on."""
        if self._runtime is not None:
            return self._runtime
        return getattr(self._group, "runtime", None)

    def _ingest_dispatch_latency(self) -> None:
        """Feed the runtime's per-class signals into the controller: the
        dispatch latency (the queue wait this stream's completions pay
        under the current traffic mix) shifts the polling/interrupt
        crossover — real serving traces, not an assumed-zero arbitration
        cost; the enforced class cap bounds the bandwidth plans are sized
        for. No recent latency samples means the contention is over:
        decay toward zero instead of holding the burst-era value forever
        (a stale inflated t0 would pin the plan at POLLING long after
        the queue emptied)."""
        rt = self.runtime
        if rt is None:
            return
        lat = rt.recent_dispatch_latency(self.priority)
        self.controller.note_dispatch_latency(lat if lat is not None else 0.0)
        self.controller.set_bandwidth_cap(rt.class_cap(self.priority))

    def set_class_cap(self, cls: "PriorityClass",
                      bytes_per_s: float | None) -> None:
        """Cap one class on the shared runtime. A cap on THIS stream's own
        class also informs the online planner immediately (plans size for
        the effective, post-cap bandwidth)."""
        rt = self.runtime
        if rt is None:
            raise RuntimeError("AdaptiveChannelGroup has no runtime to cap")
        rt.set_class_cap(cls, bytes_per_s)
        if cls is self.priority:
            self.controller.set_bandwidth_cap(bytes_per_s)

    def _ingest_chunks(self) -> None:
        """Drain engine chunk samples into the controller's fit windows —
        but let the group's health tracker PEEK them first (it reads
        non-destructively via ``chunk_seq``; the controller's drain pops).
        Every facade-side drain must go through here, or quarantine drift
        detection would starve."""
        peek = getattr(self._group, "_ingest_health_samples", None)
        if peek is not None:
            # the health windows are guarded by the group's _health_lock
            # (check_channel_health ingests under it too); try-acquire so a
            # concurrent health pass — already ingesting — just wins.
            health_lock = self._group._health_lock
            if health_lock.acquire(blocking=False):
                try:
                    peek()
                finally:
                    health_lock.release()
        self.controller.ingest_chunks(self.engines)
        if self.controller.ingest_sg(self.engines):
            # the per-segment walk cost drifted past hysteresis: memoized
            # per-layer-set pack-vs-SG decisions are stale — re-price.
            self.layouts.invalidate_sg()

    def _check_group_health(self) -> bool:
        """Run the current generation's quarantine/probe health pass; when
        the set of healthy channels changed, replan immediately around the
        reduced (or restored) channel set — losing a ring to quarantine is
        an event, not drift, so no hysteresis applies. Returns True when
        quarantine state changed."""
        g = self._group
        check = getattr(g, "check_channel_health", None)
        if check is None:
            return False  # polling generation: single bare engine
        changed = check()
        if changed:
            n_active = len(g._active_indices())
            plan = self.controller.replan_channels(n_active)
            if plan is not None:
                with self._lock:
                    self._pending_plan = plan
        return changed

    def maybe_adapt(self, *, force: bool = False) -> bool:
        """Refit from the live samples and swap plans if drift warrants it.

        Called from executors at their natural safe points (end of frame /
        batch boundary) — and implicitly before every submit. Health
        (quarantine/probe) runs first: a quarantine transition replans
        around the healthy channel set immediately, ahead of any drift
        decision. Returns True when a new generation was installed."""
        self._ingest_chunks()
        self._ingest_dispatch_latency()
        self._check_group_health()
        with self._lock:
            pending = self._pending_plan is not None
        if not pending:
            plan = self.controller.propose(force=force)
            if plan is not None:
                with self._lock:
                    self._pending_plan = plan
            elif self.controller.needs_probe:
                self._probe()
        with self._lock:
            if self._pending_plan is not None and self._drained():
                self._swap_locked()
                return True
        return False

    def _probe(self) -> None:
        """Uniform traffic can't separate t0 from BW: issue a couple of tiny
        transfers (the paper's packet-size sweep, online and cheap) so the
        window regains size diversity."""
        for nbytes in self.cfg.probe_sizes:
            x = np.zeros(nbytes, np.uint8)
            self._issue_tx(x, None, None).wait()
        self._ingest_chunks()

    # -- engine surface ------------------------------------------------------
    def _resolve_qos(self, where: str, qos: QosSpec | None,
                     priority: PriorityClass | None) -> QosSpec:
        """One facade call's effective submit context (see
        :meth:`TransferEngine._resolve_qos` — same shim, facade default)."""
        spec = resolve_submit_qos(f"{type(self).__name__}.{where}",
                                  qos, priority)
        return self.qos.merged(spec)

    def _enter(self):
        """Per-submit safe-point check: apply a pending swap if the ring is
        drained, then return the engine of the current generation. The
        caller holds an entrant reference until its ticket is tracked (or
        its sync transfer finished) — see :meth:`_leave`."""
        with self._lock:
            pending = self._pending_plan is not None
        if not pending:
            self._ingest_chunks()
            plan = self.controller.propose()
            if plan is not None:
                with self._lock:
                    self._pending_plan = plan
        with self._lock:
            if self._pending_plan is not None and self._drained():
                self._swap_locked()
            self._entrants += 1
            return self._group

    def _leave(self, ticket: Ticket | None) -> None:
        with self._lock:
            self._entrants -= 1
            self._outstanding = [t for t in self._outstanding
                                 if not t.complete]
            if ticket is not None:
                self._outstanding.append(ticket)

    def _leave_many(self, tickets: "Sequence[Ticket] | None") -> None:
        # batched variant of _leave: every per-descriptor ticket of the
        # group pins the current generation until it resolves (a swap must
        # never rebuild rings under an in-flight batch).
        with self._lock:
            self._entrants -= 1
            self._outstanding = [t for t in self._outstanding
                                 if not t.complete]
            if tickets:
                self._outstanding.extend(t for t in tickets
                                         if t is not None)

    @staticmethod
    def _done_ticket(result: list) -> Ticket:
        ev = threading.Event()
        ev.set()
        return Ticket(ev, [result])

    def _issue_tx(self, arr: np.ndarray,
                  callback: Callable[[list], None] | None,
                  layout: StagedLayout | None,
                  qos: QosSpec | None = None) -> Ticket:
        eng = self._enter()
        ticket = None
        try:
            if eng.policy.management is Management.INTERRUPT:
                ticket = eng.tx_async(arr, callback=callback, layout=layout,
                                      qos=qos)
                return ticket
            # polling generation: the submit IS the transfer (the paper's
            # user-level driver blocks the host); hand back a done ticket.
            chunks = eng.tx(np.asarray(arr))
            if callback is not None:
                callback(chunks)
            return self._done_ticket(chunks)
        finally:
            self._leave(ticket)

    def tx_async(self, host_array: np.ndarray,
                 callback: Callable[[list], None] | None = None,
                 layout: StagedLayout | None = None,
                 priority: PriorityClass | None = None, *,
                 qos: QosSpec | None = None) -> Ticket:
        spec = self._resolve_qos("tx_async", qos, priority)
        return self._issue_tx(host_array, callback, layout, qos=spec)

    def tx(self, host_array: np.ndarray,
           priority: PriorityClass | None = None, *,
           qos: QosSpec | None = None) -> list[jax.Array]:
        spec = self._resolve_qos("tx", qos, priority)
        return self.tx_async(host_array, qos=spec).wait()

    def rx_async(self, device_arrays: Sequence[jax.Array],
                 callback: Callable[[list], None] | None = None,
                 out: "np.ndarray | Sequence[np.ndarray] | None" = None,
                 priority: PriorityClass | None = None, *,
                 qos: QosSpec | None = None
                 ) -> Ticket:
        spec = self._resolve_qos("rx_async", qos, priority)
        eng = self._enter()
        ticket = None
        try:
            if eng.policy.management is Management.INTERRUPT:
                ticket = eng.rx_async(device_arrays, callback=callback,
                                      out=out, qos=spec)
                return ticket
            arrays = list(device_arrays)
            if out is not None and isinstance(out, np.ndarray):
                # bare engines take per-array buffers; carve the flat array
                out = carve_flat_out(out, arrays)
            results = eng.rx(arrays, out=out)
            if callback is not None:
                callback(results)
            return self._done_ticket(results)
        finally:
            self._leave(ticket)

    def rx(self, device_arrays: Sequence[jax.Array],
           out: "np.ndarray | Sequence[np.ndarray] | None" = None,
           priority: PriorityClass | None = None, *,
           qos: QosSpec | None = None
           ) -> list[np.ndarray]:
        spec = self._resolve_qos("rx", qos, priority)
        return self.rx_async(device_arrays, out=out, qos=spec).wait()

    # -- batched descriptor submission ---------------------------------------
    def tx_many(self, host_arrays: "Sequence[np.ndarray]",
                priority: PriorityClass | None = None, *,
                qos: QosSpec | None = None) -> list[Ticket]:
        """Batched TX through the current generation; the observed group
        size feeds the controller's batch EWMA so the polling/interrupt
        crossover prices batched dispatch correctly. On a polling
        generation each submit IS the transfer (done tickets)."""
        spec = self._resolve_qos("tx_many", qos, priority)
        grp = self._enter()
        tickets = None
        try:
            if grp.policy.management is Management.INTERRUPT:
                tickets = grp.tx_many(host_arrays, qos=spec)
                self.controller.note_submit_batch(len(tickets))
                return tickets
            done = []
            for a in host_arrays:
                chunks = grp.tx(np.asarray(a))
                done.append(self._done_ticket(
                    chunks[0] if len(chunks) == 1 else chunks))
            return done
        finally:
            self._leave_many(tickets)

    def rx_many(self, device_arrays: Sequence[jax.Array],
                out: "np.ndarray | Sequence[np.ndarray] | None" = None,
                priority: PriorityClass | None = None, *,
                qos: QosSpec | None = None) -> list[Ticket]:
        """Batched RX through the current generation (see :meth:`tx_many`);
        ``out`` keeps the flat-carve / per-array zero-copy contract."""
        spec = self._resolve_qos("rx_many", qos, priority)
        grp = self._enter()
        tickets = None
        try:
            if grp.policy.management is Management.INTERRUPT:
                tickets = grp.rx_many(device_arrays, out=out, qos=spec)
                self.controller.note_submit_batch(len(tickets))
                return tickets
            arrays = list(device_arrays)
            if out is not None and isinstance(out, np.ndarray):
                out = carve_flat_out(out, arrays)
            results = grp.rx(arrays, out=out)
            return [self._done_ticket(r) for r in results]
        finally:
            self._leave_many(tickets)

    # -- scatter-gather ------------------------------------------------------
    def prefer_sg(self, sizes: "Sequence[int]") -> bool:
        """Pack-vs-SG decision priced against the CURRENT fitted plan plus
        the live per-segment walk estimate (see the controller)."""
        return self.controller.prefer_sg(list(sizes))

    def tx_sg(self, segments: Sequence,
              priority: PriorityClass | None = None, *,
              qos: QosSpec | None = None) -> SGTicket:
        """Scatter-gather TX through the current generation: one logical
        transfer over the segment list, zero staging copy. On a polling
        generation each segment IS transferred inline (done tickets)."""
        spec = self._resolve_qos("tx_sg", qos, priority)
        grp = self._enter()
        sg = None
        try:
            if (grp.policy.management is Management.INTERRUPT
                    and hasattr(grp, "tx_sg")):
                sg = grp.tx_sg(segments, qos=spec)
                self.controller.note_submit_batch(len(sg))
                return sg
            views, _sizes = _sg_segment_views(segments, "tx")
            done = []
            for v in views:
                chunks = grp.tx(v)
                flat = reassemble_chunks(chunks)
                done.append(self._done_ticket(flat.reshape(v.shape)))
            return SGTicket(done)
        finally:
            self._leave_many(sg.tickets if sg is not None else None)

    def rx_sg(self, segments: Sequence,
              out: "np.ndarray | Sequence[np.ndarray] | None" = None,
              priority: PriorityClass | None = None, *,
              qos: QosSpec | None = None) -> SGTicket:
        """Scatter-gather RX (see :meth:`tx_sg`); ``out`` keeps the
        flat-carve / per-segment zero-copy contract."""
        spec = self._resolve_qos("rx_sg", qos, priority)
        grp = self._enter()
        sg = None
        try:
            if (grp.policy.management is Management.INTERRUPT
                    and hasattr(grp, "rx_sg")):
                sg = grp.rx_sg(segments, out=out, qos=spec)
                self.controller.note_submit_batch(len(sg))
                return sg
            views, _sizes = _sg_segment_views(segments, "rx")
            outs = out
            if out is not None and isinstance(out, np.ndarray):
                outs = carve_flat_out(out, views)
            results = grp.rx(views, out=outs)
            return SGTicket([self._done_ticket(r) for r in results])
        finally:
            self._leave_many(sg.tickets if sg is not None else None)

    # -- reporting -----------------------------------------------------------
    def summary(self) -> dict[str, dict[str, float]]:
        with self._lock:
            stats = list(self.stats)
        tx = [s for s in stats if s.direction == "tx"]
        rx = [s for s in stats if s.direction == "rx"]

        def agg(ss):
            if not ss:
                return {"us_per_byte": float("nan"), "gbps": float("nan")}
            tot_b = sum(s.nbytes for s in ss)
            tot_t = sum(s.wall_s for s in ss)
            return {"us_per_byte": tot_t * 1e6 / max(tot_b, 1),
                    "gbps": tot_b / max(tot_t, 1e-12) / 1e9}

        return {"tx": agg(tx), "rx": agg(rx)}

    def adapt_summary(self) -> dict[str, Any]:
        """Controller state for benchmarks/ROADMAP reporting."""
        c = self.controller
        with self._lock:
            generation, swaps = self.generation, self.swaps
        with c._lock:
            return {
                "generation": generation,
                "swaps": swaps,
                "refits": c.refits,
                "replans": c.replans,
                "suppressed": c.suppressed,
                "plan": c.plan.row(),
                "channel_limit": c._channel_limit,
            }

    def fault_summary(self) -> dict[str, Any]:
        """The shared fault ledger plus the CURRENT generation's quarantine
        set (the ledger spans generations; the set is per-group)."""
        return {
            "faults": self.fault_state.summary(),
            "quarantined": sorted(getattr(self._group, "quarantined", ())),
        }
