"""Multi-channel transfer rings + cost-model-adaptive policy selection.

The paper's single AXI-DMA engine tops out well below the bus limit; NEURAghe
and ZynqNet both reach peak PS<->PL throughput only by spreading one logical
stream across *multiple* DMA channels and sizing blocks to the measured
fixed-overhead/per-byte crossover. This module is that lesson at host<->device
scale:

:class:`ChannelGroup`
    Shards one logical TX/RX across N :class:`~repro.core.transfer.
    TransferEngine` descriptor rings ("channels"). TX stripes the flat
    payload into N contiguous byte ranges (bytes-balanced, zero-copy views)
    and issues them concurrently, one ring per channel; RX spreads device
    arrays over the channels greedily by byte load. Chunk order is preserved
    (stripes are contiguous and concatenated in channel order), so
    :func:`~repro.core.transfer.reassemble_chunks` and
    :meth:`~repro.core.transfer.StagedLayout.unpack` work unchanged — a
    ChannelGroup duck-types a TransferEngine everywhere the executors care
    (``policy`` / ``layouts`` / ``tx`` / ``rx`` / ``tx_async`` / ``rx_async``
    / ``close`` / ``summary``). All channels target one device by default —
    stripes must share a device to be concatenated back into one array —
    and two engines on one CPU device still win: each owns a
    completion-worker pool, so two stripes memcpy concurrently. Pass
    ``devices=`` explicitly to stripe across distinct devices (consumers
    must then be device-aware).

:class:`StagingPool`
    Size-classed free list of staging buffers shared by every channel's
    :class:`~repro.core.transfer.LayoutCache`, so striped
    :class:`~repro.core.transfer.StagedLayout` slots recycle allocations on
    shape changes instead of reallocating per frame.

:func:`calibrate_transfer` / :func:`plan_channels`
    The adaptive policy chooser: a short TX sweep at construction fits the
    paper's two-parameter model ``t(n) = t0 + n/BW``
    (:class:`~repro.core.cost_model.TransferCostModel`), and the plan derives
    ``block_bytes`` (the t0*BW crossover), ``ring_depth`` (enough slots to
    cover the stripe) and the channel count (stripe only while each stripe
    still amortizes its fixed overhead) instead of static policy constants.
    :meth:`ChannelGroup.auto` wires the whole thing together.
"""

from __future__ import annotations

import collections
import math
import os
import threading
import time
from dataclasses import dataclass
from typing import Any, Callable, Sequence

import jax
import numpy as np

from repro.analysis.validated import assert_held, make_lock
from repro.core.cost_model import TransferCostModel
from repro.core.faults import RecoveryConfig
from repro.core.qos import QosSpec, resolve_submit_qos
from repro.core.runtime import (
    PriorityClass,
    TransferChecksumError,
    TransferFaultError,
    TransferRuntime,
    TransferTimeoutError,
)
from repro.core.transfer import (
    Buffering,
    LayoutCache,
    Management,
    Partitioning,
    SGTicket,
    StagedLayout,
    Ticket,
    TransferEngine,
    TransferPolicy,
    TransferStats,
    _STATS_WINDOW,
    _check_out,
    _sg_segment_views,
    carve_flat_out,
)
from repro.dist.fault import TransferFaultState


class _IndexTicket(Ticket):
    """Per-segment view over one striped scatter-gather join: all segments
    share the joiner's master event/result, each ticket projecting out its
    own ordered slot. A post-retry join failure surfaces on every segment
    (the group already retried the faulted share on siblings)."""

    def __init__(self, done: threading.Event, out: list, index: int):
        super().__init__(done, out)
        self._index = index

    def wait(self, timeout: float | None = None) -> Any:
        return super().wait(timeout)[self._index]

_MIN_STRIPE_BYTES = 1 << 20  # below this a second channel costs more than t0
_CAL_SIZES = (16 << 10, 128 << 10, 1 << 20, 8 << 20)
_OVERHEAD_AMORT = 8.0  # a stripe must be worth >= this many t0's of wire time


# ---------------------------------------------------------------------------
# Shared staging-buffer pool
# ---------------------------------------------------------------------------

class StagingPool:
    """Size-classed (power-of-two) free list of reusable staging buffers.

    Shared across the layout caches of a :class:`ChannelGroup` so a layout
    eviction (shape change between frames) returns its buffer for the next
    layout of a similar size instead of hitting the allocator."""

    def __init__(self) -> None:
        self._lock = make_lock("StagingPool._lock")
        self._free: dict[int, list[np.ndarray]] = {}  # guarded-by: _lock
        self.allocations = 0                          # guarded-by: _lock
        self.reuses = 0                               # guarded-by: _lock

    @staticmethod
    def _size_class(nbytes: int) -> int:
        return 1 << max(12, int(nbytes - 1).bit_length())

    def acquire(self, nbytes: int) -> np.ndarray:
        sc = self._size_class(max(nbytes, 1))
        with self._lock:
            lst = self._free.get(sc)
            if lst:
                self.reuses += 1
                return lst.pop()
            self.allocations += 1
        return np.empty(sc, np.uint8)

    def release(self, buf: np.ndarray) -> None:
        with self._lock:
            self._free.setdefault(buf.nbytes, []).append(buf)


# ---------------------------------------------------------------------------
# Adaptive policy chooser
# ---------------------------------------------------------------------------

def calibrate_transfer(device: jax.Device | None = None,
                       sizes: Sequence[int] = _CAL_SIZES,
                       repeats: int = 3) -> TransferCostModel:
    """Short calibration sweep: measure TX at a few payload sizes and fit
    ``t(n) = t0 + n/BW``. Runs once at group construction (~tens of ms).

    Under load the samples can come back non-monotonic and the least-squares
    slope degenerates (bw blows past any physical link). When that happens,
    fall back to the two-point estimate: bandwidth from the largest sample
    (t0 folded in, so it *under*-estimates — safe for planning) and overhead
    from the smallest."""
    device = device or jax.devices()[0]
    ns, ts = [], []
    for nbytes in sizes:
        x = np.empty(nbytes, np.uint8)
        best = float("inf")
        for _ in range(max(1, repeats)):
            t0 = time.perf_counter()
            jax.device_put(x, device).block_until_ready()
            best = min(best, time.perf_counter() - t0)
        ns.append(nbytes)
        ts.append(best)
    model = TransferCostModel.fit(np.asarray(ns, np.float64),
                                  np.asarray(ts, np.float64))
    bw_direct = ns[-1] / max(ts[-1], 1e-9)
    if model.bw_Bps > 10.0 * bw_direct or model.t0_s >= 0.5 * ts[-1]:
        t0_direct = max(ts[0] - ns[0] / bw_direct, 1e-7)
        model = TransferCostModel(t0_s=t0_direct, bw_Bps=bw_direct)
    return model


@dataclass(frozen=True)
class ChannelPlan:
    """Fitted policy point: what the cost model chose and why."""

    n_channels: int
    policy: TransferPolicy
    model: TransferCostModel
    payload_bytes: int

    @property
    def tag(self) -> str:
        return f"adaptive-{self.n_channels}ch-{self.policy.tag}"

    def row(self) -> dict:
        """BENCH-friendly summary of the fitted choice."""
        return {
            "n_channels": self.n_channels,
            "block_bytes": self.policy.block_bytes,
            "ring_depth": self.policy.depth,
            "partitioning": self.policy.partitioning.value,
            "preempt_chunk_bytes": self.policy.preempt_chunk_bytes,
            "fit_t0_us": round(self.model.t0_s * 1e6, 3),
            "fit_gbps": round(self.model.bw_Bps / 1e9, 3),
            "payload_bytes": self.payload_bytes,
        }


def plan_channels(payload_bytes: int, *,
                  model: TransferCostModel | None = None,
                  device: jax.Device | None = None,
                  max_channels: int = 4,
                  min_stripe_bytes: int = _MIN_STRIPE_BYTES,
                  completion_workers: int = 2,
                  preempt_target_s: float | None = None) -> ChannelPlan:
    """Pick channel count / ring depth / block size from the fitted model.

    - channel count: stripe as wide as ``max_channels`` allows while (a)
      the host has a copy engine (core) per channel — channels beyond that
      just thrash the scheduler, the NEURAghe rule of one stream per HP
      port — and (b) each stripe's wire time still amortizes the fixed
      overhead (``stripe/BW >= _OVERHEAD_AMORT * t0``) and stays >= the
      minimum stripe;
    - block size: at least the ``t0*BW`` crossover (the paper's 'longer
      enough packets' criterion), and large enough that a stripe splits
      into only ~2x``completion_workers`` chunks — enough chunks to
      double-buffer every worker, few enough to amortize per-chunk setup;
    - ring depth: enough slots to cover the stripe's chunk count, clamped
      to [2, 8] (depth 1 forfeits overlap; past ~8 slots buy nothing but
      staging memory);
    - preemptive chunking: with ``preempt_target_s`` set, chunks carry a
      fitted segment size so the shared runtime can yield mid-chunk to
      latency traffic within roughly that service bound. Default OFF:
      every extra segment pays a real per-dispatch cost, which a
      streaming-only workload (no latency classes sharing the runtime)
      would pay for nothing — mixed-traffic consumers (AdaptiveConfig /
      serving) opt in.
    """
    if model is None:
        model = calibrate_transfer(device)
    payload_bytes = max(int(payload_bytes), 1)
    amortized = model.bw_Bps * model.t0_s * _OVERHEAD_AMORT
    n = min(
        max_channels,
        max(1, os.cpu_count() or 1),
        max(1, int(payload_bytes / max(amortized, 1.0))),
        max(1, payload_bytes // max(min_stripe_bytes, 1)),
    )
    stripe = math.ceil(payload_bytes / n)
    target_chunks = 2 * max(1, completion_workers)
    block = max(model.optimal_block_bytes(stripe),
                math.ceil(stripe / target_chunks))
    n_chunks = math.ceil(stripe / block)
    # preemptive chunked dispatch: size the runtime's mid-chunk yield
    # granularity from the same fit (bounded per-segment service time),
    # so a TOKEN arrival never waits out a whole block_bytes memcpy.
    preempt = (model.preempt_chunk_bytes(preempt_target_s)
               if preempt_target_s else 0)
    if n_chunks <= 1:
        policy = TransferPolicy(Management.INTERRUPT, Buffering.RING,
                                Partitioning.UNIQUE, block_bytes=block,
                                ring_depth=2,
                                completion_workers=completion_workers,
                                preempt_chunk_bytes=preempt)
    else:
        depth = max(2, min(8, n_chunks))
        policy = TransferPolicy(Management.INTERRUPT, Buffering.RING,
                                Partitioning.BLOCKS, block_bytes=block,
                                ring_depth=depth,
                                completion_workers=completion_workers,
                                preempt_chunk_bytes=preempt)
    return ChannelPlan(n_channels=n, policy=policy, model=model,
                       payload_bytes=payload_bytes)


# ---------------------------------------------------------------------------
# The channel group
# ---------------------------------------------------------------------------

class ChannelGroup:
    """N descriptor-ring engines serving one logical transfer stream.

    Duck-types :class:`TransferEngine` for the executors: same ``policy`` /
    ``layouts`` / ``tx`` / ``rx`` / ``tx_async`` / ``rx_async`` / ``close``
    surface, with payloads striped across the member rings."""

    def __init__(self, policy: TransferPolicy | None = None, *,
                 n_channels: int = 2,
                 devices: Sequence[jax.Device] | None = None,
                 pool: StagingPool | None = None,
                 min_stripe_bytes: int = _MIN_STRIPE_BYTES,
                 plan: ChannelPlan | None = None,
                 engine_factory: Callable[..., TransferEngine] | None = None,
                 layouts: LayoutCache | None = None,
                 runtime: TransferRuntime | None = None,
                 priority: PriorityClass = PriorityClass.LAYER,
                 recovery: RecoveryConfig | None = None,
                 fault_state: TransferFaultState | None = None,
                 qos: QosSpec | None = None):
        policy = policy or TransferPolicy.kernel_level_ring()
        if policy.management is not Management.INTERRUPT:
            raise ValueError(
                "ChannelGroup stripes via tx_async/rx_async and therefore "
                f"requires INTERRUPT management (got {policy.tag})")
        if n_channels < 1:
            raise ValueError(f"n_channels must be >= 1, got {n_channels}")
        if devices is None:
            # all channels target ONE device by default: consumers
            # concatenate the striped chunks into a single array
            # (reassemble_chunks / StagedLayout.unpack), which requires the
            # chunks to share a device. This is the multi-channel-DMA-on-
            # one-port analogue. Striping across distinct devices needs an
            # explicit ``devices=`` and device-aware consumers.
            devices = [jax.devices()[0]] * n_channels
        self.policy = policy
        self.plan = plan
        self.n_channels = n_channels
        self.min_stripe_bytes = max(int(min_stripe_bytes), 1)
        self.staging_pool = pool or StagingPool()
        # ``layouts`` may be handed in so plan generations (the online
        # adaptive controller rebuilds the group on drift) keep their cached
        # staging layouts instead of re-deriving every pack plan.
        self.layouts = layouts or LayoutCache(pool=self.staging_pool)
        # ``engine_factory`` builds each member ring; tests and the drift
        # benchmark inject engines with synthetic timing through it. ALL
        # stripes share one runtime (None = the process default): striping
        # multiplies channels, never completion pools.
        self.qos = QosSpec(priority=priority).merged(qos)
        self.priority = self.qos.priority
        self._runtime = runtime
        factory = engine_factory or TransferEngine
        # factories keep the narrow (policy, device, runtime, priority)
        # signature — per-call qos= carries the rest down at submit time.
        self.engines = [factory(policy, device=d, runtime=runtime,
                                priority=self.priority) for d in devices]
        self._closed = False
        # bounded recent history (see TransferEngine.stats); aggregate
        # totals live on the member engines' counters.
        self._stats_lock = make_lock("ChannelGroup._stats_lock")
        self.stats: "collections.deque[TransferStats]" = collections.deque(
            maxlen=_STATS_WINDOW)          # guarded-by: _stats_lock
        self._observers: list[Callable[[TransferStats], None]] = \
            []                             # guarded-by: _stats_lock
        # round-robin cursor for sub-stripe payloads
        self._rr = 0                       # guarded-by: _stats_lock
        self._joiners: list[threading.Thread] = []  # guarded-by: _stats_lock
        # -- self-healing state (PR 6) ---------------------------------------
        # ``fault_state`` may be handed in so an adaptive facade's plan
        # generations share ONE ledger across safe-point swaps.
        self.recovery = recovery or RecoveryConfig()
        self.fault_state = fault_state or TransferFaultState()
        self._quarantined: set[int] = set()        # guarded-by: _stats_lock
        self._consec_faults = [0] * n_channels     # guarded-by: _stats_lock
        self._health_lock = make_lock("ChannelGroup._health_lock")
        # per-channel descriptor-health windows, fed by PEEKING each
        # engine's chunk_samples via its monotone chunk_seq (the refit
        # consumer pops the same deque destructively — we must not race
        # it for samples, only read the tail it has not yet consumed).
        self._health_seen = [0] * n_channels       # guarded-by: _health_lock
        self._health: list["collections.deque[tuple[int, float]]"] = [
            collections.deque(maxlen=64)
            for _ in range(n_channels)]            # guarded-by: _health_lock
        self._probe_stamp = [float("-inf")] * n_channels  # guarded-by: _health_lock

    # -- lifecycle ----------------------------------------------------------
    @classmethod
    def auto(cls, payload_bytes: int, *,
             max_channels: int = 4,
             devices: Sequence[jax.Device] | None = None,
             model: TransferCostModel | None = None,
             pool: StagingPool | None = None,
             engine_factory: Callable[..., TransferEngine] | None = None,
             runtime: TransferRuntime | None = None,
             priority: PriorityClass = PriorityClass.LAYER,
             recovery: RecoveryConfig | None = None,
             fault_state: TransferFaultState | None = None
             ) -> "ChannelGroup":
        """Calibrate, fit, and build the group the cost model recommends."""
        device = devices[0] if devices else None
        plan = plan_channels(payload_bytes, model=model, device=device,
                             max_channels=max_channels)
        return cls(plan.policy, n_channels=plan.n_channels, devices=devices,
                   pool=pool, plan=plan, engine_factory=engine_factory,
                   runtime=runtime, priority=priority, recovery=recovery,
                   fault_state=fault_state)

    def close(self, timeout: float = 5.0) -> None:
        """Idempotent: joiners first (they wait on engine tickets, which
        need live runtime workers), then member engines deregister. The
        whole drain respects ``timeout`` per stage — a wedged descriptor
        is cancelled, never waited on forever."""
        if self._closed:
            return
        self._closed = True
        with self._stats_lock:
            joiners, self._joiners = self._joiners, []
        for t in joiners:
            t.join(timeout=timeout)
        for eng in self.engines:
            eng.close(timeout)

    @property
    def runtime(self) -> TransferRuntime | None:
        """The (shared) runtime the member engines dispatch on."""
        if self._runtime is not None:
            return self._runtime
        for eng in self.engines:
            rt = getattr(eng, "runtime", None)
            if rt is not None:
                return rt
        return None

    def __enter__(self) -> "ChannelGroup":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def maybe_adapt(self, *, force: bool = False) -> bool:
        """Safe-point hook. A plain group's PLAN is fixed at construction,
        but its channel-health machinery still runs here: drift detection
        (a silently degraded channel is pulled from the stripe rotation)
        and probe-based un-quarantine. Returns True when the active channel
        set changed (AdaptiveChannelGroup extends this with replanning)."""
        return self.check_channel_health()

    # -- channel quarantine (self-healing) -----------------------------------
    @property
    def quarantined(self) -> set[int]:
        """Channel indices currently pulled from the stripe rotation."""
        with self._stats_lock:
            return set(self._quarantined)

    def _active_indices(self) -> list[int]:
        with self._stats_lock:
            act = [i for i in range(self.n_channels)
                   if i not in self._quarantined]
        return act or list(range(self.n_channels))  # never zero channels

    def _resolve_qos(self, where: str, qos: QosSpec | None,
                     priority: PriorityClass | None) -> QosSpec:
        """One group call's effective submit context (see
        :meth:`TransferEngine._resolve_qos` — same shim, group default)."""
        spec = resolve_submit_qos(f"{type(self).__name__}.{where}",
                                  qos, priority)
        return self.qos.merged(spec)

    def _note_runtime_fault(self, tenant: str | None = None,
                            **counts) -> None:
        rt = self.runtime
        if rt is not None:
            rt.note_fault(self.priority, tenant=tenant, **counts)

    def _note_fault(self, ch: int, err: BaseException,
                    tenant: str | None = None) -> None:
        """Attribute one fault to channel ``ch`` (and to ``tenant`` when
        the stripe carried one); quarantine the channel after
        ``recovery.quarantine_after`` consecutive faults (never the last
        active channel — a degraded channel beats no channel)."""
        self.fault_state.record_fault(
            ch, timeout=isinstance(err, TransferTimeoutError),
            checksum=isinstance(err, TransferChecksumError),
            tenant=tenant)
        self._note_runtime_fault(
            tenant=tenant,
            faults=1, timeouts=int(isinstance(err, TransferTimeoutError)))
        quarantined = False
        with self._stats_lock:
            self._consec_faults[ch] += 1
            if (self._consec_faults[ch] >= self.recovery.quarantine_after
                    and ch not in self._quarantined
                    and len(self._quarantined) < self.n_channels - 1):
                self._quarantined.add(ch)
                quarantined = True
        if quarantined:
            self.fault_state.record_quarantine(ch, on=True, tenant=tenant)
            self._note_runtime_fault(tenant=tenant, quarantines=1)

    def _note_success(self, ch: int) -> None:
        with self._stats_lock:
            self._consec_faults[ch] = 0

    def _sibling_for_retry(self, ch: int) -> int | None:
        """An active channel other than ``ch`` to resubmit a failed stripe
        on (round-robin over the healthy set); None when ``ch`` is the
        only channel left."""
        with self._stats_lock:
            cands = [i for i in range(self.n_channels)
                     if i != ch and i not in self._quarantined]
            if not cands:
                return None
            self._rr += 1
            return cands[self._rr % len(cands)]

    # requires-lock: _health_lock
    def _ingest_health_samples(self) -> None:
        """Peek each engine's NEW chunk samples (chunk_seq-delimited tail;
        never pops — the adaptive refit consumer owns the destructive
        read) into the per-channel health windows."""
        assert_held(self._health_lock, "_ingest_health_samples")
        for i, eng in enumerate(self.engines):
            seq = getattr(eng, "chunk_seq", None)
            if seq is None:
                continue
            new = seq - self._health_seen[i]
            if new <= 0:
                continue
            self._health_seen[i] = seq
            tail = list(eng.chunk_samples)[-new:]
            for (_d, _m, nbytes, dt) in tail:
                if nbytes > 0:
                    self._health[i].append((nbytes, dt))

    @staticmethod
    def _median_s_per_b(window: "collections.deque[tuple[int, float]]"
                        ) -> float | None:
        if not window:
            return None
        rates = sorted(dt / nb for nb, dt in window)
        return rates[len(rates) // 2]

    def check_channel_health(self) -> bool:
        """Drift quarantine + probe-based un-quarantine. Median seconds/
        byte per channel over recent descriptors, compared to the healthy
        group's median — deliberately NOT the RollingFit t0/BW fit, whose
        size-spread gate goes degenerate under uniform chunk sizes (the
        steady state of striped traffic). Returns True when the active
        channel set changed."""
        rec = self.recovery
        if not self._health_lock.acquire(blocking=False):
            return False  # another safe point is already running checks
        try:
            changed = False
            if rec.drift_quarantine_ratio is not None:
                changed |= self._drift_check()
            changed |= self._probe_quarantined()
            return changed
        finally:
            self._health_lock.release()

    def _drift_check(self) -> bool:  # requires-lock: _health_lock
        rec = self.recovery
        self._ingest_health_samples()
        with self._stats_lock:
            active = [i for i in range(self.n_channels)
                      if i not in self._quarantined]
        medians = {i: self._median_s_per_b(self._health[i]) for i in active
                   if len(self._health[i]) >= rec.health_min_samples}
        if len(medians) < 2:
            return False  # nothing to compare against
        group = sorted(medians.values())[len(medians) // 2]
        if group <= 0:
            return False
        changed = False
        for i, m in medians.items():
            if m / group < rec.drift_quarantine_ratio:
                continue
            with self._stats_lock:
                if (i in self._quarantined
                        or len(self._quarantined) >= self.n_channels - 1):
                    continue
                self._quarantined.add(i)
                self._consec_faults[i] = 0
            self.fault_state.record_quarantine(i, on=True)
            self._note_runtime_fault(quarantines=1)
            changed = True
        return changed

    # requires-lock: _health_lock
    def _probe_quarantined(self) -> bool:
        """Issue a small bounded probe TX on each quarantined channel (rate
        limited); a probe that completes at a healthy rate returns the
        channel to the stripe rotation."""
        assert_held(self._health_lock, "_probe_quarantined")
        rec = self.recovery
        now = time.monotonic()
        with self._stats_lock:
            due = [i for i in sorted(self._quarantined)
                   if now - self._probe_stamp[i] >= rec.probe_interval_s]
        changed = False
        for i in due:
            self._probe_stamp[i] = time.monotonic()
            eng = self.engines[i]
            payload = np.zeros(max(rec.probe_bytes, 1), np.uint8)
            wait_s = rec.stripe_timeout_s or 1.0
            t0 = time.perf_counter()
            try:
                eng.tx_async(payload).wait(wait_s)  # lock-ok: _health_lock is a non-blocking
                # try-acquire exclusion guard; submitters never contend on it
            except BaseException:
                continue  # still sick: stays quarantined
            probe_s = time.perf_counter() - t0
            # a completing probe is necessary but not sufficient: a merely
            # SLOW channel (the stall fault) completes probes too. Race the
            # IDENTICAL payload on a healthy sibling — same size, same t0
            # share — so the comparison is apples-to-apples (a chunk-median
            # baseline would unfairly penalize the probe's fixed overhead).
            with self._stats_lock:
                active = [j for j in range(self.n_channels)
                          if j not in self._quarantined]
                rr = self._rr
            if active and rec.drift_quarantine_ratio is not None:
                ref = self.engines[active[rr % len(active)]]
                t0 = time.perf_counter()
                try:
                    ref.tx_async(payload).wait(wait_s)  # lock-ok: see probe above
                    ref_s = time.perf_counter() - t0
                except BaseException:  # sibling flaked: skip the rate gate
                    ref_s = None
                if (ref_s is not None and ref_s > 0
                        and probe_s / ref_s >= rec.drift_quarantine_ratio):
                    continue  # completed, but still drifted: stay out
            with self._stats_lock:
                self._quarantined.discard(i)
                self._consec_faults[i] = 0
                self._health[i].clear()  # stale sick-era samples must not
                # immediately re-trip the drift check
            self.fault_state.record_quarantine(i, on=False)
            changed = True
        return changed

    def set_class_cap(self, cls: PriorityClass,
                      bytes_per_s: float | None) -> None:
        """Per-class bandwidth cap on the SHARED runtime every member ring
        dispatches on (one cap covers all stripes — striping multiplies
        channels, never bandwidth budgets)."""
        rt = self.runtime
        if rt is None:
            raise RuntimeError("ChannelGroup has no runtime to cap")
        rt.set_class_cap(cls, bytes_per_s)

    # -- bookkeeping ---------------------------------------------------------
    @property
    def tag(self) -> str:
        return f"{self.n_channels}ch-{self.policy.tag}"

    @property
    def max_inflight(self) -> int:
        return max((e.max_inflight for e in self.engines), default=0)

    def add_observer(self, fn: Callable[[TransferStats], None]) -> None:
        """Subscribe to every group-level recorded stat (the refit feed)."""
        with self._stats_lock:
            self._observers.append(fn)

    def _record(self, stats: TransferStats) -> None:
        if not stats.management:
            stats.management = self.policy.management.value
        with self._stats_lock:
            self.stats.append(stats)
            observers = list(self._observers)
        for fn in observers:
            fn(stats)

    def _next_channel(self) -> TransferEngine:
        with self._stats_lock:
            act = [i for i in range(self.n_channels)
                   if i not in self._quarantined] or list(
                       range(self.n_channels))
            eng = self.engines[act[self._rr % len(act)]]
            self._rr += 1
        return eng

    def _delegated(self, direction: str, nbytes: int, n_items: int,
                   callback: Callable[[list], None] | None):
        """Completion callback for single-channel (sub-stripe) transfers:
        records a group-level stat so ``summary()`` sees small transfers
        too, then chains the caller's callback."""
        t0 = time.perf_counter()

        def cb(results: list) -> None:
            self._record(TransferStats(nbytes, time.perf_counter() - t0,
                                       n_items, direction, self.tag))
            if callback is not None:
                callback(results)

        return cb

    # -- striping ------------------------------------------------------------
    def _stripes(self, flat: np.ndarray,
                 n_channels: int | None = None) -> list[np.ndarray]:
        """Contiguous, bytes-balanced element ranges of ``flat`` — views, so
        striping itself copies nothing. Payloads below 2 minimum stripes use
        a single channel (a second channel would cost more than its t0).
        ``n_channels`` bounds the stripe count (the ACTIVE channel count —
        quarantined channels take no stripes)."""
        n = n_channels if n_channels is not None else self.n_channels
        if flat.nbytes >= 2 * self.min_stripe_bytes:
            n = min(n, max(1, flat.nbytes // self.min_stripe_bytes))
        else:
            n = 1
        if n == 1:
            return [flat]
        return [s for s in np.array_split(flat, n) if s.size]

    def _run_stripe(self, issue_fn: Callable[[TransferEngine], Ticket],
                    ch: int, tenant: str | None = None) -> Any:
        """Issue one stripe on channel ``ch``, wait (bounded by
        ``recovery.stripe_timeout_s``), and on a retryable fault resubmit
        on a sibling channel up to ``recovery.max_retries`` times.

        Only :class:`~repro.core.runtime.TransferFaultError` retries
        (injected faults, checksum mismatches, timeouts); structural
        errors (closed engine, bad payload) surface immediately. A
        timed-out original attempt may still be in service — safe, because
        a faulted descriptor never lands payload bytes (drops raise before
        the copy) and a merely-slow duplicate lands the same bytes."""
        wait_s = self.recovery.stripe_timeout_s
        attempt = 0
        while True:
            try:
                result = issue_fn(self.engines[ch]).wait(wait_s)
            except TransferFaultError as e:
                self._note_fault(ch, e, tenant=tenant)
                if attempt > 0:
                    self.fault_state.record_retry(success=False,
                                                  tenant=tenant)
                    self._note_runtime_fault(tenant=tenant, retries=1)
                sibling = self._sibling_for_retry(ch)
                if attempt >= self.recovery.max_retries or sibling is None:
                    raise
                attempt += 1
                ch = sibling
                continue
            self._note_success(ch)
            if attempt > 0:
                self.fault_state.record_retry(success=True, tenant=tenant)
                self._note_runtime_fault(tenant=tenant, retries=1)
            return result

    def _join(self, issue: list[Callable[[TransferEngine], Ticket]],
              channels: list[int],
              assemble: Callable[[list], list],
              direction: str, nbytes: int, n_items: int,
              master: threading.Event, ticket_out: list,
              callback: Callable[[list], None] | None,
              t0: float, tenant: str | None = None) -> None:
        """Coordinator: issue every stripe's transfer from its OWN thread
        (a full ring back-pressures its submitter, so issuing serially from
        one thread would serialize the channels), wait bounded, retry
        faulted stripes on siblings, then reassemble in stripe order."""
        n = len(issue)
        per_channel: list = [None] * n
        errs: list = [None] * n

        def run_one(i: int) -> None:
            try:
                per_channel[i] = self._run_stripe(issue[i], channels[i],
                                                  tenant=tenant)
            except BaseException as e:  # noqa: BLE001 — surfaced at wait()
                errs[i] = e

        runners = [threading.Thread(target=run_one, args=(i,), daemon=True)
                   for i in range(1, n)]
        for t in runners:
            t.start()
        run_one(0)
        for t in runners:
            t.join()

        err: BaseException | None = next(
            (e for e in errs if e is not None), None)
        if err is not None:
            ticket_out.append(err)
        else:
            results = assemble(per_channel)
            self._record(TransferStats(nbytes, time.perf_counter() - t0,
                                       n_items, direction, self.tag))
            ticket_out.append(results)
            if callback is not None:
                try:
                    callback(results)
                except BaseException as e:  # noqa: BLE001
                    ticket_out[0] = e
        master.set()

    def _spawn_joiner(self, issue, channels, assemble, direction, nbytes,
                      n_items, master, ticket_out, callback, t0,
                      tenant: str | None = None) -> None:
        # a few short-lived threads per *striped* transfer (~50 us spawn vs
        # the >= 2*min_stripe_bytes transfer they issue/join); sub-stripe
        # traffic takes the delegated path and never pays this.
        t = threading.Thread(
            target=self._join,
            args=(issue, channels, assemble, direction, nbytes, n_items,
                  master, ticket_out, callback, t0, tenant),
            daemon=True,
        )
        with self._stats_lock:
            self._joiners = [j for j in self._joiners if j.is_alive()]
            self._joiners.append(t)
        t.start()

    # -- TX -------------------------------------------------------------------
    def tx_async(self, host_array: np.ndarray,
                 callback: Callable[[list], None] | None = None,
                 layout: StagedLayout | None = None,
                 priority: PriorityClass | None = None, *,
                 qos: QosSpec | None = None) -> Ticket:
        """Striped asynchronous TX: each stripe rides its own channel's ring.

        The combined ticket completes when every channel drained; ``layout``
        (when given) is marked busy for the whole group transfer before any
        descriptor is submitted."""
        spec = self._resolve_qos("tx_async", qos, priority)
        arr = np.asarray(host_array)
        flat = arr.reshape(-1)
        active = self._active_indices()  # quarantined rings take no stripes
        stripes = self._stripes(flat, len(active))
        if len(stripes) == 1:
            # sub-stripe payload: no striping win — round-robin the channels
            # so concurrent small transfers (serving tokens) still spread.
            return self._next_channel().tx_async(
                flat, callback=self._delegated("tx", int(arr.nbytes), 1,
                                               callback),
                layout=layout, qos=spec)
        master = threading.Event()
        ticket_out: list = []
        t0 = time.perf_counter()
        if layout is not None:
            layout._busy = master  # busy BEFORE submit (whole-group window)
        # engine-parameterized issue closures: the joiner issues stripe i on
        # channels[i] first and may RE-issue it on a sibling after a fault.
        issue = [lambda eng, s=s: eng.tx_async(s, qos=spec)
                 for s in stripes]
        channels = active[:len(stripes)]

        def assemble(per_channel: list) -> list:
            # stripes are contiguous in stripe order: concatenating the
            # chunk lists reproduces the flat payload for reassemble_chunks.
            out: list = []
            for chunks in per_channel:
                out.extend(chunks)
            return out

        self._spawn_joiner(issue, channels, assemble, "tx", int(arr.nbytes),
                           len(stripes), master, ticket_out, callback, t0,
                           tenant=spec.tenant)
        return Ticket(master, ticket_out)

    def tx(self, host_array: np.ndarray,
           priority: PriorityClass | None = None, *,
           qos: QosSpec | None = None) -> list[jax.Array]:
        """Synchronous striped TX; returns the ordered device chunk list."""
        spec = self._resolve_qos("tx", qos, priority)
        return self.tx_async(host_array, qos=spec).wait()

    # -- RX -------------------------------------------------------------------
    def _rx_outs(self, arrays: list,
                 out: "np.ndarray | Sequence[np.ndarray] | None") -> list:
        """Normalise ``out=`` to one caller-owned buffer per device array.

        Accepts either a sequence of per-array buffers or ONE flat
        preallocated array covering the whole payload — the latter is carved
        into per-array byte-range views (zero-copy), so striped ordered
        reassembly lands each channel's result directly in the caller's
        array at its final offset."""
        if out is None:
            return [None] * len(arrays)
        if isinstance(out, np.ndarray):
            return carve_flat_out(out, arrays)
        # per-array buffers: validate count/writability/contiguity/sizes UP
        # FRONT — a bad list failing mid-stripe on an issuer thread would
        # surface as an opaque error after other channels already wrote.
        return _check_out(arrays, out)

    def rx_async(self, device_arrays: Sequence[jax.Array],
                 callback: Callable[[list], None] | None = None,
                 out: "np.ndarray | Sequence[np.ndarray] | None" = None,
                 priority: PriorityClass | None = None, *,
                 qos: QosSpec | None = None
                 ) -> Ticket:
        """Striped asynchronous RX: arrays spread over channels greedily by
        byte load; results come back in the original order.

        ``out``: caller-owned destination — per-array buffers or one flat
        array for the whole payload. Channels write their stripes straight
        into it; the ticket yields the caller's buffers (or the flat
        array's byte views), never fresh allocations."""
        spec = self._resolve_qos("rx_async", qos, priority)
        arrays = list(device_arrays)
        outs = self._rx_outs(arrays, out)
        nbytes = sum(int(a.size) * a.dtype.itemsize for a in arrays)
        if len(arrays) <= 1 or nbytes < 2 * self.min_stripe_bytes:
            return self._next_channel().rx_async(
                arrays, callback=self._delegated("rx", nbytes, len(arrays),
                                                 callback),
                out=outs if out is not None else None, qos=spec)
        # greedy least-loaded assignment over the ACTIVE channels
        # (bytes-balanced striping; quarantined rings take no stripes)
        active = self._active_indices()
        assign: list[list[int]] = [[] for _ in active]
        loads = [0] * len(active)
        for i, a in enumerate(arrays):
            c = min(range(len(active)), key=loads.__getitem__)
            assign[c].append(i)
            loads[c] += int(a.size) * a.dtype.itemsize
        master = threading.Event()
        ticket_out: list = []
        t0 = time.perf_counter()
        used = [(active[c], idxs) for c, idxs in enumerate(assign) if idxs]
        issue = [lambda eng, idxs=idxs: eng.rx_async(
            [arrays[i] for i in idxs],
            out=([outs[i] for i in idxs] if out is not None else None),
            qos=spec)
            for _c, idxs in used]
        channels = [c for c, _idxs in used]

        def assemble(per_channel: list) -> list:
            results: list = [None] * len(arrays)
            for (_, idxs), ch_out in zip(used, per_channel):
                for i, o in zip(idxs, ch_out):
                    results[i] = o
            return results

        self._spawn_joiner(issue, channels, assemble, "rx", nbytes,
                           len(arrays), master, ticket_out, callback, t0,
                           tenant=spec.tenant)
        return Ticket(master, ticket_out)

    def rx(self, device_arrays: Sequence[jax.Array],
           out: "np.ndarray | Sequence[np.ndarray] | None" = None,
           priority: PriorityClass | None = None, *,
           qos: QosSpec | None = None
           ) -> list[np.ndarray]:
        """Synchronous striped RX; host arrays in the original order. With
        ``out=`` the results land in the caller's preallocated buffers."""
        spec = self._resolve_qos("rx", qos, priority)
        return self.rx_async(device_arrays, out=out, qos=spec).wait()

    # -- batched descriptor submission ----------------------------------------
    def tx_many(self, host_arrays: Sequence[np.ndarray],
                priority: PriorityClass | None = None, *,
                qos: QosSpec | None = None) -> list[Ticket]:
        """Batched TX through the group: the K logical descriptors are
        round-robin partitioned over the ACTIVE channels and each channel's
        share goes down as ONE ring transaction (``TransferEngine.
        tx_many``); tickets come back in input order. Unlike the striped
        paths there is no sibling-retry here — a per-descriptor fault
        surfaces on its own ticket (the batch amortization contract is
        exactly-once submission); byte accounting lands on the per-channel
        engines."""
        spec = self._resolve_qos("tx_many", qos, priority)
        arrays = [np.asarray(a) for a in host_arrays]
        active = self._active_indices()
        if len(arrays) <= 1 or len(active) <= 1:
            return self._next_channel().tx_many(arrays, qos=spec)
        tickets: list[Ticket | None] = [None] * len(arrays)
        for c, ch in enumerate(active):
            idxs = list(range(c, len(arrays), len(active)))
            if not idxs:
                continue
            sub = self.engines[ch].tx_many([arrays[i] for i in idxs],
                                           qos=spec)
            for i, t in zip(idxs, sub):
                tickets[i] = t
        return tickets  # type: ignore[return-value]

    def rx_many(self, device_arrays: Sequence[jax.Array],
                out: "np.ndarray | Sequence[np.ndarray] | None" = None,
                priority: PriorityClass | None = None, *,
                qos: QosSpec | None = None) -> list[Ticket]:
        """Batched RX through the group, mirroring :meth:`tx_many`.
        ``out`` accepts per-array buffers or ONE flat array carved into
        per-descriptor views (zero-copy), exactly like :meth:`rx_async`."""
        spec = self._resolve_qos("rx_many", qos, priority)
        arrays = list(device_arrays)
        outs = self._rx_outs(arrays, out)
        active = self._active_indices()
        if len(arrays) <= 1 or len(active) <= 1:
            return self._next_channel().rx_many(
                arrays, out=outs if out is not None else None,
                qos=spec)
        tickets: list[Ticket | None] = [None] * len(arrays)
        for c, ch in enumerate(active):
            idxs = list(range(c, len(arrays), len(active)))
            if not idxs:
                continue
            sub = self.engines[ch].rx_many(
                [arrays[i] for i in idxs],
                out=([outs[i] for i in idxs] if out is not None else None),
                qos=spec)
            for i, t in zip(idxs, sub):
                tickets[i] = t
        return tickets  # type: ignore[return-value]

    # -- scatter-gather --------------------------------------------------------
    def prefer_sg(self, sizes: Sequence[int],
                  model: Any | None = None) -> bool:
        """Pack-vs-SG decision for the group: priced by the first ACTIVE
        channel's engine (all channels share the policy, so one engine's
        fit speaks for the group)."""
        active = self._active_indices()
        return self.engines[active[0] if active else 0].prefer_sg(
            sizes, model)

    def _sg_assign(self, sizes: list[int],
                   active: list[int]) -> list[tuple[int, list[int]]]:
        """Greedy least-loaded assignment of segments to ACTIVE channels —
        bytes-balanced at SEGMENT granularity; a segment never splits
        (splitting would reintroduce the partial-copy the SG form exists
        to avoid). Returns ``(channel, segment_indices)`` pairs."""
        assign: list[list[int]] = [[] for _ in active]
        loads = [0] * len(active)
        for i, nb in enumerate(sizes):
            c = min(range(len(active)), key=loads.__getitem__)
            assign[c].append(i)
            loads[c] += nb
        return [(active[c], idxs) for c, idxs in enumerate(assign) if idxs]

    def tx_sg(self, segments: Sequence,
              priority: PriorityClass | None = None, *,
              qos: QosSpec | None = None) -> SGTicket:
        """Scatter-gather TX through the group: the segment list is spread
        over the ACTIVE channels by byte load and each channel's share goes
        down as ONE ring slot (its engine's ``tx_sg``), zero staging copy.
        Results come back in the original segment order; a faulted share
        retries whole on a sibling channel (the striped-recovery contract),
        so striping and quarantine compose with the SG form."""
        spec = self._resolve_qos("tx_sg", qos, priority)
        views, sizes = _sg_segment_views(segments, "tx")
        active = self._active_indices()
        total = sum(sizes)
        if (len(views) <= 1 or len(active) <= 1
                or total < 2 * self.min_stripe_bytes):
            # sub-stripe or single-channel: delegate the whole chain —
            # round-robin keeps concurrent small SG submits spread.
            return self._next_channel().tx_sg(views, qos=spec)
        used = self._sg_assign(sizes, active)
        master = threading.Event()
        ticket_out: list = []
        t0 = time.perf_counter()
        issue = [lambda eng, idxs=idxs: eng.tx_sg(
            [views[i] for i in idxs], qos=spec)
            for _c, idxs in used]
        channels = [c for c, _idxs in used]

        def assemble(per_channel: list) -> list:
            results: list = [None] * len(views)
            for (_, idxs), ch_out in zip(used, per_channel):
                for i, o in zip(idxs, ch_out):
                    results[i] = o
            return results

        self._spawn_joiner(issue, channels, assemble, "tx", total,
                           len(views), master, ticket_out, None, t0,
                           tenant=spec.tenant)
        return SGTicket([_IndexTicket(master, ticket_out, i)
                         for i in range(len(views))])

    def rx_sg(self, segments: Sequence,
              out: "np.ndarray | Sequence[np.ndarray] | None" = None,
              priority: PriorityClass | None = None, *,
              qos: QosSpec | None = None) -> SGTicket:
        """Scatter-gather RX through the group (see :meth:`tx_sg`); ``out``
        accepts per-segment buffers or ONE flat array carved into
        per-segment views (zero-copy), exactly like :meth:`rx_async`."""
        spec = self._resolve_qos("rx_sg", qos, priority)
        views, sizes = _sg_segment_views(segments, "rx")
        outs = self._rx_outs(views, out)
        active = self._active_indices()
        total = sum(sizes)
        if (len(views) <= 1 or len(active) <= 1
                or total < 2 * self.min_stripe_bytes):
            return self._next_channel().rx_sg(
                views, out=outs if out is not None else None,
                qos=spec)
        used = self._sg_assign(sizes, active)
        master = threading.Event()
        ticket_out: list = []
        t0 = time.perf_counter()
        issue = [lambda eng, idxs=idxs: eng.rx_sg(
            [views[i] for i in idxs],
            out=([outs[i] for i in idxs] if out is not None else None),
            qos=spec)
            for _c, idxs in used]
        channels = [c for c, _idxs in used]

        def assemble(per_channel: list) -> list:
            results: list = [None] * len(views)
            for (_, idxs), ch_out in zip(used, per_channel):
                for i, o in zip(idxs, ch_out):
                    results[i] = o
            return results

        self._spawn_joiner(issue, channels, assemble, "rx", total,
                           len(views), master, ticket_out, None, t0,
                           tenant=spec.tenant)
        return SGTicket([_IndexTicket(master, ticket_out, i)
                         for i in range(len(views))])

    # -- reporting ------------------------------------------------------------
    def summary(self) -> dict[str, dict[str, float]]:
        # snapshot under the lock: stripe joiners append records
        # concurrently and deque iteration is not atomic vs appends
        with self._stats_lock:
            records = list(self.stats)
        tx = [s for s in records if s.direction == "tx"]
        rx = [s for s in records if s.direction == "rx"]

        def agg(ss):
            if not ss:
                return {"us_per_byte": float("nan"), "gbps": float("nan")}
            tot_b = sum(s.nbytes for s in ss)
            tot_t = sum(s.wall_s for s in ss)
            return {"us_per_byte": tot_t * 1e6 / max(tot_b, 1),
                    "gbps": tot_b / max(tot_t, 1e-12) / 1e9}

        return {"tx": agg(tx), "rx": agg(rx),
                "faults": self.fault_state.summary(),
                "quarantined": sorted(self.quarantined)}

    def fault_summary(self) -> dict[str, object]:
        """The group's fault ledger + current quarantine set (the uniform
        fault surface shared with AdaptiveChannelGroup / ServingEngine)."""
        return {"faults": self.fault_state.summary(),
                "quarantined": sorted(self.quarantined)}
