"""Blocks-mode collectives: chunked, compute-overlapped rings.

The chip<->chip incarnation of the paper's BLOCKS + DOUBLE-buffer idea.
A monolithic ``all_gather`` ('Unique mode') serialises: all communication,
then all compute. Decomposing it into a ``ppermute`` ring of N-1 chunk steps
('Blocks mode') lets the matmul on chunk k overlap the transfer of chunk
k+1 — on TPU the async collective-permute engine runs concurrently with the
MXU, so the steady state is max(compute, comm) per chunk instead of
compute+comm. Same structure for reduce-scatter (the RX direction).

These run inside ``shard_map`` over the 'model' (and 'pod') axes. The paper's
TX/RX-balance concern (DDR can't read+write at once) maps to ICI: gather and
scatter chunks share links, so ``overlapped_matmul_ag``/``_rs`` interleave
them one chunk apart rather than back-to-back.

All functions have pure-jnp semantics equal to the unchunked collective —
property-tested in tests/test_collectives.py.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax import lax

from repro.utils.jax_compat import axis_size as _axis_size  # noqa: F401
from repro.utils.jax_compat import pvary


def ring_all_gather(x: jax.Array, axis_name: str, *, axis: int = 0) -> jax.Array:
    """All-gather via an N-1 step ppermute ring (blocks mode).

    Equivalent to ``lax.all_gather(x, axis_name, axis=axis, tiled=True)``."""
    n = _axis_size(axis_name)
    if n == 1:
        return x
    idx = lax.axis_index(axis_name)
    perm = [(i, (i + 1) % n) for i in range(n)]

    def step(block, _):
        nxt = lax.ppermute(block, axis_name, perm)
        return nxt, nxt

    _, blocks = lax.scan(step, x, None, length=n - 1)
    # blocks[j] holds the shard of rank (idx - 1 - j) mod n; assemble in rank order.
    all_blocks = jnp.concatenate([x[None], blocks], axis=0)  # [n, *x.shape]
    src = (idx - jnp.arange(n)) % n  # all_blocks[j] came from rank src[j]
    order = jnp.argsort(src)
    ordered = jnp.take(all_blocks, order, axis=0)
    return _merge_leading(ordered, axis)


def ring_reduce_scatter(x: jax.Array, axis_name: str, *, axis: int = 0) -> jax.Array:
    """Reduce-scatter (sum) via an N-1 step ring.

    Equivalent to ``lax.psum_scatter(x, axis_name, scatter_dimension=axis,
    tiled=True)``."""
    n = _axis_size(axis_name)
    if n == 1:
        return x
    idx = lax.axis_index(axis_name)
    if x.shape[axis] % n:
        raise ValueError(f"dim {axis} ({x.shape[axis]}) not divisible by {n}")
    chunks = _split_dim(x, axis, n)  # [n, ...] leading chunk index

    # Ring reduce-scatter: at step s, rank i sends its running partial for
    # chunk (i - s - 1) mod n to rank i+1 (the partial created at rank i at
    # s=0 is destined for chunk (i-1), i.e. rank i-1, which it reaches after
    # the n-1 hops). Each hop adds the local contribution for the chunk the
    # partial is destined for; after the last hop rank i holds the full sum
    # of chunk i minus its own contribution, added at the end.
    perm = [(i, (i + 1) % n) for i in range(n)]

    def step(acc, s):
        c = (idx - s - 1) % n
        acc = acc + jnp.take(chunks, c, axis=0)
        return lax.ppermute(acc, axis_name, perm), None

    acc = jnp.zeros_like(jnp.take(chunks, 0, axis=0))
    acc, _ = lax.scan(step, acc, jnp.arange(n - 1))
    return acc + jnp.take(chunks, idx, axis=0)


def overlapped_matmul_ag(
    x: jax.Array,
    w: jax.Array,
    axis_name: str,
    *,
    contract_sharded: bool = False,
) -> jax.Array:
    """y = all_gather(x) @ w, with the gather chunked and overlapped.

    x: [m_local, k] shard (gather along rows); w: [k, n] local weights.
    Each ring step matmuls the chunk that just arrived while the next chunk
    is in flight — XLA schedules the ppermute DMA concurrently with the dot.
    Unique-mode reference: ``lax.all_gather(x, axis, tiled=True) @ w``."""
    n = _axis_size(axis_name)
    if n == 1:
        return x @ w
    idx = lax.axis_index(axis_name)
    m_local = x.shape[0]
    out = jnp.zeros((n * m_local,) + (w.shape[-1],), _dot_dtype(x, w))
    out = pvary(out, (axis_name,))  # mark carry as axis-varying for scan
    perm = [(i, (i + 1) % n) for i in range(n)]

    def step(carry, s):
        block, out = carry
        src = (idx - s) % n  # rank whose shard we currently hold
        nxt = lax.ppermute(block, axis_name, perm)  # comm for step s+1 ...
        out = lax.dynamic_update_slice_in_dim(
            out, (block @ w).astype(out.dtype), src * m_local, axis=0
        )  # ... overlaps this dot
        return (nxt, out), None

    (_, out), _ = lax.scan(step, (x, out), jnp.arange(n))
    return out


def overlapped_matmul_rs(
    x: jax.Array,
    w: jax.Array,
    axis_name: str,
) -> jax.Array:
    """y = reduce_scatter(x @ w) with the scatter chunked and overlapped.

    x: [m, k_local]; w: [k_local, n]. Each rank computes its partial product
    in row-chunks; partials ride the ring accumulating, so the ppermute of
    chunk j overlaps the dot producing chunk j+1. Result: rows m/n per rank,
    summed over the axis. Unique-mode reference:
    ``lax.psum_scatter(x @ w, axis, scatter_dimension=0, tiled=True)``."""
    n = _axis_size(axis_name)
    if n == 1:
        return x @ w
    idx = lax.axis_index(axis_name)
    m = x.shape[0]
    if m % n:
        raise ValueError(f"rows {m} not divisible by axis size {n}")
    mc = m // n
    perm = [(i, (i + 1) % n) for i in range(n)]

    def chunk_dot(c):
        return lax.dynamic_slice_in_dim(x, c * mc, mc, axis=0) @ w

    # Same ring schedule as ring_reduce_scatter, but each rank *computes*
    # its chunk partial just-in-time: the dot producing the partial for
    # step s+1 overlaps the ppermute of step s on real hardware.
    def step(acc, s):
        c = (idx - s - 1) % n  # chunk this traveling partial is destined for
        acc = acc + chunk_dot(c).astype(acc.dtype)
        return lax.ppermute(acc, axis_name, perm), None

    acc = pvary(jnp.zeros((mc, w.shape[-1]), _dot_dtype(x, w)), (axis_name,))
    acc, _ = lax.scan(step, acc, jnp.arange(n - 1))
    return (acc + chunk_dot(idx)).astype(_dot_dtype(x, w))


def _dot_dtype(a: jax.Array, b: jax.Array):
    return jnp.result_type(a.dtype, b.dtype)


def _split_dim(x: jax.Array, axis: int, n: int) -> jax.Array:
    shape = x.shape
    new = shape[:axis] + (n, shape[axis] // n) + shape[axis + 1 :]
    return jnp.moveaxis(x.reshape(new), axis, 0)


def _merge_leading(x: jax.Array, axis: int) -> jax.Array:
    # x: [n, ...]; concatenate leading dim into `axis` of the remainder.
    x = jnp.moveaxis(x, 0, axis)
    shape = x.shape
    return x.reshape(shape[:axis] + (shape[axis] * shape[axis + 1],) + shape[axis + 2 :])
