"""Deterministic fault injection for the transfer stack.

The paper's kernel-level driver argument is a *safety* argument: interrupt
management exists so the OS survives a misbehaving bus while still
scheduling frame collection. This module is the misbehaving bus. It is one
half of the fault story, and the split is deliberate:

- **Injection (this module)** — :class:`FaultInjector` wraps
  :class:`~repro.core.transfer.TransferEngine` through the existing
  ``engine_factory`` seam of :class:`~repro.core.channels.ChannelGroup` /
  :class:`~repro.core.adaptive.AdaptiveChannelGroup`, so faults appear
  exactly where a real flaky DMA channel would: inside ``_one`` (the
  descriptor body) and at submit time. A :class:`FaultPlan` (seed +
  :class:`FaultSpec` schedule) makes every run reproducible: per-channel
  RNG streams and op counters mean the injected (channel, op, kind)
  sequence depends only on the seed and the workload, never on thread
  interleaving across channels. The injector knows NOTHING about
  recovery.
- **Recovery (the production stack)** — bounded ticket waits and the
  runtime timeout scan live in ``repro.core.runtime`` / ``transfer``;
  retry-on-sibling, quarantine and probe-based un-quarantine live in
  ``repro.core.channels`` (tuned by :class:`RecoveryConfig`); replanning
  around a reduced channel set lives in ``repro.core.adaptive``. None of
  it imports this module's injection machinery — production code paths
  heal real faults the same way they heal injected ones.

Fault kinds (:class:`FaultSpec.kind`):

``delay``
    completion held ``delay_s`` before the payload moves (late IRQ).
``drop``
    descriptor held ``hold_s`` then *fails* without ever moving the
    payload — the repro of a completion that never fires. Bounded on
    purpose: an unboundedly-stuck in-service descriptor is the one fault
    no software layer can unstick (see
    :meth:`~repro.core.runtime.TransferRuntime.scan_timeouts`); real
    recovery comes from the caller's bounded wait + sibling retry, which
    this models faithfully. An RX drop never writes the caller's buffer.
``submit_error``
    transient :class:`InjectedFault` raised at submit time (bus NAK).
``corrupt``
    the landed RX payload is bit-flipped (caught by
    ``TransferPolicy.checksum``). RX only — never mutates device-side
    state in place.
``stall``
    every op on the channel slows by ``stall_s`` while active — the
    silently-degraded channel the quarantine machinery exists for.
    :meth:`FaultInjector.stall` toggles a manual stall for benchmarks.
"""

from __future__ import annotations

import random
import time
from dataclasses import dataclass
from typing import Any

import numpy as np

from repro.analysis.validated import assert_held, make_lock
from repro.core.runtime import TransferFaultError
from repro.core.transfer import TransferEngine

_KINDS = ("delay", "drop", "submit_error", "corrupt", "stall")


class InjectedFault(TransferFaultError):
    """The error a ``drop``/``submit_error`` injection surfaces as.

    Subclasses :class:`~repro.core.runtime.TransferFaultError`, so the
    channel layer's retry predicate treats injected faults exactly like
    organic ones."""


@dataclass(frozen=True)
class FaultSpec:
    """One scheduled fault pattern. All matching specs fire per op (an op
    is one descriptor body execution on one channel)."""

    kind: str
    p: float = 1.0                 # per-op injection probability
    channel: int | None = None     # restrict to one channel (None = any)
    direction: str | None = None   # "tx" / "rx" / None = both
    after_ops: int = 0             # channel warms up this many ops first
    max_injections: int | None = None  # cap total firings of this spec
    delay_s: float = 0.05          # ``delay``: completion held this long
    hold_s: float = 0.25           # ``drop``: held this long, then fails
    stall_s: float = 0.02          # ``stall``: per-op slowdown

    def __post_init__(self) -> None:
        if self.kind not in _KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; "
                             f"one of {_KINDS}")
        if not 0.0 <= self.p <= 1.0:
            raise ValueError(f"p must be in [0, 1], got {self.p}")
        if self.direction not in (None, "tx", "rx"):
            raise ValueError(f"direction must be tx/rx/None, "
                             f"got {self.direction!r}")
        if self.kind == "corrupt":
            if self.direction == "tx":
                raise ValueError("corrupt is RX-only (verified at the RX "
                                 "landing; TX corruption would mutate "
                                 "device-side state)")
            # pin the direction so a direction-agnostic spec never burns
            # a max_injections draw on a TX op where corruption is a no-op
            object.__setattr__(self, "direction", "rx")


@dataclass(frozen=True)
class FaultPlan:
    """Seeded, reproducible fault schedule: same seed + same workload →
    identical (channel, op, kind) event sequence."""

    seed: int = 0
    specs: tuple[FaultSpec, ...] = ()

    def __post_init__(self) -> None:
        object.__setattr__(self, "specs", tuple(self.specs))


class FaultInjector:
    """Installs a :class:`FaultPlan` behind the ``engine_factory`` seam.

    Channel identity is engine **creation order** (the order ChannelGroup
    builds its rings, which is stripe order), so a spec's ``channel=0``
    always means the group's first ring — across reruns and across plan
    generations of an adaptive group. ``events`` is the injection ledger
    the seeded-determinism contract is asserted on."""

    def __init__(self, plan: FaultPlan):
        self.plan = plan
        self._lock = make_lock("FaultInjector._lock")
        self._n_engines = 0  # guarded-by: _lock
        self._rngs: dict[int, random.Random] = {}  # guarded-by: _lock
        self._ops: dict[int, int] = {}  # guarded-by: _lock
        self._injected: dict[int, int] = {}  # guarded-by: _lock (per-spec firings)
        self._manual_stall: dict[int, float] = {}  # guarded-by: _lock
        # (channel, op_index, kind, direction, stage) in injection order
        self.events: list[tuple[int, int, str, str, str]] = []  # guarded-by: _lock

    # -- scheduling ----------------------------------------------------------
    def _rng(self, channel: int) -> random.Random:  # requires-lock: _lock
        assert_held(self._lock, "_rng")
        rng = self._rngs.get(channel)
        if rng is None:
            rng = self._rngs[channel] = random.Random(
                (self.plan.seed << 16) ^ (channel + 1))
        return rng

    def _decide(self, channel: int, direction: str,
                stage: str) -> list[FaultSpec]:
        """Advance the channel's op counter and return the specs that fire
        for this op. One lock-serialized draw per (op, matching spec):
        deterministic given the per-channel op/direction sequence."""
        want_submit = stage == "submit"
        with self._lock:
            op = self._ops.get(channel, 0)
            self._ops[channel] = op + 1
            rng = self._rng(channel)
            hits: list[FaultSpec] = []
            for si, spec in enumerate(self.plan.specs):
                if (spec.kind == "submit_error") != want_submit:
                    continue
                if spec.channel is not None and spec.channel != channel:
                    continue
                if spec.direction is not None and spec.direction != direction:
                    continue
                if op < spec.after_ops:
                    continue
                if (spec.max_injections is not None
                        and self._injected.get(si, 0) >= spec.max_injections):
                    continue
                if rng.random() >= spec.p:
                    continue
                self._injected[si] = self._injected.get(si, 0) + 1
                self.events.append((channel, op, spec.kind, direction, stage))
                hits.append(spec)
            return hits

    # -- manual control (benchmarks) ----------------------------------------
    def stall(self, channel: int, on: bool = True,
              stall_s: float = 0.02) -> None:
        """Toggle a manual per-op stall on one channel — the benchmark's
        1-of-N degraded channel, independent of the seeded schedule."""
        with self._lock:
            if on:
                self._manual_stall[channel] = float(stall_s)
            else:
                self._manual_stall.pop(channel, None)

    def _stall_for(self, channel: int) -> float:
        with self._lock:
            return self._manual_stall.get(channel, 0.0)

    @property
    def n_engines(self) -> int:
        with self._lock:
            return self._n_engines

    # -- the engine seam -----------------------------------------------------
    @staticmethod
    def _corrupt_landed(r: Any, out: np.ndarray | None) -> Any:
        """Bit-flip the landed RX bytes. With ``out=`` the caller's buffer
        is corrupted in place (that IS the landing); otherwise the result
        is copied first — on the CPU backend ``device_get`` returns a VIEW
        of the device buffer, and corrupting that in place would corrupt
        the device state a retry re-reads."""
        if out is not None:
            buf = out.reshape(-1).view(np.uint8)
            if buf.size:
                buf[0] ^= 0xFF
            return out
        arr = np.array(r, copy=True)
        flat = arr.reshape(-1).view(np.uint8)
        if flat.size:
            flat[0] ^= 0xFF
        return arr

    def engine_factory(self, base: type = TransferEngine):
        """An ``engine_factory(policy, **kw)`` callable for ChannelGroup /
        AdaptiveChannelGroup: each engine it builds is a ``base`` subclass
        whose descriptor bodies consult this injector. ``base`` may itself
        be a modelled-timing engine subclass (benchmarks compose the
        injector OVER the drift model)."""
        injector = self

        class _FaultEngine(base):  # type: ignore[misc, valid-type]
            _fault_channel: int = -1

            def _one(self, payload, direction, out=None):
                ch = self._fault_channel
                stall_s = injector._stall_for(ch)
                if stall_s > 0.0:
                    time.sleep(stall_s)
                hits = injector._decide(ch, direction, "op")
                for spec in hits:
                    if spec.kind == "delay":
                        time.sleep(spec.delay_s)
                    elif spec.kind == "stall":
                        time.sleep(spec.stall_s)
                    elif spec.kind == "drop":
                        # held, then fails WITHOUT moving the payload: an
                        # RX drop must never write the caller's buffer (a
                        # late landing would corrupt a retried result).
                        time.sleep(spec.hold_s)
                        raise InjectedFault(
                            f"dropped completion (channel {ch}, "
                            f"{direction})")
                r = super()._one(payload, direction, out)
                for spec in hits:
                    if spec.kind == "corrupt" and direction == "rx":
                        r = injector._corrupt_landed(r, out)
                return r

            def _maybe_submit_error(self, direction: str) -> None:
                for spec in injector._decide(self._fault_channel, direction,
                                             "submit"):
                    raise InjectedFault(
                        f"transient submit error (channel "
                        f"{self._fault_channel}, {direction})")

            # the injection seam passes ``priority``/``qos`` through
            # untouched: resolution (and any deprecation warning) stays in
            # the wrapped engine, attributed to the original caller.
            def tx(self, host_array, priority=None, *, qos=None):
                self._maybe_submit_error("tx")
                return super().tx(host_array, priority=priority, qos=qos)

            def rx(self, device_arrays, out=None, priority=None, *,
                   qos=None):
                self._maybe_submit_error("rx")
                return super().rx(device_arrays, out=out,
                                  priority=priority, qos=qos)

            def tx_async(self, host_array, callback=None, layout=None,
                         priority=None, *, qos=None):
                self._maybe_submit_error("tx")
                return super().tx_async(host_array, callback=callback,
                                        layout=layout, priority=priority,
                                        qos=qos)

            def rx_async(self, device_arrays, callback=None, out=None,
                         priority=None, *, qos=None):
                self._maybe_submit_error("rx")
                return super().rx_async(device_arrays, callback=callback,
                                        out=out, priority=priority, qos=qos)

            # batched submission: a submit_error fails the WHOLE group
            # before any slot is taken (uniform with tx/rx_async), while
            # per-descriptor ``_one`` faults fail only the affected ticket
            # — overriding ``_one`` already forces the engine off the
            # fused fast path, so injection seams stay per-descriptor.
            def tx_many(self, host_arrays, priority=None, *, qos=None):
                self._maybe_submit_error("tx")
                return super().tx_many(host_arrays, priority=priority,
                                       qos=qos)

            def rx_many(self, device_arrays, out=None, priority=None, *,
                        qos=None):
                self._maybe_submit_error("rx")
                return super().rx_many(device_arrays, out=out,
                                       priority=priority, qos=qos)

            # scatter-gather rides _submit_many; overriding _one above
            # already forces its per-segment loop, so payload-stage faults
            # land on individual segment tickets (mid-segment isolation).
            def tx_sg(self, segments, priority=None, *, qos=None):
                self._maybe_submit_error("tx")
                return super().tx_sg(segments, priority=priority, qos=qos)

            def rx_sg(self, segments, out=None, priority=None, *, qos=None):
                self._maybe_submit_error("rx")
                return super().rx_sg(segments, out=out, priority=priority,
                                     qos=qos)

        def factory(policy, **kw):
            eng = _FaultEngine(policy, **kw)
            with injector._lock:
                eng._fault_channel = injector._n_engines
                injector._n_engines += 1
            return eng

        return factory


@dataclass(frozen=True)
class RecoveryConfig:
    """Tuning for the channel layer's self-healing (consumed by
    :class:`~repro.core.channels.ChannelGroup`; injector-agnostic).

    ``stripe_timeout_s``: bound on every stripe ticket wait — a lost
    completion becomes a retryable ``TransferTimeoutError`` after this
    long (None keeps waits unbounded, the pre-fault-layer behaviour).
    ``max_retries``: resubmissions of one failed stripe on sibling
    channels before the error surfaces. ``quarantine_after``: consecutive
    faults that pull a channel from the stripe rotation.
    ``drift_quarantine_ratio``: a channel whose median seconds/byte over
    recent descriptors exceeds the healthy-group median by this factor is
    quarantined (None disables drift quarantine);
    ``health_min_samples`` fresh per-channel descriptor samples must exist
    before the drift verdict is trusted. Quarantined channels are probed
    with a ``probe_bytes`` transfer at most every ``probe_interval_s``
    seconds and rejoin the rotation on success."""

    stripe_timeout_s: float | None = None
    max_retries: int = 2
    quarantine_after: int = 3
    drift_quarantine_ratio: float | None = 4.0
    health_min_samples: int = 8
    probe_bytes: int = 64 << 10
    probe_interval_s: float = 1.0

    def __post_init__(self) -> None:
        if self.stripe_timeout_s is not None and self.stripe_timeout_s <= 0:
            raise ValueError("stripe_timeout_s must be positive or None")
        if self.max_retries < 0:
            raise ValueError("max_retries must be >= 0")
        if self.quarantine_after < 1:
            raise ValueError("quarantine_after must be >= 1")
        if (self.drift_quarantine_ratio is not None
                and self.drift_quarantine_ratio <= 1.0):
            raise ValueError("drift_quarantine_ratio must be > 1 or None")
