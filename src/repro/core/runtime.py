"""Unified interrupt-style TransferRuntime with QoS arbitration.

The paper's headline result is that the kernel-level *interrupt-driven*
driver beats user-level polling because completion handling is centralized:
one interrupt controller arbitrates DMA completions against every other
task competing for the CPU (DVS event collection, frame normalisation),
instead of each transfer spinning in isolation. Before this module, our
repro had the opposite shape — every engine owned a private completion
pool (N engines x 2 workers of thread sprawl, zero cross-stream
arbitration). This module is the interrupt controller: ONE process-wide
event loop that owns completion dispatch for every INTERRUPT-mode engine
and channel, arbitrating between priority classes the way the paper's OS
arbitrates DMA IRQs against sensor collection.

The paper's three management modes are three *backends* of one submit
contract ``submit(fn, nbytes=..., priority=...) -> (Event, out_list)``:

====================  =====================================================
paper mode            backend
====================  =====================================================
user-level polling    :class:`PollingBackend` — the submit IS the transfer;
                      runs inline on the caller (lowest overhead, blocks
                      the host). Engines keep this path inline — polling
                      never touches the runtime.
user-level scheduled  :class:`ScheduledBackend` — wraps the (re-homed)
                      :class:`CooperativeScheduler`: single-threaded,
                      transfers interleave with registered background
                      tasks, ``drain()`` runs the queue on the caller.
kernel interrupt      :class:`TransferRuntime` — shared bounded worker
                      pool; ISR-style completion dispatch with
                      deadline-aware weighted-fair arbitration across
                      priority classes.
====================  =====================================================

Priority classes (:class:`PriorityClass`) map the workloads of the paper's
SoC — and of this repo's serving/training stack — onto IRQ levels:

- ``SENSOR``  frame/event ingest (the paper's DVS collection), registered
  as *background* tasks that run between completions;
- ``TOKEN``   decode-token RX — latency-critical serving traffic;
- ``LAYER``   layer parameter TX / feature-map RX — streaming inference;
- ``BULK``    prefetch, checkpoint staging — best-effort throughput.

Arbitration is three-level, and starvation-free by construction:

1. *reserved latency lane*: dispatch is non-preemptive (a worker mid-memcpy
   cannot be interrupted), so once a latency-critical source (TOKEN /
   SENSOR) is registered, the last worker slot refuses LAYER/BULK
   descriptors — exactly a DMA controller's reserved high-priority
   channel. Without it, every worker can be head-of-line-blocked on a
   bulk chunk when a token arrives. Disabled when ``workers == 1`` (it
   would deadlock bulk) and until a latency class appears (a bulk-only
   process keeps every worker); recency-gated, so the lane releases
   again once latency traffic has been quiet for a few seconds — an
   idle serving engine does not pin half the workers.
2. *deadline promotion*: any queued descriptor past its class deadline is
   dispatched first, earliest absolute deadline wins (EDF). Absolute
   deadlines mean an old BULK descriptor eventually outranks fresh TOKEN
   traffic — bounded staleness, no livelock.
3. otherwise *weighted fair queuing*: each class carries a virtual time
   that advances by ``nbytes / weight`` per dispatch; the busy class with
   the smallest virtual time goes next. TOKEN's high weight lets its tiny
   descriptors jump a BULK backlog; BULK still drains at its weighted
   share. A class that went idle re-enters at the busy classes' floor so
   it cannot burst on accumulated lag.

Preemptive chunked dispatch
---------------------------
Dispatch of a single descriptor body is non-preemptive — a worker
mid-memcpy cannot be interrupted. The *chunked-dispatch contract* bounds
how long that matters: a submitter may hand the runtime a
:class:`PreemptibleWork` instead of a plain callable — a sequence of
short *segments* (sub-slices of the chunk's memcpy, sized by the fitted
cost model for a bounded per-segment service time) plus a ``collect``
fold and a ``finalize`` hook. The worker runs segments back to back; the
moment a latency-class (TOKEN/SENSOR) descriptor is queued while every
worker is busy, it *parks* the work between two segments — the
descriptor re-enters the FRONT of its class queue (with a renewed
deadline, so EDF does not immediately un-park it past the waiting
token), the worker dispatches the latency descriptor, and the parked
work resumes where its iterator left off. Guarantees of the contract:

- segments of one descriptor never run concurrently (the work is either
  in service on exactly one worker or queued);
- ``finalize(err)`` runs exactly once when the work completes or errors
  in service; a descriptor cancelled while queued/parked gets
  ``on_cancel`` instead (never both) — ring-slot release hooks stay
  single-shot;
- a parked descriptor runs at least one segment between parks, so
  continuous latency traffic slows bulk work but cannot starve it;
- preemption counts and parked-time percentiles land in
  :meth:`TransferRuntime.class_summary` (``preemptions``,
  ``preempt_park_p99_ms``).

Per-class bandwidth caps
------------------------
:meth:`TransferRuntime.set_class_cap` enforces a bytes-per-second
ceiling per priority class via token-bucket accounting inside the fair
queue: a capped class whose bucket is empty is simply not eligible for
dispatch (its head *defers*, counted in ``cap_deferrals``), so uncapped
classes borrow the freed dispatch headroom automatically. Deadline
promotion does NOT override a cap — the ceiling is hard, which is the
point of the ZynqNet-style per-class accounting. Workers park on a
timed wait sized to the earliest bucket refill, so a cap never strands
queued work.

Tier 2: per-tenant flows inside each class
------------------------------------------
The class tier is blind *within* a class: one flooding submitter
collapses p99 for every other user of the same PriorityClass. So each
class queue (:class:`_ClassFlowQueue`) replays the same arbitration one
level down, over per-tenant *flows* (Anachron's two-level DMA
arbitration, generalized from round-robin to WFQ):

- every submission carries a tenant id + weight via the
  :class:`~repro.core.qos.QosSpec` submit context (untagged traffic
  shares the ``DEFAULT_TENANT`` flow, which reproduces pre-tenancy
  scheduling exactly);
- a class nominates ONE candidate head per pick: parked resumes first
  (charge-once, they hold in-service state), then EDF over overdue
  tenant heads, then the tenant flow with the smallest byte-weighted
  virtual time (idle flows re-enter at the busy floor, same rule as the
  class tier);
- per-tenant token buckets (:meth:`TransferRuntime.set_tenant_cap`, or
  ``QosSpec.cap_bytes_per_s`` per submission) form a cap *tree*: a
  dispatch must clear BOTH its tenant bucket and the class bucket, so
  the class cap bounds the sum of its tenants' effective rates and
  uncapped tenants borrow whatever headroom the class bucket leaves;
- ``class_summary()`` grows a per-tenant ledger (``row["tenants"]``)
  and a windowed ``deadline_miss_rate``; together with
  :meth:`TransferRuntime.tenant_depth` these feed the serving layer's
  :class:`~repro.core.qos.AdmissionController`, which sheds load
  host-side before the accelerator queue backs up.

``TransferRuntime(tenant_fair=False)`` collapses tier 2 (every
descriptor lands in one flow per class) — the single-tier baseline the
tenant-isolation benchmark measures against.

Completion coalescing (per-class completion vectors)
----------------------------------------------------
The paper's floor on small packets is *management* overhead, not bus
bandwidth — and once descriptors shrink to token size, the per-completion
wakeup itself becomes the dominant management cost. Real interrupt
controllers solve this with MSI-X-style *completion vectors*: many DMA
completions coalesce into one interrupt. This runtime mirrors that. Each
:class:`PriorityClass` owns a completion vector (:class:`CoalescePolicy`)
that batches up to ``max_batch`` finished descriptors *or* a ``budget_s``
time window into ONE delivery pass — one stats/ticket/outstanding sweep
instead of N. The policy is adaptive in two ways:

- *class-shaped defaults* (:data:`DEFAULT_COALESCE`): TOKEN/SENSOR
  coalesce almost nothing (batch 2, 100 us) to protect p99; LAYER/BULK
  coalesce aggressively (batch 8/32, 1-2 ms) to amortize dispatch;
- *arrival-gated*: a class whose inter-completion gap (EWMA) exceeds its
  budget delivers immediately — coalescing sparse traffic would only add
  latency, never save a wakeup.

Two safety rules keep coalescing invisible to correctness: an errored
descriptor always flushes its class vector immediately (fault paths are
never delayed), and a completion that leaves its class *pipeline-empty*
(no queued or in-service siblings) flushes too — a synchronous waiter at
the end of a wave never stalls on the budget timer. Engine-side
protocols (ring-slot release, master-ticket ``finish_one``) run in the
descriptor body itself and are therefore never deferred; only the
runtime-level (stats, done-event, outstanding) handoff coalesces.
Savings and added latency are visible per class in
:meth:`TransferRuntime.class_summary` (``completion_wakeups``,
``wakeups_saved``, ``coalesce_batch_p99``, ``coalesce_delay_p99_ms``).

NEURAghe (Meloni et al., 2017) shows the same lesson at system scale — a
single runtime arbitrating PS/PL work is what makes heterogeneous CNN
inference compose; ZynqNet (Gschwend, 2016) motivates the per-class
bandwidth accounting and enforcement (:meth:`TransferRuntime.
class_summary`, :meth:`TransferRuntime.set_class_cap`).
"""

from __future__ import annotations

import atexit
import collections
import enum
import os
import queue
import threading
import time
import weakref
from dataclasses import dataclass, field
from typing import Any, Callable

from repro.analysis.validated import assert_held, make_condition, make_lock

# Per-class rolling window of dispatch/service latencies (bytes/counters are
# exact lifetime totals; latency percentiles come from this recent window).
_LAT_WINDOW = 2048
# Max shared workers a runtime will grow to (the whole point is bounding
# thread sprawl: the old per-engine pools were N_engines x 2, unbounded).
_MAX_WORKERS = 8
# How long an idle worker waits before exiting (no descriptors, no
# background tasks).
_IDLE_TIMEOUT_S = 30.0
# Wait granularity when background tasks are registered: an idle worker
# wakes this often to give the SENSOR-class tasks a slice.
_BG_IDLE_WAIT_S = 1e-3


class PriorityClass(enum.Enum):
    """QoS class of a transfer stream — the IRQ level of its completions."""

    SENSOR = "sensor"  # event/frame ingest (paper's DVS collection)
    TOKEN = "token"    # decode-token RX (latency-critical serving)
    LAYER = "layer"    # layer param TX / fmap RX (streaming inference)
    BULK = "bulk"      # prefetch / checkpoint staging (best-effort)


@dataclass(frozen=True)
class ClassQos:
    """Arbitration parameters of one priority class (renamed from the
    pre-PR-10 ``QosSpec`` — that name now belongs to the per-submission
    context object in :mod:`repro.core.qos`).

    ``weight``: share of dispatch bandwidth under contention (virtual time
    advances by nbytes/weight). ``deadline_s``: target queue wait; a
    descriptor past it is promoted to EDF dispatch."""

    weight: float
    deadline_s: float


DEFAULT_QOS: dict[PriorityClass, ClassQos] = {
    PriorityClass.SENSOR: ClassQos(weight=4.0, deadline_s=5e-3),
    PriorityClass.TOKEN: ClassQos(weight=8.0, deadline_s=1e-3),
    PriorityClass.LAYER: ClassQos(weight=2.0, deadline_s=20e-3),
    PriorityClass.BULK: ClassQos(weight=1.0, deadline_s=100e-3),
}

# The tier-2 flow untagged submissions land in: one shared flow arbitrates
# exactly like the pre-tenancy runtime, so single-tenant processes see
# byte-identical scheduling. Re-exported by ``repro.core.qos``.
DEFAULT_TENANT = "default"

# Per-tenant dispatch-latency window. Deliberately smaller than the class
# window (_LAT_WINDOW): a 1000-tenant serving process keeps 1000 of these.
_TENANT_LAT_WINDOW = 256

@dataclass(frozen=True)
class CoalescePolicy:
    """Completion-vector coalescing parameters of one priority class.

    ``max_batch``: flush the vector once this many completions coalesced
    (``<= 1`` disables coalescing for the class). ``budget_s``: flush no
    later than this long after the first completion entered the vector —
    the hard bound on latency a coalesced completion can be charged."""

    max_batch: int
    budget_s: float


# MSI-X-shaped defaults: latency classes coalesce a completion pair at
# most (protecting p99), throughput classes amortize a whole wave of
# small descriptors into one wakeup.
DEFAULT_COALESCE: dict[PriorityClass, CoalescePolicy] = {
    PriorityClass.SENSOR: CoalescePolicy(max_batch=2, budget_s=100e-6),
    PriorityClass.TOKEN: CoalescePolicy(max_batch=2, budget_s=100e-6),
    PriorityClass.LAYER: CoalescePolicy(max_batch=8, budget_s=1e-3),
    PriorityClass.BULK: CoalescePolicy(max_batch=32, budget_s=2e-3),
}

# Classes served by the reserved dispatch lane (see TransferRuntime): tiny,
# latency-critical descriptors that must never sit behind an in-service
# bulk chunk on every worker at once.
_LATENCY_CLASSES = (PriorityClass.TOKEN, PriorityClass.SENSOR)
# Classes whose descriptors may be submitted as PreemptibleWork (throughput
# traffic that yields to the latency classes mid-chunk).
PREEMPTIBLE_CLASSES = (PriorityClass.LAYER, PriorityClass.BULK)
# The reserved lane stays active this long past the last latency-class
# event (a TOKEN/SENSOR registration or submission). Recency-gated on
# purpose: a serving engine that merely EXISTS but has been idle must not
# halve LAYER/BULK dispatch concurrency forever — the cost is that the
# first token after a quiet period can wait out one in-service bulk chunk
# before the lane re-engages.
_LATENCY_RECENCY_S = 5.0


def _pct(samples: "collections.deque[float] | list[float]", q: float) -> float:
    if not samples:
        return float("nan")
    s = sorted(samples)
    idx = min(len(s) - 1, max(0, int(round(q * (len(s) - 1)))))
    return s[idx]


class TransferFaultError(RuntimeError):
    """A transfer failed in a way the channel layer may RETRY on a sibling
    ring: injected faults, checksum mismatches and descriptor timeouts all
    derive from this. Structural errors (closed engine, bad payload) stay
    plain RuntimeError/ValueError and are never retried."""


class TransferTimeoutError(TransferFaultError):
    """A descriptor (or a ticket waiting on one) blew its deadline — the
    repro of a dropped DMA completion surfacing as an error instead of a
    hang. Raised by ``Ticket.wait(timeout=)`` and by the runtime's
    :meth:`TransferRuntime.scan_timeouts` cancellation path."""


class TransferChecksumError(TransferFaultError):
    """Per-descriptor crc32 verification failed on RX
    (``TransferPolicy.checksum``): the payload landed, but corrupted."""


@dataclass
class TenantStats:
    """Per-tenant (tier-2 flow) accounting inside one priority class.

    Counts/bytes are exact lifetime totals; the dispatch-latency window is
    deliberately small (``_TENANT_LAT_WINDOW``) so a 1000-tenant serving
    process stays cheap. Fault columns mirror the class-level ledger so a
    misbehaving tenant's retries are attributable (PR 10 satellite)."""

    submitted: int = 0
    dispatched: int = 0
    completed: int = 0
    cancelled: int = 0
    bytes_total: int = 0
    cap_deferrals: int = 0
    deadline_misses: int = 0
    timeouts: int = 0
    faults: int = 0
    retries: int = 0
    quarantines: int = 0
    dispatch_lat_s: "collections.deque[float]" = field(
        default_factory=lambda: collections.deque(
            maxlen=_TENANT_LAT_WINDOW))

    def summary(self) -> dict[str, float]:
        return {
            "submitted": self.submitted,
            "dispatched": self.dispatched,
            "completed": self.completed,
            "cancelled": self.cancelled,
            "bytes_total": self.bytes_total,
            "cap_deferrals": self.cap_deferrals,
            "deadline_misses": self.deadline_misses,
            "timeouts": self.timeouts,
            "faults": self.faults,
            "retries": self.retries,
            "quarantines": self.quarantines,
            "dispatch_p50_ms": _pct(self.dispatch_lat_s, 0.5) * 1e3,
            "dispatch_p99_ms": _pct(self.dispatch_lat_s, 0.99) * 1e3,
        }


@dataclass
class ClassStats:
    """Per-class accounting: counts/bytes exact, latencies windowed."""

    submitted: int = 0
    dispatched: int = 0
    completed: int = 0
    cancelled: int = 0
    bytes_total: int = 0
    deadline_promotions: int = 0
    # preemptive chunked dispatch: how often this class's in-service work
    # parked for a latency arrival, and how long the parked work waited
    # before resuming (windowed).
    preemptions: int = 0
    # scheduler passes where this class had queued work but its token
    # bucket was empty (deferred by its bandwidth cap).
    cap_deferrals: int = 0
    # submissions whose EDF deadline was stretched to the cap bucket's
    # drain horizon (cap-aware deadlines: a throttled class must not sit
    # permanently overdue while stage 0 vetoes it).
    cap_deadline_stretches: int = 0
    # fault-handling ledger (PR 6): descriptors cancelled by the timeout
    # scan / ticket deadline, faults observed (injected or organic, incl.
    # checksum mismatches), stripe retries issued by the channel layer,
    # and channels pulled from rotation. Engines and groups report these
    # via note_fault(); serving surfaces read them off class_summary().
    timeouts: int = 0
    faults: int = 0
    retries: int = 0
    quarantines: int = 0
    # dispatches that happened past the descriptor's EDF deadline (the
    # admission controller's class-pressure signal; windowed rate lives
    # in TransferRuntime.deadline_miss_rate).
    deadline_misses: int = 0
    # tier-2 ledger: per-tenant flow accounting inside this class.
    tenants: dict[str, TenantStats] = field(default_factory=dict)
    # completion coalescing ledger: delivery passes actually taken, how
    # many per-completion wakeups the vector saved, and the windowed
    # batch-size / added-latency distributions. An immediate (uncoalesced)
    # delivery counts as one wakeup with batch size 1 and zero delay.
    completion_wakeups: int = 0
    wakeups_saved: int = 0
    coalesce_batch: "collections.deque[int]" = field(
        default_factory=lambda: collections.deque(maxlen=_LAT_WINDOW))
    coalesce_delay_s: "collections.deque[float]" = field(
        default_factory=lambda: collections.deque(maxlen=_LAT_WINDOW))
    dispatch_lat_s: "collections.deque[float]" = field(
        default_factory=lambda: collections.deque(maxlen=_LAT_WINDOW))
    service_lat_s: "collections.deque[float]" = field(
        default_factory=lambda: collections.deque(maxlen=_LAT_WINDOW))
    preempt_park_s: "collections.deque[float]" = field(
        default_factory=lambda: collections.deque(maxlen=_LAT_WINDOW))
    # (monotonic stamp, latency) pairs for TIME-bounded consumers (the
    # adaptive crossover); the bare deques above stay count-bounded for
    # the lifetime percentile summaries.
    dispatch_recent: "collections.deque[tuple[float, float]]" = field(
        default_factory=lambda: collections.deque(maxlen=_LAT_WINDOW))

    def tenant(self, tenant: str) -> TenantStats:
        """Get-or-create the tier-2 ledger row for one flow."""
        ts = self.tenants.get(tenant)
        if ts is None:
            ts = self.tenants[tenant] = TenantStats()
        return ts

    def summary(self) -> dict[str, float]:
        return {
            "submitted": self.submitted,
            "completed": self.completed,
            "cancelled": self.cancelled,
            "bytes_total": self.bytes_total,
            "deadline_promotions": self.deadline_promotions,
            "deadline_misses": self.deadline_misses,
            "preemptions": self.preemptions,
            "cap_deferrals": self.cap_deferrals,
            "cap_deadline_stretches": self.cap_deadline_stretches,
            "timeouts": self.timeouts,
            "faults": self.faults,
            "retries": self.retries,
            "quarantines": self.quarantines,
            "completion_wakeups": self.completion_wakeups,
            "wakeups_saved": self.wakeups_saved,
            "coalesce_batch_p50": _pct(self.coalesce_batch, 0.5),
            "coalesce_batch_p99": _pct(self.coalesce_batch, 0.99),
            "coalesce_delay_p50_ms": _pct(self.coalesce_delay_s, 0.5) * 1e3,
            "coalesce_delay_p99_ms": _pct(self.coalesce_delay_s, 0.99) * 1e3,
            "dispatch_p50_ms": _pct(self.dispatch_lat_s, 0.5) * 1e3,
            "dispatch_p99_ms": _pct(self.dispatch_lat_s, 0.99) * 1e3,
            "service_p50_ms": _pct(self.service_lat_s, 0.5) * 1e3,
            "service_p99_ms": _pct(self.service_lat_s, 0.99) * 1e3,
            "preempt_park_p50_ms": _pct(self.preempt_park_s, 0.5) * 1e3,
            "preempt_park_p99_ms": _pct(self.preempt_park_s, 0.99) * 1e3,
        }


class PreemptibleWork:
    """Resumable descriptor body — the unit of preemptive chunked dispatch.

    ``segments`` is a finite iterable of thunks; the runtime runs them in
    order on ONE worker at a time and may park the descriptor between two
    segments when a latency-class descriptor is waiting (see the module
    docstring's chunked-dispatch contract). ``collect(parts)`` folds the
    per-segment results into the descriptor result (default: the raw
    ``parts`` list). ``finalize(err_or_none)`` runs exactly once, outside
    the runtime lock, after the work completes or errors *in service* —
    engines release ring slots and fire master-ticket protocols there. A
    descriptor cancelled while queued/parked gets the submitter's
    ``on_cancel`` instead of ``finalize`` (never both)."""

    __slots__ = ("_segments", "_next", "parts", "collect", "finalize",
                 "segments_run")

    _DONE = object()  # sentinel: no further segment

    def __init__(self, segments, *,
                 collect: Callable[[list], Any] | None = None,
                 finalize: Callable[[BaseException | None], None] | None = None):
        self._segments = iter(segments)
        # one segment of lookahead, so ``exhausted`` is knowable right
        # after the last real segment ran — finished work must not take a
        # pointless park/requeue round-trip (and inflate the preemption
        # ledger) for a yield point with nothing left to yield.
        self._next = next(self._segments, self._DONE)
        self.parts: list = []
        self.collect = collect
        self.finalize = finalize
        self.segments_run = 0

    @property
    def exhausted(self) -> bool:
        return self._next is self._DONE

    def step(self) -> bool:
        """Run the next segment on the caller; True when none remain."""
        if self._next is self._DONE:
            return True
        seg = self._next
        self.parts.append(seg())
        self.segments_run += 1
        self._next = next(self._segments, self._DONE)
        return False

    def result(self) -> Any:
        return self.collect(self.parts) if self.collect else self.parts


class _TokenBucket:
    """Per-class bandwidth-cap accounting (lazily refilled under the
    runtime lock). A dispatch is allowed while the bucket is non-negative
    and *charges* the full descriptor size — one oversized descriptor may
    overshoot its burst, then the class defers until the deficit refills
    (standard token-bucket semantics; big descriptors are never starved
    by a burst smaller than themselves)."""

    __slots__ = ("rate", "burst", "tokens", "stamp")

    def __init__(self, rate_Bps: float, burst_s: float):
        self.rate = float(rate_Bps)
        self.burst = max(self.rate * burst_s, 1.0)
        self.tokens = self.burst
        self.stamp = time.monotonic()

    def _refill(self, now: float) -> None:
        self.tokens = min(self.burst, self.tokens + (now - self.stamp) * self.rate)
        self.stamp = now

    def ready(self, now: float) -> bool:
        self._refill(now)
        return self.tokens > 0.0

    def charge(self, nbytes: int) -> None:
        self.tokens -= nbytes

    def delay_s(self, now: float) -> float:
        """Seconds until the bucket turns non-negative again."""
        self._refill(now)
        if self.tokens > 0.0:
            return 0.0
        return -self.tokens / self.rate


class _TenantFlow:
    """Tier-2 flow: one tenant's FIFO inside one class queue, with its own
    WFQ virtual time, weight and (optional) token bucket — the leaf of the
    cap tree. Guarded by the runtime lock like the queue that owns it."""

    __slots__ = ("q", "vtime", "weight", "bucket", "backlog_bytes", "stats")

    def __init__(self, stats: TenantStats):
        self.q: "collections.deque[_Descriptor]" = collections.deque()
        self.vtime = 0.0
        self.weight = 1.0
        self.bucket: _TokenBucket | None = None
        self.backlog_bytes = 0
        self.stats = stats


class _ClassFlowQueue:
    """The tier-2 arbiter of ONE priority class: per-tenant FIFO flows
    under byte-weighted fair queuing, plus a ``parked`` deque where
    preempted (mid-chunk) descriptors resume with absolute precedence —
    the generalization of the plain per-class deque this replaces.

    Selection inside the class (:meth:`head`): parked resumes first, then
    EDF over the overdue tenant heads, then the minimum-vtime tenant —
    the same three-stage shape the runtime applies ACROSS classes, one
    tier down. A tenant whose token bucket is empty is not eligible (its
    head defers, counted per tenant); tenants without a bucket borrow
    whatever headroom the class bucket leaves — the cap tree's borrowing
    rule falls out of checking both buckets independently.

    ``tenant_fair=False`` routes every descriptor through one shared flow
    (strict class FIFO — the single-tier baseline the tenant-isolation
    benchmark compares against). NOT thread-safe on its own: every method
    runs under ``TransferRuntime._cond`` exactly like the deque it
    replaced."""

    __slots__ = ("stats", "flows", "parked", "tenant_fair", "_len",
                 "queued_bytes")

    def __init__(self, stats: ClassStats, tenant_fair: bool = True):
        self.stats = stats
        self.flows: dict[str, _TenantFlow] = {}
        self.parked: "collections.deque[_Descriptor]" = collections.deque()
        self.tenant_fair = tenant_fair
        self._len = 0
        self.queued_bytes = 0

    def __bool__(self) -> bool:
        return self._len > 0

    def __len__(self) -> int:
        return self._len

    def _key(self, d: "_Descriptor") -> str:
        return d.tenant if self.tenant_fair else DEFAULT_TENANT

    def flow(self, tenant: str) -> _TenantFlow:
        f = self.flows.get(tenant)
        if f is None:
            f = self.flows[tenant] = _TenantFlow(self.stats.tenant(tenant))
        return f

    def append(self, d: "_Descriptor") -> None:
        """Enqueue a new arrival on its tenant's flow. An idle flow
        re-enters at the busy flows' vtime floor (same no-burst rule the
        classes follow one tier up)."""
        f = self.flow(self._key(d))
        if not f.q:
            busy = [ff.vtime for ff in self.flows.values() if ff.q]
            if busy:
                f.vtime = max(f.vtime, min(busy))
        if self.tenant_fair:
            f.weight = max(d.weight, 1e-9)  # last submission wins
        f.q.append(d)
        f.backlog_bytes += d.nbytes
        self._len += 1
        self.queued_bytes += d.nbytes

    def appendleft(self, d: "_Descriptor") -> None:
        """Park a preempted resume at the class front (absolute precedence
        over every flow: it holds a ring slot and mid-chunk state, and its
        bytes were already charged at first dispatch)."""
        self.parked.appendleft(d)
        self._len += 1
        self.queued_bytes += d.nbytes

    def head(self, now: float) -> "tuple[_Descriptor | None, float | None]":
        """The class's next dispatchable descriptor under tenant caps,
        plus the earliest tenant-bucket refill delay when one or more
        flows deferred this pass (None, hint) means every queued flow is
        tenant-capped."""
        if self.parked:
            return self.parked[0], None
        hint: float | None = None
        best_overdue: "_Descriptor | None" = None
        best_d: "_Descriptor | None" = None
        best_vt = float("inf")
        for f in self.flows.values():
            if not f.q:
                continue
            d = f.q[0]
            if (not d.started and f.bucket is not None
                    and not f.bucket.ready(now)):
                # tenant bucket empty: this flow defers (cap tree leaf).
                # Parked resumes never reach here (they bypass via the
                # parked deque) and started heads are charge-once exempt.
                f.stats.cap_deferrals += 1
                wait = f.bucket.delay_s(now)
                if hint is None or wait < hint:
                    hint = wait
                continue
            if d.deadline <= now and (best_overdue is None
                                      or d.deadline < best_overdue.deadline):
                best_overdue = d
            if f.vtime < best_vt:
                best_vt = f.vtime
                best_d = d
        return (best_overdue if best_overdue is not None else best_d), hint

    def oldest(self) -> "_Descriptor | None":
        """Oldest submission across flows (the FIFO-baseline pick; flows
        are FIFO so per-flow heads suffice)."""
        best = self.parked[0] if self.parked else None
        for f in self.flows.values():
            if f.q and (best is None or f.q[0].t_submit < best.t_submit):
                best = f.q[0]
        return best

    def pop(self, d: "_Descriptor") -> None:
        """Remove ``d`` — which must be a current head (parked or flow)."""
        if self.parked and self.parked[0] is d:
            self.parked.popleft()
        else:
            f = self.flows[self._key(d)]
            popped = f.q.popleft()
            if popped is not d:  # pragma: no cover — selection bug guard
                f.q.appendleft(popped)
                raise RuntimeError("flow-queue pop of a non-head descriptor")
            f.backlog_bytes -= d.nbytes
        self._len -= 1
        self.queued_bytes -= d.nbytes

    def charge_dispatch(self, d: "_Descriptor") -> None:
        """First-dispatch accounting at the tenant tier: advance the
        flow's virtual time by nbytes/weight and charge its token bucket
        (the class-level twin runs in ``_pick_locked``)."""
        if not self.tenant_fair:
            return
        f = self.flows.get(self._key(d))
        if f is None:
            return
        f.vtime += max(d.nbytes, 1024) / f.weight
        if f.bucket is not None:
            f.bucket.charge(d.nbytes)

    def drain_if(self, pred: "Callable[[_Descriptor], bool]"
                 ) -> "list[_Descriptor]":
        """Remove and return every queued descriptor matching ``pred``
        (timeout scans, handle cancellation) preserving FIFO order of the
        survivors."""
        out: "list[_Descriptor]" = []
        keep: "collections.deque[_Descriptor]" = collections.deque()
        while self.parked:
            d = self.parked.popleft()
            (out if pred(d) else keep).append(d)
        self.parked.extend(keep)
        for f in self.flows.values():
            if not f.q:
                continue
            kept: "collections.deque[_Descriptor]" = collections.deque()
            while f.q:
                d = f.q.popleft()
                if pred(d):
                    out.append(d)
                    f.backlog_bytes -= d.nbytes
                else:
                    kept.append(d)
            f.q.extend(kept)
        for d in out:
            self._len -= 1
            self.queued_bytes -= d.nbytes
        return out

    def depth(self, tenant: str) -> int:
        """Queued-but-undispatched descriptors of one tenant (parked
        resumes already dispatched once and do not count)."""
        f = self.flows.get(tenant)
        return len(f.q) if f is not None else 0

    def tenant_backlog(self, tenant: str) -> int:
        f = self.flows.get(tenant)
        return f.backlog_bytes if f is not None else 0

    def set_cap(self, tenant: str, bytes_per_s: float | None,
                burst_s: float) -> None:
        f = self.flow(tenant)
        if bytes_per_s is None or bytes_per_s <= 0:
            f.bucket = None
        elif f.bucket is None or f.bucket.rate != float(bytes_per_s):
            # unchanged rate keeps the live bucket: QosSpec-carried caps
            # arrive on EVERY submission and must not refill the burst.
            f.bucket = _TokenBucket(bytes_per_s, burst_s)

    def cap(self, tenant: str) -> float | None:
        f = self.flows.get(tenant)
        return f.bucket.rate if f is not None and f.bucket is not None \
            else None


class _Descriptor:
    """One staged completion: the unit the runtime arbitrates."""

    __slots__ = ("fn", "done", "out", "cls", "nbytes", "handle",
                 "t_submit", "deadline", "on_cancel",
                 "started", "service_acc", "t_parked", "preemptions",
                 "units", "tenant", "weight")

    def __init__(self, fn: Callable[[], Any], cls: PriorityClass,
                 nbytes: int, handle: "RuntimeHandle", deadline_s: float,
                 on_cancel: Callable[[BaseException], None] | None = None,
                 units: int = 1, tenant: str = DEFAULT_TENANT,
                 weight: float = 1.0):
        self.fn = fn
        self.done = threading.Event()
        self.out: list = []
        self.cls = cls
        self.nbytes = max(int(nbytes), 0)
        # logical descriptors carried by this one submission (a tx_many/
        # rx_many group rides one runtime descriptor): dispatch latency is
        # amortized over units when fed to the adaptive crossover.
        self.units = max(int(units), 1)
        # tier-2 flow tag + WFQ weight (QosSpec-carried; see repro.core.qos)
        self.tenant = tenant
        self.weight = max(float(weight), 1e-9)
        self.handle = handle
        self.t_submit = time.monotonic()
        self.deadline = self.t_submit + deadline_s
        # invoked (outside the runtime lock) iff the descriptor is cancelled
        # while still queued: the submitter's own completion protocol (ring
        # slot release, master-ticket error propagation) must run even when
        # ``fn`` never will — a cancelled chunk must not hang its caller.
        self.on_cancel = on_cancel
        # preemptive-chunking state: first-dispatch stats/cap-charges fire
        # once; service time accumulates across park/resume stints.
        self.started = False
        self.service_acc = 0.0
        self.t_parked: float | None = None
        self.preemptions = 0


class RuntimeHandle:
    """Per-engine registration — the compat shim for the old per-engine
    completion-pool ``submit`` contract.

    ``submit(fn)`` returns ``(done_event, out_list)`` exactly like the
    retired ``_CompletionPool.submit``, so :class:`~repro.core.transfer.
    Ticket` wraps it unchanged; descriptors are tagged with the engine's
    priority class (overridable per call). ``close()`` drains this
    engine's outstanding descriptors and deregisters, so a closed engine
    can never receive a late completion."""

    def __init__(self, runtime: "TransferRuntime", owner: Any,
                 cls: PriorityClass):
        self.runtime = runtime
        self.owner_repr = repr(owner)[:80]
        self.cls = cls
        self._outstanding = 0
        self._closed = False

    @property
    def closed(self) -> bool:
        return self._closed

    @property
    def outstanding(self) -> int:
        return self._outstanding

    def submit(self, fn: Callable[[], Any], nbytes: int = 0,
               priority: "PriorityClass | None" = None,
               on_cancel: Callable[[BaseException], None] | None = None,
               units: int = 1, *,
               qos: Any = None) -> tuple[threading.Event, list]:
        # ``qos`` is duck-typed (any object with the QosSpec fields) so the
        # runtime never imports repro.core.qos — qos.py imports us.
        cls = priority
        if cls is None and qos is not None:
            cls = getattr(qos, "priority", None)
        return self.runtime._submit(self, fn, cls or self.cls, nbytes,
                                    on_cancel, units, qos=qos)

    def close(self, timeout: float = 5.0) -> None:
        self.runtime._close_handle(self, timeout)


class TransferRuntime:
    """The shared interrupt controller: one bounded worker pool dispatching
    every registered engine's completions under deadline-aware weighted-fair
    arbitration.

    ``fair=False`` disables arbitration (global FIFO by submit time) — the
    baseline a naive shared pool would be; kept for the QoS benchmark.
    Workers spawn on demand up to ``workers`` and exit after
    ``idle_timeout_s`` without work (engines registering is free; threads
    only exist while traffic flows). Completion callbacks (the ``fn``
    closures) run ON a worker, so — like a real ISR — they must never
    block on another descriptor of this runtime (self-deadlock) and must
    not issue transfers."""

    def __init__(self, workers: int | None = None, *,
                 qos: dict[PriorityClass, ClassQos] | None = None,
                 fair: bool = True,
                 tenant_fair: bool = True,
                 preempt: bool = True,
                 reserve_latency_workers: int = 1,
                 latency_recency_s: float = _LATENCY_RECENCY_S,
                 idle_timeout_s: float = _IDLE_TIMEOUT_S,
                 background_budget_s: float = 50e-6,
                 cap_burst_s: float = 0.05,
                 coalesce: dict[PriorityClass, CoalescePolicy] | None = None):
        if workers is None:
            workers = max(2, min(_MAX_WORKERS, os.cpu_count() or 2))
        self.workers = max(1, int(workers))  # guarded-by: _cond
        self.reserve_latency_workers = max(0, int(reserve_latency_workers))
        self.latency_recency_s = float(latency_recency_s)
        self.qos = dict(DEFAULT_QOS)
        if qos:
            self.qos.update(qos)
        self.fair = fair
        # tier-2 arbitration: per-tenant WFQ inside each class. Off =>
        # strict FIFO within a class (the single-tier PR-9 baseline, kept
        # for the tenant-isolation benchmark).
        self.tenant_fair = tenant_fair
        # honor PreemptibleWork yield points (park bulk work for latency
        # arrivals). Off => segments still run correctly, just back to back
        # — the PR-4 one-chunk-bound baseline, kept for the QoS benchmark.
        self.preempt = preempt
        self.idle_timeout_s = idle_timeout_s
        self.background_budget_s = background_budget_s
        # per-class bandwidth caps (token buckets), set_class_cap-managed.
        self.cap_burst_s = float(cap_burst_s)
        self._cond = make_condition("TransferRuntime._cond")
        self._caps: dict[PriorityClass, _TokenBucket] = {}  # guarded-by: _cond
        # earliest bucket-refill delay observed by the last _pick_locked
        # pass that found only cap-deferred work (None = no cap deferral):
        # workers size their wait on it so capped work is never stranded.
        self._cap_wait_hint: float | None = None            # guarded-by: _cond
        self.stats: dict[PriorityClass, ClassStats] = {
            cls: ClassStats() for cls in PriorityClass}     # guarded-by: _cond
        # tier-2 flow queues: per-tenant WFQ + token buckets inside each
        # class (the plain per-class deques of PR <= 9, generalized).
        self._queues: dict[PriorityClass, _ClassFlowQueue] \
            = {cls: _ClassFlowQueue(self.stats[cls], tenant_fair)
               for cls in PriorityClass}                    # guarded-by: _cond
        # recent (stamp, missed) dispatch outcomes per class — the
        # admission controller's deadline-miss-rate signal.
        self._miss_window: dict[PriorityClass,
                                "collections.deque[tuple[float, int]]"] = {
            cls: collections.deque(maxlen=_LAT_WINDOW)
            for cls in PriorityClass}                       # guarded-by: _cond
        # completion coalescing: per-class vector of finished-but-not-yet-
        # delivered descriptors [(descriptor, t_done)], the wall deadline
        # of the oldest vector entry, the EWMA inter-completion gap (the
        # adaptive "is coalescing worth it" signal) and the stamp of the
        # last completion per class.
        self.coalesce = dict(DEFAULT_COALESCE)              # guarded-by: _cond
        if coalesce:
            self.coalesce.update(coalesce)
        self._vectors: dict[PriorityClass,
                            list[tuple[_Descriptor, float]]] = {
            cls: [] for cls in PriorityClass}               # guarded-by: _cond
        self._vec_deadline: dict[PriorityClass, float] = {
            cls: float("inf") for cls in PriorityClass}     # guarded-by: _cond
        self._coalesce_gap: dict[PriorityClass, float] = {
            cls: float("inf") for cls in PriorityClass}     # guarded-by: _cond
        self._coalesce_last: dict[PriorityClass, float] = {
            cls: float("-inf") for cls in PriorityClass}    # guarded-by: _cond
        # in-service descriptors per class (the pipeline-empty flush test:
        # a completion with no queued AND no in-service siblings must
        # deliver now, not wait out the coalescing budget).
        self._executing_by: dict[PriorityClass, int] = {
            cls: 0 for cls in PriorityClass}                # guarded-by: _cond
        self._vtime: dict[PriorityClass, float] = {
            cls: 0.0 for cls in PriorityClass}              # guarded-by: _cond
        # descriptors currently in service
        self._executing = 0                                 # guarded-by: _cond
        # Reserved-lane activation is RECENCY-gated: the stamp updates on
        # every TOKEN/SENSOR registration or submission, and the lane is
        # active while it is fresher than ``latency_recency_s``. An idle
        # or closed serving engine therefore releases the lane (LAYER/
        # BULK get every worker back) instead of pinning it for life.
        # ``_latency_handles`` counts live latency registrations for
        # introspection/diagnostics.
        self._latency_handles = 0                           # guarded-by: _cond
        self._latency_last_event = float("-inf")            # guarded-by: _cond
        self._alive = 0                                     # guarded-by: _cond
        self._threads: list[threading.Thread] = []          # guarded-by: _cond
        self._closed = False                                # guarded-by: _cond
        # WEAK registry: an engine dropped without close() (allowed before
        # this runtime existed — per-engine pools just idled out) must not
        # pin its handle in the process-global runtime forever. Queued/
        # in-flight descriptors hold the handle strongly, so it lives
        # exactly as long as work for it can still exist.
        self._handles: "weakref.WeakSet[RuntimeHandle]" = \
            weakref.WeakSet()                               # guarded-by: _cond
        self._background: list[Callable[[], None]] = []     # guarded-by: _cond
        self._bg_cursor = 0                                 # guarded-by: _cond
        # single-flight: background tasks keep the cooperative scheduler's
        # single-threaded contract (a sensor_fn must never race itself
        # across two workers)
        self._bg_running = False                            # guarded-by: _cond
        # thread id of the ONE worker polling the background lane at
        # _BG_IDLE_WAIT_S cadence; the rest wait at idle_timeout_s and may
        # idle-exit (no N-worker busy spin)
        self._bg_spinner: int | None = None                 # guarded-by: _cond
        self.dispatches = 0                                 # guarded-by: _cond
        self.background_slices_run = 0                      # guarded-by: _cond
        self.background_errors = 0                          # guarded-by: _cond

    # -- registration --------------------------------------------------------
    def register(self, owner: Any, priority: PriorityClass,
                 workers_hint: int = 0) -> RuntimeHandle:
        """Register an engine (or any completion consumer) at a priority
        class. ``workers_hint`` may grow the shared worker cap (bounded by
        ``_MAX_WORKERS``) — a hint, not a per-engine allocation."""
        h = RuntimeHandle(self, owner, priority)
        with self._cond:
            if self._closed:
                raise RuntimeError("register() on a closed TransferRuntime")
            self._handles.add(h)
            if priority in _LATENCY_CLASSES:
                self._latency_handles += 1
                self._latency_last_event = time.monotonic()  # lane engages
            if workers_hint > 0:
                self.workers = min(_MAX_WORKERS,
                                   max(self.workers, int(workers_hint)))
        return h

    @property
    def n_registered(self) -> int:
        with self._cond:
            return len(self._handles)

    # -- per-class bandwidth caps ---------------------------------------------
    def set_class_cap(self, cls: PriorityClass,
                      bytes_per_s: float | None) -> None:
        """Enforce a bytes/s ceiling on one priority class (the ZynqNet
        per-layer bandwidth budget, as a hard limit instead of a ledger
        entry). ``None`` or ``<= 0`` clears the cap. A capped class whose
        token bucket is empty defers dispatch — even past its deadline —
        and uncapped classes borrow the freed headroom. Takes effect on
        the next dispatch decision; only enforced under ``fair=True``
        (the FIFO baseline models a runtime with no QoS at all)."""
        with self._cond:
            if bytes_per_s is None or bytes_per_s <= 0:
                self._caps.pop(cls, None)
            else:
                self._caps[cls] = _TokenBucket(bytes_per_s, self.cap_burst_s)
            self._cond.notify_all()

    def class_cap(self, cls: PriorityClass) -> float | None:
        """The enforced bytes/s ceiling for ``cls`` (None = uncapped) —
        consumers (the online transfer controller) plan against this
        effective bandwidth instead of chasing the raw link fit."""
        with self._cond:
            b = self._caps.get(cls)
            return b.rate if b is not None else None

    # -- per-tenant caps + admission signals (the cap tree's leaves) ----------
    def set_tenant_cap(self, cls: PriorityClass, tenant: str,
                       bytes_per_s: float | None, *,
                       burst_s: float | None = None) -> None:
        """Bytes/s ceiling on ONE tenant flow inside ``cls`` — a leaf of
        the cap tree. A dispatch must clear BOTH its tenant bucket and the
        class bucket, so the class cap bounds the sum of its tenants'
        effective rates whatever their leaf caps claim; tenants without a
        leaf cap borrow whatever headroom the class bucket leaves.
        ``None`` / ``<= 0`` clears the leaf. Only enforced under
        ``tenant_fair=True`` (the single-tier baseline has no tier 2)."""
        with self._cond:
            self._queues[cls].set_cap(
                tenant, bytes_per_s,
                self.cap_burst_s if burst_s is None else float(burst_s))
            self._cond.notify_all()

    def tenant_cap(self, cls: PriorityClass, tenant: str) -> float | None:
        """The enforced leaf ceiling for ``tenant`` in ``cls`` (None =
        uncapped: bounded only by the class bucket)."""
        with self._cond:
            return self._queues[cls].cap(tenant)

    def tenant_depth(self, cls: PriorityClass, tenant: str) -> int:
        """Queued-but-undispatched descriptors of one tenant — the
        admission controller's per-tenant pressure signal."""
        with self._cond:
            return self._queues[cls].depth(tenant)

    def tenant_queued_bytes(self, cls: PriorityClass, tenant: str) -> int:
        with self._cond:
            return self._queues[cls].tenant_backlog(tenant)

    def deadline_miss_rate(self, cls: PriorityClass,
                           ttl_s: float = 5.0) -> float:
        """Fraction of the class's recent dispatch outcomes (last
        ``ttl_s`` seconds) that ran past their EDF deadline — timeout
        cancellations count as misses. 0.0 with no recent traffic: an
        idle runtime must admit freely."""
        with self._cond:
            return self._miss_rate_locked(cls, ttl_s)

    def _miss_rate_locked(self, cls: PriorityClass,  # requires-lock: _cond
                          ttl_s: float = 5.0) -> float:
        assert_held(self._cond, "_miss_rate_locked")
        cutoff = time.monotonic() - ttl_s
        recent = [m for t, m in self._miss_window[cls] if t >= cutoff]
        if not recent:
            return 0.0
        return sum(recent) / len(recent)

    # -- completion coalescing -----------------------------------------------
    def set_coalesce(self, cls: PriorityClass,
                     policy: CoalescePolicy | None) -> None:
        """Set (or clear, with ``None`` / ``max_batch <= 1``) the
        completion-vector policy of one class. Takes effect on the next
        completion; anything already coalesced in the class vector is
        delivered immediately so a policy change never strands a ticket."""
        drained: list[tuple[PriorityClass, list]] = []
        with self._cond:
            if policy is None or policy.max_batch <= 1:
                self.coalesce.pop(cls, None)
            else:
                self.coalesce[cls] = policy
            vec = self._vectors[cls]
            if vec:
                self._vectors[cls] = []
                drained.append((cls, vec))
            self._cond.notify_all()
        for batch in drained:
            self._deliver(batch)

    def register_background(self, fn: Callable[[], None]) -> Callable[[], None]:
        """Register a recurring SENSOR-style background task: workers give
        it budgeted slices between completion dispatches (and while idle) —
        the paper's concurrent collection+transfer scenario. Returns an
        unregister callable."""
        with self._cond:
            if self._closed:
                raise RuntimeError(
                    "register_background() on a closed TransferRuntime")
            self._background.append(fn)
            if self._alive == 0:
                # no transfer traffic yet: collection must still run
                t = threading.Thread(target=self._run, daemon=True)
                t.start()
                self._threads.append(t)
                self._alive += 1
            self._cond.notify_all()

        def unregister() -> None:
            with self._cond:
                try:
                    self._background.remove(fn)
                except ValueError:
                    pass
        return unregister

    # -- submission ----------------------------------------------------------
    def _submit(self, handle: RuntimeHandle, fn: Callable[[], Any],
                cls: PriorityClass, nbytes: int,
                on_cancel: Callable[[BaseException], None] | None = None,
                units: int = 1, qos: Any = None) -> tuple[threading.Event, list]:
        spec = self.qos[cls]
        # QosSpec-carried per-submission context (duck-typed; None fields
        # fall back to class defaults — see repro.core.qos).
        tenant = DEFAULT_TENANT
        weight = 1.0
        deadline_s = spec.deadline_s
        t_cap = t_burst = None
        if qos is not None:
            tenant = getattr(qos, "tenant", None) or DEFAULT_TENANT
            weight = getattr(qos, "weight", None) or 1.0
            deadline_s = getattr(qos, "deadline_s", None) or spec.deadline_s
            t_cap = getattr(qos, "cap_bytes_per_s", None)
            t_burst = getattr(qos, "burst_s", None)
        d = _Descriptor(fn, cls, nbytes, handle, deadline_s, on_cancel,
                        units, tenant=tenant, weight=weight)
        with self._cond:
            if self._closed:
                raise RuntimeError("submit() on a closed TransferRuntime")
            if handle._closed:
                raise RuntimeError(
                    f"submit() on a closed runtime handle ({handle.owner_repr})")
            q = self._queues[cls]
            if t_cap is not None:
                # QosSpec-carried leaf cap: installs (or updates) the
                # tenant's bucket; an unchanged rate keeps the live bucket
                # so per-submission specs never refill the burst.
                q.set_cap(tenant, t_cap,
                          self.cap_burst_s if t_burst is None
                          else float(t_burst))
            if cls in _LATENCY_CLASSES:
                self._latency_last_event = time.monotonic()
            if not q:
                # idle class re-enters at the busy floor: it must compete
                # fairly NOW, not burst on virtual time it never spent.
                busy = [self._vtime[c] for c, qq in self._queues.items() if qq]
                if busy:
                    self._vtime[cls] = max(self._vtime[cls], min(busy))
            if not self.fair:
                d.deadline = float("inf")  # FIFO baseline: no promotion
            else:
                # cap-aware EDF: a throttled class's (or tenant's) dispatch
                # horizon is set by its token-bucket refill rate, not the
                # QoS spec. Stretch the deadline past the time the bucket
                # needs to drain the queued backlog plus this descriptor,
                # so a hard-capped flow does not go permanently overdue —
                # stage 0 (or the tier-2 head check) would veto every EDF
                # pick anyway, and the class_summary() ledger would report
                # promotions that never dispatch. The stretch takes the
                # SLOWER of the class and tenant drain horizons (the cap
                # tree's binding constraint).
                cap_now = time.monotonic()
                drain_s = 0.0
                bucket = self._caps.get(cls)
                if bucket is not None:
                    drain_s = (bucket.delay_s(cap_now)
                               + (q.queued_bytes + d.nbytes) / bucket.rate)
                if self.tenant_fair:
                    fl = q.flows.get(tenant)
                    if fl is not None and fl.bucket is not None:
                        t_drain = (fl.bucket.delay_s(cap_now)
                                   + (fl.backlog_bytes + d.nbytes)
                                   / fl.bucket.rate)
                        drain_s = max(drain_s, t_drain)
                if drain_s > 0.0:
                    capped_deadline = cap_now + drain_s + spec.deadline_s
                    if capped_deadline > d.deadline:
                        d.deadline = capped_deadline
                        self.stats[cls].cap_deadline_stretches += 1
            q.append(d)
            handle._outstanding += 1
            st = self.stats[cls]
            st.submitted += 1
            st.bytes_total += d.nbytes
            ts = st.tenant(tenant)
            ts.submitted += 1
            ts.bytes_total += d.nbytes
            while self._alive < self.workers:
                t = threading.Thread(target=self._run, daemon=True)
                t.start()
                self._threads.append(t)
                self._alive += 1
            self._threads = [t for t in self._threads if t.is_alive()]
            self._cond.notify()
        return d.done, d.out

    # -- arbitration ---------------------------------------------------------
    def _pick_locked(self) -> _Descriptor | None:  # requires-lock: _cond
        """Choose the next descriptor. Caller holds ``_cond``."""
        assert_held(self._cond, "_pick_locked")
        now = time.monotonic()
        self._cap_wait_hint = None
        if not self.fair:
            # FIFO baseline: oldest submit across every class (and across
            # every tenant flow inside each class — oldest() scans flow
            # heads, so the baseline ignores both arbitration tiers).
            d = None
            for q in self._queues.values():
                head = q.oldest()
                if head is not None and (d is None
                                         or head.t_submit < d.t_submit):
                    d = head
            if d is None:
                return None
            self._queues[d.cls].pop(d)
        else:
            # tier 2 first: each class nominates ONE candidate head.
            # Inside head(): parked resumes outrank everything (charge-
            # once, they hold in-service state), then EDF over overdue
            # tenant heads, then the min-vtime tenant flow; a tenant whose
            # token bucket is dry is skipped with its deferral counted and
            # the earliest refill folded into the wait hint.
            heads: dict[PriorityClass, _Descriptor] = {}
            for cls, q in self._queues.items():
                if not q:
                    continue
                cand, hint = q.head(now)
                if hint is not None and (self._cap_wait_hint is None
                                         or hint < self._cap_wait_hint):
                    self._cap_wait_hint = hint
                if cand is not None:
                    heads[cls] = cand
            # 0) bandwidth caps, class tier: a class whose candidate needs
            # a first dispatch but whose token bucket is empty is not
            # eligible at ANY level below (EDF must not override a cap —
            # the ceiling is hard). Record the earliest refill so a worker
            # finding only capped work parks on a timed wait instead of
            # idle-exiting. A PARKED resume is exempt: its bytes were
            # charged at first dispatch (charge-once), it holds a ring
            # slot and mid-chunk iterator state — re-gating it on the
            # deficit it itself created would stall an in-service
            # descriptor for the whole refill.
            for cls in list(heads):
                bucket = self._caps.get(cls)
                if (bucket is not None and not heads[cls].started
                        and not bucket.ready(now)):
                    del heads[cls]
                    self.stats[cls].cap_deferrals += 1
                    wait = bucket.delay_s(now)
                    if (self._cap_wait_hint is None
                            or wait < self._cap_wait_hint):
                        self._cap_wait_hint = wait
            # 1) reserved latency lane: dispatch is non-preemptive, so while
            # a TOKEN/SENSOR source exists, the last worker slot(s) refuse
            # LAYER/BULK — a token must never find every worker mid-bulk-
            # memcpy. An in-service worker always frees eventually, so the
            # deferred bulk head is re-picked on its completion notify
            # (bulk is serialized to workers-reserve while the lane is
            # active, never starved). Recency-gated: the lane releases
            # once latency-class traffic has been quiet for
            # ``latency_recency_s``, even if an idle serving engine is
            # still registered.
            reserve = min(self.reserve_latency_workers, self.workers - 1)
            lane_active = (
                now - self._latency_last_event < self.latency_recency_s)
            latency_only = (lane_active and reserve > 0
                            and self._executing >= self.workers - reserve)
            if latency_only:
                heads = {c: h for c, h in heads.items()
                         if c in _LATENCY_CLASSES}
            # 2) deadline promotion: EDF over overdue candidate heads.
            # Absolute deadlines make this starvation-free (old BULK
            # eventually outranks fresh TOKEN).
            d = None
            for cand in heads.values():
                if cand.deadline <= now and (d is None
                                             or cand.deadline < d.deadline):
                    d = cand
            if d is not None:
                self.stats[d.cls].deadline_promotions += 1
            else:
                # 3) weighted fair: busy class with the smallest vtime.
                if not heads:
                    return None
                d = heads[min(heads, key=lambda c: self._vtime[c])]
            self._queues[d.cls].pop(d)
        st = self.stats[d.cls]
        if not d.started:
            # first dispatch: charge fair-queue virtual time and the cap
            # buckets ONCE for the whole descriptor (a parked resume is
            # not a new arrival) at BOTH tiers — class vtime/bucket here,
            # tenant vtime/bucket via charge_dispatch — and stamp the
            # queue-wait latency into both ledgers.
            d.started = True
            if self.fair:
                self._vtime[d.cls] += (
                    max(d.nbytes, 1024) / self.qos[d.cls].weight)
                bucket = self._caps.get(d.cls)
                if bucket is not None:
                    bucket.charge(d.nbytes)
                self._queues[d.cls].charge_dispatch(d)
            st.dispatched += 1
            st.dispatch_lat_s.append(now - d.t_submit)
            ts = st.tenant(d.tenant)
            ts.dispatched += 1
            ts.dispatch_lat_s.append(now - d.t_submit)
            missed = int(d.deadline <= now)
            self._miss_window[d.cls].append((now, missed))
            if missed:
                st.deadline_misses += 1
                ts.deadline_misses += 1
            # dispatch_recent feeds the adaptive crossover's effective t0:
            # a batched group (units > 1) pays ONE queue wait for its whole
            # set of logical descriptors, so the per-descriptor price the
            # cost model should see is amortized. dispatch_lat_s above
            # stays raw wall-clock for the p99 summaries.
            st.dispatch_recent.append(
                (now, (now - d.t_submit) / d.units))
            self.dispatches += 1
        elif d.t_parked is not None:
            # resuming preempted work: record how long it sat parked.
            st.preempt_park_s.append(now - d.t_parked)
            d.t_parked = None
        self._executing += 1
        self._executing_by[d.cls] += 1
        return d

    # -- the event loop ------------------------------------------------------
    def _run(self) -> None:
        try:
            self._run_loop()
        except BaseException:
            # a KeyboardInterrupt/SystemExit escaping a task must not
            # strand the worker accounting (submit would never respawn)
            with self._cond:
                if self._bg_spinner == threading.get_ident():
                    self._bg_spinner = None
                self._alive -= 1
            raise

    def _run_loop(self) -> None:
        me = threading.get_ident()
        while True:
            bg_fn = None
            stay = False
            with self._cond:
                due = self._drain_due_locked()
                d = self._pick_locked()
                is_spinner = False
                if d is None and not due and not self._closed:
                    # exactly ONE worker polls the background lane at the
                    # fast cadence; the rest wait at idle_timeout_s and
                    # may idle-exit — N workers must not busy-wake every
                    # millisecond for a lane only one of them can claim.
                    is_spinner = bool(self._background) and (
                        self._bg_spinner is None or self._bg_spinner == me)
                    if is_spinner:
                        self._bg_spinner = me
                    timeout = (_BG_IDLE_WAIT_S if is_spinner
                               else self.idle_timeout_s)
                    if self._cap_wait_hint is not None:
                        # only cap-deferred work is queued: park exactly
                        # until the earliest bucket refill, then re-pick.
                        timeout = min(timeout,
                                      max(self._cap_wait_hint, 1e-4))
                    vec_hint = self._vector_wait_hint_locked()
                    if vec_hint is not None:
                        # coalesced completions pending: wake at the
                        # earliest vector budget deadline, never later.
                        timeout = min(timeout, max(vec_hint, 1e-4))
                    self._cond.wait(timeout)
                    due = self._drain_due_locked()
                    d = self._pick_locked()
                if d is None and not due:
                    if not self._closed and (
                            any(self._queues.values())
                            or any(self._vectors.values())):
                        # queued work exists but is deferred (cap bucket
                        # refilling / reserved lane), or a completion
                        # vector is still filling: this worker must NOT
                        # idle-exit — with a cap, no completion notify may
                        # ever come to wake a respawned worker.
                        stay = True
                    elif (self._closed or not self._background
                            or not is_spinner):
                        # provably idle under the lock (submit enqueues
                        # under the same lock): safe to exit.
                        if self._bg_spinner == me:
                            self._bg_spinner = None
                        self._alive -= 1
                        return
                    else:
                        bg_fn = self._next_background_locked()
            for b in due:
                self._deliver(b)
            if d is not None:
                if not self._execute(d):
                    continue  # parked mid-chunk: it resumes via the queue
                self._bg_slice_after_dispatch()
            elif bg_fn is not None:
                self._run_background(bg_fn)
            elif stay:
                continue

    def _park_locked_check(self, d: _Descriptor, t_stint: float) -> bool:
        """Between two segments of a PreemptibleWork: park ``d`` iff a
        latency-class descriptor is waiting and no idle worker can take it.
        Returns True when parked (the caller must NOT complete the
        descriptor — it re-dispatches from the front of its class queue)."""
        if (not self.preempt or not self.fair
                or d.cls in _LATENCY_CLASSES):
            return False
        with self._cond:
            if self._executing < self._alive:
                # an idle worker exists; it will take the latency arrival
                # — parking here would only add a resume round-trip.
                return False
            if not any(self._queues[c] for c in _LATENCY_CLASSES):
                return False
            d.service_acc += time.perf_counter() - t_stint
            d.preemptions += 1
            d.t_parked = time.monotonic()
            # renewed deadline: EDF must see the park as a fresh arrival,
            # or the long-overdue bulk head would immediately outrank the
            # very token it just yielded to. Starvation-free regardless —
            # parked work runs at least one segment between parks.
            d.deadline = d.t_parked + self.qos[d.cls].deadline_s
            self._queues[d.cls].appendleft(d)
            self.stats[d.cls].preemptions += 1
            self._executing -= 1
            self._executing_by[d.cls] -= 1
            self._cond.notify()
            return True

    def _execute(self, d: _Descriptor) -> bool:
        """Run a descriptor body (possibly one stint of a PreemptibleWork).
        Returns False when the work parked mid-chunk (not complete)."""
        work = d.fn if isinstance(d.fn, PreemptibleWork) else None
        result: Any = None
        err: BaseException | None = None
        t0 = time.perf_counter()
        if work is None:
            try:
                result = d.fn()
            except BaseException as e:  # surfaced at Ticket.wait()
                err = e
        else:
            while True:
                try:
                    if work.step():
                        result = work.result()
                        break
                except BaseException as e:  # surfaced at Ticket.wait()
                    err = e
                    break
                if not work.exhausted and self._park_locked_check(d, t0):
                    return False
        d.service_acc += time.perf_counter() - t0
        if work is not None and work.finalize is not None:
            try:
                work.finalize(err)
            except BaseException as e:  # noqa: BLE001
                if err is None:
                    err = e
        d.out.append(err if err is not None else result)
        # hand the completion to the per-class vector: it either flushes a
        # batch now (immediate / max_batch / pipeline-empty / error) or
        # parks the descriptor until the budget deadline. _executing drops
        # here regardless — the WORKER is free even when the runtime-level
        # (stats, done-event, outstanding) handoff is deferred. Due
        # vectors of OTHER classes ride the same lock acquisition so a
        # fully-busy pool still bounds their staleness.
        with self._cond:
            self._executing -= 1
            self._executing_by[d.cls] -= 1
            batch = self._vector_add_locked(d, err)
            due = self._drain_due_locked()
            if any(self._queues.values()):
                # a worker slot just freed: a head deferred by the reserved
                # latency lane (or parked waiters) must be re-examined NOW
                self._cond.notify()
        self._deliver(batch)
        for b in due:
            self._deliver(b)
        return True

    # -- completion vectors (MSI-X-style coalescing) --------------------------
    # requires-lock: _cond
    def _vector_add_locked(self, d: _Descriptor,
                           err: BaseException | None
                           ) -> tuple[PriorityClass, list] | None:
        """Fold a finished descriptor into its class completion vector.
        Returns a batch ``(cls, [(descriptor, t_done), ...])`` the CALLER
        must hand to :meth:`_deliver` after releasing the lock, or None
        when the completion coalesced (a later flush delivers it)."""
        assert_held(self._cond, "_vector_add_locked")
        now = time.monotonic()
        # EWMA of the inter-completion gap — the adaptive signal: when
        # completions arrive slower than the coalescing budget, batching
        # can never fill a vector in time and would only add latency.
        gap = now - self._coalesce_last[d.cls]
        self._coalesce_last[d.cls] = now
        prev = self._coalesce_gap[d.cls]
        self._coalesce_gap[d.cls] = (
            gap if prev == float("inf") else 0.75 * prev + 0.25 * gap)
        vec = self._vectors[d.cls]
        entry = (d, now)
        pol = self.coalesce.get(d.cls)
        if (pol is None or pol.max_batch <= 1 or err is not None
                or self._closed or d.handle._closed):
            # immediate delivery — an error (or teardown) also flushes the
            # whole vector so completion order within the class holds.
            if vec:
                self._vectors[d.cls] = []
                return (d.cls, vec + [entry])
            return (d.cls, [entry])
        if not vec and self._coalesce_gap[d.cls] > pol.budget_s:
            return (d.cls, [entry])  # sparse arrivals: don't coalesce
        vec.append(entry)
        if len(vec) == 1:
            self._vec_deadline[d.cls] = now + pol.budget_s
        if (len(vec) >= pol.max_batch
                or (not self._queues[d.cls]
                    and self._executing_by[d.cls] == 0)):
            # full vector — or the class pipeline just drained: the wave
            # is over, a synchronous waiter must not eat the budget timer.
            self._vectors[d.cls] = []
            return (d.cls, vec)
        return None

    def _drain_due_locked(self) -> list[tuple[PriorityClass, list]]:  # requires-lock: _cond
        """Pop every class vector whose budget deadline has passed; the
        caller delivers them outside the lock."""
        assert_held(self._cond, "_drain_due_locked")
        now = time.monotonic()
        batches = []
        for cls, vec in self._vectors.items():
            if vec and now >= self._vec_deadline[cls]:
                self._vectors[cls] = []
                batches.append((cls, vec))
        return batches

    def _drain_all_locked(self) -> list[tuple[PriorityClass, list]]:  # requires-lock: _cond
        """Pop every non-empty class vector regardless of deadline (early
        delivery is always safe); used by teardown and timeout escalation."""
        assert_held(self._cond, "_drain_all_locked")
        batches = []
        for cls, vec in self._vectors.items():
            if vec:
                self._vectors[cls] = []
                batches.append((cls, vec))
        return batches

    def _vector_wait_hint_locked(self) -> float | None:  # requires-lock: _cond
        """Seconds until the earliest pending vector deadline (None when
        every vector is empty) — idle workers clamp their wait on it so a
        coalesced completion is never stranded past its budget."""
        assert_held(self._cond, "_vector_wait_hint_locked")
        now = time.monotonic()
        hint = None
        for cls, vec in self._vectors.items():
            if vec:
                wait = self._vec_deadline[cls] - now
                if hint is None or wait < hint:
                    hint = wait
        return hint

    def _deliver(self, batch: tuple[PriorityClass, list] | None) -> None:
        """Complete one coalesced batch — ONE wakeup's worth of handoffs.
        Preserves :meth:`_execute`'s load-bearing three-step ordering,
        batched:
        1. completion stats BEFORE the done events — a caller unblocked
           by wait() must see its own completion in class_summary();
        2. the done events, in completion order — tickets resolve;
        3. outstanding AFTER done — a close() drain observing
           outstanding == 0 may then rely on every ticket being set."""
        if not batch:
            return
        cls, entries = batch
        t_flush = time.monotonic()
        with self._cond:
            st = self.stats[cls]
            st.completion_wakeups += 1
            st.wakeups_saved += len(entries) - 1
            st.coalesce_batch.append(len(entries))
            for d, t_done in entries:
                st.completed += 1
                st.tenant(d.tenant).completed += 1
                st.service_lat_s.append(d.service_acc)
                st.coalesce_delay_s.append(t_flush - t_done)
        for d, _ in entries:
            d.done.set()
        with self._cond:
            for d, _ in entries:
                d.handle._outstanding -= 1
            if any(self._queues.values()):
                self._cond.notify()
            for d, _ in entries:
                if d.handle._closed and d.handle._outstanding <= 0:
                    self._cond.notify_all()
                    break
        return

    # -- background (SENSOR ingest) ------------------------------------------
    def _next_background_locked(self) -> Callable[[], None] | None:  # requires-lock: _cond
        """Claim the background lane (single-flight). Caller must run the
        returned fn via :meth:`_run_background`, which releases the lane —
        two workers must never run background tasks concurrently (they
        were written for the cooperative scheduler's single-threaded
        model)."""
        assert_held(self._cond, "_next_background_locked")
        if not self._background or self._bg_running:
            return None
        self._bg_running = True
        fn = self._background[self._bg_cursor % len(self._background)]
        self._bg_cursor += 1
        return fn

    def _run_background(self, fn: Callable[[], None]) -> None:
        t0 = time.perf_counter()
        try:
            while True:
                try:
                    fn()
                except Exception:  # noqa: BLE001 — KeyboardInterrupt and
                    # SystemExit propagate (the worker re-raises after
                    # fixing its accounting); a sensor that raises is
                    # deregistered so it cannot spin the worker with
                    # errors, counted in ``background_errors``.
                    with self._cond:
                        self.background_errors += 1
                        try:
                            self._background.remove(fn)
                        except ValueError:
                            pass
                    return
                with self._cond:
                    self.background_slices_run += 1
                if time.perf_counter() - t0 >= self.background_budget_s:
                    return
        finally:
            with self._cond:
                self._bg_running = False

    def _bg_slice_after_dispatch(self) -> None:
        """Mirror the cooperative scheduler's 'between DMA chunks' slice in
        interrupt mode: collection keeps running under transfer load."""
        with self._cond:
            fn = self._next_background_locked()
        if fn is not None:
            self._run_background(fn)

    # -- fault handling ------------------------------------------------------
    def note_fault(self, cls: PriorityClass, *, tenant: str | None = None,
                   faults: int = 0, retries: int = 0, timeouts: int = 0,
                   quarantines: int = 0) -> None:
        """Fold fault-layer events observed OUTSIDE the runtime (engine
        checksum failures, channel-group stripe retries, quarantines) into
        the per-class ledger — and, when ``tenant`` is given, the
        per-tenant one — so ``class_summary()`` is the one place a serving
        stack reads deadline-miss and retry rates from."""
        with self._cond:
            st = self.stats[cls]
            st.faults += faults
            st.retries += retries
            st.timeouts += timeouts
            st.quarantines += quarantines
            if tenant is not None:
                ts = st.tenant(tenant)
                ts.faults += faults
                ts.retries += retries
                ts.timeouts += timeouts
                ts.quarantines += quarantines

    def scan_timeouts(self, max_age_s: float) -> int:
        """Cancel every still-QUEUED descriptor older than ``max_age_s``,
        completing it with :class:`TransferTimeoutError` — the runtime-level
        escalation behind ``Ticket.wait(timeout=)``: a dropped completion
        becomes an error the caller can retry instead of a hang.

        Only descriptors that never started are cancellable (dispatch is
        non-preemptive, and a parked PreemptibleWork holds mid-chunk
        iterator state plus a ring slot charged at first dispatch — killing
        it here would double-release). An in-service descriptor that never
        returns is the one failure this scan cannot unstick; the injector
        never models it as unbounded for exactly that reason. Returns the
        number of descriptors timed out."""
        timed_out: list[_Descriptor] = []
        now = time.monotonic()
        with self._cond:
            # escalation implies a waiter is already past its patience:
            # flush every completion vector early (always safe) so a
            # coalesced-but-undelivered completion is never mistaken for
            # a dropped one.
            pending = self._drain_all_locked()
            for cls, q in self._queues.items():
                stale = q.drain_if(
                    lambda d: not d.started and now - d.t_submit > max_age_s)
                for d in stale:
                    d.handle._outstanding -= 1
                    st = self.stats[cls]
                    st.cancelled += 1
                    st.timeouts += 1
                    ts = st.tenant(d.tenant)
                    ts.cancelled += 1
                    ts.timeouts += 1
                    # a timed-out descriptor missed its deadline by
                    # definition: feed the admission controller's window.
                    self._miss_window[cls].append((now, 1))
                timed_out.extend(stale)
            if timed_out:
                self._cond.notify_all()
        for b in pending:
            self._deliver(b)
        # outside the lock: done.set + on_cancel run submitter-side protocol
        # (ring slot release, master-ticket errors) that takes engine locks.
        for d in timed_out:
            err = TransferTimeoutError(
                f"descriptor ({d.cls.value}, {d.nbytes} B) queued "
                f"{now - d.t_submit:.3f}s > {max_age_s:.3f}s — completion "
                "presumed dropped")
            d.out.append(err)
            d.done.set()
            if d.on_cancel is not None:
                try:
                    d.on_cancel(err)
                except BaseException:
                    pass  # the error already reached the out list
        return len(timed_out)

    # -- teardown ------------------------------------------------------------
    # requires-lock: _cond
    def _cancel_handle_locked(self, handle: RuntimeHandle
                              ) -> list[_Descriptor]:
        """Pull a handle's still-queued descriptors off the queues, flag
        them failed, and return them; the CALLER must finish them with
        :meth:`_finish_cancelled` after releasing the lock (on_cancel runs
        submitter-side completion protocol — ring slot release, master
        ticket errors — that may take engine locks)."""
        assert_held(self._cond, "_cancel_handle_locked")
        cancelled: list[_Descriptor] = []
        for cls, q in self._queues.items():
            mine = q.drain_if(lambda d: d.handle is handle)
            for d in mine:
                handle._outstanding -= 1
                self.stats[cls].cancelled += 1
                self.stats[cls].tenant(d.tenant).cancelled += 1
            cancelled.extend(mine)
        return cancelled

    @staticmethod
    def _finish_cancelled(cancelled: list[_Descriptor]) -> None:
        """Complete cancelled descriptors caller-side: error the (done,
        out) pair AND run on_cancel so every ticket issued against them
        resolves and no ring slot is orphaned. Lock NOT held."""
        for d in cancelled:
            err = RuntimeError(
                "transfer cancelled: engine closed while descriptor was "
                "queued")
            d.out.append(err)
            d.done.set()
            if d.on_cancel is not None:
                try:
                    d.on_cancel(err)
                except BaseException:
                    pass  # teardown path: the error already reached the out

    def _close_handle(self, handle: RuntimeHandle, timeout: float) -> None:
        """Drain-and-deregister: wait out the engine's queued + in-flight
        descriptors (so every issued ticket completes), cancel stragglers
        past ``timeout``, then forget the handle. Idempotent. Must be
        called from a submitter thread, never from a completion worker."""
        deadline = time.monotonic() + timeout
        cancelled: list[_Descriptor] = []
        with self._cond:
            if handle._closed and handle not in self._handles:
                return
            handle._closed = True
            # flush every coalescing vector before draining: a completion
            # parked in a vector holds _outstanding up, and the drain wait
            # below must converge on real in-flight work only.
            pending = self._drain_all_locked()
        for b in pending:
            self._deliver(b)
        with self._cond:
            while handle._outstanding > 0:
                remaining = deadline - time.monotonic()
                if remaining <= 0:
                    break
                self._cond.wait(min(remaining, 0.1))
            if handle._outstanding > 0:
                cancelled = self._cancel_handle_locked(handle)
            # in-service descriptors (not cancellable) get a short grace
            grace = time.monotonic() + 1.0
            while handle._outstanding > 0 and time.monotonic() < grace:
                self._cond.wait(0.05)
            self._handles.discard(handle)
            if handle.cls in _LATENCY_CLASSES:
                self._latency_handles = max(0, self._latency_handles - 1)
        self._finish_cancelled(cancelled)

    def close(self, timeout: float = 5.0) -> None:
        """Drain everything and join the workers (process-exit hygiene: a
        worker dying mid-JAX-call during interpreter teardown aborts from
        the C++ side). Idempotent."""
        cancelled: list[_Descriptor] = []
        with self._cond:
            if self._closed:
                return
            self._closed = True
            pending = self._drain_all_locked()
            for h in list(self._handles):
                h._closed = True
                cancelled.extend(self._cancel_handle_locked(h))
            self._handles.clear()
            self._latency_handles = 0
            self._background.clear()
            threads = list(self._threads)
            self._cond.notify_all()
        for b in pending:
            self._deliver(b)
        self._finish_cancelled(cancelled)
        for t in threads:
            t.join(timeout=timeout)

    def __enter__(self) -> "TransferRuntime":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # -- reporting -----------------------------------------------------------
    def class_summary(self) -> dict[str, dict[str, float]]:
        """Per-class bandwidth/latency accounting (the ZynqNet per-class
        traffic ledger, including cap enforcement + preemption columns)."""
        with self._cond:
            out = {}
            for cls, st in self.stats.items():
                if not st.submitted:
                    continue
                row = st.summary()
                bucket = self._caps.get(cls)
                row["cap_bytes_per_s"] = (bucket.rate if bucket is not None
                                          else None)
                pol = self.coalesce.get(cls)
                row["coalesce_max_batch"] = (pol.max_batch
                                             if pol is not None else 1)
                row["deadline_miss_rate"] = self._miss_rate_locked(cls)
                q = self._queues[cls]
                tenants = {}
                for tenant, ts in st.tenants.items():
                    if not (ts.submitted or ts.faults or ts.retries):
                        continue
                    trow = ts.summary()
                    trow["queued"] = q.depth(tenant)
                    trow["cap_bytes_per_s"] = q.cap(tenant)
                    tenants[tenant] = trow
                row["tenants"] = tenants
                out[cls.value] = row
            return out

    def recent_dispatch_latency(self, cls: PriorityClass, q: float = 0.5,
                                ttl_s: float = 10.0) -> float | None:
        """Dispatch-latency percentile over the last ``ttl_s`` seconds for
        one class — the queue wait the online controller folds into the
        interrupt driver's effective t0 when re-deciding the polling
        crossover. Time-bounded on purpose: a burst from minutes ago must
        not keep inflating the crossover after the contention ended
        (``None`` means "no recent traffic" and the consumer decays)."""
        cutoff = time.monotonic() - ttl_s
        with self._cond:
            samples = [lat for t, lat in self.stats[cls].dispatch_recent
                       if t >= cutoff]
        if not samples:
            return None
        return _pct(samples, q)


# ---------------------------------------------------------------------------
# Process-wide default runtime
# ---------------------------------------------------------------------------

_global_lock = make_lock("runtime._global_lock")
_global_runtime: TransferRuntime | None = None


def _shutdown_global() -> None:
    global _global_runtime
    with _global_lock:
        rt, _global_runtime = _global_runtime, None
    if rt is not None:
        rt.close()


def get_runtime() -> TransferRuntime:
    """The process-shared TransferRuntime every kernel-mode engine joins by
    default. Created lazily; joined at interpreter exit."""
    global _global_runtime
    with _global_lock:
        if _global_runtime is None or _global_runtime._closed:
            _global_runtime = TransferRuntime()
            atexit.register(_shutdown_global)
        return _global_runtime


def set_runtime(runtime: TransferRuntime | None) -> TransferRuntime | None:
    """Swap the process-default runtime (tests/benchmarks); returns the
    previous one (NOT closed — caller owns both)."""
    global _global_runtime
    with _global_lock:
        prev, _global_runtime = _global_runtime, runtime
        return prev


# ---------------------------------------------------------------------------
# User-level backends of the same submit contract
# ---------------------------------------------------------------------------

@dataclass
class SchedulerStats:
    transfer_tasks_run: int = 0
    background_slices_run: int = 0
    drain_calls: int = 0
    total_background_s: float = 0.0


class CooperativeScheduler:
    """The paper's 'user-level scheduled' driver (re-homed from
    ``repro.core.scheduler``): a plain round-robin cooperative scheduler.
    ``submit`` enqueues a transfer task, ``register_background`` adds a
    recurring task given a slice between transfer tasks, ``drain`` runs
    until the transfer queue is empty. Single-threaded by design — the
    point of this mode is avoiding threads/interrupts while still not
    monopolising the CPU. It is the user-level twin of
    :class:`TransferRuntime`'s background-task lane."""

    def __init__(self, background_budget_s: float = 50e-6):
        self._transfers: "collections.deque[Callable[[], None]]" = (
            collections.deque())
        self._background: list[Callable[[], None]] = []
        self._bg_cursor = 0
        self.background_budget_s = background_budget_s
        self.stats = SchedulerStats()

    def submit(self, task: Callable[[], None]) -> None:
        self._transfers.append(task)

    def register_background(self, task: Callable[[], None]
                            ) -> Callable[[], None]:
        """Register a recurring background task (e.g. data normalisation).
        Returns an unregister callable (mirrors the runtime's API)."""
        self._background.append(task)

        def unregister() -> None:
            try:
                self._background.remove(task)
            except ValueError:
                pass
        return unregister

    def _run_background_slice(self) -> None:
        if not self._background:
            return
        t0 = time.perf_counter()
        while time.perf_counter() - t0 < self.background_budget_s:
            task = self._background[self._bg_cursor % len(self._background)]
            self._bg_cursor += 1
            task()
            self.stats.background_slices_run += 1
            if not self._background:
                break
        self.stats.total_background_s += time.perf_counter() - t0

    def drain(self) -> None:
        """Run transfer tasks to completion, interleaving background."""
        self.stats.drain_calls += 1
        while self._transfers:
            task = self._transfers.popleft()
            task()
            self.stats.transfer_tasks_run += 1
            self._run_background_slice()


class PollingBackend:
    """User-level polling as a backend: the submit IS the transfer — runs
    inline on the caller and returns an already-set event. Engines keep an
    equivalent inline fast path and never construct this; it exists so the
    three paper modes share one demonstrable API."""

    def submit(self, fn: Callable[[], Any], nbytes: int = 0,
               priority: PriorityClass | None = None
               ) -> tuple[threading.Event, list]:
        done = threading.Event()
        out: list = []
        try:
            out.append(fn())
        except BaseException as e:
            out.append(e)
        done.set()
        return done, out

    def close(self) -> None:
        pass


class ScheduledBackend:
    """User-level scheduled driver as a backend: descriptors become
    cooperative-scheduler tasks; the caller runs them via ``drain()``
    (single-threaded, background tasks interleaved)."""

    def __init__(self, scheduler: CooperativeScheduler | None = None):
        self.scheduler = scheduler or CooperativeScheduler()

    def submit(self, fn: Callable[[], Any], nbytes: int = 0,
               priority: PriorityClass | None = None
               ) -> tuple[threading.Event, list]:
        done = threading.Event()
        out: list = []

        def task() -> None:
            try:
                out.append(fn())
            except BaseException as e:
                out.append(e)
            done.set()

        self.scheduler.submit(task)
        return done, out

    def drain(self) -> None:
        self.scheduler.drain()

    def close(self) -> None:
        pass


def backend_for(management: Any, *,
                runtime: TransferRuntime | None = None,
                scheduler: CooperativeScheduler | None = None,
                priority: PriorityClass = PriorityClass.LAYER,
                owner: Any = None):
    """One constructor for the three paper modes. ``management`` is a
    :class:`~repro.core.transfer.Management` or its string value (kept
    stringly to avoid an import cycle)."""
    mode = getattr(management, "value", management)
    if mode == "polling":
        return PollingBackend()
    if mode == "scheduled":
        return ScheduledBackend(scheduler)
    if mode == "interrupt":
        return (runtime or get_runtime()).register(owner or "backend_for",
                                                   priority)
    raise ValueError(f"unknown management mode: {management!r}")


# ---------------------------------------------------------------------------
# Dedicated pool for long-occupancy work (checkpoint writes)
# ---------------------------------------------------------------------------

class DedicatedWorkerPool:
    """Private worker pool for tasks that hold a thread for a long time
    (multi-second checkpoint writes). Those must NOT ride the shared
    runtime — a BULK descriptor in service occupies a shared worker for
    its whole duration, which is exactly the head-of-line blocking the
    runtime exists to prevent. Same queue/idle-exit structure the retired
    per-engine ``_CompletionPool`` had; same ``submit`` contract."""

    _SENTINEL = (None, None, None)

    def __init__(self, workers: int = 1, idle_timeout_s: float = 30.0) -> None:
        self.workers = max(1, workers)
        self.idle_timeout_s = idle_timeout_s
        self._q: ("queue.Queue[tuple[Callable[[], Any] | None, "
                  "threading.Event | None, list | None]]") = queue.Queue()
        self._lock = make_lock("DedicatedWorkerPool._lock")
        self._alive = 0                   # guarded-by: _lock
        self._threads: list[threading.Thread] = []  # guarded-by: _lock
        self._closed = False              # guarded-by: _lock

    def _run(self) -> None:
        while True:
            try:
                fn, done, out = self._q.get(timeout=self.idle_timeout_s)
            except queue.Empty:
                # exit only when the queue is provably empty under the lock:
                # submit() enqueues under the same lock, so a descriptor can
                # never be stranded between our timeout and our exit.
                with self._lock:
                    if not self._q.empty():
                        continue
                    self._alive -= 1
                return
            if fn is None:  # sentinel from close()
                with self._lock:
                    self._alive -= 1
                return
            try:
                out.append(fn())
            except BaseException as e:  # surfaced at wait()
                out.append(e)
            done.set()

    def submit(self, fn: Callable[[], Any]) -> tuple[threading.Event, list]:
        done = threading.Event()
        out: list = []
        with self._lock:
            if self._closed:
                raise RuntimeError("submit() on a closed DedicatedWorkerPool")
            self._q.put((fn, done, out))
            while self._alive < self.workers:
                t = threading.Thread(target=self._run, daemon=True)
                t.start()
                self._threads.append(t)
                self._alive += 1
            self._threads = [t for t in self._threads if t.is_alive()]
        return done, out

    def close(self) -> None:
        with self._lock:
            self._closed = True
            n = self._alive
            threads = list(self._threads)
        for _ in range(n):
            self._q.put(self._SENTINEL)
        # join so no worker is still tearing down when the caller (possibly
        # the interpreter at exit) proceeds — a dying worker racing runtime
        # shutdown aborts the process from the C++ side.
        for t in threads:
            t.join(timeout=5.0)


# Back-compat alias for the retired per-engine pool's name.
_CompletionPool = DedicatedWorkerPool
