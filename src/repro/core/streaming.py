"""Per-layer streaming executor — the NullHop execution model, generalised.

NullHop processes a multi-layer CNN *one layer at a time*: the host streams
the layer's parameters (TX), then the input feature maps; the MAC array
starts computing as soon as a couple of rows arrive; output feature maps
stream back (RX) and become the next layer's input. Total frame time is the
per-layer sum of (TX + compute + RX), with overlap determined by the
transfer policy.

Here the same execution model serves models whose parameters exceed device
memory (or that we deliberately execute layer-resident to minimise HBM
footprint): layer k's weights are staged host->device while layer k-1
computes. With ``TransferPolicy.INTERRUPT`` + DOUBLE buffering the weight
stream hides behind compute exactly as the paper's double-buffered blocks
mode hides staging behind DMA.

Two implementations:

- :class:`HostStreamingExecutor` — real host->device staging (measured here);
  used by the serving engine's ``layer_streaming`` mode and the NullHop
  benchmarks.
- :func:`device_streamed_scan` — the on-device analogue for the dry-run: a
  ``jax.lax.scan`` over layers where each layer's params are all-gathered
  from their sharded resting place just-in-time (the TPU equivalent of
  per-layer TX), letting XLA overlap the gather of layer k+1 with layer k's
  compute. This is what the multi-pod configs lower.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.transfer import (
    Buffering,
    Management,
    Ticket,
    TransferEngine,
    TransferPolicy,
)


@dataclass
class LayerTiming:
    name: str
    tx_s: float
    compute_s: float
    rx_s: float
    tx_bytes: int
    rx_bytes: int

    @property
    def total_s(self) -> float:
        return self.tx_s + self.compute_s + self.rx_s


@dataclass
class FrameTiming:
    """Timing of one full multi-layer execution (one 'frame' in the paper)."""

    layers: list[LayerTiming] = field(default_factory=list)

    @property
    def frame_s(self) -> float:
        return sum(l.total_s for l in self.layers)

    @property
    def tx_us_per_byte(self) -> float:
        b = sum(l.tx_bytes for l in self.layers)
        t = sum(l.tx_s for l in self.layers)
        return t * 1e6 / max(b, 1)

    @property
    def rx_us_per_byte(self) -> float:
        b = sum(l.rx_bytes for l in self.layers)
        t = sum(l.rx_s for l in self.layers)
        return t * 1e6 / max(b, 1)


class HostStreamingExecutor:
    """Run a sequence of layers, staging each layer's params host->device
    under the engine's policy, optionally prefetching the next layer.

    ``layers`` is a list of (name, param_host_arrays, apply_fn) where
    ``apply_fn(params_device_list, x)`` returns the layer output. With an
    INTERRUPT policy the next layer's TX is issued *before* the current
    layer's compute (double-buffer prefetch), reproducing the paper's
    overlap; with POLLING everything serialises."""

    def __init__(self, engine: TransferEngine):
        self.engine = engine

    def run(
        self,
        layers: Sequence[tuple[str, list[np.ndarray], Callable[..., jax.Array]]],
        x: np.ndarray,
    ) -> tuple[np.ndarray, FrameTiming]:
        policy = self.engine.policy
        prefetch = (
            policy.management is Management.INTERRUPT
            and policy.buffering is Buffering.DOUBLE
        )
        timing = FrameTiming()

        # TX the input once (first layer's feature map)
        t0 = time.perf_counter()
        xa = np.asarray(x)
        dev_chunks = self.engine.tx(xa)
        flat = (dev_chunks[0] if len(dev_chunks) == 1
                else jnp.concatenate([c.reshape(-1) for c in dev_chunks]))
        x_dev = flat.reshape(xa.shape)  # tx() streams a flat view
        input_tx_s = time.perf_counter() - t0

        pending: Ticket | None = None
        pending_params: list | None = None
        if prefetch and layers:
            name0, params0, _ = layers[0]
            stacked = _pack(params0)
            pending = self.engine.tx_async(stacked)

        for i, (name, params_host, apply_fn) in enumerate(layers):
            # --- TX params for this layer
            t0 = time.perf_counter()
            if prefetch:
                chunks = pending.wait()
                params_dev = _unpack(chunks, params_host)
                # issue next layer's TX immediately (overlaps compute below)
                if i + 1 < len(layers):
                    pending = self.engine.tx_async(_pack(layers[i + 1][1]))
            else:
                chunks = self.engine.tx(_pack(params_host))
                params_dev = _unpack(chunks, params_host)
            tx_s = time.perf_counter() - t0
            tx_bytes = sum(p.nbytes for p in params_host)
            if i == 0:
                tx_s += input_tx_s
                tx_bytes += np.asarray(x).nbytes

            # --- compute
            t0 = time.perf_counter()
            y = apply_fn(params_dev, x_dev)
            y.block_until_ready()
            compute_s = time.perf_counter() - t0

            # --- RX (per the paper, each layer's output returns to the PS)
            t0 = time.perf_counter()
            host_out = self.engine.rx([y])[0]
            rx_s = time.perf_counter() - t0

            timing.layers.append(
                LayerTiming(name, tx_s, compute_s, rx_s, tx_bytes, host_out.nbytes)
            )
            x_dev = y  # next layer consumes device-resident output
        return host_out, timing


def _pack(arrays: list[np.ndarray]) -> np.ndarray:
    """Flatten a param list into one contiguous staging payload (the paper
    sends each layer's kernels as one stream)."""
    if not arrays:
        return np.zeros((0,), np.float32)
    return np.concatenate([np.asarray(a).reshape(-1).view(np.uint8) for a in arrays])


def _unpack(chunks: list[jax.Array], ref: list[np.ndarray]) -> list[jax.Array]:
    flat = chunks[0] if len(chunks) == 1 else jnp.concatenate(
        [c.reshape(-1) for c in chunks]
    )
    out, off = [], 0
    for a in ref:
        a = np.asarray(a)
        out.append(
            jax.lax.bitcast_convert_type(
                flat[off : off + a.nbytes].reshape(a.shape + (a.dtype.itemsize,)),
                a.dtype,
            ).reshape(a.shape)
            if a.dtype.itemsize > 1
            else flat[off : off + a.nbytes].reshape(a.shape)
        )
        off += a.nbytes
    return out


def device_streamed_scan(
    layer_fn: Callable[[Any, jax.Array], jax.Array],
    stacked_params: Any,
    x: jax.Array,
    *,
    gather_fn: Callable[[Any], Any] | None = None,
    unroll: int = 1,
) -> jax.Array:
    """On-device per-layer streaming: scan over stacked layer params.

    ``gather_fn`` (if given) materialises one layer's params from their
    sharded/compressed resting state — the device-side analogue of the
    per-layer TX. XLA schedules the gather of iteration k+1 concurrently
    with iteration k's compute when the dependency allows (double buffer)."""

    def body(carry, layer_params):
        if gather_fn is not None:
            layer_params = gather_fn(layer_params)
        return layer_fn(layer_params, carry), None

    y, _ = jax.lax.scan(body, x, stacked_params, unroll=unroll)
    return y
