"""Per-layer streaming executor — the NullHop execution model on a ring.

NullHop processes a multi-layer CNN *one layer at a time*: the host streams
the layer's parameters (TX), then the input feature maps; the MAC array
starts computing as soon as a couple of rows arrive; output feature maps
stream back (RX) and become the next layer's input. Total frame time is the
per-layer sum of (TX + compute + RX), with overlap determined by the
transfer policy.

Here the same execution model serves models whose parameters exceed device
memory (or that we deliberately execute layer-resident to minimise HBM
footprint). Under an INTERRUPT policy with ring depth >= 2 the executor runs
**three-way overlap** — the paper's balanced-TX/RX goal:

    TX(layer k+1)  ─┐
    compute(k)      ├─ concurrent (per-engine completion workers + main thread)
    RX(layer k-1)  ─┘

Layer k+1's parameters are packed into their cached :class:`StagedLayout`
staging buffer and stream host->device while layer k computes; layer k-1's
output feature map streams device->host (``rx_async``) at the same time.
Staging layouts are resolved once per layer identity through the engine's
:class:`LayoutCache`, so steady-state frames do zero pack allocation — and
zero pack *copies* when the host params are unchanged (inference weight
streaming), the ZynqNet one-time-layout lesson.

The seed's per-frame pack path (``np.concatenate`` per layer per frame,
depth-2 max) is kept behind ``staged=False`` as the benchmark baseline.

Two implementations:

- :class:`HostStreamingExecutor` — real host->device staging (measured here);
  used by the serving engine's ``layer_streaming`` mode and the NullHop
  benchmarks.
- :func:`device_streamed_scan` — the on-device analogue for the dry-run: a
  ``jax.lax.scan`` over layers where each layer's params are all-gathered
  from their sharded resting place just-in-time (the TPU equivalent of
  per-layer TX), letting XLA overlap the gather of layer k+1 with layer k's
  compute. This is what the multi-pod configs lower.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Sequence

import jax
import numpy as np

from repro.core.transfer import (
    Management,
    StagedLayout,
    Ticket,
    TransferEngine,
    _bitcast_from_bytes,
    reassemble_chunks,
)


@dataclass
class LayerTiming:
    name: str
    tx_s: float
    compute_s: float
    rx_s: float
    tx_bytes: int
    rx_bytes: int

    @property
    def total_s(self) -> float:
        return self.tx_s + self.compute_s + self.rx_s


@dataclass
class FrameTiming:
    """Timing of one full multi-layer execution (one 'frame' in the paper)."""

    layers: list[LayerTiming] = field(default_factory=list)

    @property
    def frame_s(self) -> float:
        return sum(l.total_s for l in self.layers)

    @property
    def tx_us_per_byte(self) -> float:
        b = sum(l.tx_bytes for l in self.layers)
        t = sum(l.tx_s for l in self.layers)
        return t * 1e6 / max(b, 1)

    @property
    def rx_us_per_byte(self) -> float:
        b = sum(l.rx_bytes for l in self.layers)
        t = sum(l.rx_s for l in self.layers)
        return t * 1e6 / max(b, 1)


class HostStreamingExecutor:
    """Run a sequence of layers, staging each layer's params host->device
    under the engine's policy, with ring-depth-controlled prefetch.

    ``layers`` is a list of (name, param_host_arrays, apply_fn) where
    ``apply_fn(params_device_list, x)`` returns the layer output. With an
    INTERRUPT policy of ring depth >= 2 the executor overlaps layer k+1's TX
    *and* layer k-1's RX with layer k's compute; with POLLING everything
    serialises.

    ``engine`` may be a single :class:`TransferEngine` or a
    :class:`repro.core.channels.ChannelGroup` — the group stripes each
    layer's payload across its member rings (multi-channel DMA), and the
    executor code is identical because the group duck-types the engine.

    ``staged=False`` selects the legacy per-frame pack path (re-concatenates
    params every frame) — kept only as the measured baseline for
    ``BENCH_transfer.json``.

    ``sensor_fn``: optional frame-ingest callable, registered as a
    ``SENSOR``-class background task for the duration of each ``run()`` —
    the paper's concurrent collection+transfer scenario. Under INTERRUPT
    management the shared runtime gives it budgeted slices between
    completion dispatches; under SCHEDULED the cooperative scheduler
    interleaves it between DMA chunks; under POLLING it starves (the
    paper's warning: the polling driver blocks the whole system).
    """

    def __init__(self, engine: "TransferEngine | Any", *, staged: bool = True,
                 zero_copy_rx: bool = True,
                 sensor_fn: Callable[[], None] | None = None):
        self.engine = engine
        self.staged = staged
        self.sensor_fn = sensor_fn
        self.sensor_slices = 0  # background slices observed across runs
        # per-layer host output buffers, reused frame after frame: with
        # ``zero_copy_rx`` each INTERIOR layer's fmap RX lands in the SAME
        # executor-owned buffer every frame (``rx_async(..., out=)``), so
        # steady-state frames allocate nothing on the readback side. The
        # FINAL layer's output — the frame result handed to the caller —
        # is always a fresh array, so callers may keep frames without them
        # aliasing each other.
        self.zero_copy_rx = zero_copy_rx
        self._rx_bufs: dict[Any, np.ndarray] = {}

    def _rx_out(self, key: Any, y: jax.Array, *,
                last: bool) -> list[np.ndarray] | None:
        if not self.zero_copy_rx or last:
            return None
        shape, dtype = tuple(y.shape), np.dtype(y.dtype)
        buf = self._rx_bufs.get(key)
        if buf is None or buf.shape != shape or buf.dtype != dtype:
            buf = np.empty(shape, dtype)
            self._rx_bufs[key] = buf
        return [buf]

    def _frame_end(self) -> None:
        """End-of-frame safe point: the ring is drained (every ticket
        retired), so an adaptive engine may swap its plan generation now
        (no-op on plain engines/groups)."""
        self.engine.maybe_adapt()

    def _register_sensor(self) -> Callable[[], None]:
        """Register ``sensor_fn`` as a SENSOR-class background task on the
        engine's completion backend; returns the unregister callable.
        Both registrars (runtime, cooperative scheduler) share the
        register -> unregister-callable contract, so one wrapper serves
        both. POLLING has no backend: the host is blocked for the
        duration of every transfer — collection starves, which IS the
        paper's result."""
        if self.sensor_fn is None:
            return lambda: None
        mgmt = self.engine.policy.management
        registrar = None
        if mgmt is Management.INTERRUPT:
            registrar = getattr(self.engine, "runtime", None)
        elif mgmt is Management.SCHEDULED:
            registrar = getattr(self.engine, "_scheduler", None)
        if registrar is None:
            return lambda: None
        count = {"n": 0}

        def counted() -> None:
            count["n"] += 1
            self.sensor_fn()

        inner = registrar.register_background(counted)

        def unregister() -> None:
            inner()
            self.sensor_slices += count["n"]
        return unregister

    def run(
        self,
        layers: Sequence[tuple[str, list[np.ndarray], Callable[..., jax.Array]]],
        x: np.ndarray,
    ) -> tuple[np.ndarray, FrameTiming]:
        policy = self.engine.policy
        overlapped = (
            policy.management is Management.INTERRUPT and policy.depth >= 2
        )
        unregister_sensor = self._register_sensor()
        try:
            if overlapped and self.staged:
                out = self._run_overlapped(layers, x)
            else:
                out = self._run_basic(layers, x, prefetch=overlapped)
        finally:
            unregister_sensor()
        self._frame_end()
        return out

    # -- shared input staging ----------------------------------------------
    def _tx_input(self, x: np.ndarray) -> tuple[jax.Array, float, int]:
        t0 = time.perf_counter()
        xa = np.asarray(x)
        dev_chunks = self.engine.tx(xa)
        x_dev = reassemble_chunks(dev_chunks).reshape(xa.shape)
        return x_dev, time.perf_counter() - t0, xa.nbytes

    # -- new path: cached layouts + three-way overlap -----------------------
    def _run_overlapped(self, layers, x) -> tuple[np.ndarray, FrameTiming]:
        engine = self.engine
        policy = engine.policy
        timing = FrameTiming()
        x_dev, input_tx_s, input_bytes = self._tx_input(x)
        if not layers:
            # no layers: the frame is the transferred input itself, not None
            host_out = engine.rx([x_dev])[0]
            return host_out, timing

        layouts: list[StagedLayout] = [
            engine.layouts.get((i, name), params)
            for i, (name, params, _) in enumerate(layers)
        ]

        # TX window: keep up to depth-1 layer streams in flight ahead of the
        # layer being computed (the descriptor-ring in-flight rule; slot
        # `depth` is reserved for the concurrent RX stream).
        tx_window = max(1, policy.depth - 1)
        pending_tx: list[tuple[str, Ticket]] = []  # ("pack"|"sg", ticket)
        next_tx = 0
        # per-layer-set pack-vs-SG gate: few large params ride scatter-gather
        # segments (one ring slot, zero staging memcpy); many small params
        # keep the staged pack. Decisions are memoized per layer key in the
        # LayoutCache and re-priced when the online fit moves the crossover.
        sg_capable = (hasattr(engine, "tx_sg")
                      and hasattr(engine, "prefer_sg")
                      and policy.management is Management.INTERRUPT)

        def issue_tx() -> None:
            nonlocal next_tx
            while next_tx < len(layers) and len(pending_tx) < tx_window:
                name, params, _ = layers[next_tx]
                lay = layouts[next_tx]
                if sg_capable and engine.layouts.decide_sg(
                        (next_tx, name), lay, engine.prefer_sg):
                    pending_tx.append(
                        ("sg", engine.tx_sg(lay.sg_segments(params))))
                else:
                    payload = lay.pack(params)
                    pending_tx.append(
                        ("pack", engine.tx_async(payload, layout=lay)))
                next_tx += 1

        issue_tx()

        pending_rx: tuple[int, Ticket] | None = None  # (layer idx, ticket)
        host_out: np.ndarray | None = None

        def drain_rx() -> None:
            nonlocal pending_rx, host_out
            if pending_rx is None:
                return
            j, ticket = pending_rx
            t0 = time.perf_counter()
            host_out = ticket.wait()[0]
            timing.layers[j].rx_s += time.perf_counter() - t0
            pending_rx = None

        for i, (name, params_host, apply_fn) in enumerate(layers):
            # --- TX: wait for this layer's in-flight params, then refill the
            # ring window (layers i+1 .. i+depth-1 stream during compute)
            t0 = time.perf_counter()
            kind, ticket = pending_tx.pop(0)
            if kind == "sg":
                # SG segments are whole arrays: results arrive shaped, no
                # staging unpack (and no staging buffer was ever touched).
                params_dev = ticket.wait()
            else:
                params_dev = layouts[i].unpack(ticket.wait())
            issue_tx()
            tx_s = time.perf_counter() - t0
            tx_bytes = layouts[i].nbytes
            if i == 0:
                tx_s += input_tx_s
                tx_bytes += input_bytes

            # --- compute (layer k-1's RX and layer k+1's TX are in flight)
            t0 = time.perf_counter()
            y = apply_fn(params_dev, x_dev)
            y.block_until_ready()
            compute_s = time.perf_counter() - t0

            rx_bytes = int(y.size) * y.dtype.itemsize
            timing.layers.append(
                LayerTiming(name, tx_s, compute_s, 0.0, tx_bytes, rx_bytes)
            )
            # --- RX: retire layer k-1's ticket, launch layer k's — an
            # interior fmap streams back into its reused host buffer; the
            # final layer's (the caller's frame result) gets a fresh one
            drain_rx()
            pending_rx = (i, engine.rx_async(
                [y], out=self._rx_out(i, y, last=i == len(layers) - 1)))
            x_dev = y  # next layer consumes device-resident output
        drain_rx()
        return host_out, timing

    # -- legacy/basic path: per-frame pack, serial (or depth-2 TX prefetch) --
    def _run_basic(self, layers, x, *, prefetch: bool) -> tuple[np.ndarray, FrameTiming]:
        timing = FrameTiming()
        x_dev, input_tx_s, input_bytes = self._tx_input(x)
        if not layers:
            host_out = self.engine.rx([x_dev])[0]
            return host_out, timing

        pending: Ticket | None = None
        if prefetch and layers:
            pending = self.engine.tx_async(_pack(layers[0][1]))

        host_out: np.ndarray | None = None
        for i, (name, params_host, apply_fn) in enumerate(layers):
            # --- TX params for this layer
            t0 = time.perf_counter()
            if prefetch:
                chunks = pending.wait()
                params_dev = _unpack(chunks, params_host)
                # issue next layer's TX immediately (overlaps compute below)
                if i + 1 < len(layers):
                    pending = self.engine.tx_async(_pack(layers[i + 1][1]))
            else:
                chunks = self.engine.tx(_pack(params_host))
                params_dev = _unpack(chunks, params_host)
            tx_s = time.perf_counter() - t0
            tx_bytes = sum(np.asarray(p).nbytes for p in params_host)
            if i == 0:
                tx_s += input_tx_s
                tx_bytes += input_bytes

            # --- compute
            t0 = time.perf_counter()
            y = apply_fn(params_dev, x_dev)
            y.block_until_ready()
            compute_s = time.perf_counter() - t0

            # --- RX (per the paper, each layer's output returns to the PS)
            t0 = time.perf_counter()
            host_out = self.engine.rx(
                [y], out=self._rx_out(i, y, last=i == len(layers) - 1))[0]
            rx_s = time.perf_counter() - t0

            timing.layers.append(
                LayerTiming(name, tx_s, compute_s, rx_s, tx_bytes, host_out.nbytes)
            )
            x_dev = y  # next layer consumes device-resident output
        return host_out, timing


def _pack(arrays: list[np.ndarray]) -> np.ndarray:
    """Seed-path pack: flatten a param list into one freshly-allocated
    contiguous payload, every call. Superseded by
    :meth:`repro.core.transfer.StagedLayout.pack`; kept as the measured
    baseline."""
    if not arrays:
        return np.zeros((0,), np.float32)
    return np.concatenate([np.asarray(a).reshape(-1).view(np.uint8) for a in arrays])


def _unpack(chunks: list[jax.Array], ref: list[np.ndarray]) -> list[jax.Array]:
    """Seed-path unpack: re-derives offsets from ``ref`` on every call (see
    :meth:`StagedLayout.unpack` for the cached equivalent)."""
    flat = reassemble_chunks(chunks)
    out, off = [], 0
    for a in ref:
        a = np.asarray(a)
        out.append(_bitcast_from_bytes(flat[off : off + a.nbytes], a.shape,
                                       np.dtype(a.dtype)))
        off += a.nbytes
    return out


def device_streamed_scan(
    layer_fn: Callable[[Any, jax.Array], jax.Array],
    stacked_params: Any,
    x: jax.Array,
    *,
    gather_fn: Callable[[Any], Any] | None = None,
    unroll: int = 1,
) -> jax.Array:
    """On-device per-layer streaming: scan over stacked layer params.

    ``gather_fn`` (if given) materialises one layer's params from their
    sharded/compressed resting state — the device-side analogue of the
    per-layer TX. XLA schedules the gather of iteration k+1 concurrently
    with iteration k's compute when the dependency allows (double buffer)."""

    def body(carry, layer_params):
        if gather_fn is not None:
            layer_params = gather_fn(layer_params)
        return layer_fn(layer_params, carry), None

    y, _ = jax.lax.scan(body, x, stacked_params, unroll=unroll)
    return y
