"""Transfer cost model — the analytical backbone of Fig. 4/5.

The paper's measured curves follow the classic two-parameter DMA model

    t(n) = t0 + n / BW          (per transfer)
    t(n)/n = t0/n + 1/BW        (per byte, the Fig. 5 view)

where ``t0`` is the fixed software overhead of the driver path (descriptor
setup, syscalls/context switches for the kernel driver, polling-loop entry for
the user driver) and ``BW`` the asymptotic link bandwidth. BLOCKS partitioning
with chunk size ``c`` pays the overhead per chunk but overlaps transfers when
DOUBLE-buffered:

    t_blocks(n) = ceil(n/c) * t0 + n/BW                      (single buffer)
    t_blocks(n) = t0 + max(ceil(n/c)-1, 0)*max(t0, c/BW) + c/BW   (double)

The model is used three ways:
1. fit measured host-side sweeps (benchmarks/transfer_sweep.py) and report the
   crossover size between driver modes — the paper's headline observation;
2. napkin math during §Perf hillclimbing (predict chunking deltas);
3. the ICI collective term of the roofline (chunked ring collectives).
"""

from __future__ import annotations

import math
from dataclasses import dataclass

import numpy as np

from repro.core.transfer import Buffering, Partitioning, TransferPolicy


@dataclass(frozen=True)
class TransferCostModel:
    """t(n) = t0 + n/bw, with policy-aware composition."""

    t0_s: float  # fixed per-transfer overhead (s)
    bw_Bps: float  # asymptotic bandwidth (bytes/s)

    def time_unique(self, nbytes: int) -> float:
        return self.t0_s + nbytes / self.bw_Bps

    def time_blocks(self, nbytes: int, block_bytes: int,
                    buffering: Buffering = Buffering.DOUBLE) -> float:
        n_chunks = max(1, math.ceil(nbytes / block_bytes))
        chunk_t = block_bytes / self.bw_Bps
        if buffering is Buffering.SINGLE:
            return n_chunks * (self.t0_s + chunk_t)
        # double buffer: first chunk pays setup+transfer, the rest pipeline at
        # the max of (setup, transfer) rate, plus the final drain.
        steady = max(self.t0_s, chunk_t)
        return self.t0_s + chunk_t + max(n_chunks - 1, 0) * steady

    def time(self, nbytes: int, policy: TransferPolicy) -> float:
        if policy.partitioning is Partitioning.UNIQUE:
            return self.time_unique(nbytes)
        return self.time_blocks(nbytes, policy.block_bytes, policy.buffering)

    def us_per_byte(self, nbytes: int, policy: TransferPolicy) -> float:
        return self.time(nbytes, policy) * 1e6 / max(nbytes, 1)

    def optimal_block_bytes(self, nbytes: int) -> int:
        """Block size that balances per-chunk overhead against overlap.

        With double buffering, steady-state throughput is limited by
        max(t0, c/BW); the smallest c with c/BW >= t0 (i.e. c = t0*BW) keeps
        the pipe full with minimum buffer memory. The paper's 'longer enough
        packets' criterion is exactly n >> t0*BW."""
        c = int(self.t0_s * self.bw_Bps)
        # clamp to [4KiB, nbytes]
        return max(4096, min(max(c, 4096), max(nbytes, 4096)))

    def preempt_chunk_bytes(self, target_service_s: float = 500e-6) -> int:
        """Segment size for preemptive chunked dispatch.

        A parked latency descriptor waits at most one in-service segment,
        so the segment should move for ~``target_service_s`` on the fitted
        link (``BW * target``). But splitting below ~4 fixed overheads per
        segment burns throughput for latency we cannot realize, so the
        overhead floor ``4 * t0 * BW`` wins when the fit says segments that
        small are not free. Rounded up to a power of two so refitted plans
        with near-identical fits compare equal (no swap flapping on
        noise)."""
        by_latency = int(self.bw_Bps * target_service_s)
        floor = int(4.0 * self.t0_s * self.bw_Bps)
        raw = max(4096, floor, by_latency)
        return 1 << int(raw - 1).bit_length()

    # ---- fitting ----------------------------------------------------------
    @staticmethod
    def fit(nbytes: np.ndarray, seconds: np.ndarray) -> "TransferCostModel":
        """Least-squares fit of t = t0 + n/bw over measured (n, t) samples."""
        return TransferCostModel.fit_weighted(nbytes, seconds, None)

    @staticmethod
    def fit_weighted(nbytes: np.ndarray, seconds: np.ndarray,
                     weights: np.ndarray | None) -> "TransferCostModel":
        """Weighted least-squares fit of t = t0 + n/bw.

        ``weights`` (same length as the samples) biases the fit toward
        recent samples — the online refit passes EWMA-decayed weights so a
        drifting t0/BW shows up within a window instead of being averaged
        away by stale history."""
        nbytes = np.asarray(nbytes, dtype=np.float64)
        seconds = np.asarray(seconds, dtype=np.float64)
        a = np.stack([np.ones_like(nbytes), nbytes], axis=1)
        b = seconds
        if weights is not None:
            w = np.sqrt(np.asarray(weights, dtype=np.float64))
            a = a * w[:, None]
            b = b * w
        coef, *_ = np.linalg.lstsq(a, b, rcond=None)
        t0 = float(max(coef[0], 1e-9))
        inv_bw = float(max(coef[1], 1e-15))
        return TransferCostModel(t0_s=t0, bw_Bps=1.0 / inv_bw)

    @staticmethod
    def drift_ratio(a: "TransferCostModel", b: "TransferCostModel") -> float:
        """Largest factor change between two fits, over t0 and BW (>= 1).

        The online controller replans only when this exceeds its hysteresis
        threshold — the 'did the host actually change' test."""
        rt = max(a.t0_s / max(b.t0_s, 1e-12), b.t0_s / max(a.t0_s, 1e-12))
        rb = max(a.bw_Bps / max(b.bw_Bps, 1e-3),
                 b.bw_Bps / max(a.bw_Bps, 1e-3))
        return max(rt, rb)

    def time_pack(self, total_bytes: int, copy_bw_Bps: float) -> float:
        """Staged-pack cost of one layer set: the host memcpy into the
        staging buffer (``total/copy_BW``) plus one UNIQUE descriptor over
        the packed payload — the hot-path price scatter-gather removes."""
        return total_bytes / max(copy_bw_Bps, 1.0) + self.time_unique(
            total_bytes)

    def time_sg(self, sizes: "list[int] | tuple[int, ...]",
                seg_t0_s: float | None = None) -> float:
        """Scatter-gather cost of the same layer set: ONE ring transaction
        whose descriptor walk visits K segments (``seg_t0`` each — the
        ISSUE_RD/WAIT_CPL loop iteration; defaults to the full ``t0``
        until a live refit shrinks it), zero staging copy."""
        seg_t0 = self.t0_s if seg_t0_s is None else seg_t0_s
        return self.t0_s + len(sizes) * seg_t0 + sum(sizes) / self.bw_Bps

    def amortized(self, batch: float) -> "TransferCostModel":
        """The per-descriptor cost model under batched submission: a group
        of ``batch`` descriptors pays the fixed management overhead ONCE
        (one ring transaction, one completion handoff), so each logical
        descriptor sees ``t0 / batch``; bandwidth is unchanged — the
        paper's management-overhead amortization in model form."""
        return TransferCostModel(self.t0_s / max(float(batch), 1.0),
                                 self.bw_Bps)

    @staticmethod
    def crossover_bytes(a: "TransferCostModel", b: "TransferCostModel") -> float:
        """Payload size where model b becomes faster than model a (UNIQUE).

        Solves t0_a + n/bw_a = t0_b + n/bw_b. Returns inf if b never wins,
        0 if b always wins. This is the paper's 'kernel driver wins for
        longer enough packets' threshold."""
        dt0 = b.t0_s - a.t0_s
        dinv = (1.0 / a.bw_Bps) - (1.0 / b.bw_Bps)
        if dinv <= 0:
            return 0.0 if dt0 < 0 else float("inf")
        return max(dt0 / dinv, 0.0)


# TPU v5e hardware constants (the TARGET platform; roofline uses these).
TPU_V5E = {
    "peak_bf16_flops": 197e12,  # per chip
    "hbm_Bps": 819e9,
    "ici_Bps_per_link": 50e9,
    "hbm_bytes": 16 * 2**30,
    "vmem_bytes": 128 * 2**20,
}

# Modeled DMA endpoints on the target system (for napkin math only; the
# container measurements use fitted models instead).
PCIE_H2D = TransferCostModel(t0_s=10e-6, bw_Bps=32e9)   # host->HBM over PCIe4 x16
ICI_LINK = TransferCostModel(t0_s=1e-6, bw_Bps=50e9)    # chip<->chip per link
HBM_VMEM = TransferCostModel(t0_s=0.5e-6, bw_Bps=819e9) # HBM->VMEM DMA
