"""Roofline table: reads dry-run artifacts (artifacts/dryrun*.jsonl) and
renders the per-(arch x shape x mesh) three-term roofline with bottleneck
and useful-FLOPs ratio. This is §Roofline of EXPERIMENTS.md."""

from __future__ import annotations

import glob
import json
import os


def load(pattern: str = "artifacts/dryrun_final*.jsonl") -> list[dict]:
    """Default: the post-§Perf sweep. Pass artifacts/baseline_dryrun*.jsonl
    to render the paper-faithful baseline table."""
    recs = {}
    files = sorted(glob.glob(pattern)) or sorted(
        glob.glob("artifacts/baseline_dryrun*.jsonl"))
    for f in files:
        for line in open(f):
            r = json.loads(line)
            key = (r["arch"], r["shape"], r["multi_pod"])
            recs[key] = r  # last write wins
    return list(recs.values())


def table(recs: list[dict], multi_pod: bool = False) -> str:
    rows = [r for r in recs if r["multi_pod"] == multi_pod]
    rows.sort(key=lambda r: (r["arch"], r["shape"]))
    out = [
        "| arch | shape | compute(s) | memory(s) | collective(s) | "
        "bottleneck | useful | HBM GiB/dev |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        if r["status"] == "skipped":
            out.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                       f"skipped | — | — |")
            continue
        if r["status"] != "ok":
            out.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                       f"ERROR | — | — |")
            continue
        mem = r.get("memory_analysis", {})
        hbm = (mem.get("argument_size_in_bytes", 0)
               + mem.get("temp_size_in_bytes", 0)
               + mem.get("output_size_in_bytes", 0)) / 2**30
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_term_s']:.4f} | "
            f"{r['memory_term_s']:.4f} | {r['collective_term_s']:.5f} | "
            f"{r['bottleneck']} | {r['useful_flops_ratio']:.3f} | "
            f"{hbm:.2f} |")
    return "\n".join(out)


def run() -> list[dict]:
    recs = load()
    rows = []
    for r in recs:
        if r["status"] != "ok":
            continue
        dom = max(("compute_term_s", "memory_term_s", "collective_term_s"),
                  key=lambda k: r[k])
        rows.append({
            "bench": "roofline", "arch": r["arch"], "shape": r["shape"],
            "mesh": "pod2" if r["multi_pod"] else "pod1",
            "dominant_term_s": round(r[dom], 5),
            "bottleneck": r["bottleneck"],
            "useful_flops_ratio": round(r["useful_flops_ratio"], 4),
        })
    return rows


if __name__ == "__main__":
    recs = load()
    print("## single-pod (16x16)\n")
    print(table(recs, multi_pod=False))
    print("\n## multi-pod (2x16x16)\n")
    print(table(recs, multi_pod=True))
