"""Single- vs multi-channel striping at the 50 MiB/layer point, and
static- vs cost-model-adaptive policy — the NEURAghe/ZynqNet multi-channel
DMA lesson measured on this host.

Each row transfers the streaming_layers per-layer payload (48 MiB, the
``payload_bytes_per_layer`` already tracked in ``BENCH_transfer.json``)
host->device through either the PR-1 single-engine descriptor ring or a
:class:`~repro.core.channels.ChannelGroup` striping it across N duplicate
channels, with either the static default policy or the plan a calibrated
:class:`~repro.core.cost_model.TransferCostModel` fit chooses. Results merge
into ``BENCH_transfer.json`` under ``"multichannel"`` so the perf trajectory
stays in one file.

``--quick`` shrinks the payload and repeats for the CI smoke run (and does
not rewrite the JSON).
"""

from __future__ import annotations

import argparse
import json
import pathlib
import time

import numpy as np

from repro.core.channels import ChannelGroup, calibrate_transfer, plan_channels
from repro.core.transfer import TransferEngine, TransferPolicy

BENCH_JSON = pathlib.Path(__file__).resolve().parent.parent / "BENCH_transfer.json"
PAYLOAD_BYTES = 50331648  # streaming_layers' 48 MiB per-layer payload
QUICK_PAYLOAD_BYTES = 8 << 20


def run(repeats: int = 7, quick: bool = False) -> list[dict]:
    payload = QUICK_PAYLOAD_BYTES if quick else PAYLOAD_BYTES
    repeats = 3 if quick else repeats
    x = np.random.default_rng(0).standard_normal(
        payload // 4).astype(np.float32)
    model = calibrate_transfer()
    static = TransferPolicy.kernel_level_ring(4, block_bytes=1 << 20)
    adaptive_single = plan_channels(payload, model=model, max_channels=1)
    adaptive_multi = plan_channels(payload, model=model, max_channels=4)
    if adaptive_multi.n_channels < 2:
        # single-core fallback host: still exercise the striped path
        adaptive_multi = plan_channels(payload, model=model, max_channels=2,
                                       min_stripe_bytes=payload // 2)

    def mk_group(policy, n):
        return ChannelGroup(policy, n_channels=n)

    variants = [
        # the PR-1 hot-path default: one engine, static 1 MiB blocks
        ("single-ring-static", "static", 1,
         TransferEngine(static)),
        ("single-ring-adaptive", "adaptive", 1,
         TransferEngine(adaptive_single.policy)),
        # naive striping ablation: same static policy per channel
        ("2ch-static", "static", 2, mk_group(static, 2)),
        ("4ch-static", "static", 4, mk_group(static, 4)),
        (f"{adaptive_multi.n_channels}ch-adaptive", "adaptive",
         adaptive_multi.n_channels,
         mk_group(adaptive_multi.policy, adaptive_multi.n_channels)),
    ]

    # interleave trials across variants so allocator / page-cache drift hits
    # every engine equally instead of biasing whichever ran last.
    times: dict[str, list[float]] = {name: [] for name, *_ in variants}
    for _, _, _, engine in variants:
        engine.tx(x)  # warmup: prime pools, layouts, allocator arenas
    for _ in range(repeats):
        for name, _, _, engine in variants:
            t0 = time.perf_counter()
            engine.tx(x)
            times[name].append(time.perf_counter() - t0)

    rows = []
    for name, policy_kind, n_ch, engine in variants:
        ts = sorted(times[name])
        best, median = ts[0], ts[len(ts) // 2]
        rows.append({
            "bench": "multichannel_sweep", "variant": name,
            "policy_kind": policy_kind, "n_channels": n_ch,
            "payload_bytes": x.nbytes,
            "policy": engine.policy.tag,
            "tx_ms": round(best * 1e3, 3),
            "tx_ms_median": round(median * 1e3, 3),
            "tx_us_per_byte": round(best * 1e6 / x.nbytes, 6),
            "tx_gbps": round(x.nbytes / max(best, 1e-12) / 1e9, 3),
        })
        engine.close()
    rows.append({
        "bench": "multichannel_sweep", "variant": "calibration",
        "payload_bytes": x.nbytes, **adaptive_multi.row(),
    })
    return rows


def merge_bench_json(rows: list[dict],
                     path: pathlib.Path | str = BENCH_JSON) -> dict:
    """Fold the sweep into BENCH_transfer.json under ``"multichannel"``."""
    path = pathlib.Path(path)
    doc = json.loads(path.read_text()) if path.exists() else {}
    measured = [r for r in rows if "tx_us_per_byte" in r]
    static_single = next(r for r in measured
                         if r["variant"] == "single-ring-static")
    adaptive_single = next((r for r in measured
                            if r["variant"] == "single-ring-adaptive"), None)
    multi = min((r for r in measured if r["n_channels"] >= 2),
                key=lambda r: r["tx_us_per_byte"])
    best = min(measured, key=lambda r: r["tx_us_per_byte"])
    plan = next((r for r in rows if r["variant"] == "calibration"), None)
    doc["multichannel"] = {
        "payload_bytes": measured[0]["payload_bytes"],
        "rows": rows,
        "single_ring_static": static_single,
        "multi_channel_best": multi,
        "overall_best": best,
        # the paper-style headline: striped multi-channel TX vs the PR-1
        # static single-engine ring at the 50 MiB/layer point (>1 = striping
        # + adaptive policy beat the shipped default)
        "tx_us_per_byte_ratio_single_ring_over_multi": round(
            static_single["tx_us_per_byte"]
            / max(multi["tx_us_per_byte"], 1e-12), 3),
        # like-for-like striping effect with the policy held adaptive on
        # both sides (>1 = striping itself wins; <1 = the adaptive single
        # ring already saturates this host's copy engines)
        "tx_us_per_byte_ratio_adaptive_single_over_multi": (round(
            adaptive_single["tx_us_per_byte"]
            / max(multi["tx_us_per_byte"], 1e-12), 3)
            if adaptive_single else None),
        "adaptive_plan": plan,
    }
    path.write_text(json.dumps(doc, indent=2) + "\n")
    return doc


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="small payload, no JSON rewrite (CI smoke)")
    ap.add_argument("--repeats", type=int, default=7)
    args = ap.parse_args()
    bench_rows = run(repeats=args.repeats, quick=args.quick)
    for r in bench_rows:
        print(r)
    if not args.quick:
        doc = merge_bench_json(bench_rows)
        mc = doc["multichannel"]
        print(f"wrote {BENCH_JSON}: single-ring/multi tx us/B ratio "
              f"{mc['tx_us_per_byte_ratio_single_ring_over_multi']}")
