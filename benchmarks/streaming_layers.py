"""Per-layer weight-streaming benchmark (the NullHop execution model on an
LM): serve one decode step while layer k+1's params stream host->device
under each policy. Measures the overlap gain of INTERRUPT+DOUBLE vs POLLING
— the paper's central claim at LM scale."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.streaming import HostStreamingExecutor
from repro.core.transfer import (
    Buffering,
    Management,
    Partitioning,
    TransferEngine,
    TransferPolicy,
)


def _mlp_layers(n_layers: int, d: int, f: int, key):
    """n_layers gated-MLP blocks as (name, host_params, apply)."""
    layers = []

    def apply_fn(params, x):
        wi, wo = params
        h = x @ wi
        gate, up = jnp.split(h, 2, axis=-1)
        return (jax.nn.silu(gate) * up) @ wo

    jitted = jax.jit(apply_fn)
    for i in range(n_layers):
        k1, k2, key = jax.random.split(key, 3)
        wi = np.asarray(jax.random.normal(k1, (d, 2 * f)) * 0.02,
                        np.float32)
        wo = np.asarray(jax.random.normal(k2, (f, d)) * 0.02, np.float32)
        layers.append((f"mlp{i}", [wi, wo], jitted))
    return layers


def run(n_layers: int = 8, d: int = 1024, f: int = 4096) -> list[dict]:
    key = jax.random.PRNGKey(0)
    layers = _mlp_layers(n_layers, d, f, key)
    x = np.asarray(jax.random.normal(key, (8, d)), np.float32)
    rows = []
    for name, policy in [
        ("polling-unique", TransferPolicy.user_level_polling()),
        ("interrupt-single", TransferPolicy.kernel_level()),
        ("interrupt-double-prefetch", TransferPolicy(
            Management.INTERRUPT, Buffering.DOUBLE, Partitioning.UNIQUE)),
    ]:
        ex = HostStreamingExecutor(TransferEngine(policy))
        ex.run(layers, x)  # warmup
        best = None
        for _ in range(3):
            _, timing = ex.run(layers, x)
            if best is None or timing.frame_s < best.frame_s:
                best = timing
        tx = sum(l.tx_s for l in best.layers)
        comp = sum(l.compute_s for l in best.layers)
        rows.append({
            "bench": "streaming_layers", "policy": name,
            "frame_ms": round(best.frame_s * 1e3, 2),
            "tx_ms": round(tx * 1e3, 2),
            "compute_ms": round(comp * 1e3, 2),
            "tx_hidden_frac": round(max(0.0, 1 - tx / max(best.frame_s
                                                          - comp, 1e-9))
                                    if best.frame_s > comp else 1.0, 3),
            "bytes_per_layer": best.layers[1].tx_bytes,
        })
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
