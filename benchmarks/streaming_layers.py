"""Per-layer weight-streaming benchmark (the NullHop execution model on an
LM): serve one decode step while layer k+1's params stream host->device
under each policy. Measures the overlap gain of the cached-layout descriptor
ring (``staged-ring``) against the seed per-frame pack path (``seed-pack``)
— the paper's central claim at LM scale. Emits the old-vs-new comparison to
``BENCH_transfer.json`` so the perf trajectory is tracked across PRs."""

from __future__ import annotations

import json
import pathlib

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.streaming import HostStreamingExecutor
from repro.core.transfer import (
    Buffering,
    Management,
    Partitioning,
    TransferEngine,
    TransferPolicy,
)

BENCH_JSON = pathlib.Path(__file__).resolve().parent.parent / "BENCH_transfer.json"


def _mlp_layers(n_layers: int, d: int, f: int, key):
    """n_layers gated-MLP blocks as (name, host_params, apply)."""
    layers = []

    def apply_fn(params, x):
        wi, wo = params
        h = x @ wi
        gate, up = jnp.split(h, 2, axis=-1)
        return (jax.nn.silu(gate) * up) @ wo

    jitted = jax.jit(apply_fn)
    for i in range(n_layers):
        k1, k2, key = jax.random.split(key, 3)
        wi = np.asarray(jax.random.normal(k1, (d, 2 * f)) * 0.02,
                        np.float32)
        wo = np.asarray(jax.random.normal(k2, (f, d)) * 0.02, np.float32)
        layers.append((f"mlp{i}", [wi, wo], jitted))
    return layers


# (row name, path, policy, staged) — seed-pack rows run the per-frame
# np.concatenate path the repo shipped with; staged-ring rows run the
# cached-StagedLayout descriptor-ring path.
def _variants():
    return [
        ("polling-unique", "seed-pack",
         TransferPolicy.user_level_polling(), False),
        ("interrupt-single", "seed-pack",
         TransferPolicy.kernel_level(), False),
        ("interrupt-double-prefetch", "seed-pack", TransferPolicy(
            Management.INTERRUPT, Buffering.DOUBLE, Partitioning.UNIQUE),
         False),
        ("interrupt-double-staged", "staged-ring", TransferPolicy(
            Management.INTERRUPT, Buffering.DOUBLE, Partitioning.UNIQUE),
         True),
        ("interrupt-ring4-staged", "staged-ring", TransferPolicy(
            Management.INTERRUPT, Buffering.RING, Partitioning.UNIQUE,
            ring_depth=4), True),
    ]


def run(n_layers: int = 8, d: int = 1024, f: int = 4096,
        repeats: int = 3) -> list[dict]:
    key = jax.random.PRNGKey(0)
    layers = _mlp_layers(n_layers, d, f, key)
    x = np.asarray(jax.random.normal(key, (8, d)), np.float32)
    rows = []
    for name, path, policy, staged in _variants():
        engine = TransferEngine(policy)
        ex = HostStreamingExecutor(engine, staged=staged)
        ex.run(layers, x)  # warmup
        best = None
        for _ in range(repeats):
            _, timing = ex.run(layers, x)
            if best is None or timing.frame_s < best.frame_s:
                best = timing
        tx = sum(l.tx_s for l in best.layers)
        rx = sum(l.rx_s for l in best.layers)
        comp = sum(l.compute_s for l in best.layers)
        rows.append({
            "bench": "streaming_layers", "policy": name, "path": path,
            "frame_ms": round(best.frame_s * 1e3, 3),
            "frames_per_s": round(1.0 / max(best.frame_s, 1e-9), 2),
            "tx_ms": round(tx * 1e3, 3),
            "rx_ms": round(rx * 1e3, 3),
            "compute_ms": round(comp * 1e3, 3),
            "tx_us_per_byte": round(best.tx_us_per_byte, 6),
            "tx_hidden_frac": round(max(0.0, 1 - tx / max(best.frame_s
                                                          - comp, 1e-9))
                                    if best.frame_s > comp else 1.0, 3),
            "bytes_per_layer": best.layers[1].tx_bytes,
        })
        engine.close()
    return rows


def write_bench_json(rows: list[dict] | None = None,
                     path: pathlib.Path | str = BENCH_JSON) -> dict:
    """Write the old-vs-new transfer comparison to BENCH_transfer.json,
    preserving sections other benchmarks merged in (e.g. ``multichannel``
    from benchmarks/multichannel_sweep.py)."""
    rows = rows if rows is not None else run()
    seed = min((r for r in rows if r["path"] == "seed-pack"
                and r["policy"].startswith("interrupt")),
               key=lambda r: r["frame_ms"])
    ring = min((r for r in rows if r["path"] == "staged-ring"),
               key=lambda r: r["frame_ms"])
    path = pathlib.Path(path)
    doc = json.loads(path.read_text()) if path.exists() else {}
    doc.update({
        "bench": "streaming_layers",
        "payload_bytes_per_layer": ring["bytes_per_layer"],
        "rows": rows,
        "seed_pack_best": seed,
        "staged_ring_best": ring,
        "tx_us_per_byte_ratio_seed_over_ring": round(
            seed["tx_us_per_byte"] / max(ring["tx_us_per_byte"], 1e-12), 3),
        "frames_per_s_ratio_ring_over_seed": round(
            ring["frames_per_s"] / max(seed["frames_per_s"], 1e-12), 3),
    })
    path.write_text(json.dumps(doc, indent=2) + "\n")
    return doc


if __name__ == "__main__":
    bench_rows = run()
    for r in bench_rows:
        print(r)
    doc = write_bench_json(bench_rows)
    print(f"wrote {BENCH_JSON}: ring/seed frames_per_s ratio "
          f"{doc['frames_per_s_ratio_ring_over_seed']}")
