"""Fig 4/5 reproduction: transfer time vs payload (8 B -> 6 MB) for the
three driver modes. Measured on this machine's host<->device path; the
quantities compared are the ones the paper compares (fixed overhead vs
asymptotic bandwidth, per-byte crossover)."""

from __future__ import annotations

import numpy as np

from repro.core.cost_model import TransferCostModel
from repro.core.transfer import TransferEngine, TransferPolicy
from repro.utils.timing import bench

SIZES = [8, 64, 512, 4 << 10, 32 << 10, 256 << 10, 1 << 20, 6 << 20]

DRIVERS = [
    ("user_level", TransferPolicy.user_level_polling),
    ("user_level_scheduled", TransferPolicy.user_level_scheduled),
    ("kernel_level", TransferPolicy.kernel_level),
]


def run(iters: int = 5) -> list[dict]:
    rows = []
    fits = {}
    for name, mk in DRIVERS:
        samples_n, samples_t = [], []
        for nbytes in SIZES:
            x = np.zeros(max(nbytes // 4, 2), np.float32)

            def one(x=x, mk=mk):
                eng = TransferEngine(mk())
                dev = eng.tx(x)
                eng.rx(dev)
                return eng

            t = bench(one, warmup=2, iters=iters)
            # split tx/rx from a fresh engine's stats
            eng = one()
            tx_s = eng.stats[0].wall_s
            rx_s = eng.stats[1].wall_s
            rows.append({
                "bench": "transfer_sweep", "driver": name, "bytes": x.nbytes,
                "roundtrip_ms": t.median_s * 1e3,
                "tx_us_per_byte": tx_s * 1e6 / x.nbytes,
                "rx_us_per_byte": rx_s * 1e6 / x.nbytes,
            })
            samples_n.append(x.nbytes)
            samples_t.append(t.median_s)
        fits[name] = TransferCostModel.fit(np.asarray(samples_n),
                                           np.asarray(samples_t))
    # paper's headline: crossover where kernel-level beats user-level
    cross = TransferCostModel.crossover_bytes(fits["user_level"],
                                              fits["kernel_level"])
    rows.append({
        "bench": "transfer_sweep", "driver": "crossover",
        "bytes": int(min(cross, 1 << 30)),
        "user_t0_us": fits["user_level"].t0_s * 1e6,
        "user_gbps": fits["user_level"].bw_Bps / 1e9,
        "kernel_t0_us": fits["kernel_level"].t0_s * 1e6,
        "kernel_gbps": fits["kernel_level"].bw_Bps / 1e9,
    })
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
