"""Fig 4/5 reproduction: transfer time vs payload (8 B -> 6 MB) for the
three driver modes plus the depth-4 descriptor ring. Measured on this
machine's host<->device path; the quantities compared are the ones the paper
compares (fixed overhead vs asymptotic bandwidth, per-byte crossover).

``--quick`` runs a three-size smoke sweep (used by scripts/ci.sh so the
bench can't silently rot)."""

from __future__ import annotations

import argparse

import numpy as np

from repro.core.cost_model import TransferCostModel
from repro.core.transfer import TransferEngine, TransferPolicy
from repro.utils.timing import bench

SIZES = [8, 64, 512, 4 << 10, 32 << 10, 256 << 10, 1 << 20, 6 << 20]
QUICK_SIZES = [4 << 10, 256 << 10, 1 << 20]

DRIVERS = [
    ("user_level", TransferPolicy.user_level_polling),
    ("user_level_scheduled", TransferPolicy.user_level_scheduled),
    ("kernel_level", TransferPolicy.kernel_level),
    ("kernel_level_ring4", lambda: TransferPolicy.kernel_level_ring(
        4, block_bytes=256 << 10)),
]


def run(iters: int = 5, quick: bool = False) -> list[dict]:
    sizes = QUICK_SIZES if quick else SIZES
    rows = []
    fits = {}
    for name, mk in DRIVERS:
        samples_n, samples_t = [], []
        for nbytes in sizes:
            x = np.zeros(max(nbytes // 4, 2), np.float32)

            def one(x=x, mk=mk):
                eng = TransferEngine(mk())
                dev = eng.tx(x)
                eng.rx(dev)
                eng.close()
                return eng

            t = bench(one, warmup=1 if quick else 2,
                      iters=max(2, iters // 2) if quick else iters)
            # split tx/rx from a fresh engine's stats
            eng = one()
            tx_s = eng.stats[0].wall_s
            rx_s = eng.stats[1].wall_s
            rows.append({
                "bench": "transfer_sweep", "driver": name, "bytes": x.nbytes,
                "roundtrip_ms": t.median_s * 1e3,
                "tx_us_per_byte": tx_s * 1e6 / x.nbytes,
                "rx_us_per_byte": rx_s * 1e6 / x.nbytes,
            })
            samples_n.append(x.nbytes)
            samples_t.append(t.median_s)
        fits[name] = TransferCostModel.fit(np.asarray(samples_n),
                                           np.asarray(samples_t))
    # paper's headline: crossover where kernel-level beats user-level
    cross = TransferCostModel.crossover_bytes(fits["user_level"],
                                              fits["kernel_level"])
    rows.append({
        "bench": "transfer_sweep", "driver": "crossover",
        "bytes": int(min(cross, 1 << 30)),
        "user_t0_us": fits["user_level"].t0_s * 1e6,
        "user_gbps": fits["user_level"].bw_Bps / 1e9,
        "kernel_t0_us": fits["kernel_level"].t0_s * 1e6,
        "kernel_gbps": fits["kernel_level"].bw_Bps / 1e9,
        "ring_gbps": fits["kernel_level_ring4"].bw_Bps / 1e9,
    })
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="3-size smoke sweep for CI")
    ap.add_argument("--iters", type=int, default=5)
    args = ap.parse_args()
    for r in run(iters=args.iters, quick=args.quick):
        print(r)
