"""Throughput recovery under a stalled channel: quarantine + replan, measured.

The PR-6 acceptance scenario. A ChannelGroup stripes a large payload over
N modelled DMA channels (sleep-modelled service time ``t0 + n/BW`` per
descriptor, same idiom as ``adaptive_drift``), with a
:class:`~repro.core.faults.FaultInjector` composed OVER the model through
the ``engine_factory`` seam. One channel is stalled (every descriptor on
it pays an extra ``STALL_S`` of service time — the silently-degraded
channel the paper's interrupt-management safety argument is about). Three
variants:

- ``baseline``   — all channels healthy; the fault-free throughput.
- ``faulted``    — 1 of N stalled, self-healing OFF: every striped
  transfer waits out the slow stripe, so delivered bandwidth collapses to
  roughly ``stripe_time / (stripe_time + STALL_S)`` of baseline.
- ``recovered``  — same stall, self-healing ON: drift detection pulls the
  stalled channel from the stripe rotation (measured seconds/byte median
  vs the healthy group), stripes re-spread over the remaining N-1
  channels, and throughput returns to ~(N-1)/N of baseline.

Headline: ``recovery_ratio = recovered_gbps / baseline_gbps``. The chaos
CI lane gates on ``recovery_ratio >= 0.8`` (with N=8 channels the ideal
is 7/8 = 0.875) — the process exits non-zero below the floor, in
``--quick`` mode too. Full runs merge results into
``BENCH_transfer.json`` under ``"fault_recovery"``.
"""

from __future__ import annotations

import argparse
import json
import pathlib
import sys
import threading
import time

import numpy as np

from repro.core.channels import ChannelGroup
from repro.core.faults import FaultInjector, FaultPlan, RecoveryConfig
from repro.core.transfer import TransferEngine, TransferPolicy

BENCH_JSON = pathlib.Path(__file__).resolve().parent.parent / "BENCH_transfer.json"

N_CHANNELS = 8
PAYLOAD = 32 << 20          # striped 4 MiB per channel when all are healthy
# one chunk per stripe at ANY active-channel count (32/7 MiB still fits):
# per-op accounting stays 1:1 and the 7-channel regime pays no extra
# per-chunk dispatches that would blur the (N-1)/N comparison
BLOCK = 8 << 20
MODEL_T0_S = 100e-6
MODEL_BW_BPS = 2e9          # ~2 ms of modelled service per healthy stripe
STALL_S = 0.05              # the stalled channel pays 25x a healthy stripe
RECOVERY_FLOOR = 0.8        # chaos-lane gate


def modelled_engine_factory(t0_s: float = MODEL_T0_S,
                            bw_Bps: float = MODEL_BW_BPS):
    """Engine whose every descriptor pays ``t0 + n/BW`` of service time.

    Chunks serialize on a per-engine lock (a DMA channel moves one
    descriptor at a time); the lock wait stays OUTSIDE the timed region so
    queueing never pollutes the health samples the drift check reads."""

    class ModelledEngine(TransferEngine):
        def __init__(self, *a, **kw):
            super().__init__(*a, **kw)
            self._model_lock = threading.Lock()

        def _one_timed(self, payload, direction, out=None):
            with self._model_lock:
                return super()._one_timed(payload, direction, out)

        def _one(self, payload, direction, out=None):
            if direction == "tx":
                nbytes = int(np.asarray(payload).nbytes)
            else:
                nbytes = int(payload.size) * payload.dtype.itemsize
            time.sleep(t0_s + nbytes / bw_Bps)
            return super()._one(payload, direction, out)

    return ModelledEngine


def _policy() -> TransferPolicy:
    return TransferPolicy.kernel_level_ring(4, block_bytes=BLOCK)


def _measure_tx(group: ChannelGroup, payload: np.ndarray, iters: int,
                health_every: bool = False) -> float:
    """Delivered TX GB/s over ``iters`` striped transfers."""
    t0 = time.perf_counter()
    for _ in range(iters):
        group.tx(payload)
        if health_every:
            group.check_channel_health()
    dt = time.perf_counter() - t0
    return iters * payload.nbytes / dt / 1e9


def _variant(name: str, *, stall: bool, heal: bool, iters: int,
             warmup: int) -> dict:
    inj = FaultInjector(FaultPlan(seed=0))
    rec = (RecoveryConfig(drift_quarantine_ratio=3.0, health_min_samples=4,
                          probe_interval_s=3600.0)  # no rejoin mid-measure
           if heal else
           RecoveryConfig(drift_quarantine_ratio=None,
                          quarantine_after=10 ** 6))
    g = ChannelGroup(_policy(), n_channels=N_CHANNELS,
                     engine_factory=inj.engine_factory(
                         base=modelled_engine_factory()),
                     recovery=rec)
    payload = np.zeros(PAYLOAD, np.uint8)
    if stall:
        inj.stall(0, on=True, stall_s=STALL_S)
    # warmup: fill health windows; with healing ON this is where the drift
    # check quarantines the stalled channel (measured, not configured)
    for _ in range(warmup):
        g.tx(payload)
        g.check_channel_health()
    gbps = _measure_tx(g, payload, iters, health_every=heal)
    ledger = g.fault_state.summary()
    row = {
        "bench": "fault_recovery", "variant": name,
        "n_channels": N_CHANNELS, "payload_mib": PAYLOAD >> 20,
        "stall_s": STALL_S if stall else 0.0,
        "self_healing": heal,
        "tx_gbps": round(gbps, 3),
        "quarantined": sorted(g.quarantined),
        "quarantines": ledger["quarantines"],
        "retries": ledger["retries"],
    }
    g.close()
    return row


def run(quick: bool = False) -> list[dict]:
    iters = 4 if quick else 12
    warmup = 5  # >= health_min_samples stripes per channel + one verdict
    rows = [
        _variant("baseline", stall=False, heal=False, iters=iters,
                 warmup=2),
        _variant("faulted", stall=True, heal=False, iters=max(2, iters // 2),
                 warmup=1),
        _variant("recovered", stall=True, heal=True, iters=iters,
                 warmup=warmup),
    ]
    base = next(r for r in rows if r["variant"] == "baseline")["tx_gbps"]
    fault = next(r for r in rows if r["variant"] == "faulted")["tx_gbps"]
    rec = next(r for r in rows if r["variant"] == "recovered")
    rows.append({
        "bench": "fault_recovery", "variant": "headline",
        "recovery_ratio": round(rec["tx_gbps"] / max(base, 1e-9), 3),
        "degraded_ratio": round(fault / max(base, 1e-9), 3),
        "recovered_quarantined": rec["quarantined"],
        "recovery_floor": RECOVERY_FLOOR,
    })
    return rows


def merge_bench_json(rows: list[dict],
                     path: pathlib.Path | str = BENCH_JSON) -> dict:
    """Fold the recovery run into BENCH_transfer.json."""
    path = pathlib.Path(path)
    doc = json.loads(path.read_text()) if path.exists() else {}
    head = next(r for r in rows if r["variant"] == "headline")
    by = {r["variant"]: r for r in rows}
    doc["fault_recovery"] = {
        "rows": rows,
        "baseline_gbps": by["baseline"]["tx_gbps"],
        "faulted_gbps": by["faulted"]["tx_gbps"],
        "recovered_gbps": by["recovered"]["tx_gbps"],
        "recovery_ratio": head["recovery_ratio"],
        "degraded_ratio": head["degraded_ratio"],
        "quarantines": by["recovered"]["quarantines"],
    }
    path.write_text(json.dumps(doc, indent=2) + "\n")
    return doc


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="small iteration counts, no JSON rewrite (CI "
                         "chaos lane); the recovery-ratio gate still "
                         "applies")
    args = ap.parse_args()
    bench_rows = run(quick=args.quick)
    for r in bench_rows:
        print(r)
    head = next(r for r in bench_rows if r["variant"] == "headline")
    if not args.quick:
        merge_bench_json(bench_rows)
        print(f"wrote {BENCH_JSON}: recovery_ratio "
              f"{head['recovery_ratio']} (degraded "
              f"{head['degraded_ratio']})")
    if head["recovery_ratio"] < RECOVERY_FLOOR:
        print(f"FAIL: recovery_ratio {head['recovery_ratio']} < "
              f"{RECOVERY_FLOOR} — quarantine+replan did not restore "
              "throughput", file=sys.stderr)
        sys.exit(1)
    if not head["recovered_quarantined"]:
        print("FAIL: stalled channel was never quarantined",
              file=sys.stderr)
        sys.exit(1)
