"""Blocks-mode collective benchmark: compare the HLO of a monolithic
all-gather+matmul against the chunked ppermute ring (overlapped_matmul_ag)
— per-step collective bytes, op counts, and the overlap structure. Runs in
a subprocess with 8 fake devices (compile-only analysis, like the dry-run)."""

from __future__ import annotations

import os
import subprocess
import sys

_CODE = r"""
import jax, jax.numpy as jnp, json
from jax.sharding import PartitionSpec as P
from jax import shard_map
from repro.core import pipeline_collectives as pc
from repro.launch.hlo_cost import analyze

mesh = jax.make_mesh((8,), ("m",), axis_types=(jax.sharding.AxisType.Auto,))
M, K, N = 1024, 2048, 2048
x = jax.ShapeDtypeStruct((M, K), jnp.bfloat16)
w = jax.ShapeDtypeStruct((K, N // 8), jnp.bfloat16)

def unique_mode(a, b):
    ag = jax.lax.all_gather(a, "m", axis=0, tiled=True)
    return ag @ b

def blocks_mode(a, b):
    return pc.overlapped_matmul_ag(a, b, "m")

out = {}
for name, fn in [("unique", unique_mode), ("blocks", blocks_mode)]:
    g = shard_map(fn, mesh=mesh, in_specs=(P("m", None), P(None, None)),
                  out_specs=P("m", None))
    c = jax.jit(g).lower(x, w).compile()
    cost = analyze(c.as_text(), 8)
    hlo = c.as_text()
    out[name] = {
        "collective_bytes": cost.collective_bytes,
        "by_kind": cost.collective_by_kind,
        "flops": cost.flops,
        "n_allgather": hlo.count(" all-gather("),
        "n_ppermute": hlo.count(" collective-permute("),
    }
print(json.dumps(out))
"""


def run() -> list[dict]:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = "src"
    proc = subprocess.run([sys.executable, "-c", _CODE], env=env,
                          capture_output=True, text=True,
                          cwd=os.path.dirname(os.path.dirname(
                              os.path.abspath(__file__))), timeout=600)
    if proc.returncode != 0:
        return [{"bench": "collective_overlap", "error": proc.stderr[-300:]}]
    import json
    data = json.loads(proc.stdout.strip().splitlines()[-1])
    rows = []
    for mode, d in data.items():
        rows.append({
            "bench": "collective_overlap", "mode": mode,
            "collective_bytes_per_dev": d["collective_bytes"],
            "flops_per_dev": d["flops"],
            "n_allgather": d["n_allgather"],
            "n_ppermute": d["n_ppermute"],
        })
    # derived: blocks mode exposes per-chunk overlap (n_ppermute steps whose
    # comm hides under the chunk dot) at equal total bytes
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
